"""Algorithm 1 — power/crosstalk-aware dynamic sparse training — and the
weight/mask export pipeline for the rust deployment path.

Run as a module from ``python/``:

    python -m compile.dst --out ../artifacts/trained --steps 600

Trains CNN-3 on the synthetic FashionMNIST-shaped dataset with structured
row-column masks per §3.3.5 (interleaved row init, power-minimized column
init, cosine-decayed prune/grow on column ℓ2 norm / gradient norm with
minimum-rerouter-power combination selection), then exports:

* ``<out>/cnn3/weights.json`` — {layer: {"w": [...], "b": [...]}} with the
  conv weights flattened to the (out, in) im2col layout rust consumes;
* ``<out>/cnn3/masks.json``  — rust ``LayerMask`` JSON (p, q, chunks of
  row/col booleans over the rk1 × ck2 chunk grid).
"""

from __future__ import annotations

import argparse
import itertools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets, model, power

# accelerator chunking (paper defaults): rk1 = ck2 = 64, rerouter width 16
CHUNK_ROWS = 64
CHUNK_COLS = 64
K2 = 16


# --------------------------------------------------------------------------
# mask machinery (numpy; masks are small)
# --------------------------------------------------------------------------

def interleaved_row_mask(n: int, density: float) -> np.ndarray:
    n_zero = int(round((1.0 - density) * n))
    assert n_zero <= n // 2, "interleaved pattern supports <=50% row pruning"
    mask = np.ones(n, dtype=bool)
    pos = n - 1
    for _ in range(n_zero):
        mask[pos] = False
        pos -= 2
    return mask


def best_segment_mask(k2: int, n_active: int, cap: int = 20000) -> np.ndarray:
    """Min-rerouter-power k2-wide segment with exactly n_active ones."""
    if n_active >= k2:
        return np.ones(k2, dtype=bool)
    if n_active == 0:
        return np.zeros(k2, dtype=bool)
    best, best_p = None, np.inf
    for idx in itertools.islice(itertools.combinations(range(k2), n_active), cap):
        m = np.zeros(k2, dtype=bool)
        m[list(idx)] = True
        p = power.rerouter_power_mw(m)
        if p < best_p - 1e-15:
            best, best_p = m, p
    return best


def init_masks(shapes: dict, density: float):
    """Alg. 1 init for every prunable layer. shapes: {name: (out, in)}."""
    s_r = max(density, 0.5)
    s_c = min(density / s_r, 1.0)
    masks = {}
    for name, (out_dim, in_dim) in shapes.items():
        p = -(-out_dim // CHUNK_ROWS)
        q = -(-in_dim // CHUNK_COLS)
        row = interleaved_row_mask(CHUNK_ROWS, s_r)
        seg = best_segment_mask(K2, int(round(s_c * K2)))
        col = np.tile(seg, CHUNK_COLS // K2)
        masks[name] = {
            "p": p, "q": q,
            "row": row,
            # per-chunk column masks, initialized identical
            "cols": [col.copy() for _ in range(p * q)],
        }
    return masks


def flat_layer_masks(masks: dict, shapes: dict):
    """Lift chunk masks to full (out,) row and (in,) col float vectors per
    chunk-grid — used by the training forward. For simplicity (and per the
    paper: one row pattern per layer) we build full-matrix masks."""
    out = {}
    for name, m in masks.items():
        out_dim, in_dim = shapes[name]
        p, q = m["p"], m["q"]
        row_full = np.zeros(p * CHUNK_ROWS, dtype=np.float32)
        for pi in range(p):
            row_full[pi * CHUNK_ROWS:(pi + 1) * CHUNK_ROWS] = m["row"]
        col_full = np.zeros(q * CHUNK_COLS, dtype=np.float32)
        # column masks can differ per chunk; the training mask uses the
        # qi-th chunk's mask for its column range (identical across pi by
        # construction of the update rule below)
        for qi in range(q):
            col_full[qi * CHUNK_COLS:(qi + 1) * CHUNK_COLS] = m["cols"][qi]
        out[name] = {"row": jnp.array(row_full[:out_dim]),
                     "col": jnp.array(col_full[:in_dim])}
    return out


def cosine_death_rate(alpha0: float, t: int, t_end: int) -> float:
    if t >= t_end:
        return 0.0
    return alpha0 / 2.0 * (1.0 + np.cos(t * np.pi / t_end))


def prune_grow(masks: dict, shapes: dict, params, grads, alpha: float,
               density: float, margin: int = 2, cap: int = 2000):
    """One Alg.-1 mask update: per layer, per chunk-column-grid."""
    for name, m in masks.items():
        out_dim, in_dim = shapes[name]
        w = np.asarray(params[name]["w"]).reshape(out_dim, -1)
        g = np.asarray(grads[name]["w"]).reshape(out_dim, -1)
        q = m["q"]
        rows_active = int(m["row"].sum())
        for qi in range(q):
            col = m["cols"][qi]
            lo, hi = qi * CHUNK_COLS, min((qi + 1) * CHUNK_COLS, in_dim)
            width = hi - lo
            # ℓ2 norm per column of this chunk stripe
            l2 = np.linalg.norm(w[:, lo:hi], axis=0)
            gn = np.linalg.norm(g[:, lo:hi], axis=0)
            active = [j for j in range(width) if col[j]]
            n_c = max(1, int(round(alpha * len(active) * 0.5)))
            if len(active) <= n_c:
                continue
            # prune: smallest-ℓ2 candidates, min-power combination
            cand = sorted(active, key=lambda j: l2[j])[:n_c + margin]
            best, best_p = None, np.inf
            for idx in itertools.islice(
                    itertools.combinations(cand, n_c), cap):
                trial = col.copy()
                trial[list(idx)] = False
                pmw = power.mask_power_mw(trial[:CHUNK_COLS], K2)
                if pmw < best_p - 1e-15:
                    best, best_p = idx, pmw
            col[list(best)] = False
            # grow: largest-gradient inactive candidates, min power
            inactive = [j for j in range(width) if not col[j]]
            target_active = int(round(density * CHUNK_ROWS * width /
                                      max(rows_active, 1)))
            n_grow = max(0, min(len(inactive),
                                target_active - int(col[:width].sum())))
            n_grow = min(n_grow, n_c)  # keep exchange balanced
            if n_grow == 0:
                continue
            cand = sorted(inactive, key=lambda j: -gn[j])[:n_grow + margin]
            best, best_p = None, np.inf
            for idx in itertools.islice(
                    itertools.combinations(cand, n_grow), cap):
                trial = col.copy()
                trial[list(idx)] = True
                pmw = power.mask_power_mw(trial[:CHUNK_COLS], K2)
                if pmw < best_p - 1e-15:
                    best, best_p = idx, pmw
            col[list(best)] = True
    return masks


# --------------------------------------------------------------------------
# training
# --------------------------------------------------------------------------

def train_cnn3(steps: int = 600, batch: int = 64, lr: float = 2e-3,
               density: float = 0.3, seed: int = 0, log_every: int = 50):
    ds = datasets.fmnist_like()
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    params = model.init_cnn3(key)
    shapes = {"conv2": (64, 64 * 9)}  # only conv2 is prunable in CNN-3
    masks = init_masks(shapes, density)
    t_end = int(0.8 * steps)
    alpha0 = 0.5

    loss_grad = jax.jit(jax.value_and_grad(model.loss_fn))

    # plain Adam
    mom = jax.tree_util.tree_map(jnp.zeros_like, params)
    vel = jax.tree_util.tree_map(jnp.zeros_like, params)
    b1, b2, eps = 0.9, 0.999, 1e-8

    history = []
    grads_np = None
    for t in range(1, steps + 1):
        x, y = ds.batch(rng, batch)
        fmasks = flat_layer_masks(masks, shapes)
        loss, grads = loss_grad(params, jnp.array(x), jnp.array(y), fmasks)
        mom = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, mom, grads)
        vel = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, vel, grads)
        mh = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** t), mom)
        vh = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** t), vel)
        params = jax.tree_util.tree_map(
            lambda p, m, v: p - lr * m / (jnp.sqrt(v) + eps), params, mh, vh)
        grads_np = grads
        if t % log_every == 0:
            xe, ye = ds.batch(rng, 256)
            acc = float(model.accuracy(params, jnp.array(xe), jnp.array(ye),
                                       flat_layer_masks(masks, shapes)))
            history.append((t, float(loss), acc))
            print(f"step {t:5d}  loss {float(loss):.4f}  acc {acc:.3f}")
        # mask update per "epoch" (every 50 steps here)
        if t % 50 == 0 and t < t_end:
            alpha = cosine_death_rate(alpha0, t, t_end)
            masks = prune_grow(masks, shapes, params, grads_np, alpha, density)
    return params, masks, shapes, history


# --------------------------------------------------------------------------
# export
# --------------------------------------------------------------------------

def export(params, masks, shapes, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    weights = {}
    for name, p in params.items():
        w = np.asarray(p["w"], dtype=np.float64)
        if w.ndim == 4:
            w = w.reshape(w.shape[0], -1)  # (out, in) im2col layout
        weights[name] = {"w": w.reshape(-1).tolist(),
                         "b": np.asarray(p["b"], dtype=np.float64).tolist()}
    with open(os.path.join(out_dir, "weights.json"), "w") as f:
        json.dump(weights, f)

    rust_masks = {}
    for name, m in masks.items():
        chunks = []
        for pi in range(m["p"]):
            for qi in range(m["q"]):
                chunks.append({
                    "row": [bool(v) for v in m["row"]],
                    "col": [bool(v) for v in m["cols"][qi]],
                })
        rust_masks[name] = {"p": m["p"], "q": m["q"], "chunks": chunks}
    with open(os.path.join(out_dir, "masks.json"), "w") as f:
        json.dump(rust_masks, f)
    print(f"exported weights+masks to {out_dir}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/trained")
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--density", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    params, masks, shapes, _ = train_cnn3(steps=args.steps,
                                          density=args.density,
                                          seed=args.seed)
    export(params, masks, shapes, os.path.join(args.out, "cnn3"))


if __name__ == "__main__":
    main()

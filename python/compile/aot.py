"""AOT pipeline: lower the L1/L2 jax functions to HLO **text** artifacts
for the rust PJRT runtime.

Run from ``python/``:  ``python -m compile.aot --out ../artifacts``

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` —
jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction ids which the
image's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Artifacts:
* ``ptc16_noisy.hlo.txt`` — the full noisy 16×16 PTC block forward
  (Pallas kernel, interpret-lowered: crosstalk + IG+LR + OG + PD noise),
  batch 32. Inputs: w(16,16), Γ⁺(256,256), Γ⁻(256,256), row_mask(16),
  col_mask(16), x(32,16), noise(32,16) — all f32. Output: y(32,16).
* ``ptc16_ideal.hlo.txt`` — masked exact MVM, same signature minus Γ/noise.
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels import photonic_mvm as pmvm
from .kernels import ref

K = 16
BATCH = 32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def ptc16_noisy(w, g_pos, g_neg, row_mask, col_mask, x, noise):
    y = pmvm.photonic_mvm(w, x, g_pos, g_neg, row_mask, col_mask, noise,
                          mode=ref.INPUT_GATING_LR, thermal=True,
                          output_gating=True, block_b=BATCH)
    return (y,)


def ptc16_ideal(w, row_mask, col_mask, x):
    return (ref.ideal_mvm(w, x, row_mask, col_mask),)


def lower_artifacts():
    f32 = jnp.float32
    n = K * K
    spec = jax.ShapeDtypeStruct
    noisy = jax.jit(ptc16_noisy).lower(
        spec((K, K), f32), spec((n, n), f32), spec((n, n), f32),
        spec((K,), f32), spec((K,), f32), spec((BATCH, K), f32),
        spec((BATCH, K), f32))
    ideal = jax.jit(ptc16_ideal).lower(
        spec((K, K), f32), spec((K,), f32), spec((K,), f32),
        spec((BATCH, K), f32))
    return {"ptc16_noisy": to_hlo_text(noisy), "ptc16_ideal": to_hlo_text(ideal)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    for name, text in lower_artifacts().items():
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>9} chars to {path}")


if __name__ == "__main__":
    main()

"""Power metric for sparsity masks — python mirror of the rust
``sparsity::power_opt`` (only what Alg. 1 needs at training time).

The power of a column mask is the hold power of the 1×k2 rerouter splitter
tree it programs: a node splitting up:lo active leaves needs phase
``Δφ = 2·arccos(√(up/(up+lo))) − π/2`` at cost ``|Δφ|/π · Pπ / (1−γ(l_s))``.
Balanced masks are cheapest — identical to the rust implementation.
"""

from __future__ import annotations

import numpy as np

from .thermal import gamma

LP_P_PI_MW = 15.02


def mzi_power_mw(delta_phi: float, l_s: float = 9.0) -> float:
    g = float(gamma(l_s))
    return abs(delta_phi) / np.pi * LP_P_PI_MW / (1.0 - g)


def rerouter_power_mw(col_mask: np.ndarray, l_s: float = 9.0) -> float:
    """Hold power of the splitter tree for one k2-wide segment mask."""
    counts = np.asarray(col_mask, dtype=np.int64)
    assert counts.size and (counts.size & (counts.size - 1)) == 0, \
        "segment width must be a power of two"
    total = 0.0
    while counts.size > 1:
        up, lo = counts[0::2], counts[1::2]
        tot = up + lo
        with np.errstate(divide="ignore", invalid="ignore"):
            frac = np.where(tot > 0, up / np.maximum(tot, 1), 0.5)
        phi = 2.0 * np.arccos(np.sqrt(frac)) - np.pi / 2.0
        phi = np.where(tot > 0, phi, 0.0)
        total += float(np.sum(np.abs(phi))) / np.pi * LP_P_PI_MW / (1.0 - float(gamma(l_s)))
        counts = tot
    return total


def mask_power_mw(col_mask: np.ndarray, k2: int, l_s: float = 9.0) -> float:
    """Sum of per-segment rerouter powers for a full chunk column mask."""
    col_mask = np.asarray(col_mask)
    assert col_mask.size % k2 == 0
    return sum(rerouter_power_mw(col_mask[s:s + k2], l_s)
               for s in range(0, col_mask.size, k2))

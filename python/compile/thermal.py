"""Thermal crosstalk model — python mirror of ``rust/src/thermal``.

Implements Eq. 10's γ(d) piecewise fit with the paper's published
coefficients and the Eq. 8–9 phase-sign-dependent coupling matrices for a
``rows × cols`` MZI array. The constants are identical to the rust side;
``python/tests/test_parity.py`` pins a set of golden values shared by both
implementations.
"""

from __future__ import annotations

import numpy as np

# Eq. 10, published fit (R^2 = 0.999 / 0.998).
POLY = np.array([1.0, -1.76e-1, 9.9e-3, -8.30e-6, -1.56e-5, 3.55e-7])
EXP_A0 = 0.217
EXP_A1 = 0.127
BREAK_UM = 23.0


def gamma(d):
    """γ(d) for center distance d in µm (vectorized), clamped to [0, 1]."""
    d = np.maximum(np.asarray(d, dtype=np.float64), 0.0)
    poly = sum(POLY[i] * d**i for i in range(6))
    expo = EXP_A0 * np.exp(-EXP_A1 * d)
    out = np.where(d < BREAK_UM, poly, expo)
    return np.clip(out, 0.0, 1.0)


def coupling_matrices(rows: int, cols: int, l_v: float, l_h: float, l_s: float,
                      cutoff: float = 1e-6):
    """Eq. 9 coupling matrices (Δγ⁺, Δγ⁻) for a rows×cols array.

    Physical row = input index j (pitch ``l_v``), physical column = output
    index i (pitch ``l_h``); flat node index m = j·cols... note: matches the
    rust CouplingModel layout with ``rows`` = k2 and ``cols`` = k1 and flat
    index m = row·cols + col.

    Returns (g_pos, g_neg), each (n, n) with n = rows·cols, row-major
    [victim, aggressor], diagonal zero.
    """
    n = rows * cols
    ri, ci = np.divmod(np.arange(n), cols)
    dy = (ri[None, :] - ri[:, None]) * l_v          # aggressor minus victim
    dx = (ci[None, :] - ci[:, None]) * l_h
    # aggressor positive: heater on upper arm
    d_up_pos = np.hypot(dy, dx)
    d_lo_pos = np.hypot(dy, dx + l_s)
    # aggressor negative: heater on lower arm
    d_up_neg = np.hypot(dy, dx - l_s)
    d_lo_neg = d_up_pos
    g_pos = gamma(d_up_pos) - gamma(d_lo_pos)
    g_neg = gamma(d_up_neg) - gamma(d_lo_neg)
    np.fill_diagonal(g_pos, 0.0)
    np.fill_diagonal(g_neg, 0.0)
    g_pos[np.abs(g_pos) < cutoff] = 0.0
    g_neg[np.abs(g_neg) < cutoff] = 0.0
    return g_pos.astype(np.float32), g_neg.astype(np.float32)


def perturb_phases(phases, g_pos, g_neg):
    """Eq. 8: Δφ̃ = Δφ + G⁺·max(Δφ,0) + G⁻·max(−Δφ,0). numpy reference."""
    phases = np.asarray(phases, dtype=np.float64)
    pos = np.maximum(phases, 0.0)
    neg = np.maximum(-phases, 0.0)
    return phases + g_pos.astype(np.float64) @ pos + g_neg.astype(np.float64) @ neg

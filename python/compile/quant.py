"""Quantization for the L2 model (§4.1): LSQ-style fake-quant with a
straight-through estimator — b_w-bit symmetric signed per-tensor weights,
b_in-bit unsigned activations."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _ste_round(x):
    """round() with a straight-through gradient."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def fake_quant_weight(w, bits: int = 8):
    """Symmetric signed per-tensor fake quantization."""
    levels = 2.0 ** (bits - 1) - 1.0
    scale = jnp.maximum(jnp.max(jnp.abs(w)), 1e-12) / levels
    q = jnp.clip(_ste_round(w / scale), -levels, levels)
    return q * scale


def fake_quant_act(x, bits: int = 6):
    """Unsigned fake quantization over the observed dynamic range."""
    levels = 2.0 ** bits - 1.0
    hi = jnp.maximum(jnp.max(x), 1e-12)
    q = jnp.clip(_ste_round(x / hi * levels), 0.0, levels)
    return q / levels * hi

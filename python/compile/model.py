"""Layer-2 JAX model: the paper's CNN-3 (C64K3-C64K3-Pool5-FC10) with
structured row-column masks and quantization-aware forward.

Two forward paths share the same parameters:

* ``forward`` — the differentiable training path (masked + fake-quantized
  weights, exact conv math; the paper trains without noise injection);
* ``deploy_block_mvm`` — the deployment-fidelity path for one PTC block,
  calling the L1 Pallas kernel (crosstalk + gating + LR + PD noise). This
  is what ``aot.py`` lowers for the rust runtime.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import quant
from .kernels import photonic_mvm as pmvm
from .kernels import ref as kref


def init_cnn3(key) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)

    def conv_init(k, shape):
        fan_in = np.prod(shape[1:])
        return jax.random.normal(k, shape) * np.sqrt(2.0 / fan_in)

    return {
        "conv1": {"w": conv_init(k1, (64, 1, 3, 3)), "b": jnp.zeros(64)},
        "conv2": {"w": conv_init(k2, (64, 64, 3, 3)), "b": jnp.zeros(64)},
        "fc": {"w": conv_init(k3, (10, 64 * 5 * 5)), "b": jnp.zeros(10)},
    }


def _apply_mask(w2d, mask):
    """mask = dict(row=(Co,), col=(Cin·K²,)) float {0,1} vectors."""
    if mask is None:
        return w2d
    return w2d * mask["row"][:, None] * mask["col"][None, :]


def forward(params, x, masks=None, b_w: int = 8, b_in: int = 6):
    """Training/eval forward. x: (B, 1, 28, 28). Returns logits (B, 10).

    ``masks``: {layer: {"row": (out,), "col": (in,)}} float masks over the
    *unfolded* (out, in) weight matrices, matching the rust chunk layout.
    """
    masks = masks or {}

    def conv(name, x, stride=1):
        w = params[name]["w"]
        co, ci, kh, kw = w.shape
        w2d = quant.fake_quant_weight(w.reshape(co, -1), b_w)
        w2d = _apply_mask(w2d, masks.get(name))
        wq = w2d.reshape(co, ci, kh, kw)
        y = jax.lax.conv_general_dilated(
            x, wq, window_strides=(stride, stride), padding="SAME",
            dimension_numbers=("NCHW", "OIHW", "NCHW"))
        return y + params[name]["b"][None, :, None, None]

    x = quant.fake_quant_act(x, b_in)
    x = jax.nn.relu(conv("conv1", x))
    x = quant.fake_quant_act(x, b_in)
    x = jax.nn.relu(conv("conv2", x))
    x = quant.fake_quant_act(x, b_in)
    # Pool5: 28 -> 5 via 5x5 average pooling with stride 5 (floor)
    x = jax.lax.reduce_window(x, 0.0, jax.lax.add, (1, 1, 5, 5), (1, 1, 5, 5),
                              "VALID") / 25.0
    x = x.reshape(x.shape[0], -1)
    w2d = quant.fake_quant_weight(params["fc"]["w"], b_w)
    w2d = _apply_mask(w2d, masks.get("fc"))
    return x @ w2d.T + params["fc"]["b"]


def deploy_block_mvm(w_block, x_batch, g_pos, g_neg, row_mask, col_mask,
                     noise, mode=kref.INPUT_GATING_LR, thermal=True,
                     output_gating=True):
    """Deployment-fidelity PTC-block MVM via the Pallas kernel."""
    return pmvm.photonic_mvm(w_block, x_batch, g_pos, g_neg, row_mask,
                             col_mask, noise, mode=mode, thermal=thermal,
                             output_gating=output_gating)


def loss_fn(params, x, y, masks=None):
    logits = forward(params, x, masks)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
    return nll


def accuracy(params, x, y, masks=None):
    logits = forward(params, x, masks)
    return jnp.mean(jnp.argmax(logits, axis=1) == y)

"""Synthetic class-template datasets (offline stand-in for FashionMNIST /
CIFAR; substitution documented in DESIGN.md). Class templates are sums of
random low-frequency 2-D cosines; samples add shifts + pixel noise, so the
tasks are learnable yet non-trivial — the property the paper's accuracy
tables exercise."""

from __future__ import annotations

import numpy as np


class SyntheticDataset:
    def __init__(self, channels: int, height: int, width: int, n_classes: int,
                 seed: int):
        self.channels, self.height, self.width = channels, height, width
        self.n_classes = n_classes
        rng = np.random.default_rng(seed)
        templates = []
        for _ in range(n_classes):
            img = np.zeros((channels, height, width), dtype=np.float64)
            for _ in range(4):
                fx, fy = rng.uniform(0.5, 3.0, size=2)
                phase = rng.uniform(0, 2 * np.pi)
                amp = rng.uniform(0.4, 1.0)
                cw = rng.uniform(0.3, 1.0, size=channels)
                yy, xx = np.meshgrid(np.arange(height), np.arange(width),
                                     indexing="ij")
                wave = np.cos((fx * xx / width + fy * yy / height) * 2 * np.pi
                              + phase)
                img += amp * cw[:, None, None] * wave[None, :, :]
            lo, hi = img.min(), img.max()
            templates.append((img - lo) / max(hi - lo, 1e-9))
        self.templates = np.stack(templates)

    def batch(self, rng: np.random.Generator, n: int):
        """n samples: (images (n,C,H,W) float32 in [0,1], labels (n,))."""
        labels = rng.integers(0, self.n_classes, size=n)
        imgs = self.templates[labels].copy()
        # random +/-2 px shift per sample
        for i in range(n):
            dy, dx = rng.integers(-2, 3, size=2)
            imgs[i] = np.roll(imgs[i], (dy, dx), axis=(1, 2))
        imgs += rng.normal(0.0, 0.08, size=imgs.shape)
        return np.clip(imgs, 0.0, 1.0).astype(np.float32), labels.astype(np.int32)


def fmnist_like() -> SyntheticDataset:
    return SyntheticDataset(1, 28, 28, 10, seed=0xF31)


def cifar10_like() -> SyntheticDataset:
    return SyntheticDataset(3, 32, 32, 10, seed=0xC10)


def cifar100_like() -> SyntheticDataset:
    return SyntheticDataset(3, 32, 32, 100, seed=0xC100)

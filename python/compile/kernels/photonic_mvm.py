"""Layer-1 Pallas kernel: the noisy photonic crossbar MVM.

The compute hot-spot of the SCATTER deployment path: given programmed
weights, the thermal coupling matrices, structured masks, and presampled
PD-noise draws, produce the analog output the chip would produce
(Eqs. 1, 8–14). Lowered with ``interpret=True`` — real-TPU pallas emits a
Mosaic custom-call the CPU PJRT plugin cannot run (see DESIGN.md
§Hardware-Adaptation for the TPU mapping rationale: a 16×16 PTC block is
MXU-tile-shaped, the crosstalk perturbation is a (k1k2)×(k1k2) matmul, and
BlockSpec tiles the batch so Γ stays resident in VMEM across grid steps).

Checked against ``ref.photonic_mvm_ref`` by ``python/tests/test_kernel.py``
(hypothesis sweeps shapes, masks, and modes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _kernel(w_ref, gpos_ref, gneg_ref, rmask_ref, cmask_ref, x_ref, noise_ref,
            y_ref, *, mode: int, thermal: bool, output_gating: bool):
    """One grid step: a batch-block of inputs through one PTC block."""
    w = w_ref[...]              # (k1, k2)
    row_mask = rmask_ref[...]   # (k1,)
    col_mask = cmask_ref[...]   # (k2,)
    x = x_ref[...]              # (Bblk, k2)
    noise = noise_ref[...]      # (Bblk, k1)
    k1, k2 = w.shape

    # steps 1-3: phases -> crosstalk -> realized weights
    active = row_mask[:, None] * col_mask[None, :]
    phi = -jnp.arcsin(jnp.clip(w, -1.0, 1.0)) * active
    if thermal:
        phi_flat = phi.T.reshape(-1)
        pos = jnp.maximum(phi_flat, 0.0)
        neg = jnp.maximum(-phi_flat, 0.0)
        # the MXU-shaped hot op: (n,n) @ (n,) coupling perturbation
        phi_t = phi_flat + gpos_ref[...] @ pos + gneg_ref[...] @ neg
        w_t = -jnp.sin(phi_t.reshape(k2, k1).T)
    else:
        w_t = -jnp.sin(phi)

    # step 4: input intensities
    xx = jnp.maximum(x, 0.0)
    if mode == ref.PRUNE_ONLY:
        u = xx
        lr_gain = jnp.asarray(1.0, dtype=x.dtype)
    elif mode == ref.INPUT_GATING:
        u = xx * col_mask + (1.0 - col_mask) * ref.LEAKAGE_FLOOR
        lr_gain = jnp.asarray(1.0, dtype=x.dtype)
    else:  # IG + LR
        k2_active = jnp.sum(col_mask)
        boost = jnp.where(k2_active > 0, k2 / jnp.maximum(k2_active, 1.0), 0.0)
        u = xx * col_mask * boost
        lr_gain = (k2_active / k2).astype(x.dtype)

    # step 5: accumulate photocurrent + PD noise, TIA gain, OG
    y = u @ w_t.T
    y = y + noise * (ref.PD_NOISE_STD * jnp.sqrt(jnp.asarray(k2, dtype=x.dtype)))
    y = y * lr_gain
    if output_gating:
        y = y * row_mask[None, :]
    y_ref[...] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "thermal", "output_gating",
                                             "block_b"))
def photonic_mvm(w, x, g_pos, g_neg, row_mask, col_mask, noise,
                 mode: int = ref.INPUT_GATING_LR, thermal: bool = True,
                 output_gating: bool = True, block_b: int = 32):
    """Pallas noisy photonic MVM.

    w: (k1, k2); x: (B, k2); noise: (B, k1); masks float {0,1}.
    Returns y: (B, k1). B must be a multiple of ``block_b`` (pad upstream).
    """
    k1, k2 = w.shape
    b = x.shape[0]
    assert b % block_b == 0, f"batch {b} must be a multiple of {block_b}"
    n = k1 * k2
    grid = (b // block_b,)
    kernel = functools.partial(_kernel, mode=mode, thermal=thermal,
                               output_gating=output_gating)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k1, k2), lambda i: (0, 0)),     # weights resident
            pl.BlockSpec((n, n), lambda i: (0, 0)),        # Γ⁺ resident
            pl.BlockSpec((n, n), lambda i: (0, 0)),        # Γ⁻ resident
            pl.BlockSpec((k1,), lambda i: (0,)),
            pl.BlockSpec((k2,), lambda i: (0,)),
            pl.BlockSpec((block_b, k2), lambda i: (i, 0)),  # stream batch
            pl.BlockSpec((block_b, k1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, k1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k1), x.dtype),
        interpret=True,
    )(w, g_pos, g_neg, row_mask, col_mask, x, noise)

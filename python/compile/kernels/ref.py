"""Pure-jnp oracle for the photonic crossbar MVM.

This is the correctness reference for the Pallas kernel
(``photonic_mvm.py``): identical math, no pallas machinery. It is also the
*differentiable* path used by DST training (pallas interpret kernels don't
generally support reverse-mode AD).

Signal chain (Eqs. 1, 8–14):
  1. program phases  φ[i,j] = −arcsin(w[i,j] · active[i,j])
  2. thermal crosstalk  φ̃ = φ + Γ⁺·max(φ,0) + Γ⁻·max(−φ,0)  (flattened
     in physical order m = j·k1 + i)
  3. realized weights  w̃[i,j] = −sin(φ̃)
  4. input intensities by column mode (prune-only / IG / IG+LR)
  5. y_i = Σ_j w̃[i,j]·u_j (+ PD noise), TIA gain k2′/k2 under LR,
     output gating zeroes pruned rows.
"""

from __future__ import annotations

import jax.numpy as jnp

# column modes
PRUNE_ONLY = 0
INPUT_GATING = 1
INPUT_GATING_LR = 2

LEAKAGE_FLOOR = 10.0 ** (-25.0 / 10.0)  # 25 dB extinction ratio
PD_NOISE_STD = 0.01                      # paper §3.3.2


def realized_weights(w, g_pos, g_neg, row_mask, col_mask, thermal: bool):
    """Steps 1–3: crosstalk-perturbed weights. w: (k1, k2)."""
    k1, k2 = w.shape
    active = row_mask[:, None] * col_mask[None, :]
    phi = -jnp.arcsin(jnp.clip(w, -1.0, 1.0)) * active
    if not thermal:
        return -jnp.sin(phi)
    # flatten in physical order: m = j*k1 + i  ->  transpose to (k2, k1)
    phi_flat = phi.T.reshape(-1)
    pos = jnp.maximum(phi_flat, 0.0)
    neg = jnp.maximum(-phi_flat, 0.0)
    phi_t = phi_flat + g_pos @ pos + g_neg @ neg
    return -jnp.sin(phi_t.reshape(k2, k1).T)


def input_intensities(x, col_mask, mode: int):
    """Step 4. x: (..., k2) non-negative normalized inputs."""
    k2 = x.shape[-1]
    x = jnp.maximum(x, 0.0)
    if mode == PRUNE_ONLY:
        return x, jnp.asarray(1.0)
    if mode == INPUT_GATING:
        return x * col_mask + (1.0 - col_mask) * LEAKAGE_FLOOR, jnp.asarray(1.0)
    # IG + LR
    k2_active = jnp.sum(col_mask)
    boost = jnp.where(k2_active > 0, k2 / jnp.maximum(k2_active, 1.0), 0.0)
    lr_gain = k2_active / k2
    return x * col_mask * boost, lr_gain


def photonic_mvm_ref(w, x, g_pos, g_neg, row_mask, col_mask, noise,
                     mode: int = INPUT_GATING_LR, thermal: bool = True,
                     output_gating: bool = True):
    """Noisy photonic MVM oracle.

    w: (k1, k2); x: (B, k2); noise: (B, k1) presampled unit-variance PD
    noise (scaled to 0.01·√k2 inside, Eq. 11); masks are float {0,1}.
    Returns y: (B, k1).
    """
    k2 = w.shape[1]
    w_t = realized_weights(w, g_pos, g_neg, row_mask, col_mask, thermal)
    u, lr_gain = input_intensities(x, col_mask, mode)
    y = u @ w_t.T
    y = y + noise * (PD_NOISE_STD * jnp.sqrt(jnp.asarray(k2, dtype=x.dtype)))
    y = y * lr_gain
    if output_gating:
        y = y * row_mask[None, :]
    return y


def ideal_mvm(w, x, row_mask, col_mask):
    """Masked exact MVM: the golden for N-MAE."""
    wm = w * row_mask[:, None] * col_mask[None, :]
    return x @ wm.T

"""Stdlib-only mirror of the rust integer-quantized kernel algebra.

Mirrors ``rust/src/exec/kernel.rs``'s ``QuantPanel`` — per-row weight
quantization (codes in [-127, 127], fused fold ``(max|w|/127)/1023``),
lane-width row-panel packing with nonzero-column run compression plus
the stride-1 run-compressed tail, and the exact ``i32`` accumulate with
one f64 fold per (row, streamed column) — and cross-validates:

* the packed sweep equals the naive integer reference **bit-for-bit**
  (run compression never drops a nonzero contribution, for any lane
  width, ragged shape, streamed width, or zero pattern);
* the dequantized product tracks the f64 product within the analytic
  quantization-error bound the rust tests assert;
* the ``i32`` accumulator headroom bound from the kernel's module doc.

No jax/numpy on purpose: this file runs on a bare python3, the same
way ``ci/check_bench.py`` does.  Run directly (``python3
python/tests/test_quant_kernel.py``) or under pytest.
"""

import math
import random

ACT_LEVELS = 1023.0
W_LEVELS = 127.0


def rust_round(x):
    """f64::round(): half away from zero (python's round() is banker's)."""
    return math.floor(x + 0.5) if x >= 0 else math.ceil(x - 0.5)


def quantize(w, nrows, ncols):
    """Per-row weight codes + fused fold factors, as QuantPanel::pack."""
    codes = [0] * (nrows * ncols)
    row_scale = []
    for ri in range(nrows):
        row = w[ri * ncols:(ri + 1) * ncols]
        wmax = max((abs(v) for v in row), default=0.0)
        if wmax == 0.0:
            row_scale.append(0.0)
            continue
        sw = wmax / W_LEVELS
        for ci, wv in enumerate(row):
            codes[ri * ncols + ci] = int(rust_round(wv / sw))
        row_scale.append(sw / ACT_LEVELS)
    return codes, row_scale


def pack(w, nrows, ncols, lanes):
    """Mirror of QuantPanel::pack: lane panels of column runs (weight
    stride ``lanes``) plus stride-1 run-compressed tail rows."""
    assert lanes in (8, 16)
    codes, row_scale = quantize(w, nrows, ncols)
    npanels = nrows // lanes
    panels, runs, wq, tail_rows = [], [], [], []
    for pi in range(npanels):
        base = pi * lanes
        run0 = len(runs)
        live = lambda ci: any(codes[(base + k) * ncols + ci] for k in range(lanes))
        ci = 0
        while ci < ncols:
            if not live(ci):
                ci += 1
                continue
            col0, w_off = ci, len(wq)
            while ci < ncols and live(ci):
                for k in range(lanes):
                    wq.append(codes[(base + k) * ncols + ci])
                ci += 1
            runs.append((col0, ci - col0, w_off))
        panels.append((run0, len(runs) - run0))
    for ri in range(npanels * lanes, nrows):
        run0 = len(runs)
        crow = codes[ri * ncols:(ri + 1) * ncols]
        ci = 0
        while ci < ncols:
            if crow[ci] == 0:
                ci += 1
                continue
            col0, w_off = ci, len(wq)
            while ci < ncols and crow[ci] != 0:
                wq.append(crow[ci])
                ci += 1
            runs.append((col0, ci - col0, w_off))
        tail_rows.append((run0, len(runs) - run0))
    return {
        "nrows": nrows, "ncols": ncols, "lanes": lanes, "panels": panels,
        "runs": runs, "tail_rows": tail_rows, "wq": wq,
        "row_scale": row_scale, "codes": codes,
    }


def packed_cols(p):
    return sum(length for (_c0, length, _w) in p["runs"])


def accumulate(p, xq, bcols, buf):
    """Mirror of the scalar integer sweep: exact integer sums per
    (row, streamed column), one f64 fold each, zero rows skipped.
    Python ints are exact, matching rust's i32 (headroom asserted)."""
    lanes, ncols = p["lanes"], p["ncols"]
    for pi, (run0, nruns) in enumerate(p["panels"]):
        prs = p["runs"][run0:run0 + nruns]
        for r in range(lanes):
            ri = pi * lanes + r
            fr = p["row_scale"][ri]
            if fr == 0.0:
                continue
            for t in range(bcols):
                acc = 0
                for (col0, length, w_off) in prs:
                    for j in range(length):
                        wv = p["wq"][w_off + j * lanes + r]
                        if wv:
                            acc += wv * xq[(col0 + j) * bcols + t]
                buf[ri * bcols + t] += float(acc) * fr
    base = len(p["panels"]) * lanes
    for k, (run0, nruns) in enumerate(p["tail_rows"]):
        ri = base + k
        fr = p["row_scale"][ri]
        if fr == 0.0:
            continue
        for t in range(bcols):
            acc = 0
            for (col0, length, w_off) in p["runs"][run0:run0 + nruns]:
                for j in range(length):
                    acc += p["wq"][w_off + j] * xq[(col0 + j) * bcols + t]
            buf[ri * bcols + t] += float(acc) * fr


def naive_quant(p, xq, bcols):
    """Integer reference straight off the dense code matrix."""
    nrows, ncols = p["nrows"], p["ncols"]
    out = [0.0] * (nrows * bcols)
    for ri in range(nrows):
        fr = p["row_scale"][ri]
        if fr == 0.0:
            continue
        for t in range(bcols):
            acc = sum(p["codes"][ri * ncols + ci] * xq[ci * bcols + t]
                      for ci in range(ncols))
            out[ri * bcols + t] = float(acc) * fr
    return out


def random_problem(rng, nrows, ncols, bcols, zero_frac=0.0):
    w = [0.0 if rng.random() < zero_frac else rng.uniform(-1.0, 1.0)
         for _ in range(nrows * ncols)]
    x = [rng.uniform(0.0, 1.0) for _ in range(ncols * bcols)]
    xq = [int(rust_round(v * ACT_LEVELS)) for v in x]
    return w, x, xq


SHAPES = [(1, 1), (1, 16), (2, 9), (3, 7), (5, 16), (7, 33), (8, 16),
          (9, 5), (16, 16), (17, 40), (24, 12), (33, 65)]


def test_packed_sweep_matches_naive_integer_reference():
    rng = random.Random(0xC0DE)
    for lanes in (8, 16):
        for (nrows, ncols) in SHAPES:
            for bcols in (1, 3, 8, 17, 64, 65):
                for zf in (0.0, 0.5, 0.95):
                    w, _x, xq = random_problem(rng, nrows, ncols, bcols, zf)
                    p = pack(w, nrows, ncols, lanes)
                    buf = [0.0] * (nrows * bcols)
                    accumulate(p, xq, bcols, buf)
                    want = naive_quant(p, xq, bcols)
                    assert buf == want, (lanes, nrows, ncols, bcols, zf)


def test_dequantized_product_tracks_f64_within_bound():
    rng = random.Random(7)
    for (nrows, ncols) in SHAPES:
        for bcols in (1, 8, 17):
            w, x, xq = random_problem(rng, nrows, ncols, bcols)
            p = pack(w, nrows, ncols, 8)
            buf = [0.0] * (nrows * bcols)
            accumulate(p, xq, bcols, buf)
            for ri in range(nrows):
                row = w[ri * ncols:(ri + 1) * ncols]
                wmax = max(abs(v) for v in row)
                # per-term error <= |w - what|*|x| + |what|*|x - xhat|
                # <= wmax/254 + wmax*(1 + 1/254)/2046 per column
                tol = wmax * ncols * (1 / 254 + 1 / 2046) * 1.05 + 1e-9
                for t in range(bcols):
                    exact = sum(row[ci] * x[ci * bcols + t] for ci in range(ncols))
                    got = buf[ri * bcols + t]
                    assert abs(got - exact) <= tol, (nrows, ncols, ri, t,
                                                     got, exact, tol)


def test_zero_and_quantized_to_zero_columns_are_compiled_out():
    # 8x16 panel: cols 4..12 exactly zero, col 0 so small it quantizes
    # to zero on every row -> neither may appear in any run
    nrows, ncols = 8, 16
    w = [0.0] * (nrows * ncols)
    rng = random.Random(3)
    for ri in range(nrows):
        w[ri * ncols] = 1e-4          # quantizes to code 0 (wmax ~ 1)
        w[ri * ncols + 1] = 1.0       # pins wmax
        for ci in range(12, ncols):
            w[ri * ncols + ci] = rng.uniform(-1.0, 1.0)
    p = pack(w, nrows, ncols, 8)
    assert all(p["codes"][ri * ncols] == 0 for ri in range(nrows))
    covered = set()
    for (col0, length, _w) in p["runs"]:
        covered.update(range(col0, col0 + length))
    assert 0 not in covered and not covered & set(range(4, 12))
    assert packed_cols(p) == 5  # col 1 + cols 12..16


def test_tail_rows_are_run_compressed():
    for nrows in (1, 2, 3, 5, 7, 9, 17):
        ncols = 16
        rng = random.Random(nrows)
        w = [rng.uniform(-1.0, 1.0) for _ in range(nrows * ncols)]
        for ri in range(nrows):  # zero a middle span in every row
            for ci in range(4, 12):
                w[ri * ncols + ci] = 0.0
        p = pack(w, nrows, ncols, 8)
        assert len(p["tail_rows"]) == nrows % 8
        for (run0, nruns) in p["tail_rows"]:
            assert nruns == 2  # [0,4) and [12,16)
            spans = sorted((c, c + n) for (c, n, _w) in p["runs"][run0:run0 + nruns])
            assert spans == [(0, 4), (12, 16)]


def test_all_zero_rows_fold_to_exact_zero():
    p = pack([0.0] * 24, 3, 8, 8)
    assert p["runs"] == [] and p["row_scale"] == [0.0, 0.0, 0.0]
    buf = [0.25] * 3
    accumulate(p, [1023] * 8, 1, buf)
    assert buf == [0.25] * 3  # zero rows skipped, no -0.0 fold


def test_i32_accumulator_headroom():
    # worst case |acc| = ncols * 127 * 1023 must clear i32 at the
    # kernel's debug-asserted ncols ceiling (engine blocks cap at 64)
    assert 16_000 * 127 * 1023 < 2**31 - 1
    assert 64 * 127 * 1023 * 250 < 2**31 - 1  # >250x engine margin


if __name__ == "__main__":
    fns = [v for k, v in sorted(globals().items()) if k.startswith("test_")]
    for fn in fns:
        fn()
        print(f"{fn.__name__}: ok")
    print(f"{len(fns)} mirror checks passed")

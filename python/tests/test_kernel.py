"""L1 correctness: the Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, masks, and column modes; fixed tests pin the
physics (Eq.-14 noise scaling, leakage elimination, OG exactness).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import thermal
from compile.kernels import photonic_mvm as pmvm
from compile.kernels import ref


def make_coupling(k1, k2, l_h=20.0):
    return thermal.coupling_matrices(k2, k1, 120.0, l_h, 9.0)


def random_problem(rng, k1, k2, batch):
    w = rng.uniform(-1, 1, (k1, k2)).astype(np.float32)
    x = rng.uniform(0, 1, (batch, k2)).astype(np.float32)
    noise = rng.normal(size=(batch, k1)).astype(np.float32)
    return w, x, noise


@settings(max_examples=20, deadline=None)
@given(
    k=st.sampled_from([4, 8, 16]),
    mode=st.sampled_from([ref.PRUNE_ONLY, ref.INPUT_GATING, ref.INPUT_GATING_LR]),
    thermal_on=st.booleans(),
    og=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_matches_ref(k, mode, thermal_on, og, seed):
    rng = np.random.default_rng(seed)
    gp, gn = make_coupling(k, k)
    w, x, noise = random_problem(rng, k, k, 32)
    rm = (rng.uniform(size=k) > 0.3).astype(np.float32)
    cm = (rng.uniform(size=k) > 0.3).astype(np.float32)
    args = (jnp.array(w), jnp.array(x), jnp.array(gp), jnp.array(gn),
            jnp.array(rm), jnp.array(cm), jnp.array(noise))
    y_ref = ref.photonic_mvm_ref(args[0], args[1], args[2], args[3], args[4],
                                 args[5], args[6], mode=mode,
                                 thermal=thermal_on, output_gating=og)
    y_pal = pmvm.photonic_mvm(args[0], args[1], args[2], args[3], args[4],
                              args[5], args[6], mode=mode, thermal=thermal_on,
                              output_gating=og)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_noiseless_dense_matches_exact_mvm():
    rng = np.random.default_rng(0)
    k = 16
    gp, gn = make_coupling(k, k)
    w, x, _ = random_problem(rng, k, k, 32)
    ones = np.ones(k, np.float32)
    zeros = np.zeros((32, k), np.float32)
    y = ref.photonic_mvm_ref(jnp.array(w), jnp.array(x), jnp.array(gp),
                             jnp.array(gn), jnp.array(ones), jnp.array(ones),
                             jnp.array(zeros), mode=ref.PRUNE_ONLY,
                             thermal=False, output_gating=False)
    np.testing.assert_allclose(np.asarray(y), x @ w.T, rtol=1e-5, atol=1e-5)


def test_lr_eliminates_leakage_and_scales_noise():
    """Eq. 14: LR output = masked ideal + (k2'/k2)·noise exactly (no TV)."""
    rng = np.random.default_rng(1)
    k = 16
    gp, gn = make_coupling(k, k)
    w, x, noise = random_problem(rng, k, k, 32)
    ones = np.ones(k, np.float32)
    cm = (np.arange(k) % 2 == 0).astype(np.float32)  # half active
    y = ref.photonic_mvm_ref(jnp.array(w), jnp.array(x), jnp.array(gp),
                             jnp.array(gn), jnp.array(ones), jnp.array(cm),
                             jnp.array(noise), mode=ref.INPUT_GATING_LR,
                             thermal=False, output_gating=False)
    ideal = np.asarray(ref.ideal_mvm(jnp.array(w), jnp.array(x),
                                     jnp.array(ones), jnp.array(cm)))
    expected = ideal + 0.5 * noise * (0.01 * np.sqrt(k))
    np.testing.assert_allclose(np.asarray(y), expected, rtol=1e-4, atol=1e-5)


def test_ig_leakage_bounded_by_er_floor():
    rng = np.random.default_rng(2)
    k = 8
    gp, gn = make_coupling(k, k)
    w, x, _ = random_problem(rng, k, k, 32)
    zeros = np.zeros((32, k), np.float32)
    ones = np.ones(k, np.float32)
    cm = np.zeros(k, np.float32)  # everything pruned
    y = ref.photonic_mvm_ref(jnp.array(w), jnp.array(x), jnp.array(gp),
                             jnp.array(gn), jnp.array(ones), jnp.array(cm),
                             jnp.array(zeros), mode=ref.INPUT_GATING,
                             thermal=True, output_gating=False)
    # leakage only: bounded by k2 * ER_floor * max|δw|; with φ=0 targets
    # δw is tiny, so outputs must be near zero
    assert float(np.max(np.abs(np.asarray(y)))) < 0.05


def test_output_gating_exact_zero():
    rng = np.random.default_rng(3)
    k = 8
    gp, gn = make_coupling(k, k)
    w, x, noise = random_problem(rng, k, k, 32)
    rm = (np.arange(k) % 2 == 0).astype(np.float32)
    ones = np.ones(k, np.float32)
    y = np.asarray(ref.photonic_mvm_ref(
        jnp.array(w), jnp.array(x), jnp.array(gp), jnp.array(gn),
        jnp.array(rm), jnp.array(ones), jnp.array(noise),
        mode=ref.PRUNE_ONLY, thermal=True, output_gating=True))
    assert np.all(y[:, 1::2] == 0.0)
    assert np.all(y[:, 0::2] != 0.0)


def test_crosstalk_worse_at_tighter_pitch():
    rng = np.random.default_rng(4)
    k = 16
    w, x, _ = random_problem(rng, k, k, 32)
    zeros = np.zeros((32, k), np.float32)
    ones = np.ones(k, np.float32)
    errs = []
    for lh in (16.0, 40.0):
        gp, gn = make_coupling(k, k, l_h=lh)
        y = np.asarray(ref.photonic_mvm_ref(
            jnp.array(w), jnp.array(x), jnp.array(gp), jnp.array(gn),
            jnp.array(ones), jnp.array(ones), jnp.array(zeros),
            mode=ref.PRUNE_ONLY, thermal=True, output_gating=False))
        errs.append(np.mean(np.abs(y - x @ w.T)))
    assert errs[0] > 2.0 * errs[1], errs


@pytest.mark.parametrize("batch", [32, 64, 128])
def test_batch_blocking(batch):
    rng = np.random.default_rng(5)
    k = 8
    gp, gn = make_coupling(k, k)
    w, x, noise = random_problem(rng, k, k, batch)
    ones = np.ones(k, np.float32)
    y_ref = ref.photonic_mvm_ref(jnp.array(w), jnp.array(x), jnp.array(gp),
                                 jnp.array(gn), jnp.array(ones),
                                 jnp.array(ones), jnp.array(noise))
    y_pal = pmvm.photonic_mvm(jnp.array(w), jnp.array(x), jnp.array(gp),
                              jnp.array(gn), jnp.array(ones), jnp.array(ones),
                              jnp.array(noise))
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)

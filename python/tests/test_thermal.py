"""Thermal-model parity: these golden values are pinned on BOTH sides —
rust (`thermal::gamma::tests`, `thermal::coupling::tests`) and here — so
the L1 kernel and the L3 coordinator share one physics."""

import numpy as np

from compile import thermal


def test_gamma_golden_values():
    assert abs(thermal.gamma(0.0) - 1.0) < 1e-12
    assert abs(thermal.gamma(9.0) - 0.13046) < 1e-3
    assert abs(thermal.gamma(5.0) - 0.35781) < 1e-3
    e30 = 0.217 * np.exp(-0.127 * 30.0)
    assert abs(thermal.gamma(30.0) - e30) < 1e-12


def test_gamma_monotone_and_clamped():
    d = np.linspace(0.5, 22.0, 44)
    g = thermal.gamma(d)
    assert np.all(np.diff(g) <= 1e-9)
    assert np.all((g >= 0) & (g <= 1))
    assert thermal.gamma(120.0) < 1e-6


def test_coupling_matrix_matches_rust_single_aggressor():
    # rust test `single_aggressor_perturbs_horizontal_neighbor`: 1x2 row,
    # l_h = 20, l_s = 9 -> victim 0 sees γ(20) − γ(29) from positive
    # aggressor at column 1, γ(11) − γ(20) from a negative one.
    gp, gn = thermal.coupling_matrices(1, 2, 120.0, 20.0, 9.0)
    expect_pos = thermal.gamma(20.0) - thermal.gamma(29.0)
    expect_neg = thermal.gamma(11.0) - thermal.gamma(20.0)
    assert abs(gp[0, 1] - expect_pos) < 1e-6
    assert abs(gn[0, 1] - expect_neg) < 1e-6
    assert gp[0, 0] == 0.0 and gn[1, 1] == 0.0


def test_perturbation_zero_for_zero_phases():
    gp, gn = thermal.coupling_matrices(4, 4, 120.0, 20.0, 9.0)
    out = thermal.perturb_phases(np.zeros(16), gp, gn)
    assert np.all(out == 0.0)


def test_vertical_neighbors_negligible():
    gp, gn = thermal.coupling_matrices(2, 1, 120.0, 20.0, 9.0)
    out = thermal.perturb_phases(np.array([0.0, 1.5]), gp, gn)
    assert abs(out[0]) < 1e-4

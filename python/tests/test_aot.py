"""AOT artifact pipeline: lowering succeeds, HLO text parses, and the
noisy artifact's computation matches the kernel it was lowered from."""

import jax.numpy as jnp
import numpy as np

from compile import aot, thermal
from compile.kernels import photonic_mvm as pmvm
from compile.kernels import ref


def test_lowering_produces_hlo_text():
    arts = aot.lower_artifacts()
    assert set(arts) == {"ptc16_noisy", "ptc16_ideal"}
    for name, text in arts.items():
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        # no Mosaic custom-calls: interpret-mode pallas lowers to plain HLO
        assert "tpu_custom_call" not in text, name


def test_lowered_fn_matches_kernel_numerics():
    rng = np.random.default_rng(0)
    k, b = aot.K, aot.BATCH
    gp, gn = thermal.coupling_matrices(k, k, 120.0, 16.0, 9.0)
    w = rng.uniform(-1, 1, (k, k)).astype(np.float32)
    x = rng.uniform(0, 1, (b, k)).astype(np.float32)
    noise = rng.normal(size=(b, k)).astype(np.float32)
    rm = np.ones(k, np.float32)
    cm = (np.arange(k) % 2 == 0).astype(np.float32)
    (y_art,) = aot.ptc16_noisy(jnp.array(w), jnp.array(gp), jnp.array(gn),
                               jnp.array(rm), jnp.array(cm), jnp.array(x),
                               jnp.array(noise))
    y_kernel = pmvm.photonic_mvm(jnp.array(w), jnp.array(x), jnp.array(gp),
                                 jnp.array(gn), jnp.array(rm), jnp.array(cm),
                                 jnp.array(noise), mode=ref.INPUT_GATING_LR,
                                 thermal=True, output_gating=True,
                                 block_b=b)
    np.testing.assert_allclose(np.asarray(y_art), np.asarray(y_kernel),
                               rtol=1e-5, atol=1e-6)

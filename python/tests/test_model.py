"""L2 model tests: shapes, masking, quantization, and the deploy path."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import datasets, model, quant, thermal
from compile.kernels import ref


def test_cnn3_forward_shape():
    params = model.init_cnn3(jax.random.PRNGKey(0))
    x = jnp.zeros((4, 1, 28, 28))
    logits = model.forward(params, x)
    assert logits.shape == (4, 10)


def test_mask_zeroes_contributions():
    params = model.init_cnn3(jax.random.PRNGKey(1))
    x = jnp.array(np.random.default_rng(0).uniform(0, 1, (2, 1, 28, 28)),
                  dtype=jnp.float32)
    # conv2 fully masked -> logits equal to a model with conv2 weights = 0
    masks = {"conv2": {"row": jnp.zeros(64), "col": jnp.ones(64 * 9)}}
    y_masked = model.forward(params, x, masks)
    params0 = dict(params)
    params0["conv2"] = {"w": params["conv2"]["w"] * 0.0, "b": params["conv2"]["b"]}
    y_zero = model.forward(params0, x)
    np.testing.assert_allclose(np.asarray(y_masked), np.asarray(y_zero),
                               rtol=1e-5, atol=1e-5)


def test_quantizers_bounded_error():
    rng = np.random.default_rng(2)
    w = jnp.array(rng.uniform(-2, 2, (64, 64)), dtype=jnp.float32)
    wq = quant.fake_quant_weight(w, 8)
    scale = float(jnp.max(jnp.abs(w))) / 127.0
    assert float(jnp.max(jnp.abs(wq - w))) <= scale / 2 + 1e-6
    x = jnp.array(rng.uniform(0, 3, (128,)), dtype=jnp.float32)
    xq = quant.fake_quant_act(x, 6)
    assert float(jnp.max(jnp.abs(xq - x))) <= float(jnp.max(x)) / 63.0 + 1e-6


def test_quant_gradients_flow():
    w = jnp.array([[0.5, -0.3], [0.2, 0.9]])
    g = jax.grad(lambda w: jnp.sum(quant.fake_quant_weight(w, 8) ** 2))(w)
    assert np.all(np.isfinite(np.asarray(g)))
    assert float(jnp.max(jnp.abs(g))) > 0


def test_training_reduces_loss():
    ds = datasets.fmnist_like()
    rng = np.random.default_rng(3)
    params = model.init_cnn3(jax.random.PRNGKey(3))
    loss_grad = jax.jit(jax.value_and_grad(model.loss_fn))
    x, y = ds.batch(rng, 64)
    l0, _ = loss_grad(params, jnp.array(x), jnp.array(y))
    lr = 2e-3
    for _ in range(30):
        x, y = ds.batch(rng, 64)
        _, grads = loss_grad(params, jnp.array(x), jnp.array(y))
        params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
    x, y = ds.batch(rng, 256)
    l1, _ = loss_grad(params, jnp.array(x), jnp.array(y))
    assert float(l1) < float(l0), (float(l0), float(l1))


def test_deploy_block_runs():
    gp, gn = thermal.coupling_matrices(16, 16, 120.0, 16.0, 9.0)
    rng = np.random.default_rng(4)
    w = jnp.array(rng.uniform(-1, 1, (16, 16)), dtype=jnp.float32)
    x = jnp.array(rng.uniform(0, 1, (32, 16)), dtype=jnp.float32)
    noise = jnp.array(rng.normal(size=(32, 16)), dtype=jnp.float32)
    cm = jnp.array((np.arange(16) % 2 == 0).astype(np.float32))
    rm = jnp.ones(16)
    y = model.deploy_block_mvm(w, x, jnp.array(gp), jnp.array(gn), rm, cm, noise)
    assert y.shape == (32, 16)
    # LR recovers the masked ideal within noise + crosstalk tolerance
    ideal = np.asarray(ref.ideal_mvm(w, x, rm, cm))
    err = np.mean(np.abs(np.asarray(y) - ideal)) / (np.mean(np.abs(ideal)) + 1e-9)
    assert err < 0.2, err

"""Algorithm-1 machinery tests (init patterns, power metric, prune/grow)."""

import numpy as np

from compile import dst, power


def test_interleaved_examples_match_paper():
    m = dst.interleaved_row_mask(8, 0.75)
    assert "".join("1" if v else "0" for v in m) == "11111010"
    m = dst.interleaved_row_mask(8, 0.5)
    assert "".join("1" if v else "0" for v in m) == "10101010"


def test_rerouter_power_matches_rust_semantics():
    # dense mask: every node at the free even split -> zero power
    assert power.rerouter_power_mw(np.ones(16, dtype=bool)) < 1e-12
    # clustered 4-of-8 steers once at the root (pi/2) — cheaper than
    # interleaved which full-swings all four leaf nodes
    clustered = np.array([1, 1, 1, 1, 0, 0, 0, 0], dtype=bool)
    inter = np.array([1, 0, 1, 0, 1, 0, 1, 0], dtype=bool)
    pc = power.rerouter_power_mw(clustered)
    pi_ = power.rerouter_power_mw(inter)
    assert pc < pi_
    assert abs(pi_ / pc - 4.0) < 1e-9


def test_best_segment_mask_cardinality_and_optimality():
    for n in [0, 3, 8, 16]:
        m = dst.best_segment_mask(16, n)
        assert int(m.sum()) == min(n, 16)
    # exhaustive check at k2=8, 3 active
    best = dst.best_segment_mask(8, 3, cap=10**6)
    pb = power.rerouter_power_mw(best)
    import itertools
    for idx in itertools.combinations(range(8), 3):
        m = np.zeros(8, dtype=bool)
        m[list(idx)] = True
        assert power.rerouter_power_mw(m) >= pb - 1e-12


def test_cosine_schedule():
    assert dst.cosine_death_rate(0.5, 0, 100) == 0.5
    assert abs(dst.cosine_death_rate(0.5, 50, 100) - 0.25) < 1e-12
    assert dst.cosine_death_rate(0.5, 100, 100) == 0.0


def test_init_masks_density():
    masks = dst.init_masks({"conv2": (64, 576)}, 0.3)
    m = masks["conv2"]
    assert m["p"] == 1 and m["q"] == 9
    row_density = m["row"].mean()
    col_density = m["cols"][0].mean()
    assert abs(row_density - 0.5) < 0.02
    assert abs(col_density - 0.6) < 0.05
    assert abs(row_density * col_density - 0.3) < 0.05


def test_prune_grow_keeps_structure():
    shapes = {"conv2": (64, 576)}
    masks = dst.init_masks(shapes, 0.4)
    rng = np.random.default_rng(0)
    params = {"conv2": {"w": rng.normal(size=(64, 64, 3, 3))}}
    grads = {"conv2": {"w": rng.normal(size=(64, 64, 3, 3))}}
    row_before = masks["conv2"]["row"].copy()
    dst.prune_grow(masks, shapes, params, grads, alpha=0.3, density=0.4)
    m = masks["conv2"]
    assert np.array_equal(m["row"], row_before), "row mask is frozen"
    for col in m["cols"]:
        assert col.dtype == bool and col.shape == (64,)
    # density stays in a sane band
    dens = m["row"].mean() * np.mean([c.mean() for c in m["cols"]])
    assert 0.2 < dens < 0.6, dens

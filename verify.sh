#!/usr/bin/env bash
# Tier-1 verification gate (ROADMAP.md): release build + tests, then
# short bench smokes that refresh BENCH_engine.json and BENCH_server.json
# at the repo root, and the perf gate over them. Every PR runs this via
# .github/workflows/ci.yml.
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: cargo build --release =="
(cd rust && cargo build --release)

echo "== tier-1: cargo test -q =="
(cd rust && cargo test -q)

echo "== bench smoke: engine sweep + stage breakdown (--samples 5 ≈ 50 ms/cell) =="
./rust/target/release/scatter bench engine --samples 5 --threads 1,2,4,8 --stages

echo "== bench smoke: networked serve (2 s closed-loop over TCP + B-sweep + replica sweep) =="
./rust/target/release/scatter bench serve --duration 2 --concurrency 4 --workers 2 \
  --max-batch 1,8 --replicas 1,4

echo "== bench smoke: thermal drift (policy off vs threshold recalibration) =="
./rust/target/release/scatter bench drift --samples 40

echo "== bench smoke: chaos (seeded kill-each-worker-once + recovery gate) =="
./rust/target/release/scatter bench chaos --duration 4 --concurrency 4 --workers 3 \
  --seed 42

echo "== bench smoke: swap (in-serving DST hot-swap + injected bad-canary rollback) =="
./rust/target/release/scatter bench swap --duration 4 --concurrency 4 --workers 2

echo "== bench smoke: repair (mid-life device fault -> sentinel -> quarantine + accuracy recovery) =="
./rust/target/release/scatter bench repair --duration 4 --concurrency 4 --workers 2

echo "== perf gate: ci/check_bench.py =="
python3 ci/check_bench.py --engine BENCH_engine.json --server BENCH_server.json \
  --drift BENCH_drift.json --chaos BENCH_chaos.json --swap BENCH_swap.json \
  --repair BENCH_repair.json --baseline ci/bench_baseline.json

echo "verify OK"

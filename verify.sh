#!/usr/bin/env bash
# Tier-1 verification gate (ROADMAP.md): release build + tests, then a
# short engine-bench smoke that refreshes BENCH_engine.json at the repo
# root. Every PR runs this via .github/workflows/ci.yml.
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: cargo build --release =="
(cd rust && cargo build --release)

echo "== tier-1: cargo test -q =="
(cd rust && cargo test -q)

echo "== bench smoke: engine sweep (--samples 5 ≈ 50 ms/cell) =="
./rust/target/release/scatter bench engine --samples 5 --threads 1,2,4,8

echo "verify OK"

#!/usr/bin/env python3
"""CI perf-trajectory gate.

Compares the fresh ``BENCH_engine.json`` (written by ``scatter bench
engine``) against the committed baseline in ``ci/bench_baseline.json``
and fails the build when any baselined cell's GMAC/s drops more than
``tolerance`` (default 20%). Also sanity-checks ``BENCH_server.json``
(written by ``scatter bench serve``) so a broken networked-serving path
cannot ship a green build — including the armed batched-compute floor
``per_image_throughput_b8 / per_image_throughput_b1 >= 1.3`` from the
``--max-batch`` sweep and the replica-scaling floor
``replica_speedup_4_over_1`` from the ``--replicas`` sweep (record-only
while the baseline holds ``server.replica_speedup: null``) — and ``BENCH_drift.json`` (written by
``scatter bench drift``) so the thermal-drift runtime's acceptance
criteria — threshold recalibration recovers ≥ ``min_recovery`` of the
drift-free accuracy while recompiling fewer chunks than naive full
re-programs, and the serving gauges register — hold on every build.
``BENCH_chaos.json`` (written by ``scatter bench chaos``, which kills
every engine worker once on a seeded schedule) gates recovery: zero
lost replies, at least one supervisor respawn, a full-strength pool at
drain, and post-fault throughput at or above
``chaos.min_recovery × pre-fault``. ``BENCH_swap.json`` (written by
``scatter bench swap``: in-serving DST mask hot-swap under load, a
promote phase plus an injected-bad-canary rollback phase) gates the
co-design loop: at least ``swap.min_swaps`` promoted generations, zero
lost replies in both phases, the rollback path exercised at least once,
and no candidate promoted past a failing canary. ``BENCH_repair.json``
(written by ``scatter bench repair``: mid-life photonic device faults
under load plus an offline clean/faulty/repaired accuracy triple)
gates the self-repair loop: at least one sentinel detection and one
promoted quarantine repair, no unrepairable verdicts or degraded
replicas from a repairable fault, zero lost replies, a measured
detection latency, and accuracy recovery at or above
``repair.min_recovery``.

The engine gate is **armed two ways**:

* ``engine.ratios`` — machine-independent speedup floors over the
  headline ratio fields of ``BENCH_engine.json`` (planned-vs-reference);
  these ship armed, because a ratio regression is a code regression no
  matter which runner measured it.
* ``engine.cells`` — absolute per-cell GMAC/s floors. These bootstrap
  as ``null`` (record-only: the gate prints a ready-to-paste block from
  the fresh run) because absolute numbers are machine-specific; commit
  the printed block after the first trusted CI run, and re-record after
  intentional perf changes.
* ``engine.simd_speedup`` — the integer-quantized kernel's
  simd-vs-scalar kernel-stage ratio (``speedup_simd_vs_scalar`` from
  ``--stages``) must clear ``min``; hosts without a vector unit stamp
  ``simd_sweep_skipped`` and gate cleanly.

Stdlib-only on purpose: CI and the offline dev container both run it
with a bare python3.
"""

from __future__ import annotations

import argparse
import json
import sys


def load(path):
    with open(path) as fh:
        return json.load(fh)


def engine_cells(doc):
    """{(path, threads, sparsity): gmacs} from a BENCH_engine.json."""
    cells = {}
    for row in doc.get("results", []):
        key = (row["path"], int(row["threads"]), round(float(row["sparsity"]), 6))
        cells[key] = float(row["gmacs"])
    return cells


def check_engine(fresh_path, baseline_path, failures):
    fresh_doc = load(fresh_path)
    fresh = engine_cells(fresh_doc)
    if not fresh:
        failures.append(f"{fresh_path}: no engine results — bench did not run")
        return
    base_doc = load(baseline_path)
    tolerance = float(base_doc.get("tolerance", 0.20))
    engine_base = base_doc.get("engine") or {}

    # machine-independent ratio floors (armed: these fields are computed
    # by the bench itself from the same run, so a drop is a real
    # planned-path regression, not runner noise)
    ratios = engine_base.get("ratios") or {}
    for field, spec in sorted(ratios.items()):
        floor = float(spec.get("min", 0.0))
        if field not in fresh_doc:
            failures.append(f"{fresh_path}: missing ratio field '{field}'")
            continue
        value = float(fresh_doc[field])
        if value < floor:
            failures.append(
                f"engine ratio {field}: {value:.3f} < floor {floor:.3f} "
                f"(planned path regressed vs the reference path)"
            )
    if ratios:
        print(f"engine gate: checked {len(ratios)} speedup-ratio floors")

    check_simd_speedup(fresh_path, fresh_doc, engine_base, failures)
    check_engine_stages(fresh_path, fresh_doc, engine_base, failures)

    cells = engine_base.get("cells")
    if cells is None:
        print(f"{baseline_path}: no committed baseline yet (cells: null) — record-only.")
        print("To arm the regression gate, replace the \"engine\" block with:")
        block = {
            "cells": [
                {"path": p, "threads": t, "sparsity": s, "gmacs": round(g, 3)}
                for (p, t, s), g in sorted(fresh.items())
            ]
        }
        print(json.dumps({"engine": block}, indent=2))
        return
    compared = 0
    for cell in cells:
        key = (cell["path"], int(cell["threads"]), round(float(cell["sparsity"]), 6))
        if key not in fresh:
            failures.append(f"baseline cell {key} missing from fresh engine run")
            continue
        compared += 1
        floor = float(cell["gmacs"]) * (1.0 - tolerance)
        if fresh[key] < floor:
            failures.append(
                f"engine cell {key}: {fresh[key]:.3f} GMAC/s < floor {floor:.3f} "
                f"(baseline {float(cell['gmacs']):.3f}, tolerance {tolerance:.0%})"
            )
    print(
        f"engine gate: compared {compared} cells against {baseline_path} "
        f"(tolerance {tolerance:.0%})"
    )


def check_simd_speedup(engine_path, doc, engine_base, failures):
    """Machine-independent SIMD-kernel floor: ``scatter bench engine
    --stages`` times the integer-quantized kernel stage twice on the
    tall shape in the same invocation — runtime-detected vector level
    vs the forced-scalar oracle — and writes the ratio as
    ``speedup_simd_vs_scalar``, which must clear
    ``engine.simd_speedup.min``. Both points come from one run on one
    runner, so a drop is a code regression, not runner noise. A
    ``null`` spec is record-only (the gate prints the fresh ratio and
    the ready-to-arm block). Deliberate skips gate cleanly: the bench
    stamps ``simd_sweep_skipped`` on hosts without AVX2 (non-x86
    runners, or SCATTER_FORCE_SCALAR set) and when ``--stages`` is off
    — noted, not failed. Only an armed floor with *no* sweep evidence
    (neither ratio nor stamp) fails."""
    if "simd_speedup" not in engine_base:
        return
    spec = engine_base["simd_speedup"]
    ratio = doc.get("speedup_simd_vs_scalar")
    if spec is None:
        if ratio is not None:
            print(
                f"engine gate: simd-vs-scalar kernel speedup = {float(ratio):.2f} "
                f"(record-only; baseline simd_speedup is null)"
            )
            print("To arm the SIMD-kernel floor, replace \"simd_speedup\": null with:")
            print(json.dumps({"simd_speedup": {"min": 2.0}}, indent=2))
        else:
            skipped = doc.get("simd_sweep_skipped")
            note = f" ({skipped})" if skipped else ""
            print(f"engine gate: simd sweep absent{note} — record-only, nothing to record")
        return
    floor = float(spec.get("min", 2.0))
    if ratio is None:
        skipped = doc.get("simd_sweep_skipped")
        if skipped:
            print(f"engine gate: simd sweep skipped ({skipped}) — floor not evaluated")
            return
        failures.append(
            f"{engine_path}: missing speedup_simd_vs_scalar and no "
            f"simd_sweep_skipped stamp — run 'scatter bench engine' with --stages"
        )
        return
    ratio = float(ratio)
    if ratio < floor:
        failures.append(
            f"simd-vs-scalar kernel speedup = {ratio:.3f} < floor {floor:.2f} "
            f"(the vectorized quantized sweep stopped paying over its scalar oracle)"
        )
    else:
        variant = (doc.get("simd") or {}).get("variant", "?")
        print(
            f"engine gate: simd-vs-scalar kernel speedup = {ratio:.2f} "
            f"(floor {floor:.2f}, variant {variant})"
        )


def check_engine_stages(fresh_path, fresh_doc, engine_base, failures):
    """Sanity-check the per-stage breakdown written by ``scatter bench
    engine --stages``: every path's gather/kernel/scatter shares are
    fractions summing to ~1.0, and the kernel stage is actually measured
    (a zero kernel share means the timers are not wired through the hot
    loop). Required when the baseline sets ``engine.stages.require``
    (verify.sh and CI always pass ``--stages``); merely optional
    otherwise so ad-hoc local runs without the flag still gate."""
    required = bool((engine_base.get("stages") or {}).get("require"))
    stages = fresh_doc.get("stages")
    if stages is None:
        if required:
            failures.append(
                f"{fresh_path}: no 'stages' block — run bench engine with --stages"
            )
        return
    if not isinstance(stages, dict) or not stages:
        failures.append(f"{fresh_path}: 'stages' block empty or malformed")
        return
    share_fields = ("gather_share", "kernel_share", "scatter_share")
    failures_before = len(failures)
    for path_name, block in sorted(stages.items()):
        shares = []
        for field in share_fields:
            if field not in block:
                failures.append(f"{fresh_path}: stages.{path_name} missing '{field}'")
                continue
            v = float(block[field])
            if not 0.0 <= v <= 1.0:
                failures.append(
                    f"{fresh_path}: stages.{path_name}.{field}={v} not a fraction"
                )
            shares.append(v)
        if len(shares) == len(share_fields) and abs(sum(shares) - 1.0) > 0.02:
            failures.append(
                f"{fresh_path}: stages.{path_name} shares sum to {sum(shares):.3f} "
                f"(want ~1.0)"
            )
        if "kernel_share" in block and float(block["kernel_share"]) <= 0.0:
            failures.append(
                f"{fresh_path}: stages.{path_name} kernel share is zero — "
                f"stage timers not reaching the micro-kernel"
            )
    if len(failures) == failures_before:
        kernel = {p: float(b.get("kernel_share", 0.0)) for p, b in sorted(stages.items())}
        print(
            "engine gate: stage breakdown OK — kernel shares "
            + ", ".join(f"{p}={v:.2f}" for p, v in kernel.items())
        )


def check_server(server_path, baseline_path, failures):
    doc = load(server_path)
    checks = [
        ("requests_ok", lambda v: v > 0, "> 0 requests must be served"),
        ("throughput_rps", lambda v: v > 0, "throughput must be nonzero"),
        ("client_p50_us", lambda v: v > 0, "latency must be measured"),
        ("shed_rate", lambda v: 0.0 <= v <= 1.0, "shed rate must be a fraction"),
        ("errors", lambda v: v == 0, "transport errors mean a broken serving path"),
    ]
    for field, ok, why in checks:
        if field not in doc:
            failures.append(f"{server_path}: missing field '{field}'")
            continue
        value = float(doc[field])
        if not ok(value):
            failures.append(f"{server_path}: {field}={value} ({why})")
    server = doc.get("server") or {}
    if server:
        if float(server.get("energy_mj", 0.0)) <= 0.0:
            failures.append(f"{server_path}: server.energy_mj not accounted")
    check_batch_speedup(server_path, doc, baseline_path, failures)
    check_replica_speedup(server_path, doc, baseline_path, failures)
    print(f"server gate: {server_path} structurally valid" if not failures else "")


def check_batch_speedup(server_path, doc, baseline_path, failures):
    """Machine-independent batched-compute floor: the ``--max-batch``
    sweep's ``per_image_throughput_b8 / per_image_throughput_b1`` ratio
    must clear ``server.batch_speedup.min`` from the baseline (default
    1.3). Both points run on the same machine in the same bench
    invocation, so a ratio drop means batching stopped paying — a code
    regression, not runner noise. Armed whenever the baseline carries the
    ``server.batch_speedup`` block (verify.sh and CI always pass
    ``--max-batch 1,8``). Deliberate skips gate cleanly: the bench
    stamps ``batch_sweep_skipped`` when driving a remote ``--addr``
    target (whose batching it cannot reconfigure) or when the sweep is
    disabled, and non-default sweep points carry a ``batch_sweep`` block
    — both are noted, not failed. Only an artifact with *no* sweep
    evidence (bench predates the sweep, or it silently didn't run)
    fails."""
    spec = (load(baseline_path).get("server") or {}).get("batch_speedup")
    if not spec:
        return
    floor = float(spec.get("min", 1.3))
    b1 = doc.get("per_image_throughput_b1")
    b8 = doc.get("per_image_throughput_b8")
    if b1 is None or b8 is None:
        skipped = doc.get("batch_sweep_skipped")
        if skipped:
            print(f"server gate: batch sweep skipped ({skipped}) — floor not evaluated")
            return
        if doc.get("batch_sweep"):
            print(
                "server gate: batch sweep ran without points 1 and 8 — "
                "floor not evaluated (CI pins --max-batch 1,8)"
            )
            return
        failures.append(
            f"{server_path}: missing per_image_throughput_b1/b8 — "
            f"run 'scatter bench serve' with the --max-batch 1,8 sweep"
        )
        return
    b1, b8 = float(b1), float(b8)
    if b1 <= 0.0 or b8 <= 0.0:
        failures.append(
            f"{server_path}: degenerate sweep point (b1={b1}, b8={b8} img/s)"
        )
        return
    ratio = b8 / b1
    if ratio < floor:
        failures.append(
            f"batched-compute speedup b8/b1 = {ratio:.3f} < floor {floor:.2f} "
            f"({b8:.1f} vs {b1:.1f} img/s — one-engine-pass-per-shard "
            f"batching stopped paying)"
        )
    else:
        print(f"server gate: batched-compute b8/b1 = {ratio:.2f} (floor {floor:.2f})")
    # advisory: a b8 sweep that never formed batches can't measure
    # amortization; surface it without failing (the ratio floor already
    # catches the throughput consequence)
    for pt in (doc.get("batch_sweep") or {}).get("points", []):
        if int(pt.get("max_batch", 0)) == 8 and float(pt.get("mean_occupancy", 0)) < 1.5:
            print(
                f"server gate: WARNING b8 sweep mean occupancy "
                f"{float(pt.get('mean_occupancy', 0)):.2f} — batches barely formed"
            )


def check_replica_speedup(server_path, doc, baseline_path, failures):
    """Machine-independent replica-scaling floor: the ``--replicas``
    sweep's ``replica_speedup_4_over_1`` ratio (MLP per-image throughput
    at 4 replicas over 1, both points from the same bench invocation on
    the same runner) must clear ``server.replica_speedup.min``. The
    baseline bootstraps with ``server.replica_speedup: null`` —
    record-only: the gate prints the fresh ratio and the ready-to-arm
    block; commit it after the first trusted CI artifact. Deliberate
    skips (``replica_sweep_skipped``: remote ``--addr`` target, or the
    sweep disabled) and non-default sweep points are noted, not failed;
    only an armed floor with *no* sweep evidence fails."""
    server_base = load(baseline_path).get("server") or {}
    if "replica_speedup" not in server_base:
        return
    spec = server_base["replica_speedup"]
    ratio = doc.get("replica_speedup_4_over_1")
    if spec is None:
        if ratio is not None:
            print(
                f"server gate: replica-scaling r4/r1 = {float(ratio):.2f} "
                f"(record-only; baseline replica_speedup is null)"
            )
            print("To arm the replica-scaling floor, replace \"replica_speedup\": null with:")
            print(json.dumps({"replica_speedup": {"min": 2.0}}, indent=2))
        else:
            skipped = doc.get("replica_sweep_skipped")
            note = f" ({skipped})" if skipped else ""
            print(f"server gate: replica sweep absent{note} — record-only, nothing to record")
        return
    floor = float(spec.get("min", 2.0))
    if ratio is None:
        skipped = doc.get("replica_sweep_skipped")
        if skipped:
            print(f"server gate: replica sweep skipped ({skipped}) — floor not evaluated")
            return
        if doc.get("replicas"):
            print(
                "server gate: replica sweep ran without points 1 and 4 — "
                "floor not evaluated (CI pins --replicas 1,4)"
            )
            return
        failures.append(
            f"{server_path}: missing replica_speedup_4_over_1 — "
            f"run 'scatter bench serve' with the --replicas 1,4 sweep"
        )
        return
    ratio = float(ratio)
    if ratio < floor:
        failures.append(
            f"replica-scaling speedup r4/r1 = {ratio:.3f} < floor {floor:.2f} "
            f"(4 replicas no longer scale over 1 — cluster routing regressed)"
        )
    else:
        print(f"server gate: replica-scaling r4/r1 = {ratio:.2f} (floor {floor:.2f})")


def check_drift(drift_path, baseline_path, failures):
    doc = load(drift_path)
    base = (load(baseline_path).get("drift") or {})
    min_recovery = float(base.get("min_recovery", 0.90))

    acc = doc.get("accuracy") or {}
    recovery = float(acc.get("recovery_threshold", 0.0))
    if recovery < min_recovery:
        failures.append(
            f"{drift_path}: threshold-policy recovery {recovery:.3f} < {min_recovery} "
            f"(drift-free {acc.get('drift_free')}, threshold {acc.get('policy_threshold')})"
        )
    free = float(acc.get("drift_free", 0.0))
    off = float(acc.get("policy_off", 1.0))
    if not free > 0.0:
        failures.append(f"{drift_path}: drift-free accuracy is zero — deployment broken")
    if off >= free:
        failures.append(
            f"{drift_path}: policy-off accuracy {off} did not degrade below "
            f"drift-free {free} — the drift schedule is not biting"
        )

    recal = doc.get("recalibration") or {}
    events = float(recal.get("events", 0))
    chunks = float(recal.get("chunks", 0))
    full = float(recal.get("full_reprogram_chunks", 0))
    if events < 1:
        failures.append(f"{drift_path}: threshold policy never recalibrated")
    if not chunks < full:
        failures.append(
            f"{drift_path}: recalibrated {chunks:.0f} chunks vs {full:.0f} for full "
            f"re-programs — recalibration is not incremental"
        )

    serving = doc.get("serving") or {}
    if float(serving.get("requests_ok", 0)) <= 0:
        failures.append(f"{drift_path}: drift serving phase served nothing")
    if not abs(float(serving.get("metrics_drift_rad") or 0.0)) > 0.0:
        failures.append(f"{drift_path}: /metrics drift gauge is zero")
    if float(serving.get("recalibrations", 0)) < 1:
        failures.append(f"{drift_path}: /metrics recalibration counter is zero")
    print(f"drift gate: {drift_path} recovery {recovery:.3f}, "
          f"{chunks:.0f}/{full:.0f} chunks recompiled")


def check_chaos(chaos_path, baseline_path, failures):
    """Self-healing gate over ``BENCH_chaos.json``. Every floor here is
    machine-independent: lost replies, respawn counts, and pool strength
    are exact invariants of the supervision protocol, and the recovery
    ratio compares two windows of the same run on the same runner."""
    doc = load(chaos_path)
    base = (load(baseline_path).get("chaos") or {})
    min_recovery = float(base.get("min_recovery", 0.8))

    if float(doc.get("requests_ok", 0)) <= 0:
        failures.append(f"{chaos_path}: nothing served — pool never recovered")
    lost = float(doc.get("lost", -1))
    if lost != 0:
        failures.append(
            f"{chaos_path}: lost={lost:.0f} replies (supervision must conserve "
            f"one-terminal-outcome-per-request; anything else is a dropped client)"
        )
    respawns = float(doc.get("respawns", 0))
    if respawns < 1:
        failures.append(
            f"{chaos_path}: respawns={respawns:.0f} — the kill schedule never "
            f"exercised the supervisor (seed/plan wiring broken?)"
        )
    live = float(doc.get("workers_live", -1))
    configured = float(doc.get("workers_configured", 0))
    if live != configured:
        failures.append(
            f"{chaos_path}: workers_live={live:.0f} != configured={configured:.0f} "
            f"at drain — a killed worker stayed dead"
        )
    recovery = float(doc.get("recovery_ratio", 0.0))
    if recovery < min_recovery:
        failures.append(
            f"{chaos_path}: post/pre-fault throughput ratio {recovery:.3f} < "
            f"{min_recovery} (post {float(doc.get('post_fault_rps', 0)):.1f} vs "
            f"pre {float(doc.get('pre_fault_rps', 0)):.1f} req/s)"
        )
    print(
        f"chaos gate: {chaos_path} recovery {recovery:.2f}x, "
        f"{respawns:.0f} respawns, {live:.0f}/{configured:.0f} workers live"
    )


def check_swap(swap_path, baseline_path, failures):
    """Mask hot-swap gate over ``BENCH_swap.json``. Every floor is
    machine-independent: swap/rollback counts and reply conservation are
    exact invariants of the shard-boundary cutover protocol, measured in
    one run on one runner."""
    doc = load(swap_path)
    base = (load(baseline_path).get("swap") or {})
    min_swaps = float(base.get("min_swaps", 2))

    if float(doc.get("requests_ok", 0)) <= 0:
        failures.append(f"{swap_path}: promote phase served nothing")
    swaps = float(doc.get("swaps", 0))
    if swaps < min_swaps:
        failures.append(
            f"{swap_path}: swaps={swaps:.0f} < {min_swaps:.0f} — the in-serving "
            f"DST loop never promoted enough mask generations under load"
        )
    lost = float(doc.get("lost", -1))
    if lost != 0:
        failures.append(
            f"{swap_path}: lost={lost:.0f} replies in the promote phase — a "
            f"shard-boundary cutover must never eat a reply"
        )
    if float(doc.get("generation_max", 0)) < 1:
        failures.append(f"{swap_path}: no replica ever left mask generation 0")
    rb_lost = float(doc.get("rollback_lost", -1))
    if rb_lost != 0:
        failures.append(
            f"{swap_path}: rollback phase lost {rb_lost:.0f} replies — a vetoed "
            f"candidate must not touch traffic"
        )
    rollbacks = float(doc.get("rollback_rollbacks", 0))
    if rollbacks < 1:
        failures.append(
            f"{swap_path}: rollback_rollbacks={rollbacks:.0f} — the injected "
            f"failing canary never exercised the rollback path"
        )
    rb_swaps = float(doc.get("rollback_swaps", -1))
    if rb_swaps != 0:
        failures.append(
            f"{swap_path}: rollback_swaps={rb_swaps:.0f} — a candidate was "
            f"promoted past a failing canary"
        )
    print(
        f"swap gate: {swap_path} {swaps:.0f} promotions "
        f"(top generation {float(doc.get('generation_max', 0)):.0f}), "
        f"{rollbacks:.0f} bad-canary rollbacks, 0 lost replies in both phases"
    )


def check_repair(repair_path, baseline_path, failures):
    """Self-repair gate over ``BENCH_repair.json``. The lifecycle counts
    (injected → detected → repaired, zero unrepairable/degraded/lost)
    are exact invariants of the sentinel + quarantine protocol; the
    accuracy-recovery ratio compares three evaluations of the same
    deployment on the same runner with the same seed, so every floor is
    machine-independent."""
    doc = load(repair_path)
    base = (load(baseline_path).get("repair") or {})
    min_recovery = float(base.get("min_recovery", 0.9))

    if float(doc.get("requests_ok", 0)) <= 0:
        failures.append(f"{repair_path}: serving phase served nothing")
    lost = float(doc.get("lost", -1))
    if lost != 0:
        failures.append(
            f"{repair_path}: lost={lost:.0f} replies — a quarantine repair "
            f"must never eat a reply"
        )
    if float(doc.get("faults_injected", 0)) < 1:
        failures.append(
            f"{repair_path}: no device faults injected — the mid-life "
            f"fault plan never armed"
        )
    detections = float(doc.get("detections", 0))
    if detections < 1:
        failures.append(
            f"{repair_path}: detections={detections:.0f} — the sentinel "
            f"never flagged the faulted fabric"
        )
    repairs = float(doc.get("repairs", 0))
    if repairs < 1:
        failures.append(
            f"{repair_path}: repairs={repairs:.0f} — no quarantine was "
            f"promoted past its canary"
        )
    unrepairable = float(doc.get("unrepairable", -1))
    if unrepairable != 0:
        failures.append(
            f"{repair_path}: unrepairable={unrepairable:.0f} — a maskable "
            f"dead branch must be repairable, not a degradation"
        )
    degraded = float(doc.get("degraded", -1))
    if degraded != 0:
        failures.append(
            f"{repair_path}: degraded={degraded:.0f} replicas after a "
            f"repairable fault"
        )
    detection_ms = float(doc.get("detection_ms", 0.0))
    if not detection_ms > 0.0:
        failures.append(
            f"{repair_path}: detection_ms={detection_ms} — injection→detection "
            f"latency was never measured"
        )
    recovery = float(doc.get("recovery", 0.0))
    if recovery < min_recovery:
        failures.append(
            f"{repair_path}: accuracy recovery {recovery:.3f} < {min_recovery} "
            f"(clean {doc.get('acc_clean')}, faulty {doc.get('acc_faulty')}, "
            f"repaired {doc.get('acc_repaired')})"
        )
    print(
        f"repair gate: {repair_path} {detections:.0f} detections "
        f"({detection_ms:.1f} ms), {repairs:.0f} repairs, "
        f"recovery {recovery:.2f}, 0 lost replies"
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--engine", default="BENCH_engine.json")
    ap.add_argument("--server", default=None, help="BENCH_server.json (optional)")
    ap.add_argument("--drift", default=None, help="BENCH_drift.json (optional)")
    ap.add_argument("--chaos", default=None, help="BENCH_chaos.json (optional)")
    ap.add_argument("--swap", default=None, help="BENCH_swap.json (optional)")
    ap.add_argument("--repair", default=None, help="BENCH_repair.json (optional)")
    ap.add_argument("--baseline", default="ci/bench_baseline.json")
    args = ap.parse_args()

    failures: list[str] = []
    try:
        check_engine(args.engine, args.baseline, failures)
    except (OSError, ValueError, KeyError) as e:
        failures.append(f"engine check unreadable: {e!r}")
    if args.server:
        try:
            check_server(args.server, args.baseline, failures)
        except (OSError, ValueError, KeyError) as e:
            failures.append(f"server check unreadable: {e!r}")
    if args.drift:
        try:
            check_drift(args.drift, args.baseline, failures)
        except (OSError, ValueError, KeyError) as e:
            failures.append(f"drift check unreadable: {e!r}")
    if args.chaos:
        try:
            check_chaos(args.chaos, args.baseline, failures)
        except (OSError, ValueError, KeyError) as e:
            failures.append(f"chaos check unreadable: {e!r}")
    if args.swap:
        try:
            check_swap(args.swap, args.baseline, failures)
        except (OSError, ValueError, KeyError) as e:
            failures.append(f"swap check unreadable: {e!r}")
    if args.repair:
        try:
            check_repair(args.repair, args.baseline, failures)
        except (OSError, ValueError, KeyError) as e:
            failures.append(f"repair check unreadable: {e!r}")

    if failures:
        print("\nPERF GATE FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        sys.exit(1)
    print("perf gate OK")


if __name__ == "__main__":
    main()

#!/usr/bin/env python3
"""CI perf-trajectory gate.

Compares the fresh ``BENCH_engine.json`` (written by ``scatter bench
engine``) against the committed baseline in ``ci/bench_baseline.json``
and fails the build when any baselined cell's GMAC/s drops more than
``tolerance`` (default 20%). Also sanity-checks ``BENCH_server.json``
(written by ``scatter bench serve``) so a broken networked-serving path
cannot ship a green build.

Bootstrap protocol: the baseline ships with ``"cells": null`` because no
trusted numbers exist until CI has run on real hardware. In that mode
the gate is record-only — it prints a ready-to-paste baseline block
built from the fresh run; commit it into ``ci/bench_baseline.json`` to
arm the gate. Re-bootstrap the same way after intentional perf changes.

Stdlib-only on purpose: CI and the offline dev container both run it
with a bare python3.
"""

from __future__ import annotations

import argparse
import json
import sys


def load(path):
    with open(path) as fh:
        return json.load(fh)


def engine_cells(doc):
    """{(path, threads, sparsity): gmacs} from a BENCH_engine.json."""
    cells = {}
    for row in doc.get("results", []):
        key = (row["path"], int(row["threads"]), round(float(row["sparsity"]), 6))
        cells[key] = float(row["gmacs"])
    return cells


def check_engine(fresh_path, baseline_path, failures):
    fresh = engine_cells(load(fresh_path))
    if not fresh:
        failures.append(f"{fresh_path}: no engine results — bench did not run")
        return
    base_doc = load(baseline_path)
    tolerance = float(base_doc.get("tolerance", 0.20))
    cells = (base_doc.get("engine") or {}).get("cells")
    if cells is None:
        print(f"{baseline_path}: no committed baseline yet (cells: null) — record-only.")
        print("To arm the regression gate, replace the \"engine\" block with:")
        block = {
            "cells": [
                {"path": p, "threads": t, "sparsity": s, "gmacs": round(g, 3)}
                for (p, t, s), g in sorted(fresh.items())
            ]
        }
        print(json.dumps({"engine": block}, indent=2))
        return
    compared = 0
    for cell in cells:
        key = (cell["path"], int(cell["threads"]), round(float(cell["sparsity"]), 6))
        if key not in fresh:
            failures.append(f"baseline cell {key} missing from fresh engine run")
            continue
        compared += 1
        floor = float(cell["gmacs"]) * (1.0 - tolerance)
        if fresh[key] < floor:
            failures.append(
                f"engine cell {key}: {fresh[key]:.3f} GMAC/s < floor {floor:.3f} "
                f"(baseline {float(cell['gmacs']):.3f}, tolerance {tolerance:.0%})"
            )
    print(
        f"engine gate: compared {compared} cells against {baseline_path} "
        f"(tolerance {tolerance:.0%})"
    )


def check_server(server_path, failures):
    doc = load(server_path)
    checks = [
        ("requests_ok", lambda v: v > 0, "> 0 requests must be served"),
        ("throughput_rps", lambda v: v > 0, "throughput must be nonzero"),
        ("client_p50_us", lambda v: v > 0, "latency must be measured"),
        ("shed_rate", lambda v: 0.0 <= v <= 1.0, "shed rate must be a fraction"),
        ("errors", lambda v: v == 0, "transport errors mean a broken serving path"),
    ]
    for field, ok, why in checks:
        if field not in doc:
            failures.append(f"{server_path}: missing field '{field}'")
            continue
        value = float(doc[field])
        if not ok(value):
            failures.append(f"{server_path}: {field}={value} ({why})")
    server = doc.get("server") or {}
    if server:
        if float(server.get("energy_mj", 0.0)) <= 0.0:
            failures.append(f"{server_path}: server.energy_mj not accounted")
    print(f"server gate: {server_path} structurally valid" if not failures else "")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--engine", default="BENCH_engine.json")
    ap.add_argument("--server", default=None, help="BENCH_server.json (optional)")
    ap.add_argument("--baseline", default="ci/bench_baseline.json")
    args = ap.parse_args()

    failures: list[str] = []
    try:
        check_engine(args.engine, args.baseline, failures)
    except (OSError, ValueError, KeyError) as e:
        failures.append(f"engine check unreadable: {e!r}")
    if args.server:
        try:
            check_server(args.server, failures)
        except (OSError, ValueError, KeyError) as e:
            failures.append(f"server check unreadable: {e!r}")

    if failures:
        print("\nPERF GATE FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        sys.exit(1)
    print("perf gate OK")


if __name__ == "__main__":
    main()

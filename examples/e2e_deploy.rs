//! **End-to-end deployment driver** (the headline E2E validation run
//! recorded in EXPERIMENTS.md): deploy CNN-3 onto the R=4, C=4 SCATTER
//! accelerator and reproduce the Table-3 CNN row shape —
//!
//! * dense PTC: ideal accuracy vs accuracy under thermal variation as the
//!   MZI gap shrinks 5 → 3 → 1 µm;
//! * SCATTER (s = 0.3 row-column co-sparsity): accuracy w/ TV, then
//!   recovered accuracy with IG + OG + LR;
//! * single-image inference energy for both.
//!
//! Uses python-DST-trained weights from `artifacts/trained/cnn3` when
//! present (`make train`), otherwise the in-repo prototype-readout fit.
//!
//! ```bash
//! cargo run --release --example e2e_deploy -- [n_samples]
//! ```

use scatter::bench::common::{table3_config, BenchCtx, Workload};
use scatter::config::SparsitySupport;
use scatter::coordinator::EngineOptions;
use scatter::util::Table;

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(100);
    let ctx = BenchCtx::new(n);
    let (model0, _) = ctx.fitted(Workload::Cnn3);
    println!(
        "e2e deploy: {} on synthetic FMNIST, {} eval samples, weights: {}",
        model0.name,
        n,
        if ctx.trained_dir.is_some() { "python DST bundle" } else { "prototype readout" }
    );

    let mut table = Table::new("Table-3-shaped E2E: CNN on SCATTER (R=C=4, k=16, 5 GHz)")
        .header(&["setting", "l_g (um)", "Acc ideal", "Acc w/ TV", "Acc +IG+OG+LR", "E (mJ/img)", "P_avg (W)"]);

    for (setting, density) in [("DensePTC", 1.0f64), ("SCATTER s=0.3", 0.3)] {
        for l_g in [5.0, 3.0, 1.0] {
            // ideal (quantization only); DST-style masked deployment
            let cfg = table3_config(l_g, SparsitySupport::NONE);
            let (model, ds, masks) = ctx.deployment(Workload::Cnn3, &cfg, density);
            let (acc_ideal, _) =
                ctx.accuracy(&model, &ds, &cfg, EngineOptions::IDEAL, masks.clone(), n);
            // thermal variation, no gating
            let (acc_tv, _) =
                ctx.accuracy(&model, &ds, &cfg, EngineOptions::NOISY, masks.clone(), n);
            // full SCATTER recovery (sparse only)
            let (acc_rec, energy_mj, p_avg) = if density < 1.0 {
                let cfg_full = table3_config(l_g, SparsitySupport::FULL);
                let (acc, engine) =
                    ctx.accuracy(&model, &ds, &cfg_full, EngineOptions::NOISY, masks, n);
                let rep = engine.energy_report();
                (format!("{:.1}", acc * 100.0), rep.energy_mj / n as f64, engine.p_avg_w())
            } else {
                let cfg_d = table3_config(l_g, SparsitySupport::NONE);
                let (_, engine) = ctx.accuracy(
                    &model,
                    &ds,
                    &cfg_d,
                    EngineOptions::NOISY,
                    Default::default(),
                    1,
                );
                ("-".to_string(), engine.energy_report().energy_mj, engine.p_avg_w())
            };
            table.row(vec![
                setting.to_string(),
                format!("{l_g:.0}"),
                format!("{:.1}", acc_ideal * 100.0),
                format!("{:.1}", acc_tv * 100.0),
                acc_rec,
                format!("{energy_mj:.4}"),
                format!("{p_avg:.2}"),
            ]);
        }
    }
    println!("{table}");
    println!(
        "expected shape (paper Table 3): dense accuracy collapses as l_g shrinks;\n\
         SCATTER w/ IG+OG+LR holds accuracy near ideal at l_g=1um with lower energy."
    );
}

//! Device-spacing design-space exploration (a mini Fig. 6): sweep the MZI
//! arm spacing l_s and gap l_g, and print the power-area-robustness
//! frontier with the PAP-optimal dense point and the sparsity-enabled
//! compact point highlighted.
//!
//! ```bash
//! cargo run --release --example sweep_spacing
//! ```

use scatter::area::AreaModel;
use scatter::config::{AcceleratorConfig, DacKind, SparsitySupport};
use scatter::devices::{Mzi, MziSpec};
use scatter::power::PowerModel;
use scatter::thermal::{coupling::ArrayGeometry, CouplingModel, GammaModel};
use scatter::util::Table;

fn main() {
    let gamma = GammaModel::paper();
    let mut table = Table::new("device-spacing design space (dense 16-core accelerator)")
        .header(&["l_s", "l_g", "P_avg (W)", "A (mm^2)", "PAP", "worst coupling"]);
    let mut best: Option<(f64, f64, f64)> = None;
    for ls in [7.0, 8.0, 9.0, 10.0, 11.0] {
        for lg in [1.0, 3.0, 5.0, 10.0, 20.0] {
            let cfg = AcceleratorConfig {
                l_s: ls,
                l_g: lg,
                share_r: 1,
                share_c: 1,
                dac: DacKind::Edac,
                features: SparsitySupport::NONE,
                ..Default::default()
            };
            let p = PowerModel::with_defaults(cfg.clone()).dense(None).total_w();
            let a = AreaModel::with_defaults(cfg.clone()).total_mm2();
            let coupling =
                CouplingModel::new(ArrayGeometry::from_config(&cfg), &gamma).worst_case_coupling();
            let pap = p * a;
            // dense designs must stay below a coupling budget (~1% accuracy
            // drop corresponds to the paper's l_g = 5 µm at l_s = 9 µm)
            let budget_cfg = AcceleratorConfig {
                l_s: 9.0,
                l_g: 5.0,
                ..cfg.clone()
            };
            let budget = CouplingModel::new(ArrayGeometry::from_config(&budget_cfg), &gamma)
                .worst_case_coupling();
            if coupling <= budget * 1.0001 && best.map_or(true, |(bp, _, _)| pap < bp) {
                best = Some((pap, ls, lg));
            }
            table.row(vec![
                format!("{ls:.0}"),
                format!("{lg:.0}"),
                format!("{p:.2}"),
                format!("{a:.2}"),
                format!("{pap:.1}"),
                format!("{coupling:.4}"),
            ]);
        }
    }
    println!("{table}");
    if let Some((pap, ls, lg)) = best {
        println!("PAP-optimal dense point within the crosstalk budget: l_s={ls}, l_g={lg} (PAP {pap:.1})");
    }
    // sparsity relaxes the constraint: show the SCATTER compact point
    let compact = AcceleratorConfig::default(); // l_g = 1 µm + IG+OG+LR
    let a = AreaModel::with_defaults(compact.clone()).total_mm2();
    let mzi = Mzi::new(MziSpec::low_power(), compact.l_s, &gamma);
    println!(
        "with co-sparsity + OG the chip shrinks to l_g=1 µm: {a:.2} mm^2 \
         (weight MZI mean power {:.2} mW)",
        mzi.mean_power_uniform_mw()
    );
}

//! Quickstart: build a 16×16 SCATTER PTC, run one noisy MVM on the rust
//! digital twin, compare against the ideal result, report the power, and —
//! if `make artifacts` has run — execute the same computation through the
//! AOT-compiled artifact via PJRT to prove the two layers agree.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use scatter::config::AcceleratorConfig;
use scatter::ptc::crossbar::{ColumnMode, ForwardOptions, PtcSimulator};
use scatter::thermal::{coupling::ArrayGeometry, CouplingModel, GammaModel};
use scatter::util::{nmae, snr_db, XorShiftRng};

fn main() -> scatter::Result<()> {
    let cfg = AcceleratorConfig::default();
    println!("SCATTER quickstart — one 16x16 PTC at l_s={} l_g={}", cfg.l_s, cfg.l_g);

    // a random weight block and activation vector
    let mut rng = XorShiftRng::new(42);
    let mut w = vec![0.0; 256];
    rng.fill_uniform(&mut w, -1.0, 1.0);
    let mut x = vec![0.0; 16];
    rng.fill_uniform(&mut x, 0.0, 1.0);
    // half the input columns pruned, light-redistributed
    let col_mask: Vec<bool> = (0..16).map(|j| j % 2 == 0).collect();

    let sim = PtcSimulator::from_config(&cfg);
    let golden = sim.forward_ideal(&w, &x, Some(&col_mask), None);

    let opts = ForwardOptions {
        thermal: true,
        pd_noise: true,
        phase_noise: true,
        col_mask: Some(&col_mask),
        col_mode: ColumnMode::InputGatingLr,
        ..Default::default()
    };
    let y = sim.forward(&w, &x, &opts, &mut XorShiftRng::new(cfg.noise_seed));
    println!(
        "  rust twin  : N-MAE = {:.4}  SNR = {:.1} dB",
        nmae(&y, &golden),
        snr_db(&y, &golden)
    );

    // per-block hold power
    let gamma = GammaModel::paper();
    let mzi =
        scatter::devices::Mzi::new(scatter::devices::MziSpec::low_power(), cfg.l_s, &gamma);
    let p_wgt: f64 = (0..16)
        .flat_map(|i| (0..16).map(move |j| (i, j)))
        .filter(|&(_, j)| col_mask[j])
        .map(|(i, j)| mzi.power_for_weight_mw(w[i * 16 + j]))
        .sum();
    let p_rerouter = scatter::sparsity::mask_power_mw(&col_mask, 16, &mzi);
    println!("  block power: weights {:.2} mW + rerouter {:.2} mW", p_wgt, p_rerouter);

    // worst-case coupling of this geometry
    let coupling = CouplingModel::new(ArrayGeometry::from_config(&cfg), &gamma);
    println!("  worst-case inter-MZI coupling: {:.4}", coupling.worst_case_coupling());

    // and the AOT path, if the runtime is compiled in and artifacts exist
    let rt = scatter::runtime::ArtifactRuntime::new("artifacts");
    let mut rt = match rt {
        Ok(rt) => rt,
        Err(e) => {
            println!("  (AOT/PJRT path skipped: {e})");
            println!("quickstart OK");
            return Ok(());
        }
    };
    if rt.has_artifact("ptc16_ideal") {
        let wf: Vec<f32> = w.iter().map(|&v| v as f32).collect();
        let rm = vec![1.0f32; 16];
        let cm: Vec<f32> = col_mask.iter().map(|&m| m as u8 as f32).collect();
        // batch of 32 identical inputs (artifact signature is fixed)
        let mut xb = vec![0f32; 32 * 16];
        for b in 0..32 {
            for j in 0..16 {
                xb[b * 16 + j] = x[j] as f32;
            }
        }
        let out = rt.run_f32(
            "ptc16_ideal",
            &[(&wf, &[16, 16]), (&rm, &[16]), (&cm, &[16]), (&xb, &[32, 16])],
        )?;
        let y_art: Vec<f64> = out[..16].iter().map(|&v| v as f64).collect();
        println!(
            "  AOT artifact (PJRT {}): ideal-path N-MAE vs rust golden = {:.2e}",
            rt.platform(),
            nmae(&y_art, &golden)
        );
    } else {
        println!("  (run `make artifacts` to exercise the AOT/PJRT path)");
    }
    println!("quickstart OK");
    Ok(())
}

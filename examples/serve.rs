//! Networked batched-inference service over the photonic digital twin.
//!
//! Spawns the coordinator's dynamic-batching server with the CNN-3 model
//! on the full SCATTER configuration, puts it on a TCP socket with the
//! std-only HTTP front-end, drives a stream of `POST /v1/predict`
//! requests through real keep-alive connections, and reports
//! per-request latency percentiles, throughput, accuracy, accelerator
//! energy, and the admission-control counters.
//!
//! ```bash
//! cargo run --release --example serve -- [n_requests]
//! ```

use scatter::bench::common::{BenchCtx, Workload};
use scatter::config::AcceleratorConfig;
use scatter::coordinator::net::{http_request, HttpClient, HttpServer, NetConfig};
use scatter::coordinator::{EngineOptions, InferenceServer, ServerConfig};
use scatter::util::Json;
use std::time::Duration;

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let ctx = BenchCtx::new(n);
    let cfg = AcceleratorConfig::default();
    let (model, ds, masks) = ctx.deployment(Workload::Cnn3, &cfg, 0.3);

    println!(
        "spawning SCATTER inference service: CNN-3, s=0.3, IG+OG+LR, {n} requests, \
         2 engine workers x 2 threads"
    );
    let server = InferenceServer::spawn(
        model,
        cfg,
        EngineOptions::NOISY,
        masks,
        ServerConfig::builder()
            .max_batch(8)
            .batch_timeout(Duration::from_millis(4))
            .workers(2)
            .engine_threads(2)
            .max_in_flight(128)
            .build()
            .expect("example config validates"),
    );
    let http = HttpServer::bind(server, NetConfig::default()).expect("bind ephemeral port");
    let addr = http.local_addr();
    println!("listening on http://{addr}  (try: curl http://{addr}/healthz)");

    // drive n requests through 4 real keep-alive HTTP connections
    let clients = 4usize;
    let correct: usize = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let ds = &ds;
                s.spawn(move || {
                    let mut client = HttpClient::connect(&addr).expect("connect");
                    let mut correct = 0usize;
                    for i in (c..n).step_by(clients) {
                        let (img, label) = ds.sample(0xBEEF, i);
                        let body =
                            Json::obj(vec![("image", Json::arr_f64(&img.data))]).to_string();
                        let resp = client
                            .request("POST", "/v1/predict", Some(&body))
                            .expect("predict");
                        assert_eq!(resp.status, 200, "unexpected: {}", resp.body);
                        let reply = Json::parse(&resp.body).expect("json reply");
                        let class =
                            reply.get("class").and_then(Json::as_usize).expect("class");
                        if class == label {
                            correct += 1;
                        }
                    }
                    correct
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client")).sum()
    });

    // live observability while the service is still up
    let metrics = http_request(&addr, "GET", "/metrics", None).expect("metrics");
    let in_queue = metrics
        .body
        .lines()
        .find(|l| l.starts_with("scatter_requests_total"))
        .unwrap_or("scatter_requests_total ?")
        .to_string();
    println!("live /metrics sample: {in_queue}");

    let report = http.shutdown().expect("graceful drain");
    println!(
        "served {} requests in {} batches across {} engine workers",
        report.requests, report.batches, report.workers
    );
    println!("  accuracy   : {:.1} %", 100.0 * correct as f64 / n as f64);
    println!(
        "  latency    : mean {:.1} us  p50 {} us  p99 {} us",
        report.mean_latency_us, report.p50_us, report.p99_us
    );
    println!("  throughput : {:.1} req/s", report.throughput_rps);
    println!(
        "  accelerator: {:.3} mJ total, P_avg {:.2} W",
        report.energy_mj, report.p_avg_w
    );
    println!(
        "  admission  : shed {}, expired {}, worker_lost {}",
        report.shed, report.expired, report.worker_lost
    );
}

//! Batched inference service over the photonic digital twin.
//!
//! Spawns the coordinator's dynamic-batching server with the CNN-3 model
//! on the full SCATTER configuration, submits a stream of requests from
//! the synthetic FashionMNIST-shaped dataset, and reports per-request
//! latency percentiles, throughput, accuracy, and accelerator energy.
//!
//! ```bash
//! cargo run --release --example serve -- [n_requests]
//! ```

use scatter::bench::common::{BenchCtx, Workload};
use scatter::config::AcceleratorConfig;
use scatter::coordinator::{EngineOptions, InferenceServer, ServerConfig};
use std::time::Duration;

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let ctx = BenchCtx::new(n);
    let cfg = AcceleratorConfig::default();
    let (model, ds, masks) = ctx.deployment(Workload::Cnn3, &cfg, 0.3);

    println!(
        "spawning SCATTER inference server: CNN-3, s=0.3, IG+OG+LR, {n} requests, \
         2 engine workers x 2 threads"
    );
    let server = InferenceServer::spawn(
        model,
        cfg,
        EngineOptions::NOISY,
        masks,
        ServerConfig {
            max_batch: 8,
            batch_timeout: Duration::from_millis(4),
            workers: 2,
            engine_threads: 2,
        },
    );

    let mut pending = Vec::new();
    let mut labels = Vec::new();
    for i in 0..n {
        let (img, label) = ds.sample(0xBEEF, i);
        labels.push(label);
        pending.push(server.submit(img));
    }
    let mut correct = 0usize;
    for (rx, label) in pending.into_iter().zip(labels) {
        let reply = rx.recv().expect("server reply");
        if reply.class == label {
            correct += 1;
        }
    }
    let report = server.shutdown();
    println!(
        "served {} requests in {} batches across {} engine workers",
        report.requests, report.batches, report.workers
    );
    println!("  accuracy   : {:.1} %", 100.0 * correct as f64 / n as f64);
    println!(
        "  latency    : mean {:.1} us  p50 {} us  p99 {} us",
        report.mean_latency_us, report.p50_us, report.p99_us
    );
    println!("  throughput : {:.1} req/s", report.throughput_rps);
    println!(
        "  accelerator: {:.3} mJ total, P_avg {:.2} W",
        report.energy_mj, report.p_avg_w
    );
}

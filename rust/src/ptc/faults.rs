//! Deterministic device-fault injection for the photonic tensor core.
//!
//! [`DeviceFaultPlan`] expresses *hardware* defects — a stuck MZI phase
//! shifter, a dead photodetector row, a dead rerouter tree branch — as
//! data, the same way [`crate::coordinator::FaultPlan`] expresses
//! process-level faults (worker panics, stalls). A plan is parsed once
//! from a CLI spec (`scatter serve --device-faults SPEC`), carried on
//! the engine, and lowered to per-block [`BlockFault`]s at realize time
//! in [`crate::ptc::crossbar`], right next to `realize_drifted`, so a
//! faulted chunk is exactly as bit-reproducible as a drifted one.
//!
//! Grammar (comma-separated entries):
//!
//! ```text
//! stuck@<layer|*>:c<chunk|*>:r<row>:i<col>:p<phase>   stuck-at MZI phase (rad)
//! dead-pd@<layer|*>:c<chunk|*>:r<row>                 dead photodetector (output row)
//! dead-branch@<layer|*>:c<chunk|*>:i<col>             dead rerouter tree branch (input col)
//! rand:s<seed>:n<count>                               macro: <count> seeded stuck cells
//! ```
//!
//! The spec is dimension-free on purpose: `r<row>` / `i<col>` are chunk
//! coordinates reduced modulo the chunk's realized dimensions at
//! lowering time, so a plan parses (and a `ServerConfig` round-trips)
//! without knowing the model, and an out-of-range index can never
//! panic — it just lands on a real device.

use crate::util::XorShiftRng;

/// Raw row/col values emitted by the `rand:` macro before the modulo at
/// lowering time. Any bound larger than every realistic chunk dimension
/// works; this one keeps `describe()` output readable.
const RAND_COORD_SPAN: u64 = 1024;

/// A fault lowered onto one `k1 x k2` crossbar block, in block-local
/// coordinates. Applied by `ProgrammedPtc::set_faults` at realize time,
/// after drift, so the defect survives every drift/restore/reprogram
/// cycle — broken hardware does not heal when software rewrites phases.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BlockFault {
    /// The MZI at `(out, inp)` is stuck at `phase` rad: its realized
    /// weight is pinned to `-sin(phase)` (Eq. 1) regardless of what the
    /// DAC programs.
    StuckPhase { out: usize, inp: usize, phase: f64 },
    /// The photodetector for output row `out` is dead: the whole row
    /// reads zero current.
    DeadOutput { out: usize },
    /// The rerouter branch feeding input column `inp` is dead: no light
    /// reaches the column, so every weight in it reads zero.
    DeadInput { inp: usize },
}

/// One device fault in chunk coordinates: rows span `0..r*k1` (chunk
/// output rows, each backed by a photodetector), cols span `0..c*k2`
/// (chunk input columns, each fed by a rerouter tree branch).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DeviceFault {
    /// Single MZI stuck at a fixed phase.
    StuckMzi { row: usize, col: usize, phase: f64 },
    /// Dead photodetector: chunk output `row` is zero across every
    /// block column (the paper's PD bank sits at the end of the row, so
    /// one dead PD kills the full accumulated output).
    DeadPd { row: usize },
    /// Dead rerouter tree branch: chunk input `col` receives no light
    /// in any block row (the tree fans one branch out to every row).
    DeadBranch { col: usize },
}

#[derive(Clone, Debug, PartialEq)]
struct FaultEntry {
    /// Layer name, or `None` to hit every layer.
    layer: Option<String>,
    /// Chunk id within the layer, or `None` to hit every chunk.
    chunk: Option<usize>,
    fault: DeviceFault,
}

/// A deterministic schedule of hardware defects, parsed from
/// `--device-faults`. Ordering is the spec order; lowering is pure, so
/// the same plan against the same model faults the same devices on
/// every run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DeviceFaultPlan {
    entries: Vec<FaultEntry>,
}

impl DeviceFaultPlan {
    /// The empty plan: no hardware defects.
    pub fn none() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Number of fault entries in the plan.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Merge another plan's entries into this one (mid-life injection:
    /// the engine keeps the union so later reprograms re-acquire every
    /// defect ever injected).
    pub fn extend(&mut self, other: &Self) {
        self.entries.extend(other.entries.iter().cloned());
    }

    /// Parse a comma-separated fault spec (see module docs for the
    /// grammar). The `rand:` macro expands inline, at parse time, into
    /// concrete wildcard `StuckMzi` entries so `describe()` shows
    /// exactly what will be injected.
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut entries = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            if let Some(rest) = part.strip_prefix("rand:") {
                Self::expand_rand(rest, part, &mut entries)?;
            } else {
                entries.push(Self::parse_entry(part)?);
            }
        }
        Ok(Self { entries })
    }

    /// `rand:s<seed>:n<count>` — `count` stuck cells with seeded
    /// coordinates and phases, wildcard layer/chunk.
    fn expand_rand(rest: &str, part: &str, entries: &mut Vec<FaultEntry>) -> Result<(), String> {
        let fields: Vec<&str> = rest.split(':').collect();
        let (seed_field, count_field) = match fields[..] {
            [s, n] => (s, n),
            _ => return Err(format!("device fault '{part}': rand takes s<seed>:n<count>")),
        };
        let seed = seed_field
            .strip_prefix('s')
            .and_then(|v| v.parse::<u64>().ok())
            .ok_or_else(|| format!("device fault '{part}': expected s<seed>, got '{seed_field}'"))?;
        let count = parse_index(count_field, 'n', part)?;
        for k in 0..count {
            let mut rng = XorShiftRng::from_stream(seed, &[k as u64]);
            entries.push(FaultEntry {
                layer: None,
                chunk: None,
                fault: DeviceFault::StuckMzi {
                    row: (rng.next_u64() % RAND_COORD_SPAN) as usize,
                    col: (rng.next_u64() % RAND_COORD_SPAN) as usize,
                    // Most of the ±π/2 weight range: a stuck phase far
                    // from the programmed one, so the defect is visible.
                    phase: rng.uniform_in(-1.4, 1.4),
                },
            });
        }
        Ok(())
    }

    fn parse_entry(entry: &str) -> Result<FaultEntry, String> {
        let (kind, rest) = entry.split_once('@').ok_or_else(|| {
            format!("device fault '{entry}': expected <kind>@<layer>:c<chunk>:... or rand:s<seed>:n<count>")
        })?;
        let fields: Vec<&str> = rest.split(':').collect();
        if fields.len() < 2 {
            return Err(format!("device fault '{entry}': expected <layer|*>:c<chunk|*> after '@'"));
        }
        let layer = match fields[0] {
            "" => return Err(format!("device fault '{entry}': empty layer name (use '*' for any)")),
            "*" => None,
            name => Some(name.to_string()),
        };
        let chunk = parse_wild_index(fields[1], 'c', entry)?;
        let fault = match (kind, &fields[2..]) {
            ("stuck", [row, col, phase]) => DeviceFault::StuckMzi {
                row: parse_index(row, 'r', entry)?,
                col: parse_index(col, 'i', entry)?,
                phase: parse_phase(phase, entry)?,
            },
            ("dead-pd", [row]) => DeviceFault::DeadPd { row: parse_index(row, 'r', entry)? },
            ("dead-branch", [col]) => {
                DeviceFault::DeadBranch { col: parse_index(col, 'i', entry)? }
            }
            ("stuck", _) => {
                return Err(format!("device fault '{entry}': stuck takes :r<row>:i<col>:p<phase>"))
            }
            ("dead-pd", _) => return Err(format!("device fault '{entry}': dead-pd takes :r<row>")),
            ("dead-branch", _) => {
                return Err(format!("device fault '{entry}': dead-branch takes :i<col>"))
            }
            _ => {
                return Err(format!(
                    "device fault '{entry}': unknown kind '{kind}' (stuck | dead-pd | dead-branch)"
                ))
            }
        };
        Ok(FaultEntry { layer, chunk, fault })
    }

    /// Human-readable plan, one line per entry, in the spec grammar —
    /// `describe().join(",")` re-parses to an equal plan.
    pub fn describe(&self) -> Vec<String> {
        self.entries
            .iter()
            .map(|e| {
                let layer = e.layer.as_deref().unwrap_or("*");
                let chunk = match e.chunk {
                    Some(c) => format!("c{c}"),
                    None => "c*".to_string(),
                };
                match e.fault {
                    DeviceFault::StuckMzi { row, col, phase } => {
                        format!("stuck@{layer}:{chunk}:r{row}:i{col}:p{phase}")
                    }
                    DeviceFault::DeadPd { row } => format!("dead-pd@{layer}:{chunk}:r{row}"),
                    DeviceFault::DeadBranch { col } => {
                        format!("dead-branch@{layer}:{chunk}:i{col}")
                    }
                }
            })
            .collect()
    }

    /// Lower every entry matching `(layer, chunk)` onto the chunk's
    /// `r x c` grid of `k1 x k2` blocks. Returns `(block_index, fault)`
    /// pairs with `block_index = block_row * c + block_col`, the layout
    /// `program_chunk` uses. Chunk coordinates reduce modulo the chunk
    /// dimensions here, so any spec lands on real devices.
    pub fn block_faults(
        &self,
        layer: &str,
        chunk: usize,
        k1: usize,
        k2: usize,
        r: usize,
        c: usize,
    ) -> Vec<(usize, BlockFault)> {
        let (rows, cols) = (r * k1, c * k2);
        let mut lowered = Vec::new();
        if rows == 0 || cols == 0 {
            return lowered;
        }
        for e in &self.entries {
            if let Some(l) = &e.layer {
                if l != layer {
                    continue;
                }
            }
            if let Some(cid) = e.chunk {
                if cid != chunk {
                    continue;
                }
            }
            match e.fault {
                DeviceFault::StuckMzi { row, col, phase } => {
                    let (row, col) = (row % rows, col % cols);
                    lowered.push((
                        (row / k1) * c + col / k2,
                        BlockFault::StuckPhase { out: row % k1, inp: col % k2, phase },
                    ));
                }
                DeviceFault::DeadPd { row } => {
                    // The PD accumulates the row across every block
                    // column, so one dead PD zeroes the row in all of
                    // them.
                    let row = row % rows;
                    for b in 0..c {
                        lowered
                            .push(((row / k1) * c + b, BlockFault::DeadOutput { out: row % k1 }));
                    }
                }
                DeviceFault::DeadBranch { col } => {
                    // The rerouter tree fans one branch out to every
                    // block row, so a dead branch starves the column in
                    // all of them.
                    let col = col % cols;
                    for a in 0..r {
                        lowered.push((a * c + col / k2, BlockFault::DeadInput { inp: col % k2 }));
                    }
                }
            }
        }
        lowered
    }
}

fn parse_index(field: &str, tag: char, entry: &str) -> Result<usize, String> {
    field
        .strip_prefix(tag)
        .and_then(|v| v.parse::<usize>().ok())
        .ok_or_else(|| format!("device fault '{entry}': expected {tag}<index>, got '{field}'"))
}

fn parse_wild_index(field: &str, tag: char, entry: &str) -> Result<Option<usize>, String> {
    if field.len() == 2 && field.starts_with(tag) && field.ends_with('*') {
        return Ok(None);
    }
    parse_index(field, tag, entry).map(Some)
}

fn parse_phase(field: &str, entry: &str) -> Result<f64, String> {
    field
        .strip_prefix('p')
        .and_then(|v| v.parse::<f64>().ok())
        .filter(|p| p.is_finite())
        .ok_or_else(|| format!("device fault '{entry}': expected p<phase-rad>, got '{field}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_kind() {
        let plan = DeviceFaultPlan::parse(
            "stuck@fc1:c3:r5:i2:p0.75, dead-pd@*:c0:r7, dead-branch@conv2:c*:i11",
        )
        .expect("valid spec");
        assert_eq!(plan.entries.len(), 3);
        assert_eq!(
            plan.entries[0],
            FaultEntry {
                layer: Some("fc1".into()),
                chunk: Some(3),
                fault: DeviceFault::StuckMzi { row: 5, col: 2, phase: 0.75 },
            }
        );
        assert_eq!(
            plan.entries[1],
            FaultEntry { layer: None, chunk: Some(0), fault: DeviceFault::DeadPd { row: 7 } }
        );
        assert_eq!(
            plan.entries[2],
            FaultEntry {
                layer: Some("conv2".into()),
                chunk: None,
                fault: DeviceFault::DeadBranch { col: 11 },
            }
        );
        // Negative stuck phases parse too.
        let neg = DeviceFaultPlan::parse("stuck@*:c*:r0:i0:p-1.25").expect("negative phase");
        assert_eq!(
            neg.entries[0].fault,
            DeviceFault::StuckMzi { row: 0, col: 0, phase: -1.25 }
        );
    }

    #[test]
    fn rejects_malformed_specs() {
        for spec in [
            "stuck",                          // no '@', not the rand macro
            "melt@fc1:c0:r1",                 // unknown kind
            "stuck@fc1:c0:r5:i2",             // missing phase
            "stuck@fc1:c0:r5:i2:p0.1:x9",     // too many fields
            "stuck@fc1:c0:r5:i2:pNaN",        // non-finite phase
            "stuck@:c0:r5:i2:p0.1",           // empty layer
            "stuck@fc1:q0:r5:i2:p0.1",        // bad chunk tag
            "stuck@fc1:c0:rX:i2:p0.1",        // non-numeric row
            "stuck@fc1:c0:r5:i-2:p0.1",       // negative col
            "dead-pd@fc1:c0",                 // missing row
            "dead-pd@fc1:c0:r1:r2",           // too many fields
            "dead-branch@fc1:c0:r1",          // wrong tag for col
            "rand:s1",                        // missing count
            "rand:s1:n2:x3",                  // too many fields
            "rand:sx:n2",                     // bad seed
        ] {
            let err = DeviceFaultPlan::parse(spec).expect_err(spec);
            assert!(err.contains("device fault"), "{spec}: {err}");
        }
    }

    #[test]
    fn rand_is_seed_deterministic() {
        let a = DeviceFaultPlan::parse("rand:s7:n5").expect("macro");
        let b = DeviceFaultPlan::parse("rand:s7:n5").expect("macro");
        assert_eq!(a, b, "same seed, same plan");
        assert_eq!(a.entries.len(), 5);
        for e in &a.entries {
            assert_eq!((e.layer.clone(), e.chunk), (None, None), "rand entries are wildcards");
            match e.fault {
                DeviceFault::StuckMzi { phase, .. } => {
                    assert!(phase.abs() <= 1.4, "phase in range: {phase}")
                }
                other => panic!("rand expands to StuckMzi only, got {other:?}"),
            }
        }
        let c = DeviceFaultPlan::parse("rand:s8:n5").expect("macro");
        assert_ne!(a, c, "different seed, different plan");
    }

    #[test]
    fn describe_round_trips_through_parse() {
        let plan = DeviceFaultPlan::parse(
            "stuck@fc1:c3:r5:i2:p-0.75, dead-pd@*:c1:r7, dead-branch@conv2:c*:i11, rand:s42:n3",
        )
        .expect("valid spec");
        let described = plan.describe();
        assert_eq!(described.len(), 6, "rand expands inline");
        let reparsed = DeviceFaultPlan::parse(&described.join(",")).expect("describe re-parses");
        assert_eq!(plan, reparsed);
    }

    #[test]
    fn empty_plans_lower_to_nothing() {
        assert!(DeviceFaultPlan::none().is_empty());
        assert!(DeviceFaultPlan::parse("").expect("empty").is_empty());
        assert!(DeviceFaultPlan::parse(" , ,").expect("blanks").is_empty());
        assert!(DeviceFaultPlan::none().block_faults("fc1", 0, 4, 4, 2, 3).is_empty());
    }

    #[test]
    fn lowering_maps_chunk_coordinates_onto_blocks() {
        // Chunk grid: r=2 x c=3 blocks of k1=4 x k2=4 -> 8 rows, 12 cols.
        let (k1, k2, r, c) = (4, 4, 2, 3);
        let plan = DeviceFaultPlan::parse("stuck@fc1:c2:r5:i9:p0.3").expect("spec");
        // row 5 -> block row 1, local out 1; col 9 -> block col 2, local inp 1.
        assert_eq!(
            plan.block_faults("fc1", 2, k1, k2, r, c),
            vec![(c + 2, BlockFault::StuckPhase { out: 1, inp: 1, phase: 0.3 })]
        );
        // Layer and chunk filters apply.
        assert!(plan.block_faults("fc2", 2, k1, k2, r, c).is_empty());
        assert!(plan.block_faults("fc1", 0, k1, k2, r, c).is_empty());

        // Dead PD at row 6 kills output 2 of every block in block-row 1.
        let pd = DeviceFaultPlan::parse("dead-pd@*:c*:r6").expect("spec");
        assert_eq!(
            pd.block_faults("any", 9, k1, k2, r, c),
            vec![
                (3, BlockFault::DeadOutput { out: 2 }),
                (4, BlockFault::DeadOutput { out: 2 }),
                (5, BlockFault::DeadOutput { out: 2 }),
            ]
        );

        // Dead branch at col 10 starves input 2 of block-col 2 in every row.
        let br = DeviceFaultPlan::parse("dead-branch@*:c*:i10").expect("spec");
        assert_eq!(
            br.block_faults("any", 0, k1, k2, r, c),
            vec![(2, BlockFault::DeadInput { inp: 2 }), (5, BlockFault::DeadInput { inp: 2 })]
        );

        // Out-of-range coordinates wrap instead of panicking:
        // 1005 % 8 == 5, 1029 % 12 == 9, so this is the first entry again.
        let wrapped = DeviceFaultPlan::parse("stuck@*:c*:r1005:i1029:p0.3").expect("spec");
        assert_eq!(
            wrapped.block_faults("fc1", 0, k1, k2, r, c),
            plan.block_faults("fc1", 2, k1, k2, r, c)
        );
    }
}

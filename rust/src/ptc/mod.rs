//! Phase-agnostic incoherent photonic tensor core simulator (§3.1.1).
//!
//! * [`crossbar`] — single-PTC noisy MVM with the full non-ideality stack:
//!   thermal crosstalk (Eqs. 8–9), driver phase noise, extinction-ratio
//!   leakage, PD photocurrent noise (Eq. 11), and the three column-sparsity
//!   operating modes of Fig. 5 (prune-only / IG / IG+LR) plus output gating.
//! * [`sim`] — chunk-level execution: an `rk1 × ck2` weight chunk mapped
//!   across r·c PTCs with analog partial-product accumulation across the
//!   c cores of a tile (§3.3.3).
//! * [`faults`] — deterministic device-defect injection (stuck MZI
//!   phases, dead PD rows, dead rerouter branches), lowered onto blocks
//!   at realize time so faulted chunks stay bit-reproducible.

pub mod crossbar;
pub mod faults;
pub mod sim;

pub use crossbar::{ColumnMode, ForwardOptions, PtcSimulator};
pub use faults::{BlockFault, DeviceFault, DeviceFaultPlan};
pub use sim::ChunkSimulator;

//! Phase-agnostic incoherent photonic tensor core simulator (§3.1.1).
//!
//! * [`crossbar`] — single-PTC noisy MVM with the full non-ideality stack:
//!   thermal crosstalk (Eqs. 8–9), driver phase noise, extinction-ratio
//!   leakage, PD photocurrent noise (Eq. 11), and the three column-sparsity
//!   operating modes of Fig. 5 (prune-only / IG / IG+LR) plus output gating.
//! * [`sim`] — chunk-level execution: an `rk1 × ck2` weight chunk mapped
//!   across r·c PTCs with analog partial-product accumulation across the
//!   c cores of a tile (§3.3.3).

pub mod crossbar;
pub mod sim;

pub use crossbar::{ColumnMode, ForwardOptions, PtcSimulator};
pub use sim::ChunkSimulator;

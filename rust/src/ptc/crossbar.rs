//! Single-PTC noisy crossbar MVM (§3.1.1, §3.3.2).
//!
//! Physical layout: the k1×k2 weight matrix W (output × input) maps onto a
//! grid of MZI nodes with physical row = input index j (vertical pitch
//! l_v) and physical column = output index i (horizontal pitch l_h); flat
//! node index m = j·k1 + i matches `thermal::CouplingModel`'s geometry.
//!
//! Signal chain per node: the input intensity u_j enters the node's 1×2
//! MZI power splitter; balanced photodetection of the two outputs yields
//! the full-range product `W_ij·u_j = −sin(Δφ̃_ij)·u_j` (Eq. 1); column
//! photocurrents accumulate along each physical column (output i).

use crate::devices::DeviceLibrary;
use crate::ptc::faults::BlockFault;
use crate::thermal::{coupling::ArrayGeometry, CouplingModel, GammaModel};
use crate::util::XorShiftRng;

/// How pruned weight-chunk *columns* (input ports) are handled (Fig. 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ColumnMode {
    /// Weight pruning only: even splitter, modulators stay on; pruned
    /// paths leak `δw·x` into the output (Eq. 12).
    #[default]
    PruneOnly,
    /// + input gating: DAC/MZM power-gated; residual light at the
    /// extinction-ratio floor still leaks `δw·δx` (Eq. 13).
    InputGating,
    /// + in-situ light redistribution: the rerouter steers all power to
    /// active ports (×k2/k2′) and the TIA gain is rescaled by k2′/k2;
    /// leakage is eliminated and PD noise shrinks (Eq. 14).
    InputGatingLr,
}

/// Per-call simulation options.
#[derive(Debug, Clone, Copy, Default)]
pub struct ForwardOptions<'m> {
    /// Apply inter-MZI thermal crosstalk (Eqs. 8–9).
    pub thermal: bool,
    /// Add PD photocurrent noise δn_PD (Eq. 11).
    pub pd_noise: bool,
    /// Add random phase noise on driven MZIs.
    pub phase_noise: bool,
    /// Column (input) sparsity mask, length k2; `None` = dense.
    pub col_mask: Option<&'m [bool]>,
    /// Row (output) sparsity mask, length k1; `None` = dense.
    pub row_mask: Option<&'m [bool]>,
    /// Column handling mode.
    pub col_mode: ColumnMode,
    /// Output TIA/ADC gating: pruned rows read back exact zero and their
    /// MZIs/PDs are powered down (§3.3.3).
    pub output_gating: bool,
}

/// The simulator for one k1×k2 PTC at a fixed geometry.
#[derive(Debug, Clone)]
pub struct PtcSimulator {
    pub k1: usize,
    pub k2: usize,
    pub lib: DeviceLibrary,
    coupling: CouplingModel,
}

impl PtcSimulator {
    pub fn new(geom: ArrayGeometry, gamma: &GammaModel, lib: DeviceLibrary) -> Self {
        Self { k1: geom.cols, k2: geom.rows, lib, coupling: CouplingModel::new(geom, gamma) }
    }

    pub fn from_config(cfg: &crate::AcceleratorConfig) -> Self {
        Self::new(
            ArrayGeometry::from_config(cfg),
            &GammaModel::paper(),
            DeviceLibrary::default(),
        )
    }

    pub fn coupling(&self) -> &CouplingModel {
        &self.coupling
    }

    /// Ideal MVM `y = W·x` (masked entries contribute exactly zero).
    pub fn forward_ideal(
        &self,
        w: &[f64],
        x: &[f64],
        col_mask: Option<&[bool]>,
        row_mask: Option<&[bool]>,
    ) -> Vec<f64> {
        self.check_shapes(w, x);
        let mut y = vec![0.0; self.k1];
        for i in 0..self.k1 {
            if let Some(rm) = row_mask {
                if !rm[i] {
                    continue;
                }
            }
            let mut acc = 0.0;
            for j in 0..self.k2 {
                if let Some(cm) = col_mask {
                    if !cm[j] {
                        continue;
                    }
                }
                acc += w[i * self.k2 + j] * x[j];
            }
            y[i] = acc;
        }
        y
    }

    /// Noisy MVM through the full photonic signal chain.
    ///
    /// * `w` — row-major k1×k2 weights in [−1, 1].
    /// * `x` — length-k2 non-negative normalized inputs in [0, 1].
    pub fn forward(
        &self,
        w: &[f64],
        x: &[f64],
        opts: &ForwardOptions,
        rng: &mut XorShiftRng,
    ) -> Vec<f64> {
        self.check_shapes(w, x);
        let (k1, k2) = (self.k1, self.k2);
        let n = k1 * k2;
        let full = vec![true; k1.max(k2)];
        let col_mask = opts.col_mask.unwrap_or(&full[..k2]);
        let row_mask = opts.row_mask.unwrap_or(&full[..k1]);
        assert_eq!(col_mask.len(), k2, "col mask len");
        assert_eq!(row_mask.len(), k1, "row mask len");

        // 1. program target phases; pruned weights are power-gated — but a
        //    powered-off MZI still holds its fabricated bias deviation
        //    (φ_b ≠ π/2 exactly), the Eq.-12 δw leakage source.
        let mut phases = vec![0.0f64; n];
        for j in 0..k2 {
            for i in 0..k1 {
                let active = row_mask[i] && col_mask[j];
                if active {
                    let mut phi = crate::devices::Mzi::phase_from_weight(w[i * k2 + j]);
                    if opts.phase_noise {
                        phi += rng.gaussian_std(self.lib.phase_noise_std);
                    }
                    phases[j * k1 + i] = phi;
                } else if opts.phase_noise {
                    phases[j * k1 + i] = rng.gaussian_std(self.lib.bias_deviation_std);
                }
            }
        }

        // 2. thermal crosstalk perturbs every MZI, driven or not.
        let phases = if opts.thermal { self.coupling.perturbed(&phases) } else { phases };

        // 3. realized weights through the Eq.-1 transfer.
        //    (collect once; the hot loop below reads them column-wise)
        let mut w_real = vec![0.0f64; n];
        for (m, &phi) in phases.iter().enumerate() {
            w_real[m] = crate::devices::Mzi::weight_from_phase(phi);
        }

        // 4. per-port input intensities under the column mode.
        let k2_active = col_mask.iter().filter(|&&m| m).count();
        let leak = self.lib.leakage_floor();
        let mut u = vec![0.0f64; k2];
        let mut lr_gain = 1.0;
        match opts.col_mode {
            ColumnMode::PruneOnly => {
                for j in 0..k2 {
                    u[j] = x[j].max(0.0);
                }
            }
            ColumnMode::InputGating => {
                for j in 0..k2 {
                    // gated modulators leak the ER floor of the CW carrier
                    u[j] = if col_mask[j] { x[j].max(0.0) } else { leak };
                }
            }
            ColumnMode::InputGatingLr => {
                let boost = if k2_active == 0 { 0.0 } else { k2 as f64 / k2_active as f64 };
                lr_gain = k2_active as f64 / k2 as f64; // TIA rescale (Eq. 14)
                for j in 0..k2 {
                    u[j] = if col_mask[j] { x[j].max(0.0) * boost } else { 0.0 };
                }
            }
        }

        // 5. photocurrent accumulation along each physical column, one PD
        //    noise draw per node (Eq. 11), TIA gain, output gating.
        let mut y = vec![0.0f64; k1];
        for i in 0..k1 {
            if opts.output_gating && !row_mask[i] {
                // TIA/ADC powered down: exact zero, no noise (§3.3.3)
                continue;
            }
            let mut acc = 0.0;
            for j in 0..k2 {
                acc += w_real[j * k1 + i] * u[j];
                if opts.pd_noise {
                    acc += rng.gaussian_std(self.lib.pd_noise_std);
                }
            }
            y[i] = acc * lr_gain;
        }
        y
    }

    fn check_shapes(&self, w: &[f64], x: &[f64]) {
        assert_eq!(w.len(), self.k1 * self.k2, "weight shape must be k1*k2");
        assert_eq!(x.len(), self.k2, "input must be length k2");
    }

    /// Program the PTC once for a weight block + masks, precomputing the
    /// crosstalk-perturbed realized weights. Streaming inputs through
    /// [`ProgrammedPtc::run`] then costs one k1×k2 mat-vec per vector —
    /// exactly the hardware's "program weights, stream activations" split.
    ///
    /// Phase noise is drawn once at programming time (it models static
    /// driver/DAC error, not per-cycle noise).
    pub fn program(
        &self,
        w: &[f64],
        opts: &ForwardOptions,
        rng: &mut XorShiftRng,
    ) -> ProgrammedPtc {
        let (k1, k2) = (self.k1, self.k2);
        assert_eq!(w.len(), k1 * k2);
        let full = vec![true; k1.max(k2)];
        let col_mask = opts.col_mask.unwrap_or(&full[..k2]).to_vec();
        let row_mask = opts.row_mask.unwrap_or(&full[..k1]).to_vec();

        let mut phases = vec![0.0f64; k1 * k2];
        for j in 0..k2 {
            for i in 0..k1 {
                if row_mask[i] && col_mask[j] {
                    let mut phi = crate::devices::Mzi::phase_from_weight(w[i * k2 + j]);
                    if opts.phase_noise {
                        phi += rng.gaussian_std(self.lib.phase_noise_std);
                    }
                    phases[j * k1 + i] = phi;
                } else if opts.phase_noise {
                    // fabricated bias deviation on powered-off MZIs (δw)
                    phases[j * k1 + i] = rng.gaussian_std(self.lib.bias_deviation_std);
                }
            }
        }
        let phases = if opts.thermal { self.coupling.perturbed(&phases) } else { phases };

        // store realized weights row-major (k1×k2) for cache-friendly runs
        let mut w_real = vec![0.0f64; k1 * k2];
        let mut phase_abs = vec![0.0f64; k1 * k2];
        for j in 0..k2 {
            for i in 0..k1 {
                w_real[i * k2 + j] = crate::devices::Mzi::weight_from_phase(phases[j * k1 + i]);
                phase_abs[i * k2 + j] = phases[j * k1 + i].abs();
            }
        }
        let programmed_phases = phases;

        // per-port input scaling under the column mode
        let k2_active = col_mask.iter().filter(|&&m| m).count();
        let leak = self.lib.leakage_floor();
        let (u_gain, u_floor, lr_gain) = match opts.col_mode {
            ColumnMode::PruneOnly => (vec![1.0; k2], vec![0.0; k2], 1.0),
            ColumnMode::InputGating => {
                let g: Vec<f64> =
                    col_mask.iter().map(|&m| if m { 1.0 } else { 0.0 }).collect();
                let f: Vec<f64> =
                    col_mask.iter().map(|&m| if m { 0.0 } else { leak }).collect();
                (g, f, 1.0)
            }
            ColumnMode::InputGatingLr => {
                let boost =
                    if k2_active == 0 { 0.0 } else { k2 as f64 / k2_active as f64 };
                let g: Vec<f64> =
                    col_mask.iter().map(|&m| if m { boost } else { 0.0 }).collect();
                (g, vec![0.0; k2], k2_active as f64 / k2 as f64)
            }
        };

        ProgrammedPtc {
            k1,
            k2,
            w_real,
            phase_abs,
            mask_gen: 0,
            phases: programmed_phases,
            row_mask,
            u_gain,
            u_floor,
            lr_gain,
            output_gating: opts.output_gating,
            faults: Vec::new(),
            pd_noise: opts.pd_noise,
            pd_noise_std: self.lib.pd_noise_std,
            scratch: vec![0.0; k2],
        }
    }
}

/// A PTC with weights programmed and non-idealities frozen; streams input
/// vectors at one mat-vec each.
#[derive(Debug, Clone)]
pub struct ProgrammedPtc {
    pub k1: usize,
    pub k2: usize,
    /// Realized (crosstalk-perturbed) weights, row-major k1×k2.
    pub w_real: Vec<f64>,
    /// |Δφ̃| per weight (row-major) — read once at programming time by
    /// the MZI hold-power model. [`Self::realize_drifted`] keeps it in
    /// sync with the current realized phases, but the energy ledger
    /// intentionally stays at programming-time power (drift is bounded
    /// by the recalibration budget; EXPERIMENTS.md §Thermal-drift).
    pub phase_abs: Vec<f64>,
    /// Mask generation whose row/column masks this block was programmed
    /// under (0 = baseline). The simulator always programs at 0; the
    /// engine stamps the real generation when it (re)programs a chunk,
    /// so hot-swapped blocks are attributable to their mask artifact.
    pub mask_gen: u64,
    /// Signed programmed phases (crosstalk-perturbed, node layout
    /// j·k1+i) — the calibration reference [`Self::realize_drifted`]
    /// re-realizes against when runtime thermal drift moves the array.
    phases: Vec<f64>,
    // pub(crate): `exec::plan` compiles these frozen non-idealities into
    // gain-folded active-index execution plans.
    pub(crate) row_mask: Vec<bool>,
    pub(crate) u_gain: Vec<f64>,
    pub(crate) u_floor: Vec<f64>,
    pub(crate) lr_gain: f64,
    pub(crate) output_gating: bool,
    /// Hardware defects pinned onto this block ([`BlockFault`]). Applied
    /// after every (re-)realization — programming or drifting the phases
    /// cannot heal broken devices, so faulted chunks stay exactly as
    /// bit-reproducible as healthy ones.
    faults: Vec<BlockFault>,
    pd_noise: bool,
    pd_noise_std: f64,
    scratch: Vec<f64>,
}

impl ProgrammedPtc {
    /// Run one input vector through the programmed crossbar, accumulating
    /// into `y` (length k1). PD noise (if enabled) is drawn fresh per call
    /// — it is per-cycle photocurrent noise.
    pub fn run_into(&mut self, x: &[f64], y: &mut [f64], rng: &mut XorShiftRng) {
        assert_eq!(x.len(), self.k2);
        assert_eq!(y.len(), self.k1);
        // effective port intensities
        let mut u = std::mem::take(&mut self.scratch);
        for j in 0..self.k2 {
            u[j] = x[j].max(0.0) * self.u_gain[j] + self.u_floor[j];
        }
        let noise_std_row = self.pd_noise_std * (self.k2 as f64).sqrt();
        for i in 0..self.k1 {
            if self.output_gating && !self.row_mask[i] {
                continue;
            }
            let wrow = &self.w_real[i * self.k2..(i + 1) * self.k2];
            let mut acc = 0.0;
            for j in 0..self.k2 {
                acc += wrow[j] * u[j];
            }
            if self.pd_noise {
                // sum of k2 iid gaussians == one gaussian at sqrt(k2)·σ
                acc += rng.gaussian_std(noise_std_row);
            }
            y[i] += acc * self.lr_gain;
        }
        self.scratch = u;
    }

    pub fn run(&mut self, x: &[f64], rng: &mut XorShiftRng) -> Vec<f64> {
        let mut y = vec![0.0; self.k1];
        self.run_into(x, &mut y, rng);
        y
    }

    /// Re-realize the crossbar from its programmed phases plus a runtime
    /// drift offset `scale · pattern[m]` per node (node layout j·k1+i,
    /// matching [`crate::thermal::DriftModel::block_pattern`]).
    ///
    /// `scale == 0.0` reproduces the programming-time realized weights
    /// **bit for bit** — the same `weight_from_phase(phases[m])`
    /// evaluation as [`PtcSimulator::program`] — which is what makes a
    /// recalibrated chunk indistinguishable from a freshly programmed
    /// one without re-running masks, quantization, or the crosstalk
    /// model. Device faults re-pin afterwards: a stuck or dead node is
    /// stuck through drift *and* through restoration, so faulted blocks
    /// keep the same bit-exactness contract on their healthy nodes.
    pub fn realize_drifted(&mut self, scale: f64, pattern: &[f64]) {
        let (k1, k2) = (self.k1, self.k2);
        assert_eq!(pattern.len(), k1 * k2, "drift pattern must cover the array");
        for j in 0..k2 {
            for i in 0..k1 {
                let m = j * k1 + i;
                // scale 0 short-circuits the add so ±0.0 phases keep
                // their programming-time bit pattern exactly
                let phi = if scale == 0.0 {
                    self.phases[m]
                } else {
                    self.phases[m] + scale * pattern[m]
                };
                self.w_real[i * k2 + j] = crate::devices::Mzi::weight_from_phase(phi);
                self.phase_abs[i * k2 + j] = phi.abs();
            }
        }
        self.apply_faults();
    }

    /// Pin hardware defects onto this block (block-local coordinates,
    /// from [`crate::ptc::DeviceFaultPlan::block_faults`]). Takes effect
    /// immediately and re-applies after every future realization.
    pub fn set_faults(&mut self, faults: Vec<BlockFault>) {
        self.faults = faults;
        self.apply_faults();
    }

    pub fn faults(&self) -> &[BlockFault] {
        &self.faults
    }

    /// Overwrite realized weights at faulted devices. Stuck MZIs realize
    /// their stuck phase through Eq. 1 (and burn its hold power); dead
    /// PD rows and dead rerouter branches read exactly zero (no light,
    /// no current — their phase-power entries are left untouched since
    /// the heater may still be driven).
    fn apply_faults(&mut self) {
        let (k1, k2) = (self.k1, self.k2);
        for fi in 0..self.faults.len() {
            let f = self.faults[fi];
            match f {
                BlockFault::StuckPhase { out, inp, phase } => {
                    self.w_real[out * k2 + inp] = crate::devices::Mzi::weight_from_phase(phase);
                    self.phase_abs[out * k2 + inp] = phase.abs();
                }
                BlockFault::DeadOutput { out } => {
                    for j in 0..k2 {
                        self.w_real[out * k2 + j] = 0.0;
                    }
                }
                BlockFault::DeadInput { inp } => {
                    for i in 0..k1 {
                        self.w_real[i * k2 + inp] = 0.0;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod programmed_tests {
    use super::*;
    use crate::devices::DeviceLibrary;
    use crate::thermal::{coupling::ArrayGeometry, GammaModel};
    use crate::util::nmae;

    fn sim() -> PtcSimulator {
        let geom = ArrayGeometry { rows: 16, cols: 16, l_v: 120.0, l_h: 16.0, l_s: 9.0 };
        PtcSimulator::new(geom, &GammaModel::paper(), DeviceLibrary::default())
    }

    #[test]
    fn programmed_matches_forward_noiseless() {
        let s = sim();
        let mut rng = XorShiftRng::new(1);
        let mut w = vec![0.0; 256];
        rng.fill_uniform(&mut w, -1.0, 1.0);
        let mut x = vec![0.0; 16];
        rng.fill_uniform(&mut x, 0.0, 1.0);
        let col_mask: Vec<bool> = (0..16).map(|j| j % 2 == 0).collect();
        let row_mask: Vec<bool> = (0..16).map(|i| i % 4 != 3).collect();
        for mode in [ColumnMode::PruneOnly, ColumnMode::InputGating, ColumnMode::InputGatingLr] {
            let opts = ForwardOptions {
                thermal: true,
                col_mask: Some(&col_mask),
                row_mask: Some(&row_mask),
                col_mode: mode,
                output_gating: true,
                ..Default::default()
            };
            let y_fwd = s.forward(&w, &x, &opts, &mut XorShiftRng::new(0));
            let mut prog = s.program(&w, &opts, &mut XorShiftRng::new(0));
            let y_prog = prog.run(&x, &mut XorShiftRng::new(0));
            assert!(nmae(&y_prog, &y_fwd) < 1e-12, "mode {mode:?}");
        }
    }

    #[test]
    fn realize_drifted_perturbs_and_restores_exactly() {
        let s = sim();
        let mut rng = XorShiftRng::new(5);
        let mut w = vec![0.0; 256];
        rng.fill_uniform(&mut w, -1.0, 1.0);
        let opts = ForwardOptions { thermal: true, ..Default::default() };
        let mut prog = s.program(&w, &opts, &mut XorShiftRng::new(0));
        let w0 = prog.w_real.clone();
        let p0 = prog.phase_abs.clone();
        let pattern: Vec<f64> = (0..256).map(|m| 0.4 + (m % 5) as f64 * 0.1).collect();
        prog.realize_drifted(0.2, &pattern);
        assert_ne!(prog.w_real, w0, "drift must move realized weights");
        prog.realize_drifted(0.0, &pattern);
        assert_eq!(prog.w_real, w0, "recalibration restores weights bit-for-bit");
        assert_eq!(prog.phase_abs, p0, "and the power-model phases");
    }

    #[test]
    fn device_faults_pin_weights_through_drift_and_restore() {
        let s = sim();
        let mut rng = XorShiftRng::new(5);
        let mut w = vec![0.0; 256];
        rng.fill_uniform(&mut w, -1.0, 1.0);
        let opts = ForwardOptions { thermal: true, ..Default::default() };
        let mut prog = s.program(&w, &opts, &mut XorShiftRng::new(0));
        let clean = prog.w_real.clone();

        prog.set_faults(vec![
            BlockFault::StuckPhase { out: 2, inp: 3, phase: 0.9 },
            BlockFault::DeadOutput { out: 5 },
            BlockFault::DeadInput { inp: 7 },
        ]);
        let stuck_w = crate::devices::Mzi::weight_from_phase(0.9);
        assert_eq!(prog.w_real[2 * 16 + 3], stuck_w, "stuck MZI pinned");
        assert!((0..16).all(|j| prog.w_real[5 * 16 + j] == 0.0), "dead PD row dark");
        assert!((0..16).all(|i| prog.w_real[i * 16 + 7] == 0.0), "dead branch dark");
        let faulted = prog.w_real.clone();

        let pattern: Vec<f64> = (0..256).map(|m| 0.4 + (m % 5) as f64 * 0.1).collect();
        prog.realize_drifted(0.2, &pattern);
        assert_eq!(prog.w_real[2 * 16 + 3], stuck_w, "stuck cell ignores drift");
        assert!((0..16).all(|j| prog.w_real[5 * 16 + j] == 0.0), "dead row stays dark");
        assert_ne!(prog.w_real, faulted, "healthy cells still drift");

        prog.realize_drifted(0.0, &pattern);
        assert_eq!(prog.w_real, faulted, "restore is bit-exact, faults included");
        for i in 0..16 {
            for j in 0..16 {
                if i == 5 || j == 7 || (i == 2 && j == 3) {
                    continue;
                }
                assert_eq!(
                    prog.w_real[i * 16 + j],
                    clean[i * 16 + j],
                    "healthy node ({i},{j}) matches the fault-free program bit-for-bit"
                );
            }
        }
    }

    #[test]
    fn programmed_noise_statistics_match_forward() {
        let s = sim();
        let mut rng = XorShiftRng::new(2);
        let mut w = vec![0.0; 256];
        rng.fill_uniform(&mut w, -1.0, 1.0);
        let mut x = vec![0.0; 16];
        rng.fill_uniform(&mut x, 0.0, 1.0);
        let opts = ForwardOptions { pd_noise: true, ..Default::default() };
        let ideal = s.forward_ideal(&w, &x, None, None);
        let mut prog = s.program(&w, &opts, &mut XorShiftRng::new(0));
        let mut acc2 = 0.0;
        let trials = 3000;
        let mut nrng = XorShiftRng::new(3);
        for _ in 0..trials {
            let y = prog.run(&x, &mut nrng);
            for i in 0..16 {
                acc2 += (y[i] - ideal[i]).powi(2);
            }
        }
        let std = (acc2 / (trials * 16) as f64).sqrt();
        // sqrt(16)*0.01 = 0.04
        assert!((std - 0.04).abs() < 0.002, "std={std}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{nmae, snr_db};

    fn geom(k1: usize, k2: usize, l_g: f64) -> ArrayGeometry {
        ArrayGeometry { rows: k2, cols: k1, l_v: 120.0, l_h: l_g + 15.0, l_s: 9.0 }
    }

    fn sim(k1: usize, k2: usize, l_g: f64) -> PtcSimulator {
        PtcSimulator::new(geom(k1, k2, l_g), &GammaModel::paper(), DeviceLibrary::default())
    }

    fn rand_problem(k1: usize, k2: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = XorShiftRng::new(seed);
        let mut w = vec![0.0; k1 * k2];
        rng.fill_uniform(&mut w, -1.0, 1.0);
        let mut x = vec![0.0; k2];
        rng.fill_uniform(&mut x, 0.0, 1.0);
        (w, x)
    }

    #[test]
    fn noiseless_matches_ideal() {
        let s = sim(8, 8, 5.0);
        let (w, x) = rand_problem(8, 8, 1);
        let opts = ForwardOptions::default(); // everything off
        let y = s.forward(&w, &x, &opts, &mut XorShiftRng::new(0));
        let ideal = s.forward_ideal(&w, &x, None, None);
        assert!(nmae(&y, &ideal) < 1e-12);
    }

    #[test]
    fn thermal_crosstalk_degrades_and_tighter_pitch_is_worse() {
        let (w, x) = rand_problem(16, 16, 2);
        let opts = ForwardOptions { thermal: true, ..Default::default() };
        let e_tight = {
            let s = sim(16, 16, 1.0);
            let y = s.forward(&w, &x, &opts, &mut XorShiftRng::new(0));
            nmae(&y, &s.forward_ideal(&w, &x, None, None))
        };
        let e_loose = {
            let s = sim(16, 16, 20.0);
            let y = s.forward(&w, &x, &opts, &mut XorShiftRng::new(0));
            nmae(&y, &s.forward_ideal(&w, &x, None, None))
        };
        assert!(e_tight > 0.0 && e_loose > 0.0);
        assert!(e_tight > 2.0 * e_loose, "tight={e_tight} loose={e_loose}");
    }

    #[test]
    fn pd_noise_statistics() {
        let s = sim(4, 16, 5.0);
        let (w, x) = rand_problem(4, 16, 3);
        let ideal = s.forward_ideal(&w, &x, None, None);
        let opts = ForwardOptions { pd_noise: true, ..Default::default() };
        let mut rng = XorShiftRng::new(7);
        // Var per output = k2 * 0.01^2 -> std = sqrt(16)*0.01 = 0.04
        let trials = 4000;
        let mut acc2 = 0.0;
        for _ in 0..trials {
            let y = s.forward(&w, &x, &opts, &mut rng);
            for i in 0..4 {
                let d = y[i] - ideal[i];
                acc2 += d * d;
            }
        }
        let std = (acc2 / (trials * 4) as f64).sqrt();
        assert!((std - 0.04).abs() < 0.002, "std={std}");
    }

    #[test]
    fn fig5_mode_ordering_prune_ig_lr() {
        // Fig. 5 / Fig. 9(b): N-MAE(prune-only) > N-MAE(IG) > N-MAE(IG+LR).
        let s = sim(16, 16, 3.0);
        let (w, x) = rand_problem(16, 16, 4);
        let col_mask: Vec<bool> = (0..16).map(|j| j % 2 == 0).collect(); // 50% cols
        let golden = s.forward_ideal(&w, &x, Some(&col_mask), None);
        let run = |mode: ColumnMode, seed: u64| {
            let opts = ForwardOptions {
                thermal: true,
                pd_noise: true,
                phase_noise: true,
                col_mask: Some(&col_mask),
                col_mode: mode,
                ..Default::default()
            };
            let mut rng = XorShiftRng::new(seed);
            let mut tot = 0.0;
            for t in 0..50 {
                let _ = t;
                let y = s.forward(&w, &x, &opts, &mut rng);
                tot += nmae(&y, &golden);
            }
            tot / 50.0
        };
        let e_prune = run(ColumnMode::PruneOnly, 10);
        let e_ig = run(ColumnMode::InputGating, 10);
        let e_lr = run(ColumnMode::InputGatingLr, 10);
        assert!(e_prune > e_ig, "prune {e_prune} > IG {e_ig}");
        assert!(e_ig > e_lr, "IG {e_ig} > LR {e_lr}");
    }

    #[test]
    fn lr_noise_reduction_matches_eq14() {
        // With ONLY PD noise (no crosstalk), LR at 25% active should cut
        // noise std by k2'/k2 = 0.25 vs the dense case.
        let s = sim(4, 16, 5.0);
        let (w, x) = rand_problem(4, 16, 5);
        let col_mask: Vec<bool> = (0..16).map(|j| j % 4 == 0).collect(); // 4 of 16
        let golden = s.forward_ideal(&w, &x, Some(&col_mask), None);
        let measure = |mode: ColumnMode| {
            let opts = ForwardOptions {
                pd_noise: true,
                col_mask: Some(&col_mask),
                col_mode: mode,
                ..Default::default()
            };
            let mut rng = XorShiftRng::new(17);
            let mut acc2 = 0.0;
            let trials = 3000;
            for _ in 0..trials {
                let y = s.forward(&w, &x, &opts, &mut rng);
                for i in 0..4 {
                    let d = y[i] - golden[i];
                    acc2 += d * d;
                }
            }
            (acc2 / (trials * 4) as f64).sqrt()
        };
        // IG keeps full-amplitude noise (sqrt(16)*0.01 = 0.04) plus tiny leakage
        let std_ig = measure(ColumnMode::InputGating);
        let std_lr = measure(ColumnMode::InputGatingLr);
        assert!((std_lr / std_ig - 0.25).abs() < 0.05, "ig={std_ig} lr={std_lr}");
    }

    #[test]
    fn lr_snr_gain_about_12db_at_quarter_active() {
        // 20·log10(4) ≈ 12 dB PD-noise SNR gain at k2'/k2 = 1/4.
        let s = sim(8, 16, 5.0);
        let (w, x) = rand_problem(8, 16, 6);
        let col_mask: Vec<bool> = (0..16).map(|j| j % 4 == 0).collect();
        let golden = s.forward_ideal(&w, &x, Some(&col_mask), None);
        let collect = |mode: ColumnMode| {
            let opts = ForwardOptions {
                pd_noise: true,
                col_mask: Some(&col_mask),
                col_mode: mode,
                ..Default::default()
            };
            let mut rng = XorShiftRng::new(23);
            let mut ys = Vec::new();
            let mut gs = Vec::new();
            for _ in 0..500 {
                ys.extend(s.forward(&w, &x, &opts, &mut rng));
                gs.extend(golden.iter().copied());
            }
            snr_db(&ys, &gs)
        };
        let gain = collect(ColumnMode::InputGatingLr) - collect(ColumnMode::InputGating);
        assert!((gain - 12.04).abs() < 1.5, "LR SNR gain {gain} dB");
    }

    #[test]
    fn output_gating_zeroes_pruned_rows() {
        let s = sim(8, 8, 3.0);
        let (w, x) = rand_problem(8, 8, 8);
        let row_mask: Vec<bool> = (0..8).map(|i| i % 2 == 0).collect();
        let opts = ForwardOptions {
            thermal: true,
            pd_noise: true,
            row_mask: Some(&row_mask),
            output_gating: true,
            ..Default::default()
        };
        let y = s.forward(&w, &x, &opts, &mut XorShiftRng::new(9));
        for (i, &m) in row_mask.iter().enumerate() {
            if !m {
                assert_eq!(y[i], 0.0, "OG row {i} must be exactly zero");
            } else {
                assert_ne!(y[i], 0.0);
            }
        }
    }

    #[test]
    fn row_sparsity_without_og_leaks_garbage() {
        // Fig. 9(a): pruned rows w/o OG still emit crosstalk+noise garbage.
        let s = sim(8, 8, 1.0);
        let (w, x) = rand_problem(8, 8, 11);
        let row_mask: Vec<bool> = (0..8).map(|i| i % 2 == 0).collect();
        let golden = s.forward_ideal(&w, &x, None, Some(&row_mask));
        let mk = |og: bool, seed: u64| {
            let opts = ForwardOptions {
                thermal: true,
                pd_noise: true,
                row_mask: Some(&row_mask),
                output_gating: og,
                ..Default::default()
            };
            let mut rng = XorShiftRng::new(seed);
            let mut tot = 0.0;
            for _ in 0..50 {
                tot += nmae(&s.forward(&w, &x, &opts, &mut rng), &golden);
            }
            tot / 50.0
        };
        let e_no_og = mk(false, 21);
        let e_og = mk(true, 21);
        assert!(e_no_og > e_og, "no-OG {e_no_og} must exceed OG {e_og}");
    }

    #[test]
    fn interleaved_rows_beat_clustered_rows_under_og() {
        // Fig. 9(a): interleaved 1s minimize crosstalk on surviving rows.
        let s = sim(16, 8, 1.0);
        let (w, x) = rand_problem(16, 8, 13);
        let interleaved: Vec<bool> = (0..16).map(|i| i % 2 == 0).collect();
        let clustered: Vec<bool> = (0..16).map(|i| i < 8).collect();
        let run = |mask: &Vec<bool>| {
            let golden = s.forward_ideal(&w, &x, None, Some(mask));
            let opts = ForwardOptions {
                thermal: true,
                row_mask: Some(mask),
                output_gating: true,
                ..Default::default()
            };
            let y = s.forward(&w, &x, &opts, &mut XorShiftRng::new(0));
            nmae(&y, &golden)
        };
        let e_inter = run(&interleaved);
        let e_clust = run(&clustered);
        assert!(e_inter < e_clust, "interleaved {e_inter} < clustered {e_clust}");
    }

    #[test]
    fn all_columns_pruned_lr_outputs_noise_only_zero_signal() {
        let s = sim(4, 8, 5.0);
        let (w, x) = rand_problem(4, 8, 14);
        let col_mask = vec![false; 8];
        let opts = ForwardOptions {
            col_mask: Some(&col_mask),
            col_mode: ColumnMode::InputGatingLr,
            ..Default::default()
        };
        let y = s.forward(&w, &x, &opts, &mut XorShiftRng::new(2));
        assert!(y.iter().all(|&v| v == 0.0));
    }
}

//! Chunk-level execution: one `rk1 × ck2` weight chunk mapped across an
//! r×c grid of PTCs (§3.2, Fig. 2).
//!
//! * the c PTCs of a tile see disjoint k2-segments of the input and their
//!   photocurrents sum in the analog domain into one shared TIA/ADC
//!   (§3.3.3), so PD noise accumulates over all c·k2 nodes of a row;
//! * the r tiles sharing an input-modulation module see the same inputs
//!   but hold different k1-blocks of chunk rows;
//! * each input module owns one 1×k2 rerouter per segment — the paper
//!   assumes the same sparsity pattern for every k1×k2 block (§3.3.5), in
//!   which case per-segment LR gains equal the shared-TIA rescale exactly.

use super::crossbar::{ColumnMode, ForwardOptions, PtcSimulator};
use crate::util::XorShiftRng;

/// Chunk-level simulation options (masks are passed per call).
#[derive(Debug, Clone, Copy, Default)]
pub struct ChunkOptions {
    pub thermal: bool,
    pub pd_noise: bool,
    pub phase_noise: bool,
    pub col_mode: ColumnMode,
    pub output_gating: bool,
}

/// Simulates a full `rk1 × ck2` chunk on r·c PTC instances.
#[derive(Debug, Clone)]
pub struct ChunkSimulator {
    pub ptc: PtcSimulator,
    pub r: usize,
    pub c: usize,
}

impl ChunkSimulator {
    pub fn new(ptc: PtcSimulator, r: usize, c: usize) -> Self {
        assert!(r > 0 && c > 0);
        Self { ptc, r, c }
    }

    pub fn from_config(cfg: &crate::AcceleratorConfig) -> Self {
        Self::new(PtcSimulator::from_config(cfg), cfg.share_r, cfg.share_c)
    }

    pub fn rows(&self) -> usize {
        self.r * self.ptc.k1
    }

    pub fn cols(&self) -> usize {
        self.c * self.ptc.k2
    }

    /// Ideal chunk MVM with masks.
    pub fn forward_ideal(
        &self,
        w: &[f64],
        x: &[f64],
        col_mask: Option<&[bool]>,
        row_mask: Option<&[bool]>,
    ) -> Vec<f64> {
        let (rows, cols) = (self.rows(), self.cols());
        assert_eq!(w.len(), rows * cols);
        assert_eq!(x.len(), cols);
        let mut y = vec![0.0; rows];
        for i in 0..rows {
            if let Some(rm) = row_mask {
                if !rm[i] {
                    continue;
                }
            }
            let mut acc = 0.0;
            for j in 0..cols {
                if let Some(cm) = col_mask {
                    if !cm[j] {
                        continue;
                    }
                }
                acc += w[i * cols + j] * x[j];
            }
            y[i] = acc;
        }
        y
    }

    /// Noisy chunk MVM: block-decompose, run each PTC through the full
    /// signal chain, and accumulate analog partial products per tile.
    pub fn forward(
        &self,
        w: &[f64],
        x: &[f64],
        opts: &ChunkOptions,
        col_mask: Option<&[bool]>,
        row_mask: Option<&[bool]>,
        rng: &mut XorShiftRng,
    ) -> Vec<f64> {
        let (k1, k2) = (self.ptc.k1, self.ptc.k2);
        let (rows, cols) = (self.rows(), self.cols());
        assert_eq!(w.len(), rows * cols, "chunk weight shape");
        assert_eq!(x.len(), cols, "chunk input len");
        if let Some(cm) = col_mask {
            assert_eq!(cm.len(), cols);
        }
        if let Some(rm) = row_mask {
            assert_eq!(rm.len(), rows);
        }

        let mut y = vec![0.0f64; rows];
        let mut w_block = vec![0.0f64; k1 * k2];
        for a in 0..self.r {
            // row-block mask segment — borrowed, not copied: the old
            // `.to_vec()` here allocated two Vecs per (a, b) block on
            // every forward call
            let rm_seg: Option<&[bool]> = row_mask.map(|rm| &rm[a * k1..(a + 1) * k1]);
            for b in 0..self.c {
                let cm_seg: Option<&[bool]> =
                    col_mask.map(|cm| &cm[b * k2..(b + 1) * k2]);
                // gather the k1×k2 block (a,b)
                for i in 0..k1 {
                    let src = (a * k1 + i) * cols + b * k2;
                    w_block[i * k2..(i + 1) * k2].copy_from_slice(&w[src..src + k2]);
                }
                let fwd_opts = ForwardOptions {
                    thermal: opts.thermal,
                    pd_noise: opts.pd_noise,
                    phase_noise: opts.phase_noise,
                    col_mask: cm_seg,
                    row_mask: rm_seg,
                    col_mode: opts.col_mode,
                    output_gating: opts.output_gating,
                };
                let yb = self.ptc.forward(&w_block, &x[b * k2..(b + 1) * k2], &fwd_opts, rng);
                for i in 0..k1 {
                    y[a * k1 + i] += yb[i];
                }
            }
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::thermal::{coupling::ArrayGeometry, GammaModel};
    use crate::util::nmae;

    fn chunk_sim(r: usize, c: usize) -> ChunkSimulator {
        let geom = ArrayGeometry { rows: 8, cols: 8, l_v: 120.0, l_h: 20.0, l_s: 9.0 };
        let ptc = PtcSimulator::new(
            geom,
            &GammaModel::paper(),
            crate::devices::DeviceLibrary::default(),
        );
        ChunkSimulator::new(ptc, r, c)
    }

    fn problem(rows: usize, cols: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = XorShiftRng::new(seed);
        let mut w = vec![0.0; rows * cols];
        rng.fill_uniform(&mut w, -1.0, 1.0);
        let mut x = vec![0.0; cols];
        rng.fill_uniform(&mut x, 0.0, 1.0);
        (w, x)
    }

    #[test]
    fn noiseless_chunk_matches_ideal() {
        let s = chunk_sim(2, 2);
        let (w, x) = problem(16, 16, 1);
        let y = s.forward(
            &w,
            &x,
            &ChunkOptions::default(),
            None,
            None,
            &mut XorShiftRng::new(0),
        );
        let ideal = s.forward_ideal(&w, &x, None, None);
        assert!(nmae(&y, &ideal) < 1e-12);
    }

    #[test]
    fn chunk_equals_blockwise_sum() {
        // With 1x1 sharing the chunk sim must equal the bare PTC.
        let s = chunk_sim(1, 1);
        let (w, x) = problem(8, 8, 2);
        let y_chunk = s.forward(
            &w,
            &x,
            &ChunkOptions { thermal: true, ..Default::default() },
            None,
            None,
            &mut XorShiftRng::new(3),
        );
        let opts = ForwardOptions { thermal: true, ..Default::default() };
        let y_ptc = s.ptc.forward(&w, &x, &opts, &mut XorShiftRng::new(3));
        assert!(nmae(&y_chunk, &y_ptc) < 1e-12);
    }

    #[test]
    fn masked_chunk_gating_and_lr() {
        let s = chunk_sim(2, 2);
        let (w, x) = problem(16, 16, 4);
        // uniform per-block pattern (paper §3.3.5): same k2-segment mask
        let seg: Vec<bool> = (0..8).map(|j| j % 2 == 0).collect();
        let col_mask: Vec<bool> = seg.iter().chain(seg.iter()).copied().collect();
        let row_seg: Vec<bool> = (0..8).map(|i| i % 2 == 0).collect();
        let row_mask: Vec<bool> = row_seg.iter().chain(row_seg.iter()).copied().collect();
        let golden = s.forward_ideal(&w, &x, Some(&col_mask), Some(&row_mask));
        let opts = ChunkOptions {
            thermal: true,
            pd_noise: true,
            col_mode: ColumnMode::InputGatingLr,
            output_gating: true,
            ..Default::default()
        };
        let mut rng = XorShiftRng::new(5);
        let mut e = 0.0;
        for _ in 0..20 {
            e += nmae(&s.forward(&w, &x, &opts, Some(&col_mask), Some(&row_mask), &mut rng), &golden);
        }
        e /= 20.0;
        assert!(e < 0.15, "full SCATTER chunk error should be small: {e}");
        // prune-only for comparison
        let opts_p = ChunkOptions {
            thermal: true,
            pd_noise: true,
            col_mode: ColumnMode::PruneOnly,
            output_gating: false,
            ..Default::default()
        };
        let mut rng = XorShiftRng::new(5);
        let mut ep = 0.0;
        for _ in 0..20 {
            ep += nmae(
                &s.forward(&w, &x, &opts_p, Some(&col_mask), Some(&row_mask), &mut rng),
                &golden,
            );
        }
        ep /= 20.0;
        assert!(ep > e, "prune-only {ep} worse than SCATTER {e}");
    }

    #[test]
    fn pd_noise_accumulates_across_tile_cores() {
        // variance per output row scales with c*k2 nodes
        let s1 = chunk_sim(1, 1);
        let s2 = chunk_sim(1, 2);
        let (w1, x1) = problem(8, 8, 6);
        let (w2, x2) = problem(8, 16, 6);
        let measure = |s: &ChunkSimulator, w: &[f64], x: &[f64]| {
            let ideal = s.forward_ideal(w, x, None, None);
            let opts = ChunkOptions { pd_noise: true, ..Default::default() };
            let mut rng = XorShiftRng::new(8);
            let mut acc2 = 0.0;
            let trials = 2000;
            for _ in 0..trials {
                let y = s.forward(w, x, &opts, None, None, &mut rng);
                for i in 0..y.len() {
                    acc2 += (y[i] - ideal[i]).powi(2);
                }
            }
            (acc2 / (trials * s.rows()) as f64).sqrt()
        };
        let std1 = measure(&s1, &w1, &x1);
        let std2 = measure(&s2, &w2, &x2);
        assert!(
            (std2 / std1 - 2f64.sqrt()).abs() < 0.1,
            "doubling c doubles noise nodes: {std1} {std2}"
        );
    }

    #[test]
    #[should_panic]
    fn wrong_chunk_shape_panics() {
        let s = chunk_sim(2, 2);
        let (w, x) = problem(8, 8, 9);
        let _ = s.forward(&w, &x, &ChunkOptions::default(), None, None, &mut XorShiftRng::new(0));
    }
}

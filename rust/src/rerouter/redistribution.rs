//! Light-redistribution SNR math (Eq. 14).
//!
//! With k2′ of k2 ports active, LR boosts active-port intensity by k2/k2′
//! and the TIA gain is scaled back by k2′/k2, so the PD-noise term shrinks
//! by k2′/k2 while the signal is unchanged — an SNR gain of
//! `20·log10(k2/k2′)` dB on the noise-amplitude scale (the paper quotes
//! ~7 dB at 20 % column sparsity... k2′/k2 = 0.8 → 10·log10((1/0.8)²) ≈ 1.9 dB
//! per noise-power; the 7 dB figure also banks the eliminated leakage —
//! both effects are measured separately by `bench::fig9`).

/// Residual PD-noise scale factor after LR: k2′/k2 (Eq. 14).
pub fn lr_noise_factor(k2_active: usize, k2: usize) -> f64 {
    assert!(k2 > 0 && k2_active <= k2);
    k2_active as f64 / k2 as f64
}

/// SNR gain in dB from the PD-noise reduction alone.
pub fn lr_snr_gain_db(k2_active: usize, k2: usize) -> f64 {
    if k2_active == 0 {
        return f64::INFINITY;
    }
    -20.0 * lr_noise_factor(k2_active, k2).log10()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_no_gain() {
        assert_eq!(lr_noise_factor(16, 16), 1.0);
        assert_eq!(lr_snr_gain_db(16, 16), 0.0);
    }

    #[test]
    fn half_active_6db() {
        assert!((lr_snr_gain_db(8, 16) - 6.0206).abs() < 1e-3);
    }

    #[test]
    fn noise_factor_linear() {
        assert!((lr_noise_factor(4, 16) - 0.25).abs() < 1e-12);
    }
}

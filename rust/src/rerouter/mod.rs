//! In-situ tunable light rerouter (§3.3.2, Fig. 5 *Right*).
//!
//! A binary tree of cascaded MZI power splitters replaces the passive even
//! splitter tree on the input side. Given a column sparsity mask, each
//! tree node is programmed with the split ratio `up : lo` equal to the
//! count of active leaves in its two subtrees, so *all* optical power is
//! steered to active ports — pruned ports receive (ideally) zero light and
//! active ports are boosted by k2/k2′ (Eq. 14).

pub mod redistribution;
pub mod tree;

pub use redistribution::{lr_noise_factor, lr_snr_gain_db};
pub use tree::{RerouterTree, TreeNode};

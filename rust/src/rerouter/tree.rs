//! The cascaded-MZI splitter tree and its mask-dependent programming.
//!
//! Programming rule (§3.3.5 "How to Calculate Power Metric for a Mask?"):
//! for a node whose subtrees contain `up` and `lo` active leaves, the split
//! ratio is up:lo and the phase is `Δφ = 2·arccos(√(up/(up+lo))) − φ_b`
//! (φ_b = π/2). If up+lo = 0 the node idles at Δφ = 0.

use crate::devices::Mzi;
use std::f64::consts::FRAC_PI_2;

/// One programmed splitter node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TreeNode {
    /// Tree level (0 = root).
    pub level: usize,
    /// Index within the level.
    pub index: usize,
    /// Active leaves in the upper / lower subtree.
    pub up: usize,
    pub lo: usize,
    /// Programmed phase (rad).
    pub phase: f64,
}

impl TreeNode {
    /// Fraction of this node's input power sent to the upper branch.
    pub fn up_fraction(&self) -> f64 {
        if self.up + self.lo == 0 {
            0.5 // idle even split
        } else {
            self.up as f64 / (self.up + self.lo) as f64
        }
    }
}

/// A programmed 1×k rerouter tree (k must be a power of two; the paper's
/// k2 = 16).
#[derive(Debug, Clone)]
pub struct RerouterTree {
    pub leaves: usize,
    pub nodes: Vec<TreeNode>,
}

impl RerouterTree {
    /// Program the tree for a column mask (`true` = active port).
    pub fn program(mask: &[bool]) -> Self {
        let k = mask.len();
        assert!(k.is_power_of_two() && k >= 2, "rerouter needs power-of-two ports, got {k}");
        let levels = k.trailing_zeros() as usize;
        let mut nodes = Vec::with_capacity(k - 1);
        // active-leaf counts per subtree, computed bottom-up
        // count[l][i] = number of active leaves under node i at level l
        let mut counts: Vec<usize> = mask.iter().map(|&m| m as usize).collect();
        for level in (0..levels).rev() {
            let n_nodes = 1usize << level;
            let mut next = Vec::with_capacity(n_nodes);
            for i in 0..n_nodes {
                let up = counts[2 * i];
                let lo = counts[2 * i + 1];
                let total = up + lo;
                let phase = if total == 0 {
                    0.0
                } else {
                    2.0 * ((up as f64 / total as f64).sqrt()).acos() - FRAC_PI_2
                };
                nodes.push(TreeNode { level, index: i, up, lo, phase });
                next.push(total);
            }
            counts = next;
        }
        // order root-first for readability
        nodes.sort_by_key(|n| (n.level, n.index));
        Self { leaves: k, nodes }
    }

    /// Per-leaf power fractions delivered by the programmed tree for a
    /// unit input. Active leaves each get 1/k2′; pruned leaves get 0
    /// (up to splitter ideality, modeled in `ptc::sim`).
    pub fn leaf_powers(&self) -> Vec<f64> {
        let mut powers = vec![1.0f64];
        for level in 0..self.levels() {
            let mut next = Vec::with_capacity(powers.len() * 2);
            for (i, &p) in powers.iter().enumerate() {
                let node = self.node(level, i);
                let fu = node.up_fraction();
                next.push(p * fu);
                next.push(p * (1.0 - fu));
            }
            powers = next;
        }
        powers
    }

    /// Total electrical hold power (mW) of the programmed tree using the
    /// rerouter MZI device at arm spacing l_s.
    pub fn power_mw(&self, mzi: &Mzi) -> f64 {
        self.nodes.iter().map(|n| mzi.power_mw(n.phase)).sum()
    }

    /// Number of active leaves (k2′).
    pub fn active_leaves(&self) -> usize {
        let root = &self.nodes[0];
        root.up + root.lo
    }

    pub fn levels(&self) -> usize {
        self.leaves.trailing_zeros() as usize
    }

    fn node(&self, level: usize, index: usize) -> &TreeNode {
        // nodes are sorted (level, index); level l starts at 2^l - 1
        &self.nodes[(1 << level) - 1 + index]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::MziSpec;
    use crate::thermal::gamma::GammaModel;

    fn mzi() -> Mzi {
        Mzi::new(MziSpec::low_power(), 9.0, &GammaModel::paper())
    }

    #[test]
    fn all_active_is_even_split() {
        let t = RerouterTree::program(&[true; 8]);
        let p = t.leaf_powers();
        for &x in &p {
            assert!((x - 0.125).abs() < 1e-12);
        }
        assert_eq!(t.active_leaves(), 8);
        // even split = φ = 0 everywhere = zero hold power
        assert!(t.power_mw(&mzi()) < 1e-12);
    }

    #[test]
    fn paper_example_mask_10110010() {
        // §3.3.5: m^c = 10110010 -> root ratio up:lo = 3:1
        let mask = [true, false, true, true, false, false, true, false];
        let t = RerouterTree::program(&mask);
        let root = &t.nodes[0];
        assert_eq!((root.up, root.lo), (3, 1));
        let p = t.leaf_powers();
        // all active leaves get 1/4 of the light, pruned get 0
        for (i, &m) in mask.iter().enumerate() {
            if m {
                assert!((p[i] - 0.25).abs() < 1e-12, "leaf {i}: {}", p[i]);
            } else {
                assert!(p[i].abs() < 1e-12, "pruned leaf {i} gets {}", p[i]);
            }
        }
        assert_eq!(t.active_leaves(), 4);
    }

    #[test]
    fn power_conservation() {
        let masks: [&[bool]; 3] = [
            &[true, true, false, true, false, false, true, true],
            &[true; 16],
            &[false, true, false, false, true, false, false, false,
              false, false, true, false, false, false, false, true],
        ];
        for mask in masks {
            let t = RerouterTree::program(mask);
            let total: f64 = t.leaf_powers().iter().sum();
            assert!((total - 1.0).abs() < 1e-12, "light is conserved");
        }
    }

    #[test]
    fn boost_factor_is_k2_over_active() {
        // 8 ports, 2 active -> each active port gets 1/2 = (1/8)·(8/2)
        let mask = [false, false, true, false, false, false, false, true];
        let t = RerouterTree::program(&mask);
        let p = t.leaf_powers();
        assert!((p[2] - 0.5).abs() < 1e-12);
        assert!((p[7] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn all_pruned_idles() {
        let t = RerouterTree::program(&[false; 8]);
        assert_eq!(t.active_leaves(), 0);
        for n in &t.nodes {
            assert_eq!(n.phase, 0.0, "idle nodes at Δφ=0");
        }
    }

    #[test]
    fn phases_bounded_pm_half_pi() {
        let mask = [true, false, false, false, true, true, true, false];
        let t = RerouterTree::program(&mask);
        for n in &t.nodes {
            assert!(n.phase.abs() <= FRAC_PI_2 + 1e-12);
        }
    }

    #[test]
    fn mask_power_ordering_clustered_cheaper() {
        // The bias point phi_b = pi/2 is the even split, so steering costs
        // power: an interleaved mask pays a full-swing leaf node per pair,
        // while a clustered mask steers once at the root — 4x cheaper.
        let interleaved = [true, false, true, false, true, false, true, false];
        let clustered = [true, true, true, true, false, false, false, false];
        let m = mzi();
        let pi_ = RerouterTree::program(&interleaved).power_mw(&m);
        let pc = RerouterTree::program(&clustered).power_mw(&m);
        assert!(pc < pi_, "clustered {pc} < interleaved {pi_}");
        assert!((pi_ / pc - 4.0).abs() < 1e-9, "ratio {}", pi_ / pc);
    }

    #[test]
    #[should_panic]
    fn rejects_non_power_of_two() {
        let _ = RerouterTree::program(&[true; 6]);
    }
}

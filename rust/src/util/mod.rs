//! Small shared utilities: deterministic RNG, math helpers, table printing.

pub mod json;
pub mod rng;
pub mod stats;
pub mod table;

pub use json::Json;
pub use rng::XorShiftRng;
pub use stats::{mean, nmae, snr_db};
pub use table::Table;

//! Small shared utilities: deterministic RNG, math helpers, table
//! printing, JSON, and CLI flag parsing.

pub mod args;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;

pub use args::{FlagTable, ParsedArgs};
pub use json::Json;
pub use rng::XorShiftRng;
pub use stats::{mean, nmae, snr_db};
pub use table::Table;

//! Error metrics used throughout the paper's evaluation.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Normalized mean-absolute error (N-MAE), the paper's fidelity metric
/// (Figs. 4(d), 5, 9): `mean(|a - b|) / mean(|b|)` with `b` the golden.
pub fn nmae(noisy: &[f64], golden: &[f64]) -> f64 {
    assert_eq!(noisy.len(), golden.len(), "nmae: length mismatch");
    if noisy.is_empty() {
        return 0.0;
    }
    let num: f64 = noisy.iter().zip(golden).map(|(a, b)| (a - b).abs()).sum();
    let den: f64 = golden.iter().map(|b| b.abs()).sum();
    if den == 0.0 {
        // All-zero golden: report the raw mean absolute error instead.
        num / noisy.len() as f64
    } else {
        num / den
    }
}

/// Signal-to-noise ratio in dB between a golden signal and its noisy
/// realization: `10 log10(sum(golden²) / sum((noisy-golden)²))`.
pub fn snr_db(noisy: &[f64], golden: &[f64]) -> f64 {
    assert_eq!(noisy.len(), golden.len(), "snr: length mismatch");
    let sig: f64 = golden.iter().map(|x| x * x).sum();
    let err: f64 = noisy.iter().zip(golden).map(|(a, b)| (a - b) * (a - b)).sum();
    if err == 0.0 {
        f64::INFINITY
    } else {
        10.0 * (sig / err).log10()
    }
}

/// Relative root-mean-square error.
pub fn rel_rmse(noisy: &[f64], golden: &[f64]) -> f64 {
    assert_eq!(noisy.len(), golden.len());
    let err: f64 = noisy.iter().zip(golden).map(|(a, b)| (a - b) * (a - b)).sum();
    let sig: f64 = golden.iter().map(|x| x * x).sum();
    if sig == 0.0 {
        (err / noisy.len().max(1) as f64).sqrt()
    } else {
        (err / sig).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nmae_zero_for_identical() {
        let a = [1.0, -2.0, 3.0];
        assert_eq!(nmae(&a, &a), 0.0);
    }

    #[test]
    fn nmae_scales_with_error() {
        let g = [1.0, 1.0, 1.0, 1.0];
        let n = [1.1, 0.9, 1.1, 0.9];
        assert!((nmae(&n, &g) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn snr_known_value() {
        let g = [1.0, 1.0];
        let n = [1.1, 1.0];
        // sig=2, err=0.01 -> 10*log10(200) ~ 23.0103
        assert!((snr_db(&n, &g) - 23.0103).abs() < 1e-3);
    }

    #[test]
    fn snr_infinite_for_identical() {
        let g = [0.5, -0.25];
        assert!(snr_db(&g, &g).is_infinite());
    }

    #[test]
    fn mean_empty() {
        assert_eq!(mean(&[]), 0.0);
    }
}

//! Declarative CLI flag parsing shared by every `scatter` subcommand.
//!
//! Each subcommand declares a [`FlagTable`] — name, optional value
//! metavar, and help line per flag — and gets parsing, unknown-flag
//! rejection, and a generated `--help` screen from the one table. This
//! replaces the hand-rolled `flag_value` scans that `cmd_serve` and
//! `cmd_bench` used to duplicate, so new flags (`--replicas`,
//! `--steal`, `--config`) land in exactly one place.
//!
//! Flags accept both `--name value` and `--name=value`; flags declared
//! without a metavar are boolean switches. Anything not starting with
//! `--` is collected as a positional (bench targets use one).

use std::fmt::Write as _;
use std::str::FromStr;

/// One flag declaration: `--name VALUE  help`.
#[derive(Debug, Clone)]
struct FlagSpec {
    name: &'static str,
    /// Metavar shown in help (`N`, `FILE`, `A,B,...`); `None` marks a
    /// boolean switch that takes no value.
    value: Option<&'static str>,
    help: &'static str,
}

/// A subcommand's full flag declaration; build with [`FlagTable::new`]
/// and chained [`FlagTable::flag`]/[`FlagTable::switch`] calls.
#[derive(Debug, Clone)]
pub struct FlagTable {
    usage: &'static str,
    about: &'static str,
    specs: Vec<FlagSpec>,
}

/// Parse result: flag values plus positionals, queried by flag name.
#[derive(Debug, Clone, Default)]
pub struct ParsedArgs {
    values: Vec<(&'static str, Option<String>)>,
    positionals: Vec<String>,
    help: bool,
}

impl FlagTable {
    pub fn new(usage: &'static str, about: &'static str) -> Self {
        Self { usage, about, specs: Vec::new() }
    }

    /// Declare a value-taking flag (`--name METAVAR`).
    pub fn flag(mut self, name: &'static str, metavar: &'static str, help: &'static str) -> Self {
        self.specs.push(FlagSpec { name, value: Some(metavar), help });
        self
    }

    /// Declare a boolean switch (`--name`, no value).
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(FlagSpec { name, value: None, help });
        self
    }

    /// The generated help screen — usage line, about text, then one
    /// aligned row per flag straight from the table.
    pub fn help_text(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "usage: {}", self.usage);
        if !self.about.is_empty() {
            let _ = writeln!(out, "\n{}", self.about);
        }
        let _ = writeln!(out, "\noptions:");
        let left: Vec<String> = self
            .specs
            .iter()
            .map(|s| match s.value {
                Some(mv) => format!("{} {mv}", s.name),
                None => s.name.to_string(),
            })
            .collect();
        let width = left.iter().map(|l| l.len()).max().unwrap_or(0).max(6);
        for (l, s) in left.iter().zip(&self.specs) {
            let _ = writeln!(out, "  {l:width$}  {}", s.help);
        }
        let _ = writeln!(out, "  {:width$}  print this help", "--help");
        out
    }

    /// Parse `args`; unknown flags, missing values, and duplicate
    /// occurrences are errors that name the offending flag (the caller
    /// prints the help screen). Duplicates used to be silently
    /// last-wins, which hid typos like `--workers 4 ... --workers 2` in
    /// long command lines.
    pub fn parse(&self, args: &[String]) -> Result<ParsedArgs, String> {
        let mut out = ParsedArgs::default();
        let mut it = args.iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                out.help = true;
                continue;
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let Some(spec) = self.specs.iter().find(|s| s.name.trim_start_matches('-') == name)
                else {
                    return Err(format!("unknown flag --{name}"));
                };
                if out.values.iter().any(|(n, _)| *n == spec.name) {
                    return Err(format!("duplicate flag {}", spec.name));
                }
                match spec.value {
                    Some(_) => {
                        let value = match inline {
                            Some(v) => v,
                            None => match it.next() {
                                Some(v) if !v.starts_with("--") => v.clone(),
                                _ => return Err(format!("flag {} expects a value", spec.name)),
                            },
                        };
                        out.values.push((spec.name, Some(value)));
                    }
                    None => {
                        if inline.is_some() {
                            return Err(format!("switch {} takes no value", spec.name));
                        }
                        out.values.push((spec.name, None));
                    }
                }
            } else {
                out.positionals.push(arg.clone());
            }
        }
        Ok(out)
    }
}

impl ParsedArgs {
    /// `--help` was present anywhere on the line.
    pub fn wants_help(&self) -> bool {
        self.help
    }

    /// Value given for a flag (each flag appears at most once — the
    /// parser rejects duplicates).
    pub fn value(&self, name: &str) -> Option<&str> {
        self.values.iter().find(|(n, _)| *n == name).and_then(|(_, v)| v.as_deref())
    }

    /// The flag or switch appeared at all.
    pub fn has(&self, name: &str) -> bool {
        self.values.iter().any(|(n, _)| *n == name)
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// Parse a flag's value with `FromStr`; `Ok(None)` when absent.
    pub fn get<T: FromStr>(&self, name: &str) -> Result<Option<T>, String> {
        match self.value(name) {
            None => Ok(None),
            Some(raw) => raw
                .parse::<T>()
                .map(Some)
                .map_err(|_| format!("flag {name}: cannot parse {raw:?}")),
        }
    }

    /// Parse a comma-separated list (`--replicas 1,4`).
    pub fn get_list<T: FromStr>(&self, name: &str) -> Result<Option<Vec<T>>, String> {
        match self.value(name) {
            None => Ok(None),
            Some(raw) => raw
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse::<T>()
                        .map_err(|_| format!("flag {name}: cannot parse {s:?} in {raw:?}"))
                })
                .collect::<Result<Vec<T>, String>>()
                .map(Some),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> FlagTable {
        FlagTable::new("scatter serve [options]", "run the server")
            .flag("--workers", "N", "engine workers")
            .flag("--max-batch", "B", "batch cap")
            .flag("--replicas", "A,B", "replica sweep")
            .switch("--steal", "enable work stealing")
    }

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_values_switches_and_positionals() {
        let p = table()
            .parse(&args(&["serve", "--workers", "4", "--steal", "--max-batch=8"]))
            .expect("parse");
        assert_eq!(p.positionals(), &["serve".to_string()]);
        assert_eq!(p.get::<usize>("--workers").unwrap(), Some(4));
        assert_eq!(p.get::<usize>("--max-batch").unwrap(), Some(8));
        assert!(p.has("--steal"));
        assert!(!p.wants_help());
        assert_eq!(p.get::<usize>("--replicas").unwrap(), None);
    }

    #[test]
    fn rejects_unknown_flags_and_missing_values() {
        assert!(table().parse(&args(&["--bogus"])).unwrap_err().contains("--bogus"));
        let err = table().parse(&args(&["--workers"])).unwrap_err();
        assert!(err.contains("--workers"), "{err}");
        let err = table().parse(&args(&["--workers", "--steal"])).unwrap_err();
        assert!(err.contains("expects a value"), "{err}");
        let err = table().parse(&args(&["--steal=yes"])).unwrap_err();
        assert!(err.contains("takes no value"), "{err}");
    }

    #[test]
    fn rejects_duplicate_flags_and_switches_naming_them() {
        let err =
            table().parse(&args(&["--workers", "4", "--workers", "2"])).unwrap_err();
        assert!(
            err.contains("duplicate") && err.contains("--workers"),
            "duplicate value flag must be named: {err}"
        );
        let err = table().parse(&args(&["--steal", "--steal"])).unwrap_err();
        assert!(
            err.contains("duplicate") && err.contains("--steal"),
            "duplicate switch must be named: {err}"
        );
        // inline and spaced spellings of the same flag still collide
        let err =
            table().parse(&args(&["--max-batch=8", "--max-batch", "4"])).unwrap_err();
        assert!(err.contains("duplicate") && err.contains("--max-batch"), "{err}");
        // repeated --help stays fine (it is not a table flag)
        assert!(table().parse(&args(&["--help", "--help"])).unwrap().wants_help());
    }

    #[test]
    fn comma_lists_and_help_generation() {
        let p = table().parse(&args(&["--replicas", "1,4", "--help"])).expect("parse");
        assert_eq!(p.get_list::<usize>("--replicas").unwrap(), Some(vec![1, 4]));
        assert!(p.wants_help());
        let help = table().help_text();
        for needle in
            ["usage: scatter serve", "--workers N", "--steal", "work stealing", "--help"]
        {
            assert!(help.contains(needle), "help missing {needle:?}:\n{help}");
        }
    }

    #[test]
    fn bad_typed_values_name_the_flag() {
        let p = table().parse(&args(&["--workers", "lots"])).expect("parse");
        let err = p.get::<usize>("--workers").unwrap_err();
        assert!(err.contains("--workers") && err.contains("lots"), "{err}");
        let p = table().parse(&args(&["--replicas", "1,x"])).expect("parse");
        assert!(p.get_list::<usize>("--replicas").is_err());
    }
}

//! Minimal aligned-table printer for the benchmark harness output.
//!
//! Every table/figure harness prints its rows through this so the output is
//! comparable row-for-row against the paper's tables.

#[derive(Debug, Default, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        Self { title: title.to_string(), ..Default::default() }
    }

    pub fn header(mut self, cols: &[&str]) -> Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        self.rows.push(cells);
        self
    }

    pub fn row_f(&mut self, cells: &[(&str, usize)]) -> &mut Self {
        self.rows.push(cells.iter().map(|(s, _)| s.to_string()).collect());
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncol = self
            .rows
            .iter()
            .map(|r| r.len())
            .chain(std::iter::once(self.header.len()))
            .max()
            .unwrap_or(0);
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, w) in widths.iter().enumerate() {
                let cell = cells.get(i).map(String::as_str).unwrap_or("");
                line.push_str(&format!("{cell:>w$}  ", w = w));
            }
            line.trim_end().to_string()
        };
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            out.push('\n');
            out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * ncol));
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("t").header(&["a", "bbbb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let s = t.render();
        assert!(s.contains("== t =="));
        assert!(s.lines().count() >= 4);
        // header and rows align on the same column width
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[1].len(), lines[3].len());
    }

    #[test]
    fn empty_table_ok() {
        let t = Table::new("empty");
        assert!(t.render().contains("empty"));
        assert_eq!(t.n_rows(), 0);
    }
}

//! Deterministic xorshift128+ RNG.
//!
//! All stochastic hardware non-idealities (PD noise, phase noise) must be
//! reproducible across runs and across the rust/python boundary, so the
//! simulator uses a tiny self-contained generator rather than an external
//! crate whose stream could change between versions.

/// xorshift128+ with splitmix64 seeding. Passes BigCrush for our purposes
/// (noise injection); NOT cryptographic.
#[derive(Debug, Clone)]
pub struct XorShiftRng {
    s0: u64,
    s1: u64,
    /// Cached second gaussian from the Box-Muller pair.
    cached: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl XorShiftRng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm);
        let s1 = splitmix64(&mut sm);
        Self { s0, s1, cached: None }
    }

    /// Counter-based sub-stream: derive an independent generator from a
    /// base seed and a tuple of stream ids (epoch, chunk, column, ...).
    ///
    /// The parallel execution layer (`exec`) seeds one stream per
    /// (chunk, activation-column) so noise draws are bit-identical no
    /// matter how work items land on worker threads (EXPERIMENTS.md
    /// §Perf). Each id perturbs a splitmix64 chain, so streams whose
    /// tuples differ in any position are decorrelated.
    pub fn from_stream(seed: u64, ids: &[u64]) -> Self {
        let mut state = seed;
        let mut acc = splitmix64(&mut state);
        for &id in ids {
            state ^= id.wrapping_mul(0x9E3779B97F4A7C15);
            acc ^= splitmix64(&mut state);
        }
        Self::new(acc)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via the Marsaglia polar method (pair cached).
    /// Exact gaussian, ~1.6× faster than Box-Muller (no sin/cos) — this
    /// sits on the per-cycle PD-noise hot path (EXPERIMENTS.md §Perf).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(v) = self.cached.take() {
            return v;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s >= 1.0 || s == 0.0 {
                continue;
            }
            let f = (-2.0 * s.ln() / s).sqrt();
            self.cached = Some(v * f);
            return u * f;
        }
    }

    /// Gaussian with given std (mean 0).
    #[inline]
    pub fn gaussian_std(&mut self, std: f64) -> f64 {
        self.gaussian() * std
    }

    /// Random index in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Fill a slice with uniform values in [lo, hi).
    pub fn fill_uniform(&mut self, buf: &mut [f64], lo: f64, hi: f64) {
        for v in buf.iter_mut() {
            *v = self.uniform_in(lo, hi);
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = XorShiftRng::new(42);
        let mut b = XorShiftRng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = XorShiftRng::new(1);
        let mut b = XorShiftRng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = XorShiftRng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.uniform();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = XorShiftRng::new(9);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.gaussian();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn stream_ids_decorrelate_and_reproduce() {
        let mut a = XorShiftRng::from_stream(42, &[1, 7, 3]);
        let mut b = XorShiftRng::from_stream(42, &[1, 7, 3]);
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // any differing id position yields a different stream
        for ids in [[2u64, 7, 3], [1, 8, 3], [1, 7, 4]] {
            let mut c = XorShiftRng::from_stream(42, &ids);
            let mut a = XorShiftRng::from_stream(42, &[1, 7, 3]);
            let same = (0..32).filter(|_| a.next_u64() == c.next_u64()).count();
            assert!(same < 2, "stream {ids:?} collides");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = XorShiftRng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}

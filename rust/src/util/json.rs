//! Minimal JSON reader/writer.
//!
//! The offline build has no serde, so configuration files, mask exports,
//! and the python↔rust weight bundles use this ~300-line implementation.
//! It supports the full JSON value model with f64 numbers — sufficient for
//! every interchange format in this repo.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_bool(xs: &[bool]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Bool(x)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Strict numeric-array decode: `None` if this is not an array or
    /// *any* element is non-numeric. (A lenient `filter_map` here once
    /// let a corrupt weight bundle decode into a wrong-length tensor
    /// instead of an error.)
    pub fn f64_vec(&self) -> Option<Vec<f64>> {
        let a = self.as_arr()?;
        let mut out = Vec::with_capacity(a.len());
        for v in a {
            out.push(v.as_f64()?);
        }
        Some(out)
    }

    /// Strict bool-array decode; numbers are accepted as 0/nonzero (the
    /// python mask exports use 0/1), anything else is `None` — malformed
    /// entries used to coerce to `false` silently.
    pub fn bool_vec(&self) -> Option<Vec<bool>> {
        let a = self.as_arr()?;
        let mut out = Vec::with_capacity(a.len());
        for v in a {
            out.push(v.as_bool().or_else(|| v.as_f64().map(|x| x != 0.0))?);
        }
        Some(out)
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no NaN/Infinity literal; `write!("{x}")`
                    // used to emit them verbatim, corrupting BENCH_*.json
                    // artifacts into unparseable text
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse from text.
    pub fn parse(s: &str) -> Result<Json, String> {
        let bytes = s.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing characters at {pos}"));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end of input".into());
    }
    match b[*pos] {
        b'n' => expect(b, pos, "null").map(|_| Json::Null),
        b't' => expect(b, pos, "true").map(|_| Json::Bool(true)),
        b'f' => expect(b, pos, "false").map(|_| Json::Bool(false)),
        b'"' => parse_string(b, pos).map(Json::Str),
        b'[' => {
            *pos += 1;
            let mut arr = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Json::Arr(arr));
            }
            loop {
                arr.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(arr));
                    }
                    _ => return Err(format!("expected ',' or ']' at {pos}")),
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut map = BTreeMap::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(format!("expected ':' at {pos}"));
                }
                *pos += 1;
                let val = parse_value(b, pos)?;
                map.insert(key, val);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(map));
                    }
                    _ => return Err(format!("expected ',' or '}}' at {pos}")),
                }
            }
        }
        _ => parse_number(b, pos),
    }
}

fn expect(b: &[u8], pos: &mut usize, word: &str) -> Result<(), String> {
    if b.len() >= *pos + word.len() && &b[*pos..*pos + word.len()] == word.as_bytes() {
        *pos += word.len();
        Ok(())
    } else {
        Err(format!("expected '{word}' at {pos}"))
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at {pos}"));
    }
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| "bad \\u escape".to_string())?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| "bad \\u escape".to_string())?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => return Err(format!("bad escape at {pos}")),
                }
                *pos += 1;
            }
            _ => {
                // copy a full utf-8 scalar
                let start = *pos;
                let len = utf8_len(b[*pos]);
                *pos += len;
                out.push_str(
                    std::str::from_utf8(&b[start..start + len])
                        .map_err(|_| "invalid utf-8".to_string())?,
                );
            }
        }
    }
    Err("unterminated string".into())
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos]).map_err(|_| "bad number".to_string())?;
    text.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{text}': {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let v = Json::obj(vec![
            ("a", Json::Num(1.5)),
            ("b", Json::Arr(vec![Json::Bool(true), Json::Null, Json::Str("x\"y".into())])),
            ("c", Json::obj(vec![("d", Json::Num(-3.0))])),
        ]);
        let s = v.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn parses_whitespace_and_ints() {
        let v = Json::parse(" { \"k\" : [ 1 , 2.5 , -3e2 ] } ").unwrap();
        let arr = v.get("k").unwrap().f64_vec().unwrap();
        assert_eq!(arr, vec![1.0, 2.5, -300.0]);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(1.25).to_string(), "1.25");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_string() {
        let v = Json::parse("\"caf\\u00e9 µm\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "café µm");
    }

    #[test]
    fn bool_vec_accepts_numbers() {
        let v = Json::parse("[1, 0, true, false]").unwrap();
        assert_eq!(v.bool_vec().unwrap(), vec![true, false, true, false]);
    }

    #[test]
    fn non_finite_serializes_as_null_and_round_trips() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string(), "null");
        // a bench artifact carrying a NaN cell must stay valid JSON
        let doc = Json::obj(vec![
            ("p_avg_w", Json::Num(f64::NAN)),
            ("gmacs", Json::Num(12.5)),
        ]);
        let text = doc.to_string();
        assert_eq!(text, "{\"gmacs\":12.5,\"p_avg_w\":null}");
        let back = Json::parse(&text).expect("round-trips through the parser");
        assert_eq!(back.get("p_avg_w"), Some(&Json::Null));
        assert_eq!(back.get("gmacs").and_then(Json::as_f64), Some(12.5));
        // nested containers too
        let arr = Json::arr_f64(&[1.0, f64::NAN, 3.0]).to_string();
        assert_eq!(arr, "[1,null,3]");
        assert!(Json::parse(&arr).is_ok());
    }

    #[test]
    fn f64_vec_rejects_any_non_numeric_element() {
        assert_eq!(
            Json::parse("[1, 2.5, 3]").unwrap().f64_vec(),
            Some(vec![1.0, 2.5, 3.0])
        );
        for bad in ["[1, \"x\", 3]", "[1, null, 3]", "[1, true]", "[[1]]"] {
            assert_eq!(
                Json::parse(bad).unwrap().f64_vec(),
                None,
                "{bad} must not decode into a shorter tensor"
            );
        }
        assert_eq!(Json::Str("not an array".into()).f64_vec(), None);
    }

    #[test]
    fn bool_vec_rejects_malformed_elements() {
        for bad in ["[true, \"x\"]", "[1, null]", "[[true]]", "[false, {}]"] {
            assert_eq!(
                Json::parse(bad).unwrap().bool_vec(),
                None,
                "{bad} must not coerce to false"
            );
        }
        assert_eq!(Json::parse("[]").unwrap().bool_vec(), Some(vec![]));
    }
}

//! Crosstalk/power-minimized mask initialization (Alg. 1 lines 1–3).
//!
//! * Row density `s^r = max(s, 0.5)`: at most half the rows are pruned and
//!   the zeros are interleaved (`1010…` at 50 %) so every surviving MZI has
//!   a powered-off horizontal neighbor — the minimum-crosstalk pattern of
//!   Fig. 9(a). The paper's worked example: s^r = 0.75, rk1 = 8 →
//!   `11111010`.
//! * Column density `s^c = s / s^r`, with the active set chosen per chunk
//!   to minimize rerouter power (balanced subtree counts are cheapest).

use super::mask::{ChunkMask, LayerMask};
use super::power_opt::best_segment_mask;
use crate::devices::Mzi;

/// Interleaved row mask with `density` fraction of ones: zeros are placed
/// from the tail at every other position (paper's worked example).
pub fn interleaved_row_mask(n: usize, density: f64) -> Vec<bool> {
    assert!((0.0..=1.0).contains(&density));
    let n_zero = ((1.0 - density) * n as f64).round() as usize;
    assert!(
        n_zero <= n / 2,
        "interleaved pattern supports at most 50% row pruning ({n_zero} zeros of {n})"
    );
    let mut mask = vec![true; n];
    // zeros at n-1, n-3, n-5, ... keeps every zero isolated between ones
    let mut pos = n as isize - 1;
    for _ in 0..n_zero {
        mask[pos as usize] = false;
        pos -= 2;
    }
    mask
}

/// Initialize a layer mask for target density `s` on a p×q grid of
/// `rows × cols` chunks whose rerouter segments are `k2` ports wide.
///
/// Returns the mask and the (s^r, s^c) split actually used.
pub fn init_layer_mask(
    p: usize,
    q: usize,
    rows: usize,
    cols: usize,
    k2: usize,
    s: f64,
    rerouter_mzi: &Mzi,
) -> (LayerMask, f64, f64) {
    assert!(cols % k2 == 0, "chunk cols must be a multiple of k2");
    assert!((0.0..=1.0).contains(&s), "density in [0,1]");
    let s_r = s.max(0.5);
    let s_c = (s / s_r).min(1.0);

    let row = interleaved_row_mask(rows, s_r);

    // per-segment column pattern, identical across the chunk's c segments
    // (paper: same pattern per k1×k2 block) and across chunks at init;
    // power-aware DST will diversify them later.
    let active_per_seg = (s_c * k2 as f64).round() as usize;
    let seg = best_segment_mask(k2, active_per_seg, rerouter_mzi, 20_000);
    let col: Vec<bool> = (0..cols).map(|j| seg[j % k2]).collect();

    let chunk = ChunkMask::new(row, col);
    let lm = LayerMask { p, q, chunks: vec![chunk; p * q] };
    (lm, s_r, s_c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::MziSpec;
    use crate::thermal::GammaModel;

    fn mzi() -> Mzi {
        Mzi::new(MziSpec::low_power(), 9.0, &GammaModel::paper())
    }

    #[test]
    fn paper_worked_example_11111010() {
        let m = interleaved_row_mask(8, 0.75);
        let s: String = m.iter().map(|&b| if b { '1' } else { '0' }).collect();
        assert_eq!(s, "11111010");
    }

    #[test]
    fn half_density_is_1010() {
        let m = interleaved_row_mask(8, 0.5);
        let s: String = m.iter().map(|&b| if b { '1' } else { '0' }).collect();
        assert_eq!(s, "10101010");
    }

    #[test]
    fn full_density_all_ones() {
        assert!(interleaved_row_mask(16, 1.0).iter().all(|&b| b));
    }

    #[test]
    fn zeros_always_isolated() {
        for n in [4usize, 8, 12, 16, 64] {
            for d in [0.5, 0.6, 0.75, 0.9] {
                let m = interleaved_row_mask(n, d);
                for i in 0..n - 1 {
                    assert!(
                        m[i] || m[i + 1],
                        "adjacent zeros at {i} for n={n} d={d}"
                    );
                }
            }
        }
    }

    #[test]
    #[should_panic]
    fn rejects_below_half_density() {
        let _ = interleaved_row_mask(8, 0.3);
    }

    #[test]
    fn init_splits_density_per_paper() {
        // s = 0.3 -> s^r = 0.5, s^c = 0.6
        let (lm, s_r, s_c) = init_layer_mask(2, 3, 64, 64, 16, 0.3, &mzi());
        assert_eq!(s_r, 0.5);
        assert!((s_c - 0.6).abs() < 1e-12);
        // realized density ≈ s (rounding to integer counts)
        assert!((lm.density() - 0.3).abs() < 0.05, "density={}", lm.density());
        // high target density: all sparsity goes to rows
        let (_, s_r, s_c) = init_layer_mask(1, 1, 64, 64, 16, 0.75, &mzi());
        assert_eq!(s_r, 0.75);
        assert!((s_c - 1.0).abs() < 1e-12);
    }

    #[test]
    fn init_mask_row_is_interleaved() {
        let (lm, _, _) = init_layer_mask(1, 1, 8, 16, 16, 0.3, &mzi());
        let row = &lm.chunk(0, 0).row;
        let s: String = row.iter().map(|&b| if b { '1' } else { '0' }).collect();
        assert_eq!(s, "10101010");
    }

    #[test]
    fn init_segment_pattern_repeats_per_k2() {
        let (lm, _, _) = init_layer_mask(1, 1, 64, 64, 16, 0.4, &mzi());
        let col = &lm.chunk(0, 0).col;
        for j in 0..16 {
            for seg in 1..4 {
                assert_eq!(col[j], col[seg * 16 + j], "pattern must repeat per segment");
            }
        }
    }
}

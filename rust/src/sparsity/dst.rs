//! Algorithm 1 — power/crosstalk-aware dynamic sparse training — mask
//! update machinery (the gradient/weight statistics come from the caller,
//! which is the JAX training loop at build time or the rust deployment
//! refinement in `coordinator`).
//!
//! Per update (every ΔT steps while t < T_end):
//! 1. death rate α ← (α0/2)(1 + cos(tπ/T_end));
//! 2. **prune**: D = ⌈α·Σ(m^r ⊙ m^c)⌉ weights ⇒ n_c = D / (Σm^r / (p·q))
//!    columns; candidates = smallest-ℓ2 active columns (n_c + Δm of them);
//!    the C(n_c+Δm, n_c) combination with minimum power is pruned;
//! 3. **grow**: restore the same number of columns, candidates by largest
//!    gradient norm, again minimum-power combination.

use super::mask::LayerMask;
use super::power_opt::select_min_power_combination;
use crate::devices::Mzi;

/// Cosine-decayed death rate (Alg. 1 line 8).
pub fn cosine_death_rate(alpha0: f64, t: usize, t_end: usize) -> f64 {
    if t >= t_end {
        return 0.0;
    }
    alpha0 / 2.0 * (1.0 + (t as f64 * std::f64::consts::PI / t_end as f64).cos())
}

/// DST controller state for one layer.
#[derive(Debug, Clone)]
pub struct DstState {
    pub mask: LayerMask,
    /// Target density s (fraction nonzero).
    pub target_density: f64,
    /// Initial death rate α0.
    pub alpha0: f64,
    /// Step at which prune/grow stops (80 % of training).
    pub t_end: usize,
    /// Selection margin Δm.
    pub margin: usize,
    /// Rerouter segment width k2.
    pub k2: usize,
    /// Combination-enumeration cap.
    pub cap: usize,
}

impl DstState {
    pub fn new(mask: LayerMask, target_density: f64, alpha0: f64, t_end: usize, k2: usize) -> Self {
        Self { mask, target_density, alpha0, t_end, margin: 2, k2, cap: 10_000 }
    }

    /// Number of columns to prune this round for a chunk grid (Alg. 1
    /// lines 9–10): the death count D spread over columns, where each
    /// column holds Σm^r/(p·q) active weights.
    fn columns_to_prune(&self, alpha: f64) -> usize {
        let active = self.mask.active_elements() as f64;
        let d = (alpha * active).ceil();
        let pq = (self.mask.p * self.mask.q) as f64;
        let rows_per_chunk: f64 = self
            .mask
            .chunks
            .iter()
            .map(|c| c.active_rows() as f64)
            .sum::<f64>()
            / pq;
        if rows_per_chunk == 0.0 {
            return 0;
        }
        // per-chunk column count, spread over all chunks
        ((d / rows_per_chunk) / pq).round() as usize
    }

    /// One prune+grow round.
    ///
    /// * `col_l2[chunk][col]` — ℓ2 norms of each column's weights;
    /// * `col_grad[chunk][col]` — gradient norms for the growth stage;
    /// * `t` — current step.
    ///
    /// Returns the death rate used (0 ⇒ no-op round).
    pub fn update(
        &mut self,
        col_l2: &[Vec<f64>],
        col_grad: &[Vec<f64>],
        t: usize,
        mzi: &Mzi,
    ) -> f64 {
        if t >= self.t_end {
            return 0.0;
        }
        let alpha = cosine_death_rate(self.alpha0, t, self.t_end);
        let n_c = self.columns_to_prune(alpha);
        if n_c == 0 {
            return alpha;
        }
        assert_eq!(col_l2.len(), self.mask.chunks.len());
        assert_eq!(col_grad.len(), self.mask.chunks.len());

        for (ci, chunk) in self.mask.chunks.iter_mut().enumerate() {
            // ---- prune stage ----
            let mut active: Vec<usize> =
                (0..chunk.cols).filter(|&j| chunk.col[j]).collect();
            if active.len() <= n_c {
                continue; // nothing sensible to prune
            }
            active.sort_by(|&a, &b| {
                col_l2[ci][a].partial_cmp(&col_l2[ci][b]).unwrap()
            });
            let pool: Vec<usize> =
                active.iter().copied().take((n_c + self.margin).min(active.len())).collect();
            let to_prune = select_min_power_combination(
                &chunk.col, &pool, n_c.min(pool.len()), false, self.k2, mzi, self.cap,
            );
            for &j in &to_prune {
                chunk.col[j] = false;
            }

            // ---- grow stage ----
            // restore enough columns to return to the target density
            let rows = chunk.active_rows().max(1);
            let target_active =
                (self.target_density * (chunk.rows * chunk.cols) as f64).round() as usize;
            let cur_active = chunk.active_elements();
            let n_grow = if target_active > cur_active {
                ((target_active - cur_active) as f64 / rows as f64).round() as usize
            } else {
                0
            };
            if n_grow == 0 {
                continue;
            }
            let mut inactive: Vec<usize> =
                (0..chunk.cols).filter(|&j| !chunk.col[j]).collect();
            inactive.sort_by(|&a, &b| {
                col_grad[ci][b].partial_cmp(&col_grad[ci][a]).unwrap()
            });
            let pool: Vec<usize> = inactive
                .iter()
                .copied()
                .take((n_grow + self.margin).min(inactive.len()))
                .collect();
            let to_grow = select_min_power_combination(
                &chunk.col, &pool, n_grow.min(pool.len()), true, self.k2, mzi, self.cap,
            );
            for &j in &to_grow {
                chunk.col[j] = true;
            }
        }
        alpha
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::MziSpec;
    use crate::sparsity::init::init_layer_mask;
    use crate::thermal::GammaModel;
    use crate::util::XorShiftRng;

    fn mzi() -> Mzi {
        Mzi::new(MziSpec::low_power(), 9.0, &GammaModel::paper())
    }

    #[test]
    fn cosine_schedule_endpoints() {
        assert!((cosine_death_rate(0.5, 0, 100) - 0.5).abs() < 1e-12);
        let mid = cosine_death_rate(0.5, 50, 100);
        assert!((mid - 0.25).abs() < 1e-12);
        assert!(cosine_death_rate(0.5, 100, 100) == 0.0);
        assert!(cosine_death_rate(0.5, 150, 100) == 0.0);
    }

    #[test]
    fn schedule_monotone_decreasing() {
        let mut prev = f64::INFINITY;
        for t in (0..100).step_by(10) {
            let a = cosine_death_rate(0.5, t, 100);
            assert!(a <= prev);
            prev = a;
        }
    }

    fn stats(state: &DstState, seed: u64) -> (Vec<Vec<f64>>, Vec<Vec<f64>>) {
        let mut rng = XorShiftRng::new(seed);
        let l2: Vec<Vec<f64>> = state
            .mask
            .chunks
            .iter()
            .map(|c| (0..c.cols).map(|_| rng.uniform()).collect())
            .collect();
        let grad: Vec<Vec<f64>> = state
            .mask
            .chunks
            .iter()
            .map(|c| (0..c.cols).map(|_| rng.uniform()).collect())
            .collect();
        (l2, grad)
    }

    #[test]
    fn density_preserved_across_updates() {
        let (mask, _, _) = init_layer_mask(2, 2, 16, 32, 16, 0.4, &mzi());
        let d0 = mask.density();
        let mut st = DstState::new(mask, 0.4, 0.5, 1000, 16);
        for (i, t) in (0..1000).step_by(100).enumerate() {
            let (l2, grad) = stats(&st, i as u64);
            st.update(&l2, &grad, t, &mzi());
            let d = st.mask.density();
            assert!(
                (d - d0).abs() < 0.15,
                "density drifted at t={t}: {d} vs {d0}"
            );
        }
    }

    #[test]
    fn masks_frozen_after_t_end() {
        let (mask, _, _) = init_layer_mask(1, 1, 16, 32, 16, 0.4, &mzi());
        let mut st = DstState::new(mask, 0.4, 0.5, 100, 16);
        let before = st.mask.clone();
        let (l2, grad) = stats(&st, 3);
        let alpha = st.update(&l2, &grad, 100, &mzi());
        assert_eq!(alpha, 0.0);
        assert_eq!(st.mask.chunks[0], before.chunks[0]);
    }

    #[test]
    fn prune_pool_is_smallest_l2() {
        // init dense-ish, but target a LOWER density so the growth stage
        // cannot fully restore what pruning removed.
        let (mask, _, _) = init_layer_mask(1, 1, 16, 16, 16, 0.9, &mzi());
        let mut st = DstState::new(mask, 0.5, 0.6, 100, 16);
        // distinct norms: columns 12..15 have the largest l2 and never
        // enter the candidate pool, so they must survive pruning.
        let l2: Vec<Vec<f64>> = vec![(0..16).map(|j| (j + 1) as f64).collect()];
        let grad = vec![vec![0.0; 16]];
        st.update(&l2, &grad, 0, &mzi());
        let col = &st.mask.chunks[0].col;
        let pruned: Vec<usize> = (0..16).filter(|&j| !col[j]).collect();
        assert!(!pruned.is_empty(), "net pruning must happen at target 0.5 < init 0.9");
        assert!(
            pruned.iter().all(|&j| j < 12),
            "largest-l2 columns must survive: pruned={pruned:?}"
        );
        // density moved toward the target
        assert!(st.mask.density() < 0.9);
    }

    #[test]
    fn row_mask_untouched_by_updates() {
        let (mask, _, _) = init_layer_mask(1, 2, 16, 32, 16, 0.3, &mzi());
        let row0 = mask.chunks[0].row.clone();
        let mut st = DstState::new(mask, 0.3, 0.5, 500, 16);
        for t in (0..500).step_by(50) {
            let (l2, grad) = stats(&st, t as u64);
            st.update(&l2, &grad, t, &mzi());
        }
        for c in &st.mask.chunks {
            assert_eq!(c.row, row0, "Alg. 1 fixes the row mask after init");
        }
    }
}

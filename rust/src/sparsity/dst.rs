//! Algorithm 1 — power/crosstalk-aware dynamic sparse training — mask
//! update machinery (the gradient/weight statistics come from the caller,
//! which is the JAX training loop at build time or the rust deployment
//! refinement in `coordinator`).
//!
//! Per update (every ΔT steps while t < T_end):
//! 1. death rate α ← (α0/2)(1 + cos(tπ/T_end));
//! 2. **prune**: D = ⌈α·Σ(m^r ⊙ m^c)⌉ weights ⇒ n_c = D / (Σm^r / (p·q))
//!    columns; candidates = smallest-ℓ2 active columns (n_c + Δm of them);
//!    the C(n_c+Δm, n_c) combination with minimum power is pruned;
//! 3. **grow**: restore the same number of columns, candidates by largest
//!    gradient norm, again minimum-power combination.

use super::mask::LayerMask;
use super::power_opt::{mask_power_mw, select_min_power_combination};
use crate::devices::Mzi;
use std::collections::BTreeMap;

/// Cosine-decayed death rate (Alg. 1 line 8).
pub fn cosine_death_rate(alpha0: f64, t: usize, t_end: usize) -> f64 {
    if t >= t_end {
        return 0.0;
    }
    alpha0 / 2.0 * (1.0 + (t as f64 * std::f64::consts::PI / t_end as f64).cos())
}

/// DST controller state for one layer.
#[derive(Debug, Clone)]
pub struct DstState {
    pub mask: LayerMask,
    /// Target density s (fraction nonzero).
    pub target_density: f64,
    /// Initial death rate α0.
    pub alpha0: f64,
    /// Step at which prune/grow stops (80 % of training).
    pub t_end: usize,
    /// Selection margin Δm.
    pub margin: usize,
    /// Rerouter segment width k2.
    pub k2: usize,
    /// Combination-enumeration cap.
    pub cap: usize,
}

impl DstState {
    pub fn new(mask: LayerMask, target_density: f64, alpha0: f64, t_end: usize, k2: usize) -> Self {
        Self { mask, target_density, alpha0, t_end, margin: 2, k2, cap: 10_000 }
    }

    /// Number of columns to prune this round for a chunk grid (Alg. 1
    /// lines 9–10): the death count D spread over columns, where each
    /// column holds Σm^r/(p·q) active weights.
    fn columns_to_prune(&self, alpha: f64) -> usize {
        let active = self.mask.active_elements() as f64;
        let d = (alpha * active).ceil();
        let pq = (self.mask.p * self.mask.q) as f64;
        let rows_per_chunk: f64 = self
            .mask
            .chunks
            .iter()
            .map(|c| c.active_rows() as f64)
            .sum::<f64>()
            / pq;
        if rows_per_chunk == 0.0 {
            return 0;
        }
        // per-chunk column count, spread over all chunks
        ((d / rows_per_chunk) / pq).round() as usize
    }

    /// One prune+grow round.
    ///
    /// * `col_l2[chunk][col]` — ℓ2 norms of each column's weights;
    /// * `col_grad[chunk][col]` — gradient norms for the growth stage;
    /// * `t` — current step.
    ///
    /// Returns the death rate used (0 ⇒ no-op round).
    pub fn update(
        &mut self,
        col_l2: &[Vec<f64>],
        col_grad: &[Vec<f64>],
        t: usize,
        mzi: &Mzi,
    ) -> f64 {
        if t >= self.t_end {
            return 0.0;
        }
        let alpha = cosine_death_rate(self.alpha0, t, self.t_end);
        let n_c = self.columns_to_prune(alpha);
        if n_c == 0 {
            return alpha;
        }
        assert_eq!(col_l2.len(), self.mask.chunks.len());
        assert_eq!(col_grad.len(), self.mask.chunks.len());

        for (ci, chunk) in self.mask.chunks.iter_mut().enumerate() {
            // ---- prune stage ----
            let mut active: Vec<usize> =
                (0..chunk.cols).filter(|&j| chunk.col[j]).collect();
            if active.len() <= n_c {
                continue; // nothing sensible to prune
            }
            active.sort_by(|&a, &b| {
                col_l2[ci][a].partial_cmp(&col_l2[ci][b]).unwrap()
            });
            let pool: Vec<usize> =
                active.iter().copied().take((n_c + self.margin).min(active.len())).collect();
            let to_prune = select_min_power_combination(
                &chunk.col, &pool, n_c.min(pool.len()), false, self.k2, mzi, self.cap,
            );
            for &j in &to_prune {
                chunk.col[j] = false;
            }

            // ---- grow stage ----
            // restore enough columns to return to the target density
            let rows = chunk.active_rows().max(1);
            let target_active =
                (self.target_density * (chunk.rows * chunk.cols) as f64).round() as usize;
            let cur_active = chunk.active_elements();
            let n_grow = if target_active > cur_active {
                ((target_active - cur_active) as f64 / rows as f64).round() as usize
            } else {
                0
            };
            if n_grow == 0 {
                continue;
            }
            let mut inactive: Vec<usize> =
                (0..chunk.cols).filter(|&j| !chunk.col[j]).collect();
            inactive.sort_by(|&a, &b| {
                col_grad[ci][b].partial_cmp(&col_grad[ci][a]).unwrap()
            });
            let pool: Vec<usize> = inactive
                .iter()
                .copied()
                .take((n_grow + self.margin).min(inactive.len()))
                .collect();
            let to_grow = select_min_power_combination(
                &chunk.col, &pool, n_grow.min(pool.len()), true, self.k2, mzi, self.cap,
            );
            for &j in &to_grow {
                chunk.col[j] = true;
            }
        }
        alpha
    }
}

/// Column ℓ2 norms of a row-major `out_dim × in_dim` weight matrix on
/// the `rows × cols` chunk grid: `result[pi·q + qi][j]` is the norm of
/// chunk (pi, qi)'s column `j`. Padding columns/rows beyond the matrix
/// edge contribute zero, matching the scheduler's zero-padded chunking.
pub fn chunked_col_norms(
    w: &[f64],
    out_dim: usize,
    in_dim: usize,
    rows: usize,
    cols: usize,
) -> Vec<Vec<f64>> {
    assert_eq!(w.len(), out_dim * in_dim, "row-major weight matrix");
    let p = out_dim.div_ceil(rows);
    let q = in_dim.div_ceil(cols);
    let mut out = vec![vec![0.0; cols]; p * q];
    for pi in 0..p {
        for qi in 0..q {
            let norms = &mut out[pi * q + qi];
            for (j, norm) in norms.iter_mut().enumerate() {
                let gj = qi * cols + j;
                if gj >= in_dim {
                    continue;
                }
                let mut acc = 0.0;
                for i in 0..rows {
                    let gi = pi * rows + i;
                    if gi >= out_dim {
                        break;
                    }
                    let v = w[gi * in_dim + gj];
                    acc += v * v;
                }
                *norm = acc.sqrt();
            }
        }
    }
    out
}

/// One mask candidate emitted by a [`DstJob`] round: the full per-layer
/// mask set plus the power accounting that justifies it.
#[derive(Debug, Clone)]
pub struct DstCandidate {
    pub masks: BTreeMap<String, LayerMask>,
    /// Estimated rerouter hold power of the candidate mask set (mW).
    pub power_mw: f64,
    /// Serving power observed on the energy ledger when this round ran
    /// (W) — the co-design loop's input signal, kept for provenance.
    pub observed_power_w: f64,
}

/// A resumable in-serving DST job: the algorithm half of the co-design
/// loop (ROADMAP item 5), wrapping one [`DstState`] per masked layer.
///
/// Offline DST consumes gradients; a serving replica has none, so both
/// the prune criterion and the growth criterion use the weight-column
/// ℓ2 norms (the standard magnitude proxy) while the *selection among
/// candidates* stays the paper's min-power combination search. The
/// server feeds each round the average power from its per-request
/// energy ledger; the job folds it into an EWMA, stamps it on every
/// emitted [`DstCandidate`], and the dispatcher uses the same ledger to
/// pace rounds (no traffic served → no power signal → no step).
///
/// The job is resumable by construction: all state is `t` plus the
/// per-layer masks, so a step can run whenever a replica is idle and
/// cool, days apart if need be.
#[derive(Debug, Clone)]
pub struct DstJob {
    states: BTreeMap<String, DstState>,
    mzi: Mzi,
    k2: usize,
    t: usize,
    t_end: usize,
    /// EWMA of the observed serving power (W); 0 until the first signal.
    observed_power_w: f64,
}

impl DstJob {
    /// Wrap the currently-deployed masks. Each layer's target density is
    /// its deployed density — in-serving DST re-selects *which* columns
    /// carry light for minimum power, it does not change model capacity
    /// (the accuracy canary guards the swap, not a retrain).
    pub fn new(
        masks: BTreeMap<String, LayerMask>,
        alpha0: f64,
        t_end: usize,
        k2: usize,
        mzi: Mzi,
    ) -> Self {
        let states = masks
            .into_iter()
            .map(|(name, mask)| {
                let density = mask.density();
                (name, DstState::new(mask, density, alpha0, t_end.max(1), k2))
            })
            .collect();
        Self { states, mzi, k2, t: 0, t_end: t_end.max(1), observed_power_w: 0.0 }
    }

    /// The cosine schedule ran out: every further round is a no-op.
    pub fn is_done(&self) -> bool {
        self.t >= self.t_end
    }

    /// Steps taken so far.
    pub fn step_count(&self) -> usize {
        self.t
    }

    /// Current per-layer masks (the last candidate, or the initial set).
    pub fn masks(&self) -> BTreeMap<String, LayerMask> {
        self.states.iter().map(|(n, s)| (n.clone(), s.mask.clone())).collect()
    }

    /// Estimated rerouter hold power of the current mask set (mW).
    pub fn power_estimate_mw(&self) -> f64 {
        self.states
            .values()
            .flat_map(|s| s.mask.chunks.iter())
            .map(|c| mask_power_mw(&c.col, self.k2, &self.mzi))
            .sum()
    }

    /// One prune+grow round over every layer. `col_stats[layer]` are
    /// the chunked weight-column norms (see [`chunked_col_norms`]);
    /// layers without stats are skipped. `observed_power_w` is the
    /// serving power from the energy ledger. Returns a candidate only
    /// when some mask bit actually changed — an unchanged round (α
    /// annealed to ~0, or the min-power selection kept the status quo)
    /// emits nothing, so the server never swaps for a no-op.
    pub fn step(
        &mut self,
        col_stats: &BTreeMap<String, Vec<Vec<f64>>>,
        observed_power_w: f64,
    ) -> Option<DstCandidate> {
        if self.is_done() {
            return None;
        }
        if observed_power_w > 0.0 {
            self.observed_power_w = if self.observed_power_w == 0.0 {
                observed_power_w
            } else {
                0.8 * self.observed_power_w + 0.2 * observed_power_w
            };
        }
        let mut changed = false;
        for (name, st) in &mut self.states {
            let Some(stats) = col_stats.get(name) else { continue };
            if stats.len() != st.mask.chunks.len() {
                continue; // stale stats for a reshaped layer: skip, not panic
            }
            let before: Vec<Vec<bool>> =
                st.mask.chunks.iter().map(|c| c.col.clone()).collect();
            st.update(stats, stats, self.t, &self.mzi);
            if st.mask.chunks.iter().map(|c| &c.col).ne(before.iter()) {
                changed = true;
            }
        }
        self.t += 1;
        changed.then(|| DstCandidate {
            masks: self.masks(),
            power_mw: self.power_estimate_mw(),
            observed_power_w: self.observed_power_w,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::MziSpec;
    use crate::sparsity::init::init_layer_mask;
    use crate::thermal::GammaModel;
    use crate::util::XorShiftRng;

    fn mzi() -> Mzi {
        Mzi::new(MziSpec::low_power(), 9.0, &GammaModel::paper())
    }

    #[test]
    fn cosine_schedule_endpoints() {
        assert!((cosine_death_rate(0.5, 0, 100) - 0.5).abs() < 1e-12);
        let mid = cosine_death_rate(0.5, 50, 100);
        assert!((mid - 0.25).abs() < 1e-12);
        assert!(cosine_death_rate(0.5, 100, 100) == 0.0);
        assert!(cosine_death_rate(0.5, 150, 100) == 0.0);
    }

    #[test]
    fn schedule_monotone_decreasing() {
        let mut prev = f64::INFINITY;
        for t in (0..100).step_by(10) {
            let a = cosine_death_rate(0.5, t, 100);
            assert!(a <= prev);
            prev = a;
        }
    }

    fn stats(state: &DstState, seed: u64) -> (Vec<Vec<f64>>, Vec<Vec<f64>>) {
        let mut rng = XorShiftRng::new(seed);
        let l2: Vec<Vec<f64>> = state
            .mask
            .chunks
            .iter()
            .map(|c| (0..c.cols).map(|_| rng.uniform()).collect())
            .collect();
        let grad: Vec<Vec<f64>> = state
            .mask
            .chunks
            .iter()
            .map(|c| (0..c.cols).map(|_| rng.uniform()).collect())
            .collect();
        (l2, grad)
    }

    #[test]
    fn density_preserved_across_updates() {
        let (mask, _, _) = init_layer_mask(2, 2, 16, 32, 16, 0.4, &mzi());
        let d0 = mask.density();
        let mut st = DstState::new(mask, 0.4, 0.5, 1000, 16);
        for (i, t) in (0..1000).step_by(100).enumerate() {
            let (l2, grad) = stats(&st, i as u64);
            st.update(&l2, &grad, t, &mzi());
            let d = st.mask.density();
            assert!(
                (d - d0).abs() < 0.15,
                "density drifted at t={t}: {d} vs {d0}"
            );
        }
    }

    #[test]
    fn masks_frozen_after_t_end() {
        let (mask, _, _) = init_layer_mask(1, 1, 16, 32, 16, 0.4, &mzi());
        let mut st = DstState::new(mask, 0.4, 0.5, 100, 16);
        let before = st.mask.clone();
        let (l2, grad) = stats(&st, 3);
        let alpha = st.update(&l2, &grad, 100, &mzi());
        assert_eq!(alpha, 0.0);
        assert_eq!(st.mask.chunks[0], before.chunks[0]);
    }

    #[test]
    fn prune_pool_is_smallest_l2() {
        // init dense-ish, but target a LOWER density so the growth stage
        // cannot fully restore what pruning removed.
        let (mask, _, _) = init_layer_mask(1, 1, 16, 16, 16, 0.9, &mzi());
        let mut st = DstState::new(mask, 0.5, 0.6, 100, 16);
        // distinct norms: columns 12..15 have the largest l2 and never
        // enter the candidate pool, so they must survive pruning.
        let l2: Vec<Vec<f64>> = vec![(0..16).map(|j| (j + 1) as f64).collect()];
        let grad = vec![vec![0.0; 16]];
        st.update(&l2, &grad, 0, &mzi());
        let col = &st.mask.chunks[0].col;
        let pruned: Vec<usize> = (0..16).filter(|&j| !col[j]).collect();
        assert!(!pruned.is_empty(), "net pruning must happen at target 0.5 < init 0.9");
        assert!(
            pruned.iter().all(|&j| j < 12),
            "largest-l2 columns must survive: pruned={pruned:?}"
        );
        // density moved toward the target
        assert!(st.mask.density() < 0.9);
    }

    #[test]
    fn chunked_col_norms_match_direct_computation() {
        // 3×5 matrix on a 2×2 grid → p=2, q=3 with padding on both edges
        let w: Vec<f64> = (0..15).map(|i| i as f64).collect();
        let norms = chunked_col_norms(&w, 3, 5, 2, 2);
        assert_eq!(norms.len(), 6);
        // chunk (0,0) col 0 covers w[0][0], w[1][0] = 0, 5
        assert!((norms[0][0] - (25.0f64).sqrt()).abs() < 1e-12);
        // chunk (1,2) col 0 covers w[2][4] = 14 only (row 3 is padding)
        assert!((norms[5][0] - 14.0).abs() < 1e-12);
        // chunk (1,2) col 1 is pure padding (in_dim 5, gj = 5)
        assert_eq!(norms[5][1], 0.0);
    }

    fn job_masks() -> std::collections::BTreeMap<String, LayerMask> {
        let mut masks = std::collections::BTreeMap::new();
        for name in ["conv2", "conv3"] {
            let (m, _, _) = init_layer_mask(2, 2, 16, 32, 16, 0.4, &mzi());
            masks.insert(name.to_string(), m);
        }
        masks
    }

    fn job_stats(
        job: &DstJob,
        seed: u64,
    ) -> std::collections::BTreeMap<String, Vec<Vec<f64>>> {
        let mut rng = XorShiftRng::new(seed);
        job.masks()
            .iter()
            .map(|(n, lm)| {
                let stats = lm
                    .chunks
                    .iter()
                    .map(|c| (0..c.cols).map(|_| rng.uniform()).collect())
                    .collect();
                (n.clone(), stats)
            })
            .collect()
    }

    #[test]
    fn dst_job_emits_candidates_and_preserves_density_and_rows() {
        let masks = job_masks();
        let d0: std::collections::BTreeMap<String, f64> =
            masks.iter().map(|(n, m)| (n.clone(), m.density())).collect();
        let rows0: Vec<Vec<bool>> =
            masks["conv2"].chunks.iter().map(|c| c.row.clone()).collect();
        let mut job = DstJob::new(masks, 0.5, 50, 16, mzi());
        assert!(job.power_estimate_mw() > 0.0, "active columns hold rerouter power");
        let mut emitted = 0;
        for t in 0..50 {
            if let Some(cand) = job.step(&job_stats(&job, t), 2.5) {
                emitted += 1;
                assert!(cand.power_mw > 0.0);
                assert!(
                    (cand.observed_power_w - 2.5).abs() < 1e-9,
                    "ledger signal stamped on the candidate"
                );
                for (n, m) in &cand.masks {
                    assert!(
                        (m.density() - d0[n]).abs() < 0.15,
                        "in-serving DST keeps capacity: {n} {} vs {}",
                        m.density(),
                        d0[n]
                    );
                }
            }
        }
        assert!(emitted >= 1, "a 50-round job must emit at least one candidate");
        assert!(job.is_done());
        assert_eq!(job.step_count(), 50);
        assert!(job.step(&job_stats(&job, 99), 2.5).is_none(), "done job is a no-op");
        let rows_after: Vec<Vec<bool>> =
            job.masks()["conv2"].chunks.iter().map(|c| c.row.clone()).collect();
        assert_eq!(rows_after, rows0, "Alg. 1 fixes row masks after init");
    }

    #[test]
    fn dst_job_skips_layers_with_stale_stats() {
        let mut job = DstJob::new(job_masks(), 0.5, 10, 16, mzi());
        let before = job.masks();
        // wrong chunk count: the layer must be skipped, not panic
        let stats: std::collections::BTreeMap<String, Vec<Vec<f64>>> =
            [("conv2".to_string(), vec![vec![1.0; 16]])].into_iter().collect();
        let cand = job.step(&stats, 0.0);
        assert!(cand.is_none(), "no well-formed stats, no candidate");
        assert_eq!(
            job.masks()["conv2"].chunks[0].col, before["conv2"].chunks[0].col,
            "skipped layer unchanged"
        );
        assert_eq!(job.step_count(), 1, "the round still advances the schedule");
    }

    #[test]
    fn dst_job_power_signal_folds_as_ewma() {
        let mut job = DstJob::new(job_masks(), 0.5, 100, 16, mzi());
        let stats = job_stats(&job, 7);
        let _ = job.step(&stats, 4.0);
        let _ = job.step(&stats, 0.0); // no traffic: signal held, not zeroed
        let cand = loop {
            if let Some(c) = job.step(&job_stats(&job, job.step_count() as u64), 2.0) {
                break c;
            }
            assert!(!job.is_done(), "schedule exhausted without a candidate");
        };
        assert!(
            cand.observed_power_w > 2.0 && cand.observed_power_w < 4.0,
            "EWMA between the two observed signals: {}",
            cand.observed_power_w
        );
    }

    #[test]
    fn row_mask_untouched_by_updates() {
        let (mask, _, _) = init_layer_mask(1, 2, 16, 32, 16, 0.3, &mzi());
        let row0 = mask.chunks[0].row.clone();
        let mut st = DstState::new(mask, 0.3, 0.5, 500, 16);
        for t in (0..500).step_by(50) {
            let (l2, grad) = stats(&st, t as u64);
            st.update(&l2, &grad, t, &mzi());
        }
        for c in &st.mask.chunks {
            assert_eq!(c.row, row0, "Alg. 1 fixes the row mask after init");
        }
    }
}

//! Power-aware column-mask selection (§3.3.5 "How to Calculate Power
//! Metric for a Mask?" + the prune/grow candidate selection).
//!
//! The power of a column mask is the hold power of the rerouter trees it
//! programs (splitting-ratio-dependent, via `P(|Δφ|, l_s)`) plus the
//! gated/ungated DAC+MZM cost of its active ports. Among masks with equal
//! cardinality the DAC term is constant, so the *rerouter* power breaks
//! ties. Since the φ_b = π/2 bias point is the *even* split, steering
//! costs power proportional to the deviation — and fully steering light
//! away from a subtree costs the π/2 maximum. The cheapest masks therefore
//! **cluster** their active ports so that only a few high-level nodes
//! steer and the rest idle at the free even split (and clustering columns
//! is crosstalk-free: input ports are vertical neighbours at l_v = 120 µm).

use crate::devices::Mzi;
use crate::rerouter::RerouterTree;

/// Power metric (mW) of a column mask: sum of per-k2-segment rerouter hold
/// power. `k2` is the rerouter width; `mask.len()` must be a multiple.
pub fn mask_power_mw(mask: &[bool], k2: usize, mzi: &Mzi) -> f64 {
    assert!(mask.len() % k2 == 0, "mask must cover whole segments");
    mask.chunks(k2).map(|seg| RerouterTree::program(seg).power_mw(mzi)).sum()
}

/// Exhaustively (up to `cap` combinations) find the minimum-power mask of
/// `k2` ports with exactly `n_active` active. Deterministic: ties resolve
/// to the lexicographically first combination.
pub fn best_segment_mask(k2: usize, n_active: usize, mzi: &Mzi, cap: usize) -> Vec<bool> {
    assert!(n_active <= k2);
    if n_active == k2 {
        return vec![true; k2];
    }
    if n_active == 0 {
        return vec![false; k2];
    }
    let mut best: Option<(f64, Vec<bool>)> = None;
    let mut count = 0usize;
    let mut visit = |mask: &Vec<bool>| {
        let p = mask_power_mw(mask, k2, mzi);
        if best.as_ref().map_or(true, |(bp, _)| p < *bp - 1e-15) {
            best = Some((p, mask.clone()));
        }
    };
    // lexicographic k-combinations with a visit cap
    let mut idx: Vec<usize> = (0..n_active).collect();
    loop {
        let mut mask = vec![false; k2];
        for &i in &idx {
            mask[i] = true;
        }
        visit(&mask);
        count += 1;
        if count >= cap {
            break;
        }
        // advance combination
        let mut i = n_active;
        loop {
            if i == 0 {
                return best.unwrap().1;
            }
            i -= 1;
            if idx[i] != i + k2 - n_active {
                break;
            }
        }
        idx[i] += 1;
        for j in i + 1..n_active {
            idx[j] = idx[j - 1] + 1;
        }
    }
    best.unwrap().1
}

/// Alg. 1 prune/grow helper: among `candidates` (column indices), choose
/// `n_select` whose *deactivation* (for pruning) or *activation* (growth)
/// minimizes total mask power. Enumerates all C(|candidates|, n_select)
/// combinations up to `cap`; the candidate pool is small (n_c + Δm).
///
/// `base_mask` is the current column mask; `activate` = true for growth.
/// Returns the chosen candidate indices.
pub fn select_min_power_combination(
    base_mask: &[bool],
    candidates: &[usize],
    n_select: usize,
    activate: bool,
    k2: usize,
    mzi: &Mzi,
    cap: usize,
) -> Vec<usize> {
    assert!(n_select <= candidates.len());
    if n_select == 0 {
        return Vec::new();
    }
    let mut best: Option<(f64, Vec<usize>)> = None;
    let mut count = 0usize;
    let mut idx: Vec<usize> = (0..n_select).collect();
    loop {
        let chosen: Vec<usize> = idx.iter().map(|&i| candidates[i]).collect();
        let mut mask = base_mask.to_vec();
        for &c in &chosen {
            mask[c] = activate;
        }
        let p = mask_power_mw(&mask, k2, mzi);
        if best.as_ref().map_or(true, |(bp, _)| p < *bp - 1e-15) {
            best = Some((p, chosen));
        }
        count += 1;
        if count >= cap {
            break;
        }
        let n = candidates.len();
        let mut i = n_select;
        loop {
            if i == 0 {
                return best.unwrap().1;
            }
            i -= 1;
            if idx[i] != i + n - n_select {
                break;
            }
        }
        idx[i] += 1;
        for j in i + 1..n_select {
            idx[j] = idx[j - 1] + 1;
        }
    }
    best.unwrap().1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::MziSpec;
    use crate::thermal::GammaModel;

    fn mzi() -> Mzi {
        Mzi::new(MziSpec::low_power(), 9.0, &GammaModel::paper())
    }

    #[test]
    fn dense_mask_costs_nothing() {
        let m = mzi();
        assert!(mask_power_mw(&[true; 16], 16, &m) < 1e-12);
    }

    #[test]
    fn best_mask_is_clustered() {
        let m = mzi();
        // 8 ports, 4 active: the optimum packs the active ports into one
        // subtree so only the root steers (one pi/2 node); every other
        // node idles at the free even split.
        let best = best_segment_mask(8, 4, &m, 100_000);
        let p_best = mask_power_mw(&best, 8, &m);
        let clustered: Vec<bool> = (0..8).map(|i| i < 4).collect();
        let p_clustered = mask_power_mw(&clustered, 8, &m);
        assert!((p_best - p_clustered).abs() < 1e-12, "{p_best} vs {p_clustered}");
        // the interleaved mask pays a full-swing leaf per pair: 4x worse
        let inter: Vec<bool> = (0..8).map(|i| i % 2 == 0).collect();
        let p_inter = mask_power_mw(&inter, 8, &m);
        assert!(p_inter > p_best * 3.0, "interleaved {p_inter} vs best {p_best}");
    }

    #[test]
    fn best_mask_has_exact_cardinality() {
        let m = mzi();
        for n in 0..=8 {
            let mask = best_segment_mask(8, n, &m, 1_000_000);
            assert_eq!(mask.iter().filter(|&&b| b).count(), n);
        }
    }

    #[test]
    fn odd_counts_still_minimized() {
        let m = mzi();
        let best = best_segment_mask(8, 3, &m, 1_000_000);
        let p_best = mask_power_mw(&best, 8, &m);
        // exhaustive check: nothing beats it
        for a in 0..8 {
            for b in a + 1..8 {
                for c in b + 1..8 {
                    let mut mask = vec![false; 8];
                    mask[a] = true;
                    mask[b] = true;
                    mask[c] = true;
                    assert!(mask_power_mw(&mask, 8, &m) >= p_best - 1e-12);
                }
            }
        }
    }

    #[test]
    fn cap_respected_and_still_returns_valid() {
        let m = mzi();
        let mask = best_segment_mask(16, 8, &m, 10);
        assert_eq!(mask.iter().filter(|&&b| b).count(), 8);
    }

    #[test]
    fn select_prune_forms_cluster() {
        let m = mzi();
        // start dense on 8 ports; prune 4 of candidates {0..7}: the
        // minimum-power survivor set occupies one root subtree.
        let base = vec![true; 8];
        let candidates: Vec<usize> = (0..8).collect();
        let chosen = select_min_power_combination(&base, &candidates, 4, false, 8, &m, 1_000_000);
        let mut mask = base.clone();
        for &c in &chosen {
            mask[c] = false;
        }
        let p = mask_power_mw(&mask, 8, &m);
        let clustered: Vec<bool> = (0..8).map(|i| i < 4).collect();
        assert!((p - mask_power_mw(&clustered, 8, &m)).abs() < 1e-12);
        // the pruned set is one whole subtree
        let survivors: Vec<usize> = (0..8).filter(|j| mask[*j]).collect();
        assert!(
            survivors.iter().all(|&j| j < 4) || survivors.iter().all(|&j| j >= 4),
            "survivors should cluster: {survivors:?}"
        );
    }

    #[test]
    fn grow_joins_the_cluster() {
        let m = mzi();
        // 2 active in the left subtree; growing 2 more is cheapest when
        // they complete that subtree (only the root steers).
        let base = vec![true, true, false, false, false, false, false, false];
        let candidates: Vec<usize> = (2..8).collect();
        let chosen = select_min_power_combination(&base, &candidates, 2, true, 8, &m, 1_000_000);
        let mut mask = base.clone();
        for &c in &chosen {
            mask[c] = true;
        }
        assert_eq!(chosen, vec![2, 3], "grow completes the left subtree");
        let p = mask_power_mw(&mask, 8, &m);
        // strictly cheaper than spreading into the right subtree
        let spread = [true, true, false, false, true, true, false, false];
        assert!(p < mask_power_mw(&spread, 8, &m));
    }
}

//! Structured row-column sparsity (§3.3.5).
//!
//! A layer's im2col'd weight matrix (C_o × C_i·K²) is padded and
//! partitioned into a p×q grid of `rk1 × ck2` chunks. Each chunk carries:
//!
//! * a **row mask** over its rk1 rows (output channels) — pruned rows get
//!   TIA/ADC output gating; the pattern is *interleaved* to maximize the
//!   physical spacing of active MZIs (crosstalk suppression, Fig. 9(a));
//!   the paper fixes one row pattern for all chunks of a layer;
//! * a **column mask** over its ck2 columns (input ports) — pruned columns
//!   get DAC/MZM input gating and the rerouter redistributes their light;
//!   column patterns are chosen *per chunk* to minimize power.

pub mod dst;
pub mod init;
pub mod mask;
pub mod power_opt;

pub use dst::{chunked_col_norms, cosine_death_rate, DstCandidate, DstJob, DstState};
pub use init::{init_layer_mask, interleaved_row_mask};
pub use mask::{ChunkMask, LayerMask};
pub use power_opt::{best_segment_mask, mask_power_mw, select_min_power_combination};

//! Mask containers for chunked structured sparsity.


/// Row/column mask of one `rows × cols` weight chunk.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChunkMask {
    pub rows: usize,
    pub cols: usize,
    /// `true` = active row (output kept).
    pub row: Vec<bool>,
    /// `true` = active column (input kept).
    pub col: Vec<bool>,
}

impl ChunkMask {
    pub fn dense(rows: usize, cols: usize) -> Self {
        Self { rows, cols, row: vec![true; rows], col: vec![true; cols] }
    }

    pub fn new(row: Vec<bool>, col: Vec<bool>) -> Self {
        Self { rows: row.len(), cols: col.len(), row, col }
    }

    pub fn active_rows(&self) -> usize {
        self.row.iter().filter(|&&m| m).count()
    }

    pub fn active_cols(&self) -> usize {
        self.col.iter().filter(|&&m| m).count()
    }

    /// Element (i, j) survives iff both its row and column are active.
    #[inline]
    pub fn element(&self, i: usize, j: usize) -> bool {
        self.row[i] && self.col[j]
    }

    /// Number of surviving weights.
    pub fn active_elements(&self) -> usize {
        self.active_rows() * self.active_cols()
    }

    /// Density (fraction of nonzero weights) of this chunk.
    pub fn density(&self) -> f64 {
        self.active_elements() as f64 / (self.rows * self.cols) as f64
    }

    /// Apply to a row-major weight chunk in place.
    pub fn apply(&self, w: &mut [f64]) {
        assert_eq!(w.len(), self.rows * self.cols);
        for i in 0..self.rows {
            for j in 0..self.cols {
                if !self.element(i, j) {
                    w[i * self.cols + j] = 0.0;
                }
            }
        }
    }

    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        Json::obj(vec![
            ("row", Json::arr_bool(&self.row)),
            ("col", Json::arr_bool(&self.col)),
        ])
    }

    pub fn from_json(v: &crate::util::Json) -> crate::Result<Self> {
        let row = v
            .get("row")
            .and_then(crate::util::Json::bool_vec)
            .ok_or_else(|| crate::Error::Serde("chunk mask missing 'row'".into()))?;
        let col = v
            .get("col")
            .and_then(crate::util::Json::bool_vec)
            .ok_or_else(|| crate::Error::Serde("chunk mask missing 'col'".into()))?;
        Ok(Self::new(row, col))
    }
}

/// All chunk masks of one layer (p×q grid, row-major).
#[derive(Debug, Clone)]
pub struct LayerMask {
    pub p: usize,
    pub q: usize,
    pub chunks: Vec<ChunkMask>,
}

impl LayerMask {
    pub fn dense(p: usize, q: usize, rows: usize, cols: usize) -> Self {
        Self { p, q, chunks: vec![ChunkMask::dense(rows, cols); p * q] }
    }

    pub fn chunk(&self, pi: usize, qi: usize) -> &ChunkMask {
        &self.chunks[pi * self.q + qi]
    }

    pub fn chunk_mut(&mut self, pi: usize, qi: usize) -> &mut ChunkMask {
        &mut self.chunks[pi * self.q + qi]
    }

    /// Layer-wide density.
    pub fn density(&self) -> f64 {
        let total: usize = self.chunks.iter().map(|c| c.rows * c.cols).sum();
        let act: usize = self.chunks.iter().map(|c| c.active_elements()).sum();
        act as f64 / total.max(1) as f64
    }

    /// Total active (nonzero) weights — `Σ (m^r ⊙ m^c)` in Alg. 1.
    pub fn active_elements(&self) -> usize {
        self.chunks.iter().map(|c| c.active_elements()).sum()
    }

    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        Json::obj(vec![
            ("p", Json::Num(self.p as f64)),
            ("q", Json::Num(self.q as f64)),
            ("chunks", Json::Arr(self.chunks.iter().map(|c| c.to_json()).collect())),
        ])
    }

    pub fn from_json(v: &crate::util::Json) -> crate::Result<Self> {
        use crate::util::Json;
        let p = v.get("p").and_then(Json::as_usize).unwrap_or(1);
        let q = v.get("q").and_then(Json::as_usize).unwrap_or(1);
        let chunks = v
            .get("chunks")
            .and_then(Json::as_arr)
            .ok_or_else(|| crate::Error::Serde("layer mask missing 'chunks'".into()))?
            .iter()
            .map(ChunkMask::from_json)
            .collect::<crate::Result<Vec<_>>>()?;
        if chunks.len() != p * q {
            return Err(crate::Error::Serde(format!(
                "layer mask has {} chunks, expected {}",
                chunks.len(),
                p * q
            )));
        }
        Ok(Self { p, q, chunks })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_mask_everything_active() {
        let m = ChunkMask::dense(4, 8);
        assert_eq!(m.active_rows(), 4);
        assert_eq!(m.active_cols(), 8);
        assert_eq!(m.density(), 1.0);
    }

    #[test]
    fn element_is_row_and_col() {
        let m = ChunkMask::new(vec![true, false], vec![true, true, false]);
        assert!(m.element(0, 0));
        assert!(!m.element(1, 0));
        assert!(!m.element(0, 2));
        assert_eq!(m.active_elements(), 2);
    }

    #[test]
    fn apply_zeroes_pruned() {
        let m = ChunkMask::new(vec![true, false], vec![true, false]);
        let mut w = vec![1.0, 2.0, 3.0, 4.0];
        m.apply(&mut w);
        assert_eq!(w, vec![1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn layer_density_mixed() {
        let mut lm = LayerMask::dense(1, 2, 2, 2);
        lm.chunk_mut(0, 1).row = vec![true, false];
        assert!((lm.density() - 0.75).abs() < 1e-12);
        assert_eq!(lm.active_elements(), 6);
    }

    #[test]
    fn json_roundtrip() {
        let m = ChunkMask::new(vec![true, false, true], vec![false, true]);
        let s = m.to_json().to_string();
        let back = ChunkMask::from_json(&crate::util::Json::parse(&s).unwrap()).unwrap();
        assert_eq!(back, m);
    }
}

//! Deterministic fault injection for the serving stack.
//!
//! Every recovery path in the supervisor (`server.rs`) is driven by a
//! [`FaultPlan`]: a seedable, fully explicit schedule of worker faults
//! addressed by `(worker index, shard sequence number)`. Shard sequence
//! numbers are per-worker-slot and survive respawns (the dispatcher
//! numbers shards monotonically per slot across generations), so a plan
//! like "worker 1 panics at shard 3" fires exactly once no matter how
//! the surrounding traffic interleaves — chaos tests are reproducible
//! bit-for-bit, not statistically.
//!
//! Spec grammar (CLI `--faults`, comma-separated entries):
//!
//! ```text
//!   panic@w0:s2          worker 0 panics on receiving its shard #2
//!   stall@w1:s3:500ms    worker 1 sleeps 500 ms before executing shard #3
//!   slow@*:s5:20ms       every worker delays its shard-#5 replies 20 ms
//!   drop@w0:s7           worker 0 drops shard #7's reply channels
//!   kill-each:42         seeded macro: every worker panics once early on
//! ```
//!
//! `panic`, `stall` and `drop` fire while the shard is parked in the
//! worker's checkpoint slot, so the supervisor recovers the requests
//! losslessly; `slow` fires after the worker has committed to the shard
//! and exercises the late-reply path.

use crate::util::XorShiftRng;
use std::time::Duration;

/// What a worker does when its fault entry matches the current shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Panic with the shard still parked in the checkpoint slot: the
    /// supervisor recovers and re-dispatches every request.
    Panic,
    /// Sleep with the shard still parked: long stalls trip the watchdog
    /// and the supervisor steals the shard from the zombie.
    Stall(Duration),
    /// Execute normally, then sleep before replying: exercises client
    /// reply timeouts without losing work.
    SlowReply(Duration),
    /// Drop the shard's reply channels without executing: clients see a
    /// disconnect (retryable), the worker itself stays healthy.
    DropReplies,
}

/// One scheduled fault: `worker` of `None` is the `*` wildcard.
#[derive(Debug, Clone, PartialEq, Eq)]
struct FaultEntry {
    worker: Option<usize>,
    seq: u64,
    action: FaultAction,
}

/// A deterministic schedule of worker faults (empty by default).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    entries: Vec<FaultEntry>,
}

impl FaultPlan {
    /// No faults — the production default.
    pub fn none() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The action scheduled for shard `seq` on worker `worker`, if any.
    /// First matching entry wins; wildcard entries match every worker.
    pub fn action(&self, worker: usize, seq: u64) -> Option<FaultAction> {
        self.entries
            .iter()
            .find(|e| e.seq == seq && (e.worker.is_none() || e.worker == Some(worker)))
            .map(|e| e.action)
    }

    /// Seeded chaos macro: every worker panics exactly once, at a shard
    /// sequence drawn from `seed` in `[1, 4)` — early enough that short
    /// bench runs hit every fault, late enough that each replica serves
    /// real traffic first. Counter-based, so the same `(workers, seed)`
    /// always yields the same plan.
    pub fn kill_each_worker_once(workers: usize, seed: u64) -> Self {
        let entries = (0..workers)
            .map(|w| FaultEntry {
                worker: Some(w),
                seq: 1 + XorShiftRng::from_stream(seed, &[w as u64]).next_u64() % 3,
                action: FaultAction::Panic,
            })
            .collect();
        Self { entries }
    }

    /// Parse a `--faults` spec (see module docs for the grammar).
    /// `workers` resolves the `kill-each:SEED` macro.
    pub fn parse(spec: &str, workers: usize) -> Result<Self, String> {
        let mut plan = Self::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            if let Some(seed) = part.strip_prefix("kill-each:") {
                let seed: u64 = seed
                    .parse()
                    .map_err(|_| format!("bad kill-each seed in `{part}`"))?;
                plan.entries
                    .extend(Self::kill_each_worker_once(workers, seed).entries);
                continue;
            }
            plan.entries.push(parse_entry(part)?);
        }
        Ok(plan)
    }

    /// Human-readable entry list (bench JSON / serve logs).
    pub fn describe(&self) -> Vec<String> {
        self.entries
            .iter()
            .map(|e| {
                let target = match e.worker {
                    Some(w) => format!("w{w}"),
                    None => "*".into(),
                };
                match e.action {
                    FaultAction::Panic => format!("panic@{target}:s{}", e.seq),
                    FaultAction::Stall(d) => {
                        format!("stall@{target}:s{}:{}ms", e.seq, d.as_millis())
                    }
                    FaultAction::SlowReply(d) => {
                        format!("slow@{target}:s{}:{}ms", e.seq, d.as_millis())
                    }
                    FaultAction::DropReplies => format!("drop@{target}:s{}", e.seq),
                }
            })
            .collect()
    }
}

fn parse_entry(part: &str) -> Result<FaultEntry, String> {
    let (kind, rest) = part
        .split_once('@')
        .ok_or_else(|| format!("fault `{part}` missing `@` (kind@wW:sN[:Dms])"))?;
    let mut fields = rest.split(':');
    let worker = match fields.next() {
        Some("*") => None,
        Some(w) => Some(
            w.strip_prefix('w')
                .and_then(|n| n.parse::<usize>().ok())
                .ok_or_else(|| format!("fault `{part}`: worker must be wN or *"))?,
        ),
        None => return Err(format!("fault `{part}` missing worker field")),
    };
    let seq = fields
        .next()
        .and_then(|s| s.strip_prefix('s'))
        .and_then(|n| n.parse::<u64>().ok())
        .ok_or_else(|| format!("fault `{part}`: shard must be sN"))?;
    let duration = match fields.next() {
        Some(d) => Some(
            d.strip_suffix("ms")
                .and_then(|n| n.parse::<u64>().ok())
                .map(Duration::from_millis)
                .ok_or_else(|| format!("fault `{part}`: duration must be <N>ms"))?,
        ),
        None => None,
    };
    if fields.next().is_some() {
        return Err(format!("fault `{part}`: too many fields"));
    }
    let action = match (kind, duration) {
        ("panic", None) => FaultAction::Panic,
        ("drop", None) => FaultAction::DropReplies,
        ("panic" | "drop", Some(_)) => {
            return Err(format!("fault `{part}`: {kind} takes no duration"))
        }
        ("stall", Some(d)) => FaultAction::Stall(d),
        ("slow", Some(d)) => FaultAction::SlowReply(d),
        ("stall" | "slow", None) => {
            return Err(format!("fault `{part}`: {kind} needs a :<N>ms duration"))
        }
        _ => return Err(format!("fault `{part}`: unknown kind `{kind}`")),
    };
    Ok(FaultEntry { worker, seq, action })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_kind() {
        let plan =
            FaultPlan::parse("panic@w0:s2, stall@w1:s3:500ms, slow@*:s5:20ms, drop@w0:s7", 2)
                .unwrap();
        assert_eq!(plan.action(0, 2), Some(FaultAction::Panic));
        assert_eq!(plan.action(1, 2), None, "panic is worker-addressed");
        assert_eq!(
            plan.action(1, 3),
            Some(FaultAction::Stall(Duration::from_millis(500)))
        );
        assert_eq!(
            plan.action(0, 5),
            Some(FaultAction::SlowReply(Duration::from_millis(20))),
            "wildcard matches worker 0"
        );
        assert_eq!(
            plan.action(7, 5),
            Some(FaultAction::SlowReply(Duration::from_millis(20))),
            "wildcard matches any worker"
        );
        assert_eq!(plan.action(0, 7), Some(FaultAction::DropReplies));
        assert_eq!(plan.action(0, 0), None);
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "panic",             // no @
            "panic@x0:s1",       // bad worker
            "panic@w0:3",        // shard missing s prefix
            "panic@w0:s1:10ms",  // panic takes no duration
            "stall@w0:s1",       // stall needs a duration
            "stall@w0:s1:10s",   // wrong unit
            "melt@w0:s1",        // unknown kind
            "slow@w0:s1:1ms:x",  // trailing field
            "kill-each:banana",  // bad seed
        ] {
            assert!(FaultPlan::parse(bad, 2).is_err(), "`{bad}` must be rejected");
        }
    }

    #[test]
    fn kill_each_is_seed_deterministic_and_covers_every_worker() {
        let a = FaultPlan::kill_each_worker_once(3, 0xC0FFEE);
        let b = FaultPlan::kill_each_worker_once(3, 0xC0FFEE);
        assert_eq!(a, b, "same seed, same plan — bit for bit");
        assert_ne!(a, FaultPlan::kill_each_worker_once(3, 1), "seed matters");
        for w in 0..3 {
            let seq = (0..8).find(|&s| a.action(w, s) == Some(FaultAction::Panic));
            let seq = seq.expect("every worker is scheduled to die once");
            assert!((1..4).contains(&seq), "kill lands early: seq {seq}");
            assert_eq!(
                (0..8).filter(|&s| a.action(w, s).is_some()).count(),
                1,
                "exactly one fault per worker"
            );
        }
        // the macro parses through the CLI grammar too
        let via_spec = FaultPlan::parse("kill-each:12648430", 3).unwrap();
        assert_eq!(via_spec, a, "spec form resolves to the same plan");
    }

    #[test]
    fn describe_round_trips_through_parse() {
        let plan =
            FaultPlan::parse("panic@w0:s2,stall@w1:s3:500ms,slow@*:s5:20ms,drop@w0:s7", 2)
                .unwrap();
        let spec = plan.describe().join(",");
        assert_eq!(FaultPlan::parse(&spec, 2).unwrap(), plan);
    }

    #[test]
    fn empty_plan_never_fires() {
        let plan = FaultPlan::none();
        assert!(plan.is_empty());
        for w in 0..4 {
            for s in 0..16 {
                assert_eq!(plan.action(w, s), None);
            }
        }
    }
}

//! Chunk partitioning and slot scheduling (§3.2, Fig. 2).
//!
//! A layer's `out_dim × in_dim` weight matrix is zero-padded to a p×q grid
//! of `rk1 × ck2` chunks. The accelerator holds `R·C/(r·c)` chunk *slots*
//! at a time; executing one chunk against one input vector costs one cycle
//! regardless of its sparsity (the paper's fixed-cycle clarification), so
//! a layer with `n_cols` activation vectors takes
//! `ceil(p·q / slots) · n_cols` wall cycles.

use crate::AcceleratorConfig;

/// Where one chunk lands: the slot index and its (tile, core) rectangle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkAssignment {
    pub pi: usize,
    pub qi: usize,
    /// Slot index in 0..slots.
    pub slot: usize,
    /// Wave index: chunks with the same wave execute concurrently.
    pub wave: usize,
}

/// Static schedule for one layer.
#[derive(Debug, Clone)]
pub struct LayerSchedule {
    pub out_dim: usize,
    pub in_dim: usize,
    /// Chunk-grid dims.
    pub p: usize,
    pub q: usize,
    /// Chunk dims.
    pub chunk_rows: usize,
    pub chunk_cols: usize,
    pub assignments: Vec<ChunkAssignment>,
    pub slots: usize,
}

impl LayerSchedule {
    pub fn n_waves(&self) -> usize {
        self.assignments.iter().map(|a| a.wave + 1).max().unwrap_or(0)
    }

    /// Wall cycles to stream `n_cols` activation vectors through the layer.
    pub fn wall_cycles(&self, n_cols: usize) -> u64 {
        (self.n_waves() * n_cols) as u64
    }

    /// Per-chunk cycles for the same workload (for Eq.-style E_tot sums).
    pub fn chunk_cycles(&self, n_cols: usize) -> u64 {
        n_cols as u64
    }
}

/// The chunk scheduler bound to an accelerator configuration.
#[derive(Debug, Clone)]
pub struct Scheduler {
    pub cfg: AcceleratorConfig,
}

impl Scheduler {
    pub fn new(cfg: AcceleratorConfig) -> Self {
        Self { cfg }
    }

    /// Number of simultaneous chunk slots.
    pub fn slots(&self) -> usize {
        self.cfg.n_cores() / (self.cfg.share_r * self.cfg.share_c)
    }

    /// Build the schedule for a matmul of shape `out_dim × in_dim`.
    pub fn schedule(&self, out_dim: usize, in_dim: usize) -> LayerSchedule {
        let (rows, cols) = self.cfg.chunk_shape();
        let p = out_dim.div_ceil(rows);
        let q = in_dim.div_ceil(cols);
        let slots = self.slots().max(1);
        let mut assignments = Vec::with_capacity(p * q);
        for pi in 0..p {
            for qi in 0..q {
                let linear = pi * q + qi;
                assignments.push(ChunkAssignment {
                    pi,
                    qi,
                    slot: linear % slots,
                    wave: linear / slots,
                });
            }
        }
        LayerSchedule {
            out_dim,
            in_dim,
            p,
            q,
            chunk_rows: rows,
            chunk_cols: cols,
            assignments,
            slots,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AcceleratorConfig {
        AcceleratorConfig::default() // R=C=4, r=c=4, 16x16 -> 1 slot of 64x64
    }

    #[test]
    fn slot_count() {
        let s = Scheduler::new(cfg());
        assert_eq!(s.slots(), 1);
        let s = Scheduler::new(AcceleratorConfig {
            share_r: 1,
            share_c: 1,
            ..AcceleratorConfig::default()
        });
        assert_eq!(s.slots(), 16);
    }

    #[test]
    fn chunk_grid_covers_matrix() {
        let s = Scheduler::new(cfg());
        let sched = s.schedule(100, 130); // chunks are 64x64
        assert_eq!((sched.p, sched.q), (2, 3));
        assert_eq!(sched.assignments.len(), 6);
        assert!(sched.p * sched.chunk_rows >= 100);
        assert!(sched.q * sched.chunk_cols >= 130);
    }

    #[test]
    fn waves_respect_slot_capacity() {
        let s = Scheduler::new(AcceleratorConfig {
            share_r: 2,
            share_c: 2,
            ..AcceleratorConfig::default()
        }); // 16 cores / 4 = 4 slots, chunks are 32x32
        let sched = s.schedule(64, 96); // p=2, q=3 -> 6 chunks, 4 slots
        assert_eq!(sched.n_waves(), 2);
        // no wave uses a slot twice
        for w in 0..sched.n_waves() {
            let mut used = vec![false; sched.slots];
            for a in sched.assignments.iter().filter(|a| a.wave == w) {
                assert!(!used[a.slot], "slot reuse within a wave");
                used[a.slot] = true;
            }
        }
    }

    #[test]
    fn wall_cycles_scale_with_waves_and_cols() {
        let s = Scheduler::new(cfg());
        let sched = s.schedule(128, 64); // p=2,q=1, 1 slot -> 2 waves
        assert_eq!(sched.wall_cycles(100), 200);
        assert_eq!(sched.chunk_cycles(100), 100);
    }

    #[test]
    fn exact_fit_no_padding_waste() {
        let s = Scheduler::new(cfg());
        let sched = s.schedule(64, 64);
        assert_eq!((sched.p, sched.q), (1, 1));
        assert_eq!(sched.n_waves(), 1);
    }
}

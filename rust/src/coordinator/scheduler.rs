//! Scheduling at two scales.
//!
//! **Chunk scale** (§3.2, Fig. 2): a layer's `out_dim × in_dim` weight
//! matrix is zero-padded to a p×q grid of `rk1 × ck2` chunks. The
//! accelerator holds `R·C/(r·c)` chunk *slots* at a time; executing one
//! chunk against one input vector costs one cycle regardless of its
//! sparsity (the paper's fixed-cycle clarification), so a layer with
//! `n_cols` activation vectors takes `ceil(p·q / slots) · n_cols` wall
//! cycles. [`Scheduler`]/[`LayerSchedule`] model this.
//!
//! **Cluster scale**: the serving dispatcher routes request batches
//! across N engine-worker replicas. Each replica exposes a load/thermal
//! summary ([`ReplicaState`]); [`plan_shards`] splits a batch across
//! the coolest, least-loaded replicas. Thermal state is a scheduling
//! dimension unique to photonics — replicas heat independently, so the
//! router steers around a replica while it recalibrates (the brownout
//! `hot` bit) and, among cool replicas, minimizes the continuous heat
//! score so load drifts toward thermally settled hardware *before*
//! anyone trips a brownout.

use crate::exec::partition_ranges;
use crate::AcceleratorConfig;
use std::ops::Range;

/// Where one chunk lands: the slot index and its (tile, core) rectangle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkAssignment {
    pub pi: usize,
    pub qi: usize,
    /// Slot index in 0..slots.
    pub slot: usize,
    /// Wave index: chunks with the same wave execute concurrently.
    pub wave: usize,
}

/// Static schedule for one layer.
#[derive(Debug, Clone)]
pub struct LayerSchedule {
    pub out_dim: usize,
    pub in_dim: usize,
    /// Chunk-grid dims.
    pub p: usize,
    pub q: usize,
    /// Chunk dims.
    pub chunk_rows: usize,
    pub chunk_cols: usize,
    pub assignments: Vec<ChunkAssignment>,
    pub slots: usize,
}

impl LayerSchedule {
    pub fn n_waves(&self) -> usize {
        self.assignments.iter().map(|a| a.wave + 1).max().unwrap_or(0)
    }

    /// Wall cycles to stream `n_cols` activation vectors through the layer.
    pub fn wall_cycles(&self, n_cols: usize) -> u64 {
        (self.n_waves() * n_cols) as u64
    }

    /// Per-chunk cycles for the same workload (for Eq.-style E_tot sums).
    pub fn chunk_cycles(&self, n_cols: usize) -> u64 {
        n_cols as u64
    }
}

/// The chunk scheduler bound to an accelerator configuration.
#[derive(Debug, Clone)]
pub struct Scheduler {
    pub cfg: AcceleratorConfig,
}

impl Scheduler {
    pub fn new(cfg: AcceleratorConfig) -> Self {
        Self { cfg }
    }

    /// Number of simultaneous chunk slots.
    pub fn slots(&self) -> usize {
        self.cfg.n_cores() / (self.cfg.share_r * self.cfg.share_c)
    }

    /// Build the schedule for a matmul of shape `out_dim × in_dim`.
    pub fn schedule(&self, out_dim: usize, in_dim: usize) -> LayerSchedule {
        let (rows, cols) = self.cfg.chunk_shape();
        let p = out_dim.div_ceil(rows);
        let q = in_dim.div_ceil(cols);
        let slots = self.slots().max(1);
        let mut assignments = Vec::with_capacity(p * q);
        for pi in 0..p {
            for qi in 0..q {
                let linear = pi * q + qi;
                assignments.push(ChunkAssignment {
                    pi,
                    qi,
                    slot: linear % slots,
                    wave: linear / slots,
                });
            }
        }
        LayerSchedule {
            out_dim,
            in_dim,
            p,
            q,
            chunk_rows: rows,
            chunk_cols: cols,
            assignments,
            slots,
        }
    }
}

/// Cluster-scheduler knobs carried by
/// [`crate::coordinator::ServerConfig`].
#[derive(Debug, Clone, Default)]
pub struct ClusterConfig {
    /// Allow idle replicas to steal queued shards from loaded ones.
    /// Off by default: stealing trades strict per-replica shard
    /// ordering for tail latency, and deterministic fault schedules
    /// (seeded `FaultPlan`s keyed on per-replica sequence numbers)
    /// want the strict order.
    pub steal: bool,
}

/// The router's view of one replica at dispatch time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplicaState {
    /// Worker-slot index (stable across respawns).
    pub idx: usize,
    /// Shards enqueued or executing on this replica.
    pub queue_depth: u64,
    /// EWMA shard service time in microseconds (0 = no sample yet).
    pub ewma_us: u64,
    /// Device-health score: 0 = healthy fabric, higher = degraded (an
    /// unrepairable device fault the sentinel could not quarantine).
    /// Ranked right after load, so a degraded replica only serves when
    /// its queue is strictly shallower than every healthy peer's.
    pub health: u64,
    /// Continuous thermal score in milliradians of accumulated phase
    /// error; the router minimizes this among cool replicas.
    pub heat_milli: u64,
    /// Browned out: phase error past the brownout budget, replica is
    /// recalibrating. Excluded from routing while any peer is cool.
    pub hot: bool,
}

impl ReplicaState {
    /// An idle, cold, healthy replica — the state every slot starts in.
    pub fn idle(idx: usize) -> Self {
        Self { idx, queue_depth: 0, health: 0, ewma_us: 0, heat_milli: 0, hot: false }
    }
}

/// Rank key: load first (queue depth, then device health, then expected
/// service time via the heat-then-EWMA tie-break), index last so ties
/// break deterministically toward lower slot numbers.
fn rank(r: &ReplicaState) -> (u64, u64, u64, u64, usize) {
    (r.queue_depth, r.health, r.heat_milli, r.ewma_us, r.idx)
}

/// Split a batch of `n` requests into per-replica shards.
///
/// Cool replicas split the batch near-equally, assigned best-ranked
/// first (so when the batch is smaller than the pool, the coolest,
/// least-loaded replicas serve it). If *every* replica is browned out
/// there is nowhere cool to steer, so the batch degrades to
/// `max(1, max_batch/2)`-sized shards dealt round-robin — each
/// recalibration pause then blocks half a batch instead of a full one.
pub fn plan_shards(
    n: usize,
    replicas: &[ReplicaState],
    max_batch: usize,
) -> Vec<(usize, Range<usize>)> {
    if n == 0 || replicas.is_empty() {
        return Vec::new();
    }
    let mut cool: Vec<&ReplicaState> = replicas.iter().filter(|r| !r.hot).collect();
    if !cool.is_empty() {
        cool.sort_by_key(|r| rank(r));
        return partition_ranges(n, cool.len())
            .into_iter()
            .zip(cool)
            .map(|(range, r)| (r.idx, range))
            .collect();
    }
    let mut order: Vec<&ReplicaState> = replicas.iter().collect();
    order.sort_by_key(|r| rank(r));
    let half = (max_batch / 2).max(1);
    let mut plan = Vec::new();
    let (mut start, mut i) = (0, 0);
    while start < n {
        let end = (start + half).min(n);
        plan.push((order[i % order.len()].idx, start..end));
        start = end;
        i += 1;
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AcceleratorConfig {
        AcceleratorConfig::default() // R=C=4, r=c=4, 16x16 -> 1 slot of 64x64
    }

    #[test]
    fn slot_count() {
        let s = Scheduler::new(cfg());
        assert_eq!(s.slots(), 1);
        let s = Scheduler::new(AcceleratorConfig {
            share_r: 1,
            share_c: 1,
            ..AcceleratorConfig::default()
        });
        assert_eq!(s.slots(), 16);
    }

    #[test]
    fn chunk_grid_covers_matrix() {
        let s = Scheduler::new(cfg());
        let sched = s.schedule(100, 130); // chunks are 64x64
        assert_eq!((sched.p, sched.q), (2, 3));
        assert_eq!(sched.assignments.len(), 6);
        assert!(sched.p * sched.chunk_rows >= 100);
        assert!(sched.q * sched.chunk_cols >= 130);
    }

    #[test]
    fn waves_respect_slot_capacity() {
        let s = Scheduler::new(AcceleratorConfig {
            share_r: 2,
            share_c: 2,
            ..AcceleratorConfig::default()
        }); // 16 cores / 4 = 4 slots, chunks are 32x32
        let sched = s.schedule(64, 96); // p=2, q=3 -> 6 chunks, 4 slots
        assert_eq!(sched.n_waves(), 2);
        // no wave uses a slot twice
        for w in 0..sched.n_waves() {
            let mut used = vec![false; sched.slots];
            for a in sched.assignments.iter().filter(|a| a.wave == w) {
                assert!(!used[a.slot], "slot reuse within a wave");
                used[a.slot] = true;
            }
        }
    }

    #[test]
    fn wall_cycles_scale_with_waves_and_cols() {
        let s = Scheduler::new(cfg());
        let sched = s.schedule(128, 64); // p=2,q=1, 1 slot -> 2 waves
        assert_eq!(sched.wall_cycles(100), 200);
        assert_eq!(sched.chunk_cycles(100), 100);
    }

    #[test]
    fn exact_fit_no_padding_waste() {
        let s = Scheduler::new(cfg());
        let sched = s.schedule(64, 64);
        assert_eq!((sched.p, sched.q), (1, 1));
        assert_eq!(sched.n_waves(), 1);
    }

    #[test]
    fn equal_replicas_partition_in_index_order() {
        let pool: Vec<ReplicaState> = (0..3).map(ReplicaState::idle).collect();
        assert_eq!(
            plan_shards(6, &pool, 8),
            vec![(0, 0..2), (1, 2..4), (2, 4..6)],
            "ties split near-equally in index order"
        );
        // a single request lands on the lowest index, never an empty shard
        assert_eq!(plan_shards(1, &pool, 8), vec![(0, 0..1)]);
        assert!(plan_shards(0, &pool, 8).is_empty());
        assert!(plan_shards(4, &[], 8).is_empty());
    }

    #[test]
    fn hot_replicas_are_excluded_while_any_peer_is_cool() {
        let mut pool: Vec<ReplicaState> = (0..3).map(ReplicaState::idle).collect();
        pool[1].hot = true;
        let plan = plan_shards(6, &pool, 8);
        assert_eq!(plan, vec![(0, 0..3), (2, 3..6)], "hot replica receives nothing");
    }

    #[test]
    fn all_hot_pool_degrades_to_half_batches_round_robin() {
        let mut pool: Vec<ReplicaState> = (0..2).map(ReplicaState::idle).collect();
        for r in &mut pool {
            r.hot = true;
        }
        let plan = plan_shards(6, &pool, 8);
        assert_eq!(plan.len(), 2, "half-batches of max(1, 8/2)=4");
        assert_eq!(plan[0], (0, 0..4));
        assert_eq!(plan[1], (1, 4..6));
        // max_batch 1 must not wedge into zero-sized shards
        let plan = plan_shards(2, &pool, 1);
        assert_eq!(plan, vec![(0, 0..1), (1, 1..2)]);
    }

    #[test]
    fn load_routes_around_deep_queues_and_heat() {
        let mut pool: Vec<ReplicaState> = (0..3).map(ReplicaState::idle).collect();
        pool[0].queue_depth = 2;
        let plan = plan_shards(1, &pool, 8);
        assert_eq!(plan, vec![(1, 0..1)], "deepest queue is ranked last");

        // equal depth: the cooler replica wins
        let mut pool: Vec<ReplicaState> = (0..2).map(ReplicaState::idle).collect();
        pool[0].heat_milli = 40;
        assert_eq!(plan_shards(1, &pool, 8), vec![(1, 0..1)]);

        // equal depth and heat: the faster replica (lower EWMA) wins
        let mut pool: Vec<ReplicaState> = (0..2).map(ReplicaState::idle).collect();
        pool[0].ewma_us = 900;
        pool[1].ewma_us = 200;
        assert_eq!(plan_shards(1, &pool, 8), vec![(1, 0..1)]);
    }

    #[test]
    fn degraded_health_down_ranks_next_to_heat() {
        // equal load: the healthy replica wins even when it is hotter
        let mut pool: Vec<ReplicaState> = (0..2).map(ReplicaState::idle).collect();
        pool[0].health = 1;
        pool[1].heat_milli = 80;
        assert_eq!(plan_shards(1, &pool, 8), vec![(1, 0..1)]);

        // but health ranks below load: a degraded idle replica still
        // beats a healthy one with a deep queue (it serves, just last)
        let mut pool: Vec<ReplicaState> = (0..2).map(ReplicaState::idle).collect();
        pool[0].health = 1;
        pool[1].queue_depth = 2;
        assert_eq!(plan_shards(1, &pool, 8), vec![(0, 0..1)]);

        // an all-degraded pool keeps serving (graceful degradation)
        let mut pool: Vec<ReplicaState> = (0..2).map(ReplicaState::idle).collect();
        for r in &mut pool {
            r.health = 1;
        }
        let covered: usize = plan_shards(4, &pool, 8).iter().map(|(_, r)| r.len()).sum();
        assert_eq!(covered, 4);
    }

    #[test]
    fn planning_is_deterministic() {
        let mut pool: Vec<ReplicaState> = (0..4).map(ReplicaState::idle).collect();
        pool[2].queue_depth = 1;
        pool[3].heat_milli = 7;
        let a = plan_shards(9, &pool, 8);
        let b = plan_shards(9, &pool, 8);
        assert_eq!(a, b);
        let covered: usize = a.iter().map(|(_, r)| r.len()).sum();
        assert_eq!(covered, 9, "every request is assigned exactly once");
    }
}

//! Readiness polling for the HTTP reactor, dependency-free.
//!
//! On Linux this wraps the raw `epoll` syscalls via `extern "C"`
//! declarations — the symbols live in the libc that `std` already
//! links, so no crate dependency is needed. Everything OS-specific
//! hides behind [`Poller`]: register a file descriptor with a `u64`
//! token and an [`Interest`], then [`Poller::wait`] returns the tokens
//! that are readable/writable. Level-triggered semantics throughout —
//! a ready fd keeps reporting until drained, which pairs naturally
//! with "read until `WouldBlock`" nonblocking IO.
//!
//! On non-Linux targets a portable fallback reports every registered
//! token as ready after a short sleep. That degrades the reactor to a
//! poll loop — spurious readiness is harmless against nonblocking
//! sockets — so the serving stack still works, just without the
//! 10k-connection scaling property the epoll backend provides.

use std::io;
use std::time::Duration;

/// Which readiness edges a registration subscribes to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const READ: Interest = Interest { readable: true, writable: false };
    pub const READ_WRITE: Interest = Interest { readable: true, writable: true };
}

/// One readiness report from [`Poller::wait`].
#[derive(Debug, Clone, Copy)]
pub struct PollEvent {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    /// Peer hung up or the fd errored; treat as readable-to-EOF.
    pub hangup: bool,
}

#[cfg(target_os = "linux")]
pub use linux::Poller;
#[cfg(not(target_os = "linux"))]
pub use portable::Poller;

#[cfg(target_os = "linux")]
mod linux {
    use super::{Interest, PollEvent};
    use std::io;
    use std::os::fd::RawFd;
    use std::time::Duration;

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;

    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: i32 = 0x80000;

    /// Kernel ABI struct; packed on x86-64 (the kernel's layout), the
    /// natural `repr(C)` everywhere else — matching libc's definition.
    #[cfg_attr(any(target_arch = "x86_64", target_arch = "x86"), repr(C, packed))]
    #[cfg_attr(not(any(target_arch = "x86_64", target_arch = "x86")), repr(C))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout_ms: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    /// epoll-backed readiness queue.
    #[derive(Debug)]
    pub struct Poller {
        epfd: i32,
    }

    // The epoll fd is a plain kernel handle; ctl/wait are thread-safe.
    unsafe impl Send for Poller {}
    unsafe impl Sync for Poller {}

    fn mask_of(interest: Interest) -> u32 {
        let mut mask = EPOLLRDHUP;
        if interest.readable {
            mask |= EPOLLIN;
        }
        if interest.writable {
            mask |= EPOLLOUT;
        }
        mask
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller { epfd })
        }

        fn ctl(&self, op: i32, fd: RawFd, event: Option<EpollEvent>) -> io::Result<()> {
            let mut ev = event.unwrap_or(EpollEvent { events: 0, data: 0 });
            let ptr = if event.is_some() { &mut ev as *mut EpollEvent } else { std::ptr::null_mut() };
            let rc = unsafe { epoll_ctl(self.epfd, op, fd, ptr) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn register(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, Some(EpollEvent { events: mask_of(interest), data: token }))
        }

        pub fn modify(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, Some(EpollEvent { events: mask_of(interest), data: token }))
        }

        pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, None)
        }

        /// Block up to `timeout` for readiness; ready tokens are
        /// appended to `events` (cleared first). Interrupted waits
        /// (`EINTR`) report as an empty round, not an error.
        pub fn wait(&self, events: &mut Vec<PollEvent>, timeout: Duration) -> io::Result<()> {
            events.clear();
            let mut raw = [EpollEvent { events: 0, data: 0 }; 128];
            let timeout_ms = timeout.as_millis().min(i32::MAX as u128) as i32;
            let n = unsafe {
                epoll_wait(self.epfd, raw.as_mut_ptr(), raw.len() as i32, timeout_ms)
            };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(err);
            }
            for ev in raw.iter().take(n as usize) {
                let bits = ev.events;
                events.push(PollEvent {
                    token: ev.data,
                    readable: bits & (EPOLLIN | EPOLLRDHUP) != 0,
                    writable: bits & EPOLLOUT != 0,
                    hangup: bits & (EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.epfd);
            }
        }
    }
}

#[cfg(not(target_os = "linux"))]
mod portable {
    use super::{Interest, PollEvent};
    use std::collections::BTreeMap;
    use std::io;
    use std::sync::Mutex;
    use std::time::Duration;

    type RawFd = i32;

    /// Portable fallback: every registered token reports ready each
    /// round after a short sleep. Spurious readiness only costs a
    /// `WouldBlock` per idle socket.
    #[derive(Debug, Default)]
    pub struct Poller {
        tokens: Mutex<BTreeMap<RawFd, u64>>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller::default())
        }

        pub fn register(&self, fd: RawFd, token: u64, _interest: Interest) -> io::Result<()> {
            self.tokens.lock().unwrap().insert(fd, token);
            Ok(())
        }

        pub fn modify(&self, fd: RawFd, token: u64, _interest: Interest) -> io::Result<()> {
            self.tokens.lock().unwrap().insert(fd, token);
            Ok(())
        }

        pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
            self.tokens.lock().unwrap().remove(&fd);
            Ok(())
        }

        pub fn wait(&self, events: &mut Vec<PollEvent>, timeout: Duration) -> io::Result<()> {
            events.clear();
            std::thread::sleep(timeout.min(Duration::from_millis(5)));
            for (_, &token) in self.tokens.lock().unwrap().iter() {
                events.push(PollEvent { token, readable: true, writable: true, hangup: false });
            }
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};

    #[cfg(target_os = "linux")]
    use std::os::fd::AsRawFd;
    #[cfg(not(target_os = "linux"))]
    trait AsRawFd {
        fn as_raw_fd(&self) -> i32;
    }
    #[cfg(not(target_os = "linux"))]
    impl<T> AsRawFd for T {
        fn as_raw_fd(&self) -> i32 {
            0
        }
    }

    #[test]
    fn reports_readable_when_bytes_arrive() {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).expect("connect");
        let (server, _) = listener.accept().expect("accept");
        server.set_nonblocking(true).expect("nonblocking");

        let poller = Poller::new().expect("poller");
        poller.register(server.as_raw_fd(), 7, Interest::READ).expect("register");

        let mut events = Vec::new();
        poller.wait(&mut events, Duration::from_millis(10)).expect("idle wait");
        assert!(
            events.iter().all(|e| e.token == 7),
            "only registered tokens may be reported"
        );

        client.write_all(b"ping").expect("write");
        client.flush().expect("flush");
        // readiness must arrive within a bounded number of rounds
        let mut saw_readable = false;
        for _ in 0..200 {
            poller.wait(&mut events, Duration::from_millis(10)).expect("wait");
            if events.iter().any(|e| e.token == 7 && e.readable) {
                saw_readable = true;
                break;
            }
        }
        assert!(saw_readable, "pending bytes must report readable");

        let mut srv = server;
        let mut buf = [0u8; 16];
        let n = srv.read(&mut buf).expect("read after readiness");
        assert_eq!(&buf[..n], b"ping");
        poller.deregister(srv.as_raw_fd()).expect("deregister");
    }

    #[test]
    fn writable_interest_reports_on_open_socket() {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().unwrap();
        let _client = TcpStream::connect(addr).expect("connect");
        let (server, _) = listener.accept().expect("accept");
        server.set_nonblocking(true).expect("nonblocking");

        let poller = Poller::new().expect("poller");
        poller
            .register(server.as_raw_fd(), 3, Interest::READ_WRITE)
            .expect("register");
        let mut events = Vec::new();
        let mut saw_writable = false;
        for _ in 0..200 {
            poller.wait(&mut events, Duration::from_millis(10)).expect("wait");
            if events.iter().any(|e| e.token == 3 && e.writable) {
                saw_writable = true;
                break;
            }
        }
        assert!(saw_writable, "an open socket with buffer space is writable");
    }
}

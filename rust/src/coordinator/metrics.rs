//! Latency / throughput metrics for the inference service.
//!
//! [`LatencyRecorder`] is the single-owner percentile ledger;
//! [`ServerMetrics`] is the thread-shared live counterpart the engine
//! workers write into and the HTTP front-end's `/metrics` endpoint reads
//! out of while the service is running (the shutdown [`ServerReport`]
//! used to be the only observable — a networked server must be
//! observable mid-flight).
//!
//! [`ServerReport`]: crate::coordinator::ServerReport

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Poison-tolerant lock: a metrics mutex is only ever held across a few
/// stores, so state behind a poisoned one is still consistent — and a
/// metrics read must never amplify an engine-worker panic into a
/// front-end panic.
fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Records request latencies and computes percentiles.
///
/// Samples are kept sorted lazily: a percentile query sorts at most
/// once after the last `record`, so a report reading several
/// percentiles pays one sort total (the previous implementation cloned
/// and re-sorted the full sample vector on *every* call). Insertion
/// order is not preserved — every statistic here is order-independent.
#[derive(Debug, Default, Clone)]
pub struct LatencyRecorder {
    samples_us: Vec<u64>,
    /// Length of `samples_us` when it was last sorted; `!= len()` means
    /// unsorted tail entries exist.
    sorted_len: usize,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
    }

    pub fn len(&self) -> usize {
        self.samples_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_us.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if self.sorted_len != self.samples_us.len() {
            self.samples_us.sort_unstable();
            self.sorted_len = self.samples_us.len();
        }
    }

    /// Percentile in microseconds (nearest-rank). Sorts only when new
    /// samples arrived since the last query.
    pub fn percentile_us(&mut self, p: f64) -> u64 {
        self.ensure_sorted();
        percentile_us_of(&self.samples_us, p)
    }

    pub fn mean_us(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<u64>() as f64 / self.samples_us.len() as f64
    }

    pub fn max_us(&self) -> u64 {
        self.samples_us.iter().copied().max().unwrap_or(0)
    }

    /// Fold another recorder's samples in (used when merging per-worker
    /// recorders into one server-wide report).
    pub fn merge(&mut self, other: &LatencyRecorder) {
        self.samples_us.extend_from_slice(&other.samples_us);
    }
}

/// Retained latency samples for the live percentile view: a sliding
/// window of the most recent requests, so a long-running server holds
/// bounded memory and `/metrics` scrapes sort a bounded set. Totals
/// (count, sum → mean, max) stay exact over the whole run. 64Ki samples
/// ≈ the last minute of traffic at 1k req/s.
const LATENCY_WINDOW: usize = 1 << 16;

/// Ring of the most recent latency samples (µs).
#[derive(Debug, Default)]
struct LatencyRing {
    samples_us: Vec<u64>,
    pos: usize,
}

impl LatencyRing {
    fn push(&mut self, us: u64) {
        if self.samples_us.len() < LATENCY_WINDOW {
            self.samples_us.push(us);
        } else {
            let p = self.pos;
            self.samples_us[p] = us;
        }
        self.pos = (self.pos + 1) % LATENCY_WINDOW;
    }
}

/// Nearest-rank percentile over an already-sorted window (the same
/// formula as [`LatencyRecorder::percentile_us`]).
fn percentile_us_of(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil().max(1.0) as usize - 1;
    sorted[rank.min(sorted.len() - 1)]
}

/// Live, thread-shared serving metrics: engine workers and the
/// dispatcher write, `/metrics` and the shutdown report read. Energy is
/// tracked per worker slot (each worker owns its engine ledger and
/// overwrites its cumulative snapshot after every shard), so readers sum
/// slots without contending with the hot path.
#[derive(Debug)]
pub struct ServerMetrics {
    latencies: Mutex<LatencyRing>,
    lat_sum_us: AtomicU64,
    lat_max_us: AtomicU64,
    served: AtomicUsize,
    batches: AtomicUsize,
    expired: AtomicU64,
    worker_lost: AtomicU64,
    /// Batch-occupancy histogram: per-bin counts of requests per
    /// dispatched dynamic batch (bin `i` covers occupancies in
    /// `(OCCUPANCY_BUCKETS[i-1], OCCUPANCY_BUCKETS[i]]`, the last bin is
    /// everything above the largest bound). Exposed cumulatively as the
    /// Prometheus `scatter_batch_occupancy` histogram.
    occupancy_bins: [AtomicU64; OCCUPANCY_BUCKETS.len() + 1],
    /// Σ occupancy over every dispatched batch (mean = sum / batches).
    occupancy_sum: AtomicU64,
    energy: Vec<Mutex<(f64, f64)>>, // per worker: cumulative (energy_mj, busy_ms)
    /// Per-worker thermal-drift gauges, overwritten after every tick.
    thermal: Vec<Mutex<ThermalGauges>>,
    /// Per-worker liveness (`scatter_worker_up`). A slot starts `true`
    /// — presumed live until the supervisor proves otherwise — so a
    /// freshly spawned server never reports a spurious degraded state.
    worker_up: Vec<AtomicBool>,
    /// Per-worker thermal-brownout flag (`scatter_brownout_active` is
    /// the count of set flags).
    worker_brownout: Vec<AtomicBool>,
    /// Worker respawns performed by the supervisor.
    worker_restarts: AtomicU64,
    /// Loss-driven request re-dispatches performed by the supervisor.
    request_retries: AtomicU64,
    /// Cumulative brownout entries across workers.
    brownouts: AtomicU64,
    /// Shards the cluster scheduler routed to each replica (the
    /// steering observable: a hot replica's share visibly drops).
    routed: Vec<AtomicU64>,
    /// Shards executed by a replica other than the one they were
    /// routed to (work stealing, when enabled).
    steals: AtomicU64,
    /// Per-replica heat score (milliradians of accumulated phase
    /// error), overwritten after every thermal tick.
    replica_heat_milli: Vec<AtomicU64>,
    /// Per-replica shard queue depth (enqueued + executing),
    /// overwritten by the dispatcher each supervision pass.
    replica_queue_depth: Vec<AtomicU64>,
    /// Per-replica active mask generation (`scatter_mask_generation`);
    /// 0 is the deployment baseline, hot-swapped artifacts carry the
    /// monotone ids stamped by the DST loop.
    mask_generation: Vec<AtomicU64>,
    /// Mask artifacts promoted by the hot-swap canary, across replicas.
    mask_swaps: AtomicU64,
    /// Mask artifacts rejected by the canary and rolled back.
    mask_rollbacks: AtomicU64,
    /// Rerouter hold-power estimate (mW) of the newest promoted
    /// artifact; the deployment baseline reports 0 (unknown).
    mask_power_mw: Mutex<f64>,
    /// Server start instant: `scatter_uptime_seconds`, and the epoch the
    /// fault injection/detection stamps below are measured from.
    started: Instant,
    /// Device-fault injections applied to engine fabrics.
    faults_injected: AtomicU64,
    /// µs after `started` of the first fault injection (0 = none yet).
    fault_injected_at_us: AtomicU64,
    /// Faulted chunks flagged by the sentinel probe.
    fault_detections: AtomicU64,
    /// µs after `started` of the first sentinel detection (0 = none yet).
    fault_detected_at_us: AtomicU64,
    /// Quarantine repairs promoted by the repair canary.
    fault_repairs: AtomicU64,
    /// Sentinel findings that could not be quarantined; each permanently
    /// degrades its replica.
    fault_unrepairable: AtomicU64,
    /// Per-replica degraded flag (unrepairable device fault).
    worker_degraded: Vec<AtomicBool>,
    /// Per-replica quarantined weight-cell gauge.
    quarantined_cells: Vec<AtomicU64>,
    /// Mask artifacts skipped by the startup artifact-dir scan
    /// (truncated, corrupt, or foreign files).
    artifacts_skipped: AtomicU64,
}

/// Upper bounds of the batch-occupancy histogram buckets (requests per
/// dynamic batch); occupancies above the last bound land in the
/// implicit `+Inf` bin.
pub const OCCUPANCY_BUCKETS: [usize; 5] = [1, 2, 4, 8, 16];

/// One engine worker's drift/recalibration gauges (zero when the drift
/// runtime is off). Built from a tick's
/// [`ThermalStatus`](crate::coordinator::engine::ThermalStatus) via
/// `From`, so publish sites cannot drift out of sync field-by-field.
#[derive(Debug, Default, Clone, Copy)]
pub struct ThermalGauges {
    /// Current drift envelope (rad).
    pub drift_rad: f64,
    /// Worst residual phase-error estimate across the worker's chunks.
    pub phase_error_rad: f64,
    /// Cumulative recalibration actions.
    pub recal_events: u64,
    /// Cumulative chunks recompiled by recalibration.
    pub recal_chunks: u64,
    /// Chunks under drift management on this worker.
    pub chunks_total: u64,
}

impl From<crate::coordinator::engine::ThermalStatus> for ThermalGauges {
    fn from(s: crate::coordinator::engine::ThermalStatus) -> Self {
        Self {
            drift_rad: s.env_rad,
            phase_error_rad: s.phase_error_rad,
            recal_events: s.recal_events,
            recal_chunks: s.recal_chunks,
            chunks_total: s.chunks_total,
        }
    }
}

impl ServerMetrics {
    pub fn new(workers: usize) -> Self {
        Self {
            latencies: Mutex::new(LatencyRing::default()),
            lat_sum_us: AtomicU64::new(0),
            lat_max_us: AtomicU64::new(0),
            served: AtomicUsize::new(0),
            batches: AtomicUsize::new(0),
            expired: AtomicU64::new(0),
            worker_lost: AtomicU64::new(0),
            occupancy_bins: Default::default(),
            occupancy_sum: AtomicU64::new(0),
            energy: (0..workers.max(1)).map(|_| Mutex::new((0.0, 0.0))).collect(),
            thermal: (0..workers.max(1)).map(|_| Mutex::new(ThermalGauges::default())).collect(),
            worker_up: (0..workers.max(1)).map(|_| AtomicBool::new(true)).collect(),
            worker_brownout: (0..workers.max(1)).map(|_| AtomicBool::new(false)).collect(),
            worker_restarts: AtomicU64::new(0),
            request_retries: AtomicU64::new(0),
            brownouts: AtomicU64::new(0),
            routed: (0..workers.max(1)).map(|_| AtomicU64::new(0)).collect(),
            steals: AtomicU64::new(0),
            replica_heat_milli: (0..workers.max(1)).map(|_| AtomicU64::new(0)).collect(),
            replica_queue_depth: (0..workers.max(1)).map(|_| AtomicU64::new(0)).collect(),
            mask_generation: (0..workers.max(1)).map(|_| AtomicU64::new(0)).collect(),
            mask_swaps: AtomicU64::new(0),
            mask_rollbacks: AtomicU64::new(0),
            mask_power_mw: Mutex::new(0.0),
            started: Instant::now(),
            faults_injected: AtomicU64::new(0),
            fault_injected_at_us: AtomicU64::new(0),
            fault_detections: AtomicU64::new(0),
            fault_detected_at_us: AtomicU64::new(0),
            fault_repairs: AtomicU64::new(0),
            fault_unrepairable: AtomicU64::new(0),
            worker_degraded: (0..workers.max(1)).map(|_| AtomicBool::new(false)).collect(),
            quarantined_cells: (0..workers.max(1)).map(|_| AtomicU64::new(0)).collect(),
            artifacts_skipped: AtomicU64::new(0),
        }
    }

    /// Stamp `slot` with "now" (µs after server start, min 1 so 0 keeps
    /// meaning "never") unless it was already stamped.
    fn stamp_first(&self, slot: &AtomicU64) {
        let now = (self.started.elapsed().as_micros() as u64).max(1);
        let _ = slot.compare_exchange(0, now, Ordering::AcqRel, Ordering::Acquire);
    }

    /// `n` device-fault injections applied to an engine fabric.
    pub fn note_faults_injected(&self, n: u64) {
        if n == 0 {
            return;
        }
        self.faults_injected.fetch_add(n, Ordering::Relaxed);
        self.stamp_first(&self.fault_injected_at_us);
    }

    /// `n` faulted chunks flagged by a sentinel probe.
    pub fn note_fault_detections(&self, n: u64) {
        if n == 0 {
            return;
        }
        self.fault_detections.fetch_add(n, Ordering::Relaxed);
        self.stamp_first(&self.fault_detected_at_us);
    }

    /// One quarantine repair promoted by the repair canary.
    pub fn note_fault_repair(&self) {
        self.fault_repairs.fetch_add(1, Ordering::Relaxed);
    }

    /// One sentinel finding that could not be quarantined.
    pub fn note_fault_unrepairable(&self) {
        self.fault_unrepairable.fetch_add(1, Ordering::Relaxed);
    }

    /// Set/clear replica `widx`'s degraded (unrepairable-fault) flag.
    pub fn set_worker_degraded(&self, widx: usize, on: bool) {
        if let Some(flag) = self.worker_degraded.get(widx) {
            flag.store(on, Ordering::Release);
        }
    }

    /// Overwrite replica `widx`'s quarantined weight-cell gauge.
    pub fn set_worker_quarantined_cells(&self, widx: usize, cells: u64) {
        if let Some(slot) = self.quarantined_cells.get(widx) {
            slot.store(cells, Ordering::Relaxed);
        }
    }

    /// `n` mask artifacts skipped by the startup artifact-dir scan.
    pub fn note_artifacts_skipped(&self, n: u64) {
        self.artifacts_skipped.fetch_add(n, Ordering::Relaxed);
    }

    /// Record one successfully served request.
    pub fn record_served(&self, latency: Duration) {
        let us = latency.as_micros() as u64;
        lock_clean(&self.latencies).push(us);
        self.lat_sum_us.fetch_add(us, Ordering::Relaxed);
        self.lat_max_us.fetch_max(us, Ordering::Relaxed);
        self.served.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_batch(&self) {
        self.batches.fetch_add(1, Ordering::Relaxed);
    }

    /// Record how many requests rode in one dispatched dynamic batch
    /// (called by the dispatcher alongside [`Self::note_batch`]).
    pub fn note_batch_occupancy(&self, n: usize) {
        let bin = OCCUPANCY_BUCKETS
            .iter()
            .position(|&b| n <= b)
            .unwrap_or(OCCUPANCY_BUCKETS.len());
        self.occupancy_bins[bin].fetch_add(1, Ordering::Relaxed);
        self.occupancy_sum.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Requests dropped because their deadline passed while queued.
    pub fn note_expired(&self, n: u64) {
        self.expired.fetch_add(n, Ordering::Relaxed);
    }

    /// Requests failed because their engine worker died.
    pub fn note_worker_lost(&self, n: u64) {
        self.worker_lost.fetch_add(n, Ordering::Relaxed);
    }

    /// Mark worker slot `widx` live (spawned/respawned) or down.
    pub fn set_worker_up(&self, widx: usize, up: bool) {
        if let Some(flag) = self.worker_up.get(widx) {
            flag.store(up, Ordering::Release);
        }
    }

    /// Set/clear worker `widx`'s thermal-brownout flag.
    pub fn set_worker_brownout(&self, widx: usize, on: bool) {
        if let Some(flag) = self.worker_brownout.get(widx) {
            flag.store(on, Ordering::Release);
        }
    }

    /// One supervisor respawn of a worker slot.
    pub fn note_worker_restart(&self) {
        self.worker_restarts.fetch_add(1, Ordering::Relaxed);
    }

    /// One loss-driven request re-dispatch.
    pub fn note_request_retry(&self) {
        self.request_retries.fetch_add(1, Ordering::Relaxed);
    }

    /// One brownout entry (a worker crossed its phase-error budget).
    pub fn note_brownout(&self) {
        self.brownouts.fetch_add(1, Ordering::Relaxed);
    }

    /// One shard routed to replica `widx` by the cluster scheduler.
    pub fn note_routed(&self, widx: usize) {
        if let Some(slot) = self.routed.get(widx) {
            slot.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// One shard stolen off another replica's queue.
    pub fn note_steal(&self) {
        self.steals.fetch_add(1, Ordering::Relaxed);
    }

    /// Overwrite replica `widx`'s heat score (milliradians).
    pub fn set_replica_heat(&self, widx: usize, milli: u64) {
        if let Some(slot) = self.replica_heat_milli.get(widx) {
            slot.store(milli, Ordering::Relaxed);
        }
    }

    /// Overwrite replica `widx`'s shard queue depth gauge.
    pub fn set_replica_queue_depth(&self, widx: usize, depth: u64) {
        if let Some(slot) = self.replica_queue_depth.get(widx) {
            slot.store(depth, Ordering::Relaxed);
        }
    }

    /// One mask artifact promoted by the hot-swap canary.
    pub fn note_mask_swap(&self) {
        self.mask_swaps.fetch_add(1, Ordering::Relaxed);
    }

    /// One mask artifact rejected by the canary and rolled back.
    pub fn note_mask_rollback(&self) {
        self.mask_rollbacks.fetch_add(1, Ordering::Relaxed);
    }

    /// Overwrite replica `widx`'s active mask generation gauge.
    pub fn set_mask_generation(&self, widx: usize, generation: u64) {
        if let Some(slot) = self.mask_generation.get(widx) {
            slot.store(generation, Ordering::Relaxed);
        }
    }

    /// Overwrite the promoted-artifact rerouter-power gauge (mW).
    pub fn set_mask_power_mw(&self, mw: f64) {
        *lock_clean(&self.mask_power_mw) = mw;
    }

    /// Overwrite worker `widx`'s cumulative energy ledger snapshot.
    pub fn set_worker_energy(&self, widx: usize, energy_mj: f64, busy_ms: f64) {
        if let Some(slot) = self.energy.get(widx) {
            *lock_clean(slot) = (energy_mj, busy_ms);
        }
    }

    /// Overwrite worker `widx`'s thermal-drift gauges after a tick.
    pub fn set_worker_thermal(&self, widx: usize, g: ThermalGauges) {
        if let Some(slot) = self.thermal.get(widx) {
            *lock_clean(slot) = g;
        }
    }

    /// Consistent-enough point-in-time view (each gauge is internally
    /// consistent; cross-gauge skew is bounded by one request).
    /// Percentiles cover the sliding [`LATENCY_WINDOW`]; count, mean,
    /// and max are exact over the whole run.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut window = lock_clean(&self.latencies).samples_us.clone();
        window.sort_unstable();
        let (energy_mj, busy_ms) = self
            .energy
            .iter()
            .map(|s| *lock_clean(s))
            .fold((0.0, 0.0), |(e, b), (de, db)| (e + de, b + db));
        // thermal: worst-case drift/error across workers, summed counters
        let mut thermal_drift_rad = 0.0f64;
        let mut thermal_phase_error_rad = 0.0f64;
        let (mut recalibrations, mut recal_chunks, mut thermal_chunks) = (0u64, 0u64, 0u64);
        for slot in &self.thermal {
            let g = *lock_clean(slot);
            if g.drift_rad.abs() > thermal_drift_rad.abs() {
                thermal_drift_rad = g.drift_rad;
            }
            thermal_phase_error_rad = thermal_phase_error_rad.max(g.phase_error_rad);
            recalibrations += g.recal_events;
            recal_chunks += g.recal_chunks;
            thermal_chunks += g.chunks_total;
        }
        let requests = self.served.load(Ordering::Relaxed);
        let mean_us = if requests > 0 {
            self.lat_sum_us.load(Ordering::Relaxed) as f64 / requests as f64
        } else {
            0.0
        };
        let batches = self.batches.load(Ordering::Relaxed);
        let mut batch_occupancy = [0u64; OCCUPANCY_BUCKETS.len() + 1];
        for (dst, bin) in batch_occupancy.iter_mut().zip(&self.occupancy_bins) {
            *dst = bin.load(Ordering::Relaxed);
        }
        let batch_occupancy_sum = self.occupancy_sum.load(Ordering::Relaxed);
        let occupancy_count: u64 = batch_occupancy.iter().sum();
        let worker_up: Vec<bool> =
            self.worker_up.iter().map(|f| f.load(Ordering::Acquire)).collect();
        let workers_live = worker_up.iter().filter(|&&up| up).count();
        let brownout_active = self
            .worker_brownout
            .iter()
            .filter(|f| f.load(Ordering::Acquire))
            .count();
        let worker_degraded: Vec<bool> =
            self.worker_degraded.iter().map(|f| f.load(Ordering::Acquire)).collect();
        let degraded_active = worker_degraded.iter().filter(|&&d| d).count();
        let injected_at = self.fault_injected_at_us.load(Ordering::Acquire);
        let detected_at = self.fault_detected_at_us.load(Ordering::Acquire);
        let fault_detection_latency_us = if injected_at > 0 && detected_at > 0 {
            detected_at.saturating_sub(injected_at)
        } else {
            0
        };
        MetricsSnapshot {
            uptime_s: self.started.elapsed().as_secs_f64(),
            faults_injected: self.faults_injected.load(Ordering::Relaxed),
            fault_detections: self.fault_detections.load(Ordering::Relaxed),
            fault_repairs: self.fault_repairs.load(Ordering::Relaxed),
            fault_unrepairable: self.fault_unrepairable.load(Ordering::Relaxed),
            fault_detection_latency_us,
            worker_degraded,
            degraded_active,
            quarantined_cells: self
                .quarantined_cells
                .iter()
                .map(|s| s.load(Ordering::Relaxed))
                .collect(),
            artifacts_skipped: self.artifacts_skipped.load(Ordering::Relaxed),
            workers_configured: worker_up.len(),
            workers_live,
            worker_up,
            brownout_active,
            worker_restarts: self.worker_restarts.load(Ordering::Relaxed),
            request_retries: self.request_retries.load(Ordering::Relaxed),
            brownouts_total: self.brownouts.load(Ordering::Relaxed),
            routed: self.routed.iter().map(|s| s.load(Ordering::Relaxed)).collect(),
            steals: self.steals.load(Ordering::Relaxed),
            replica_heat_milli: self
                .replica_heat_milli
                .iter()
                .map(|s| s.load(Ordering::Relaxed))
                .collect(),
            replica_queue_depth: self
                .replica_queue_depth
                .iter()
                .map(|s| s.load(Ordering::Relaxed))
                .collect(),
            mask_generation: self
                .mask_generation
                .iter()
                .map(|s| s.load(Ordering::Relaxed))
                .collect(),
            mask_swaps: self.mask_swaps.load(Ordering::Relaxed),
            mask_rollbacks: self.mask_rollbacks.load(Ordering::Relaxed),
            mask_power_mw: *self.mask_power_mw.lock().unwrap(),
            requests,
            batches,
            mean_batch_occupancy: if occupancy_count > 0 {
                batch_occupancy_sum as f64 / occupancy_count as f64
            } else {
                0.0
            },
            batch_occupancy,
            batch_occupancy_sum,
            expired: self.expired.load(Ordering::Relaxed),
            worker_lost: self.worker_lost.load(Ordering::Relaxed),
            mean_us,
            p50_us: percentile_us_of(&window, 50.0),
            p99_us: percentile_us_of(&window, 99.0),
            max_us: self.lat_max_us.load(Ordering::Relaxed),
            energy_mj,
            busy_ms,
            p_avg_w: if busy_ms > 0.0 { energy_mj / busy_ms } else { 0.0 },
            thermal_drift_rad,
            thermal_phase_error_rad,
            recalibrations,
            recal_chunks,
            thermal_chunks,
        }
    }
}

/// Point-in-time view of [`ServerMetrics`].
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    /// Seconds since the metrics registry (≈ the server) came up.
    pub uptime_s: f64,
    /// Device-fault injections applied to engine fabrics.
    pub faults_injected: u64,
    /// Faulted chunks flagged by the sentinel probe.
    pub fault_detections: u64,
    /// Quarantine repairs promoted by the repair canary.
    pub fault_repairs: u64,
    /// Sentinel findings that could not be quarantined.
    pub fault_unrepairable: u64,
    /// µs between the first fault injection and the first sentinel
    /// detection (0 until both have happened).
    pub fault_detection_latency_us: u64,
    /// Per-replica degraded flag (unrepairable device fault).
    pub worker_degraded: Vec<bool>,
    /// Replicas currently degraded.
    pub degraded_active: usize,
    /// Per-replica quarantined weight-cell gauge.
    pub quarantined_cells: Vec<u64>,
    /// Mask artifacts skipped by the startup artifact-dir scan.
    pub artifacts_skipped: u64,
    /// Worker slots the server was configured with.
    pub workers_configured: usize,
    /// Worker slots currently live (respawned as needed).
    pub workers_live: usize,
    /// Per-slot liveness, indexed by worker id.
    pub worker_up: Vec<bool>,
    /// Worker slots currently browned out (over phase-error budget).
    pub brownout_active: usize,
    /// Worker respawns performed by the supervisor.
    pub worker_restarts: u64,
    /// Loss-driven request re-dispatches performed by the supervisor.
    pub request_retries: u64,
    /// Cumulative brownout entries across workers.
    pub brownouts_total: u64,
    /// Shards routed to each replica by the cluster scheduler.
    pub routed: Vec<u64>,
    /// Shards executed away from their routed replica (work stealing).
    pub steals: u64,
    /// Per-replica heat score (milliradians of phase error).
    pub replica_heat_milli: Vec<u64>,
    /// Per-replica shard queue depth at the last supervision pass.
    pub replica_queue_depth: Vec<u64>,
    /// Per-replica active mask generation (0 = deployment baseline).
    pub mask_generation: Vec<u64>,
    /// Mask artifacts promoted by the hot-swap canary.
    pub mask_swaps: u64,
    /// Mask artifacts rejected by the canary and rolled back.
    pub mask_rollbacks: u64,
    /// Rerouter power estimate (mW) of the newest promoted artifact.
    pub mask_power_mw: f64,
    pub requests: usize,
    pub batches: usize,
    /// Per-bin batch-occupancy counts (bounds [`OCCUPANCY_BUCKETS`] plus
    /// the trailing `+Inf` bin), non-cumulative.
    pub batch_occupancy: [u64; OCCUPANCY_BUCKETS.len() + 1],
    /// Σ occupancy over every dispatched batch.
    pub batch_occupancy_sum: u64,
    /// Mean requests per dispatched dynamic batch (0 before traffic).
    pub mean_batch_occupancy: f64,
    pub expired: u64,
    pub worker_lost: u64,
    pub mean_us: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
    pub energy_mj: f64,
    pub busy_ms: f64,
    pub p_avg_w: f64,
    /// Worst drift envelope across workers (rad; 0 = runtime off).
    pub thermal_drift_rad: f64,
    /// Worst residual phase error across workers (rad).
    pub thermal_phase_error_rad: f64,
    /// Total recalibration actions across workers.
    pub recalibrations: u64,
    /// Total chunks recompiled by recalibration across workers.
    pub recal_chunks: u64,
    /// Total chunks under drift management across workers.
    pub thermal_chunks: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_nearest_rank() {
        let mut r = LatencyRecorder::new();
        for us in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
            r.record(Duration::from_micros(us));
        }
        assert_eq!(r.percentile_us(50.0), 50);
        assert_eq!(r.percentile_us(90.0), 90);
        assert_eq!(r.percentile_us(99.0), 100);
        assert_eq!(r.max_us(), 100);
        assert!((r.mean_us() - 55.0).abs() < 1e-9);
    }

    #[test]
    fn empty_recorder_zeroes() {
        let mut r = LatencyRecorder::new();
        assert_eq!(r.percentile_us(99.0), 0);
        assert_eq!(r.mean_us(), 0.0);
        assert!(r.is_empty());
    }

    /// The lazy-sort implementation must report exactly what the naive
    /// clone-and-sort-per-call one did, across interleaved records and
    /// queries (including re-querying without new samples).
    #[test]
    fn lazy_sort_percentiles_match_naive_clone_sort() {
        let naive = |samples: &[u64], p: f64| -> u64 {
            if samples.is_empty() {
                return 0;
            }
            let mut v = samples.to_vec();
            v.sort_unstable();
            let rank = ((p / 100.0) * v.len() as f64).ceil().max(1.0) as usize - 1;
            v[rank.min(v.len() - 1)]
        };
        let mut r = LatencyRecorder::new();
        let mut shadow: Vec<u64> = Vec::new();
        let mut state = 0x9E3779B97F4A7C15u64;
        for round in 0..50 {
            for _ in 0..=(round % 7) {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(round);
                let us = state >> 40;
                r.record(Duration::from_micros(us));
                shadow.push(us);
            }
            for p in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
                assert_eq!(r.percentile_us(p), naive(&shadow, p), "p={p} round={round}");
                // second query with no new samples: same answer
                assert_eq!(r.percentile_us(p), naive(&shadow, p));
            }
            assert_eq!(r.max_us(), *shadow.iter().max().unwrap());
            assert_eq!(r.len(), shadow.len());
        }
    }

    #[test]
    fn server_metrics_snapshot_sums_worker_energy() {
        let m = ServerMetrics::new(3);
        m.record_served(Duration::from_micros(100));
        m.record_served(Duration::from_micros(300));
        m.note_batch();
        m.note_expired(2);
        m.set_worker_energy(0, 1.5, 10.0);
        m.set_worker_energy(2, 0.5, 10.0);
        m.set_worker_energy(0, 2.0, 20.0); // cumulative overwrite, not add
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.batches, 1);
        assert_eq!(s.expired, 2);
        assert!((s.energy_mj - 2.5).abs() < 1e-12);
        assert!((s.p_avg_w - 2.5 / 30.0).abs() < 1e-12);
        assert_eq!(s.p50_us, 100);
        assert_eq!(s.p99_us, 300);
        assert!((s.mean_us - 200.0).abs() < 1e-9);
    }

    #[test]
    fn batch_occupancy_histogram_bins_and_mean() {
        let m = ServerMetrics::new(1);
        // bounds 1, 2, 4, 8, 16, +Inf — one batch per interesting edge
        for n in [1usize, 2, 3, 4, 5, 8, 16, 17, 40] {
            m.note_batch();
            m.note_batch_occupancy(n);
        }
        let s = m.snapshot();
        assert_eq!(s.batch_occupancy, [1, 1, 2, 2, 1, 2], "bins: 1|2|3-4|5-8|9-16|17+");
        assert_eq!(s.batch_occupancy_sum, 96);
        assert!((s.mean_batch_occupancy - 96.0 / 9.0).abs() < 1e-12);
        assert_eq!(s.batches, 9);
    }

    #[test]
    fn batch_occupancy_empty_is_zero() {
        let s = ServerMetrics::new(1).snapshot();
        assert_eq!(s.batch_occupancy, [0; 6]);
        assert_eq!(s.mean_batch_occupancy, 0.0);
    }

    #[test]
    fn latency_window_stays_bounded_and_slides() {
        let m = ServerMetrics::new(1);
        for i in 0..(LATENCY_WINDOW + 10) {
            m.record_served(Duration::from_micros(i as u64 + 1));
        }
        let s = m.snapshot();
        assert_eq!(s.requests, LATENCY_WINDOW + 10, "count stays exact past the window");
        assert_eq!(s.max_us, LATENCY_WINDOW as u64 + 10, "max stays exact");
        let ring = m.latencies.lock().unwrap();
        assert_eq!(ring.samples_us.len(), LATENCY_WINDOW, "memory bounded");
        // the 10 oldest samples (1..=10) were overwritten by the slide
        assert_eq!(*ring.samples_us.iter().min().unwrap(), 11);
    }

    #[test]
    fn thermal_gauges_aggregate_worst_case_and_sums() {
        let m = ServerMetrics::new(2);
        m.set_worker_thermal(
            0,
            ThermalGauges {
                drift_rad: -0.3,
                phase_error_rad: 0.01,
                recal_events: 2,
                recal_chunks: 5,
                chunks_total: 16,
            },
        );
        m.set_worker_thermal(
            1,
            ThermalGauges {
                drift_rad: 0.1,
                phase_error_rad: 0.04,
                recal_events: 1,
                recal_chunks: 3,
                chunks_total: 16,
            },
        );
        let s = m.snapshot();
        assert_eq!(s.thermal_drift_rad, -0.3, "max by magnitude, sign kept");
        assert_eq!(s.thermal_phase_error_rad, 0.04);
        assert_eq!(s.recalibrations, 3);
        assert_eq!(s.recal_chunks, 8);
        assert_eq!(s.thermal_chunks, 32);
    }

    #[test]
    fn out_of_range_worker_slot_ignored() {
        let m = ServerMetrics::new(1);
        m.set_worker_energy(5, 1.0, 1.0); // no panic
        m.set_worker_up(5, false);
        m.set_worker_brownout(5, true);
        let s = m.snapshot();
        assert_eq!(s.energy_mj, 0.0);
        assert_eq!(s.workers_live, 1, "out-of-range flags are ignored");
        assert_eq!(s.brownout_active, 0);
    }

    #[test]
    fn worker_up_gauge_tracks_supervision() {
        let m = ServerMetrics::new(3);
        let s = m.snapshot();
        assert_eq!(s.workers_configured, 3);
        assert_eq!(s.workers_live, 3, "slots are presumed live at spawn");
        assert_eq!(s.worker_up, vec![true, true, true]);
        m.set_worker_up(1, false);
        let s = m.snapshot();
        assert_eq!(s.workers_live, 2);
        assert_eq!(s.worker_up, vec![true, false, true]);
        m.set_worker_up(1, true); // respawned
        assert_eq!(m.snapshot().workers_live, 3);
    }

    #[test]
    fn routing_steal_and_heat_gauges_track_the_cluster() {
        let m = ServerMetrics::new(3);
        m.note_routed(0);
        m.note_routed(0);
        m.note_routed(2);
        m.note_steal();
        m.set_replica_heat(1, 42);
        m.set_replica_heat(1, 7); // gauge overwrites, not adds
        m.set_replica_queue_depth(2, 5);
        m.note_routed(9); // out-of-range slots are ignored
        m.set_replica_heat(9, 1);
        let s = m.snapshot();
        assert_eq!(s.routed, vec![2, 0, 1]);
        assert_eq!(s.steals, 1);
        assert_eq!(s.replica_heat_milli, vec![0, 7, 0]);
        assert_eq!(s.replica_queue_depth, vec![0, 0, 5]);
    }

    #[test]
    fn mask_swap_counters_and_generation_gauges() {
        let m = ServerMetrics::new(2);
        let s = m.snapshot();
        assert_eq!(s.mask_generation, vec![0, 0], "deployment baseline is generation 0");
        assert_eq!((s.mask_swaps, s.mask_rollbacks), (0, 0));
        assert_eq!(s.mask_power_mw, 0.0);
        m.note_mask_swap();
        m.set_mask_generation(0, 3);
        m.set_mask_power_mw(18.5);
        m.note_mask_rollback();
        m.set_mask_generation(1, 3);
        m.set_mask_generation(1, 2); // rollback overwrites, not max
        m.set_mask_generation(9, 7); // out-of-range slots are ignored
        let s = m.snapshot();
        assert_eq!(s.mask_generation, vec![3, 2]);
        assert_eq!(s.mask_swaps, 1);
        assert_eq!(s.mask_rollbacks, 1);
        assert!((s.mask_power_mw - 18.5).abs() < 1e-12);
    }

    #[test]
    fn fault_lifecycle_counters_and_detection_latency() {
        let m = ServerMetrics::new(2);
        let s = m.snapshot();
        assert_eq!(
            (s.faults_injected, s.fault_detections, s.fault_repairs, s.fault_unrepairable),
            (0, 0, 0, 0)
        );
        assert_eq!(s.fault_detection_latency_us, 0, "no stamps yet");
        assert_eq!(s.worker_degraded, vec![false, false]);
        assert!(s.uptime_s >= 0.0);

        m.note_fault_detections(0); // a clean probe must not stamp
        assert_eq!(m.snapshot().fault_detection_latency_us, 0);

        m.note_faults_injected(2);
        std::thread::sleep(Duration::from_millis(2));
        m.note_fault_detections(2);
        m.note_fault_detections(1); // later detections keep the first stamp
        m.note_fault_repair();
        m.note_fault_unrepairable();
        m.set_worker_degraded(1, true);
        m.set_worker_quarantined_cells(0, 3);
        m.set_worker_degraded(9, true); // out-of-range slots are ignored
        m.note_artifacts_skipped(4);
        let s = m.snapshot();
        assert_eq!(s.faults_injected, 2);
        assert_eq!(s.fault_detections, 3);
        assert_eq!(s.fault_repairs, 1);
        assert_eq!(s.fault_unrepairable, 1);
        assert!(s.fault_detection_latency_us >= 1_000, "detected after the injection");
        assert_eq!(s.worker_degraded, vec![false, true]);
        assert_eq!(s.degraded_active, 1);
        assert_eq!(s.quarantined_cells, vec![3, 0]);
        assert_eq!(s.artifacts_skipped, 4);
    }

    #[test]
    fn restart_retry_and_brownout_counters_accumulate() {
        let m = ServerMetrics::new(2);
        m.note_worker_restart();
        m.note_worker_restart();
        m.note_request_retry();
        m.note_brownout();
        m.set_worker_brownout(0, true);
        let s = m.snapshot();
        assert_eq!(s.worker_restarts, 2);
        assert_eq!(s.request_retries, 1);
        assert_eq!(s.brownouts_total, 1);
        assert_eq!(s.brownout_active, 1);
        m.set_worker_brownout(0, false); // cooled down: gauge clears,
        let s = m.snapshot(); // the cumulative counter does not
        assert_eq!(s.brownout_active, 0);
        assert_eq!(s.brownouts_total, 1);
    }
}

//! Latency / throughput metrics for the inference service.

use std::time::Duration;

/// Records request latencies and computes percentiles.
#[derive(Debug, Default, Clone)]
pub struct LatencyRecorder {
    samples_us: Vec<u64>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
    }

    pub fn len(&self) -> usize {
        self.samples_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_us.is_empty()
    }

    /// Percentile in microseconds (nearest-rank).
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.samples_us.is_empty() {
            return 0;
        }
        let mut v = self.samples_us.clone();
        v.sort_unstable();
        let rank = ((p / 100.0) * v.len() as f64).ceil().max(1.0) as usize - 1;
        v[rank.min(v.len() - 1)]
    }

    pub fn mean_us(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<u64>() as f64 / self.samples_us.len() as f64
    }

    pub fn max_us(&self) -> u64 {
        self.samples_us.iter().copied().max().unwrap_or(0)
    }

    /// Fold another recorder's samples in (used when merging per-worker
    /// recorders into one server-wide report).
    pub fn merge(&mut self, other: &LatencyRecorder) {
        self.samples_us.extend_from_slice(&other.samples_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_nearest_rank() {
        let mut r = LatencyRecorder::new();
        for us in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
            r.record(Duration::from_micros(us));
        }
        assert_eq!(r.percentile_us(50.0), 50);
        assert_eq!(r.percentile_us(90.0), 90);
        assert_eq!(r.percentile_us(99.0), 100);
        assert_eq!(r.max_us(), 100);
        assert!((r.mean_us() - 55.0).abs() < 1e-9);
    }

    #[test]
    fn empty_recorder_zeroes() {
        let r = LatencyRecorder::new();
        assert_eq!(r.percentile_us(99.0), 0);
        assert_eq!(r.mean_us(), 0.0);
        assert!(r.is_empty());
    }
}

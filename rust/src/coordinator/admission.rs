//! Admission control for the inference service.
//!
//! The north-star deployment serves open-loop traffic: arrival rate is
//! set by clients, not by the accelerator, so an unbounded inbox turns
//! overload into unbounded latency and memory. [`AdmissionController`]
//! instead enforces a hard in-flight cap — a request is either admitted
//! (it holds a [`Permit`] until its reply is sent) or *shed* immediately
//! with [`crate::Error::Busy`], which the HTTP front-end
//! ([`crate::coordinator::net`]) translates into `503` + `Retry-After`.
//! Shedding at the door keeps the queue short enough that admitted
//! requests meet their deadlines; expired work is dropped before it
//! wastes engine time (see [`crate::coordinator::server`]).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Admission-control knobs for [`crate::coordinator::ServerConfig`].
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Hard cap on requests admitted but not yet replied to (queued in
    /// the dispatcher, batched, or executing). Submissions beyond the
    /// cap are shed with [`crate::Error::Busy`]. Clamped to ≥ 1.
    pub max_in_flight: usize,
    /// Deadline applied to requests that do not carry their own; `None`
    /// means admitted requests never expire in queue.
    pub default_deadline: Option<Duration>,
    /// Back-off hint returned with shed requests (the HTTP layer rounds
    /// it up to whole seconds for the `Retry-After` header).
    pub retry_after: Duration,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        Self {
            max_in_flight: 256,
            default_deadline: None,
            retry_after: Duration::from_millis(50),
        }
    }
}

/// Shared in-flight accounting; one per [`crate::coordinator::InferenceServer`],
/// shared with the HTTP front-end for `/metrics` and `/healthz`.
#[derive(Debug)]
pub struct AdmissionController {
    cfg: AdmissionConfig,
    in_flight: AtomicUsize,
    admitted: AtomicU64,
    shed: AtomicU64,
}

impl AdmissionController {
    pub fn new(cfg: AdmissionConfig) -> Arc<Self> {
        Arc::new(Self {
            cfg,
            in_flight: AtomicUsize::new(0),
            admitted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        })
    }

    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// Admit one request or shed it. The returned [`Permit`] releases
    /// the in-flight slot when dropped (after the reply is sent, or when
    /// the request dies anywhere along the pipeline).
    pub fn try_admit(self: &Arc<Self>) -> crate::Result<Permit> {
        let cap = self.cfg.max_in_flight.max(1);
        let admitted = self
            .in_flight
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |n| {
                (n < cap).then_some(n + 1)
            })
            .is_ok();
        if admitted {
            self.admitted.fetch_add(1, Ordering::Relaxed);
            Ok(Permit { ctrl: Arc::clone(self) })
        } else {
            self.shed.fetch_add(1, Ordering::Relaxed);
            Err(crate::Error::Busy {
                retry_after_ms: self.cfg.retry_after.as_millis() as u64,
            })
        }
    }

    /// Resolve a request's deadline: its own ask wins, then the
    /// configured default, then none.
    pub fn deadline_from(&self, now: Instant, requested: Option<Duration>) -> Option<Instant> {
        requested.or(self.cfg.default_deadline).map(|d| now + d)
    }

    /// Requests admitted but not yet replied to (the queue-depth gauge).
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    pub fn admitted_total(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }

    pub fn shed_total(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }
}

/// RAII in-flight slot; dropping it re-opens the slot to new arrivals.
#[derive(Debug)]
pub struct Permit {
    ctrl: Arc<AdmissionController>,
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.ctrl.in_flight.fetch_sub(1, Ordering::AcqRel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctrl(cap: usize) -> Arc<AdmissionController> {
        AdmissionController::new(AdmissionConfig {
            max_in_flight: cap,
            ..Default::default()
        })
    }

    #[test]
    fn cap_reached_sheds_and_release_reopens() {
        let c = ctrl(2);
        let p1 = c.try_admit().expect("slot 1");
        let p2 = c.try_admit().expect("slot 2");
        assert_eq!(c.in_flight(), 2);
        let shed = c.try_admit();
        assert!(matches!(shed, Err(crate::Error::Busy { .. })), "cap must shed");
        assert_eq!(c.shed_total(), 1);
        drop(p1);
        assert_eq!(c.in_flight(), 1);
        let p3 = c.try_admit().expect("freed slot re-admits");
        drop(p2);
        drop(p3);
        assert_eq!(c.in_flight(), 0);
        assert_eq!(c.admitted_total(), 3);
    }

    #[test]
    fn zero_cap_clamps_to_one() {
        let c = ctrl(0);
        let p = c.try_admit().expect("cap 0 behaves as cap 1");
        assert!(c.try_admit().is_err());
        drop(p);
    }

    #[test]
    fn deadline_resolution_order() {
        let now = Instant::now();
        let c = AdmissionController::new(AdmissionConfig {
            default_deadline: Some(Duration::from_secs(5)),
            ..Default::default()
        });
        let own = c.deadline_from(now, Some(Duration::from_secs(1))).unwrap();
        assert_eq!(own, now + Duration::from_secs(1), "request's own deadline wins");
        let def = c.deadline_from(now, None).unwrap();
        assert_eq!(def, now + Duration::from_secs(5), "falls back to the default");
        let none = AdmissionController::new(AdmissionConfig::default());
        assert!(none.deadline_from(now, None).is_none());
    }

    #[test]
    fn concurrent_admits_never_exceed_cap() {
        let c = ctrl(8);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let c = &c;
                s.spawn(move || {
                    for _ in 0..200 {
                        if let Ok(p) = c.try_admit() {
                            assert!(c.in_flight() <= 8);
                            drop(p);
                        }
                    }
                });
            }
        });
        assert_eq!(c.in_flight(), 0);
    }
}

//! Networked front-end: a dependency-free HTTP/1.1 server over
//! `std::net::TcpListener` that puts the inference service on a socket.
//!
//! ## Reactor architecture
//!
//! One thread runs a readiness-driven event loop over a
//! [`Poller`](crate::coordinator::poller::Poller) (epoll on Linux): the
//! listener and every connection are nonblocking and registered by
//! token, and each connection is a small state machine — accumulate
//! request bytes, route, park a `/v1/predict` on its reply channel
//! without blocking the loop, stream the response out, repeat
//! (keep-alive + pipelining). Connection count is bounded by
//! [`NetConfig::max_connections`], not by threads: a thousand idle
//! keep-alive connections cost a thousand fds and nothing else — the
//! thread-per-connection design this replaced held a stack per idle
//! socket and collapsed under slow-loris clients.
//!
//! Routes:
//!
//! * `POST /v1/predict` — body `{"image":[f64,...], "shape":[c,h,w]?,
//!   "deadline_ms":n?}`; replies `{"class":k, "logits":[...],
//!   "latency_us":n, "batch_size":b, "energy_mj":e}` (`energy_mj` is the
//!   request's column share of its batched engine pass).
//! * `GET /healthz` — liveness: 200 while any worker serves (status
//!   `degraded` plus a `reason` when below full strength — a slot down,
//!   browned out, or carrying an unrepairable device fault), 503 only
//!   when zero workers are live.
//! * `GET /readyz` — readiness: 503 while draining, with zero live
//!   workers, or with every replica degraded; load balancers route away
//!   on `/readyz` long before `/healthz` would restart the process.
//! * `GET /metrics` — Prometheus text format: request/shed/expired
//!   counters, the `scatter_batch_occupancy` histogram, p50/p99
//!   latency, queue depth, energy and average power from the engine
//!   ledgers, the cluster-routing series (per-replica routed shards,
//!   steals, heat, queue depth), the device-fault repair series
//!   (injections, sentinel detections, repairs, quarantined cells,
//!   degraded replicas), uptime, and build info.
//!
//! ## Error envelope
//!
//! Every non-2xx response carries one JSON shape:
//!
//! ```json
//! {"error": {"code": "overloaded", "message": "...", "retryable": true,
//!            "retry_after_s": 1}}
//! ```
//!
//! `code` is a stable machine-readable slug (`bad_request`,
//! `not_found`, `payload_too_large`, `internal`, `overloaded`,
//! `unavailable`, `draining`, `deadline_exceeded`), `retryable` tells
//! the client whether the same request can succeed later, and 503s
//! carry `retry_after_s` both in the body and as a `Retry-After`
//! header. Overload is shed with `503 overloaded` (admission cap),
//! expired deadlines get `504 deadline_exceeded`.
//!
//! The parser handles exactly the protocol subset the load generator,
//! `curl`, and the e2e tests speak: `Content-Length` bodies, keep-alive
//! connections, no chunked encoding. A hand-rolled client
//! ([`HttpClient`], [`http_request`]) lives here too so the bench
//! driver and tests exercise the same wire path end to end.
//!
//! Shutdown is SIGTERM-style graceful: [`HttpServer::shutdown`] stops
//! accepting, lets in-flight connections finish, drains the inference
//! queue, and returns the final [`ServerReport`].

use crate::coordinator::poller::{Interest, Poller};
use crate::coordinator::server::{InferenceServer, ReplyResult, ServeError, ServerReport};
use crate::nn::Tensor;
use crate::util::Json;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Front-end configuration.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Bind address; use port 0 for an ephemeral port.
    pub addr: String,
    /// Default input-tensor shape (CHW) assumed when `/v1/predict`
    /// bodies omit `"shape"`.
    pub input_shape: Vec<usize>,
    /// Cap on concurrently open connections; beyond it new connections
    /// are served one `503` and closed.
    pub max_connections: usize,
    /// How long a connection waits for the engine's reply before
    /// answering `500`.
    pub reply_timeout: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".into(),
            input_shape: vec![1, 28, 28],
            max_connections: 64,
            reply_timeout: Duration::from_secs(120),
        }
    }
}

/// HTTP-level counters (requests by outcome class), separate from the
/// inference-level [`crate::coordinator::ServerMetrics`].
#[derive(Debug, Default)]
struct HttpStats {
    requests: AtomicU64,
    responses_2xx: AtomicU64,
    responses_4xx: AtomicU64,
    responses_5xx: AtomicU64,
}

impl HttpStats {
    fn count_response(&self, status: u16) {
        let class = match status {
            200..=299 => &self.responses_2xx,
            400..=499 => &self.responses_4xx,
            _ => &self.responses_5xx,
        };
        class.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(unix)]
fn raw_fd<T: std::os::fd::AsRawFd>(t: &T) -> i32 {
    t.as_raw_fd()
}

#[cfg(not(unix))]
fn raw_fd<T>(_t: &T) -> i32 {
    0
}

/// A running networked inference front-end.
pub struct HttpServer {
    addr: SocketAddr,
    inference: Arc<InferenceServer>,
    stop: Arc<AtomicBool>,
    reactor: Option<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind and start serving `inference` on `cfg.addr`.
    pub fn bind(inference: InferenceServer, cfg: NetConfig) -> crate::Result<Self> {
        let listener = TcpListener::bind(&cfg.addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let poller = Poller::new()?;
        poller.register(raw_fd(&listener), LISTENER_TOKEN, Interest::READ)?;
        let inference = Arc::new(inference);
        let stop = Arc::new(AtomicBool::new(false));
        let reactor = {
            let mut reactor = Reactor {
                poller,
                listener,
                conns: BTreeMap::new(),
                next_token: LISTENER_TOKEN + 1,
                inference: Arc::clone(&inference),
                cfg: Arc::new(cfg),
                stop: Arc::clone(&stop),
                stats: Arc::new(HttpStats::default()),
                live_conns: Arc::new(AtomicUsize::new(0)),
            };
            std::thread::spawn(move || reactor.run())
        };
        Ok(Self { addr, inference, stop, reactor: Some(reactor) })
    }

    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Shared handle to the underlying inference service.
    pub fn inference(&self) -> Arc<InferenceServer> {
        Arc::clone(&self.inference)
    }

    /// Graceful drain: stop accepting, finish in-flight connections,
    /// drain the inference queue, and return the final report.
    pub fn shutdown(mut self) -> crate::Result<ServerReport> {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.reactor.take() {
            let _ = h.join();
        }
        self.inference.shutdown()
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        // consumed by shutdown() in the normal path; this covers early
        // returns in tests so the reactor thread doesn't spin forever
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.reactor.take() {
            let _ = h.join();
        }
    }
}

const MAX_REQUEST_BYTES: usize = 4 << 20;
const LISTENER_TOKEN: u64 = 0;
/// How long a connection accepted over the cap may sit before its `503`
/// is sent even without a complete request head.
const REJECT_GRACE: Duration = Duration::from_millis(100);
/// How long a half-received request may linger once the server drains.
const DRAIN_PARTIAL_GRACE: Duration = Duration::from_secs(1);
/// Hard ceiling on finishing in-flight work after shutdown is signaled.
const DRAIN_DEADLINE: Duration = Duration::from_secs(30);

/// A `/v1/predict` parked on its reply channel. The reactor polls
/// `try_recv` each tick instead of blocking a thread on `recv`.
struct Pending {
    rx: mpsc::Receiver<ReplyResult>,
    since: Instant,
    keep_alive: bool,
}

/// Per-connection state machine.
struct Conn {
    stream: TcpStream,
    /// Accumulated request bytes not yet consumed by the parser.
    buf: Vec<u8>,
    /// Queued response bytes; `out[out_pos..]` is still unsent.
    out: Vec<u8>,
    out_pos: usize,
    /// A predict in flight; while `Some`, no further pipelined request
    /// is parsed (responses stay in request order).
    awaiting: Option<Pending>,
    /// `100 Continue` already sent for the current partial request.
    sent_continue: bool,
    close_after_write: bool,
    /// Current poller registration includes write interest.
    want_write: bool,
    /// Accepted over the connection cap: answer one `503` and close.
    reject: bool,
    /// The reject `503` has been queued.
    reject_sent: bool,
    created: Instant,
    /// First time this conn was seen with a partial request mid-drain.
    drain_partial_since: Option<Instant>,
    closed: bool,
}

impl Conn {
    fn new(stream: TcpStream, reject: bool) -> Self {
        Self {
            stream,
            buf: Vec::new(),
            out: Vec::new(),
            out_pos: 0,
            awaiting: None,
            sent_continue: false,
            close_after_write: false,
            want_write: false,
            reject,
            reject_sent: false,
            created: Instant::now(),
            drain_partial_since: None,
            closed: false,
        }
    }

    fn has_output(&self) -> bool {
        self.out_pos < self.out.len()
    }

    fn queue_response(&mut self, resp: &Response, keep_alive: bool, stats: &HttpStats) {
        stats.count_response(resp.status);
        self.out.extend_from_slice(&render_response(resp, keep_alive));
        if !keep_alive {
            self.close_after_write = true;
        }
    }
}

/// Read everything currently available; `false` = connection is done
/// (EOF or a hard error).
fn read_into(conn: &mut Conn) -> bool {
    let mut tmp = [0u8; 16384];
    loop {
        // stop pulling once the buffer is oversized — the parser will
        // answer 413; reading further just buys the client free memory
        if conn.buf.len() > MAX_REQUEST_BYTES {
            return true;
        }
        match conn.stream.read(&mut tmp) {
            Ok(0) => return false,
            Ok(n) => conn.buf.extend_from_slice(&tmp[..n]),
            Err(e) if e.kind() == ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
}

/// Push queued response bytes until the socket blocks.
fn flush_conn(conn: &mut Conn) {
    while conn.out_pos < conn.out.len() {
        match conn.stream.write(&conn.out[conn.out_pos..]) {
            Ok(0) => {
                conn.closed = true;
                return;
            }
            Ok(n) => conn.out_pos += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.closed = true;
                return;
            }
        }
    }
    conn.out.clear();
    conn.out_pos = 0;
    if conn.close_after_write {
        conn.closed = true;
    }
}

/// Parse and serve every complete request currently buffered (stops at
/// a parked predict so responses stay ordered).
fn process_conn(
    conn: &mut Conn,
    inference: &InferenceServer,
    cfg: &NetConfig,
    stats: &HttpStats,
    draining: bool,
) {
    while !conn.closed && conn.awaiting.is_none() && !conn.reject {
        match parse_request(&conn.buf) {
            Parse::Complete(req, consumed) => {
                conn.buf.drain(..consumed);
                conn.sent_continue = false;
                stats.requests.fetch_add(1, Ordering::Relaxed);
                let keep_alive = req.keep_alive && !draining;
                if draining && req.method == "POST" {
                    let resp =
                        Response::busy("draining", "server draining; retry elsewhere", 1000);
                    conn.queue_response(&resp, keep_alive, stats);
                    if !keep_alive {
                        return;
                    }
                    continue;
                }
                match route(&req, inference, cfg, stats, draining) {
                    Routed::Done(resp) => {
                        conn.queue_response(&resp, keep_alive, stats);
                        if !keep_alive {
                            return;
                        }
                    }
                    Routed::Wait(rx) => {
                        conn.awaiting =
                            Some(Pending { rx, since: Instant::now(), keep_alive });
                        return;
                    }
                }
            }
            Parse::Partial => {
                if conn.buf.len() > MAX_REQUEST_BYTES {
                    let resp = Response::error(
                        413,
                        "payload_too_large",
                        "request body too large",
                        false,
                    );
                    conn.queue_response(&resp, false, stats);
                    return;
                }
                // curl sends `Expect: 100-continue` for bodies >1KB
                // (every predict image) and waits ~1s for the interim
                // reply before transmitting — answer it once per
                // request so the advertised quickstart isn't stalled
                if !conn.sent_continue {
                    if let Some(h) = find_subslice(&conn.buf, b"\r\n\r\n") {
                        let head =
                            String::from_utf8_lossy(&conn.buf[..h]).to_ascii_lowercase();
                        if head.contains("expect: 100-continue") {
                            conn.out.extend_from_slice(b"HTTP/1.1 100 Continue\r\n\r\n");
                            conn.sent_continue = true;
                        }
                    }
                }
                return;
            }
            Parse::Bad(msg) => {
                let resp = Response::error(400, "bad_request", &msg, false);
                conn.queue_response(&resp, false, stats);
                return;
            }
        }
    }
}

/// Poll a parked predict; queue its response when the reply (or the
/// timeout) arrives.
fn poll_pending(conn: &mut Conn, inference: &InferenceServer, cfg: &NetConfig, stats: &HttpStats) {
    let Some(pending) = &conn.awaiting else { return };
    let resp = match pending.rx.try_recv() {
        Ok(Ok(reply)) => Response::json(
            200,
            Json::obj(vec![
                ("class", Json::Num(reply.class as f64)),
                ("logits", Json::arr_f64(&reply.logits)),
                ("latency_us", Json::Num(reply.latency.as_micros() as f64)),
                ("batch_size", Json::Num(reply.batch_size as f64)),
                ("energy_mj", Json::Num(reply.energy_mj)),
            ]),
        ),
        Ok(Err(ServeError::Expired)) => Response::error(
            504,
            "deadline_exceeded",
            "deadline expired in queue",
            true,
        ),
        Ok(Err(ServeError::WorkerLost)) => {
            Response::busy("unavailable", "engine worker lost; retry", 1000)
        }
        // a dropped reply sender means the engine worker died holding
        // this request: retryable, and ours to count (the dispatcher
        // only counts shards it fails to hand over after the death)
        Err(mpsc::TryRecvError::Disconnected) => {
            inference.metrics().note_worker_lost(1);
            Response::busy("unavailable", "engine worker lost; retry", 1000)
        }
        Err(mpsc::TryRecvError::Empty) => {
            if pending.since.elapsed() < cfg.reply_timeout {
                return;
            }
            Response::error(500, "internal", "timed out waiting for engine reply", false)
        }
    };
    let keep_alive = pending.keep_alive;
    conn.awaiting = None;
    conn.queue_response(&resp, keep_alive, stats);
}

/// A connection accepted over the cap: pull whatever the client sent
/// (closing with unread data can turn the response into a TCP RST on
/// common stacks), answer one `503`, close. The grace period bounds how
/// long we wait for a client that never sends.
fn poll_reject(conn: &mut Conn, stats: &HttpStats) {
    if conn.reject_sent {
        return;
    }
    let head_done = find_subslice(&conn.buf, b"\r\n\r\n").is_some();
    if head_done || conn.created.elapsed() >= REJECT_GRACE {
        stats.requests.fetch_add(1, Ordering::Relaxed);
        let resp = Response::busy("overloaded", "connection limit reached", 1000);
        conn.queue_response(&resp, false, stats);
        conn.reject_sent = true;
        conn.buf.clear();
    }
}

struct Reactor {
    poller: Poller,
    listener: TcpListener,
    conns: BTreeMap<u64, Conn>,
    next_token: u64,
    inference: Arc<InferenceServer>,
    cfg: Arc<NetConfig>,
    stop: Arc<AtomicBool>,
    stats: Arc<HttpStats>,
    live_conns: Arc<AtomicUsize>,
}

impl Reactor {
    fn run(&mut self) {
        let mut events = Vec::new();
        let mut draining = false;
        let mut drain_deadline: Option<Instant> = None;
        loop {
            if !draining && self.stop.load(Ordering::Acquire) {
                draining = true;
                drain_deadline = Some(Instant::now() + DRAIN_DEADLINE);
                let _ = self.poller.deregister(raw_fd(&self.listener));
            }
            if draining
                && (self.conns.is_empty()
                    || drain_deadline.is_some_and(|d| Instant::now() >= d))
            {
                return;
            }
            // short ticks while anything is pending (parked replies,
            // unsent output, reject grace); long ticks when fully idle
            let busy = self.conns.values().any(|c| {
                c.awaiting.is_some() || c.has_output() || (c.reject && !c.reject_sent)
            });
            let timeout = if busy || draining {
                Duration::from_millis(5)
            } else {
                Duration::from_millis(50)
            };
            if self.poller.wait(&mut events, timeout).is_err() {
                std::thread::sleep(Duration::from_millis(5));
            }
            for ev in &events {
                if ev.token == LISTENER_TOKEN {
                    if !draining {
                        self.accept_ready();
                    }
                    continue;
                }
                if let Some(conn) = self.conns.get_mut(&ev.token) {
                    if ev.readable || ev.hangup {
                        let open = read_into(conn);
                        if !open {
                            // client is gone; last-gasp flush of
                            // anything already queued, then close
                            flush_conn(conn);
                            conn.closed = true;
                        }
                    }
                }
            }
            self.sweep(draining);
        }
    }

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let _ = stream.set_nonblocking(true);
                    let _ = stream.set_nodelay(true);
                    let reject = self.conns.len() >= self.cfg.max_connections;
                    let token = self.next_token;
                    self.next_token += 1;
                    if self.poller.register(raw_fd(&stream), token, Interest::READ).is_err()
                    {
                        continue; // kernel said no; drop the socket
                    }
                    self.conns.insert(token, Conn::new(stream, reject));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
        self.live_conns.store(self.conns.len(), Ordering::Release);
    }

    /// One pass over every connection: advance reject/pending/parse
    /// state machines, flush output, update poller interest, apply
    /// drain policy, reap closed connections.
    fn sweep(&mut self, draining: bool) {
        for (&token, conn) in self.conns.iter_mut() {
            if conn.closed {
                continue;
            }
            if conn.reject {
                poll_reject(conn, &self.stats);
            } else {
                poll_pending(conn, &self.inference, &self.cfg, &self.stats);
                process_conn(conn, &self.inference, &self.cfg, &self.stats, draining);
            }
            flush_conn(conn);
            if conn.closed {
                continue;
            }
            if draining && conn.awaiting.is_none() && !conn.has_output() {
                if conn.buf.is_empty() {
                    // idle keep-alive connection during drain
                    conn.closed = true;
                } else {
                    // half-received request: bounded grace to finish
                    let t0 = *conn.drain_partial_since.get_or_insert_with(Instant::now);
                    if t0.elapsed() > DRAIN_PARTIAL_GRACE {
                        conn.closed = true;
                    }
                }
                if conn.closed {
                    continue;
                }
            }
            let want_write = conn.has_output();
            if want_write != conn.want_write {
                // best-effort: a failed re-registration only costs
                // latency (the next read event re-enters the sweep)
                conn.want_write = want_write;
                let interest =
                    if want_write { Interest::READ_WRITE } else { Interest::READ };
                let _ = self.poller.modify(raw_fd(&conn.stream), token, interest);
            }
        }
        let dead: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| c.closed)
            .map(|(&t, _)| t)
            .collect();
        for token in dead {
            if let Some(conn) = self.conns.remove(&token) {
                let _ = self.poller.deregister(raw_fd(&conn.stream));
                let _ = conn.stream.shutdown(std::net::Shutdown::Both);
            }
        }
        self.live_conns.store(self.conns.len(), Ordering::Release);
    }
}

enum Routed {
    Done(Response),
    /// A predict handed to the inference service; the reactor parks the
    /// connection on this receiver.
    Wait(mpsc::Receiver<ReplyResult>),
}

fn route(
    req: &HttpRequest,
    inference: &InferenceServer,
    cfg: &NetConfig,
    stats: &HttpStats,
    draining: bool,
) -> Routed {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let adm = inference.admission();
            let snap = inference.snapshot();
            // degraded = still serving but below full strength (a worker
            // slot down, browned out, or carrying an unrepairable device
            // fault); down = zero live workers, which is a 503 so load
            // balancers eject the instance
            let mut reasons: Vec<&str> = Vec::new();
            if snap.workers_live < snap.workers_configured {
                reasons.push("worker_down");
            }
            if snap.brownout_active > 0 {
                reasons.push("brownout");
            }
            if snap.degraded_active > 0 {
                reasons.push("device_fault");
            }
            let status = if snap.workers_live == 0 {
                "down"
            } else if !reasons.is_empty() {
                "degraded"
            } else {
                "ok"
            };
            let code = if snap.workers_live == 0 { 503 } else { 200 };
            Routed::Done(Response::json(
                code,
                Json::obj(vec![
                    ("status", Json::Str(status.into())),
                    ("reason", Json::Str(reasons.join("+"))),
                    ("in_flight", Json::Num(adm.in_flight() as f64)),
                    ("workers_live", Json::Num(snap.workers_live as f64)),
                    ("workers_configured", Json::Num(snap.workers_configured as f64)),
                    ("brownout_active", Json::Num(snap.brownout_active as f64)),
                    ("degraded_replicas", Json::Num(snap.degraded_active as f64)),
                ]),
            ))
        }
        ("GET", "/readyz") => {
            let snap = inference.snapshot();
            let all_degraded = snap.workers_configured > 0
                && snap.degraded_active >= snap.workers_configured;
            let reason = if draining {
                "draining"
            } else if snap.workers_live == 0 {
                "no_live_workers"
            } else if all_degraded {
                "all_replicas_degraded"
            } else {
                ""
            };
            let body = Json::obj(vec![
                ("ready", Json::Bool(reason.is_empty())),
                ("reason", Json::Str(reason.into())),
                ("workers_live", Json::Num(snap.workers_live as f64)),
                ("degraded_replicas", Json::Num(snap.degraded_active as f64)),
            ]);
            let code = if reason.is_empty() { 200 } else { 503 };
            Routed::Done(Response::json(code, body))
        }
        ("GET", "/metrics") => Routed::Done(Response {
            status: 200,
            content_type: "text/plain; version=0.0.4",
            body: render_prometheus(inference, stats),
            retry_after_s: None,
        }),
        ("POST", "/v1/predict") => handle_predict(req, inference, cfg),
        _ => Routed::Done(Response::error(404, "not_found", "no such route", false)),
    }
}

fn handle_predict(req: &HttpRequest, inference: &InferenceServer, cfg: &NetConfig) -> Routed {
    let body = match Json::parse(&req.body) {
        Ok(v) => v,
        Err(e) => {
            return Routed::Done(Response::error(
                400,
                "bad_request",
                &format!("bad json: {e}"),
                false,
            ))
        }
    };
    // strict decode: a single non-numeric element rejects the request
    // (f64_vec no longer silently drops malformed entries)
    let Some(image) = body.get("image").and_then(Json::f64_vec) else {
        return Routed::Done(Response::error(
            400,
            "bad_request",
            "missing or malformed 'image' array",
            false,
        ));
    };
    let shape: Vec<usize> = body
        .get("shape")
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(Json::as_usize).collect())
        .unwrap_or_else(|| cfg.input_shape.clone());
    // checked product: an adversarial shape like [2, usize::MAX] must
    // answer 400, not overflow
    let volume = shape.iter().try_fold(1usize, |acc, &d| acc.checked_mul(d));
    if shape.is_empty() || volume != Some(image.len()) {
        return Routed::Done(Response::error(
            400,
            "bad_request",
            &format!("image has {} values, shape {shape:?} disagrees", image.len()),
            false,
        ));
    }
    let deadline = body
        .get("deadline_ms")
        .and_then(Json::as_f64)
        .map(|ms| Duration::from_millis(ms.max(0.0) as u64));
    match inference.submit_with_deadline(Tensor::from_vec(&shape, image), deadline) {
        Ok(rx) => Routed::Wait(rx),
        Err(crate::Error::Busy { retry_after_ms }) => Routed::Done(Response::busy(
            "overloaded",
            "overloaded: admission cap reached",
            retry_after_ms,
        )),
        Err(e) => {
            Routed::Done(Response::busy("unavailable", &format!("unavailable: {e}"), 1000))
        }
    }
}

fn render_prometheus(inference: &InferenceServer, stats: &HttpStats) -> String {
    let snap = inference.snapshot();
    let adm = inference.admission();
    let mut o = String::new();
    let _ = writeln!(o, "# HELP scatter_requests_total Inference requests served.");
    let _ = writeln!(o, "# TYPE scatter_requests_total counter");
    let _ = writeln!(o, "scatter_requests_total {}", snap.requests);
    let _ = writeln!(o, "# TYPE scatter_batches_total counter");
    let _ = writeln!(o, "scatter_batches_total {}", snap.batches);
    let _ = writeln!(
        o,
        "# HELP scatter_batch_occupancy Requests per dispatched dynamic batch."
    );
    let _ = writeln!(o, "# TYPE scatter_batch_occupancy histogram");
    let mut cum = 0u64;
    for (bin, le) in snap
        .batch_occupancy
        .iter()
        .zip(crate::coordinator::metrics::OCCUPANCY_BUCKETS)
    {
        cum += bin;
        let _ = writeln!(o, "scatter_batch_occupancy_bucket{{le=\"{le}\"}} {cum}");
    }
    cum += snap.batch_occupancy[snap.batch_occupancy.len() - 1];
    let _ = writeln!(o, "scatter_batch_occupancy_bucket{{le=\"+Inf\"}} {cum}");
    let _ = writeln!(o, "scatter_batch_occupancy_sum {}", snap.batch_occupancy_sum);
    let _ = writeln!(o, "scatter_batch_occupancy_count {cum}");
    let _ = writeln!(o, "# TYPE scatter_shed_total counter");
    let _ = writeln!(o, "scatter_shed_total {}", adm.shed_total());
    let _ = writeln!(o, "# TYPE scatter_expired_total counter");
    let _ = writeln!(o, "scatter_expired_total {}", snap.expired);
    let _ = writeln!(o, "# TYPE scatter_worker_lost_total counter");
    let _ = writeln!(o, "scatter_worker_lost_total {}", snap.worker_lost);
    let _ = writeln!(o, "# HELP scatter_worker_up Per-slot engine worker liveness.");
    let _ = writeln!(o, "# TYPE scatter_worker_up gauge");
    for (widx, up) in snap.worker_up.iter().enumerate() {
        let _ = writeln!(o, "scatter_worker_up{{worker=\"{widx}\"}} {}", u8::from(*up));
    }
    let _ = writeln!(o, "# TYPE scatter_workers_live gauge");
    let _ = writeln!(o, "scatter_workers_live {}", snap.workers_live);
    let _ = writeln!(o, "# HELP scatter_worker_restarts_total Supervisor worker respawns.");
    let _ = writeln!(o, "# TYPE scatter_worker_restarts_total counter");
    let _ = writeln!(o, "scatter_worker_restarts_total {}", snap.worker_restarts);
    let _ = writeln!(
        o,
        "# HELP scatter_request_retries_total Loss-driven request re-dispatches."
    );
    let _ = writeln!(o, "# TYPE scatter_request_retries_total counter");
    let _ = writeln!(o, "scatter_request_retries_total {}", snap.request_retries);
    let _ = writeln!(
        o,
        "# HELP scatter_replica_routed_total Shards routed to each replica slot."
    );
    let _ = writeln!(o, "# TYPE scatter_replica_routed_total counter");
    for (widx, n) in snap.routed.iter().enumerate() {
        let _ = writeln!(o, "scatter_replica_routed_total{{worker=\"{widx}\"}} {n}");
    }
    let _ = writeln!(o, "# HELP scatter_steals_total Shards stolen between replica queues.");
    let _ = writeln!(o, "# TYPE scatter_steals_total counter");
    let _ = writeln!(o, "scatter_steals_total {}", snap.steals);
    let _ = writeln!(
        o,
        "# HELP scatter_replica_heat_millirad Routing heat score (phase error) per replica."
    );
    let _ = writeln!(o, "# TYPE scatter_replica_heat_millirad gauge");
    for (widx, h) in snap.replica_heat_milli.iter().enumerate() {
        let _ = writeln!(o, "scatter_replica_heat_millirad{{worker=\"{widx}\"}} {h}");
    }
    let _ = writeln!(
        o,
        "# HELP scatter_replica_queue_depth Shards queued or executing per replica."
    );
    let _ = writeln!(o, "# TYPE scatter_replica_queue_depth gauge");
    for (widx, d) in snap.replica_queue_depth.iter().enumerate() {
        let _ = writeln!(o, "scatter_replica_queue_depth{{worker=\"{widx}\"}} {d}");
    }
    let _ = writeln!(
        o,
        "# HELP scatter_mask_generation Active mask artifact generation per replica (0 = deployment baseline)."
    );
    let _ = writeln!(o, "# TYPE scatter_mask_generation gauge");
    for (widx, g) in snap.mask_generation.iter().enumerate() {
        let _ = writeln!(o, "scatter_mask_generation{{worker=\"{widx}\"}} {g}");
    }
    let _ = writeln!(o, "# HELP scatter_mask_swaps_total Mask generations promoted after a passing canary.");
    let _ = writeln!(o, "# TYPE scatter_mask_swaps_total counter");
    let _ = writeln!(o, "scatter_mask_swaps_total {}", snap.mask_swaps);
    let _ = writeln!(o, "# HELP scatter_mask_rollbacks_total Mask candidates rolled back by a failing canary.");
    let _ = writeln!(o, "# TYPE scatter_mask_rollbacks_total counter");
    let _ = writeln!(o, "scatter_mask_rollbacks_total {}", snap.mask_rollbacks);
    let _ = writeln!(o, "# HELP scatter_mask_power_mw Estimated rerouter power of the active mask artifact.");
    let _ = writeln!(o, "# TYPE scatter_mask_power_mw gauge");
    let _ = writeln!(o, "scatter_mask_power_mw {}", snap.mask_power_mw);
    let _ = writeln!(
        o,
        "# HELP scatter_brownout_active Workers currently over their phase-error budget."
    );
    let _ = writeln!(o, "# TYPE scatter_brownout_active gauge");
    let _ = writeln!(o, "scatter_brownout_active {}", snap.brownout_active);
    let _ = writeln!(o, "# TYPE scatter_brownouts_total counter");
    let _ = writeln!(o, "scatter_brownouts_total {}", snap.brownouts_total);
    let _ = writeln!(o, "# HELP scatter_queue_depth Admitted requests awaiting reply.");
    let _ = writeln!(o, "# TYPE scatter_queue_depth gauge");
    let _ = writeln!(o, "scatter_queue_depth {}", adm.in_flight());
    let _ = writeln!(o, "# TYPE scatter_request_latency_microseconds summary");
    let _ = writeln!(
        o,
        "scatter_request_latency_microseconds{{quantile=\"0.5\"}} {}",
        snap.p50_us
    );
    let _ = writeln!(
        o,
        "scatter_request_latency_microseconds{{quantile=\"0.99\"}} {}",
        snap.p99_us
    );
    let _ = writeln!(
        o,
        "scatter_request_latency_microseconds_sum {}",
        snap.mean_us * snap.requests as f64
    );
    let _ = writeln!(o, "scatter_request_latency_microseconds_count {}", snap.requests);
    let _ = writeln!(o, "# HELP scatter_energy_millijoules_total Accelerator energy spent.");
    let _ = writeln!(o, "# TYPE scatter_energy_millijoules_total counter");
    let _ = writeln!(o, "scatter_energy_millijoules_total {}", snap.energy_mj);
    let _ = writeln!(o, "# HELP scatter_p_avg_watts Average accelerator power while busy.");
    let _ = writeln!(o, "# TYPE scatter_p_avg_watts gauge");
    let _ = writeln!(o, "scatter_p_avg_watts {}", snap.p_avg_w);
    let _ = writeln!(o, "# HELP scatter_thermal_drift_rad Worst drift envelope across workers.");
    let _ = writeln!(o, "# TYPE scatter_thermal_drift_rad gauge");
    let _ = writeln!(o, "scatter_thermal_drift_rad {}", snap.thermal_drift_rad);
    let _ = writeln!(
        o,
        "# HELP scatter_thermal_phase_error_rad Worst residual phase error across workers."
    );
    let _ = writeln!(o, "# TYPE scatter_thermal_phase_error_rad gauge");
    let _ = writeln!(o, "scatter_thermal_phase_error_rad {}", snap.thermal_phase_error_rad);
    let _ = writeln!(
        o,
        "# HELP scatter_thermal_recalibrations_total Online recalibration actions."
    );
    let _ = writeln!(o, "# TYPE scatter_thermal_recalibrations_total counter");
    let _ = writeln!(o, "scatter_thermal_recalibrations_total {}", snap.recalibrations);
    let _ = writeln!(
        o,
        "# HELP scatter_thermal_recalibrated_chunks_total Chunks recompiled by recalibration."
    );
    let _ = writeln!(o, "# TYPE scatter_thermal_recalibrated_chunks_total counter");
    let _ = writeln!(o, "scatter_thermal_recalibrated_chunks_total {}", snap.recal_chunks);
    let _ = writeln!(o, "# HELP scatter_device_faults_injected_total Device faults injected into engine fabrics.");
    let _ = writeln!(o, "# TYPE scatter_device_faults_injected_total counter");
    let _ = writeln!(o, "scatter_device_faults_injected_total {}", snap.faults_injected);
    let _ = writeln!(o, "# HELP scatter_sentinel_detections_total Faulted chunks flagged by the sentinel probe.");
    let _ = writeln!(o, "# TYPE scatter_sentinel_detections_total counter");
    let _ = writeln!(o, "scatter_sentinel_detections_total {}", snap.fault_detections);
    let _ = writeln!(o, "# HELP scatter_fault_repairs_total Quarantine repairs promoted by the repair canary.");
    let _ = writeln!(o, "# TYPE scatter_fault_repairs_total counter");
    let _ = writeln!(o, "scatter_fault_repairs_total {}", snap.fault_repairs);
    let _ = writeln!(o, "# HELP scatter_fault_unrepairable_total Sentinel findings that could not be quarantined.");
    let _ = writeln!(o, "# TYPE scatter_fault_unrepairable_total counter");
    let _ = writeln!(o, "scatter_fault_unrepairable_total {}", snap.fault_unrepairable);
    let _ = writeln!(o, "# HELP scatter_fault_detection_latency_seconds First-injection to first-detection latency.");
    let _ = writeln!(o, "# TYPE scatter_fault_detection_latency_seconds gauge");
    let _ = writeln!(
        o,
        "scatter_fault_detection_latency_seconds {}",
        snap.fault_detection_latency_us as f64 / 1e6
    );
    let _ = writeln!(o, "# HELP scatter_worker_degraded Replicas carrying an unrepairable device fault.");
    let _ = writeln!(o, "# TYPE scatter_worker_degraded gauge");
    for (widx, d) in snap.worker_degraded.iter().enumerate() {
        let _ = writeln!(o, "scatter_worker_degraded{{worker=\"{widx}\"}} {}", u8::from(*d));
    }
    let _ = writeln!(o, "# HELP scatter_quarantined_cells Weight cells quarantined by the repair loop, per replica.");
    let _ = writeln!(o, "# TYPE scatter_quarantined_cells gauge");
    for (widx, c) in snap.quarantined_cells.iter().enumerate() {
        let _ = writeln!(o, "scatter_quarantined_cells{{worker=\"{widx}\"}} {c}");
    }
    let _ = writeln!(o, "# HELP scatter_artifacts_skipped_total Mask artifacts skipped by the startup scan.");
    let _ = writeln!(o, "# TYPE scatter_artifacts_skipped_total counter");
    let _ = writeln!(o, "scatter_artifacts_skipped_total {}", snap.artifacts_skipped);
    let _ = writeln!(o, "# HELP scatter_uptime_seconds Seconds since the server came up.");
    let _ = writeln!(o, "# TYPE scatter_uptime_seconds gauge");
    let _ = writeln!(o, "scatter_uptime_seconds {}", snap.uptime_s);
    let _ = writeln!(o, "# HELP scatter_build_info Build metadata as labels, value is always 1.");
    let _ = writeln!(o, "# TYPE scatter_build_info gauge");
    let _ = writeln!(
        o,
        "scatter_build_info{{version=\"{}\"}} 1",
        env!("CARGO_PKG_VERSION")
    );
    let _ = writeln!(
        o,
        "# HELP scatter_kernel_variant Active engine kernel as labels, value is always 1."
    );
    let _ = writeln!(o, "# TYPE scatter_kernel_variant gauge");
    let _ = writeln!(
        o,
        "scatter_kernel_variant{{variant=\"{}\",precision=\"{}\"}} 1",
        crate::exec::detected_simd().as_str(),
        inference.precision().as_str()
    );
    let _ = writeln!(o, "# TYPE scatter_http_requests_total counter");
    let _ = writeln!(o, "scatter_http_requests_total {}", stats.requests.load(Ordering::Relaxed));
    let _ = writeln!(
        o,
        "scatter_http_responses_total{{class=\"2xx\"}} {}",
        stats.responses_2xx.load(Ordering::Relaxed)
    );
    let _ = writeln!(
        o,
        "scatter_http_responses_total{{class=\"4xx\"}} {}",
        stats.responses_4xx.load(Ordering::Relaxed)
    );
    let _ = writeln!(
        o,
        "scatter_http_responses_total{{class=\"5xx\"}} {}",
        stats.responses_5xx.load(Ordering::Relaxed)
    );
    o
}

// ---------------------------------------------------------------------
// wire format
// ---------------------------------------------------------------------

struct HttpRequest {
    method: String,
    path: String,
    body: String,
    keep_alive: bool,
}

enum Parse {
    Complete(HttpRequest, usize),
    Partial,
    Bad(String),
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

fn parse_request(buf: &[u8]) -> Parse {
    let Some(hdr_end) = find_subslice(buf, b"\r\n\r\n") else {
        return Parse::Partial;
    };
    let Ok(head) = std::str::from_utf8(&buf[..hdr_end]) else {
        return Parse::Bad("non-utf8 request head".into());
    };
    let mut lines = head.split("\r\n");
    let Some(request_line) = lines.next() else {
        return Parse::Bad("empty request".into());
    };
    let mut parts = request_line.split_whitespace();
    let (Some(method), Some(path), Some(version)) = (parts.next(), parts.next(), parts.next())
    else {
        return Parse::Bad(format!("malformed request line '{request_line}'"));
    };
    if !version.starts_with("HTTP/1.") {
        return Parse::Bad(format!("unsupported version '{version}'"));
    }
    let mut content_length = 0usize;
    let mut keep_alive = true; // HTTP/1.1 default
    for line in lines {
        let Some((name, value)) = line.split_once(':') else { continue };
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            match value.parse() {
                Ok(n) => content_length = n,
                Err(_) => return Parse::Bad(format!("bad content-length '{value}'")),
            }
        } else if name.eq_ignore_ascii_case("connection") {
            keep_alive = !value.eq_ignore_ascii_case("close");
        } else if name.eq_ignore_ascii_case("transfer-encoding") {
            return Parse::Bad("chunked bodies unsupported; send Content-Length".into());
        }
    }
    if content_length > MAX_REQUEST_BYTES {
        return Parse::Bad("request body too large".into());
    }
    let body_start = hdr_end + 4;
    if buf.len() < body_start + content_length {
        return Parse::Partial;
    }
    let body = String::from_utf8_lossy(&buf[body_start..body_start + content_length]).into_owned();
    Parse::Complete(
        HttpRequest { method: method.into(), path: path.into(), body, keep_alive },
        body_start + content_length,
    )
}

struct Response {
    status: u16,
    content_type: &'static str,
    body: String,
    retry_after_s: Option<u64>,
}

impl Response {
    fn json(status: u16, value: Json) -> Self {
        Self {
            status,
            content_type: "application/json",
            body: value.to_string(),
            retry_after_s: None,
        }
    }

    /// The structured error envelope every non-2xx response carries:
    /// `{"error":{"code","message","retryable"}}`.
    fn error(status: u16, code: &str, msg: &str, retryable: bool) -> Self {
        Self::json(
            status,
            Json::obj(vec![(
                "error",
                Json::obj(vec![
                    ("code", Json::Str(code.into())),
                    ("message", Json::Str(msg.into())),
                    ("retryable", Json::Bool(retryable)),
                ]),
            )]),
        )
    }

    /// `503` + `Retry-After` (whole seconds, rounded up), with the hint
    /// mirrored as `retry_after_s` inside the error envelope so JSON
    /// clients never need to read headers.
    fn busy(code: &str, msg: &str, retry_after_ms: u64) -> Self {
        let secs = retry_after_ms.div_ceil(1000).max(1);
        let mut r = Self::json(
            503,
            Json::obj(vec![(
                "error",
                Json::obj(vec![
                    ("code", Json::Str(code.into())),
                    ("message", Json::Str(msg.into())),
                    ("retryable", Json::Bool(true)),
                    ("retry_after_s", Json::Num(secs as f64)),
                ]),
            )]),
        );
        r.retry_after_s = Some(secs);
        r
    }
}

fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

fn render_response(resp: &Response, keep_alive: bool) -> Vec<u8> {
    let mut head = String::with_capacity(160 + resp.body.len());
    let _ = write!(
        head,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n",
        resp.status,
        status_reason(resp.status),
        resp.content_type,
        resp.body.len()
    );
    if let Some(s) = resp.retry_after_s {
        let _ = write!(head, "Retry-After: {s}\r\n");
    }
    let _ = write!(head, "Connection: {}\r\n\r\n", if keep_alive { "keep-alive" } else { "close" });
    head.push_str(&resp.body);
    head.into_bytes()
}

// ---------------------------------------------------------------------
// client (load generator + tests drive the same wire path)
// ---------------------------------------------------------------------

/// A parsed HTTP response.
#[derive(Debug, Clone)]
pub struct HttpResponse {
    pub status: u16,
    pub body: String,
    pub retry_after_s: Option<u64>,
}

/// A keep-alive HTTP/1.1 client for one connection.
pub struct HttpClient {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl HttpClient {
    pub fn connect(addr: &SocketAddr) -> crate::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs(180)))?;
        Ok(Self { stream, buf: Vec::new() })
    }

    /// Issue one request and block for its response.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> crate::Result<HttpResponse> {
        let body = body.unwrap_or("");
        let head = format!(
            "{method} {path} HTTP/1.1\r\nHost: scatter\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: keep-alive\r\n\r\n",
            body.len()
        );
        self.stream.write_all(head.as_bytes())?;
        self.stream.write_all(body.as_bytes())?;
        self.stream.flush()?;
        let mut tmp = [0u8; 8192];
        loop {
            if let Some((resp, consumed)) = parse_response(&self.buf)? {
                self.buf.drain(..consumed);
                return Ok(resp);
            }
            match self.stream.read(&mut tmp) {
                Ok(0) => {
                    return Err(crate::Error::Runtime(
                        "connection closed mid-response".into(),
                    ))
                }
                Ok(n) => self.buf.extend_from_slice(&tmp[..n]),
                Err(e) => return Err(crate::Error::Io(e)),
            }
        }
    }
}

/// One-shot request on a fresh connection.
pub fn http_request(
    addr: &SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> crate::Result<HttpResponse> {
    HttpClient::connect(addr)?.request(method, path, body)
}

/// First sample value of the `/metrics` line starting with `prefix`
/// (comment lines skipped); NaN when absent. One scraper shared by the
/// drift bench and the e2e tests, so they cannot parse differently.
pub fn metric_value(text: &str, prefix: &str) -> f64 {
    text.lines()
        .filter(|l| !l.starts_with('#'))
        .find(|l| l.starts_with(prefix))
        .and_then(|l| l.split_whitespace().last())
        .and_then(|v| v.parse().ok())
        .unwrap_or(f64::NAN)
}

/// Resolve a `host:port` string (e.g. a `--addr` flag) to a socket
/// address.
pub fn resolve_addr(addr: &str) -> crate::Result<SocketAddr> {
    addr.to_socket_addrs()
        .map_err(crate::Error::Io)?
        .next()
        .ok_or_else(|| crate::Error::Config(format!("'{addr}' resolves to no address")))
}

/// `Ok(None)` = need more bytes.
fn parse_response(buf: &[u8]) -> crate::Result<Option<(HttpResponse, usize)>> {
    let Some(hdr_end) = find_subslice(buf, b"\r\n\r\n") else {
        return Ok(None);
    };
    let head = std::str::from_utf8(&buf[..hdr_end])
        .map_err(|_| crate::Error::Runtime("non-utf8 response head".into()))?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| crate::Error::Runtime(format!("bad status line '{status_line}'")))?;
    let mut content_length = 0usize;
    let mut retry_after_s = None;
    for line in lines {
        let Some((name, value)) = line.split_once(':') else { continue };
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            content_length = value.parse().unwrap_or(0);
        } else if name.eq_ignore_ascii_case("retry-after") {
            retry_after_s = value.parse().ok();
        }
    }
    let body_start = hdr_end + 4;
    if buf.len() < body_start + content_length {
        return Ok(None);
    }
    let body = String::from_utf8_lossy(&buf[body_start..body_start + content_length]).into_owned();
    Ok(Some((HttpResponse { status, body, retry_after_s }, body_start + content_length)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_request_with_body_and_pipelining() {
        let wire = b"POST /v1/predict HTTP/1.1\r\nContent-Length: 7\r\n\r\n{\"a\":1}\
GET /healthz HTTP/1.1\r\n\r\n";
        match parse_request(wire) {
            Parse::Complete(req, consumed) => {
                assert_eq!(req.method, "POST");
                assert_eq!(req.path, "/v1/predict");
                assert_eq!(req.body, "{\"a\":1}");
                assert!(req.keep_alive);
                // second pipelined request parses from the remainder
                match parse_request(&wire[consumed..]) {
                    Parse::Complete(req2, _) => assert_eq!(req2.path, "/healthz"),
                    _ => panic!("pipelined request must parse"),
                }
            }
            _ => panic!("complete request must parse"),
        }
    }

    #[test]
    fn partial_requests_wait_for_more_bytes() {
        assert!(matches!(parse_request(b"POST /v1/pre"), Parse::Partial));
        assert!(matches!(
            parse_request(b"POST /p HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort"),
            Parse::Partial
        ));
    }

    #[test]
    fn connection_close_disables_keep_alive() {
        let wire = b"GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n";
        match parse_request(wire) {
            Parse::Complete(req, _) => assert!(!req.keep_alive),
            _ => panic!("must parse"),
        }
    }

    #[test]
    fn response_roundtrip() {
        let wire =
            b"HTTP/1.1 503 Service Unavailable\r\nContent-Length: 2\r\nRetry-After: 3\r\n\r\n{}";
        let (resp, consumed) = parse_response(wire).unwrap().expect("complete");
        assert_eq!(resp.status, 503);
        assert_eq!(resp.body, "{}");
        assert_eq!(resp.retry_after_s, Some(3));
        assert_eq!(consumed, wire.len());
        assert!(parse_response(&wire[..10]).unwrap().is_none(), "partial → None");
    }

    #[test]
    fn error_envelope_shape_is_stable() {
        let resp = Response::error(400, "bad_request", "nope", false);
        let doc = Json::parse(&resp.body).expect("envelope is json");
        let err = doc.get("error").expect("error object");
        assert_eq!(err.get("code").and_then(Json::as_str), Some("bad_request"));
        assert_eq!(err.get("message").and_then(Json::as_str), Some("nope"));
        assert_eq!(err.get("retryable").and_then(Json::as_bool), Some(false));
        assert!(err.get("retry_after_s").is_none(), "only 503s carry the hint");

        let resp = Response::busy("overloaded", "try later", 2500);
        assert_eq!(resp.status, 503);
        assert_eq!(resp.retry_after_s, Some(3), "rounded up to whole seconds");
        let doc = Json::parse(&resp.body).expect("envelope is json");
        let err = doc.get("error").expect("error object");
        assert_eq!(err.get("retryable").and_then(Json::as_bool), Some(true));
        assert_eq!(err.get("retry_after_s").and_then(Json::as_f64), Some(3.0));
    }

    #[test]
    fn render_response_marks_connection_disposition() {
        let resp = Response::json(200, Json::obj(vec![("ok", Json::Bool(true))]));
        let wire = String::from_utf8(render_response(&resp, true)).unwrap();
        assert!(wire.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(wire.contains("Connection: keep-alive\r\n"));
        assert!(wire.ends_with("{\"ok\":true}"));
        let wire = String::from_utf8(render_response(&resp, false)).unwrap();
        assert!(wire.contains("Connection: close\r\n"));
    }
}

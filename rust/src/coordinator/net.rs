//! Networked front-end: a dependency-free HTTP/1.1 server over
//! `std::net::TcpListener` that puts the inference service on a socket.
//!
//! Routes:
//!
//! * `POST /v1/predict` — body `{"image":[f64,...], "shape":[c,h,w]?,
//!   "deadline_ms":n?}`; replies `{"class":k, "logits":[...],
//!   "latency_us":n, "batch_size":b, "energy_mj":e}` (`energy_mj` is the
//!   request's column share of its batched engine pass). Overload is
//!   shed with `503` + `Retry-After` (admission cap), expired deadlines
//!   get `504`.
//! * `GET /healthz` — liveness + current queue depth.
//! * `GET /metrics` — Prometheus text format: request/shed/expired
//!   counters, the `scatter_batch_occupancy` histogram (requests per
//!   dispatched dynamic batch), p50/p99 latency, queue depth, energy and
//!   average power from the engine ledgers.
//!
//! The parser handles exactly the protocol subset the load generator,
//! `curl`, and the e2e tests speak: `Content-Length` bodies, keep-alive
//! connections, no chunked encoding. A hand-rolled client
//! ([`HttpClient`], [`http_request`]) lives here too so the bench
//! driver and tests exercise the same wire path end to end.
//!
//! Shutdown is SIGTERM-style graceful: [`HttpServer::shutdown`] stops
//! accepting, lets in-flight connections finish, drains the inference
//! queue, and returns the final [`ServerReport`].

use crate::coordinator::server::{InferenceServer, ServeError, ServerReport};
use crate::nn::Tensor;
use crate::util::Json;
use std::fmt::Write as _;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Front-end configuration.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Bind address; use port 0 for an ephemeral port.
    pub addr: String,
    /// Default input-tensor shape (CHW) assumed when `/v1/predict`
    /// bodies omit `"shape"`.
    pub input_shape: Vec<usize>,
    /// Cap on concurrently handled connections; beyond it new
    /// connections are served one `503` and closed.
    pub max_connections: usize,
    /// How long a connection handler waits for the engine's reply
    /// before answering `500`.
    pub reply_timeout: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".into(),
            input_shape: vec![1, 28, 28],
            max_connections: 64,
            reply_timeout: Duration::from_secs(120),
        }
    }
}

/// HTTP-level counters (requests by outcome class), separate from the
/// inference-level [`crate::coordinator::ServerMetrics`].
#[derive(Debug, Default)]
struct HttpStats {
    requests: AtomicU64,
    responses_2xx: AtomicU64,
    responses_4xx: AtomicU64,
    responses_5xx: AtomicU64,
}

/// A running networked inference front-end.
pub struct HttpServer {
    addr: SocketAddr,
    inference: Arc<InferenceServer>,
    stop: Arc<AtomicBool>,
    live_conns: Arc<AtomicUsize>,
    accept: Option<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind and start serving `inference` on `cfg.addr`.
    pub fn bind(inference: InferenceServer, cfg: NetConfig) -> crate::Result<Self> {
        let listener = TcpListener::bind(&cfg.addr)?;
        // non-blocking accept so the loop can poll the stop flag
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let inference = Arc::new(inference);
        let stop = Arc::new(AtomicBool::new(false));
        let live_conns = Arc::new(AtomicUsize::new(0));
        let stats = Arc::new(HttpStats::default());
        let accept = {
            let inference = Arc::clone(&inference);
            let stop = Arc::clone(&stop);
            let live_conns = Arc::clone(&live_conns);
            let cfg = Arc::new(cfg);
            std::thread::spawn(move || loop {
                if stop.load(Ordering::Acquire) {
                    return;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        if live_conns.load(Ordering::Acquire) >= cfg.max_connections {
                            let stats = Arc::clone(&stats);
                            std::thread::spawn(move || reject_conn(stream, &stats));
                            continue;
                        }
                        live_conns.fetch_add(1, Ordering::AcqRel);
                        let inference = Arc::clone(&inference);
                        let stop = Arc::clone(&stop);
                        let live_conns = Arc::clone(&live_conns);
                        let cfg = Arc::clone(&cfg);
                        let stats = Arc::clone(&stats);
                        std::thread::spawn(move || {
                            handle_conn(stream, &inference, &cfg, &stop, &stats);
                            live_conns.fetch_sub(1, Ordering::AcqRel);
                        });
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => std::thread::sleep(Duration::from_millis(5)),
                }
            })
        };
        Ok(Self { addr, inference, stop, live_conns, accept: Some(accept) })
    }

    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Shared handle to the underlying inference service.
    pub fn inference(&self) -> Arc<InferenceServer> {
        Arc::clone(&self.inference)
    }

    /// Graceful drain: stop accepting, finish in-flight connections,
    /// drain the inference queue, and return the final report.
    pub fn shutdown(mut self) -> crate::Result<ServerReport> {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // keep-alive handlers notice the stop flag at their next idle
        // poll (≤ ~200 ms); give in-flight predicts time to finish
        let deadline = Instant::now() + Duration::from_secs(30);
        while self.live_conns.load(Ordering::Acquire) > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        self.inference.shutdown()
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        // consumed by shutdown() in the normal path; this covers early
        // returns in tests so the accept thread doesn't spin forever
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

const MAX_REQUEST_BYTES: usize = 4 << 20;

/// Over the connection cap: best-effort pull of the client's request
/// bytes off the socket first (closing with unread data can turn the
/// response into a TCP RST on common stacks), then answer `503` +
/// `Retry-After` and close. Runs on its own short-lived thread so the
/// accept loop never blocks on a shed client.
fn reject_conn(mut stream: TcpStream, stats: &HttpStats) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let mut tmp = [0u8; 8192];
    let _ = stream.read(&mut tmp);
    stats.requests.fetch_add(1, Ordering::Relaxed);
    stats.responses_5xx.fetch_add(1, Ordering::Relaxed);
    let resp = Response::busy("connection limit reached", 1);
    let _ = write_response(&mut stream, &resp, false);
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

/// Serve one connection: parse pipelined/keep-alive requests out of a
/// persistent buffer, answer each, exit on close or server stop.
fn handle_conn(
    mut stream: TcpStream,
    inference: &InferenceServer,
    cfg: &NetConfig,
    stop: &AtomicBool,
    stats: &HttpStats,
) {
    let _ = stream.set_nodelay(true);
    // short read timeout: the loop wakes to poll the stop flag
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let mut buf: Vec<u8> = Vec::new();
    let mut tmp = [0u8; 8192];
    let mut drain_seen: Option<Instant> = None;
    let mut sent_continue = false;
    loop {
        match parse_request(&buf) {
            Parse::Complete(req, consumed) => {
                buf.drain(..consumed);
                sent_continue = false;
                stats.requests.fetch_add(1, Ordering::Relaxed);
                let draining = stop.load(Ordering::Acquire);
                let resp = if draining && req.method == "POST" {
                    Response::busy("server draining", 1)
                } else {
                    route(&req, inference, cfg, stats)
                };
                let class = match resp.status {
                    200..=299 => &stats.responses_2xx,
                    400..=499 => &stats.responses_4xx,
                    _ => &stats.responses_5xx,
                };
                class.fetch_add(1, Ordering::Relaxed);
                let keep_alive = req.keep_alive && !draining;
                if write_response(&mut stream, &resp, keep_alive).is_err() || !keep_alive {
                    return;
                }
            }
            Parse::Partial => {
                // curl sends `Expect: 100-continue` for bodies >1KB
                // (every predict image) and waits ~1s for the interim
                // reply before transmitting — answer it once per
                // request so the advertised quickstart isn't stalled
                if !sent_continue {
                    if let Some(h) = find_subslice(&buf, b"\r\n\r\n") {
                        let head = String::from_utf8_lossy(&buf[..h]).to_ascii_lowercase();
                        if head.contains("expect: 100-continue") {
                            let _ = stream.write_all(b"HTTP/1.1 100 Continue\r\n\r\n");
                            let _ = stream.flush();
                            sent_continue = true;
                        }
                    }
                }
                if stop.load(Ordering::Acquire) {
                    if buf.is_empty() {
                        return; // idle keep-alive connection during drain
                    }
                    // half-received request during drain: give the
                    // client one second to finish the send, then cut
                    let t0 = *drain_seen.get_or_insert_with(Instant::now);
                    if t0.elapsed() > Duration::from_secs(1) {
                        return;
                    }
                }
                match stream.read(&mut tmp) {
                    Ok(0) => return,
                    Ok(n) => {
                        buf.extend_from_slice(&tmp[..n]);
                        if buf.len() > MAX_REQUEST_BYTES {
                            let resp =
                                Response::json_error(413, "request body too large");
                            let _ = write_response(&mut stream, &resp, false);
                            return;
                        }
                    }
                    Err(e)
                        if e.kind() == ErrorKind::WouldBlock
                            || e.kind() == ErrorKind::TimedOut =>
                    {
                        continue;
                    }
                    Err(_) => return,
                }
            }
            Parse::Bad(msg) => {
                let resp = Response::json_error(400, &msg);
                let _ = write_response(&mut stream, &resp, false);
                return;
            }
        }
    }
}

fn route(
    req: &HttpRequest,
    inference: &InferenceServer,
    cfg: &NetConfig,
    stats: &HttpStats,
) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let adm = inference.admission();
            let snap = inference.snapshot();
            // degraded = still serving but below full strength (a worker
            // slot down or browned out); down = zero live workers, which
            // is a 503 so load balancers eject the instance
            let status = if snap.workers_live == 0 {
                "down"
            } else if snap.workers_live < snap.workers_configured || snap.brownout_active > 0 {
                "degraded"
            } else {
                "ok"
            };
            let code = if snap.workers_live == 0 { 503 } else { 200 };
            Response::json(
                code,
                Json::obj(vec![
                    ("status", Json::Str(status.into())),
                    ("in_flight", Json::Num(adm.in_flight() as f64)),
                    ("workers_live", Json::Num(snap.workers_live as f64)),
                    ("workers_configured", Json::Num(snap.workers_configured as f64)),
                    ("brownout_active", Json::Num(snap.brownout_active as f64)),
                ]),
            )
        }
        ("GET", "/metrics") => Response {
            status: 200,
            content_type: "text/plain; version=0.0.4",
            body: render_prometheus(inference, stats),
            retry_after_s: None,
        },
        ("POST", "/v1/predict") => handle_predict(req, inference, cfg),
        _ => Response::json_error(404, "no such route"),
    }
}

fn handle_predict(req: &HttpRequest, inference: &InferenceServer, cfg: &NetConfig) -> Response {
    let body = match Json::parse(&req.body) {
        Ok(v) => v,
        Err(e) => return Response::json_error(400, &format!("bad json: {e}")),
    };
    // strict decode: a single non-numeric element rejects the request
    // (f64_vec no longer silently drops malformed entries)
    let Some(image) = body.get("image").and_then(Json::f64_vec) else {
        return Response::json_error(400, "missing or malformed 'image' array");
    };
    let shape: Vec<usize> = body
        .get("shape")
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(Json::as_usize).collect())
        .unwrap_or_else(|| cfg.input_shape.clone());
    if shape.is_empty() || shape.iter().product::<usize>() != image.len() {
        return Response::json_error(
            400,
            &format!("image has {} values, shape {shape:?} disagrees", image.len()),
        );
    }
    let deadline = body
        .get("deadline_ms")
        .and_then(Json::as_f64)
        .map(|ms| Duration::from_millis(ms.max(0.0) as u64));
    let rx = match inference.submit_with_deadline(Tensor::from_vec(&shape, image), deadline) {
        Ok(rx) => rx,
        Err(crate::Error::Busy { retry_after_ms }) => {
            return Response::busy("overloaded: admission cap reached", retry_after_ms)
        }
        Err(e) => return Response::busy(&format!("unavailable: {e}"), 1000),
    };
    match rx.recv_timeout(cfg.reply_timeout) {
        Ok(Ok(reply)) => Response::json(
            200,
            Json::obj(vec![
                ("class", Json::Num(reply.class as f64)),
                ("logits", Json::arr_f64(&reply.logits)),
                ("latency_us", Json::Num(reply.latency.as_micros() as f64)),
                ("batch_size", Json::Num(reply.batch_size as f64)),
                ("energy_mj", Json::Num(reply.energy_mj)),
            ]),
        ),
        Ok(Err(ServeError::Expired)) => Response::json_error(504, "deadline expired in queue"),
        Ok(Err(ServeError::WorkerLost)) => Response::busy("engine worker lost; retry", 1000),
        // a dropped reply sender means the engine worker died holding
        // this request: retryable, and ours to count (the dispatcher
        // only counts shards it fails to hand over after the death)
        Err(mpsc::RecvTimeoutError::Disconnected) => {
            inference.metrics().note_worker_lost(1);
            Response::busy("engine worker lost; retry", 1000)
        }
        Err(mpsc::RecvTimeoutError::Timeout) => {
            Response::json_error(500, "timed out waiting for engine reply")
        }
    }
}

fn render_prometheus(inference: &InferenceServer, stats: &HttpStats) -> String {
    let snap = inference.snapshot();
    let adm = inference.admission();
    let mut o = String::new();
    let _ = writeln!(o, "# HELP scatter_requests_total Inference requests served.");
    let _ = writeln!(o, "# TYPE scatter_requests_total counter");
    let _ = writeln!(o, "scatter_requests_total {}", snap.requests);
    let _ = writeln!(o, "# TYPE scatter_batches_total counter");
    let _ = writeln!(o, "scatter_batches_total {}", snap.batches);
    let _ = writeln!(
        o,
        "# HELP scatter_batch_occupancy Requests per dispatched dynamic batch."
    );
    let _ = writeln!(o, "# TYPE scatter_batch_occupancy histogram");
    let mut cum = 0u64;
    for (bin, le) in snap
        .batch_occupancy
        .iter()
        .zip(crate::coordinator::metrics::OCCUPANCY_BUCKETS)
    {
        cum += bin;
        let _ = writeln!(o, "scatter_batch_occupancy_bucket{{le=\"{le}\"}} {cum}");
    }
    cum += snap.batch_occupancy[snap.batch_occupancy.len() - 1];
    let _ = writeln!(o, "scatter_batch_occupancy_bucket{{le=\"+Inf\"}} {cum}");
    let _ = writeln!(o, "scatter_batch_occupancy_sum {}", snap.batch_occupancy_sum);
    let _ = writeln!(o, "scatter_batch_occupancy_count {cum}");
    let _ = writeln!(o, "# TYPE scatter_shed_total counter");
    let _ = writeln!(o, "scatter_shed_total {}", adm.shed_total());
    let _ = writeln!(o, "# TYPE scatter_expired_total counter");
    let _ = writeln!(o, "scatter_expired_total {}", snap.expired);
    let _ = writeln!(o, "# TYPE scatter_worker_lost_total counter");
    let _ = writeln!(o, "scatter_worker_lost_total {}", snap.worker_lost);
    let _ = writeln!(o, "# HELP scatter_worker_up Per-slot engine worker liveness.");
    let _ = writeln!(o, "# TYPE scatter_worker_up gauge");
    for (widx, up) in snap.worker_up.iter().enumerate() {
        let _ = writeln!(o, "scatter_worker_up{{worker=\"{widx}\"}} {}", u8::from(*up));
    }
    let _ = writeln!(o, "# TYPE scatter_workers_live gauge");
    let _ = writeln!(o, "scatter_workers_live {}", snap.workers_live);
    let _ = writeln!(o, "# HELP scatter_worker_restarts_total Supervisor worker respawns.");
    let _ = writeln!(o, "# TYPE scatter_worker_restarts_total counter");
    let _ = writeln!(o, "scatter_worker_restarts_total {}", snap.worker_restarts);
    let _ = writeln!(
        o,
        "# HELP scatter_request_retries_total Loss-driven request re-dispatches."
    );
    let _ = writeln!(o, "# TYPE scatter_request_retries_total counter");
    let _ = writeln!(o, "scatter_request_retries_total {}", snap.request_retries);
    let _ = writeln!(
        o,
        "# HELP scatter_brownout_active Workers currently over their phase-error budget."
    );
    let _ = writeln!(o, "# TYPE scatter_brownout_active gauge");
    let _ = writeln!(o, "scatter_brownout_active {}", snap.brownout_active);
    let _ = writeln!(o, "# TYPE scatter_brownouts_total counter");
    let _ = writeln!(o, "scatter_brownouts_total {}", snap.brownouts_total);
    let _ = writeln!(o, "# HELP scatter_queue_depth Admitted requests awaiting reply.");
    let _ = writeln!(o, "# TYPE scatter_queue_depth gauge");
    let _ = writeln!(o, "scatter_queue_depth {}", adm.in_flight());
    let _ = writeln!(o, "# TYPE scatter_request_latency_microseconds summary");
    let _ = writeln!(
        o,
        "scatter_request_latency_microseconds{{quantile=\"0.5\"}} {}",
        snap.p50_us
    );
    let _ = writeln!(
        o,
        "scatter_request_latency_microseconds{{quantile=\"0.99\"}} {}",
        snap.p99_us
    );
    let _ = writeln!(
        o,
        "scatter_request_latency_microseconds_sum {}",
        snap.mean_us * snap.requests as f64
    );
    let _ = writeln!(o, "scatter_request_latency_microseconds_count {}", snap.requests);
    let _ = writeln!(o, "# HELP scatter_energy_millijoules_total Accelerator energy spent.");
    let _ = writeln!(o, "# TYPE scatter_energy_millijoules_total counter");
    let _ = writeln!(o, "scatter_energy_millijoules_total {}", snap.energy_mj);
    let _ = writeln!(o, "# HELP scatter_p_avg_watts Average accelerator power while busy.");
    let _ = writeln!(o, "# TYPE scatter_p_avg_watts gauge");
    let _ = writeln!(o, "scatter_p_avg_watts {}", snap.p_avg_w);
    let _ = writeln!(o, "# HELP scatter_thermal_drift_rad Worst drift envelope across workers.");
    let _ = writeln!(o, "# TYPE scatter_thermal_drift_rad gauge");
    let _ = writeln!(o, "scatter_thermal_drift_rad {}", snap.thermal_drift_rad);
    let _ = writeln!(
        o,
        "# HELP scatter_thermal_phase_error_rad Worst residual phase error across workers."
    );
    let _ = writeln!(o, "# TYPE scatter_thermal_phase_error_rad gauge");
    let _ = writeln!(o, "scatter_thermal_phase_error_rad {}", snap.thermal_phase_error_rad);
    let _ = writeln!(
        o,
        "# HELP scatter_thermal_recalibrations_total Online recalibration actions."
    );
    let _ = writeln!(o, "# TYPE scatter_thermal_recalibrations_total counter");
    let _ = writeln!(o, "scatter_thermal_recalibrations_total {}", snap.recalibrations);
    let _ = writeln!(
        o,
        "# HELP scatter_thermal_recalibrated_chunks_total Chunks recompiled by recalibration."
    );
    let _ = writeln!(o, "# TYPE scatter_thermal_recalibrated_chunks_total counter");
    let _ = writeln!(o, "scatter_thermal_recalibrated_chunks_total {}", snap.recal_chunks);
    let _ = writeln!(o, "# TYPE scatter_http_requests_total counter");
    let _ = writeln!(o, "scatter_http_requests_total {}", stats.requests.load(Ordering::Relaxed));
    let _ = writeln!(
        o,
        "scatter_http_responses_total{{class=\"2xx\"}} {}",
        stats.responses_2xx.load(Ordering::Relaxed)
    );
    let _ = writeln!(
        o,
        "scatter_http_responses_total{{class=\"4xx\"}} {}",
        stats.responses_4xx.load(Ordering::Relaxed)
    );
    let _ = writeln!(
        o,
        "scatter_http_responses_total{{class=\"5xx\"}} {}",
        stats.responses_5xx.load(Ordering::Relaxed)
    );
    o
}

// ---------------------------------------------------------------------
// wire format
// ---------------------------------------------------------------------

struct HttpRequest {
    method: String,
    path: String,
    body: String,
    keep_alive: bool,
}

enum Parse {
    Complete(HttpRequest, usize),
    Partial,
    Bad(String),
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

fn parse_request(buf: &[u8]) -> Parse {
    let Some(hdr_end) = find_subslice(buf, b"\r\n\r\n") else {
        return Parse::Partial;
    };
    let Ok(head) = std::str::from_utf8(&buf[..hdr_end]) else {
        return Parse::Bad("non-utf8 request head".into());
    };
    let mut lines = head.split("\r\n");
    let Some(request_line) = lines.next() else {
        return Parse::Bad("empty request".into());
    };
    let mut parts = request_line.split_whitespace();
    let (Some(method), Some(path), Some(version)) = (parts.next(), parts.next(), parts.next())
    else {
        return Parse::Bad(format!("malformed request line '{request_line}'"));
    };
    if !version.starts_with("HTTP/1.") {
        return Parse::Bad(format!("unsupported version '{version}'"));
    }
    let mut content_length = 0usize;
    let mut keep_alive = true; // HTTP/1.1 default
    for line in lines {
        let Some((name, value)) = line.split_once(':') else { continue };
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            match value.parse() {
                Ok(n) => content_length = n,
                Err(_) => return Parse::Bad(format!("bad content-length '{value}'")),
            }
        } else if name.eq_ignore_ascii_case("connection") {
            keep_alive = !value.eq_ignore_ascii_case("close");
        } else if name.eq_ignore_ascii_case("transfer-encoding") {
            return Parse::Bad("chunked bodies unsupported; send Content-Length".into());
        }
    }
    if content_length > MAX_REQUEST_BYTES {
        return Parse::Bad("request body too large".into());
    }
    let body_start = hdr_end + 4;
    if buf.len() < body_start + content_length {
        return Parse::Partial;
    }
    let body = String::from_utf8_lossy(&buf[body_start..body_start + content_length]).into_owned();
    Parse::Complete(
        HttpRequest { method: method.into(), path: path.into(), body, keep_alive },
        body_start + content_length,
    )
}

struct Response {
    status: u16,
    content_type: &'static str,
    body: String,
    retry_after_s: Option<u64>,
}

impl Response {
    fn json(status: u16, value: Json) -> Self {
        Self {
            status,
            content_type: "application/json",
            body: value.to_string(),
            retry_after_s: None,
        }
    }

    fn json_error(status: u16, msg: &str) -> Self {
        Self::json(status, Json::obj(vec![("error", Json::Str(msg.into()))]))
    }

    /// `503` with a `Retry-After` hint (whole seconds, rounded up).
    fn busy(msg: &str, retry_after_ms: u64) -> Self {
        let mut r = Self::json_error(503, msg);
        r.retry_after_s = Some(retry_after_ms.div_ceil(1000).max(1));
        r
    }
}

fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

fn write_response(
    stream: &mut TcpStream,
    resp: &Response,
    keep_alive: bool,
) -> std::io::Result<()> {
    let mut head = String::with_capacity(160);
    let _ = write!(
        head,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n",
        resp.status,
        status_reason(resp.status),
        resp.content_type,
        resp.body.len()
    );
    if let Some(s) = resp.retry_after_s {
        let _ = write!(head, "Retry-After: {s}\r\n");
    }
    let _ = write!(head, "Connection: {}\r\n\r\n", if keep_alive { "keep-alive" } else { "close" });
    stream.write_all(head.as_bytes())?;
    stream.write_all(resp.body.as_bytes())?;
    stream.flush()
}

// ---------------------------------------------------------------------
// client (load generator + tests drive the same wire path)
// ---------------------------------------------------------------------

/// A parsed HTTP response.
#[derive(Debug, Clone)]
pub struct HttpResponse {
    pub status: u16,
    pub body: String,
    pub retry_after_s: Option<u64>,
}

/// A keep-alive HTTP/1.1 client for one connection.
pub struct HttpClient {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl HttpClient {
    pub fn connect(addr: &SocketAddr) -> crate::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs(180)))?;
        Ok(Self { stream, buf: Vec::new() })
    }

    /// Issue one request and block for its response.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> crate::Result<HttpResponse> {
        let body = body.unwrap_or("");
        let head = format!(
            "{method} {path} HTTP/1.1\r\nHost: scatter\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: keep-alive\r\n\r\n",
            body.len()
        );
        self.stream.write_all(head.as_bytes())?;
        self.stream.write_all(body.as_bytes())?;
        self.stream.flush()?;
        let mut tmp = [0u8; 8192];
        loop {
            if let Some((resp, consumed)) = parse_response(&self.buf)? {
                self.buf.drain(..consumed);
                return Ok(resp);
            }
            match self.stream.read(&mut tmp) {
                Ok(0) => {
                    return Err(crate::Error::Runtime(
                        "connection closed mid-response".into(),
                    ))
                }
                Ok(n) => self.buf.extend_from_slice(&tmp[..n]),
                Err(e) => return Err(crate::Error::Io(e)),
            }
        }
    }
}

/// One-shot request on a fresh connection.
pub fn http_request(
    addr: &SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> crate::Result<HttpResponse> {
    HttpClient::connect(addr)?.request(method, path, body)
}

/// First sample value of the `/metrics` line starting with `prefix`
/// (comment lines skipped); NaN when absent. One scraper shared by the
/// drift bench and the e2e tests, so they cannot parse differently.
pub fn metric_value(text: &str, prefix: &str) -> f64 {
    text.lines()
        .filter(|l| !l.starts_with('#'))
        .find(|l| l.starts_with(prefix))
        .and_then(|l| l.split_whitespace().last())
        .and_then(|v| v.parse().ok())
        .unwrap_or(f64::NAN)
}

/// Resolve a `host:port` string (e.g. a `--addr` flag) to a socket
/// address.
pub fn resolve_addr(addr: &str) -> crate::Result<SocketAddr> {
    addr.to_socket_addrs()
        .map_err(crate::Error::Io)?
        .next()
        .ok_or_else(|| crate::Error::Config(format!("'{addr}' resolves to no address")))
}

/// `Ok(None)` = need more bytes.
fn parse_response(buf: &[u8]) -> crate::Result<Option<(HttpResponse, usize)>> {
    let Some(hdr_end) = find_subslice(buf, b"\r\n\r\n") else {
        return Ok(None);
    };
    let head = std::str::from_utf8(&buf[..hdr_end])
        .map_err(|_| crate::Error::Runtime("non-utf8 response head".into()))?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| crate::Error::Runtime(format!("bad status line '{status_line}'")))?;
    let mut content_length = 0usize;
    let mut retry_after_s = None;
    for line in lines {
        let Some((name, value)) = line.split_once(':') else { continue };
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            content_length = value.parse().unwrap_or(0);
        } else if name.eq_ignore_ascii_case("retry-after") {
            retry_after_s = value.parse().ok();
        }
    }
    let body_start = hdr_end + 4;
    if buf.len() < body_start + content_length {
        return Ok(None);
    }
    let body = String::from_utf8_lossy(&buf[body_start..body_start + content_length]).into_owned();
    Ok(Some((HttpResponse { status, body, retry_after_s }, body_start + content_length)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_request_with_body_and_pipelining() {
        let wire = b"POST /v1/predict HTTP/1.1\r\nContent-Length: 7\r\n\r\n{\"a\":1}\
GET /healthz HTTP/1.1\r\n\r\n";
        match parse_request(wire) {
            Parse::Complete(req, consumed) => {
                assert_eq!(req.method, "POST");
                assert_eq!(req.path, "/v1/predict");
                assert_eq!(req.body, "{\"a\":1}");
                assert!(req.keep_alive);
                // second pipelined request parses from the remainder
                match parse_request(&wire[consumed..]) {
                    Parse::Complete(req2, _) => assert_eq!(req2.path, "/healthz"),
                    _ => panic!("pipelined request must parse"),
                }
            }
            _ => panic!("complete request must parse"),
        }
    }

    #[test]
    fn partial_requests_wait_for_more_bytes() {
        assert!(matches!(parse_request(b"POST /v1/pre"), Parse::Partial));
        assert!(matches!(
            parse_request(b"POST /p HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort"),
            Parse::Partial
        ));
    }

    #[test]
    fn connection_close_disables_keep_alive() {
        let wire = b"GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n";
        match parse_request(wire) {
            Parse::Complete(req, _) => assert!(!req.keep_alive),
            _ => panic!("must parse"),
        }
    }

    #[test]
    fn response_roundtrip() {
        let wire =
            b"HTTP/1.1 503 Service Unavailable\r\nContent-Length: 2\r\nRetry-After: 3\r\n\r\n{}";
        let (resp, consumed) = parse_response(wire).unwrap().expect("complete");
        assert_eq!(resp.status, 503);
        assert_eq!(resp.body, "{}");
        assert_eq!(resp.retry_after_s, Some(3));
        assert_eq!(consumed, wire.len());
        assert!(parse_response(&wire[..10]).unwrap().is_none(), "partial → None");
    }
}

//! Threaded batched-inference service over the photonic twin.
//!
//! Architecture (vLLM-router-like, scaled to this accelerator): clients
//! submit images over an mpsc channel; the worker thread owns the
//! [`PhotonicEngine`] + model, collects requests into dynamic batches
//! (up to `max_batch` or `batch_timeout`), executes them, and replies on
//! per-request channels. The offline toolchain has no tokio, so the event
//! loop is std::thread + mpsc — same batching semantics, simpler runtime.

use crate::coordinator::engine::{EngineOptions, PhotonicEngine};
use crate::coordinator::metrics::LatencyRecorder;
use crate::nn::{Model, Tensor};
use crate::AcceleratorConfig;
use std::sync::mpsc::{self, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub batch_timeout: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { max_batch: 8, batch_timeout: Duration::from_millis(2) }
    }
}

struct Request {
    image: Tensor,
    submitted: Instant,
    reply: Sender<Reply>,
}

/// One served prediction.
#[derive(Debug, Clone)]
pub struct Reply {
    pub class: usize,
    pub logits: Vec<f64>,
    pub latency: Duration,
    pub batch_size: usize,
}

/// Aggregate report at shutdown.
#[derive(Debug, Clone)]
pub struct ServerReport {
    pub requests: usize,
    pub batches: usize,
    pub mean_latency_us: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub throughput_rps: f64,
    pub energy_mj: f64,
    pub p_avg_w: f64,
}

/// Handle to a running inference server.
pub struct InferenceServer {
    tx: Sender<Request>,
    worker: Option<JoinHandle<ServerReport>>,
}

impl InferenceServer {
    /// Spawn the worker thread owning the engine + model.
    pub fn spawn(
        model: Model,
        cfg: AcceleratorConfig,
        opts: EngineOptions,
        masks: std::collections::BTreeMap<String, crate::sparsity::LayerMask>,
        server_cfg: ServerConfig,
    ) -> Self {
        let (tx, rx): (Sender<Request>, Receiver<Request>) = mpsc::channel();
        let worker = std::thread::spawn(move || {
            let mut engine = PhotonicEngine::new(cfg, opts);
            engine.set_masks(masks);
            // §4.1: deploy the final linear layer on non-adjacent MZI
            // columns (crosstalk-protected readout)
            if let Some((last, _, _)) = model.matmul_layers().last() {
                engine.set_protected([last.clone()].into_iter().collect());
            }
            let mut latencies = LatencyRecorder::new();
            let mut batches = 0usize;
            let started = Instant::now();
            let mut served = 0usize;
            loop {
                // block for the first request (or shutdown)
                let first = match rx.recv() {
                    Ok(r) => r,
                    Err(_) => break,
                };
                // dynamic batching: drain until max_batch or timeout
                let mut batch = vec![first];
                let deadline = Instant::now() + server_cfg.batch_timeout;
                while batch.len() < server_cfg.max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => batch.push(r),
                        Err(_) => break,
                    }
                }
                let bsz = batch.len();
                batches += 1;
                for req in batch {
                    let logits = model.forward(req.image, &mut engine);
                    let class = logits.argmax();
                    let latency = req.submitted.elapsed();
                    latencies.record(latency);
                    served += 1;
                    let _ = req.reply.send(Reply {
                        class,
                        logits: logits.data,
                        latency,
                        batch_size: bsz,
                    });
                }
            }
            let elapsed = started.elapsed().as_secs_f64().max(1e-9);
            let rep = engine.energy_report();
            ServerReport {
                requests: served,
                batches,
                mean_latency_us: latencies.mean_us(),
                p50_us: latencies.percentile_us(50.0),
                p99_us: latencies.percentile_us(99.0),
                throughput_rps: served as f64 / elapsed,
                energy_mj: rep.energy_mj,
                p_avg_w: engine.p_avg_w(),
            }
        });
        Self { tx, worker: Some(worker) }
    }

    /// Submit an image; returns a receiver for the reply.
    pub fn submit(&self, image: Tensor) -> Receiver<Reply> {
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = Request { image, submitted: Instant::now(), reply: reply_tx };
        self.tx.send(req).expect("server worker alive");
        reply_rx
    }

    /// Shut down and collect the report.
    pub fn shutdown(mut self) -> ServerReport {
        drop(self.tx);
        self.worker.take().unwrap().join().expect("worker panicked")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SparsitySupport;

    #[test]
    fn serves_batches_and_reports() {
        let model = crate::nn::models::cnn3();
        let cfg = AcceleratorConfig {
            features: SparsitySupport::NONE,
            dac: crate::config::DacKind::Edac,
            l_g: 5.0,
            ..Default::default()
        };
        let server = InferenceServer::spawn(
            model,
            cfg,
            EngineOptions::IDEAL,
            Default::default(),
            ServerConfig { max_batch: 4, batch_timeout: Duration::from_millis(1) },
        );
        let ds = crate::data::SyntheticDataset::new(crate::data::DatasetSpec::fmnist_like());
        let mut rxs = Vec::new();
        for i in 0..6 {
            let (img, _) = ds.sample(0, i);
            rxs.push(server.submit(img));
        }
        for rx in rxs {
            let reply = rx.recv_timeout(Duration::from_secs(120)).expect("reply");
            assert_eq!(reply.logits.len(), 10);
            assert!(reply.class < 10);
            assert!(reply.batch_size >= 1);
        }
        let report = server.shutdown();
        assert_eq!(report.requests, 6);
        assert!(report.batches >= 1 && report.batches <= 6);
        assert!(report.energy_mj > 0.0);
        assert!(report.p99_us >= report.p50_us);
    }
}

//! Threaded batched-inference service over the photonic twin.
//!
//! Architecture (vLLM-router-like, scaled to this accelerator): clients
//! submit images over a **bounded** mpsc channel; a dispatcher thread
//! collects requests into dynamic batches (up to `max_batch` or
//! `batch_timeout`) and shards each batch across `workers` engine
//! threads, each owning its own [`PhotonicEngine`] + model replica
//! (mirroring N physical accelerator boards behind one router). A
//! worker executes its whole shard as ONE batched forward
//! ([`Model::forward_batch`]: every matmul layer streams `shard ×
//! positions` activation columns through the programmed arrays in a
//! single engine pass — the §3.2 cycle amortization `max_batch` exists
//! to buy), then splits logits, per-request latency, and a per-request
//! energy share back into individual [`Reply`]s on per-request
//! channels. Workers stream their latency/energy ledgers into a shared
//! [`ServerMetrics`] (including the batch-occupancy histogram), which
//! both the live `/metrics` endpoint ([`crate::coordinator::net`]) and
//! the shutdown [`ServerReport`] read. The offline toolchain has no
//! tokio, so the event loop is std::thread + mpsc — same batching
//! semantics, simpler runtime.
//!
//! Overload behavior (the part an open-loop deployment lives or dies
//! by):
//!
//! * **admission control** — [`InferenceServer::submit`] sheds with
//!   [`crate::Error::Busy`] once `admission.max_in_flight` requests are
//!   in flight, instead of queueing unboundedly;
//! * **deadlines** — a request that expires while queued is dropped
//!   *before* it reaches an engine ([`ServeError::Expired`]), so stale
//!   work never wastes accelerator time;
//! * **degraded workers** — a dead engine worker fails its shard's
//!   requests with [`ServeError::WorkerLost`] and is retired from the
//!   shard rotation; the service keeps running on the survivors (the
//!   seed design `panic!`ed the whole process);
//! * **graceful drain** — [`InferenceServer::shutdown`] stops accepting,
//!   finishes everything in flight, and emits the final [`ServerReport`].

use crate::coordinator::admission::{AdmissionConfig, AdmissionController, Permit};
use crate::coordinator::engine::{EngineOptions, PhotonicEngine};
use crate::coordinator::metrics::{MetricsSnapshot, ServerMetrics, ThermalGauges};
use crate::exec::partition_ranges;
use crate::nn::{Model, Tensor};
use crate::thermal::{DriftConfig, ThermalPolicy};
use crate::AcceleratorConfig;
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub batch_timeout: Duration,
    /// Engine worker threads the dispatcher shards batches across; each
    /// owns a full engine + model replica. 1 reproduces the single-board
    /// service exactly.
    pub workers: usize,
    /// Worker threads inside each engine's compiled execution path
    /// ([`PhotonicEngine::set_threads`]). Keep `workers ×
    /// engine_threads` at or below the host's cores.
    pub engine_threads: usize,
    /// Load-shedding and deadline policy.
    pub admission: AdmissionConfig,
    /// Runtime thermal-drift model + recalibration policy. The default
    /// (`drift: None`) reproduces the seed behavior: phases frozen at
    /// programming time, no drift, no recalibration.
    pub thermal: ThermalServerConfig,
}

/// Thermal-drift runtime knobs for the serving stack. Each engine
/// worker gets the drift config with its own `worker_id`, so replicas
/// behind the router drift (and self-heat with their own traffic)
/// independently.
#[derive(Debug, Clone, Default)]
pub struct ThermalServerConfig {
    /// `Some` enables the drift runtime on every engine worker.
    pub drift: Option<DriftConfig>,
    /// When/how workers recalibrate (ignored while `drift` is `None`).
    pub policy: ThermalPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            batch_timeout: Duration::from_millis(2),
            workers: 1,
            engine_threads: 1,
            admission: AdmissionConfig::default(),
            thermal: ThermalServerConfig::default(),
        }
    }
}

struct Request {
    image: Tensor,
    submitted: Instant,
    deadline: Option<Instant>,
    permit: Permit,
    reply: Sender<ReplyResult>,
}

impl Request {
    fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// One served prediction.
#[derive(Debug, Clone)]
pub struct Reply {
    pub class: usize,
    pub logits: Vec<f64>,
    pub latency: Duration,
    pub batch_size: usize,
    /// This request's share of the accelerator energy its batched
    /// engine pass spent (the shard's engine-ledger delta apportioned by
    /// column share — every request of a shard streams the same column
    /// count, so the share is `delta / shard_len`), in mJ.
    pub energy_mj: f64,
}

/// Why an admitted request still failed (shed-at-the-door is
/// [`crate::Error::Busy`] from [`InferenceServer::submit`] instead).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeError {
    /// The deadline passed while the request was queued; it was dropped
    /// before wasting engine time.
    Expired,
    /// The engine worker holding the request died before replying; the
    /// request is safe to retry (it never executed to completion).
    WorkerLost,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Expired => write!(f, "request deadline expired in queue"),
            ServeError::WorkerLost => write!(f, "engine worker died before replying"),
        }
    }
}

impl From<ServeError> for crate::Error {
    fn from(e: ServeError) -> Self {
        crate::Error::Runtime(e.to_string())
    }
}

/// What a reply receiver yields: a prediction, or the reason the
/// admitted request died in the pipeline.
pub type ReplyResult = Result<Reply, ServeError>;

/// Aggregate report at shutdown.
#[derive(Debug, Clone)]
pub struct ServerReport {
    pub requests: usize,
    pub batches: usize,
    /// Mean requests per dispatched dynamic batch — how much of the
    /// `max_batch` compute amortization traffic actually realized.
    pub mean_batch_occupancy: f64,
    pub workers: usize,
    pub mean_latency_us: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub throughput_rps: f64,
    pub energy_mj: f64,
    pub p_avg_w: f64,
    /// Requests shed at admission ([`crate::Error::Busy`]).
    pub shed: u64,
    /// Admitted requests dropped on an expired deadline.
    pub expired: u64,
    /// Admitted requests failed by a dead engine worker.
    pub worker_lost: u64,
    /// Thermal recalibration actions across workers (0 = runtime off).
    pub recalibrations: u64,
    /// Chunks recompiled by thermal recalibration across workers.
    pub recal_chunks: u64,
}

/// A shard of a dynamic batch, tagged with the full batch size (clients
/// observe the batch they rode in, not the shard).
struct Shard {
    requests: Vec<Request>,
    batch_size: usize,
}

/// Depth of each engine worker's shard queue. Small on purpose: the
/// dispatcher blocking on a busy worker is backpressure, and the
/// admission cap already bounds total queued work.
const WORKER_QUEUE_DEPTH: usize = 2;

fn spawn_engine_worker(
    widx: usize,
    model: Model,
    cfg: AcceleratorConfig,
    opts: EngineOptions,
    masks: std::collections::BTreeMap<String, crate::sparsity::LayerMask>,
    engine_threads: usize,
    thermal: ThermalServerConfig,
    metrics: Arc<ServerMetrics>,
    rx: Receiver<Shard>,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        let mut engine = PhotonicEngine::new(cfg, opts);
        engine.set_threads(engine_threads);
        engine.set_masks(masks);
        // §4.1: deploy the final linear layer on non-adjacent MZI
        // columns (crosstalk-protected readout)
        if let Some((last, _, _)) = model.matmul_layers().last() {
            engine.set_protected([last.clone()].into_iter().collect());
        }
        // thermal-drift runtime: this worker's replica drifts with wall
        // time (scaled) and its own served-request self-heating
        let time_scale = thermal.drift.as_ref().map(|d| d.time_scale);
        if let Some(drift) = thermal.drift {
            engine.set_thermal(
                DriftConfig { worker_id: widx as u64, ..drift },
                thermal.policy,
            );
        }
        let started = Instant::now();
        let mut served: u64 = 0;
        while let Ok(shard) = rx.recv() {
            let batch_size = shard.batch_size;
            // second-chance deadline check, hoisted to ONE scan over the
            // whole shard *before* batch assembly: requests that expired
            // in this worker's shard queue never inflate the batched
            // matmul's column count
            let now = Instant::now();
            let (live, dead): (Vec<Request>, Vec<Request>) =
                shard.requests.into_iter().partition(|r| !r.expired(now));
            if !dead.is_empty() {
                metrics.note_expired(dead.len() as u64);
                for req in dead {
                    let Request { permit, reply, .. } = req;
                    drop(permit);
                    let _ = reply.send(Err(ServeError::Expired));
                }
            }
            if !live.is_empty() {
                let n = live.len();
                let mut images = Vec::with_capacity(n);
                let mut routing = Vec::with_capacity(n);
                for req in live {
                    let Request { image, submitted, permit, reply, .. } = req;
                    images.push(image);
                    routing.push((submitted, permit, reply));
                }
                // the tentpole: the whole shard is ONE batched forward —
                // every matmul layer runs once with n_cols = n × positions
                let e_before = engine.energy_report().energy_mj;
                let outputs = model.forward_batch(images, &mut engine);
                // apportion the engine's energy delta by column share
                // (uniform: same model, same column count per request)
                let e_each = (engine.energy_report().energy_mj - e_before) / n as f64;
                served += n as u64;
                for ((submitted, permit, reply), logits) in routing.into_iter().zip(outputs) {
                    let class = logits.argmax();
                    let latency = submitted.elapsed();
                    metrics.record_served(latency);
                    // release the slot before replying so a ping-pong
                    // client can re-submit without a spurious shed
                    drop(permit);
                    let _ = reply.send(Ok(Reply {
                        class,
                        logits: logits.data,
                        latency,
                        batch_size,
                        energy_mj: e_each,
                    }));
                }
            }
            let rep = engine.energy_report();
            metrics.set_worker_energy(widx, rep.energy_mj, rep.time_ms);
            // advance the drift runtime once per shard and publish the
            // post-tick gauges
            if let Some(scale) = time_scale {
                let t_s = started.elapsed().as_secs_f64() * scale;
                if let Some(s) = engine.thermal_tick(t_s, served) {
                    metrics.set_worker_thermal(widx, ThermalGauges::from(s));
                }
            }
        }
    })
}

/// Handle to a running inference server. Cheap to share behind an
/// `Arc`: every method takes `&self`, including [`shutdown`].
///
/// [`shutdown`]: InferenceServer::shutdown
pub struct InferenceServer {
    /// `None` once draining; taking it closes the dispatcher inbox.
    tx: Mutex<Option<SyncSender<Request>>>,
    admission: Arc<AdmissionController>,
    metrics: Arc<ServerMetrics>,
    dispatcher: Mutex<Option<JoinHandle<ServerReport>>>,
}

impl InferenceServer {
    /// Spawn the dispatcher + engine worker threads.
    pub fn spawn(
        model: Model,
        cfg: AcceleratorConfig,
        opts: EngineOptions,
        masks: std::collections::BTreeMap<String, crate::sparsity::LayerMask>,
        server_cfg: ServerConfig,
    ) -> Self {
        let n_workers = server_cfg.workers.max(1);
        let admission = AdmissionController::new(server_cfg.admission.clone());
        let metrics = Arc::new(ServerMetrics::new(n_workers));
        // inbox bound = admission cap: a submit holding a permit can
        // never block on a full channel
        let inbox = server_cfg.admission.max_in_flight.max(1);
        let (tx, rx): (SyncSender<Request>, Receiver<Request>) = mpsc::sync_channel(inbox);
        let dispatcher = {
            let admission = Arc::clone(&admission);
            let metrics = Arc::clone(&metrics);
            std::thread::spawn(move || {
                run_dispatcher(model, cfg, opts, masks, server_cfg, admission, metrics, rx)
            })
        };
        Self {
            tx: Mutex::new(Some(tx)),
            admission,
            metrics,
            dispatcher: Mutex::new(Some(dispatcher)),
        }
    }

    /// Submit an image with no explicit deadline (the configured
    /// `default_deadline` still applies).
    pub fn submit(&self, image: Tensor) -> crate::Result<Receiver<ReplyResult>> {
        self.submit_with_deadline(image, None)
    }

    /// Submit an image; returns a receiver for the reply.
    ///
    /// Errors instead of panicking (the seed `expect`ed on a dead
    /// dispatcher): [`crate::Error::Busy`] when admission sheds the
    /// request, [`crate::Error::Runtime`] when the server is draining or
    /// the dispatcher died.
    pub fn submit_with_deadline(
        &self,
        image: Tensor,
        deadline: Option<Duration>,
    ) -> crate::Result<Receiver<ReplyResult>> {
        let permit = self.admission.try_admit()?;
        let tx = match &*self.tx.lock().unwrap() {
            Some(tx) => tx.clone(),
            None => {
                return Err(crate::Error::Runtime(
                    "inference server draining: not accepting new requests".into(),
                ))
            }
        };
        let now = Instant::now();
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = Request {
            image,
            submitted: now,
            deadline: self.admission.deadline_from(now, deadline),
            permit,
            reply: reply_tx,
        };
        tx.send(req).map_err(|_| {
            crate::Error::Runtime("inference dispatcher disconnected".into())
        })?;
        Ok(reply_rx)
    }

    /// Admission state (queue depth, shed counters) for the front-end.
    pub fn admission(&self) -> Arc<AdmissionController> {
        Arc::clone(&self.admission)
    }

    /// Live serving metrics (latency, energy) for the front-end.
    pub fn metrics(&self) -> Arc<ServerMetrics> {
        Arc::clone(&self.metrics)
    }

    /// Point-in-time metrics view.
    pub fn snapshot(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Graceful drain: stop accepting (subsequent [`submit`]s get
    /// [`crate::Error::Runtime`]), finish every in-flight request, join
    /// the workers, and return the final report. Errors on double
    /// shutdown or a panicked dispatcher.
    ///
    /// [`submit`]: InferenceServer::submit
    pub fn shutdown(&self) -> crate::Result<ServerReport> {
        drop(self.tx.lock().unwrap().take());
        let handle = self.dispatcher.lock().unwrap().take().ok_or_else(|| {
            crate::Error::Runtime("inference server already shut down".into())
        })?;
        handle
            .join()
            .map_err(|_| crate::Error::Runtime("inference dispatcher panicked".into()))
    }
}

#[allow(clippy::too_many_arguments)]
fn run_dispatcher(
    model: Model,
    cfg: AcceleratorConfig,
    opts: EngineOptions,
    masks: std::collections::BTreeMap<String, crate::sparsity::LayerMask>,
    server_cfg: ServerConfig,
    admission: Arc<AdmissionController>,
    metrics: Arc<ServerMetrics>,
    rx: Receiver<Request>,
) -> ServerReport {
    let n_workers = server_cfg.workers.max(1);
    let mut worker_txs: Vec<Option<SyncSender<Shard>>> = Vec::with_capacity(n_workers);
    let mut handles = Vec::with_capacity(n_workers);
    for widx in 0..n_workers {
        let (wtx, wrx) = mpsc::sync_channel::<Shard>(WORKER_QUEUE_DEPTH);
        handles.push(spawn_engine_worker(
            widx,
            model.clone(),
            cfg.clone(),
            opts,
            masks.clone(),
            server_cfg.engine_threads.max(1),
            server_cfg.thermal.clone(),
            Arc::clone(&metrics),
            wrx,
        ));
        worker_txs.push(Some(wtx));
    }

    let started = Instant::now();
    loop {
        // block for the first request (or shutdown)
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break,
        };
        // dynamic batching: drain until max_batch or timeout
        let mut batch = vec![first];
        let deadline = Instant::now() + server_cfg.batch_timeout;
        while batch.len() < server_cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(_) => break,
            }
        }
        // drop expired requests before they cost engine time
        let now = Instant::now();
        let (mut batch, dead): (Vec<Request>, Vec<Request>) =
            batch.into_iter().partition(|r| !r.expired(now));
        if !dead.is_empty() {
            metrics.note_expired(dead.len() as u64);
            for req in dead {
                let _ = req.reply.send(Err(ServeError::Expired));
            }
        }
        if batch.is_empty() {
            continue;
        }
        let alive: Vec<usize> = worker_txs
            .iter()
            .enumerate()
            .filter_map(|(i, t)| t.is_some().then_some(i))
            .collect();
        if alive.is_empty() {
            // every engine is gone: degrade to failing requests fast
            // (clients see retryable errors, the process stays up)
            metrics.note_worker_lost(batch.len() as u64);
            for req in batch {
                let _ = req.reply.send(Err(ServeError::WorkerLost));
            }
            continue;
        }
        let batch_size = batch.len();
        metrics.note_batch();
        metrics.note_batch_occupancy(batch_size);
        // shard the batch across live engine workers (contiguous
        // near-equal splits; lone requests go to the first live worker)
        let ranges = partition_ranges(batch.len(), alive.len());
        for (k, range) in ranges.into_iter().enumerate().rev() {
            let requests: Vec<Request> = batch.drain(range).collect();
            let widx = alive[k];
            let sent = worker_txs[widx]
                .as_ref()
                .expect("alive index")
                .send(Shard { requests, batch_size });
            if let Err(mpsc::SendError(shard)) = sent {
                // worker died: retire it and fail its shard's requests
                // as retryable, instead of aborting the process
                worker_txs[widx] = None;
                metrics.note_worker_lost(shard.requests.len() as u64);
                for req in shard.requests {
                    let _ = req.reply.send(Err(ServeError::WorkerLost));
                }
            }
        }
    }
    // shutdown: close worker queues, join, report from the shared ledger
    worker_txs.clear();
    for h in handles {
        let _ = h.join();
    }
    let snap = metrics.snapshot();
    let elapsed = started.elapsed().as_secs_f64().max(1e-9);
    ServerReport {
        requests: snap.requests,
        batches: snap.batches,
        mean_batch_occupancy: snap.mean_batch_occupancy,
        workers: n_workers,
        mean_latency_us: snap.mean_us,
        p50_us: snap.p50_us,
        p99_us: snap.p99_us,
        throughput_rps: snap.requests as f64 / elapsed,
        energy_mj: snap.energy_mj,
        // average power per occupied accelerator slot-time, consistent
        // with the single-worker definition
        p_avg_w: snap.p_avg_w,
        shed: admission.shed_total(),
        expired: snap.expired,
        worker_lost: snap.worker_lost,
        recalibrations: snap.recalibrations,
        recal_chunks: snap.recal_chunks,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SparsitySupport;

    fn test_cfg() -> AcceleratorConfig {
        AcceleratorConfig {
            features: SparsitySupport::NONE,
            dac: crate::config::DacKind::Edac,
            l_g: 5.0,
            ..Default::default()
        }
    }

    fn sample_img(class: usize, i: usize) -> Tensor {
        let ds = crate::data::SyntheticDataset::new(crate::data::DatasetSpec::fmnist_like());
        ds.sample(class as u64, i).0
    }

    #[test]
    fn serves_batches_and_reports() {
        let server = InferenceServer::spawn(
            crate::nn::models::cnn3(),
            test_cfg(),
            EngineOptions::IDEAL,
            Default::default(),
            ServerConfig {
                max_batch: 4,
                batch_timeout: Duration::from_millis(1),
                ..Default::default()
            },
        );
        let mut rxs = Vec::new();
        for i in 0..6 {
            rxs.push(server.submit(sample_img(0, i)).expect("admitted"));
        }
        for rx in rxs {
            let reply = rx
                .recv_timeout(Duration::from_secs(120))
                .expect("reply")
                .expect("served");
            assert_eq!(reply.logits.len(), 10);
            assert!(reply.class < 10);
            assert!(reply.batch_size >= 1);
            assert!(
                reply.energy_mj > 0.0,
                "every request carries its batched-pass energy share"
            );
        }
        let report = server.shutdown().expect("report");
        assert_eq!(report.requests, 6);
        assert!(report.batches >= 1 && report.batches <= 6);
        assert!(
            report.mean_batch_occupancy >= 1.0
                && report.mean_batch_occupancy <= 4.0 + 1e-9,
            "mean occupancy within [1, max_batch]: {}",
            report.mean_batch_occupancy
        );
        assert!(
            (report.mean_batch_occupancy - 6.0 / report.batches as f64).abs() < 1e-9,
            "mean occupancy consistent with requests/batches"
        );
        assert!(report.energy_mj > 0.0);
        assert!(report.p99_us >= report.p50_us);
        assert_eq!(report.shed, 0);
        assert_eq!(report.expired, 0);
    }

    /// The batched engine pass must return exactly what per-request
    /// passes on a fresh engine return: EngineOptions::IDEAL has no
    /// per-call randomness, so the served logits are reproducible by a
    /// standalone engine regardless of how the server batched them.
    #[test]
    fn served_logits_equal_offline_forward_regardless_of_batching() {
        let model = crate::nn::models::cnn3();
        let server = InferenceServer::spawn(
            model.clone(),
            test_cfg(),
            EngineOptions::IDEAL,
            Default::default(),
            ServerConfig {
                max_batch: 8,
                batch_timeout: Duration::from_millis(50),
                ..Default::default()
            },
        );
        let images: Vec<Tensor> = (0..5).map(|i| sample_img(2, i)).collect();
        let rxs: Vec<_> = images
            .iter()
            .map(|img| server.submit(img.clone()).expect("admitted"))
            .collect();
        let mut offline = PhotonicEngine::new(test_cfg(), EngineOptions::IDEAL);
        if let Some((last, _, _)) = model.matmul_layers().last() {
            offline.set_protected([last.clone()].into_iter().collect());
        }
        for (img, rx) in images.into_iter().zip(rxs) {
            let want = model.forward(img, &mut offline);
            let reply = rx
                .recv_timeout(Duration::from_secs(120))
                .expect("reply")
                .expect("served");
            assert_eq!(reply.logits, want.data, "batched serving moved bits");
        }
        server.shutdown().expect("report");
    }

    #[test]
    fn multi_worker_sharding_serves_everything() {
        let server = InferenceServer::spawn(
            crate::nn::models::cnn3(),
            test_cfg(),
            EngineOptions::IDEAL,
            Default::default(),
            ServerConfig {
                max_batch: 8,
                batch_timeout: Duration::from_millis(2),
                workers: 3,
                engine_threads: 1,
                ..Default::default()
            },
        );
        let mut rxs = Vec::new();
        for i in 0..9 {
            rxs.push(server.submit(sample_img(7, i)).expect("admitted"));
        }
        // every request answered exactly once, with sane logits
        for rx in rxs {
            let reply = rx
                .recv_timeout(Duration::from_secs(120))
                .expect("reply")
                .expect("served");
            assert_eq!(reply.logits.len(), 10);
        }
        let report = server.shutdown().expect("report");
        assert_eq!(report.requests, 9);
        assert_eq!(report.workers, 3);
        assert!(report.energy_mj > 0.0, "all workers account energy");
    }

    #[test]
    fn admission_cap_sheds_with_conservation() {
        // one slot, and a long batching window so the first request is
        // still holding its permit when the rest arrive
        let server = InferenceServer::spawn(
            crate::nn::models::cnn3(),
            test_cfg(),
            EngineOptions::IDEAL,
            Default::default(),
            ServerConfig {
                max_batch: 8,
                batch_timeout: Duration::from_millis(300),
                admission: AdmissionConfig { max_in_flight: 1, ..Default::default() },
                ..Default::default()
            },
        );
        let rx = server.submit(sample_img(0, 0)).expect("first admitted");
        let mut shed = 0;
        for i in 0..5 {
            match server.submit(sample_img(0, i + 1)) {
                Err(crate::Error::Busy { retry_after_ms }) => {
                    assert!(retry_after_ms > 0);
                    shed += 1;
                }
                Ok(_) => panic!("cap 1 must shed while slot is held"),
                Err(e) => panic!("unexpected error {e}"),
            }
        }
        assert_eq!(shed, 5);
        let reply = rx.recv_timeout(Duration::from_secs(120)).expect("reply");
        assert!(reply.is_ok(), "admitted request must be served");
        let report = server.shutdown().expect("report");
        assert_eq!(report.requests, 1);
        assert_eq!(report.shed, 5);
    }

    #[test]
    fn expired_deadline_dropped_before_engine() {
        let server = InferenceServer::spawn(
            crate::nn::models::cnn3(),
            test_cfg(),
            EngineOptions::IDEAL,
            Default::default(),
            ServerConfig::default(),
        );
        // a zero deadline is already expired when the dispatcher looks
        let rx = server
            .submit_with_deadline(sample_img(0, 0), Some(Duration::ZERO))
            .expect("admitted");
        let reply = rx.recv_timeout(Duration::from_secs(30)).expect("reply");
        assert!(matches!(reply, Err(ServeError::Expired)), "got {reply:?}");
        let report = server.shutdown().expect("report");
        assert_eq!(report.requests, 0, "expired work never reached an engine");
        assert_eq!(report.expired, 1);
    }

    #[test]
    fn thermal_runtime_recalibrates_and_reports() {
        // heat-only drift (time_scale 0 freezes the ambient term), so
        // the envelope depends only on each worker's served count —
        // fully deterministic under test scheduling
        let server = InferenceServer::spawn(
            crate::nn::models::cnn3(),
            test_cfg(),
            EngineOptions::IDEAL,
            Default::default(),
            ServerConfig {
                max_batch: 2,
                batch_timeout: Duration::from_millis(1),
                thermal: ThermalServerConfig {
                    drift: Some(DriftConfig {
                        ambient_amp_rad: 0.0,
                        self_heat_amp_rad: 0.2,
                        self_heat_tau_reqs: 4.0,
                        time_scale: 0.0,
                        ..DriftConfig::default()
                    }),
                    policy: ThermalPolicy::Threshold { budget_rad: 0.01 },
                },
                ..Default::default()
            },
        );
        // serve sequentially so the single worker ticks between requests
        for i in 0..10 {
            let rx = server.submit(sample_img(3, i)).expect("admitted");
            let reply =
                rx.recv_timeout(Duration::from_secs(120)).expect("reply").expect("served");
            assert_eq!(reply.logits.len(), 10);
        }
        let snap = server.snapshot();
        assert!(snap.thermal_drift_rad > 0.0, "self-heating must register");
        assert!(snap.thermal_chunks > 0, "chunks under drift management");
        let report = server.shutdown().expect("report");
        assert_eq!(report.requests, 10);
        assert!(
            report.recalibrations >= 1,
            "threshold policy must have recalibrated: {report:?}"
        );
        assert!(report.recal_chunks >= 1);
    }

    #[test]
    fn shutdown_drains_in_flight_work() {
        let server = InferenceServer::spawn(
            crate::nn::models::cnn3(),
            test_cfg(),
            EngineOptions::IDEAL,
            Default::default(),
            ServerConfig {
                max_batch: 8,
                batch_timeout: Duration::from_millis(100),
                ..Default::default()
            },
        );
        let rxs: Vec<_> =
            (0..5).map(|i| server.submit(sample_img(1, i)).expect("admitted")).collect();
        // immediate shutdown must still serve everything already queued
        let report = server.shutdown().expect("report");
        assert_eq!(report.requests, 5, "drain serves queued work");
        for rx in rxs {
            assert!(rx.recv().expect("reply buffered").is_ok());
        }
        // post-drain submits fail cleanly, no panic
        match server.submit(sample_img(1, 9)) {
            Err(crate::Error::Runtime(_)) => {}
            other => panic!("expected Runtime error after shutdown, got {other:?}"),
        }
        assert!(server.shutdown().is_err(), "double shutdown is an error");
    }
}

//! Threaded batched-inference service over the photonic twin.
//!
//! Architecture (vLLM-router-like, scaled to this accelerator): clients
//! submit images over a **bounded** mpsc channel; a dispatcher thread
//! collects requests into dynamic batches (up to `max_batch` or
//! `batch_timeout`) and shards each batch across `workers` engine
//! replicas, each owning its own [`PhotonicEngine`] + model replica
//! (mirroring N physical accelerator boards behind one router). A
//! worker executes its whole shard as ONE batched forward
//! ([`Model::forward_batch`]: every matmul layer streams `shard ×
//! positions` activation columns through the programmed arrays in a
//! single engine pass — the §3.2 cycle amortization `max_batch` exists
//! to buy), then splits logits, per-request latency, and a per-request
//! energy share back into individual [`Reply`]s on per-request
//! channels. Workers stream their latency/energy ledgers into a shared
//! [`ServerMetrics`] (including the batch-occupancy histogram), which
//! both the live `/metrics` endpoint ([`crate::coordinator::net`]) and
//! the shutdown [`ServerReport`] read. The offline toolchain has no
//! tokio, so the event loop is std::thread + mpsc — same batching
//! semantics, simpler runtime.
//!
//! ## Cluster scheduling (replica routing)
//!
//! Each worker slot owns a persistent [`ReplicaQueue`] of shards. The
//! dispatcher snapshots every live replica as a
//! [`scheduler::ReplicaState`] — queue depth, EWMA shard service time,
//! continuous thermal heat score, brownout bit — and
//! [`scheduler::plan_shards`] splits each dynamic batch across the
//! coolest, least-loaded replicas. With `ClusterConfig::steal` enabled,
//! an idle replica steals queued shards from the deepest peer queue
//! (victim pops front, thief pops back), trading strict per-replica
//! shard ordering for tail latency. Queues outlive worker generations:
//! a respawned worker resumes its predecessor's backlog, and a
//! generation token retires zombies (a replaced worker exits at its
//! next queue visit instead of double-serving).
//!
//! [`scheduler::ReplicaState`]: crate::coordinator::scheduler::ReplicaState
//! [`scheduler::plan_shards`]: crate::coordinator::scheduler::plan_shards
//!
//! ## Self-healing (worker supervision)
//!
//! The dispatcher doubles as a supervisor. Each engine worker parks the
//! shard it just received in a per-generation **checkpoint slot**
//! ([`WorkerHealth::checkpoint`]) before committing to execute it, and
//! stamps a heartbeat ([`WorkerHealth::busy_since_ms`]). Every
//! supervision tick the dispatcher checks each worker:
//!
//! * **dead** (thread finished, e.g. a panic) — the checkpointed shard
//!   is recovered losslessly, the worker is respawned with a fresh
//!   engine built from the retained config, and the recovered requests
//!   are re-dispatched with bounded retries + exponential backoff
//!   ([`SupervisorConfig`]) before surfacing [`ServeError::WorkerLost`];
//! * **stuck** (busy past the shard watchdog) — the shard is *stolen*
//!   from the checkpoint slot (try-lock, never blocking) and the zombie
//!   is detached; because a worker only executes a shard it can still
//!   take *out* of its slot, execution stays exactly-once.
//!
//! Every recovery path is exercisable on demand through a seedable
//! [`FaultPlan`] (`ServerConfig::faults`, `scatter serve --faults`,
//! `scatter bench chaos`).
//!
//! ## Thermal brownout
//!
//! With a drift runtime enabled and `brownout_budget_rad` set, a worker
//! whose post-tick phase-error estimate exceeds the budget is marked
//! **browned out**: the dispatcher steers new shards to cooler replicas
//! (or, when every replica is hot, halves shard sizes so each ticks and
//! recalibrates sooner), and the worker force-recalibrates before its
//! next shard — graceful degradation instead of serving silently
//! drifted values. Below the brownout threshold the same phase-error
//! estimate feeds the router continuously (the replica heat score), so
//! load drifts toward thermally settled hardware *before* anyone trips
//! a brownout.
//!
//! ## In-serving DST + mask hot-swap (the co-design loop)
//!
//! With `ServerConfig::dst` enabled, the dispatcher steps a resumable
//! [`DstJob`] on its idle headroom — paced by `dst.period` and gated on
//! at least one idle, non-browned-out replica — feeding it the weight
//! column statistics (fixed: serving never retrains) and the average
//! power from the live energy ledger. Every candidate the job emits
//! becomes a versioned [`MaskArtifact`] (monotone generation id,
//! content-hashed, optionally persisted atomically) published to the
//! workers. Each worker canaries the artifact at its next **shard
//! boundary**: requests in flight finished on the old generation, the
//! next shard has not started, so the cutover is atomic from the
//! client's point of view. The canary forwards a fixed probe batch on
//! the old and the new generation and promotes only if the argmax
//! agreement clears `dst.canary_threshold`; a failing candidate is
//! rolled back (the engine reprograms exactly the affected chunks back)
//! and vetoed for every peer. No request is ever dropped, delayed past
//! one probe pass, or served by a half-programmed engine on either
//! path.
//!
//! ## Device-fault repair (sentinel + quarantine)
//!
//! With `ServerConfig::repair` configured, each worker's fabric can be
//! seeded with deterministic *device* faults ([`DeviceFaultPlan`]:
//! stuck MZI phases, dead rerouter branches, dead photodetector rows) —
//! at boot (infant mortality) or after `inject_after_shards` served
//! shards (mid-life failure). With `repair.sentinel` on, the worker
//! spends idle headroom (paced by `repair.probe_period`, always at a
//! shard boundary) forwarding fixed sentinel probes per programmed
//! chunk and comparing against golden digests captured at programming
//! time; a deviation localizes the fault to (chunk, rows, cols). The
//! repair path quarantines the faulted cells by diffing a pruned mask
//! through the *same* [`PhotonicEngine::apply_mask_update`] + canary +
//! rollback machinery the DST hot-swap uses, so traffic outside the
//! quarantined region is untouched. A finding no mask can cover (dense
//! layer, exhausted region) marks the replica **degraded**: the cluster
//! scheduler down-ranks it right after load (it still serves — graceful
//! degradation, not eviction), `/healthz` reports `degraded` with
//! reason `device_fault`, and `/readyz` flips 503 only when *every*
//! replica is degraded.
//!
//! Overload behavior (the part an open-loop deployment lives or dies
//! by):
//!
//! * **admission control** — [`InferenceServer::submit`] sheds with
//!   [`crate::Error::Busy`] once `admission.max_in_flight` requests are
//!   in flight, instead of queueing unboundedly;
//! * **deadlines** — a request that expires while queued is dropped
//!   *before* it reaches an engine ([`ServeError::Expired`]), so stale
//!   work never wastes accelerator time;
//! * **degraded workers** — a dead engine worker is respawned and its
//!   in-flight shard re-dispatched; only a slot whose restart budget is
//!   exhausted is retired, and requests fail with
//!   [`ServeError::WorkerLost`] only after their retry budget is spent
//!   (the seed design `panic!`ed the whole process);
//! * **graceful drain** — [`InferenceServer::shutdown`] stops accepting,
//!   finishes everything in flight (supervision stays live mid-drain),
//!   and emits the final [`ServerReport`].
//!
//! ## Configuration
//!
//! [`ServerConfig`] is constructed through [`ServerConfig::builder`],
//! which validates invariants (`workers >= 1`, `max_batch >= 1`,
//! `watchdog > batch_timeout`, ...) and returns typed
//! [`crate::Error::Config`] errors, or loaded from a JSON file
//! ([`ServerConfig::from_json`], `scatter serve --config FILE`).

use crate::coordinator::admission::{AdmissionConfig, AdmissionController, Permit};
use crate::coordinator::engine::{EngineOptions, PhotonicEngine};
use crate::coordinator::faults::{FaultAction, FaultPlan};
use crate::coordinator::metrics::{MetricsSnapshot, ServerMetrics, ThermalGauges};
use crate::coordinator::scheduler::{plan_shards, ClusterConfig, ReplicaState};
use crate::devices::{Mzi, MziSpec};
use crate::exec::KernelPrecision;
use crate::nn::{Model, Tensor};
use crate::ptc::faults::DeviceFaultPlan;
use crate::runtime::MaskArtifact;
use crate::sparsity::{chunked_col_norms, DstJob};
use crate::thermal::{DriftConfig, GammaModel, ThermalPolicy};
use crate::util::{Json, XorShiftRng};
use crate::AcceleratorConfig;
use std::collections::{BTreeMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Serving-stack configuration. Construct through
/// [`ServerConfig::builder`] (validated) or [`ServerConfig::from_json`]
/// (`--config FILE`); `Default` is the valid single-replica baseline.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub(crate) max_batch: usize,
    pub(crate) batch_timeout: Duration,
    /// Engine worker replicas the dispatcher routes batches across; each
    /// owns a full engine + model replica. 1 reproduces the single-board
    /// service exactly.
    pub(crate) workers: usize,
    /// Worker threads inside each engine's compiled execution path
    /// ([`PhotonicEngine::set_threads`]). Keep `workers ×
    /// engine_threads` at or below the host's cores.
    pub(crate) engine_threads: usize,
    /// Load-shedding and deadline policy.
    pub(crate) admission: AdmissionConfig,
    /// Runtime thermal-drift model + recalibration policy. The default
    /// (`drift: None`) reproduces the seed behavior: phases frozen at
    /// programming time, no drift, no recalibration.
    pub(crate) thermal: ThermalServerConfig,
    /// Worker supervision: watchdog, retry budget, restart budget.
    pub(crate) supervisor: SupervisorConfig,
    /// Deterministic fault injection (empty in production).
    pub(crate) faults: FaultPlan,
    /// Cluster-scheduler knobs (work stealing).
    pub(crate) cluster: ClusterConfig,
    /// In-serving DST + mask hot-swap (the co-design loop). Disabled by
    /// default: the deployed masks serve untouched.
    pub(crate) dst: DstServerConfig,
    /// Device-fault injection + sentinel detection + quarantine repair.
    /// Disabled by default: no defects, no probing.
    pub(crate) repair: RepairServerConfig,
    /// Kernel precision every engine worker runs at
    /// ([`PhotonicEngine::set_precision`]). `Exact` (the default) keeps
    /// the bit-exact f64 quad kernel; `Quantized` switches the hot loop
    /// to the integer SIMD kernel (i16 codes, `i32` accumulation),
    /// gated by argmax agreement >= 0.99 against `Exact`.
    pub(crate) precision: KernelPrecision,
}

/// Thermal-drift runtime knobs for the serving stack. Each engine
/// worker gets the drift config with its own `worker_id`, so replicas
/// behind the router drift (and self-heat with their own traffic)
/// independently.
#[derive(Debug, Clone, Default)]
pub struct ThermalServerConfig {
    /// `Some` enables the drift runtime on every engine worker.
    pub drift: Option<DriftConfig>,
    /// When/how workers recalibrate (ignored while `drift` is `None`).
    pub policy: ThermalPolicy,
    /// `Some(budget)` enables thermal brownout: a worker whose
    /// post-tick phase-error estimate exceeds `budget` rad is steered
    /// around and force-recalibrated before its next shard.
    pub brownout_budget_rad: Option<f64>,
    /// Restrict the drift runtime to one replica (the rest stay ideal).
    /// A test/bench hook: force exactly one replica hot and watch the
    /// router steer load off it.
    pub drift_only_worker: Option<usize>,
}

/// In-serving DST knobs — the serving half of the co-design loop. When
/// enabled, the dispatcher steps a power-optimizing [`DstJob`] on its
/// idle headroom, publishes each candidate as a versioned
/// [`MaskArtifact`], and workers canary + hot-swap it at their next
/// shard boundary.
#[derive(Debug, Clone)]
pub struct DstServerConfig {
    /// `true` runs the DST loop; `false` (default) serves the deployed
    /// masks untouched.
    pub enabled: bool,
    /// Minimum spacing between DST rounds.
    pub period: Duration,
    /// Prune/grow rounds before the cosine schedule ends the job.
    pub rounds: usize,
    /// Canary gate: the fraction of probe images whose argmax must
    /// agree between the old and the new generation for a candidate to
    /// promote. 0 disables the gate; 1 demands exact agreement.
    pub canary_threshold: f64,
    /// Fault-injection hook (`scatter bench swap` / CI): force every
    /// candidate's canary verdict to *fail*, so the rollback path runs
    /// deterministically. The mechanical swap — apply, probe, roll
    /// back, veto — still executes for real.
    pub inject_bad_canary: bool,
    /// `Some(dir)` persists every emitted generation atomically as
    /// `mask_gen_NNNNNN.json` (provenance; never serving-critical).
    pub artifact_dir: Option<PathBuf>,
}

impl Default for DstServerConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            period: Duration::from_millis(20),
            rounds: 40,
            canary_threshold: 0.5,
            inject_bad_canary: false,
            artifact_dir: None,
        }
    }
}

/// Device-fault lifecycle knobs (`repair` section of the JSON config):
/// which hardware defects to inject (`--device-faults`), when they
/// strike, and whether the sentinel probe + quarantine-repair loop runs
/// against them.
#[derive(Debug, Clone)]
pub struct RepairServerConfig {
    /// Hardware defects injected into every engine replica's fabric
    /// (spec grammar in [`DeviceFaultPlan`]). Empty = healthy devices.
    pub device_faults: DeviceFaultPlan,
    /// Shards a replica serves before the faults pin in. 0 = defective
    /// from programming time (infant mortality); >0 models a device
    /// failing mid-flight under live load.
    pub inject_after_shards: u64,
    /// `true` runs the sentinel probe on idle shard boundaries and
    /// quarantines what it localizes through the mask hot-swap path.
    pub sentinel: bool,
    /// Minimum spacing between sentinel probes per replica.
    pub probe_period: Duration,
    /// Repair canary: the fraction of probe images whose argmax must
    /// match the pre-fault reference for a quarantine to promote. Only
    /// enforced when a pre-fault reference exists (delayed injection);
    /// faults present from boot have no clean reference to hold
    /// repairs against, so those promote unconditionally.
    pub canary_threshold: f64,
}

impl Default for RepairServerConfig {
    fn default() -> Self {
        Self {
            device_faults: DeviceFaultPlan::none(),
            inject_after_shards: 0,
            sentinel: false,
            probe_period: Duration::from_millis(20),
            canary_threshold: 0.5,
        }
    }
}

/// Supervision policy: how failures are detected and how hard the
/// dispatcher tries to heal before giving up.
#[derive(Debug, Clone)]
pub struct SupervisorConfig {
    /// A worker busy on one shard longer than this is declared stuck:
    /// its checkpointed shard is stolen and the worker replaced.
    pub watchdog: Duration,
    /// Re-dispatch attempts per request after a worker loss before the
    /// request fails with [`ServeError::WorkerLost`].
    pub max_retries: u32,
    /// Base retry backoff; re-dispatch attempt `k` waits `backoff ×
    /// 2^(k−1)`.
    pub backoff: Duration,
    /// Respawn budget per worker slot; 0 retires a dead worker forever
    /// (the pre-supervision behavior).
    pub max_restarts: u64,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        Self {
            watchdog: Duration::from_secs(30),
            max_retries: 3,
            backoff: Duration::from_millis(2),
            max_restarts: u64::MAX,
        }
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            batch_timeout: Duration::from_millis(2),
            workers: 1,
            engine_threads: 1,
            admission: AdmissionConfig::default(),
            thermal: ThermalServerConfig::default(),
            supervisor: SupervisorConfig::default(),
            faults: FaultPlan::none(),
            cluster: ClusterConfig::default(),
            dst: DstServerConfig::default(),
            repair: RepairServerConfig::default(),
            precision: KernelPrecision::Exact,
        }
    }
}

impl ServerConfig {
    /// Start building a validated configuration from the defaults.
    pub fn builder() -> ServerConfigBuilder {
        ServerConfigBuilder { cfg: ServerConfig::default() }
    }

    /// A builder seeded with this config's values — how CLI flag
    /// overrides stack on top of a `--config` file (the result passes
    /// validation again at `build`).
    pub fn to_builder(&self) -> ServerConfigBuilder {
        ServerConfigBuilder { cfg: self.clone() }
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    pub fn batch_timeout(&self) -> Duration {
        self.batch_timeout
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn engine_threads(&self) -> usize {
        self.engine_threads
    }

    pub fn precision(&self) -> KernelPrecision {
        self.precision
    }

    pub fn steal(&self) -> bool {
        self.cluster.steal
    }

    pub fn admission(&self) -> &AdmissionConfig {
        &self.admission
    }

    pub fn thermal(&self) -> &ThermalServerConfig {
        &self.thermal
    }

    pub fn supervisor(&self) -> &SupervisorConfig {
        &self.supervisor
    }

    pub fn faults(&self) -> &FaultPlan {
        &self.faults
    }

    pub fn dst(&self) -> &DstServerConfig {
        &self.dst
    }

    pub fn repair(&self) -> &RepairServerConfig {
        &self.repair
    }

    /// Serialize for `--config` files. Durations are milliseconds;
    /// `max_restarts`/`deadline_ms` use `null` for "unbounded"/"none";
    /// the fault plan round-trips through its spec grammar. Lossy only
    /// for a non-default [`DriftConfig`] (the file format carries
    /// `"drift": true|false`, standing for the default drift model).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("max_batch", Json::Num(self.max_batch as f64)),
            ("batch_timeout_ms", Json::Num(self.batch_timeout.as_millis() as f64)),
            ("workers", Json::Num(self.workers as f64)),
            ("engine_threads", Json::Num(self.engine_threads as f64)),
            ("precision", Json::Str(self.precision.as_str().into())),
            ("steal", Json::Bool(self.cluster.steal)),
            ("max_in_flight", Json::Num(self.admission.max_in_flight as f64)),
            (
                "deadline_ms",
                match self.admission.default_deadline {
                    Some(d) => Json::Num(d.as_millis() as f64),
                    None => Json::Null,
                },
            ),
            ("retry_after_ms", Json::Num(self.admission.retry_after.as_millis() as f64)),
            ("watchdog_ms", Json::Num(self.supervisor.watchdog.as_millis() as f64)),
            ("max_retries", Json::Num(self.supervisor.max_retries as f64)),
            ("backoff_ms", Json::Num(self.supervisor.backoff.as_millis() as f64)),
            (
                "max_restarts",
                if self.supervisor.max_restarts == u64::MAX {
                    Json::Null
                } else {
                    Json::Num(self.supervisor.max_restarts as f64)
                },
            ),
            ("thermal", thermal_to_json(&self.thermal)),
            ("dst", dst_to_json(&self.dst)),
            ("repair", repair_to_json(&self.repair)),
        ];
        if !self.faults.is_empty() {
            pairs.push(("faults", Json::Str(self.faults.describe().join(","))));
        }
        Json::obj(pairs)
    }

    /// Load from `--config FILE` text. Unknown keys are rejected (a
    /// typo must not silently fall back to a default), and the result
    /// passes the same builder validation as programmatic construction.
    pub fn from_json(text: &str) -> crate::Result<ServerConfig> {
        let doc = Json::parse(text)
            .map_err(|e| crate::Error::Config(format!("server config: {e}")))?;
        let Json::Obj(map) = &doc else {
            return Err(crate::Error::Config("server config must be a JSON object".into()));
        };
        let mut b = ServerConfig::builder();
        let mut faults_spec: Option<String> = None;
        for (key, val) in map {
            match key.as_str() {
                "max_batch" => b = b.max_batch(cfg_usize(val, key)?),
                "batch_timeout_ms" => {
                    b = b.batch_timeout(Duration::from_millis(cfg_u64(val, key)?))
                }
                "workers" => b = b.workers(cfg_usize(val, key)?),
                "engine_threads" => b = b.engine_threads(cfg_usize(val, key)?),
                "precision" => {
                    let s = val.as_str().ok_or_else(|| {
                        crate::Error::Config(
                            "server config key \"precision\" must be a string".into(),
                        )
                    })?;
                    let p = s
                        .parse::<KernelPrecision>()
                        .map_err(|e| crate::Error::Config(format!("precision: {e}")))?;
                    b = b.precision(p);
                }
                "steal" => b = b.steal(cfg_bool(val, key)?),
                "max_in_flight" => b = b.max_in_flight(cfg_usize(val, key)?),
                "deadline_ms" => {
                    b = b.default_deadline(match val {
                        Json::Null => None,
                        v => Some(Duration::from_millis(cfg_u64(v, key)?)),
                    })
                }
                "retry_after_ms" => {
                    b = b.retry_after(Duration::from_millis(cfg_u64(val, key)?))
                }
                "watchdog_ms" => b = b.watchdog(Duration::from_millis(cfg_u64(val, key)?)),
                "max_retries" => b = b.max_retries(cfg_u64(val, key)? as u32),
                "backoff_ms" => b = b.backoff(Duration::from_millis(cfg_u64(val, key)?)),
                "max_restarts" => {
                    b = b.max_restarts(match val {
                        Json::Null => u64::MAX,
                        v => cfg_u64(v, key)?,
                    })
                }
                "thermal" => b = b.thermal(thermal_from_json(val)?),
                "dst" => b = b.dst(dst_from_json(val)?),
                "repair" => b = b.repair(repair_from_json(val)?),
                "faults" => {
                    let spec = val.as_str().ok_or_else(|| {
                        crate::Error::Config(
                            "server config key \"faults\" must be a spec string".into(),
                        )
                    })?;
                    // parsed after the loop: kill-each needs the final
                    // worker count, and BTreeMap order visits "faults"
                    // before "workers"
                    faults_spec = Some(spec.to_string());
                }
                other => {
                    return Err(crate::Error::Config(format!(
                        "unknown server config key {other:?}"
                    )))
                }
            }
        }
        if let Some(spec) = faults_spec {
            let plan = FaultPlan::parse(&spec, b.cfg.workers.max(1))
                .map_err(|e| crate::Error::Config(format!("faults: {e}")))?;
            b = b.faults(plan);
        }
        b.build()
    }
}

fn thermal_to_json(t: &ThermalServerConfig) -> Json {
    let mut pairs = vec![("drift", Json::Bool(t.drift.is_some()))];
    match t.policy {
        ThermalPolicy::Off => pairs.push(("policy", Json::Str("off".into()))),
        ThermalPolicy::Periodic { every_requests } => {
            pairs.push(("policy", Json::Str("periodic".into())));
            pairs.push(("every_requests", Json::Num(every_requests as f64)));
        }
        ThermalPolicy::Threshold { budget_rad } => {
            pairs.push(("policy", Json::Str("threshold".into())));
            pairs.push(("budget_rad", Json::Num(budget_rad)));
        }
    }
    if let Some(b) = t.brownout_budget_rad {
        pairs.push(("brownout_budget_rad", Json::Num(b)));
    }
    if let Some(w) = t.drift_only_worker {
        pairs.push(("drift_only_worker", Json::Num(w as f64)));
    }
    Json::obj(pairs)
}

fn thermal_from_json(v: &Json) -> crate::Result<ThermalServerConfig> {
    let Json::Obj(map) = v else {
        return Err(crate::Error::Config(
            "server config key \"thermal\" must be an object".into(),
        ));
    };
    let mut t = ThermalServerConfig::default();
    let mut policy_name: Option<String> = None;
    let mut every_requests: Option<u64> = None;
    let mut budget_rad: Option<f64> = None;
    for (key, val) in map {
        match key.as_str() {
            "drift" => {
                if cfg_bool(val, "thermal.drift")? {
                    t.drift = Some(DriftConfig::default());
                }
            }
            "policy" => {
                let name = val.as_str().ok_or_else(|| {
                    crate::Error::Config("thermal.policy must be a string".into())
                })?;
                policy_name = Some(name.to_string());
            }
            "every_requests" => {
                every_requests = Some(cfg_u64(val, "thermal.every_requests")?)
            }
            "budget_rad" => budget_rad = Some(cfg_f64(val, "thermal.budget_rad")?),
            "brownout_budget_rad" => {
                t.brownout_budget_rad = Some(cfg_f64(val, "thermal.brownout_budget_rad")?)
            }
            "drift_only_worker" => {
                t.drift_only_worker = Some(cfg_usize(val, "thermal.drift_only_worker")?)
            }
            other => {
                return Err(crate::Error::Config(format!(
                    "unknown thermal config key {other:?}"
                )))
            }
        }
    }
    t.policy = match policy_name.as_deref() {
        None | Some("off") => ThermalPolicy::Off,
        Some("periodic") => ThermalPolicy::Periodic {
            every_requests: every_requests.ok_or_else(|| {
                crate::Error::Config(
                    "thermal.policy \"periodic\" needs every_requests".into(),
                )
            })?,
        },
        Some("threshold") => ThermalPolicy::Threshold {
            budget_rad: budget_rad.ok_or_else(|| {
                crate::Error::Config("thermal.policy \"threshold\" needs budget_rad".into())
            })?,
        },
        Some(other) => {
            return Err(crate::Error::Config(format!("unknown thermal policy {other:?}")))
        }
    };
    Ok(t)
}

fn dst_to_json(d: &DstServerConfig) -> Json {
    let mut pairs = vec![
        ("enabled", Json::Bool(d.enabled)),
        ("period_ms", Json::Num(d.period.as_millis() as f64)),
        ("rounds", Json::Num(d.rounds as f64)),
        ("canary_threshold", Json::Num(d.canary_threshold)),
    ];
    if d.inject_bad_canary {
        pairs.push(("inject_bad_canary", Json::Bool(true)));
    }
    if let Some(dir) = &d.artifact_dir {
        pairs.push(("artifact_dir", Json::Str(dir.display().to_string())));
    }
    Json::obj(pairs)
}

fn dst_from_json(v: &Json) -> crate::Result<DstServerConfig> {
    let Json::Obj(map) = v else {
        return Err(crate::Error::Config(
            "server config key \"dst\" must be an object".into(),
        ));
    };
    let mut d = DstServerConfig::default();
    for (key, val) in map {
        match key.as_str() {
            "enabled" => d.enabled = cfg_bool(val, "dst.enabled")?,
            "period_ms" => {
                d.period = Duration::from_millis(cfg_u64(val, "dst.period_ms")?)
            }
            "rounds" => d.rounds = cfg_usize(val, "dst.rounds")?,
            "canary_threshold" => {
                d.canary_threshold = cfg_f64(val, "dst.canary_threshold")?
            }
            "inject_bad_canary" => {
                d.inject_bad_canary = cfg_bool(val, "dst.inject_bad_canary")?
            }
            "artifact_dir" => {
                let s = val.as_str().ok_or_else(|| {
                    crate::Error::Config("dst.artifact_dir must be a string".into())
                })?;
                d.artifact_dir = Some(PathBuf::from(s));
            }
            other => {
                return Err(crate::Error::Config(format!(
                    "unknown dst config key {other:?}"
                )))
            }
        }
    }
    Ok(d)
}

fn repair_to_json(r: &RepairServerConfig) -> Json {
    let mut pairs = Vec::new();
    if !r.device_faults.is_empty() {
        pairs.push(("device_faults", Json::Str(r.device_faults.describe().join(","))));
    }
    pairs.push(("inject_after_shards", Json::Num(r.inject_after_shards as f64)));
    pairs.push(("sentinel", Json::Bool(r.sentinel)));
    pairs.push(("probe_period_ms", Json::Num(r.probe_period.as_millis() as f64)));
    pairs.push(("canary_threshold", Json::Num(r.canary_threshold)));
    Json::obj(pairs)
}

fn repair_from_json(v: &Json) -> crate::Result<RepairServerConfig> {
    let Json::Obj(map) = v else {
        return Err(crate::Error::Config(
            "server config key \"repair\" must be an object".into(),
        ));
    };
    let mut r = RepairServerConfig::default();
    for (key, val) in map {
        match key.as_str() {
            "device_faults" => {
                let spec = val.as_str().ok_or_else(|| {
                    crate::Error::Config(
                        "repair.device_faults must be a spec string".into(),
                    )
                })?;
                r.device_faults = DeviceFaultPlan::parse(spec)
                    .map_err(|e| crate::Error::Config(format!("repair.device_faults: {e}")))?;
            }
            "inject_after_shards" => {
                r.inject_after_shards = cfg_u64(val, "repair.inject_after_shards")?
            }
            "sentinel" => r.sentinel = cfg_bool(val, "repair.sentinel")?,
            "probe_period_ms" => {
                r.probe_period = Duration::from_millis(cfg_u64(val, "repair.probe_period_ms")?)
            }
            "canary_threshold" => {
                r.canary_threshold = cfg_f64(val, "repair.canary_threshold")?
            }
            other => {
                return Err(crate::Error::Config(format!(
                    "unknown repair config key {other:?}"
                )))
            }
        }
    }
    Ok(r)
}

fn cfg_f64(v: &Json, key: &str) -> crate::Result<f64> {
    v.as_f64().ok_or_else(|| {
        crate::Error::Config(format!("server config key {key:?} must be a number"))
    })
}

fn cfg_u64(v: &Json, key: &str) -> crate::Result<u64> {
    let x = cfg_f64(v, key)?;
    if x < 0.0 || x.fract() != 0.0 {
        return Err(crate::Error::Config(format!(
            "server config key {key:?} must be a non-negative integer"
        )));
    }
    Ok(x as u64)
}

fn cfg_usize(v: &Json, key: &str) -> crate::Result<usize> {
    cfg_u64(v, key).map(|x| x as usize)
}

fn cfg_bool(v: &Json, key: &str) -> crate::Result<bool> {
    v.as_bool().ok_or_else(|| {
        crate::Error::Config(format!("server config key {key:?} must be a boolean"))
    })
}

/// Validating builder for [`ServerConfig`] — the only construction path
/// outside this crate. Setters mirror the config fields plus shortcuts
/// into the nested policies (`max_in_flight`, `watchdog`, ...);
/// [`build`](ServerConfigBuilder::build) checks every invariant and
/// returns [`crate::Error::Config`] naming the violated one.
#[derive(Debug, Clone)]
pub struct ServerConfigBuilder {
    cfg: ServerConfig,
}

impl ServerConfigBuilder {
    pub fn max_batch(mut self, n: usize) -> Self {
        self.cfg.max_batch = n;
        self
    }

    pub fn batch_timeout(mut self, d: Duration) -> Self {
        self.cfg.batch_timeout = d;
        self
    }

    /// Engine replica count (`--replicas` at the bench level routes
    /// through this).
    pub fn workers(mut self, n: usize) -> Self {
        self.cfg.workers = n;
        self
    }

    pub fn engine_threads(mut self, n: usize) -> Self {
        self.cfg.engine_threads = n;
        self
    }

    /// Kernel precision for every engine worker (`--precision`).
    pub fn precision(mut self, p: KernelPrecision) -> Self {
        self.cfg.precision = p;
        self
    }

    pub fn admission(mut self, a: AdmissionConfig) -> Self {
        self.cfg.admission = a;
        self
    }

    pub fn max_in_flight(mut self, n: usize) -> Self {
        self.cfg.admission.max_in_flight = n;
        self
    }

    pub fn default_deadline(mut self, d: Option<Duration>) -> Self {
        self.cfg.admission.default_deadline = d;
        self
    }

    pub fn retry_after(mut self, d: Duration) -> Self {
        self.cfg.admission.retry_after = d;
        self
    }

    pub fn thermal(mut self, t: ThermalServerConfig) -> Self {
        self.cfg.thermal = t;
        self
    }

    pub fn supervisor(mut self, s: SupervisorConfig) -> Self {
        self.cfg.supervisor = s;
        self
    }

    pub fn watchdog(mut self, d: Duration) -> Self {
        self.cfg.supervisor.watchdog = d;
        self
    }

    pub fn max_retries(mut self, n: u32) -> Self {
        self.cfg.supervisor.max_retries = n;
        self
    }

    pub fn backoff(mut self, d: Duration) -> Self {
        self.cfg.supervisor.backoff = d;
        self
    }

    pub fn max_restarts(mut self, n: u64) -> Self {
        self.cfg.supervisor.max_restarts = n;
        self
    }

    pub fn faults(mut self, f: FaultPlan) -> Self {
        self.cfg.faults = f;
        self
    }

    /// In-serving DST + mask hot-swap knobs.
    pub fn dst(mut self, d: DstServerConfig) -> Self {
        self.cfg.dst = d;
        self
    }

    /// Device-fault injection + sentinel-repair knobs.
    pub fn repair(mut self, r: RepairServerConfig) -> Self {
        self.cfg.repair = r;
        self
    }

    /// Shortcut: inject this device-fault plan (`--device-faults`).
    pub fn device_faults(mut self, plan: DeviceFaultPlan) -> Self {
        self.cfg.repair.device_faults = plan;
        self
    }

    /// Shortcut: arm the sentinel probe + quarantine repair loop
    /// (`--sentinel`), keeping the other repair knobs.
    pub fn sentinel(mut self, on: bool) -> Self {
        self.cfg.repair.sentinel = on;
        self
    }

    /// Enable work stealing between replica queues.
    pub fn steal(mut self, on: bool) -> Self {
        self.cfg.cluster.steal = on;
        self
    }

    /// Validate and produce the config. Each violated invariant gets
    /// its own [`crate::Error::Config`] message.
    pub fn build(self) -> crate::Result<ServerConfig> {
        let cfg = self.cfg;
        if cfg.workers == 0 {
            return Err(crate::Error::Config("workers must be >= 1".into()));
        }
        if cfg.max_batch == 0 {
            return Err(crate::Error::Config("max_batch must be >= 1".into()));
        }
        if cfg.engine_threads == 0 {
            return Err(crate::Error::Config("engine_threads must be >= 1".into()));
        }
        if cfg.admission.max_in_flight == 0 {
            return Err(crate::Error::Config("admission.max_in_flight must be >= 1".into()));
        }
        if cfg.supervisor.watchdog <= cfg.batch_timeout {
            return Err(crate::Error::Config(format!(
                "supervisor.watchdog ({}ms) must exceed batch_timeout ({}ms): a watchdog \
                 shorter than one batching window declares healthy workers stuck",
                cfg.supervisor.watchdog.as_millis(),
                cfg.batch_timeout.as_millis()
            )));
        }
        if cfg.dst.enabled {
            if !(0.0..=1.0).contains(&cfg.dst.canary_threshold) {
                return Err(crate::Error::Config(format!(
                    "dst.canary_threshold ({}) must be within [0, 1]",
                    cfg.dst.canary_threshold
                )));
            }
            if cfg.dst.rounds == 0 {
                return Err(crate::Error::Config("dst.rounds must be >= 1".into()));
            }
        }
        if cfg.repair.sentinel && !(0.0..=1.0).contains(&cfg.repair.canary_threshold) {
            return Err(crate::Error::Config(format!(
                "repair.canary_threshold ({}) must be within [0, 1]",
                cfg.repair.canary_threshold
            )));
        }
        Ok(cfg)
    }
}

/// Poison-recovering lock: a panicked holder leaves the data intact for
/// our protocols (the checkpoint slot holds plain owned requests; the
/// server handle holds channel ends), so recover instead of cascading
/// the panic into every caller.
fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

struct Request {
    image: Tensor,
    submitted: Instant,
    deadline: Option<Instant>,
    permit: Permit,
    reply: Sender<ReplyResult>,
    /// Loss-driven re-dispatches so far (backpressure requeues are free).
    retries: u32,
}

impl Request {
    fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// Terminal failure: release the admission slot, then answer.
fn fail_request(req: Request, err: ServeError) {
    let Request { permit, reply, .. } = req;
    drop(permit);
    let _ = reply.send(Err(err));
}

/// One served prediction.
#[derive(Debug, Clone)]
pub struct Reply {
    pub class: usize,
    pub logits: Vec<f64>,
    pub latency: Duration,
    pub batch_size: usize,
    /// This request's share of the accelerator energy its batched
    /// engine pass spent (the shard's engine-ledger delta apportioned by
    /// column share — every request of a shard streams the same column
    /// count, so the share is `delta / shard_len`), in mJ.
    pub energy_mj: f64,
}

/// Why an admitted request still failed (shed-at-the-door is
/// [`crate::Error::Busy`] from [`InferenceServer::submit`] instead).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeError {
    /// The deadline passed while the request was queued; it was dropped
    /// before wasting engine time.
    Expired,
    /// Every re-dispatch attempt ran out of live workers; the request is
    /// safe to retry (it never executed to completion).
    WorkerLost,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Expired => write!(f, "request deadline expired in queue"),
            ServeError::WorkerLost => write!(f, "engine worker died before replying"),
        }
    }
}

impl From<ServeError> for crate::Error {
    fn from(e: ServeError) -> Self {
        crate::Error::Runtime(e.to_string())
    }
}

/// What a reply receiver yields: a prediction, or the reason the
/// admitted request died in the pipeline.
pub type ReplyResult = Result<Reply, ServeError>;

/// Aggregate report at shutdown.
#[derive(Debug, Clone)]
pub struct ServerReport {
    pub requests: usize,
    pub batches: usize,
    /// Mean requests per dispatched dynamic batch — how much of the
    /// `max_batch` compute amortization traffic actually realized.
    pub mean_batch_occupancy: f64,
    pub workers: usize,
    /// Worker slots still live (respawned as needed) at shutdown.
    pub workers_live: usize,
    pub mean_latency_us: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub throughput_rps: f64,
    pub energy_mj: f64,
    pub p_avg_w: f64,
    /// Requests shed at admission ([`crate::Error::Busy`]).
    pub shed: u64,
    /// Admitted requests dropped on an expired deadline.
    pub expired: u64,
    /// Admitted requests failed by a dead engine worker after their
    /// retry budget was spent.
    pub worker_lost: u64,
    /// Worker respawns performed by the supervisor.
    pub worker_restarts: u64,
    /// Loss-driven request re-dispatches performed by the supervisor.
    pub request_retries: u64,
    /// Thermal brownout entries across workers.
    pub brownouts: u64,
    /// Thermal recalibration actions across workers (0 = runtime off).
    pub recalibrations: u64,
    /// Chunks recompiled by thermal recalibration across workers.
    pub recal_chunks: u64,
    /// Shards stolen between replica queues (`ClusterConfig::steal`).
    pub steals: u64,
    /// Shards routed to each replica slot by the cluster scheduler.
    pub routed: Vec<u64>,
    /// Mask artifacts promoted by the hot-swap canary.
    pub mask_swaps: u64,
    /// Mask artifacts rejected by the canary and rolled back.
    pub mask_rollbacks: u64,
    /// Per-replica active mask generation at shutdown (0 = baseline).
    pub mask_generation: Vec<u64>,
    /// Rerouter power estimate (mW) of the newest promoted artifact.
    pub mask_power_mw: f64,
    /// Device-fault events injected into worker fabrics (plan entries at
    /// boot, faulted chunks for mid-life injection).
    pub faults_injected: u64,
    /// Sentinel findings (fault localizations) across workers.
    pub fault_detections: u64,
    /// Quarantine repairs promoted by the repair canary.
    pub fault_repairs: u64,
    /// Findings no repair mask could cover (replica degraded instead).
    pub fault_unrepairable: u64,
    /// First-injection → first-detection latency (µs; 0 until both).
    pub fault_detection_latency_us: u64,
    /// Per-replica degraded flag at shutdown.
    pub degraded: Vec<bool>,
}

/// A shard of a dynamic batch, tagged with the full batch size (clients
/// observe the batch they rode in, not the shard), its per-slot
/// sequence number (monotone across worker generations — the fault
/// plan's address space), and the slot whose queue ledger carries it
/// (`home` — unchanged by stealing, so accounting follows the queue a
/// shard was charged to).
struct Shard {
    requests: Vec<Request>,
    batch_size: usize,
    seq: u64,
    home: usize,
}

/// In-flight headroom per replica: the dispatcher plans shards only
/// onto replicas whose queued + executing shard count is below this.
/// Small on purpose — the admission cap already bounds total queued
/// work, and deep per-replica queues would defeat load-aware routing.
const WORKER_QUEUE_DEPTH: usize = 2;

/// How often the dispatcher wakes to run supervision while idle.
const SUPERVISE_TICK: Duration = Duration::from_millis(10);

/// How long an idle worker sleeps on its queue condvar per wait round.
/// Bounded so steal attempts, generation checks, and shutdown stay
/// live even if a notify is missed.
const WORKER_POLL: Duration = Duration::from_millis(10);

/// Initial death rate of the in-serving DST job (the cosine schedule
/// anneals it to 0 over `DstServerConfig::rounds`).
const DST_ALPHA0: f64 = 0.3;

/// One replica slot's persistent shard queue. Outlives worker
/// generations: a respawned worker resumes the backlog its predecessor
/// left, and the `gen` token retires zombies (a worker whose generation
/// no longer matches exits at its next queue visit).
///
/// The ledger (`enqueued` − `accounted`) counts shards queued or
/// executing on this slot. Workers account a shard against its *home*
/// queue when done; the supervisor reconciles the ledger on respawn
/// (writes off what a dead generation had popped) and settles it on
/// retirement. `ewma_us` is the router's service-time estimate,
/// updated by the slot's own worker after each executed shard.
struct ReplicaQueue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
    /// Shards ever pushed to this queue.
    enqueued: AtomicU64,
    /// Shards fully accounted (served, dropped, recovered, or written
    /// off by reconciliation).
    accounted: AtomicU64,
    /// EWMA shard service time in µs (0 = no sample yet).
    ewma_us: AtomicU64,
}

struct QueueInner {
    shards: VecDeque<Shard>,
    /// Generation token: bumped by the supervisor when it retires the
    /// slot's worker, so the zombie can never serve the replacement's
    /// queue.
    gen: u64,
    /// Set at shutdown after the backlog drains.
    closed: bool,
}

impl ReplicaQueue {
    fn new() -> Self {
        Self {
            inner: Mutex::new(QueueInner {
                shards: VecDeque::new(),
                gen: 0,
                closed: false,
            }),
            cv: Condvar::new(),
            enqueued: AtomicU64::new(0),
            accounted: AtomicU64::new(0),
            ewma_us: AtomicU64::new(0),
        }
    }

    /// Shards queued or executing on this slot.
    fn in_flight(&self) -> u64 {
        self.enqueued
            .load(Ordering::Acquire)
            .saturating_sub(self.accounted.load(Ordering::Acquire))
    }

    /// Mark one shard of this queue's ledger fully handled.
    fn account(&self) {
        self.accounted.fetch_add(1, Ordering::AcqRel);
    }

    fn push(&self, shard: Shard) {
        self.enqueued.fetch_add(1, Ordering::AcqRel);
        lock_clean(&self.inner).shards.push_back(shard);
        self.cv.notify_one();
    }

    /// Fold one shard-execution sample into the EWMA (`new = (4·old +
    /// sample) / 5`); the first sample seeds it. Clamped to >= 1 µs so
    /// "has a sample" and "no sample yet" stay distinguishable.
    fn observe_service_us(&self, us: u64) {
        let sample = us.max(1);
        let old = self.ewma_us.load(Ordering::Acquire);
        let new = if old == 0 { sample } else { (4 * old + sample) / 5 };
        self.ewma_us.store(new, Ordering::Release);
    }
}

/// Shared per-generation worker state: heartbeat, checkpoint slot,
/// thermal scores. A respawn allocates a fresh `WorkerHealth`, so a
/// detached zombie can never corrupt the state of its replacement.
struct WorkerHealth {
    /// Heartbeat: ms since the dispatcher epoch when the current shard
    /// was received (`u64::MAX` = idle). The watchdog reads this.
    busy_since_ms: AtomicU64,
    /// Post-tick phase-error estimate exceeded the brownout budget.
    brownout: AtomicBool,
    /// Unrepairable device fault: the sentinel localized a defect the
    /// quarantine path cannot mask off. The router down-ranks this
    /// replica permanently (for this generation); a respawn re-programs
    /// from scratch and re-evaluates.
    degraded: AtomicBool,
    /// Continuous thermal score (phase error in milliradians) for the
    /// router's heat-aware ranking; 0 until the first thermal tick.
    heat_milli: AtomicU64,
    /// The checkpoint slot: a shard parks here from receive until the
    /// worker commits to executing it, so the supervisor can recover it
    /// losslessly from a dead or stuck worker.
    checkpoint: Mutex<Option<Shard>>,
}

impl WorkerHealth {
    fn new() -> Self {
        Self {
            busy_since_ms: AtomicU64::new(u64::MAX),
            brownout: AtomicBool::new(false),
            degraded: AtomicBool::new(false),
            heat_milli: AtomicU64::new(0),
            checkpoint: Mutex::new(None),
        }
    }

    fn begin_busy(&self, epoch: Instant) {
        let ms = Instant::now().saturating_duration_since(epoch).as_millis() as u64;
        self.busy_since_ms.store(ms, Ordering::Release);
    }

    fn end_busy(&self) {
        self.busy_since_ms.store(u64::MAX, Ordering::Release);
    }

    /// How long the current shard has been in progress, if any.
    fn busy_for(&self, epoch: Instant, now: Instant) -> Option<Duration> {
        let since = self.busy_since_ms.load(Ordering::Acquire);
        if since == u64::MAX {
            return None;
        }
        Some(now.saturating_duration_since(epoch + Duration::from_millis(since)))
    }
}

/// One DST candidate in flight through the hot-swap protocol. The
/// dispatcher publishes it; every worker reads it at its next shard
/// boundary. `rejected` fans one replica's canary failure out to the
/// pool, so a bad generation is tested once, not once per replica.
struct PendingSwap {
    artifact: MaskArtifact,
    /// Force the canary verdict to fail (rollback fault injection).
    bad_canary: bool,
    /// Set by the first worker whose canary rejects this generation.
    rejected: AtomicBool,
}

/// Everything needed to (re)build an engine worker — retained by the
/// dispatcher so the supervisor can respawn with a fresh engine.
struct WorkerContext {
    model: Model,
    cfg: AcceleratorConfig,
    opts: EngineOptions,
    masks: std::collections::BTreeMap<String, crate::sparsity::LayerMask>,
    engine_threads: usize,
    /// Kernel precision each (re)spawned engine runs at.
    precision: KernelPrecision,
    thermal: ThermalServerConfig,
    faults: FaultPlan,
    metrics: Arc<ServerMetrics>,
    /// Time origin for the heartbeat encoding.
    epoch: Instant,
    /// One persistent shard queue per replica slot.
    queues: Vec<Arc<ReplicaQueue>>,
    /// Idle replicas steal from the deepest peer queue.
    steal: bool,
    /// In-serving DST knobs (the co-design loop's serving half).
    dst: DstServerConfig,
    /// Device-fault injection + sentinel-repair knobs.
    repair: RepairServerConfig,
    /// Newest mask artifact awaiting per-replica canary + cutover.
    swap: Mutex<Option<Arc<PendingSwap>>>,
}

/// One live worker generation.
struct WorkerGen {
    handle: JoinHandle<()>,
    health: Arc<WorkerHealth>,
}

/// Dispatcher-side bookkeeping for one worker slot across generations.
struct WorkerSlot {
    widx: usize,
    /// Respawns performed on this slot.
    restarts: u64,
    /// Next shard sequence number (monotone across generations, so the
    /// fault plan's addresses survive respawns).
    seq_next: u64,
    /// `None` = retired (restart budget exhausted).
    gen: Option<WorkerGen>,
}

fn spawn_engine_worker(ctx: &Arc<WorkerContext>, widx: usize) -> WorkerGen {
    let health = Arc::new(WorkerHealth::new());
    ctx.metrics.set_worker_up(widx, true);
    // a respawned worker reprograms from scratch: its degraded verdict
    // is re-evaluated by the sentinel, so the gauge starts clean
    ctx.metrics.set_worker_degraded(widx, false);
    // bind to the queue's current generation: if the supervisor later
    // bumps it, this worker knows to stand down
    let my_gen = lock_clean(&ctx.queues[widx].inner).gen;
    let handle = {
        let ctx = Arc::clone(ctx);
        let health = Arc::clone(&health);
        std::thread::spawn(move || run_engine_worker(ctx, widx, my_gen, health))
    };
    WorkerGen { handle, health }
}

/// Take the deepest peer backlog's newest shard (victim pops front,
/// thief pops back — the classic deque split keeps the victim's oldest
/// work with the victim). Try-locks only: stealing never blocks on a
/// busy queue.
fn try_steal(ctx: &WorkerContext, widx: usize) -> Option<Shard> {
    let mut victim = None;
    let mut deepest = 0usize;
    for (i, q) in ctx.queues.iter().enumerate() {
        if i == widx {
            continue;
        }
        if let Ok(inner) = q.inner.try_lock() {
            if inner.shards.len() > deepest {
                deepest = inner.shards.len();
                victim = Some(i);
            }
        }
    }
    let mut inner = ctx.queues[victim?].inner.try_lock().ok()?;
    let shard = inner.shards.pop_back();
    if shard.is_some() {
        ctx.metrics.note_steal();
    }
    shard
}

/// Next shard for worker `widx` of generation `my_gen`: own queue
/// first, then (if enabled) a steal from the deepest peer, else a
/// bounded condvar wait. Returns `None` when the generation is retired
/// or the queue is closed and drained.
fn next_shard(ctx: &WorkerContext, widx: usize, my_gen: u64) -> Option<Shard> {
    let q = &ctx.queues[widx];
    let mut inner = lock_clean(&q.inner);
    loop {
        if inner.gen != my_gen {
            return None;
        }
        if let Some(shard) = inner.shards.pop_front() {
            return Some(shard);
        }
        if inner.closed {
            return None;
        }
        if ctx.steal {
            drop(inner);
            let stolen = try_steal(ctx, widx);
            inner = lock_clean(&q.inner);
            if let Some(shard) = stolen {
                return Some(shard);
            }
            // nothing to steal: re-check own state, then sleep below
            if inner.gen != my_gen || inner.closed || !inner.shards.is_empty() {
                continue;
            }
        }
        inner = match q.cv.wait_timeout(inner, WORKER_POLL) {
            Ok((guard, _)) => guard,
            Err(e) => e.into_inner().0,
        };
    }
}

fn run_engine_worker(
    ctx: Arc<WorkerContext>,
    widx: usize,
    my_gen: u64,
    health: Arc<WorkerHealth>,
) {
    let mut engine = PhotonicEngine::new(ctx.cfg.clone(), ctx.opts);
    engine.set_threads(ctx.engine_threads);
    engine.set_precision(ctx.precision);
    engine.set_masks(ctx.masks.clone());
    // §4.1: deploy the final linear layer on non-adjacent MZI
    // columns (crosstalk-protected readout)
    if let Some((last, _, _)) = ctx.model.matmul_layers().last() {
        engine.set_protected([last.clone()].into_iter().collect());
    }
    // thermal-drift runtime: this worker's replica drifts with wall
    // time (scaled) and its own served-request self-heating.
    // `drift_only_worker` narrows the runtime to one replica — the
    // hot-replica routing experiments force exactly one hot board.
    let drift_here = ctx.thermal.drift_only_worker.is_none_or(|w| w == widx);
    let time_scale = if drift_here {
        ctx.thermal.drift.as_ref().map(|d| d.time_scale)
    } else {
        None
    };
    if drift_here {
        if let Some(drift) = ctx.thermal.drift.clone() {
            engine.set_thermal(
                DriftConfig { worker_id: widx as u64, ..drift },
                ctx.thermal.policy,
            );
        }
    }
    // device faults present from programming time (infant mortality):
    // the engine pins them into every chunk it realizes, while the
    // sentinel goldens stay fault-free — a probe flags them immediately
    let inject_now = !ctx.repair.device_faults.is_empty();
    if inject_now && ctx.repair.inject_after_shards == 0 {
        engine.set_device_faults(ctx.repair.device_faults.clone());
        ctx.metrics.note_faults_injected(ctx.repair.device_faults.len() as u64);
    }
    // canary probe: identical on every replica (fixed seed), so a
    // candidate generation is judged on the same inputs everywhere
    let probe = if ctx.dst.enabled || ctx.repair.sentinel {
        probe_batch(&ctx.model)
    } else {
        Vec::new()
    };
    // repair canary reference: the probe argmaxes of the *clean* fabric.
    // Only exists when injection is delayed — a fabric faulted from boot
    // has no clean state to reference, so its repairs promote ungated.
    let repair_ref: Option<Vec<usize>> =
        (ctx.repair.sentinel && ctx.repair.inject_after_shards > 0).then(|| {
            ctx.model
                .forward_batch(probe.clone(), &mut engine)
                .iter()
                .map(Tensor::argmax)
                .collect()
        });
    let started = Instant::now();
    let mut served: u64 = 0;
    let mut shards_seen: u64 = 0;
    let mut midlife_injected = false;
    let mut last_sentinel = Instant::now();
    while let Some(shard) = next_shard(&ctx, widx, my_gen) {
        // shard boundary: everything in flight finished on the old
        // generation and the popped shard has not started — the one
        // point where a mask cutover is atomic for clients
        if ctx.dst.enabled {
            maybe_swap_masks(&ctx, widx, &mut engine, &probe);
        }
        // mid-life device failure: once the configured shard count has
        // been served, pin the faults into the live programmed state
        // (goldens are NOT refreshed — that asymmetry is what the
        // sentinel detects)
        if inject_now
            && !midlife_injected
            && ctx.repair.inject_after_shards > 0
            && shards_seen >= ctx.repair.inject_after_shards
        {
            midlife_injected = true;
            let chunks = engine.inject_device_faults(&ctx.repair.device_faults);
            ctx.metrics.note_faults_injected(chunks.max(1) as u64);
        }
        if ctx.repair.sentinel && last_sentinel.elapsed() >= ctx.repair.probe_period {
            last_sentinel = Instant::now();
            maybe_repair(&ctx, widx, &mut engine, &probe, repair_ref.as_deref(), &health);
        }
        shards_seen += 1;
        let seq = shard.seq;
        let batch_size = shard.batch_size;
        let home = shard.home;
        health.begin_busy(ctx.epoch);
        // checkpoint: park the shard where the supervisor can reach it.
        // From here until the take() below, a death or watchdog theft
        // loses nothing — the requests live in the slot, unexecuted.
        *lock_clean(&health.checkpoint) = Some(shard);
        match ctx.faults.action(widx, seq) {
            Some(FaultAction::Panic) => {
                // the shard stays parked: the supervisor recovers it
                panic!("injected fault: worker {widx} dies at shard s{seq}");
            }
            Some(FaultAction::Stall(d)) => std::thread::sleep(d),
            Some(FaultAction::DropReplies) => {
                // reply channels vanish un-sent: clients observe a
                // disconnect (retryable); the worker stays healthy
                drop(lock_clean(&health.checkpoint).take());
                ctx.queues[home].account();
                health.end_busy();
                continue;
            }
            Some(FaultAction::SlowReply(_)) | None => {}
        }
        // commit: take the shard back out. An empty slot means the
        // watchdog already stole it — it belongs to a replacement now.
        let Some(shard) = lock_clean(&health.checkpoint).take() else {
            health.end_busy();
            continue;
        };
        if let Some(FaultAction::SlowReply(d)) = ctx.faults.action(widx, seq) {
            // committed, so this shard is ours alone: a late reply, not
            // a lost one, even if the watchdog replaces us meanwhile
            std::thread::sleep(d);
        }
        if let Some(budget) = ctx.thermal.brownout_budget_rad {
            if health.brownout.load(Ordering::Acquire)
                && engine.thermal_phase_error_rad() > budget
            {
                // browned out: restore fidelity before serving more
                engine.recalibrate_thermal();
            }
        }
        let exec_started = Instant::now();
        // second-chance deadline check, hoisted to ONE scan over the
        // whole shard *before* batch assembly: requests that expired
        // in this worker's shard queue never inflate the batched
        // matmul's column count
        let now = Instant::now();
        let (live, dead): (Vec<Request>, Vec<Request>) =
            shard.requests.into_iter().partition(|r| !r.expired(now));
        if !dead.is_empty() {
            ctx.metrics.note_expired(dead.len() as u64);
            for req in dead {
                fail_request(req, ServeError::Expired);
            }
        }
        if !live.is_empty() {
            let n = live.len();
            let mut images = Vec::with_capacity(n);
            let mut routing = Vec::with_capacity(n);
            for req in live {
                let Request { image, submitted, permit, reply, .. } = req;
                images.push(image);
                routing.push((submitted, permit, reply));
            }
            // the whole shard is ONE batched forward — every matmul
            // layer runs once with n_cols = n × positions
            let e_before = engine.energy_report().energy_mj;
            let outputs = ctx.model.forward_batch(images, &mut engine);
            // apportion the engine's energy delta by column share
            // (uniform: same model, same column count per request)
            let e_each = (engine.energy_report().energy_mj - e_before) / n as f64;
            served += n as u64;
            for ((submitted, permit, reply), logits) in routing.into_iter().zip(outputs) {
                let class = logits.argmax();
                let latency = submitted.elapsed();
                ctx.metrics.record_served(latency);
                // release the slot before replying so a ping-pong
                // client can re-submit without a spurious shed
                drop(permit);
                let _ = reply.send(Ok(Reply {
                    class,
                    logits: logits.data,
                    latency,
                    batch_size,
                    energy_mj: e_each,
                }));
            }
        }
        // settle the ledger against the shard's home queue (a stolen
        // shard still belongs to its victim's ledger) and feed the
        // router's service-time estimate from our own execution
        ctx.queues[home].account();
        ctx.queues[widx].observe_service_us(exec_started.elapsed().as_micros() as u64);
        health.end_busy();
        let rep = engine.energy_report();
        ctx.metrics.set_worker_energy(widx, rep.energy_mj, rep.time_ms);
        // advance the drift runtime once per shard and publish the
        // post-tick heat score, gauges, and brownout state
        if let Some(scale) = time_scale {
            let t_s = started.elapsed().as_secs_f64() * scale;
            if let Some(s) = engine.thermal_tick(t_s, served) {
                let heat = (s.phase_error_rad.max(0.0) * 1000.0) as u64;
                health.heat_milli.store(heat, Ordering::Release);
                ctx.metrics.set_replica_heat(widx, heat);
                if let Some(budget) = ctx.thermal.brownout_budget_rad {
                    let hot = s.phase_error_rad > budget;
                    let was = health.brownout.swap(hot, Ordering::AcqRel);
                    ctx.metrics.set_worker_brownout(widx, hot);
                    if hot && !was {
                        ctx.metrics.note_brownout();
                    }
                }
                ctx.metrics.set_worker_thermal(widx, ThermalGauges::from(s));
            }
        }
    }
}

/// Probe images for the swap canary, with no distribution assumptions
/// beyond the model's input shape. Every replica derives the same batch
/// from the same seed, so a candidate generation gets one verdict, not
/// one per replica's traffic mix.
const PROBE_BATCH: usize = 4;

fn probe_batch(model: &Model) -> Vec<Tensor> {
    let shape = model.input_shape.clone();
    let n: usize = shape.iter().product();
    let mut rng = XorShiftRng::new(0x5CA7_7E12);
    (0..PROBE_BATCH)
        .map(|_| Tensor::from_vec(&shape, (0..n).map(|_| rng.uniform()).collect()))
        .collect()
}

/// Per-shard-boundary hot-swap: if a newer generation is pending,
/// canary it on this replica's engine between shards. The probe runs
/// once on the old generation and once on the new (the second pass also
/// flushes the incremental reprogram, so the next shard pays nothing);
/// the candidate promotes only if the argmax agreement clears the
/// configured threshold, otherwise the engine reprograms the affected
/// chunks back and the generation is vetoed for every peer.
fn maybe_swap_masks(
    ctx: &WorkerContext,
    widx: usize,
    engine: &mut PhotonicEngine,
    probe: &[Tensor],
) {
    let Some(pending) = lock_clean(&ctx.swap).clone() else { return };
    if pending.rejected.load(Ordering::Acquire)
        || pending.artifact.generation <= engine.mask_generation()
    {
        return;
    }
    let before = ctx.model.forward_batch(probe.to_vec(), engine);
    let old_masks = engine.masks().clone();
    let old_gen = engine.mask_generation();
    engine.apply_mask_update(pending.artifact.masks.clone(), pending.artifact.generation);
    let after = ctx.model.forward_batch(probe.to_vec(), engine);
    let agree = before.iter().zip(&after).filter(|(b, a)| a.argmax() == b.argmax()).count();
    let promote = !pending.bad_canary
        && agree as f64 >= ctx.dst.canary_threshold * probe.len() as f64;
    if promote {
        ctx.metrics.note_mask_swap();
        ctx.metrics.set_mask_generation(widx, pending.artifact.generation);
        ctx.metrics.set_mask_power_mw(pending.artifact.power_mw);
    } else {
        // roll back to the generation that was serving; the veto stops
        // peers from re-testing a known-bad candidate
        engine.apply_mask_update(old_masks, old_gen);
        pending.rejected.store(true, Ordering::Release);
        ctx.metrics.note_mask_rollback();
        ctx.metrics.set_mask_generation(widx, old_gen);
    }
}

/// Per-shard-boundary sentinel + quarantine repair. The sentinel probe
/// sweeps every programmed chunk against its fault-free golden digest
/// (O(chunks) dot products — no live traffic touched); anything it
/// localizes is quarantined by diffing a repair mask through the same
/// [`PhotonicEngine::apply_mask_update`] + canary + rollback path the
/// DST hot-swap uses. Unrepairable findings (no masks installed for the
/// layer, or the defect sits outside every maskable cell) permanently
/// degrade the replica: the router down-ranks it and `/healthz` reports
/// `degraded` with reason `device_fault`.
fn maybe_repair(
    ctx: &WorkerContext,
    widx: usize,
    engine: &mut PhotonicEngine,
    probe: &[Tensor],
    repair_ref: Option<&[usize]>,
    health: &WorkerHealth,
) {
    if health.degraded.load(Ordering::Acquire) {
        // verdict already in: re-probing a degraded fabric every period
        // would only burn idle headroom re-discovering the same defect
        return;
    }
    let findings = engine.sentinel_probe_all();
    if findings.is_empty() {
        return;
    }
    ctx.metrics.note_fault_detections(findings.len() as u64);
    let degrade = |reason: &str| {
        health.degraded.store(true, Ordering::Release);
        ctx.metrics.note_fault_unrepairable();
        ctx.metrics.set_worker_degraded(widx, true);
        eprintln!("[scatter] worker {widx}: unrepairable device fault ({reason}); degraded");
    };
    let Some((repaired, cells)) = engine.quarantine_masks(&findings) else {
        degrade("no maskable cells cover the finding");
        return;
    };
    let old_masks = engine.masks().clone();
    let old_gen = engine.mask_generation();
    // the repair bumps this replica's local generation so the swap gate
    // (`artifact.generation <= engine generation`) stays monotone
    engine.apply_mask_update(repaired, old_gen + 1);
    // probe pass doubles as the canary and flushes the incremental
    // reprogram, which also re-baselines the repaired chunks' goldens
    let after = ctx.model.forward_batch(probe.to_vec(), engine);
    let promote = match repair_ref {
        Some(want) => {
            let agree =
                after.iter().zip(want).filter(|(a, &w)| a.argmax() == w).count();
            agree as f64 >= ctx.repair.canary_threshold * want.len().max(1) as f64
        }
        // no clean reference (faults predate the first probe): masking
        // off a defective region cannot be worse than serving it
        None => true,
    };
    if promote {
        engine.record_quarantine(&findings);
        ctx.metrics.note_fault_repair();
        ctx.metrics
            .set_worker_quarantined_cells(widx, engine.quarantined_cell_count() as u64);
        eprintln!(
            "[scatter] worker {widx}: quarantined {cells} cell(s) across {} finding(s)",
            findings.len()
        );
    } else {
        engine.apply_mask_update(old_masks, old_gen);
        degrade("repair canary failed against the pre-fault reference");
    }
}

/// Handle to a running inference server. Cheap to share behind an
/// `Arc`: every method takes `&self`, including [`shutdown`].
///
/// [`shutdown`]: InferenceServer::shutdown
pub struct InferenceServer {
    /// `None` once draining; taking it closes the dispatcher inbox.
    tx: Mutex<Option<SyncSender<Request>>>,
    admission: Arc<AdmissionController>,
    metrics: Arc<ServerMetrics>,
    dispatcher: Mutex<Option<JoinHandle<ServerReport>>>,
    /// Kernel precision the engine workers were spawned with — surfaced
    /// as the `scatter_kernel_variant` info gauge on `/metrics`.
    precision: KernelPrecision,
}

impl InferenceServer {
    /// Spawn the dispatcher + engine worker threads.
    pub fn spawn(
        model: Model,
        cfg: AcceleratorConfig,
        opts: EngineOptions,
        masks: std::collections::BTreeMap<String, crate::sparsity::LayerMask>,
        server_cfg: ServerConfig,
    ) -> Self {
        let admission = AdmissionController::new(server_cfg.admission.clone());
        let metrics = Arc::new(ServerMetrics::new(server_cfg.workers.max(1)));
        // inbox bound = admission cap: a submit holding a permit can
        // never block on a full channel
        let inbox = server_cfg.admission.max_in_flight.max(1);
        let (tx, rx): (SyncSender<Request>, Receiver<Request>) = mpsc::sync_channel(inbox);
        let precision = server_cfg.precision;
        let dispatcher = {
            let admission = Arc::clone(&admission);
            let metrics = Arc::clone(&metrics);
            std::thread::spawn(move || {
                run_dispatcher(model, cfg, opts, masks, server_cfg, admission, metrics, rx)
            })
        };
        Self {
            tx: Mutex::new(Some(tx)),
            admission,
            metrics,
            dispatcher: Mutex::new(Some(dispatcher)),
            precision,
        }
    }

    /// Kernel precision the engine workers run at.
    pub fn precision(&self) -> KernelPrecision {
        self.precision
    }

    /// Submit an image with no explicit deadline (the configured
    /// `default_deadline` still applies).
    pub fn submit(&self, image: Tensor) -> crate::Result<Receiver<ReplyResult>> {
        self.submit_with_deadline(image, None)
    }

    /// Submit an image; returns a receiver for the reply.
    ///
    /// Errors instead of panicking (the seed `expect`ed on a dead
    /// dispatcher): [`crate::Error::Busy`] when admission sheds the
    /// request, [`crate::Error::Runtime`] when the server is draining or
    /// the dispatcher died. A poisoned handle lock (some caller panicked
    /// mid-submit) is recovered, not propagated.
    pub fn submit_with_deadline(
        &self,
        image: Tensor,
        deadline: Option<Duration>,
    ) -> crate::Result<Receiver<ReplyResult>> {
        let permit = self.admission.try_admit()?;
        let tx = match &*lock_clean(&self.tx) {
            Some(tx) => tx.clone(),
            None => {
                return Err(crate::Error::Runtime(
                    "inference server draining: not accepting new requests".into(),
                ))
            }
        };
        let now = Instant::now();
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = Request {
            image,
            submitted: now,
            deadline: self.admission.deadline_from(now, deadline),
            permit,
            reply: reply_tx,
            retries: 0,
        };
        tx.send(req).map_err(|_| {
            crate::Error::Runtime("inference dispatcher disconnected".into())
        })?;
        Ok(reply_rx)
    }

    /// Admission state (queue depth, shed counters) for the front-end.
    pub fn admission(&self) -> Arc<AdmissionController> {
        Arc::clone(&self.admission)
    }

    /// Live serving metrics (latency, energy) for the front-end.
    pub fn metrics(&self) -> Arc<ServerMetrics> {
        Arc::clone(&self.metrics)
    }

    /// Point-in-time metrics view.
    pub fn snapshot(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Graceful drain: stop accepting (subsequent [`submit`]s get
    /// [`crate::Error::Runtime`]), finish every in-flight request —
    /// supervision keeps running, so a worker dying mid-drain is still
    /// healed — join the workers, and return the final report. Errors on
    /// double shutdown or a panicked dispatcher.
    ///
    /// [`submit`]: InferenceServer::submit
    pub fn shutdown(&self) -> crate::Result<ServerReport> {
        drop(lock_clean(&self.tx).take());
        let handle = lock_clean(&self.dispatcher).take().ok_or_else(|| {
            crate::Error::Runtime("inference server already shut down".into())
        })?;
        handle
            .join()
            .map_err(|_| crate::Error::Runtime("inference dispatcher panicked".into()))
    }
}

/// Park a lost shard's requests for re-dispatch, failing the ones whose
/// retry budget is spent.
fn requeue_lost(
    requests: Vec<Request>,
    retry_q: &mut Vec<(Instant, Request)>,
    sup: &SupervisorConfig,
    metrics: &ServerMetrics,
    now: Instant,
) {
    let mut failed = 0u64;
    for mut req in requests {
        if req.retries >= sup.max_retries {
            failed += 1;
            fail_request(req, ServeError::WorkerLost);
        } else {
            req.retries += 1;
            // exponential backoff: base × 2^(attempt−1)
            let delay = sup.backoff.saturating_mul(1u32 << (req.retries - 1).min(20));
            metrics.note_request_retry();
            retry_q.push((now + delay, req));
        }
    }
    if failed > 0 {
        metrics.note_worker_lost(failed);
    }
}

/// One supervision pass: reap dead workers, steal from stuck ones,
/// respawn within budget (the replacement resumes the queue backlog),
/// and requeue recovered requests. Also publishes the per-replica
/// queue-depth gauges.
fn supervise(
    slots: &mut [WorkerSlot],
    ctx: &Arc<WorkerContext>,
    sup: &SupervisorConfig,
    retry_q: &mut Vec<(Instant, Request)>,
) {
    let now = Instant::now();
    for slot in slots.iter_mut() {
        let q = &ctx.queues[slot.widx];
        ctx.metrics.set_replica_queue_depth(slot.widx, q.in_flight());
        let (dead, stuck) = match &slot.gen {
            Some(g) => {
                let dead = g.handle.is_finished();
                let stuck = !dead
                    && g.health
                        .busy_for(ctx.epoch, now)
                        .is_some_and(|d| d >= sup.watchdog);
                (dead, stuck)
            }
            None => continue,
        };
        if !dead && !stuck {
            continue;
        }
        // retire this generation: bump the queue's generation token so
        // a stuck zombie stands down at its next queue visit (it may
        // still finish the shard it committed to — a late reply, not
        // double execution: the checkpoint protocol keeps execution
        // exactly-once).
        let gen = slot.gen.take().expect("checked above");
        lock_clean(&q.inner).gen += 1;
        q.cv.notify_all();
        if dead {
            let _ = gen.handle.join(); // reap; a panic is already handled
        } // stuck: detach — never block the dispatcher on a zombie
        ctx.metrics.set_worker_up(slot.widx, false);
        ctx.metrics.set_worker_brownout(slot.widx, false);
        // recover the checkpointed shard: a dead worker's slot is free
        // (poison recovered); for a stuck one only try_lock — a held
        // lock means the worker is actively moving, nothing to steal
        let recovered = if dead {
            lock_clean(&gen.health.checkpoint).take()
        } else {
            match gen.health.checkpoint.try_lock() {
                Ok(mut g) => g.take(),
                Err(_) => None,
            }
        };
        if let Some(shard) = recovered {
            // settle the recovered shard against its home ledger (it
            // may be a stolen shard from a peer's queue)
            ctx.queues[shard.home].account();
            requeue_lost(shard.requests, retry_q, sup, &ctx.metrics, now);
        }
        if slot.restarts < sup.max_restarts {
            // warm restart: fresh engine from the retained config, same
            // worker id (drift fingerprints + metric slots stay stable).
            // The replacement resumes the queue backlog — queued shards
            // survive their worker.
            slot.restarts += 1;
            ctx.metrics.note_worker_restart();
            // reconcile the ledger first: backlogged shards stay in
            // flight; anything the dead generation had popped without
            // accounting is written off. (A detached zombie completing
            // after this store double-accounts one shard — benign: the
            // ledger saturates at zero and the next reconcile resets it.)
            let backlog = lock_clean(&q.inner).shards.len() as u64;
            q.accounted.store(
                q.enqueued.load(Ordering::Acquire).saturating_sub(backlog),
                Ordering::Release,
            );
            slot.gen = Some(spawn_engine_worker(ctx, slot.widx));
        } else {
            // retired for good: nothing will serve this queue again —
            // requeue its backlog and settle the ledger
            let orphans: Vec<Shard> =
                lock_clean(&q.inner).shards.drain(..).collect();
            for shard in orphans {
                requeue_lost(shard.requests, retry_q, sup, &ctx.metrics, now);
            }
            q.accounted.store(q.enqueued.load(Ordering::Acquire), Ordering::Release);
        }
    }
}

/// Route `batch` across the replica pool: snapshot every live replica
/// with queue headroom as a [`ReplicaState`] and let the cluster
/// scheduler split the batch across the coolest, least-loaded ones.
/// Returns without blocking: requests that cannot be placed right now
/// are parked in `retry_q`.
fn dispatch_batch(
    mut batch: Vec<Request>,
    slots: &mut [WorkerSlot],
    ctx: &Arc<WorkerContext>,
    retry_q: &mut Vec<(Instant, Request)>,
    max_batch: usize,
) {
    let any_live = slots.iter().any(|s| s.gen.is_some());
    if !any_live {
        // every restart budget is spent: degrade to failing requests
        // fast (clients see retryable errors, the process stays up)
        ctx.metrics.note_worker_lost(batch.len() as u64);
        for req in batch {
            fail_request(req, ServeError::WorkerLost);
        }
        return;
    }
    // capacity-aware routing: only replicas with queue headroom are
    // candidates, so a planned shard can always be queued immediately
    // and the dispatcher never blocks behind a slow worker
    let avail: Vec<ReplicaState> = slots
        .iter()
        .filter_map(|s| {
            s.gen.as_ref().and_then(|g| {
                let q = &ctx.queues[s.widx];
                let depth = q.in_flight();
                (depth < WORKER_QUEUE_DEPTH as u64).then(|| ReplicaState {
                    idx: s.widx,
                    queue_depth: depth,
                    ewma_us: q.ewma_us.load(Ordering::Acquire),
                    health: g.health.degraded.load(Ordering::Acquire) as u64,
                    heat_milli: g.health.heat_milli.load(Ordering::Acquire),
                    hot: g.health.brownout.load(Ordering::Acquire),
                })
            })
        })
        .collect();
    let now = Instant::now();
    if avail.is_empty() {
        // live but saturated: park the whole batch for a moment (no
        // retry charge — backpressure, not failure)
        for req in batch {
            retry_q.push((now + Duration::from_millis(1), req));
        }
        return;
    }
    let batch_size = batch.len();
    ctx.metrics.note_batch();
    ctx.metrics.note_batch_occupancy(batch_size);
    let plan = plan_shards(batch_size, &avail, max_batch);
    // drain back-to-front so earlier ranges stay valid
    for (widx, range) in plan.into_iter().rev() {
        let requests: Vec<Request> = batch.drain(range).collect();
        let slot = &mut slots[widx];
        let shard = Shard { requests, batch_size, seq: slot.seq_next, home: widx };
        slot.seq_next += 1;
        ctx.queues[widx].push(shard);
        ctx.metrics.note_routed(widx);
    }
}

#[allow(clippy::too_many_arguments)]
fn run_dispatcher(
    model: Model,
    cfg: AcceleratorConfig,
    opts: EngineOptions,
    masks: std::collections::BTreeMap<String, crate::sparsity::LayerMask>,
    server_cfg: ServerConfig,
    admission: Arc<AdmissionController>,
    metrics: Arc<ServerMetrics>,
    rx: Receiver<Request>,
) -> ServerReport {
    let n_workers = server_cfg.workers.max(1);
    let sup = server_cfg.supervisor.clone();
    // co-design loop setup. Weight-column statistics are fixed for the
    // whole run (serving never retrains), so compute them once while
    // the model is still ours to borrow mutably; the DST job wraps the
    // deployed masks and re-selects columns for minimum power at the
    // same density.
    let dst_cfg = server_cfg.dst.clone();
    let mut model = model;
    let mut col_stats: BTreeMap<String, Vec<Vec<f64>>> = BTreeMap::new();
    if dst_cfg.enabled {
        let (rows, cols) = cfg.chunk_shape();
        let dims: BTreeMap<String, (usize, usize)> =
            model.matmul_layers().into_iter().map(|(n, o, i)| (n, (o, i))).collect();
        model.visit_weights_mut(|name, w, _| {
            if let Some(&(o, i)) = dims.get(name) {
                col_stats.insert(name.to_string(), chunked_col_norms(w, o, i, rows, cols));
            }
        });
    }
    let mut dst_job: Option<DstJob> = (dst_cfg.enabled && !masks.is_empty()).then(|| {
        let mzi = Mzi::new(MziSpec::low_power(), cfg.l_s, &GammaModel::paper());
        DstJob::new(masks.clone(), DST_ALPHA0, dst_cfg.rounds, cfg.k2, mzi)
    });
    let mut next_generation: u64 = 1;
    if let Some(dir) = dst_cfg.enabled.then_some(dst_cfg.artifact_dir.as_ref()).flatten() {
        // resume the generation counter past any persisted history,
        // skipping (and counting) whatever did not survive on disk —
        // a damaged artifact directory must not stop the service or
        // replay a stale generation number
        let (prior, skipped) = MaskArtifact::scan_dir(dir);
        if let Some(last) = prior.last() {
            next_generation = last.generation + 1;
        }
        metrics.note_artifacts_skipped(skipped as u64);
    }
    let mut last_dst_round = Instant::now();
    let queues: Vec<Arc<ReplicaQueue>> =
        (0..n_workers).map(|_| Arc::new(ReplicaQueue::new())).collect();
    let ctx = Arc::new(WorkerContext {
        model,
        cfg,
        opts,
        masks,
        engine_threads: server_cfg.engine_threads.max(1),
        precision: server_cfg.precision,
        thermal: server_cfg.thermal.clone(),
        faults: server_cfg.faults.clone(),
        metrics: Arc::clone(&metrics),
        epoch: Instant::now(),
        queues,
        steal: server_cfg.cluster.steal,
        dst: server_cfg.dst.clone(),
        repair: server_cfg.repair.clone(),
        swap: Mutex::new(None),
    });
    let mut slots: Vec<WorkerSlot> = (0..n_workers)
        .map(|widx| WorkerSlot {
            widx,
            restarts: 0,
            seq_next: 0,
            gen: Some(spawn_engine_worker(&ctx, widx)),
        })
        .collect();

    let started = Instant::now();
    let mut retry_q: Vec<(Instant, Request)> = Vec::new();
    let mut inbox_open = true;
    loop {
        supervise(&mut slots, &ctx, &sup, &mut retry_q);
        // co-design loop: step the DST job on the dispatcher's idle
        // headroom — paced by the period and gated on an idle,
        // non-browned-out replica, so background mask optimization
        // never displaces traffic or leans on a drifted board
        if let Some(job) = dst_job.as_mut() {
            let idle_cool = || {
                slots.iter().any(|s| {
                    s.gen.as_ref().is_some_and(|g| {
                        !g.health.brownout.load(Ordering::Acquire)
                            && ctx.queues[s.widx].in_flight() == 0
                    })
                })
            };
            if !job.is_done()
                && last_dst_round.elapsed() >= dst_cfg.period
                && idle_cool()
            {
                last_dst_round = Instant::now();
                let p_avg_w = metrics.snapshot().p_avg_w;
                if let Some(cand) = job.step(&col_stats, p_avg_w) {
                    let artifact = MaskArtifact::new(
                        next_generation,
                        cand.masks,
                        cand.power_mw,
                        cand.observed_power_w,
                    );
                    if let Some(dir) = &dst_cfg.artifact_dir {
                        // provenance only: a full disk must never take
                        // serving down with it
                        let _ = artifact.save_atomic(dir);
                    }
                    next_generation += 1;
                    *lock_clean(&ctx.swap) = Some(Arc::new(PendingSwap {
                        artifact,
                        bad_canary: dst_cfg.inject_bad_canary,
                        rejected: AtomicBool::new(false),
                    }));
                }
            }
        }
        // due retries seed the batch ahead of fresh arrivals
        let mut batch: Vec<Request> = Vec::new();
        let now = Instant::now();
        let mut i = 0;
        while i < retry_q.len() && batch.len() < server_cfg.max_batch {
            if retry_q[i].0 <= now {
                batch.push(retry_q.remove(i).1);
            } else {
                i += 1;
            }
        }
        if inbox_open && batch.is_empty() {
            // wait for work, bounded so supervision (and pending
            // retries) stay live
            let mut wait = SUPERVISE_TICK;
            if let Some(due) = retry_q.iter().map(|(d, _)| *d).min() {
                let until = due.saturating_duration_since(now);
                wait = wait.min(until.max(Duration::from_millis(1)));
            }
            match rx.recv_timeout(wait) {
                Ok(r) => batch.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => inbox_open = false,
            }
        }
        if inbox_open && !batch.is_empty() {
            // dynamic batching: top up until max_batch or timeout
            let deadline = Instant::now() + server_cfg.batch_timeout;
            while batch.len() < server_cfg.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(r) => batch.push(r),
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        inbox_open = false;
                        break;
                    }
                }
            }
        }
        if batch.is_empty() {
            // inbox closed: drain. Keep supervising until no retry is
            // pending and every queue ledger is settled — a worker
            // dying mid-drain is still healed, and its queue backlog is
            // served by the replacement.
            if !inbox_open
                && retry_q.is_empty()
                && ctx.queues.iter().map(|q| q.in_flight()).sum::<u64>() == 0
            {
                break;
            }
            if !inbox_open {
                std::thread::sleep(Duration::from_millis(1));
            }
            continue;
        }
        // drop expired requests before they cost engine time
        let now = Instant::now();
        let (batch, dead): (Vec<Request>, Vec<Request>) =
            batch.into_iter().partition(|r| !r.expired(now));
        if !dead.is_empty() {
            metrics.note_expired(dead.len() as u64);
            for req in dead {
                fail_request(req, ServeError::Expired);
            }
        }
        if batch.is_empty() {
            continue;
        }
        dispatch_batch(batch, &mut slots, &ctx, &mut retry_q, server_cfg.max_batch);
    }
    // shutdown: close worker queues, join, report from the shared ledger
    let workers_live = slots.iter().filter(|s| s.gen.is_some()).count();
    for q in &ctx.queues {
        lock_clean(&q.inner).closed = true;
        q.cv.notify_all();
    }
    let handles: Vec<JoinHandle<()>> =
        slots.iter_mut().filter_map(|s| s.gen.take()).map(|g| g.handle).collect();
    for h in handles {
        let _ = h.join();
    }
    let snap = metrics.snapshot();
    let elapsed = started.elapsed().as_secs_f64().max(1e-9);
    ServerReport {
        requests: snap.requests,
        batches: snap.batches,
        mean_batch_occupancy: snap.mean_batch_occupancy,
        workers: n_workers,
        workers_live,
        mean_latency_us: snap.mean_us,
        p50_us: snap.p50_us,
        p99_us: snap.p99_us,
        throughput_rps: snap.requests as f64 / elapsed,
        energy_mj: snap.energy_mj,
        // average power per occupied accelerator slot-time, consistent
        // with the single-worker definition
        p_avg_w: snap.p_avg_w,
        shed: admission.shed_total(),
        expired: snap.expired,
        worker_lost: snap.worker_lost,
        worker_restarts: snap.worker_restarts,
        request_retries: snap.request_retries,
        brownouts: snap.brownouts_total,
        recalibrations: snap.recalibrations,
        recal_chunks: snap.recal_chunks,
        steals: snap.steals,
        routed: snap.routed,
        mask_swaps: snap.mask_swaps,
        mask_rollbacks: snap.mask_rollbacks,
        mask_generation: snap.mask_generation,
        mask_power_mw: snap.mask_power_mw,
        faults_injected: snap.faults_injected,
        fault_detections: snap.fault_detections,
        fault_repairs: snap.fault_repairs,
        fault_unrepairable: snap.fault_unrepairable,
        fault_detection_latency_us: snap.fault_detection_latency_us,
        degraded: snap.worker_degraded,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SparsitySupport;

    fn test_cfg() -> AcceleratorConfig {
        AcceleratorConfig {
            features: SparsitySupport::NONE,
            dac: crate::config::DacKind::Edac,
            l_g: 5.0,
            ..Default::default()
        }
    }

    fn sample_img(class: usize, i: usize) -> Tensor {
        let ds = crate::data::SyntheticDataset::new(crate::data::DatasetSpec::fmnist_like());
        ds.sample(class as u64, i).0
    }

    fn heat_only_drift() -> DriftConfig {
        DriftConfig {
            ambient_amp_rad: 0.0,
            self_heat_amp_rad: 0.2,
            self_heat_tau_reqs: 4.0,
            time_scale: 0.0,
            ..DriftConfig::default()
        }
    }

    #[test]
    fn builder_validates_each_invariant() {
        let cases: Vec<(ServerConfigBuilder, &str)> = vec![
            (ServerConfig::builder().workers(0), "workers"),
            (ServerConfig::builder().max_batch(0), "max_batch"),
            (ServerConfig::builder().engine_threads(0), "engine_threads"),
            (ServerConfig::builder().max_in_flight(0), "max_in_flight"),
            (
                ServerConfig::builder()
                    .batch_timeout(Duration::from_millis(100))
                    .watchdog(Duration::from_millis(100)),
                "watchdog",
            ),
            (
                ServerConfig::builder().dst(DstServerConfig {
                    enabled: true,
                    canary_threshold: 1.5,
                    ..Default::default()
                }),
                "canary_threshold",
            ),
            (
                ServerConfig::builder().dst(DstServerConfig {
                    enabled: true,
                    rounds: 0,
                    ..Default::default()
                }),
                "rounds",
            ),
            (
                ServerConfig::builder().repair(RepairServerConfig {
                    sentinel: true,
                    canary_threshold: 1.5,
                    ..Default::default()
                }),
                "repair.canary_threshold",
            ),
        ];
        for (builder, needle) in cases {
            match builder.build() {
                Err(crate::Error::Config(msg)) => {
                    assert!(msg.contains(needle), "message {msg:?} must name {needle:?}")
                }
                other => panic!("invalid config for {needle:?} must fail, got {other:?}"),
            }
        }
        assert!(ServerConfig::builder().build().is_ok(), "defaults are valid");
    }

    #[test]
    fn config_json_roundtrip_and_validation() {
        let cfg = ServerConfig::builder()
            .max_batch(6)
            .batch_timeout(Duration::from_millis(3))
            .workers(4)
            .precision(KernelPrecision::Quantized)
            .steal(true)
            .max_in_flight(64)
            .default_deadline(Some(Duration::from_millis(250)))
            .watchdog(Duration::from_millis(500))
            .max_restarts(2)
            .thermal(ThermalServerConfig {
                drift: Some(DriftConfig::default()),
                policy: ThermalPolicy::Threshold { budget_rad: 0.01 },
                brownout_budget_rad: Some(0.02),
                drift_only_worker: Some(1),
            })
            .faults(FaultPlan::parse("panic@w0:s2", 4).expect("spec"))
            .dst(DstServerConfig {
                enabled: true,
                period: Duration::from_millis(7),
                rounds: 12,
                canary_threshold: 0.75,
                inject_bad_canary: true,
                artifact_dir: Some(PathBuf::from("/tmp/masks")),
            })
            .repair(RepairServerConfig {
                device_faults: DeviceFaultPlan::parse("dead-pd@fc1:c0:r3").expect("spec"),
                inject_after_shards: 9,
                sentinel: true,
                probe_period: Duration::from_millis(4),
                canary_threshold: 0.25,
            })
            .build()
            .expect("valid config");
        let text = cfg.to_json().to_string();
        let back = ServerConfig::from_json(&text).expect("round-trip parses");
        assert_eq!(back.max_batch, 6);
        assert_eq!(back.batch_timeout, Duration::from_millis(3));
        assert_eq!(back.workers, 4);
        assert_eq!(back.precision, KernelPrecision::Quantized);
        assert!(back.cluster.steal);
        assert_eq!(back.admission.max_in_flight, 64);
        assert_eq!(back.admission.default_deadline, Some(Duration::from_millis(250)));
        assert_eq!(back.supervisor.watchdog, Duration::from_millis(500));
        assert_eq!(back.supervisor.max_restarts, 2);
        assert!(back.thermal.drift.is_some());
        assert!(matches!(
            back.thermal.policy,
            ThermalPolicy::Threshold { budget_rad } if (budget_rad - 0.01).abs() < 1e-12
        ));
        assert_eq!(back.thermal.brownout_budget_rad, Some(0.02));
        assert_eq!(back.thermal.drift_only_worker, Some(1));
        assert_eq!(back.faults.describe(), cfg.faults.describe());
        assert!(back.dst.enabled);
        assert_eq!(back.dst.period, Duration::from_millis(7));
        assert_eq!(back.dst.rounds, 12);
        assert!((back.dst.canary_threshold - 0.75).abs() < 1e-12);
        assert!(back.dst.inject_bad_canary);
        assert_eq!(back.dst.artifact_dir, Some(PathBuf::from("/tmp/masks")));
        assert_eq!(
            back.repair.device_faults.describe(),
            cfg.repair.device_faults.describe()
        );
        assert_eq!(back.repair.inject_after_shards, 9);
        assert!(back.repair.sentinel);
        assert_eq!(back.repair.probe_period, Duration::from_millis(4));
        assert!((back.repair.canary_threshold - 0.25).abs() < 1e-12);
        // default precision is Exact; bad values must be rejected, not
        // silently coerced
        assert_eq!(
            ServerConfig::from_json("{}").expect("empty config").precision,
            KernelPrecision::Exact
        );
        assert_eq!(
            ServerConfig::from_json("{\"precision\": \"QUANTIZED\"}")
                .expect("case-insensitive")
                .precision,
            KernelPrecision::Quantized
        );
        assert!(ServerConfig::from_json("{\"precision\": \"fast\"}").is_err());
        assert!(ServerConfig::from_json("{\"precision\": 3}").is_err());
        // typos must not silently fall back to defaults
        assert!(ServerConfig::from_json("{\"max_batcch\": 4}").is_err());
        assert!(
            ServerConfig::from_json("{\"dst\": {\"perod_ms\": 5}}").is_err(),
            "unknown dst keys must not be dropped silently"
        );
        assert!(
            ServerConfig::from_json("{\"repair\": {\"probe_perod_ms\": 5}}").is_err(),
            "unknown repair keys must not be dropped silently"
        );
        assert!(
            ServerConfig::from_json("{\"repair\": {\"device_faults\": \"melt@x\"}}").is_err(),
            "malformed fault specs must fail at load time"
        );
        // file configs pass the same validation as the builder
        assert!(ServerConfig::from_json("{\"workers\": 0}").is_err());
    }

    #[test]
    fn replica_queue_ledger_and_ewma() {
        let q = ReplicaQueue::new();
        assert_eq!(q.in_flight(), 0);
        q.push(Shard { requests: Vec::new(), batch_size: 1, seq: 0, home: 0 });
        assert_eq!(q.in_flight(), 1, "queued counts as in flight");
        let popped = lock_clean(&q.inner).shards.pop_front();
        assert!(popped.is_some());
        assert_eq!(q.in_flight(), 1, "executing still counts as in flight");
        q.account();
        assert_eq!(q.in_flight(), 0, "accounting settles the ledger");
        // EWMA: first sample seeds, later samples fold at 1/5 weight
        q.observe_service_us(1000);
        assert_eq!(q.ewma_us.load(Ordering::Acquire), 1000);
        q.observe_service_us(2000);
        assert_eq!(q.ewma_us.load(Ordering::Acquire), 1200);
    }

    #[test]
    fn serves_batches_and_reports() {
        let server = InferenceServer::spawn(
            crate::nn::models::cnn3(),
            test_cfg(),
            EngineOptions::IDEAL,
            Default::default(),
            ServerConfig::builder()
                .max_batch(4)
                .batch_timeout(Duration::from_millis(1))
                .build()
                .expect("config"),
        );
        let mut rxs = Vec::new();
        for i in 0..6 {
            rxs.push(server.submit(sample_img(0, i)).expect("admitted"));
        }
        for rx in rxs {
            let reply = rx
                .recv_timeout(Duration::from_secs(120))
                .expect("reply")
                .expect("served");
            assert_eq!(reply.logits.len(), 10);
            assert!(reply.class < 10);
            assert!(reply.batch_size >= 1);
            assert!(
                reply.energy_mj > 0.0,
                "every request carries its batched-pass energy share"
            );
        }
        let report = server.shutdown().expect("report");
        assert_eq!(report.requests, 6);
        assert!(report.batches >= 1 && report.batches <= 6);
        assert!(
            report.mean_batch_occupancy >= 1.0
                && report.mean_batch_occupancy <= 4.0 + 1e-9,
            "mean occupancy within [1, max_batch]: {}",
            report.mean_batch_occupancy
        );
        assert!(
            (report.mean_batch_occupancy - 6.0 / report.batches as f64).abs() < 1e-9,
            "mean occupancy consistent with requests/batches"
        );
        assert!(report.energy_mj > 0.0);
        assert!(report.p99_us >= report.p50_us);
        assert_eq!(report.shed, 0);
        assert_eq!(report.expired, 0);
        assert_eq!(report.worker_restarts, 0, "no faults, no restarts");
        assert_eq!(report.workers_live, 1);
        assert_eq!(report.routed.len(), 1);
        assert_eq!(
            report.routed[0] as usize, report.batches,
            "single replica carries every dispatched batch"
        );
        assert_eq!(report.steals, 0, "stealing is off by default");
    }

    /// The batched engine pass must return exactly what per-request
    /// passes on a fresh engine return: EngineOptions::IDEAL has no
    /// per-call randomness, so the served logits are reproducible by a
    /// standalone engine regardless of how the server batched them.
    #[test]
    fn served_logits_equal_offline_forward_regardless_of_batching() {
        let model = crate::nn::models::cnn3();
        let server = InferenceServer::spawn(
            model.clone(),
            test_cfg(),
            EngineOptions::IDEAL,
            Default::default(),
            ServerConfig::builder()
                .max_batch(8)
                .batch_timeout(Duration::from_millis(50))
                .build()
                .expect("config"),
        );
        let images: Vec<Tensor> = (0..5).map(|i| sample_img(2, i)).collect();
        let rxs: Vec<_> = images
            .iter()
            .map(|img| server.submit(img.clone()).expect("admitted"))
            .collect();
        let mut offline = PhotonicEngine::new(test_cfg(), EngineOptions::IDEAL);
        if let Some((last, _, _)) = model.matmul_layers().last() {
            offline.set_protected([last.clone()].into_iter().collect());
        }
        for (img, rx) in images.into_iter().zip(rxs) {
            let want = model.forward(img, &mut offline);
            let reply = rx
                .recv_timeout(Duration::from_secs(120))
                .expect("reply")
                .expect("served");
            assert_eq!(reply.logits, want.data, "batched serving moved bits");
        }
        server.shutdown().expect("report");
    }

    #[test]
    fn multi_worker_sharding_serves_everything() {
        let server = InferenceServer::spawn(
            crate::nn::models::cnn3(),
            test_cfg(),
            EngineOptions::IDEAL,
            Default::default(),
            ServerConfig::builder()
                .max_batch(8)
                .batch_timeout(Duration::from_millis(2))
                .workers(3)
                .engine_threads(1)
                .build()
                .expect("config"),
        );
        let mut rxs = Vec::new();
        for i in 0..9 {
            rxs.push(server.submit(sample_img(7, i)).expect("admitted"));
        }
        // every request answered exactly once, with sane logits
        for rx in rxs {
            let reply = rx
                .recv_timeout(Duration::from_secs(120))
                .expect("reply")
                .expect("served");
            assert_eq!(reply.logits.len(), 10);
        }
        let report = server.shutdown().expect("report");
        assert_eq!(report.requests, 9);
        assert_eq!(report.workers, 3);
        assert_eq!(report.workers_live, 3);
        assert!(report.energy_mj > 0.0, "all workers account energy");
        assert_eq!(report.routed.len(), 3);
        assert!(report.routed.iter().sum::<u64>() >= 1, "shards were routed");
    }

    #[test]
    fn admission_cap_sheds_with_conservation() {
        // one slot, and a long batching window so the first request is
        // still holding its permit when the rest arrive
        let server = InferenceServer::spawn(
            crate::nn::models::cnn3(),
            test_cfg(),
            EngineOptions::IDEAL,
            Default::default(),
            ServerConfig::builder()
                .max_batch(8)
                .batch_timeout(Duration::from_millis(300))
                .max_in_flight(1)
                .build()
                .expect("config"),
        );
        let rx = server.submit(sample_img(0, 0)).expect("first admitted");
        let mut shed = 0;
        for i in 0..5 {
            match server.submit(sample_img(0, i + 1)) {
                Err(crate::Error::Busy { retry_after_ms }) => {
                    assert!(retry_after_ms > 0);
                    shed += 1;
                }
                Ok(_) => panic!("cap 1 must shed while slot is held"),
                Err(e) => panic!("unexpected error {e}"),
            }
        }
        assert_eq!(shed, 5);
        let reply = rx.recv_timeout(Duration::from_secs(120)).expect("reply");
        assert!(reply.is_ok(), "admitted request must be served");
        let report = server.shutdown().expect("report");
        assert_eq!(report.requests, 1);
        assert_eq!(report.shed, 5);
    }

    #[test]
    fn expired_deadline_dropped_before_engine() {
        let server = InferenceServer::spawn(
            crate::nn::models::cnn3(),
            test_cfg(),
            EngineOptions::IDEAL,
            Default::default(),
            ServerConfig::builder().build().expect("config"),
        );
        // a zero deadline is already expired when the dispatcher looks
        let rx = server
            .submit_with_deadline(sample_img(0, 0), Some(Duration::ZERO))
            .expect("admitted");
        let reply = rx.recv_timeout(Duration::from_secs(30)).expect("reply");
        assert!(matches!(reply, Err(ServeError::Expired)), "got {reply:?}");
        let report = server.shutdown().expect("report");
        assert_eq!(report.requests, 0, "expired work never reached an engine");
        assert_eq!(report.expired, 1);
    }

    #[test]
    fn thermal_runtime_recalibrates_and_reports() {
        // heat-only drift (time_scale 0 freezes the ambient term), so
        // the envelope depends only on each worker's served count —
        // fully deterministic under test scheduling
        let server = InferenceServer::spawn(
            crate::nn::models::cnn3(),
            test_cfg(),
            EngineOptions::IDEAL,
            Default::default(),
            ServerConfig::builder()
                .max_batch(2)
                .batch_timeout(Duration::from_millis(1))
                .thermal(ThermalServerConfig {
                    drift: Some(heat_only_drift()),
                    policy: ThermalPolicy::Threshold { budget_rad: 0.01 },
                    ..Default::default()
                })
                .build()
                .expect("config"),
        );
        // serve sequentially so the single worker ticks between requests
        for i in 0..10 {
            let rx = server.submit(sample_img(3, i)).expect("admitted");
            let reply =
                rx.recv_timeout(Duration::from_secs(120)).expect("reply").expect("served");
            assert_eq!(reply.logits.len(), 10);
        }
        let snap = server.snapshot();
        assert!(snap.thermal_drift_rad > 0.0, "self-heating must register");
        assert!(snap.thermal_chunks > 0, "chunks under drift management");
        assert_eq!(
            snap.replica_heat_milli.len(),
            1,
            "one heat gauge per replica slot"
        );
        let report = server.shutdown().expect("report");
        assert_eq!(report.requests, 10);
        assert!(
            report.recalibrations >= 1,
            "threshold policy must have recalibrated: {report:?}"
        );
        assert!(report.recal_chunks >= 1);
    }

    #[test]
    fn shutdown_drains_in_flight_work() {
        let server = InferenceServer::spawn(
            crate::nn::models::cnn3(),
            test_cfg(),
            EngineOptions::IDEAL,
            Default::default(),
            ServerConfig::builder()
                .max_batch(8)
                .batch_timeout(Duration::from_millis(100))
                .build()
                .expect("config"),
        );
        let rxs: Vec<_> =
            (0..5).map(|i| server.submit(sample_img(1, i)).expect("admitted")).collect();
        // immediate shutdown must still serve everything already queued
        let report = server.shutdown().expect("report");
        assert_eq!(report.requests, 5, "drain serves queued work");
        for rx in rxs {
            assert!(rx.recv().expect("reply buffered").is_ok());
        }
        // post-drain submits fail cleanly, no panic
        match server.submit(sample_img(1, 9)) {
            Err(crate::Error::Runtime(_)) => {}
            other => panic!("expected Runtime error after shutdown, got {other:?}"),
        }
        assert!(server.shutdown().is_err(), "double shutdown is an error");
    }

    /// Satellite: a caller panicking while holding the handle locks must
    /// not poison the server for everyone else.
    #[test]
    fn submit_survives_poisoned_handle_lock() {
        let server = Arc::new(InferenceServer::spawn(
            crate::nn::models::cnn3(),
            test_cfg(),
            EngineOptions::IDEAL,
            Default::default(),
            ServerConfig::builder()
                .max_batch(2)
                .batch_timeout(Duration::from_millis(1))
                .build()
                .expect("config"),
        ));
        let poisoner = Arc::clone(&server);
        let _ = std::thread::spawn(move || {
            let _guard = poisoner.tx.lock().unwrap();
            panic!("poison the handle lock");
        })
        .join();
        assert!(server.tx.is_poisoned(), "precondition: lock is poisoned");
        let rx = server.submit(sample_img(0, 0)).expect("submit recovers the lock");
        assert!(rx.recv_timeout(Duration::from_secs(120)).expect("reply").is_ok());
        let report = server.shutdown().expect("shutdown recovers the lock");
        assert_eq!(report.requests, 1);
    }

    /// Tentpole: an injected worker panic loses nothing — the
    /// supervisor recovers the checkpointed shard, respawns the worker,
    /// and the retried requests produce bit-identical logits (IDEAL has
    /// no per-call randomness, and the respawned engine reprograms from
    /// the same retained config).
    #[test]
    fn supervisor_respawns_after_injected_panic() {
        let model = crate::nn::models::cnn3();
        let server = InferenceServer::spawn(
            model.clone(),
            test_cfg(),
            EngineOptions::IDEAL,
            Default::default(),
            ServerConfig::builder()
                .max_batch(4)
                .batch_timeout(Duration::from_millis(20))
                .faults(FaultPlan::parse("panic@w0:s0", 1).expect("spec"))
                .backoff(Duration::from_millis(1))
                .build()
                .expect("config"),
        );
        let images: Vec<Tensor> = (0..4).map(|i| sample_img(5, i)).collect();
        let rxs: Vec<_> = images
            .iter()
            .map(|img| server.submit(img.clone()).expect("admitted"))
            .collect();
        let mut offline = PhotonicEngine::new(test_cfg(), EngineOptions::IDEAL);
        if let Some((last, _, _)) = model.matmul_layers().last() {
            offline.set_protected([last.clone()].into_iter().collect());
        }
        for (img, rx) in images.into_iter().zip(rxs) {
            let want = model.forward(img, &mut offline);
            let reply = rx
                .recv_timeout(Duration::from_secs(120))
                .expect("reply")
                .expect("served after respawn");
            assert_eq!(reply.logits, want.data, "warm restart moved bits");
        }
        let report = server.shutdown().expect("report");
        assert_eq!(report.requests, 4, "every request served despite the panic");
        assert_eq!(report.worker_restarts, 1, "exactly one respawn");
        assert!(report.request_retries >= 1, "the killed shard was re-dispatched");
        assert_eq!(report.worker_lost, 0, "nothing surfaced as lost");
        assert_eq!(report.workers_live, 1, "pool back to full strength");
    }

    /// Tentpole: the watchdog steals the checkpointed shard from a
    /// stalled worker and a replacement serves it long before the
    /// zombie wakes up.
    #[test]
    fn watchdog_steals_stalled_shard() {
        let server = InferenceServer::spawn(
            crate::nn::models::cnn3(),
            test_cfg(),
            EngineOptions::IDEAL,
            Default::default(),
            ServerConfig::builder()
                .max_batch(2)
                .batch_timeout(Duration::from_millis(20))
                .faults(FaultPlan::parse("stall@w0:s0:20000ms", 1).expect("spec"))
                .watchdog(Duration::from_millis(50))
                .backoff(Duration::from_millis(1))
                .build()
                .expect("config"),
        );
        let started = Instant::now();
        let rxs: Vec<_> =
            (0..2).map(|i| server.submit(sample_img(4, i)).expect("admitted")).collect();
        for rx in rxs {
            let reply = rx
                .recv_timeout(Duration::from_secs(120))
                .expect("reply")
                .expect("served by the replacement");
            assert_eq!(reply.logits.len(), 10);
        }
        assert!(
            started.elapsed() < Duration::from_secs(15),
            "served via theft, not by waiting out the stall"
        );
        let report = server.shutdown().expect("report");
        assert_eq!(report.requests, 2);
        assert_eq!(report.worker_restarts, 1, "the zombie was replaced");
        assert_eq!(report.worker_lost, 0);
    }

    /// Tentpole: the retry budget is a real bound — a slot that dies on
    /// every attempt eventually surfaces `WorkerLost`.
    #[test]
    fn retry_budget_exhausts_to_worker_lost() {
        let server = InferenceServer::spawn(
            crate::nn::models::cnn3(),
            test_cfg(),
            EngineOptions::IDEAL,
            Default::default(),
            ServerConfig::builder()
                .max_batch(2)
                .batch_timeout(Duration::from_millis(1))
                .faults(FaultPlan::parse("panic@w0:s0,panic@w0:s1", 1).expect("spec"))
                .max_retries(1)
                .backoff(Duration::from_millis(1))
                .build()
                .expect("config"),
        );
        let rx = server.submit(sample_img(0, 0)).expect("admitted");
        let reply = rx.recv_timeout(Duration::from_secs(120)).expect("reply");
        assert!(matches!(reply, Err(ServeError::WorkerLost)), "got {reply:?}");
        let report = server.shutdown().expect("report");
        assert_eq!(report.requests, 0);
        assert_eq!(report.worker_lost, 1, "budget exhaustion surfaces WorkerLost");
        assert_eq!(report.worker_restarts, 2, "both panics healed the slot");
        assert!(report.request_retries >= 1);
    }

    /// Tentpole: a replica over its phase-error budget browns out —
    /// the flag registers, and with the recal policy OFF the only
    /// recalibrations in the report are the forced brownout ones.
    #[test]
    fn brownout_forces_recalibration_and_keeps_serving() {
        let server = InferenceServer::spawn(
            crate::nn::models::cnn3(),
            test_cfg(),
            EngineOptions::IDEAL,
            Default::default(),
            ServerConfig::builder()
                .max_batch(1)
                .batch_timeout(Duration::from_millis(1))
                .thermal(ThermalServerConfig {
                    drift: Some(heat_only_drift()),
                    policy: ThermalPolicy::Off,
                    brownout_budget_rad: Some(1e-3),
                    ..Default::default()
                })
                .build()
                .expect("config"),
        );
        for i in 0..8 {
            let rx = server.submit(sample_img(6, i)).expect("admitted");
            let reply =
                rx.recv_timeout(Duration::from_secs(120)).expect("reply").expect("served");
            assert_eq!(reply.logits.len(), 10, "brownout degrades, never drops");
        }
        let snap = server.snapshot();
        assert!(snap.brownouts_total >= 1, "self-heating must trip the budget");
        let report = server.shutdown().expect("report");
        assert_eq!(report.requests, 8);
        assert!(report.brownouts >= 1);
        assert!(
            report.recalibrations >= 1,
            "policy is Off, so any recalibration is brownout-forced: {report:?}"
        );
    }

    /// Offline twin of a serving replica at one mask generation: same
    /// config, same protected readout, same masks.
    fn offline_at(
        model: &Model,
        cfg: &AcceleratorConfig,
        masks: BTreeMap<String, crate::sparsity::LayerMask>,
    ) -> PhotonicEngine {
        let mut e = PhotonicEngine::new(cfg.clone(), EngineOptions::IDEAL);
        e.set_masks(masks);
        if let Some((last, _, _)) = model.matmul_layers().last() {
            e.set_protected([last.clone()].into_iter().collect());
        }
        e
    }

    /// Tentpole: the co-design loop promotes candidate masks while
    /// traffic flows — at least two generations cut over at shard
    /// boundaries, reply conservation holds (nothing shed, expired, or
    /// lost to the swap), and every reply is bit-identical to an
    /// offline forward of whichever persisted generation was active.
    #[test]
    fn dst_promotes_masks_under_load_with_bit_exact_replies() {
        let model = crate::nn::models::cnn3();
        let cfg = test_cfg();
        let masks = crate::bench::common::build_masks(&model, &cfg, 0.6);
        assert!(!masks.is_empty(), "cnn3 must expose a maskable middle layer");
        let dir = std::env::temp_dir()
            .join(format!("scatter_swap_promote_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let server = InferenceServer::spawn(
            model.clone(),
            cfg.clone(),
            EngineOptions::IDEAL,
            masks.clone(),
            ServerConfig::builder()
                .max_batch(2)
                .batch_timeout(Duration::from_millis(1))
                .dst(DstServerConfig {
                    enabled: true,
                    period: Duration::from_millis(1),
                    rounds: 30,
                    // the canary gate itself is exercised by the
                    // rollback test below; 0 makes promotion
                    // deterministic here (argmax agreement of an
                    // untrained net under a real mask delta is not
                    // predictable)
                    canary_threshold: 0.0,
                    inject_bad_canary: false,
                    artifact_dir: Some(dir.clone()),
                })
                .build()
                .expect("config"),
        );
        // waves of traffic with idle gaps: the dispatcher only steps
        // DST on an idle, cool replica, and the worker only cuts over
        // at a shard boundary
        let mut replies: Vec<(Tensor, Vec<f64>)> = Vec::new();
        let mut waves = 0usize;
        while server.snapshot().mask_swaps < 2 && waves < 400 {
            let imgs: Vec<Tensor> =
                (0..2).map(|i| sample_img(waves % 10, i)).collect();
            let rxs: Vec<_> = imgs
                .iter()
                .map(|img| server.submit(img.clone()).expect("admitted"))
                .collect();
            for (img, rx) in imgs.into_iter().zip(rxs) {
                let reply = rx
                    .recv_timeout(Duration::from_secs(120))
                    .expect("reply")
                    .expect("served across swaps");
                replies.push((img, reply.logits));
            }
            waves += 1;
            std::thread::sleep(Duration::from_millis(2));
        }
        let report = server.shutdown().expect("report");
        assert!(
            report.mask_swaps >= 2,
            "at least two generations promoted: {report:?}"
        );
        assert_eq!(report.mask_rollbacks, 0, "every canary passed");
        assert!(report.mask_generation[0] >= 2, "gauge tracks the cutovers");
        assert!(report.mask_power_mw > 0.0, "promoted artifact carries power");
        assert_eq!(report.requests as usize, replies.len());
        assert_eq!(report.shed, 0);
        assert_eq!(report.expired, 0);
        assert_eq!(report.worker_lost, 0, "no drops attributable to swaps");
        // bit-exactness: one offline engine per deployed generation
        // (baseline + every persisted artifact, in generation order);
        // the active generation only moves forward, so a monotone
        // cursor over that list must explain every reply
        let mut engines = vec![offline_at(&model, &cfg, masks)];
        let mut paths: Vec<PathBuf> = std::fs::read_dir(&dir)
            .expect("artifact dir")
            .flatten()
            .map(|e| e.path())
            .filter(|p| p.extension().is_some_and(|x| x == "json"))
            .collect();
        paths.sort();
        for p in &paths {
            let a = MaskArtifact::load(p).expect("persisted artifact verifies");
            engines.push(offline_at(&model, &cfg, a.masks));
        }
        assert!(engines.len() >= 3, "baseline + >=2 persisted generations");
        let mut cur = 0usize;
        'replies: for (img, logits) in replies {
            for idx in cur..engines.len() {
                if model.forward(img.clone(), &mut engines[idx]).data == logits {
                    cur = idx;
                    continue 'replies;
                }
            }
            panic!("reply matches no deployed generation (cursor {cur})");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Tentpole: an injected failing canary rolls the candidate back at
    /// the shard boundary — no promotion, the generation gauge stays at
    /// the deployment baseline, no traffic is dropped, and every reply
    /// is still bit-identical to the baseline offline forward.
    #[test]
    fn bad_canary_rolls_back_without_dropping_traffic() {
        let model = crate::nn::models::cnn3();
        let cfg = test_cfg();
        let masks = crate::bench::common::build_masks(&model, &cfg, 0.6);
        let server = InferenceServer::spawn(
            model.clone(),
            cfg.clone(),
            EngineOptions::IDEAL,
            masks.clone(),
            ServerConfig::builder()
                .max_batch(2)
                .batch_timeout(Duration::from_millis(1))
                .dst(DstServerConfig {
                    enabled: true,
                    period: Duration::from_millis(1),
                    rounds: 20,
                    canary_threshold: 0.5,
                    inject_bad_canary: true,
                    artifact_dir: None,
                })
                .build()
                .expect("config"),
        );
        let mut offline = offline_at(&model, &cfg, masks);
        let mut waves = 0usize;
        let mut served = 0u64;
        while server.snapshot().mask_rollbacks < 1 && waves < 400 {
            let imgs: Vec<Tensor> =
                (0..2).map(|i| sample_img(waves % 10, i)).collect();
            let rxs: Vec<_> = imgs
                .iter()
                .map(|img| server.submit(img.clone()).expect("admitted"))
                .collect();
            for (img, rx) in imgs.into_iter().zip(rxs) {
                let want = model.forward(img, &mut offline);
                let reply = rx
                    .recv_timeout(Duration::from_secs(120))
                    .expect("reply")
                    .expect("served across the rollback");
                assert_eq!(
                    reply.logits, want.data,
                    "rollback must restore the baseline bit-for-bit"
                );
                served += 1;
            }
            waves += 1;
            std::thread::sleep(Duration::from_millis(2));
        }
        let report = server.shutdown().expect("report");
        assert!(report.mask_rollbacks >= 1, "canary veto must fire: {report:?}");
        assert_eq!(report.mask_swaps, 0, "a vetoed candidate never promotes");
        assert_eq!(report.mask_generation, vec![0], "gauge stays at baseline");
        assert_eq!(report.requests, served);
        assert_eq!(report.shed, 0);
        assert_eq!(report.expired, 0);
        assert_eq!(report.worker_lost, 0, "rollback drops nothing");
        assert_eq!(report.worker_restarts, 0, "rollback is not a crash path");
    }

    /// Satellite: a server restarting over a damaged artifact directory
    /// comes up on what survives — the skip count is published, and the
    /// generation counter resumes past the persisted history instead of
    /// replaying generation numbers.
    #[test]
    fn startup_scan_skips_damage_and_resumes_generations() {
        let model = crate::nn::models::cnn3();
        let cfg = test_cfg();
        let masks = crate::bench::common::build_masks(&model, &cfg, 0.6);
        let dir = std::env::temp_dir()
            .join(format!("scatter_swap_resume_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        MaskArtifact::new(3, masks.clone(), 1.0, 0.0)
            .save_atomic(&dir)
            .expect("persist prior generation");
        std::fs::write(dir.join("mask_gen_000004.json"), "{\"gener").expect("garbage");
        let server = InferenceServer::spawn(
            model,
            cfg,
            EngineOptions::IDEAL,
            masks,
            ServerConfig::builder()
                .max_batch(2)
                .batch_timeout(Duration::from_millis(1))
                .dst(DstServerConfig {
                    enabled: true,
                    period: Duration::from_millis(1),
                    rounds: 10,
                    canary_threshold: 0.0,
                    inject_bad_canary: false,
                    artifact_dir: Some(dir.clone()),
                })
                .build()
                .expect("config"),
        );
        let mut waves = 0usize;
        while server.snapshot().mask_swaps < 1 && waves < 400 {
            let rx = server.submit(sample_img(waves % 10, 0)).expect("admitted");
            assert!(rx.recv_timeout(Duration::from_secs(120)).expect("reply").is_ok());
            waves += 1;
            std::thread::sleep(Duration::from_millis(2));
        }
        let snap = server.snapshot();
        assert_eq!(snap.artifacts_skipped, 1, "the corrupt file is counted, not fatal");
        let report = server.shutdown().expect("report");
        assert!(report.mask_swaps >= 1, "serving resumed over the damage: {report:?}");
        assert!(
            report.mask_generation[0] >= 4,
            "generation counter resumed past the persisted gen 3: {report:?}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Tentpole: a rerouter branch dies mid-serving; the sentinel
    /// localizes it from idle-headroom probes, the repair canary
    /// promotes a quarantine mask, and not one request is shed, expired,
    /// or lost along the way. The replica stays healthy (a covered
    /// fault is repaired, not degraded).
    #[test]
    fn sentinel_repairs_midlife_fault_with_reply_conservation() {
        let model = crate::nn::models::cnn3();
        let cfg = test_cfg();
        let masks = crate::bench::common::build_masks(&model, &cfg, 0.6);
        // break an *active* branch of the masked middle layer — the
        // rerouter tree over that chunk is exactly the hardware the
        // quarantine repair steers light away with
        let (layer, lm) = masks.iter().next().expect("cnn3 has a masked layer");
        let j = lm.chunk(0, 0).col.iter().position(|&a| a).expect("active col");
        let plan = DeviceFaultPlan::parse(&format!("dead-branch@{layer}:c0:i{j}"))
            .expect("valid spec");
        let server = InferenceServer::spawn(
            model,
            cfg,
            EngineOptions::IDEAL,
            masks.clone(),
            ServerConfig::builder()
                .max_batch(2)
                .batch_timeout(Duration::from_millis(1))
                .repair(RepairServerConfig {
                    device_faults: plan,
                    inject_after_shards: 3,
                    sentinel: true,
                    probe_period: Duration::from_millis(1),
                    // agreement of an untrained net across a real mask
                    // delta is not predictable; the gate itself is
                    // exercised by the degraded-replica test below
                    canary_threshold: 0.0,
                })
                .build()
                .expect("config"),
        );
        let mut served = 0u64;
        let mut waves = 0usize;
        while server.snapshot().fault_repairs < 1 && waves < 400 {
            let rxs: Vec<_> = (0..2)
                .map(|i| server.submit(sample_img(waves % 10, i)).expect("admitted"))
                .collect();
            for rx in rxs {
                let reply = rx
                    .recv_timeout(Duration::from_secs(120))
                    .expect("reply")
                    .expect("served across fault + repair");
                assert_eq!(reply.logits.len(), 10);
                served += 1;
            }
            waves += 1;
            std::thread::sleep(Duration::from_millis(2));
        }
        let report = server.shutdown().expect("report");
        assert!(report.faults_injected >= 1, "mid-life injection fired: {report:?}");
        assert!(report.fault_detections >= 1, "sentinel localized the fault");
        assert!(report.fault_repairs >= 1, "quarantine repair promoted: {report:?}");
        assert_eq!(report.fault_unrepairable, 0, "covered fault must not degrade");
        assert_eq!(report.degraded, vec![false], "replica stays in full health");
        assert!(
            report.fault_detection_latency_us > 0,
            "injection->detection latency measured: {report:?}"
        );
        // reply conservation across the whole inject/detect/repair arc
        assert_eq!(report.requests, served);
        assert_eq!(report.shed, 0);
        assert_eq!(report.expired, 0);
        assert_eq!(report.worker_lost, 0, "repair drops nothing");
        assert_eq!(report.worker_restarts, 0, "repair is not a crash path");
    }

    /// Tentpole: a stuck MZI in the dense-deployed readout layer has no
    /// rerouter tree to quarantine around — the replica is marked
    /// degraded (visible in the report and down-ranked by the cluster
    /// scheduler) but keeps serving traffic: graceful degradation, not
    /// eviction.
    #[test]
    fn unrepairable_fault_degrades_replica_but_keeps_serving() {
        let model = crate::nn::models::cnn3();
        let (last, _, _) = model.matmul_layers().last().expect("readout").clone();
        let plan = DeviceFaultPlan::parse(&format!("stuck@{last}:c0:r0:i0:p1.2"))
            .expect("valid spec");
        let server = InferenceServer::spawn(
            model,
            test_cfg(),
            EngineOptions::IDEAL,
            Default::default(),
            ServerConfig::builder()
                .max_batch(2)
                .batch_timeout(Duration::from_millis(1))
                .repair(RepairServerConfig {
                    device_faults: plan,
                    inject_after_shards: 0, // broken from boot
                    sentinel: true,
                    probe_period: Duration::from_millis(1),
                    canary_threshold: 0.5,
                })
                .build()
                .expect("config"),
        );
        let mut served = 0u64;
        let mut waves = 0usize;
        while server.snapshot().fault_unrepairable < 1 && waves < 400 {
            let rxs: Vec<_> = (0..2)
                .map(|i| server.submit(sample_img(waves % 10, i)).expect("admitted"))
                .collect();
            for rx in rxs {
                let reply = rx
                    .recv_timeout(Duration::from_secs(120))
                    .expect("reply")
                    .expect("a degraded replica still serves");
                assert_eq!(reply.logits.len(), 10);
                served += 1;
            }
            waves += 1;
        }
        // degraded replicas keep serving — drive a few more waves to
        // prove the pool did not silently stop accepting work
        for i in 0..4 {
            let rx = server.submit(sample_img(i, i)).expect("admitted while degraded");
            assert!(
                rx.recv_timeout(Duration::from_secs(120)).expect("reply").is_ok(),
                "degraded replica must answer"
            );
            served += 1;
        }
        let report = server.shutdown().expect("report");
        assert!(report.faults_injected >= 1, "boot injection registered");
        assert!(report.fault_detections >= 1, "sentinel flagged the dense layer");
        assert!(report.fault_unrepairable >= 1, "no mask covers the readout fault");
        assert_eq!(report.fault_repairs, 0, "nothing to promote");
        assert_eq!(report.degraded, vec![true], "replica marked degraded");
        assert_eq!(report.requests, served, "conservation holds while degraded");
        assert_eq!(report.shed, 0);
        assert_eq!(report.expired, 0);
        assert_eq!(report.worker_lost, 0);
    }
}

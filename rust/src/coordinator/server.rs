//! Threaded batched-inference service over the photonic twin.
//!
//! Architecture (vLLM-router-like, scaled to this accelerator): clients
//! submit images over an mpsc channel; a dispatcher thread collects
//! requests into dynamic batches (up to `max_batch` or `batch_timeout`)
//! and shards each batch across `workers` engine threads, each owning its
//! own [`PhotonicEngine`] + model replica (mirroring N physical
//! accelerator boards behind one router). Workers reply on per-request
//! channels and keep their own latency/energy ledgers, merged into one
//! [`ServerReport`] at shutdown. The offline toolchain has no tokio, so
//! the event loop is std::thread + mpsc — same batching semantics,
//! simpler runtime.

use crate::coordinator::engine::{EngineOptions, PhotonicEngine};
use crate::coordinator::metrics::LatencyRecorder;
use crate::exec::partition_ranges;
use crate::nn::{Model, Tensor};
use crate::AcceleratorConfig;
use std::sync::mpsc::{self, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub batch_timeout: Duration,
    /// Engine worker threads the dispatcher shards batches across; each
    /// owns a full engine + model replica. 1 reproduces the single-board
    /// service exactly.
    pub workers: usize,
    /// Worker threads inside each engine's compiled execution path
    /// ([`PhotonicEngine::set_threads`]). Keep `workers ×
    /// engine_threads` at or below the host's cores.
    pub engine_threads: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            batch_timeout: Duration::from_millis(2),
            workers: 1,
            engine_threads: 1,
        }
    }
}

struct Request {
    image: Tensor,
    submitted: Instant,
    reply: Sender<Reply>,
}

/// One served prediction.
#[derive(Debug, Clone)]
pub struct Reply {
    pub class: usize,
    pub logits: Vec<f64>,
    pub latency: Duration,
    pub batch_size: usize,
}

/// Aggregate report at shutdown.
#[derive(Debug, Clone)]
pub struct ServerReport {
    pub requests: usize,
    pub batches: usize,
    pub workers: usize,
    pub mean_latency_us: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub throughput_rps: f64,
    pub energy_mj: f64,
    pub p_avg_w: f64,
}

/// One engine worker's ledger, merged at shutdown.
struct WorkerStats {
    latencies: LatencyRecorder,
    served: usize,
    energy_mj: f64,
    busy_ms: f64,
}

/// A shard of a dynamic batch, tagged with the full batch size (clients
/// observe the batch they rode in, not the shard).
struct Shard {
    requests: Vec<Request>,
    batch_size: usize,
}

fn spawn_engine_worker(
    model: Model,
    cfg: AcceleratorConfig,
    opts: EngineOptions,
    masks: std::collections::BTreeMap<String, crate::sparsity::LayerMask>,
    engine_threads: usize,
    rx: Receiver<Shard>,
) -> JoinHandle<WorkerStats> {
    std::thread::spawn(move || {
        let mut engine = PhotonicEngine::new(cfg, opts);
        engine.set_threads(engine_threads);
        engine.set_masks(masks);
        // §4.1: deploy the final linear layer on non-adjacent MZI
        // columns (crosstalk-protected readout)
        if let Some((last, _, _)) = model.matmul_layers().last() {
            engine.set_protected([last.clone()].into_iter().collect());
        }
        let mut latencies = LatencyRecorder::new();
        let mut served = 0usize;
        while let Ok(shard) = rx.recv() {
            for req in shard.requests {
                let logits = model.forward(req.image, &mut engine);
                let class = logits.argmax();
                let latency = req.submitted.elapsed();
                latencies.record(latency);
                served += 1;
                let _ = req.reply.send(Reply {
                    class,
                    logits: logits.data,
                    latency,
                    batch_size: shard.batch_size,
                });
            }
        }
        let rep = engine.energy_report();
        WorkerStats { latencies, served, energy_mj: rep.energy_mj, busy_ms: rep.time_ms }
    })
}

/// Handle to a running inference server.
pub struct InferenceServer {
    tx: Sender<Request>,
    dispatcher: Option<JoinHandle<ServerReport>>,
}

impl InferenceServer {
    /// Spawn the dispatcher + engine worker threads.
    pub fn spawn(
        model: Model,
        cfg: AcceleratorConfig,
        opts: EngineOptions,
        masks: std::collections::BTreeMap<String, crate::sparsity::LayerMask>,
        server_cfg: ServerConfig,
    ) -> Self {
        let (tx, rx): (Sender<Request>, Receiver<Request>) = mpsc::channel();
        let dispatcher = std::thread::spawn(move || {
            let n_workers = server_cfg.workers.max(1);
            let mut worker_txs = Vec::with_capacity(n_workers);
            let mut handles = Vec::with_capacity(n_workers);
            for _ in 0..n_workers {
                let (wtx, wrx) = mpsc::channel::<Shard>();
                handles.push(spawn_engine_worker(
                    model.clone(),
                    cfg.clone(),
                    opts,
                    masks.clone(),
                    server_cfg.engine_threads.max(1),
                    wrx,
                ));
                worker_txs.push(wtx);
            }

            let mut batches = 0usize;
            let started = Instant::now();
            loop {
                // block for the first request (or shutdown)
                let first = match rx.recv() {
                    Ok(r) => r,
                    Err(_) => break,
                };
                // dynamic batching: drain until max_batch or timeout
                let mut batch = vec![first];
                let deadline = Instant::now() + server_cfg.batch_timeout;
                while batch.len() < server_cfg.max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => batch.push(r),
                        Err(_) => break,
                    }
                }
                let batch_size = batch.len();
                batches += 1;
                // shard the batch across engine workers (contiguous
                // near-equal splits; lone requests go to worker 0)
                let ranges = partition_ranges(batch.len(), n_workers);
                for (widx, range) in ranges.into_iter().enumerate().rev() {
                    let requests: Vec<Request> = batch.drain(range).collect();
                    if worker_txs[widx].send(Shard { requests, batch_size }).is_err() {
                        // fail fast, like the pre-sharding single-worker
                        // design: a dead worker must surface at submit(),
                        // not silently drop requests until shutdown
                        panic!("engine worker {widx} died (shard queue disconnected)");
                    }
                }
            }
            // shutdown: close worker queues, join, merge ledgers
            drop(worker_txs);
            let mut latencies = LatencyRecorder::new();
            let mut served = 0usize;
            let mut energy_mj = 0.0;
            let mut busy_ms = 0.0;
            for h in handles {
                let stats = h.join().expect("engine worker panicked");
                latencies.merge(&stats.latencies);
                served += stats.served;
                energy_mj += stats.energy_mj;
                busy_ms += stats.busy_ms;
            }
            let elapsed = started.elapsed().as_secs_f64().max(1e-9);
            ServerReport {
                requests: served,
                batches,
                workers: n_workers,
                mean_latency_us: latencies.mean_us(),
                p50_us: latencies.percentile_us(50.0),
                p99_us: latencies.percentile_us(99.0),
                throughput_rps: served as f64 / elapsed,
                energy_mj,
                // average power per occupied accelerator slot-time,
                // consistent with the single-worker definition
                p_avg_w: if busy_ms > 0.0 { energy_mj / busy_ms } else { 0.0 },
            }
        });
        Self { tx, dispatcher: Some(dispatcher) }
    }

    /// Submit an image; returns a receiver for the reply.
    pub fn submit(&self, image: Tensor) -> Receiver<Reply> {
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = Request { image, submitted: Instant::now(), reply: reply_tx };
        self.tx.send(req).expect("server dispatcher alive");
        reply_rx
    }

    /// Shut down and collect the report.
    pub fn shutdown(mut self) -> ServerReport {
        drop(self.tx);
        self.dispatcher.take().unwrap().join().expect("dispatcher panicked")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SparsitySupport;

    fn test_cfg() -> AcceleratorConfig {
        AcceleratorConfig {
            features: SparsitySupport::NONE,
            dac: crate::config::DacKind::Edac,
            l_g: 5.0,
            ..Default::default()
        }
    }

    #[test]
    fn serves_batches_and_reports() {
        let model = crate::nn::models::cnn3();
        let server = InferenceServer::spawn(
            model,
            test_cfg(),
            EngineOptions::IDEAL,
            Default::default(),
            ServerConfig {
                max_batch: 4,
                batch_timeout: Duration::from_millis(1),
                ..Default::default()
            },
        );
        let ds = crate::data::SyntheticDataset::new(crate::data::DatasetSpec::fmnist_like());
        let mut rxs = Vec::new();
        for i in 0..6 {
            let (img, _) = ds.sample(0, i);
            rxs.push(server.submit(img));
        }
        for rx in rxs {
            let reply = rx.recv_timeout(Duration::from_secs(120)).expect("reply");
            assert_eq!(reply.logits.len(), 10);
            assert!(reply.class < 10);
            assert!(reply.batch_size >= 1);
        }
        let report = server.shutdown();
        assert_eq!(report.requests, 6);
        assert!(report.batches >= 1 && report.batches <= 6);
        assert!(report.energy_mj > 0.0);
        assert!(report.p99_us >= report.p50_us);
    }

    #[test]
    fn multi_worker_sharding_serves_everything() {
        let model = crate::nn::models::cnn3();
        let server = InferenceServer::spawn(
            model,
            test_cfg(),
            EngineOptions::IDEAL,
            Default::default(),
            ServerConfig {
                max_batch: 8,
                batch_timeout: Duration::from_millis(2),
                workers: 3,
                engine_threads: 1,
            },
        );
        let ds = crate::data::SyntheticDataset::new(crate::data::DatasetSpec::fmnist_like());
        let mut rxs = Vec::new();
        for i in 0..9 {
            let (img, _) = ds.sample(7, i);
            rxs.push(server.submit(img));
        }
        // every request answered exactly once, with sane logits
        for rx in rxs {
            let reply = rx.recv_timeout(Duration::from_secs(120)).expect("reply");
            assert_eq!(reply.logits.len(), 10);
        }
        let report = server.shutdown();
        assert_eq!(report.requests, 9);
        assert_eq!(report.workers, 3);
        assert!(report.energy_mj > 0.0, "all workers account energy");
    }
}

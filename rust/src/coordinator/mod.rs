//! Layer-3 coordinator: maps im2col'd weight chunks onto the multi-core
//! accelerator, programs gating + rerouters per chunk, streams activations,
//! accounts energy, and serves batched inference requests.
//!
//! * [`scheduler`] — chunk partitioning and tile/core slot assignment;
//! * [`engine`] — [`PhotonicEngine`]: the `nn::MatmulEngine` backend that
//!   executes every model matmul on the photonic digital twin with
//!   quantization, masks, noise and per-chunk energy accounting;
//! * [`server`] — a threaded batched-inference service (the offline build
//!   has no tokio; std::thread + mpsc provide the same dynamic-batching
//!   architecture) with bounded queues, per-request deadlines, and
//!   graceful drain;
//! * [`admission`] — the in-flight cap + load-shedding policy in front
//!   of the service;
//! * [`faults`] — seedable deterministic fault injection
//!   ([`FaultPlan`]) driving the supervisor's recovery paths in tests,
//!   `--faults` runs, and `bench chaos`;
//! * [`net`] — the std-only HTTP/1.1 front-end (`POST /v1/predict`,
//!   `GET /healthz`, `GET /metrics`), a readiness-driven reactor over
//!   [`poller`] that puts the service on a socket;
//! * [`poller`] — dependency-free readiness polling (epoll on Linux)
//!   behind a portable `Poller` abstraction;
//! * [`metrics`] — latency/throughput/energy reporting, live and at
//!   shutdown.

pub mod admission;
pub mod engine;
pub mod faults;
pub mod metrics;
pub mod net;
pub mod poller;
pub mod scheduler;
pub mod server;

pub use admission::{AdmissionConfig, AdmissionController};
pub use engine::{EngineOptions, PhotonicEngine, ThermalStatus};
pub use faults::{FaultAction, FaultPlan};
pub use metrics::{LatencyRecorder, MetricsSnapshot, ServerMetrics, ThermalGauges};
pub use net::{HttpServer, NetConfig};
pub use scheduler::{ChunkAssignment, ClusterConfig, LayerSchedule, ReplicaState, Scheduler};
pub use server::{
    DstServerConfig, InferenceServer, RepairServerConfig, Reply, ReplyResult, ServeError,
    ServerConfig, ServerConfigBuilder, ServerReport, SupervisorConfig, ThermalServerConfig,
};

//! Layer-3 coordinator: maps im2col'd weight chunks onto the multi-core
//! accelerator, programs gating + rerouters per chunk, streams activations,
//! accounts energy, and serves batched inference requests.
//!
//! * [`scheduler`] — chunk partitioning and tile/core slot assignment;
//! * [`engine`] — [`PhotonicEngine`]: the `nn::MatmulEngine` backend that
//!   executes every model matmul on the photonic digital twin with
//!   quantization, masks, noise and per-chunk energy accounting;
//! * [`server`] — a threaded batched-inference service (the offline build
//!   has no tokio; std::thread + mpsc provide the same dynamic-batching
//!   architecture);
//! * [`metrics`] — latency/throughput/energy reporting.

pub mod engine;
pub mod metrics;
pub mod scheduler;
pub mod server;

pub use engine::{EngineOptions, PhotonicEngine};
pub use metrics::LatencyRecorder;
pub use scheduler::{ChunkAssignment, LayerSchedule, Scheduler};
pub use server::{InferenceServer, ServerConfig, ServerReport};

//! [`PhotonicEngine`] — the photonic digital-twin matmul backend.
//!
//! For each model matmul it: pads the weight matrix to the chunk grid,
//! applies the layer's structured mask, quantizes (b_w symmetric weights,
//! b_in unsigned activations), *programs* each chunk's PTCs once
//! (crosstalk-perturbed realized weights, gating, rerouter trees), then
//! streams activation columns through the programmed arrays while
//! accounting per-chunk power × cycles into the energy ledger (Eq. §4.1).
//!
//! Execution is **sparsity-compiled and parallel**: programming also
//! compiles each chunk into an [`exec::ChunkPlan`](crate::exec::ChunkPlan)
//! (active-index gather tables + gain-folded weight panels), and
//! streaming partitions (chunk-row × column-block) work items across a
//! scoped worker pool. Per-cycle PD noise comes from counter-based
//! per-(chunk, column) RNG streams, so outputs are bit-identical for any
//! [`PhotonicEngine::set_threads`] value (EXPERIMENTS.md §Perf). The
//! pre-compilation scalar path survives as
//! [`PhotonicEngine::matmul_reference`] — the equivalence oracle and
//! bench baseline.

use crate::config::AcceleratorConfig;
use crate::coordinator::scheduler::Scheduler;
use crate::devices::{DeviceLibrary, Mzi, MziSpec};
use crate::exec::{
    detected_simd, parallel_for_with, parallel_map, ChunkPlan, DisjointWriter,
    KernelPrecision, PanelCache, SimdLevel, StageBreakdown, StageTimes, WorkerArena,
};
use crate::nn::MatmulEngine;
use crate::power::{EnergyAccumulator, EnergyReport, PowerModel};
use crate::ptc::crossbar::{ColumnMode, ForwardOptions, ProgrammedPtc, PtcSimulator};
use crate::ptc::faults::{BlockFault, DeviceFaultPlan};
use crate::quant::{SymmetricQuant, UnsignedQuant};
use crate::sparsity::{mask_power_mw, ChunkMask, LayerMask};
use crate::thermal::drift::layer_stream_id;
use crate::thermal::{DriftConfig, DriftModel, GammaModel, ThermalPolicy};
use crate::util::XorShiftRng;
use std::collections::BTreeMap;

/// Noise/feature switches for a deployment run.
#[derive(Debug, Clone, Copy)]
pub struct EngineOptions {
    /// Inject thermal crosstalk ("w/ TV" columns of Table 3).
    pub thermal: bool,
    /// Inject PD photocurrent noise.
    pub pd_noise: bool,
    /// Inject static phase-programming noise.
    pub phase_noise: bool,
    /// Quantize weights/activations (b_w / b_in from the config).
    pub quantize: bool,
}

impl EngineOptions {
    /// Everything off: the twin reduces to an exact (quantized) matmul.
    pub const IDEAL: Self =
        Self { thermal: false, pd_noise: false, phase_noise: false, quantize: true };
    /// Full non-ideality stack ("w/ TV").
    pub const NOISY: Self =
        Self { thermal: true, pd_noise: true, phase_noise: true, quantize: true };
}

struct ProgrammedChunk {
    /// r·c programmed PTC blocks, row-major over the (r, c) grid.
    blocks: Vec<ProgrammedPtc>,
    /// Per-slot hold power of this chunk (mW).
    power: crate::power::PowerBreakdown,
    row_mask: Vec<bool>,
    /// Per-row PD-noise std for the whole chunk: σ·√(c·k2)·lr_gain —
    /// drawn once per (row, column) instead of once per block row, which
    /// is statistically identical (sum of independent gaussians) and 4×
    /// cheaper at r = c = 4 (EXPERIMENTS.md §Perf).
    noise_std: f64,
    /// Sparsity-compiled execution plan over the programmed blocks.
    plan: ChunkPlan,
    /// Layer-dim clips the plan was compiled with (needed to recompile
    /// after a drift re-realization without re-deriving the schedule).
    row_limit: usize,
    col_limit: usize,
    /// Program-time sentinel digest of the *fault-free* realization,
    /// captured before device faults pin in — the reference
    /// [`PhotonicEngine::sentinel_probe_all`] compares against.
    golden: SentinelGolden,
    /// Runtime thermal-drift state; `None` when the drift runtime is off.
    drift: Option<ChunkDrift>,
}

/// Per-chunk runtime drift state (tentpole of the thermal-drift runtime:
/// the recalibration unit is the chunk, so only chunks past their budget
/// re-realize and recompile).
struct ChunkDrift {
    /// Per-node susceptibility fingerprints, one per PTC block
    /// (node layout j·k1+i, matching `ProgrammedPtc::realize_drifted`).
    patterns: Vec<Vec<f64>>,
    /// RMS of the fingerprints — scales |env| into a phase-error
    /// estimate without touching per-node data.
    pattern_rms: f64,
    /// Drift envelope currently baked into `w_real`/`plan`.
    applied_env: f64,
    /// Drift envelope compensated away at the last recalibration (the
    /// calibration reference; residual error ∝ |env − comp_env|).
    comp_env: f64,
}

impl ProgrammedChunk {
    /// Re-realize every block at the drift offset `env − comp_env` and
    /// recompile the execution plan. With `env == comp_env` this
    /// reproduces the programming-time plan bit for bit.
    ///
    /// `self.power` is deliberately NOT recomputed: the hold-power
    /// ledger keeps programming-time phases (a drift bounded by the
    /// recalibration budget moves it second-order; EXPERIMENTS.md
    /// §Thermal-drift, known limits).
    fn rebake(&mut self, env: f64, r: usize, c: usize) {
        let Some(d) = &mut self.drift else { return };
        let scale = env - d.comp_env;
        for (b, blk) in self.blocks.iter_mut().enumerate() {
            blk.realize_drifted(scale, &d.patterns[b]);
        }
        d.applied_env = env;
        let mask_gen = self.plan.mask_gen;
        self.plan = ChunkPlan::from_blocks(
            &self.blocks,
            r,
            c,
            self.row_limit,
            self.col_limit,
            self.noise_std,
        );
        // thermal recalibration never changes which artifact the chunk
        // is executing — keep the hot-swap attribution
        self.plan.mask_gen = mask_gen;
    }
}

/// Program-time sentinel reference for one chunk: the fixed-seed probe
/// response plus the gain-folded weight surface of the *fault-free*
/// realization. Captured in `program_chunk` before device faults pin
/// in, so a faulted chunk deviates from its own golden immediately.
#[derive(Default)]
struct SentinelGolden {
    /// `plan.sentinel_response(probe)` of the clean plan.
    response: Vec<f64>,
    /// Clean `plan.w` (same gather tables as the live plan — faults
    /// never touch port gains), used to localize a flagged chunk to
    /// specific rows/columns.
    w: Vec<f64>,
}

/// One sentinel detection: a chunk whose live execution plan deviates
/// from its program-time golden digest, localized to the chunk-local
/// row/column coordinates to quarantine.
#[derive(Debug, Clone, PartialEq)]
pub struct SentinelFinding {
    pub layer: String,
    /// Chunk index `pi·q + qi` within the layer's grid.
    pub chunk: usize,
    /// Chunk-local rows to quarantine (dead-PD signature: a whole row
    /// deviates).
    pub rows: Vec<usize>,
    /// Chunk-local columns to quarantine (stuck-MZI / dead-branch
    /// signature).
    pub cols: Vec<usize>,
    /// Largest per-weight deviation observed (diagnostic).
    pub worst_dev: f64,
}

/// One distinct activation gather table within a chunk-column `qi`.
/// Pass 1 of the two-pass matmul materializes one quantized panel per
/// (group, column block); every chunk-row whose plan shares the table
/// reads it read-only in pass 2, which is what removes the O(p×)
/// re-gather/re-quantize redundancy of the single-pass path.
struct PanelGroup {
    /// Chunk-column this table gathers from.
    qi: usize,
    /// The shared gather table — bit-equal to `plan.cols` of every
    /// member chunk. Valid across thermal rebakes: `realize_drifted`
    /// perturbs `w_real` only, never the port gains `cols` derives from.
    cols: Vec<u32>,
}

struct ProgrammedLayer {
    out_dim: usize,
    in_dim: usize,
    p: usize,
    q: usize,
    chunks: Vec<ProgrammedChunk>,
    /// Distinct activation gather tables across the layer's chunks
    /// (deduped per chunk-column at `program_layer` time). For uniform
    /// column masks this has exactly `q` entries — one shared panel per
    /// chunk-column regardless of `p`; fully heterogeneous masks
    /// degenerate to one group per chunk (no redundancy to remove, and
    /// none paid).
    panel_groups: Vec<PanelGroup>,
    /// Chunk index (`pi·q + qi`) → index into `panel_groups`.
    group_of: Vec<usize>,
    w_scale: f64,
    n_waves: usize,
    /// 2 for protected layers (non-adjacent mapping halves occupancy).
    cycle_factor: u64,
}

/// Open batched-forward context ([`MatmulEngine::begin_batch`]): the
/// noise-epoch geometry that makes ONE batched pass per layer draw the
/// exact PD-noise bits the equivalent sequential per-item forwards
/// would. Sequential item `g`'s `l`-th matmul call runs at epoch
/// `base + g·stride + l` (every plain call advances the epoch by one,
/// and each item makes `stride` calls); a batched call at call-index `l`
/// therefore addresses item `g`'s columns with exactly that epoch.
struct BatchCtx {
    batch: u64,
    /// Matmul calls per item (the model's matmul-layer count).
    stride: u64,
    /// `noise_epoch` when the context opened.
    base: u64,
    /// Batched matmul calls issued so far in this context.
    calls: u64,
}

/// Column → PD-noise-stream addressing for one matmul call. Columns are
/// item-major (`cols_per_item` per item); item `g`'s column `t` draws
/// from stream `(epoch0 + g·epoch_stride, chunk, t)` — for an unbatched
/// call (`cols_per_item = n_cols`, one item) this degenerates to the
/// original `(epoch, chunk, col)` addressing bit for bit.
#[derive(Clone, Copy)]
struct NoiseGrid {
    epoch0: u64,
    epoch_stride: u64,
    cols_per_item: usize,
}

impl NoiseGrid {
    /// (epoch, item-local column) of packed column `col`.
    #[inline]
    fn stream(&self, col: usize) -> (u64, u64) {
        let g = (col / self.cols_per_item) as u64;
        (
            self.epoch0.wrapping_add(g.wrapping_mul(self.epoch_stride)),
            (col % self.cols_per_item) as u64,
        )
    }
}

/// Engine-level thermal-drift runtime state.
struct ThermalState {
    model: DriftModel,
    policy: ThermalPolicy,
    /// Drift envelope at the last tick.
    env: f64,
    /// Served count at the last periodic recalibration.
    last_recal_served: u64,
    /// Cumulative recalibration actions (ticks that recalibrated ≥ 1 chunk).
    recal_events: u64,
    /// Cumulative chunks re-realized + recompiled by recalibration.
    recal_chunks: u64,
    /// Cumulative physics updates (drift baked into plans outside
    /// recalibration).
    drift_applies: u64,
}

/// Gauges returned by [`PhotonicEngine::thermal_tick`] and read by the
/// serving metrics (`/metrics`) and `scatter bench drift`.
#[derive(Debug, Clone, Copy, Default)]
pub struct ThermalStatus {
    /// Current drift envelope (rad).
    pub env_rad: f64,
    /// Worst residual phase-error estimate across chunks *after* this
    /// tick's actions (recalibrated chunks contribute zero).
    pub phase_error_rad: f64,
    /// Cumulative recalibration actions.
    pub recal_events: u64,
    /// Cumulative chunks recompiled by recalibration — compare against
    /// `recal_events × chunks_total`, the cost of naive full re-programs.
    pub recal_chunks: u64,
    /// Programmed chunks currently under drift management.
    pub chunks_total: u64,
    /// Cumulative drift physics updates.
    pub drift_applies: u64,
}

/// The engine. One instance per deployment run; keeps programmed layers
/// cached so repeated inferences (batches) only pay programming once.
pub struct PhotonicEngine {
    pub cfg: AcceleratorConfig,
    pub opts: EngineOptions,
    sim: PtcSimulator,
    power: PowerModel,
    scheduler: Scheduler,
    rerouter_mzi: Mzi,
    masks: BTreeMap<String, LayerMask>,
    /// Layers deployed with the paper's §4.1 protection: weights mapped to
    /// non-adjacent MZI columns, eliminating inter-MZI crosstalk at the
    /// cost of 2x cycles (half physical occupancy).
    protected: std::collections::BTreeSet<String>,
    programmed: BTreeMap<String, ProgrammedLayer>,
    /// Runtime thermal-drift model + recalibration policy (`None` =
    /// seed behavior: Eqs. 8–9 applied once at programming time only).
    thermal: Option<ThermalState>,
    /// Generation id of the installed mask set (0 = the deployment
    /// baseline from [`Self::set_masks`]; hot-swap artifacts carry
    /// monotone ids via [`Self::apply_mask_update`]).
    mask_generation: u64,
    /// Chunk indices per programmed layer whose masks changed in the
    /// last [`Self::apply_mask_update`] and are awaiting incremental
    /// reprogramming — flushed lazily at the layer's next matmul call,
    /// where the weight matrix is in hand.
    pending_reprogram: BTreeMap<String, Vec<usize>>,
    /// Hardware-defect plan lowered onto every chunk at programming time
    /// (and re-lowered on every reprogram — broken devices stay broken).
    device_faults: DeviceFaultPlan,
    /// Promoted quarantines: layer → (chunk, rows, cols) cells that must
    /// stay masked off in every future mask generation. Intersected into
    /// incoming [`Self::apply_mask_update`] sets so a DST step can never
    /// resurrect a column that was quarantined around a dead device.
    quarantined: BTreeMap<String, Vec<(usize, Vec<usize>, Vec<usize>)>>,
    energy: EnergyAccumulator,
    rng: crate::util::XorShiftRng,
    /// Worker threads for the compiled execution path (1 = inline).
    threads: usize,
    /// Monotone per-matmul-call counter; part of every noise-stream id so
    /// repeated calls draw independent noise while staying reproducible.
    noise_epoch: u64,
    /// Open batched-forward context (`None` outside
    /// [`MatmulEngine::begin_batch`] / [`MatmulEngine::end_batch`]).
    batch_ctx: Option<BatchCtx>,
    /// Shared activation-panel slab, reused (grow-only) across matmul
    /// calls — the steady state allocates nothing but the output.
    panels: PanelCache,
    /// Per-column (normalization divisor, output scale) scratch, reused
    /// (capacity grow-only) across matmul calls like `panels`.
    col_norm: (Vec<f64>, Vec<f64>),
    /// Per-stage wall-time accumulators (gather/kernel/scatter) behind
    /// [`Self::set_stage_timing`]; zero overhead while disabled.
    stage_times: StageTimes,
    stage_timing: bool,
    /// Kernel numeric mode for [`MatmulEngine::matmul_batch`]: `Exact`
    /// (default) keeps the bit-identity contract; `Quantized` runs the
    /// integer SIMD kernel. The reference/uncached oracle paths are
    /// always exact regardless.
    precision: KernelPrecision,
    /// SIMD variant the quantized kernel dispatches to. Resolved from
    /// runtime detection (+ `SCATTER_FORCE_SCALAR`) at construction;
    /// [`Self::set_simd_override`] can lower it within a process (the
    /// bench's simd-vs-scalar cell).
    simd: SimdLevel,
}

impl PhotonicEngine {
    pub fn new(cfg: AcceleratorConfig, opts: EngineOptions) -> Self {
        let gamma = GammaModel::paper();
        let lib = DeviceLibrary::default();
        let sim = PtcSimulator::from_config(&cfg);
        let power = PowerModel::new(cfg.clone(), lib, &gamma);
        let scheduler = Scheduler::new(cfg.clone());
        let rerouter_mzi = Mzi::new(MziSpec::low_power(), cfg.l_s, &gamma);
        let rng = crate::util::XorShiftRng::new(cfg.noise_seed);
        Self {
            cfg,
            opts,
            sim,
            power,
            scheduler,
            rerouter_mzi,
            masks: BTreeMap::new(),
            protected: Default::default(),
            programmed: BTreeMap::new(),
            thermal: None,
            mask_generation: 0,
            pending_reprogram: BTreeMap::new(),
            device_faults: DeviceFaultPlan::none(),
            quarantined: BTreeMap::new(),
            energy: EnergyAccumulator::new(),
            rng,
            threads: 1,
            noise_epoch: 0,
            batch_ctx: None,
            panels: PanelCache::new(),
            col_norm: (Vec::new(), Vec::new()),
            stage_times: StageTimes::new(),
            stage_timing: false,
            precision: KernelPrecision::default(),
            simd: detected_simd(),
        }
    }

    /// Toggle per-stage (gather/kernel/scatter) wall-time accounting for
    /// `scatter bench engine --stages`. Off by default: the hot loops
    /// skip every clock read.
    pub fn set_stage_timing(&mut self, on: bool) {
        self.stage_timing = on;
        let _ = self.stage_times.take(); // start from clean counters
    }

    /// Drain the per-stage timers accumulated since the last call (or
    /// since [`Self::set_stage_timing`] enabled them).
    pub fn take_stage_breakdown(&mut self) -> StageBreakdown {
        self.stage_times.take()
    }

    /// Set the worker-thread count for the compiled execution path.
    /// Outputs are bit-identical for every value (noise streams are
    /// counter-based per (chunk, column), not per thread).
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Select the kernel numeric mode for the compiled batch path.
    /// `Exact` (default) preserves bit-identity with the reference and
    /// uncached oracles; `Quantized` runs the integer SIMD kernel
    /// (same determinism across thread counts and SIMD levels, its own
    /// integer rounding).
    pub fn set_precision(&mut self, precision: KernelPrecision) {
        self.precision = precision;
    }

    pub fn precision(&self) -> KernelPrecision {
        self.precision
    }

    /// Override the SIMD variant the quantized kernel dispatches to,
    /// clamped to what the CPU supports (`None` restores detection).
    /// The `SCATTER_FORCE_SCALAR` env var is read once per process, so
    /// in-process comparisons — the bench's `simd_vs_scalar` cell, the
    /// forced-scalar property tests — go through here instead.
    pub fn set_simd_override(&mut self, level: Option<SimdLevel>) {
        let detected = detected_simd();
        self.simd = level.map_or(detected, |l| l.min(detected));
    }

    /// The SIMD variant currently dispatched under `Quantized`.
    pub fn simd_level(&self) -> SimdLevel {
        self.simd
    }

    /// Install per-layer sparsity masks (from `nn::loader` or
    /// `sparsity::init`). Clears the programming cache and resets the
    /// mask generation to 0 — this is the full-deployment path; use
    /// [`Self::apply_mask_update`] for versioned incremental swaps.
    pub fn set_masks(&mut self, masks: BTreeMap<String, LayerMask>) {
        self.masks = masks;
        self.programmed.clear();
        self.pending_reprogram.clear();
        self.mask_generation = 0;
    }

    pub fn masks(&self) -> &BTreeMap<String, LayerMask> {
        &self.masks
    }

    /// Generation id of the installed mask set (see
    /// [`Self::apply_mask_update`]).
    pub fn mask_generation(&self) -> u64 {
        self.mask_generation
    }

    /// Install a new mask generation **incrementally**: diff the new
    /// masks against the installed ones per chunk and schedule only the
    /// chunks whose row/column pattern actually changed for
    /// reprogramming — unchanged chunks keep their programmed blocks,
    /// compiled plans, and thermal-drift calibration state untouched.
    /// This is the hot-swap analogue of the per-chunk thermal
    /// recalibration path: cost scales with the DST step's churn, not
    /// the model size.
    ///
    /// Reprogramming happens lazily at each affected layer's next
    /// matmul call (where the weight matrix is available); the shared
    /// activation-panel groups are rebuilt for affected layers only.
    /// Layers whose chunk grid no longer matches (shape change) fall
    /// back to a full re-program. Returns the number of chunks
    /// scheduled for reprogramming across all programmed layers.
    pub fn apply_mask_update(
        &mut self,
        mut masks: BTreeMap<String, LayerMask>,
        generation: u64,
    ) -> usize {
        // promoted quarantines outlive any one generation: a DST step
        // re-activating a column that sits over a dead device would
        // re-expose the fault, so intersect them into every update
        self.intersect_quarantine(&mut masks);
        let (rows, cols) = self.cfg.chunk_shape();
        let dense = ChunkMask::dense(rows, cols);
        let mut dirty_total = 0usize;
        let mut drop_layers: Vec<String> = Vec::new();
        for (layer, pl) in &self.programmed {
            let old = self.masks.get(layer);
            let new = masks.get(layer);
            let grid_ok = |lm: Option<&LayerMask>| {
                lm.is_none_or(|m| m.p == pl.p && m.q == pl.q)
            };
            if !grid_ok(old) || !grid_ok(new) {
                // chunk grid changed shape: no per-chunk diff is
                // meaningful — full re-program on next use
                drop_layers.push(layer.clone());
                dirty_total += pl.chunks.len();
                continue;
            }
            let mut dirty: Vec<usize> = Vec::new();
            for pi in 0..pl.p {
                for qi in 0..pl.q {
                    let oc = old.map(|m| m.chunk(pi, qi)).unwrap_or(&dense);
                    let nc = new.map(|m| m.chunk(pi, qi)).unwrap_or(&dense);
                    if oc != nc {
                        dirty.push(pi * pl.q + qi);
                    }
                }
            }
            if !dirty.is_empty() {
                dirty_total += dirty.len();
                self.pending_reprogram.insert(layer.clone(), dirty);
            }
        }
        for layer in drop_layers {
            self.programmed.remove(&layer);
            self.pending_reprogram.remove(&layer);
        }
        self.masks = masks;
        self.mask_generation = generation;
        dirty_total
    }

    /// Intersect every promoted quarantine into `masks` (cells out of
    /// range for a layer's current grid are skipped — a reshaped layer
    /// gets a fresh fault lifecycle).
    fn intersect_quarantine(&self, masks: &mut BTreeMap<String, LayerMask>) {
        for (layer, entries) in &self.quarantined {
            let Some(lm) = masks.get_mut(layer) else { continue };
            if lm.q == 0 {
                continue;
            }
            for (chunk, rows, cols) in entries {
                let (pi, qi) = (chunk / lm.q, chunk % lm.q);
                if pi >= lm.p {
                    continue;
                }
                let cm = lm.chunk_mut(pi, qi);
                for &r in rows {
                    if r < cm.row.len() {
                        cm.row[r] = false;
                    }
                }
                for &c in cols {
                    if c < cm.col.len() {
                        cm.col[c] = false;
                    }
                }
            }
        }
    }

    /// Install a device-fault plan **before** programming (the
    /// `scatter serve --device-faults` startup path). Clears the
    /// programming cache so every chunk re-programs with its faults
    /// pinned — and with a clean golden digest captured first, so the
    /// sentinel detects the defects at its very first probe.
    pub fn set_device_faults(&mut self, plan: DeviceFaultPlan) {
        self.device_faults = plan;
        self.programmed.clear();
        self.pending_reprogram.clear();
    }

    pub fn device_faults(&self) -> &DeviceFaultPlan {
        &self.device_faults
    }

    /// Break devices **mid-life**: lower `plan` onto every programmed
    /// chunk in place (recompiling only the affected plans, like a
    /// thermal rebake) and merge it into the stored fault plan so later
    /// reprograms re-acquire the damage. Golden digests are deliberately
    /// NOT refreshed — that is the whole point: the sentinel compares
    /// the now-faulted fabric against its pre-fault reference. Returns
    /// the number of programmed chunks hit.
    pub fn inject_device_faults(&mut self, plan: &DeviceFaultPlan) -> usize {
        self.device_faults.extend(plan);
        let (k1, k2) = (self.cfg.k1, self.cfg.k2);
        let (r, c) = (self.cfg.share_r, self.cfg.share_c);
        let mut hit = 0usize;
        for (layer, pl) in &mut self.programmed {
            for (idx, chunk) in pl.chunks.iter_mut().enumerate() {
                let lowered = plan.block_faults(layer, idx, k1, k2, r, c);
                if lowered.is_empty() {
                    continue;
                }
                let mut per_block: Vec<Vec<BlockFault>> =
                    vec![Vec::new(); chunk.blocks.len()];
                for (b, f) in lowered {
                    per_block[b].push(f);
                }
                for (b, fs) in per_block.into_iter().enumerate() {
                    if fs.is_empty() {
                        continue;
                    }
                    let mut all = chunk.blocks[b].faults().to_vec();
                    all.extend(fs);
                    chunk.blocks[b].set_faults(all);
                }
                let mask_gen = chunk.plan.mask_gen;
                chunk.plan = ChunkPlan::from_blocks(
                    &chunk.blocks,
                    r,
                    c,
                    chunk.row_limit,
                    chunk.col_limit,
                    chunk.noise_std,
                );
                chunk.plan.mask_gen = mask_gen;
                hit += 1;
            }
        }
        hit
    }

    /// Sentinel probe: replay the fixed-seed probe vector through every
    /// programmed chunk's live execution plan and compare against the
    /// program-time golden digest, localizing deviations to chunk-local
    /// rows/columns. O(active rows) per healthy chunk (response compare
    /// only); the O(rows·cols) weight-surface diff runs only for flagged
    /// chunks. Runs entirely on the twin's compiled plans — live traffic
    /// is never touched.
    ///
    /// The tolerance absorbs residual thermal drift: recalibration
    /// restores programming-time weights exactly, so golden-vs-live
    /// deviation from drift is bounded by the residual phase error, not
    /// the total excursion.
    pub fn sentinel_probe_all(&self) -> Vec<SentinelFinding> {
        let tol = 1e-9 + 4.0 * self.thermal_phase_error_rad();
        let mut findings = Vec::new();
        for (layer, pl) in &self.programmed {
            for (idx, chunk) in pl.chunks.iter().enumerate() {
                let plan = &chunk.plan;
                let g = &chunk.golden;
                let nc = plan.n_active_cols();
                let nr = plan.rows.len();
                if nr == 0 || g.response.len() != nr || g.w.len() != nr * nc {
                    continue;
                }
                let probe = ChunkPlan::sentinel_probe(nc);
                let resp = plan.sentinel_response(&probe);
                // deviations can add coherently across a row's columns
                let resp_tol = tol * (nc as f64).max(1.0);
                let flagged =
                    resp.iter().zip(&g.response).any(|(a, b)| (a - b).abs() > resp_tol);
                if !flagged {
                    continue;
                }
                // localization: diff the gain-folded weight surfaces
                let mut worst = 0.0f64;
                let mut row_hits = vec![0usize; nr];
                let mut row_nz = vec![0usize; nr];
                let mut col_hits = vec![0usize; nc];
                for ri in 0..nr {
                    for ci in 0..nc {
                        if g.w[ri * nc + ci].abs() > tol {
                            row_nz[ri] += 1;
                        }
                        let dev = (plan.w[ri * nc + ci] - g.w[ri * nc + ci]).abs();
                        if dev > tol {
                            row_hits[ri] += 1;
                            col_hits[ci] += 1;
                            worst = worst.max(dev);
                        }
                    }
                }
                // a row deviating across most of its live cells is a
                // dead output (PD row); isolated deviations implicate
                // their columns (stuck MZI / dead rerouter branch)
                let dead_row: Vec<bool> = (0..nr)
                    .map(|ri| row_hits[ri] >= 2 && 2 * row_hits[ri] > row_nz[ri])
                    .collect();
                let rows_q: Vec<usize> = (0..nr)
                    .filter(|&ri| dead_row[ri])
                    .map(|ri| plan.rows[ri] as usize)
                    .collect();
                let mut cols_q: Vec<usize> = Vec::new();
                for ci in 0..nc {
                    if col_hits[ci] == 0 {
                        continue;
                    }
                    let outside = (0..nr).any(|ri| {
                        !dead_row[ri]
                            && (plan.w[ri * nc + ci] - g.w[ri * nc + ci]).abs() > tol
                    });
                    if outside {
                        cols_q.push(plan.cols[ci] as usize);
                    }
                }
                findings.push(SentinelFinding {
                    layer: layer.clone(),
                    chunk: idx,
                    rows: rows_q,
                    cols: cols_q,
                    worst_dev: worst,
                });
            }
        }
        findings
    }

    /// Build the repair-mask candidate for `findings`: the current mask
    /// set with every localized row/column quarantined (set inactive).
    /// Returns the new masks plus the number of newly-quarantined cells,
    /// or `None` when the fabric is **unrepairable** — a faulted layer
    /// carries no mask (deployed dense: no rerouter tree to steer light
    /// away with), its grid no longer matches, or the findings localize
    /// no cells at all (nothing a mask swap could route around).
    ///
    /// This is a pure computation: nothing is recorded until the swap
    /// survives its canary and the caller promotes it with
    /// [`Self::record_quarantine`] — a rolled-back repair leaves no
    /// trace, exactly like a rolled-back DST step.
    pub fn quarantine_masks(
        &self,
        findings: &[SentinelFinding],
    ) -> Option<(BTreeMap<String, LayerMask>, usize)> {
        let mut masks = self.masks.clone();
        let mut cells = 0usize;
        for f in findings {
            let lm = masks.get_mut(&f.layer)?;
            let pl = self.programmed.get(&f.layer)?;
            if lm.p != pl.p || lm.q != pl.q || pl.q == 0 {
                return None;
            }
            let (pi, qi) = (f.chunk / pl.q, f.chunk % pl.q);
            if pi >= pl.p {
                return None;
            }
            let cm = lm.chunk_mut(pi, qi);
            for &r in &f.rows {
                if r < cm.row.len() && cm.row[r] {
                    cm.row[r] = false;
                    cells += 1;
                }
            }
            for &c in &f.cols {
                if c < cm.col.len() && cm.col[c] {
                    cm.col[c] = false;
                    cells += 1;
                }
            }
        }
        if cells == 0 {
            return None;
        }
        Some((masks, cells))
    }

    /// Promote `findings` into the persistent quarantine record (called
    /// after the repair swap survives its canary): every future
    /// [`Self::apply_mask_update`] — DST steps included — re-intersects
    /// these cells, so the fabric never routes light back over a dead
    /// device.
    pub fn record_quarantine(&mut self, findings: &[SentinelFinding]) {
        for f in findings {
            self.quarantined.entry(f.layer.clone()).or_default().push((
                f.chunk,
                f.rows.clone(),
                f.cols.clone(),
            ));
        }
    }

    /// Total (row + column) cells in the promoted quarantine record.
    pub fn quarantined_cell_count(&self) -> usize {
        self.quarantined
            .values()
            .flat_map(|v| v.iter())
            .map(|(_, rows, cols)| rows.len() + cols.len())
            .sum()
    }

    /// Mark layers for non-adjacent-column deployment (§4.1: "we protect
    /// the last linear layer by mapping the weights to non-adjacent
    /// columns of MZIs to eliminate crosstalk"). Clears the cache.
    pub fn set_protected(&mut self, layers: std::collections::BTreeSet<String>) {
        self.protected = layers;
        self.programmed.clear();
        self.pending_reprogram.clear();
    }

    /// Enable the thermal-drift runtime: programmed phases drift with
    /// virtual time / served traffic per `drift`, and `policy` decides
    /// when chunks recalibrate. Clears the programming cache (drift
    /// fingerprints are attached at `program_layer` time).
    pub fn set_thermal(&mut self, drift: DriftConfig, policy: ThermalPolicy) {
        self.thermal = Some(ThermalState {
            model: DriftModel::new(drift),
            policy,
            env: 0.0,
            last_recal_served: 0,
            recal_events: 0,
            recal_chunks: 0,
            drift_applies: 0,
        });
        self.programmed.clear();
        self.pending_reprogram.clear();
    }

    /// Advance the drift runtime to virtual time `t_s` / served count
    /// `served`: re-realize drifted chunks (physics) and recalibrate the
    /// ones the policy selects (control). Returns the post-tick gauges,
    /// or `None` when the runtime is disabled.
    ///
    /// Recalibration is **incremental**: a selected chunk re-realizes
    /// its `ProgrammedPtc` blocks from their stored programmed phases
    /// and recompiles only its own `ChunkPlan` — masks, quantization,
    /// rerouter trees and gain tables from `program_layer` are reused
    /// untouched, so the cost is per-chunk, not per-layer.
    pub fn thermal_tick(&mut self, t_s: f64, served: u64) -> Option<ThermalStatus> {
        let (r, c) = (self.cfg.share_r, self.cfg.share_c);
        let (env, policy, apply_eps, due_periodic) = {
            let st = self.thermal.as_mut()?;
            let env = st.model.env(t_s, served);
            st.env = env;
            let due = match st.policy {
                ThermalPolicy::Periodic { every_requests } => {
                    served.saturating_sub(st.last_recal_served) >= every_requests.max(1)
                }
                _ => false,
            };
            (env, st.policy, st.model.config().apply_eps_rad, due)
        };

        let mut recal_now = 0u64;
        let mut applies_now = 0u64;
        let mut max_err = 0.0f64;
        let mut chunks_total = 0u64;
        for pl in self.programmed.values_mut() {
            for chunk in &mut pl.chunks {
                chunks_total += 1;
                let Some((comp, applied, rms)) = chunk
                    .drift
                    .as_ref()
                    .map(|d| (d.comp_env, d.applied_env, d.pattern_rms))
                else {
                    continue;
                };
                let err = (env - comp).abs() * rms;
                let moved = comp != env || applied != env;
                let recal = moved
                    && match policy {
                        ThermalPolicy::Off => false,
                        ThermalPolicy::Threshold { budget_rad } => err > budget_rad,
                        ThermalPolicy::Periodic { .. } => due_periodic,
                    };
                if recal {
                    if let Some(d) = &mut chunk.drift {
                        d.comp_env = env;
                    }
                    chunk.rebake(env, r, c);
                    recal_now += 1;
                } else {
                    if (env - applied).abs() > apply_eps {
                        chunk.rebake(env, r, c);
                        applies_now += 1;
                    }
                    max_err = max_err.max(err);
                }
            }
        }

        let st = self.thermal.as_mut().expect("checked above");
        if due_periodic {
            st.last_recal_served = served;
        }
        if recal_now > 0 {
            st.recal_events += 1;
            st.recal_chunks += recal_now;
        }
        st.drift_applies += applies_now;
        Some(ThermalStatus {
            env_rad: env,
            phase_error_rad: max_err,
            recal_events: st.recal_events,
            recal_chunks: st.recal_chunks,
            chunks_total,
            drift_applies: st.drift_applies,
        })
    }

    /// Force-recalibrate every drifted chunk regardless of policy (the
    /// operator's "recal now" button; also what `ThermalPolicy::Off`
    /// deployments would call from a maintenance window). Returns the
    /// number of chunks recompiled.
    pub fn recalibrate_thermal(&mut self) -> u64 {
        let (r, c) = (self.cfg.share_r, self.cfg.share_c);
        let Some(env) = self.thermal.as_ref().map(|st| st.env) else { return 0 };
        let mut recal_now = 0u64;
        for pl in self.programmed.values_mut() {
            for chunk in &mut pl.chunks {
                let Some(d) = &mut chunk.drift else { continue };
                if d.comp_env == env && d.applied_env == env {
                    continue; // already calibrated at this envelope
                }
                d.comp_env = env;
                chunk.rebake(env, r, c);
                recal_now += 1;
            }
        }
        let st = self.thermal.as_mut().expect("checked above");
        if recal_now > 0 {
            st.recal_events += 1;
            st.recal_chunks += recal_now;
        }
        recal_now
    }

    /// Worst residual phase-error estimate (rad) across programmed
    /// chunks at the last-tick envelope, without advancing the runtime —
    /// the heartbeat the serving supervisor reads between ticks for its
    /// brownout decision. 0 while the drift runtime is off.
    pub fn thermal_phase_error_rad(&self) -> f64 {
        let Some(env) = self.thermal.as_ref().map(|st| st.env) else { return 0.0 };
        let mut max_err = 0.0f64;
        for pl in self.programmed.values() {
            for chunk in &pl.chunks {
                if let Some(d) = &chunk.drift {
                    max_err = max_err.max((env - d.comp_env).abs() * d.pattern_rms);
                }
            }
        }
        max_err
    }

    /// Drift envelope (rad) as of the last [`Self::thermal_tick`]
    /// (0 while the drift runtime is off).
    pub fn thermal_env_rad(&self) -> f64 {
        self.thermal.as_ref().map(|st| st.env).unwrap_or(0.0)
    }

    /// Energy/power ledger for everything executed so far.
    pub fn energy_report(&self) -> EnergyReport {
        self.energy.report(self.cfg.freq_ghz)
    }

    pub fn reset_energy(&mut self) {
        self.energy = EnergyAccumulator::new();
    }

    /// Average accelerator power over the executed workload, in W. The
    /// ledger records every chunk's power for its cycles while wall time
    /// counts each wave once, so energy/wall-time is already the average
    /// *concurrent* power across occupied slots.
    pub fn p_avg_w(&self) -> f64 {
        self.energy_report().p_avg_w
    }

    fn column_mode(&self) -> ColumnMode {
        let f = self.cfg.features;
        if f.light_redistribution {
            ColumnMode::InputGatingLr
        } else if f.input_gating {
            ColumnMode::InputGating
        } else {
            ColumnMode::PruneOnly
        }
    }

    fn program_layer(&mut self, layer: &str, w: &[f64], out_dim: usize, in_dim: usize) {
        let protected = self.protected.contains(layer);
        let sched = self.scheduler.schedule(out_dim, in_dim);
        let (rows, cols) = (sched.chunk_rows, sched.chunk_cols);

        // per-tensor symmetric quantization + normalization to [-1, 1]
        let quant = SymmetricQuant::calibrate(self.cfg.b_w, w);
        let w_max = w.iter().fold(0.0f64, |m, &x| m.max(x.abs())).max(1e-12);

        let layer_mask = self.masks.get(layer).cloned();
        let mut chunks = Vec::with_capacity(sched.p * sched.q);
        let dense_chunk = ChunkMask::dense(rows, cols);

        for pi in 0..sched.p {
            for qi in 0..sched.q {
                let mask = layer_mask
                    .as_ref()
                    .map(|lm| lm.chunk(pi, qi).clone())
                    .unwrap_or_else(|| dense_chunk.clone());
                chunks.push(self.program_chunk(
                    layer, w, out_dim, in_dim, pi, qi, sched.q, &quant, w_max, mask,
                ));
            }
        }
        let (panel_groups, group_of) =
            Self::build_panel_groups(&chunks, sched.p, sched.q);
        self.programmed.insert(
            layer.to_string(),
            ProgrammedLayer {
                out_dim,
                in_dim,
                p: sched.p,
                q: sched.q,
                chunks,
                panel_groups,
                group_of,
                w_scale: w_max,
                n_waves: sched.n_waves(),
                cycle_factor: if protected { 2 } else { 1 },
            },
        );
        // a full (re)program realizes the current mask set everywhere —
        // any finer-grained pending work for this layer is subsumed
        self.pending_reprogram.remove(layer);
    }

    /// Program one `rows × cols` chunk of `layer` under `mask`: gather +
    /// normalize + quantize + mask the weights, program the r×c PTC
    /// blocks, price the hold power, compile the execution plan, and
    /// attach drift fingerprints. Shared verbatim by the full
    /// [`Self::program_layer`] pass and the incremental hot-swap path
    /// ([`Self::apply_mask_update`] → [`Self::flush_mask_update`]), which
    /// is what makes an incrementally-reprogrammed chunk bit-identical
    /// to a freshly-programmed one.
    #[allow(clippy::too_many_arguments)]
    fn program_chunk(
        &mut self,
        layer: &str,
        w: &[f64],
        out_dim: usize,
        in_dim: usize,
        pi: usize,
        qi: usize,
        q: usize,
        quant: &SymmetricQuant,
        w_max: f64,
        mask: ChunkMask,
    ) -> ProgrammedChunk {
        let protected = self.protected.contains(layer);
        let (rows, cols) = self.cfg.chunk_shape();
        let (k1, k2) = (self.cfg.k1, self.cfg.k2);
        let (r, c) = (self.cfg.share_r, self.cfg.share_c);
        assert_eq!(mask.rows, rows, "layer {layer}: mask rows");
        assert_eq!(mask.cols, cols, "layer {layer}: mask cols");

        // gather + normalize + quantize + mask the chunk
        let mut wc = vec![0.0f64; rows * cols];
        for i in 0..rows {
            let gi = pi * rows + i;
            if gi >= out_dim {
                break;
            }
            for j in 0..cols {
                let gj = qi * cols + j;
                if gj >= in_dim {
                    break;
                }
                let mut v = w[gi * in_dim + gj];
                if self.opts.quantize {
                    v = quant.quantize(v);
                }
                wc[i * cols + j] = v / w_max;
            }
        }
        mask.apply(&mut wc);

        // program the r×c PTC blocks
        let mut blocks = Vec::with_capacity(r * c);
        let mut chunk_phases = vec![0.0f64; rows * cols];
        for a in 0..r {
            let rm = &mask.row[a * k1..(a + 1) * k1];
            for b in 0..c {
                let cm = &mask.col[b * k2..(b + 1) * k2];
                let mut wb = vec![0.0f64; k1 * k2];
                for i in 0..k1 {
                    let src = (a * k1 + i) * cols + b * k2;
                    wb[i * k2..(i + 1) * k2].copy_from_slice(&wc[src..src + k2]);
                }
                let fo = ForwardOptions {
                    thermal: self.opts.thermal && !protected,
                    // noise is hoisted to the chunk level (below)
                    pd_noise: false,
                    phase_noise: self.opts.phase_noise,
                    col_mask: Some(cm),
                    row_mask: Some(rm),
                    col_mode: self.column_mode(),
                    output_gating: self.cfg.features.output_gating,
                };
                let mut prog = self.sim.program(&wb, &fo, &mut self.rng);
                prog.mask_gen = self.mask_generation;
                // lift |phases| into chunk layout for the power model
                for i in 0..k1 {
                    for j in 0..k2 {
                        chunk_phases[(a * k1 + i) * cols + b * k2 + j] =
                            prog.phase_abs[i * k2 + j];
                    }
                }
                blocks.push(prog);
            }
        }

        // per-slot hold power incl. rerouter trees
        let rerouter_mw = mask_power_mw(&mask.col, k2, &self.rerouter_mzi);
        let power = self.power.chunk(&chunk_phases, &mask.col, &mask.row, rerouter_mw);
        // chunk-level PD noise: c·k2 nodes per row, LR-rescaled
        let lr_gain = if self.cfg.features.light_redistribution {
            let active = mask.col.iter().filter(|&&m| m).count();
            active as f64 / mask.col.len() as f64
        } else {
            1.0
        };
        let noise_std = if self.opts.pd_noise {
            self.sim.lib.pd_noise_std * ((c * k2) as f64).sqrt() * lr_gain
        } else {
            0.0
        };
        // compile the sparsity-aware execution plan: active-index
        // gather tables + gain-folded panels over the realized
        // weights, clipped to the layer's true dims
        let row_limit = rows.min(out_dim - pi * rows);
        let col_limit = cols.min(in_dim - qi * cols);
        let mut plan = ChunkPlan::from_blocks(&blocks, r, c, row_limit, col_limit, noise_std);
        plan.mask_gen = self.mask_generation;
        // sentinel golden: digest the *fault-free* realization before
        // any device defect pins in, so a faulted chunk deviates from
        // its own golden at the very first probe
        let probe = ChunkPlan::sentinel_probe(plan.n_active_cols());
        let golden =
            SentinelGolden { response: plan.sentinel_response(&probe), w: plan.w.clone() };
        // pin hardware defects and recompile. Faults mutate realized
        // weights only — never port gains — so the faulted plan keeps
        // the exact gather tables the golden was captured with.
        let lowered = self.device_faults.block_faults(layer, pi * q + qi, k1, k2, r, c);
        if !lowered.is_empty() {
            let mut per_block: Vec<Vec<BlockFault>> = vec![Vec::new(); blocks.len()];
            for (b, f) in lowered {
                per_block[b].push(f);
            }
            for (b, fs) in per_block.into_iter().enumerate() {
                if !fs.is_empty() {
                    blocks[b].set_faults(fs);
                }
            }
            let mask_gen = plan.mask_gen;
            plan = ChunkPlan::from_blocks(&blocks, r, c, row_limit, col_limit, noise_std);
            plan.mask_gen = mask_gen;
        }
        // attach the runtime drift fingerprints (counter-based:
        // reprogramming the same layer re-derives them exactly)
        let drift = self.thermal.as_ref().map(|st| {
            let layer_id = layer_stream_id(layer);
            let chunk_id = (pi * q + qi) as u64;
            let patterns = st.model.chunk_patterns(layer_id, chunk_id, r * c, k1 * k2);
            let n_nodes = (r * c * k1 * k2) as f64;
            let sum_sq: f64 = patterns
                .iter()
                .flat_map(|p| p.iter())
                .map(|v| v * v)
                .sum();
            ChunkDrift {
                patterns,
                pattern_rms: (sum_sq / n_nodes).sqrt(),
                // programming calibrates at the *current*
                // environment, not the t = 0 one
                applied_env: st.env,
                comp_env: st.env,
            }
        });
        ProgrammedChunk {
            blocks,
            power,
            row_mask: mask.row.clone(),
            noise_std,
            plan,
            row_limit,
            col_limit,
            golden,
            drift,
        }
    }

    /// Dedupe the activation gather tables per chunk-column: every
    /// chunk-row whose plan shares a table will read one shared
    /// quantized panel per column block (matmul pass 1) instead of
    /// re-gathering it p times.
    fn build_panel_groups(
        chunks: &[ProgrammedChunk],
        p: usize,
        q: usize,
    ) -> (Vec<PanelGroup>, Vec<usize>) {
        let mut panel_groups: Vec<PanelGroup> = Vec::new();
        let mut group_of = vec![0usize; chunks.len()];
        for qi in 0..q {
            let mut local: Vec<usize> = Vec::new(); // this column's groups
            for pi in 0..p {
                let idx = pi * q + qi;
                let cols_tbl = &chunks[idx].plan.cols;
                let g = match local
                    .iter()
                    .copied()
                    .find(|&g| panel_groups[g].cols == *cols_tbl)
                {
                    Some(g) => g,
                    None => {
                        panel_groups.push(PanelGroup { qi, cols: cols_tbl.clone() });
                        local.push(panel_groups.len() - 1);
                        panel_groups.len() - 1
                    }
                };
                group_of[idx] = g;
            }
        }
        (panel_groups, group_of)
    }

    /// Flush a pending incremental mask update for `layer`: reprogram
    /// exactly the chunks [`Self::apply_mask_update`] diffed as changed
    /// (running the same per-chunk recipe as [`Self::program_layer`])
    /// and rebuild the layer's shared panel groups, which is the only
    /// [`PanelCache`] invalidation needed — the cache re-derives its
    /// slab layout from the groups on every call. Unchanged chunks keep
    /// their programmed blocks and thermal calibration state.
    fn flush_mask_update(&mut self, layer: &str, w: &[f64]) {
        let Some(dirty) = self.pending_reprogram.remove(layer) else { return };
        let Some(mut pl) = self.programmed.remove(layer) else { return };
        let quant = SymmetricQuant::calibrate(self.cfg.b_w, w);
        let w_max = w.iter().fold(0.0f64, |m, &x| m.max(x.abs())).max(1e-12);
        let (rows, cols) = self.cfg.chunk_shape();
        let layer_mask = self.masks.get(layer).cloned();
        let dense_chunk = ChunkMask::dense(rows, cols);
        for idx in dirty {
            let (pi, qi) = (idx / pl.q, idx % pl.q);
            let mask = layer_mask
                .as_ref()
                .map(|lm| lm.chunk(pi, qi).clone())
                .unwrap_or_else(|| dense_chunk.clone());
            pl.chunks[idx] = self.program_chunk(
                layer, w, pl.out_dim, pl.in_dim, pi, qi, pl.q, &quant, w_max, mask,
            );
        }
        let (panel_groups, group_of) = Self::build_panel_groups(&pl.chunks, pl.p, pl.q);
        pl.panel_groups = panel_groups;
        pl.group_of = group_of;
        self.programmed.insert(layer.to_string(), pl);
    }

    /// Per-call activation normalization scan, shared by all execution
    /// paths and run only after the staleness check decided the call is
    /// proceeding.
    ///
    /// **Unsigned-activation contract**: the twin intensity-encodes
    /// activations, so negative values carry no light — they clip to
    /// zero at the modulator (`(v / x_max).clamp(0.0, 1.0)`) and are
    /// deliberately excluded from this scan (`fold` from `0.0`). An
    /// all-zero (or all-negative) input therefore normalizes against the
    /// `1e-12` floor and streams pure darkness: finite outputs, leakage
    /// bias only. NaN activations are a caller bug the clamp would
    /// silently swallow, hence the debug assertion.
    fn activation_max(x: &[f64]) -> f64 {
        debug_assert!(
            x.iter().all(|v| !v.is_nan()),
            "activations must not contain NaN"
        );
        x.iter().fold(0.0f64, |m, &v| m.max(v)).max(1e-12)
    }

    /// Per-item activation maxima of an item-major batched panel
    /// (`in_dim` rows × `batch·cols_per_item` columns): each item
    /// normalizes against *its own* modulator full-scale, exactly like
    /// the sequential per-item call it replaces — a shared batch-wide max
    /// would re-quantize every image against the brightest one and break
    /// batched-vs-sequential value identity. Same unsigned-activation
    /// contract (and `1e-12` floor) as [`Self::activation_max`].
    fn batch_activation_max(
        x: &[f64],
        n_cols: usize,
        cols_per_item: usize,
        batch: usize,
    ) -> Vec<f64> {
        debug_assert!(
            x.iter().all(|v| !v.is_nan()),
            "activations must not contain NaN"
        );
        let mut maxes = vec![0.0f64; batch];
        for row in x.chunks_exact(n_cols) {
            for (m, stripe) in maxes.iter_mut().zip(row.chunks_exact(cols_per_item)) {
                *m = stripe.iter().fold(*m, |acc, &v| acc.max(v));
            }
        }
        for m in &mut maxes {
            *m = m.max(1e-12);
        }
        maxes
    }

    /// Record the energy for streaming `n_cols` activation columns
    /// through a programmed layer (shared by both execution paths).
    fn record_layer_energy(
        energy: &mut EnergyAccumulator,
        layer: &str,
        pl: &ProgrammedLayer,
        n_cols: usize,
    ) {
        // energy ledger: every chunk holds power for n_cols cycles
        // (x2 for protected layers: non-adjacent mapping halves occupancy)
        for chunk in &pl.chunks {
            energy.record(layer, &chunk.power, pl.cycle_factor * n_cols as u64);
        }
        energy.advance_wall(pl.cycle_factor * (pl.n_waves * n_cols) as u64);
    }

    /// The pre-compilation execution path: streams every activation
    /// column through every programmed PTC block with per-element
    /// bool-mask branching, drawing noise from the engine's sequential
    /// RNG. Kept as the equivalence oracle for the compiled planner
    /// (`rust/tests/exec_engine.rs`) and as the bench baseline
    /// (EXPERIMENTS.md §Perf); the `MatmulEngine` impl below is the
    /// production path.
    pub fn matmul_reference(
        &mut self,
        layer: &str,
        w: &[f64],
        x: &[f64],
        out_dim: usize,
        in_dim: usize,
        n_cols: usize,
    ) -> Vec<f64> {
        assert_eq!(w.len(), out_dim * in_dim);
        assert_eq!(x.len(), in_dim * n_cols);
        if out_dim == 0 || in_dim == 0 || n_cols == 0 {
            // degenerate layer: the product is all zeros (empty when the
            // output itself is empty) — nothing to program or meter
            return vec![0.0; out_dim * n_cols];
        }
        let stale = match self.programmed.get(layer) {
            Some(pl) => pl.out_dim != out_dim || pl.in_dim != in_dim,
            None => true,
        };
        if stale {
            self.program_layer(layer, w, out_dim, in_dim);
        } else {
            // a hot-swap may have queued dirty chunks for this layer —
            // reprogram exactly those before executing
            self.flush_mask_update(layer, w);
        }

        let x_max = Self::activation_max(x);
        let aq = UnsignedQuant { bits: self.cfg.b_in, max: 1.0 };
        let (rows, cols) = self.cfg.chunk_shape();
        let (k1, k2) = (self.cfg.k1, self.cfg.k2);
        let (r, c) = (self.cfg.share_r, self.cfg.share_c);

        let pl = self.programmed.get_mut(layer).unwrap();
        let scale = pl.w_scale * x_max;
        let mut y = vec![0.0f64; out_dim * n_cols];
        let mut xseg = vec![0.0f64; k2];
        let mut yblock = vec![0.0f64; k1];

        for col in 0..n_cols {
            for qi in 0..pl.q {
                for pi in 0..pl.p {
                    let chunk = &mut pl.chunks[pi * pl.q + qi];
                    for b in 0..c {
                        // gather + normalize + quantize this input segment
                        for j in 0..k2 {
                            let gj = qi * cols + b * k2 + j;
                            let v = if gj < in_dim { x[gj * n_cols + col] } else { 0.0 };
                            let v = (v / x_max).clamp(0.0, 1.0);
                            xseg[j] =
                                if self.opts.quantize { aq.quantize(v) } else { v };
                        }
                        for a in 0..r {
                            let blk = &mut chunk.blocks[a * c + b];
                            yblock.iter_mut().for_each(|v| *v = 0.0);
                            blk.run_into(&xseg, &mut yblock, &mut self.rng);
                            for i in 0..k1 {
                                let gi = pi * rows + a * k1 + i;
                                if gi < out_dim {
                                    y[gi * n_cols + col] += yblock[i] * scale;
                                }
                            }
                        }
                    }
                    // hoisted PD noise: one draw per active chunk row
                    if chunk.noise_std > 0.0 {
                        let og = self.cfg.features.output_gating;
                        for i in 0..rows {
                            if og && !chunk.row_mask[i] {
                                continue;
                            }
                            let gi = pi * rows + i;
                            if gi < out_dim {
                                y[gi * n_cols + col] +=
                                    self.rng.gaussian_std(chunk.noise_std) * scale;
                            }
                        }
                    }
                }
            }
        }

        Self::record_layer_energy(&mut self.energy, layer, pl, n_cols);
        y
    }

    /// The faithful **pre-PR4 (PR1-style) single-pass** compiled path:
    /// (chunk-row × column-block) items that each gather + quantize
    /// their own copy of the activation panel into a fresh `Vec`, sweep
    /// it with the scalar branch-per-weight kernel
    /// (`ChunkPlan::accumulate_scalar`), and get collected into a
    /// `Vec<Vec<f64>>` before scattering on the caller. Every column
    /// block's panel is thus materialized once *per chunk-row* — the
    /// O(p×) redundancy (plus the scalar kernel and the allocation
    /// churn) that the two-pass [`MatmulEngine::matmul`] removes.
    ///
    /// Kept (a) as the uncached baseline `scatter bench engine` measures
    /// the zero-redundancy speedup ratio against
    /// (`ci/bench_baseline.json` arms `speedup_cached_vs_uncached_tall`
    /// at ≥ 1.3×), and (b) as the equivalence oracle: outputs equal the
    /// cached path's for every thread count and feature set, PD noise
    /// included — the noise streams are counter-based per (chunk,
    /// column) and the kernels share per-element term order
    /// (`rust/tests/exec_engine.rs`).
    pub fn matmul_uncached(
        &mut self,
        layer: &str,
        w: &[f64],
        x: &[f64],
        out_dim: usize,
        in_dim: usize,
        n_cols: usize,
    ) -> Vec<f64> {
        assert_eq!(w.len(), out_dim * in_dim);
        assert_eq!(x.len(), in_dim * n_cols);
        if out_dim == 0 || in_dim == 0 || n_cols == 0 {
            return vec![0.0; out_dim * n_cols];
        }
        let stale = match self.programmed.get(layer) {
            Some(pl) => pl.out_dim != out_dim || pl.in_dim != in_dim,
            None => true,
        };
        if stale {
            self.program_layer(layer, w, out_dim, in_dim);
        } else {
            // a hot-swap may have queued dirty chunks for this layer —
            // reprogram exactly those before executing
            self.flush_mask_update(layer, w);
        }

        // per-call context, copied out before borrowing the plan
        let x_max = Self::activation_max(x);
        let aq = UnsignedQuant { bits: self.cfg.b_in, max: 1.0 };
        let quantize = self.opts.quantize;
        let (rows, cols) = self.cfg.chunk_shape();
        let seed = self.cfg.noise_seed;
        let threads = self.threads;
        let epoch = self.noise_epoch;
        self.noise_epoch = self.noise_epoch.wrapping_add(1);
        let timing = self.stage_timing.then_some(&self.stage_times);

        let pl = self.programmed.get(layer).unwrap();
        let scale = pl.w_scale * x_max;
        let (p, q) = (pl.p, pl.q);
        let (block_cols, n_cblocks) = Self::column_blocking(threads, p, n_cols);
        let n_items = p * n_cblocks;

        let results: Vec<Vec<f64>> = parallel_map(threads, n_items, |item| {
            let pi = item / n_cblocks;
            let col0 = (item % n_cblocks) * block_cols;
            let bcols = block_cols.min(n_cols - col0);
            let mut buf = vec![0.0f64; rows * bcols];
            let mut xq: Vec<f64> = Vec::new();
            for qi in 0..q {
                let chunk = &pl.chunks[pi * q + qi];
                let plan = &chunk.plan;
                // every item re-gathers + re-quantizes its own panel —
                // the redundancy the cached path exists to remove
                let t0 = timing.map(|_| std::time::Instant::now());
                xq.clear();
                xq.resize(plan.n_active_cols() * bcols, 0.0);
                for (ci, &j) in plan.cols.iter().enumerate() {
                    let gj = qi * cols + j as usize;
                    let src = &x[gj * n_cols + col0..gj * n_cols + col0 + bcols];
                    let dst = &mut xq[ci * bcols..(ci + 1) * bcols];
                    for (d, &v) in dst.iter_mut().zip(src) {
                        let v = (v / x_max).clamp(0.0, 1.0);
                        *d = if quantize { aq.quantize(v) } else { v };
                    }
                }
                if let Some(st) = timing {
                    st.add_gather(t0.expect("timer started").elapsed());
                }
                let t0 = timing.map(|_| std::time::Instant::now());
                plan.accumulate_scalar(&xq, bcols, &mut buf);
                if let Some(st) = timing {
                    st.add_kernel(t0.expect("timer started").elapsed());
                }
                if plan.noise_std > 0.0 {
                    let t0 = timing.map(|_| std::time::Instant::now());
                    let chunk_id = (pi * q + qi) as u64;
                    for t in 0..bcols {
                        let mut nrng = XorShiftRng::from_stream(
                            seed,
                            &[epoch, chunk_id, (col0 + t) as u64],
                        );
                        for &row in &plan.rows {
                            buf[row as usize * bcols + t] +=
                                nrng.gaussian_std(plan.noise_std);
                        }
                    }
                    if let Some(st) = timing {
                        st.add_scatter(t0.expect("timer started").elapsed());
                    }
                }
            }
            buf
        });

        // scatter the disjoint (chunk-row × column-block) regions into y
        let t0 = timing.map(|_| std::time::Instant::now());
        let mut y = vec![0.0f64; out_dim * n_cols];
        for (item, buf) in results.iter().enumerate() {
            let pi = item / n_cblocks;
            let col0 = (item % n_cblocks) * block_cols;
            let bcols = block_cols.min(n_cols - col0);
            let row_limit = rows.min(out_dim - pi * rows);
            for i in 0..row_limit {
                let gi = pi * rows + i;
                let src = &buf[i * bcols..(i + 1) * bcols];
                let dst = &mut y[gi * n_cols + col0..gi * n_cols + col0 + bcols];
                for (d, &v) in dst.iter_mut().zip(src) {
                    *d = v * scale;
                }
            }
        }
        if let Some(st) = timing {
            st.add_scatter(t0.expect("timer started").elapsed());
        }

        Self::record_layer_energy(&mut self.energy, layer, pl, n_cols);
        y
    }

    /// Column-blocking policy, shared verbatim by the cached and
    /// uncached paths: panel-contiguous sweeps sized so the pool has a
    /// few items per worker to load-balance. Block size never affects
    /// results — accumulation order per (row, column) is fixed, and
    /// noise streams are per column.
    fn column_blocking(threads: usize, p: usize, n_cols: usize) -> (usize, usize) {
        let target_items = (threads * 4).max(p);
        let blocks_per_p = target_items.div_ceil(p).max(1);
        let block_cols = n_cols.div_ceil(blocks_per_p).clamp(1, 64);
        (block_cols, n_cols.div_ceil(block_cols))
    }
}

impl MatmulEngine for PhotonicEngine {
    /// The production single-call path: one item spanning every column.
    /// Delegates to [`MatmulEngine::matmul_batch`] with `batch = 1`,
    /// which reproduces the historical behavior bit for bit (one noise
    /// epoch covering all columns, one activation full-scale).
    fn matmul(
        &mut self,
        layer: &str,
        w: &[f64],
        x: &[f64],
        out_dim: usize,
        in_dim: usize,
        n_cols: usize,
    ) -> Vec<f64> {
        self.matmul_batch(layer, w, x, out_dim, in_dim, n_cols, 1)
    }

    /// Open a batched-forward context: record the epoch base and batch
    /// geometry so every [`MatmulEngine::matmul_batch`] call until
    /// [`MatmulEngine::end_batch`] addresses item `g`'s noise streams at
    /// epoch `base + g·matmuls_per_item + call_index` — the exact epochs
    /// the sequential per-item schedule would consume.
    fn begin_batch(&mut self, batch: usize, matmuls_per_item: u64) {
        debug_assert!(self.batch_ctx.is_none(), "begin_batch while a batch is open");
        self.batch_ctx = Some(BatchCtx {
            batch: batch as u64,
            stride: matmuls_per_item,
            base: self.noise_epoch,
            calls: 0,
        });
    }

    /// Close the batched-forward context and advance the noise epoch to
    /// where the equivalent sequential forwards would have left it
    /// (`base + batch · matmuls_per_item`).
    fn end_batch(&mut self) {
        if let Some(ctx) = self.batch_ctx.take() {
            self.noise_epoch = ctx.base.wrapping_add(ctx.batch.wrapping_mul(ctx.stride));
        }
    }

    /// Zero-redundancy two-pass execution over a whole batch. **Pass 1**
    /// materializes, once per (distinct gather table, column block), the
    /// gathered + normalized + quantized activation panel into the
    /// engine's shared slab ([`PanelCache`], sized by the full
    /// `batch · cols_per_item` column count) — a (group × column-block)
    /// parallel fan-out writing disjoint slab regions. **Pass 2** fans
    /// (chunk-row × column-block) items that read those panels
    /// read-only, sweep them through each chunk's register-blocked
    /// weight panel (`ChunkPlan::accumulate`), and scatter scaled
    /// results directly into the preallocated output's disjoint
    /// (row-band × column-block) regions — no per-item allocation
    /// (worker arenas), no result collection.
    ///
    /// **Batched-vs-sequential value identity** (the
    /// `rust/tests/batch_forward.rs` property): columns are item-major,
    /// and each item keeps the exact semantics of the per-item call it
    /// replaces —
    ///
    /// * *normalization*: item `g` normalizes and re-scales against its
    ///   own activation maximum ([`Self::batch_activation_max`]), never
    ///   a batch-wide one;
    /// * *noise*: item `g`'s column `t` draws from stream
    ///   `(epoch(g), chunk, t)` ([`NoiseGrid`]) with `epoch(g)` supplied
    ///   by the open [`BatchCtx`] (or `noise_epoch + g` for a standalone
    ///   batched call, matching `g` prior plain calls), so the bits are
    ///   independent of batching, thread count, block partitioning, and
    ///   the pass split.
    ///
    /// Also equal to [`Self::matmul_uncached`] output-for-output when
    /// `batch = 1`: quantization is elementwise (pass-invariant) and the
    /// two kernels share per-element MAC term order.
    ///
    /// Under [`KernelPrecision::Quantized`] pass 1 instead materializes
    /// each panel as `i16` activation codes (the DAC-quantized value
    /// re-gridded onto [`crate::exec::kernel::ACT_LEVELS`]) in the
    /// cache's aligned code slab, and pass 2 sweeps the integer
    /// [`QuantPanel`](crate::exec::QuantPanel) kernel at the engine's
    /// SIMD level. Every determinism invariant above still holds —
    /// integer sums are order-independent and the noise/scatter stages
    /// are unchanged — but the result lives on the integer grid, so
    /// oracle equality is replaced by the argmax-agreement gate
    /// (`rust/tests/exec_engine.rs`).
    fn matmul_batch(
        &mut self,
        layer: &str,
        w: &[f64],
        x: &[f64],
        out_dim: usize,
        in_dim: usize,
        cols_per_item: usize,
        batch: usize,
    ) -> Vec<f64> {
        let n_cols = cols_per_item * batch;
        assert_eq!(w.len(), out_dim * in_dim);
        assert_eq!(x.len(), in_dim * n_cols);
        if out_dim == 0 || in_dim == 0 || n_cols == 0 {
            // degenerate: nothing to program, meter, or draw noise for
            // (the epoch stays put, exactly like the sequential calls)
            return vec![0.0; out_dim * n_cols];
        }
        let stale = match self.programmed.get(layer) {
            Some(pl) => pl.out_dim != out_dim || pl.in_dim != in_dim,
            None => true,
        };
        if stale {
            self.program_layer(layer, w, out_dim, in_dim);
        } else {
            // a hot-swap may have queued dirty chunks for this layer —
            // reprogram exactly those before executing
            self.flush_mask_update(layer, w);
        }

        // per-call context, copied out before borrowing the plan
        let x_maxes = Self::batch_activation_max(x, n_cols, cols_per_item, batch);
        let aq = UnsignedQuant { bits: self.cfg.b_in, max: 1.0 };
        let quantize = self.opts.quantize;
        let (rows, cols) = self.cfg.chunk_shape();
        let seed = self.cfg.noise_seed;
        let threads = self.threads;
        let (epoch0, epoch_stride) = match self.batch_ctx.as_mut() {
            Some(ctx) => {
                debug_assert_eq!(batch as u64, ctx.batch, "batch size vs begin_batch");
                debug_assert!(ctx.calls < ctx.stride, "more matmul calls than declared");
                let e = ctx.base.wrapping_add(ctx.calls);
                ctx.calls += 1;
                (e, ctx.stride)
            }
            None => {
                // standalone call: item g draws the epoch g sequential
                // calls would have consumed, then the counter moves past
                // the whole batch
                let e = self.noise_epoch;
                self.noise_epoch = self.noise_epoch.wrapping_add(batch as u64);
                (e, 1)
            }
        };
        let grid = NoiseGrid { epoch0, epoch_stride, cols_per_item };
        let quant_mode = self.precision == KernelPrecision::Quantized;
        let simd = self.simd;
        let timing = self.stage_timing.then_some(&self.stage_times);
        let mut panels = std::mem::take(&mut self.panels);
        let (mut col_xmax, mut col_scale) = std::mem::take(&mut self.col_norm);

        let pl = self.programmed.get(layer).unwrap();
        let (p, q) = (pl.p, pl.q);
        // per-column normalization divisor and output scale, item-major
        // stripes (the sequential calls' `x_max` / `w_scale · x_max`);
        // the scratch vectors are engine-owned and grow-only, so the
        // steady state stays allocation-free beyond the output
        col_xmax.clear();
        col_scale.clear();
        for (g, &m) in x_maxes.iter().enumerate() {
            let end = (g + 1) * cols_per_item;
            col_xmax.resize(end, m);
            col_scale.resize(end, pl.w_scale * m);
        }
        let (block_cols, n_cblocks) = Self::column_blocking(threads, p, n_cols);

        // ---- pass 1: shared quantized-activation panels, one per
        // (gather-table group, column block) ----
        panels.prepare(pl.panel_groups.iter().map(|g| g.cols.len() * n_cols));
        let n_pitems = pl.panel_groups.len() * n_cblocks;
        if quant_mode {
            // quantized pass 1: same gather/normalize/DAC-quantize, then
            // re-grid onto the i16 code slab the integer kernel streams
            panels.prepare_quant();
            let (offsets, qslab) = panels.quant_parts_mut();
            let writer = DisjointWriter::new(qslab);
            parallel_for_with(threads, n_pitems, || (), |item, _| {
                let g = item / n_cblocks;
                let col0 = (item % n_cblocks) * block_cols;
                let bcols = block_cols.min(n_cols - col0);
                let grp = &pl.panel_groups[g];
                let nc = grp.cols.len();
                let t0 = timing.map(|_| std::time::Instant::now());
                // SAFETY: group panels are disjoint slab ranges (prefix-
                // sum offsets) and column blocks partition each panel,
                // so every item owns its range exclusively
                let panel = unsafe { writer.slice_mut(offsets[g] + nc * col0, nc * bcols) };
                let xm = &col_xmax[col0..col0 + bcols];
                for (ci, &j) in grp.cols.iter().enumerate() {
                    let gj = grp.qi * cols + j as usize;
                    let src = &x[gj * n_cols + col0..gj * n_cols + col0 + bcols];
                    let dst = &mut panel[ci * bcols..(ci + 1) * bcols];
                    for ((d, &v), &m) in dst.iter_mut().zip(src).zip(xm) {
                        let v = (v / m).clamp(0.0, 1.0);
                        let vq = if quantize { aq.quantize(v) } else { v };
                        *d = (vq * crate::exec::kernel::ACT_LEVELS).round() as i16;
                    }
                }
                if let Some(st) = timing {
                    st.add_gather(t0.expect("timer started").elapsed());
                }
            });
        } else {
            let (offsets, slab) = panels.parts_mut();
            let writer = DisjointWriter::new(slab);
            parallel_for_with(threads, n_pitems, || (), |item, _| {
                let g = item / n_cblocks;
                let col0 = (item % n_cblocks) * block_cols;
                let bcols = block_cols.min(n_cols - col0);
                let grp = &pl.panel_groups[g];
                let nc = grp.cols.len();
                let t0 = timing.map(|_| std::time::Instant::now());
                // SAFETY: as in the quantized branch above
                let panel = unsafe { writer.slice_mut(offsets[g] + nc * col0, nc * bcols) };
                let xm = &col_xmax[col0..col0 + bcols];
                for (ci, &j) in grp.cols.iter().enumerate() {
                    let gj = grp.qi * cols + j as usize;
                    let src = &x[gj * n_cols + col0..gj * n_cols + col0 + bcols];
                    let dst = &mut panel[ci * bcols..(ci + 1) * bcols];
                    for ((d, &v), &m) in dst.iter_mut().zip(src).zip(xm) {
                        let v = (v / m).clamp(0.0, 1.0);
                        *d = if quantize { aq.quantize(v) } else { v };
                    }
                }
                if let Some(st) = timing {
                    st.add_gather(t0.expect("timer started").elapsed());
                }
            });
        }

        // ---- pass 2: accumulate + direct scatter, panels read-only ----
        let (offsets, slab) = panels.parts();
        let qslab = if quant_mode { panels.quant_parts().1 } else { &[][..] };
        let mut y = vec![0.0f64; out_dim * n_cols];
        let writer = DisjointWriter::new(&mut y);
        let n_items = p * n_cblocks;
        parallel_for_with(threads, n_items, WorkerArena::new, |item, arena| {
            let pi = item / n_cblocks;
            let col0 = (item % n_cblocks) * block_cols;
            let bcols = block_cols.min(n_cols - col0);
            let buf = arena.zeroed(rows * bcols);
            for qi in 0..q {
                let idx = pi * q + qi;
                let plan = &pl.chunks[idx].plan;
                let nc = plan.n_active_cols();
                let off = offsets[pl.group_of[idx]] + nc * col0;
                let t0 = timing.map(|_| std::time::Instant::now());
                if quant_mode {
                    let xq = &qslab[off..][..nc * bcols];
                    plan.accumulate_quant(xq, bcols, buf, simd);
                } else {
                    let xq = &slab[off..][..nc * bcols];
                    plan.accumulate(xq, bcols, buf);
                }
                if let Some(st) = timing {
                    st.add_kernel(t0.expect("timer started").elapsed());
                }
                // hoisted PD noise, one draw per active chunk row from a
                // counter-based per-(item-epoch, chunk, item-local
                // column) stream — bit-identical for any thread count,
                // block partitioning, pass split, or batching
                if plan.noise_std > 0.0 {
                    let t0 = timing.map(|_| std::time::Instant::now());
                    let chunk_id = idx as u64;
                    for t in 0..bcols {
                        let (epoch, lcol) = grid.stream(col0 + t);
                        let mut nrng = XorShiftRng::from_stream(seed, &[epoch, chunk_id, lcol]);
                        for &row in &plan.rows {
                            buf[row as usize * bcols + t] +=
                                nrng.gaussian_std(plan.noise_std);
                        }
                    }
                    if let Some(st) = timing {
                        st.add_scatter(t0.expect("timer started").elapsed());
                    }
                }
            }
            // direct scatter: this item exclusively owns output rows
            // [pi·rows, pi·rows + row_limit) × columns [col0, col0+bcols)
            let t0 = timing.map(|_| std::time::Instant::now());
            let row_limit = rows.min(out_dim - pi * rows);
            let sc = &col_scale[col0..col0 + bcols];
            for i in 0..row_limit {
                let gi = pi * rows + i;
                // SAFETY: (row-band × column-block) regions are pairwise
                // disjoint across items
                let dst = unsafe { writer.slice_mut(gi * n_cols + col0, bcols) };
                let src = &buf[i * bcols..(i + 1) * bcols];
                for ((d, &v), &s) in dst.iter_mut().zip(src).zip(sc) {
                    *d = v * s;
                }
            }
            if let Some(st) = timing {
                st.add_scatter(t0.expect("timer started").elapsed());
            }
        });

        Self::record_layer_energy(&mut self.energy, layer, pl, n_cols);
        self.panels = panels;
        self.col_norm = (col_xmax, col_scale);
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{ExactEngine, MatmulEngine};
    use crate::util::{nmae, XorShiftRng};

    fn small_cfg(features: crate::config::SparsitySupport) -> AcceleratorConfig {
        AcceleratorConfig {
            features,
            l_g: 5.0,
            dac: crate::config::DacKind::Edac,
            ..Default::default()
        }
    }

    fn problem(out: usize, inp: usize, n_cols: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = XorShiftRng::new(seed);
        let mut w = vec![0.0; out * inp];
        rng.fill_uniform(&mut w, -0.5, 0.5);
        let mut x = vec![0.0; inp * n_cols];
        rng.fill_uniform(&mut x, 0.0, 1.0);
        (w, x)
    }

    #[test]
    fn ideal_engine_matches_exact_within_quantization() {
        let cfg = small_cfg(crate::config::SparsitySupport::NONE);
        let mut eng = PhotonicEngine::new(cfg, EngineOptions::IDEAL);
        let (w, x) = problem(64, 64, 4, 1);
        let y = eng.matmul("l", &w, &x, 64, 64, 4);
        let y_exact = ExactEngine.matmul("l", &w, &x, 64, 64, 4);
        let e = nmae(&y, &y_exact);
        assert!(e < 0.02, "quantization-only error should be small: {e}");
    }

    #[test]
    fn padded_shapes_work() {
        let cfg = small_cfg(crate::config::SparsitySupport::NONE);
        let mut eng = PhotonicEngine::new(cfg, EngineOptions::IDEAL);
        let (w, x) = problem(70, 90, 3, 2);
        let y = eng.matmul("l", &w, &x, 70, 90, 3);
        let y_exact = ExactEngine.matmul("l", &w, &x, 70, 90, 3);
        assert_eq!(y.len(), 210);
        assert!(nmae(&y, &y_exact) < 0.03);
    }

    #[test]
    fn thermal_noise_hurts_and_scatter_recovers() {
        let (w, x) = problem(64, 64, 8, 3);
        let y_exact = ExactEngine.matmul("l", &w, &x, 64, 64, 8);
        // dense + thermal variation at tight pitch: big error
        let cfg = AcceleratorConfig {
            l_g: 1.0,
            features: crate::config::SparsitySupport::NONE,
            dac: crate::config::DacKind::Edac,
            ..Default::default()
        };
        let mut noisy = PhotonicEngine::new(cfg.clone(), EngineOptions::NOISY);
        let e_dense = nmae(&noisy.matmul("l", &w, &x, 64, 64, 8), &y_exact);

        // sparse masks + full SCATTER features: error drops
        let scfg = AcceleratorConfig {
            features: crate::config::SparsitySupport::FULL,
            ..cfg
        };
        let mut scatter = PhotonicEngine::new(scfg, EngineOptions::NOISY);
        let gamma = GammaModel::paper();
        let mzi = Mzi::new(MziSpec::low_power(), 9.0, &gamma);
        let (mask, _, _) = crate::sparsity::init_layer_mask(1, 1, 64, 64, 16, 0.5, &mzi);
        let mut masks = BTreeMap::new();
        masks.insert("l".to_string(), mask.clone());
        scatter.set_masks(masks);
        // golden = exact matmul under the same mask
        let mut wm = w.clone();
        // apply mask to weights for the golden
        let chunk = mask.chunk(0, 0);
        for i in 0..64 {
            for j in 0..64 {
                if !chunk.element(i, j) {
                    wm[i * 64 + j] = 0.0;
                }
            }
        }
        let y_masked = ExactEngine.matmul("l", &wm, &x, 64, 64, 8);
        let e_scatter = nmae(&scatter.matmul("l", &w, &x, 64, 64, 8), &y_masked);
        assert!(
            e_scatter < e_dense * 0.5,
            "SCATTER {e_scatter} should beat dense-under-TV {e_dense}"
        );
    }

    /// Heat-only drift schedule: env depends only on the served count,
    /// so every assertion below is deterministic (no wall clock).
    fn heat_only_drift() -> DriftConfig {
        DriftConfig {
            ambient_amp_rad: 0.0,
            self_heat_amp_rad: 0.2,
            self_heat_tau_reqs: 24.0,
            ..DriftConfig::default()
        }
    }

    fn drift_opts() -> EngineOptions {
        // thermal crosstalk + quantization only: no per-call randomness,
        // so output equality below is exact
        EngineOptions { thermal: true, pd_noise: false, phase_noise: false, quantize: true }
    }

    #[test]
    fn drift_runtime_inert_until_ticked() {
        let cfg = small_cfg(crate::config::SparsitySupport::FULL);
        let (w, x) = problem(128, 128, 4, 21);
        let mut plain = PhotonicEngine::new(cfg.clone(), drift_opts());
        let mut thermal = PhotonicEngine::new(cfg, drift_opts());
        thermal.set_thermal(heat_only_drift(), ThermalPolicy::Off);
        let y_plain = plain.matmul("l", &w, &x, 128, 128, 4);
        let y_thermal = thermal.matmul("l", &w, &x, 128, 128, 4);
        assert_eq!(y_plain, y_thermal, "un-ticked runtime must not perturb anything");
    }

    #[test]
    fn drift_degrades_and_recalibration_restores_exactly() {
        let cfg = small_cfg(crate::config::SparsitySupport::FULL);
        let (w, x) = problem(128, 128, 4, 22);
        let mut eng = PhotonicEngine::new(cfg, drift_opts());
        eng.set_thermal(heat_only_drift(), ThermalPolicy::Off);
        let y0 = eng.matmul("l", &w, &x, 128, 128, 4);
        let s = eng.thermal_tick(0.0, 50).expect("runtime enabled");
        assert!(s.env_rad > 0.1, "self-heating after 50 requests: {}", s.env_rad);
        assert_eq!(s.chunks_total, 4, "128x128 on the 64x64 grid");
        assert!(s.phase_error_rad > 0.0);
        assert!(s.drift_applies > 0, "physics update must have re-baked plans");
        assert_eq!(s.recal_events, 0, "policy off never recalibrates");
        let y1 = eng.matmul("l", &w, &x, 128, 128, 4);
        assert_ne!(y0, y1, "drifted plans must change the output");
        let n = eng.recalibrate_thermal();
        assert_eq!(n, 4, "all drifted chunks recompile");
        let y2 = eng.matmul("l", &w, &x, 128, 128, 4);
        assert_eq!(y0, y2, "recalibrated == freshly-programmed, bit for bit");
    }

    #[test]
    fn threshold_policy_bounds_error_and_recalibrates_incrementally() {
        let cfg = small_cfg(crate::config::SparsitySupport::FULL);
        let (w, x) = problem(256, 256, 2, 23);
        let mut eng = PhotonicEngine::new(cfg, drift_opts());
        let budget = 0.05;
        eng.set_thermal(heat_only_drift(), ThermalPolicy::Threshold { budget_rad: budget });
        let _ = eng.matmul("l", &w, &x, 256, 256, 2);
        let mut last = ThermalStatus::default();
        for served in 1..=60u64 {
            last = eng.thermal_tick(0.0, served).expect("runtime enabled");
            assert!(
                last.phase_error_rad <= budget + 1e-12,
                "residual error {} exceeds budget at n={served}",
                last.phase_error_rad
            );
        }
        assert_eq!(last.chunks_total, 16, "256x256 on the 64x64 grid");
        assert!(last.recal_events >= 2, "chunks cross the budget at different times");
        assert!(last.recal_chunks >= 1);
        assert!(
            last.recal_chunks < last.recal_events * last.chunks_total,
            "incremental: {} chunks over {} events beats full re-programs",
            last.recal_chunks,
            last.recal_events
        );
    }

    /// A (old, new) mask pair over the 2×2 chunk grid of a 128×128
    /// layer where exactly chunk (0, 1) differs (one column swapped
    /// on ↔ off), so the incremental swap has one dirty chunk.
    fn swap_masks() -> (crate::sparsity::LayerMask, crate::sparsity::LayerMask) {
        let gamma = GammaModel::paper();
        let mzi = Mzi::new(MziSpec::low_power(), 9.0, &gamma);
        let (old, _, _) = crate::sparsity::init_layer_mask(2, 2, 64, 64, 16, 0.5, &mzi);
        let mut new = old.clone();
        let c = new.chunk_mut(0, 1);
        let j_on = c.col.iter().position(|&m| m).expect("an active column");
        let j_off = c.col.iter().position(|&m| !m).expect("a pruned column");
        c.col[j_on] = false;
        c.col[j_off] = true;
        (old, new)
    }

    fn one_layer(mask: &crate::sparsity::LayerMask) -> BTreeMap<String, LayerMask> {
        let mut m = BTreeMap::new();
        m.insert("l".to_string(), mask.clone());
        m
    }

    #[test]
    fn incremental_mask_swap_matches_fresh_program_bit_for_bit() {
        let cfg = small_cfg(crate::config::SparsitySupport::FULL);
        let (w, x) = problem(128, 128, 4, 31);
        let (old, new) = swap_masks();

        let mut eng = PhotonicEngine::new(cfg.clone(), drift_opts());
        eng.set_masks(one_layer(&old));
        let y_old = eng.matmul("l", &w, &x, 128, 128, 4);
        assert_eq!(eng.mask_generation(), 0);

        let dirty = eng.apply_mask_update(one_layer(&new), 7);
        assert_eq!(dirty, 1, "exactly the edited chunk is dirty");
        assert_eq!(eng.mask_generation(), 7);
        let y_inc = eng.matmul("l", &w, &x, 128, 128, 4);
        assert_ne!(y_old, y_inc, "the mask change must show in the output");

        // only the reprogrammed chunk carries the new generation tag
        let pl = eng.programmed.get("l").expect("programmed");
        for (idx, chunk) in pl.chunks.iter().enumerate() {
            let expect = if idx == 1 { 7 } else { 0 };
            assert_eq!(chunk.plan.mask_gen, expect, "plan tag of chunk {idx}");
            assert!(
                chunk.blocks.iter().all(|b| b.mask_gen == expect),
                "block tags of chunk {idx}"
            );
        }

        // bit-identical to a fresh engine programmed under the new masks
        let mut fresh = PhotonicEngine::new(cfg, drift_opts());
        fresh.set_masks(one_layer(&new));
        let y_fresh = fresh.matmul("l", &w, &x, 128, 128, 4);
        assert_eq!(y_inc, y_fresh, "incremental reprogram == fresh program");
    }

    #[test]
    fn mask_swap_preserves_unchanged_chunk_calibration() {
        let cfg = small_cfg(crate::config::SparsitySupport::FULL);
        let (w, x) = problem(128, 128, 2, 32);
        let (old, new) = swap_masks();
        let mut eng = PhotonicEngine::new(cfg, drift_opts());
        eng.set_thermal(heat_only_drift(), ThermalPolicy::Off);
        eng.set_masks(one_layer(&old));
        let _ = eng.matmul("l", &w, &x, 128, 128, 2);
        let s = eng.thermal_tick(0.0, 50).expect("runtime enabled");
        assert!(s.env_rad > 0.1);

        assert_eq!(eng.apply_mask_update(one_layer(&new), 1), 1);
        let _ = eng.matmul("l", &w, &x, 128, 128, 2); // flushes the swap
        let pl = eng.programmed.get("l").expect("programmed");
        let d = pl.chunks[1].drift.as_ref().expect("drift state");
        assert_eq!(
            d.comp_env, s.env_rad,
            "the reprogrammed chunk calibrates at the current envelope"
        );
        let d0 = pl.chunks[0].drift.as_ref().expect("drift state");
        assert_eq!(d0.comp_env, 0.0, "unchanged chunks keep their calibration");

        // ...so a forced recalibration only touches the 3 unchanged chunks
        assert_eq!(eng.recalibrate_thermal(), 3);
    }

    #[test]
    fn mask_update_before_programming_defers_to_first_program() {
        let cfg = small_cfg(crate::config::SparsitySupport::FULL);
        let (w, x) = problem(128, 128, 2, 33);
        let (_, new) = swap_masks();
        let mut eng = PhotonicEngine::new(cfg.clone(), drift_opts());
        assert_eq!(
            eng.apply_mask_update(one_layer(&new), 3),
            0,
            "nothing programmed yet, so nothing is dirty"
        );
        let y = eng.matmul("l", &w, &x, 128, 128, 2);
        let pl = eng.programmed.get("l").expect("programmed");
        assert!(pl.chunks.iter().all(|c| c.plan.mask_gen == 3), "first program stamps");

        let mut fresh = PhotonicEngine::new(cfg, drift_opts());
        fresh.set_masks(one_layer(&new));
        assert_eq!(y, fresh.matmul("l", &w, &x, 128, 128, 2));
    }

    #[test]
    fn periodic_policy_recalibrates_on_cadence() {
        let cfg = small_cfg(crate::config::SparsitySupport::FULL);
        let (w, x) = problem(128, 128, 2, 24);
        let mut eng = PhotonicEngine::new(cfg, drift_opts());
        eng.set_thermal(
            heat_only_drift(),
            ThermalPolicy::Periodic { every_requests: 10 },
        );
        let _ = eng.matmul("l", &w, &x, 128, 128, 2);
        let s = eng.thermal_tick(0.0, 5).expect("on");
        assert_eq!(s.recal_events, 0, "before the cadence");
        let s = eng.thermal_tick(0.0, 10).expect("on");
        assert_eq!(s.recal_events, 1);
        assert_eq!(s.recal_chunks, s.chunks_total, "periodic touches every chunk");
        let s = eng.thermal_tick(0.0, 19).expect("on");
        assert_eq!(s.recal_events, 1, "cadence counts from the last recal");
        let s = eng.thermal_tick(0.0, 20).expect("on");
        assert_eq!(s.recal_events, 2);
    }

    #[test]
    fn sentinel_detects_and_localizes_injected_faults() {
        let cfg = small_cfg(crate::config::SparsitySupport::FULL);
        let (w, x) = problem(128, 128, 4, 41);
        let (mask, _) = swap_masks();
        let mut eng = PhotonicEngine::new(cfg, drift_opts());
        eng.set_masks(one_layer(&mask));
        let y0 = eng.matmul("l", &w, &x, 128, 128, 4);
        assert!(eng.sentinel_probe_all().is_empty(), "clean fabric: no findings");

        // break an active rerouter branch in chunk (0,1) and an active
        // PD row in chunk (1,0)
        let j = mask.chunk(0, 1).col.iter().position(|&m| m).expect("active col");
        let ri = mask.chunk(1, 0).row.iter().position(|&m| m).expect("active row");
        let plan = crate::ptc::DeviceFaultPlan::parse(&format!(
            "dead-branch@l:c1:i{j},dead-pd@l:c2:r{ri}"
        ))
        .expect("valid spec");
        assert_eq!(eng.inject_device_faults(&plan), 2, "two chunks hit");
        let y1 = eng.matmul("l", &w, &x, 128, 128, 4);
        assert_ne!(y0, y1, "dead devices must corrupt the output");

        let findings = eng.sentinel_probe_all();
        assert_eq!(findings.len(), 2, "both faulted chunks flagged: {findings:?}");
        let branch = &findings[0];
        assert_eq!((branch.layer.as_str(), branch.chunk), ("l", 1));
        assert_eq!(branch.cols, vec![j], "dead branch localizes to its column");
        assert!(branch.rows.is_empty(), "no dead row in chunk 1: {branch:?}");
        let pd = &findings[1];
        assert_eq!((pd.layer.as_str(), pd.chunk), ("l", 2));
        assert_eq!(pd.rows, vec![ri], "dead PD localizes to its row");
        assert!(pd.cols.is_empty(), "no dead column in chunk 2: {pd:?}");
        assert!(pd.worst_dev > 1e-6, "dead weights deviate visibly");
    }

    #[test]
    fn repair_restores_untouched_rows_bit_for_bit() {
        let cfg = small_cfg(crate::config::SparsitySupport::FULL);
        let (w, x) = problem(128, 128, 4, 42);
        let (mask, _) = swap_masks();
        let mut eng = PhotonicEngine::new(cfg.clone(), drift_opts());
        eng.set_masks(one_layer(&mask));
        let y_clean = eng.matmul("l", &w, &x, 128, 128, 4);

        // fault confined to chunk (0,1) → output rows 64.. (the pi = 1
        // band) are served by untouched chunks throughout
        let j = mask.chunk(0, 1).col.iter().position(|&m| m).expect("active col");
        let plan =
            crate::ptc::DeviceFaultPlan::parse(&format!("dead-branch@l:c1:i{j}")).unwrap();
        assert_eq!(eng.inject_device_faults(&plan), 1);
        let y_fault = eng.matmul("l", &w, &x, 128, 128, 4);
        assert_ne!(y_clean[..64 * 4], y_fault[..64 * 4], "faulted band corrupts");
        assert_eq!(y_clean[64 * 4..], y_fault[64 * 4..], "other band untouched");

        // detect → quarantine → hot-swap repair
        let findings = eng.sentinel_probe_all();
        assert_eq!(findings.len(), 1);
        let (repaired, cells) =
            eng.quarantine_masks(&findings).expect("masked layer is repairable");
        assert_eq!(cells, 1, "exactly the dead column is quarantined");
        assert_eq!(eng.apply_mask_update(repaired.clone(), 1), 1, "one dirty chunk");
        let y_rep = eng.matmul("l", &w, &x, 128, 128, 4);
        assert_eq!(
            y_clean[64 * 4..],
            y_rep[64 * 4..],
            "rows outside the quarantined chunk are bit-identical to pre-fault"
        );
        // the reprogram re-baselined the golden around the (now masked)
        // dead branch: the sentinel is quiet again
        assert!(eng.sentinel_probe_all().is_empty(), "repaired fabric probes clean");

        // repaired state == fresh deployment with the same quarantine
        // masks on equally-broken hardware, bit for bit
        let mut fresh = PhotonicEngine::new(cfg, drift_opts());
        fresh.set_device_faults(plan.clone());
        fresh.set_masks(repaired);
        let y_fresh = fresh.matmul("l", &w, &x, 128, 128, 4);
        assert_eq!(y_rep, y_fresh, "repair swap == fresh program, bit for bit");

        // promote the quarantine: a later DST step proposing the
        // original mask must not resurrect the dead column
        eng.record_quarantine(&findings);
        assert_eq!(eng.quarantined_cell_count(), 1);
        assert_eq!(
            eng.apply_mask_update(one_layer(&mask), 2),
            0,
            "the resurrection intersects away to the installed masks"
        );
        assert!(
            !eng.masks().get("l").expect("layer").chunk(0, 1).col[j],
            "quarantined column stays off across generations"
        );
    }

    #[test]
    fn unmasked_layer_faults_are_unrepairable() {
        let cfg = small_cfg(crate::config::SparsitySupport::FULL);
        let (w, x) = problem(64, 64, 2, 43);
        let mut eng = PhotonicEngine::new(cfg, drift_opts());
        // startup-path faults: installed before programming, detected at
        // the first probe
        eng.set_device_faults(
            crate::ptc::DeviceFaultPlan::parse("stuck@l:c0:r3:i4:p1.2").unwrap(),
        );
        let _ = eng.matmul("l", &w, &x, 64, 64, 2);
        let findings = eng.sentinel_probe_all();
        assert_eq!(findings.len(), 1, "startup fault visible at first probe");
        assert_eq!(findings[0].cols, vec![4], "stuck MZI implicates its column");
        // ...but the layer was deployed dense (no mask): there is no
        // rerouter tree to steer light away with — unrepairable
        assert!(eng.quarantine_masks(&findings).is_none());
    }

    #[test]
    fn energy_ledger_accumulates() {
        let cfg = small_cfg(crate::config::SparsitySupport::NONE);
        let mut eng = PhotonicEngine::new(cfg, EngineOptions::IDEAL);
        let (w, x) = problem(64, 64, 10, 4);
        let _ = eng.matmul("l", &w, &x, 64, 64, 10);
        let rep = eng.energy_report();
        assert!(rep.energy_mj > 0.0);
        assert_eq!(rep.cycles, 10, "1 chunk, 1 wave, 10 cols");
        assert!(eng.p_avg_w() > 0.0);
        // a second call doubles energy (programming is cached)
        let _ = eng.matmul("l", &w, &x, 64, 64, 10);
        let rep2 = eng.energy_report();
        assert!((rep2.energy_mj / rep.energy_mj - 2.0).abs() < 1e-9);
    }

    #[test]
    fn gating_reduces_recorded_power() {
        let (w, x) = problem(64, 64, 4, 5);
        let gamma = GammaModel::paper();
        let mzi = Mzi::new(MziSpec::low_power(), 9.0, &gamma);
        let (mask, _, _) = crate::sparsity::init_layer_mask(1, 1, 64, 64, 16, 0.3, &mzi);
        let run = |features| {
            let cfg = small_cfg(features);
            let mut eng = PhotonicEngine::new(cfg, EngineOptions::IDEAL);
            let mut masks = BTreeMap::new();
            masks.insert("l".to_string(), mask.clone());
            eng.set_masks(masks);
            let _ = eng.matmul("l", &w, &x, 64, 64, 4);
            eng.p_avg_w()
        };
        let p_none = run(crate::config::SparsitySupport::NONE);
        let p_full = run(crate::config::SparsitySupport::FULL);
        assert!(p_full < p_none * 0.9, "gating saves power: {p_full} vs {p_none}");
    }
}

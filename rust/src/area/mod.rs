//! Chip-area modeling (§3.2.2, Eqs. 5–7) and the folded rerouter layout.

pub mod layout;
pub mod model;

pub use model::{AreaBreakdown, AreaModel};

//! Analytic chip-area model (Eqs. 5–7).
//!
//! ```text
//!   A_node    = (l_s + w_PS) × (l_Y + l_PS + l_DC)                  (Eq. 5)
//!   A_PTC,wgt = ((k2−1)·l_v + len) × ((k1−1)·l_h + width)           (Eq. 6)
//!   A         = RC·(A_PTC + k2·A_MMI + 2k1k2·A_PD)
//!             + RC/r·(k2·A_DAC + k2·A_MZM + A_rerouter)
//!             + RC/c·(k1·A_ADC + k1·A_TIA)                          (Eq. 7)
//! ```
//!
//! Calibration note: with the default `DeviceLibrary` (A_DAC = 0.011 mm²)
//! the eoDAC upgrade adds (RC/r)·k2·A_DAC = 0.704 mm², exactly the delta
//! the paper quotes under Table 3.

use crate::config::{AcceleratorConfig, DacKind};
use crate::devices::{DeviceLibrary, MziSpec};

/// Itemized area numbers, all in mm².
#[derive(Debug, Clone, Copy, Default)]
pub struct AreaBreakdown {
    pub weight_array_mm2: f64,
    pub mmi_mm2: f64,
    pub pd_mm2: f64,
    pub dac_mm2: f64,
    pub mzm_mm2: f64,
    pub rerouter_mm2: f64,
    pub adc_mm2: f64,
    pub tia_mm2: f64,
}

impl AreaBreakdown {
    pub fn total_mm2(&self) -> f64 {
        self.weight_array_mm2
            + self.mmi_mm2
            + self.pd_mm2
            + self.dac_mm2
            + self.mzm_mm2
            + self.rerouter_mm2
            + self.adc_mm2
            + self.tia_mm2
    }
}

#[derive(Debug, Clone)]
pub struct AreaModel {
    pub cfg: AcceleratorConfig,
    pub lib: DeviceLibrary,
}

impl AreaModel {
    pub fn new(cfg: AcceleratorConfig, lib: DeviceLibrary) -> Self {
        Self { cfg, lib }
    }

    pub fn with_defaults(cfg: AcceleratorConfig) -> Self {
        Self::new(cfg, DeviceLibrary::default())
    }

    /// Eq. 5: single crossbar-node footprint in mm².
    pub fn node_mm2(&self) -> f64 {
        let spec = MziSpec::from_kind(self.cfg.mzi);
        spec.width_um(self.cfg.l_s) * spec.length_um * 1e-6
    }

    /// Eq. 6: the k1×k2 weight-MZI array footprint of one PTC in mm².
    pub fn ptc_weight_array_mm2(&self) -> f64 {
        let c = &self.cfg;
        let spec = MziSpec::from_kind(c.mzi);
        let height_um = (c.k2 as f64 - 1.0) * c.l_v + spec.length_um;
        let width_um = (c.k1 as f64 - 1.0) * c.l_h() + spec.width_um(c.l_s);
        height_um * width_um * 1e-6
    }

    /// Eq. 7: full-chip breakdown.
    pub fn breakdown(&self) -> AreaBreakdown {
        let c = &self.cfg;
        let rc = c.n_cores() as f64;
        let per_r = rc / c.share_r as f64;
        let per_c = rc / c.share_c as f64;
        let dac_factor = match c.dac {
            DacKind::Edac => 1.0,
            DacKind::Eodac { segments, .. } => segments as f64,
        };
        AreaBreakdown {
            weight_array_mm2: rc * self.ptc_weight_array_mm2(),
            mmi_mm2: rc * c.k2 as f64 * self.lib.area_mmi_mm2,
            pd_mm2: rc * 2.0 * (c.k1 * c.k2) as f64 * self.lib.area_pd_mm2,
            dac_mm2: per_r * c.k2 as f64 * self.lib.area_dac_mm2 * dac_factor,
            mzm_mm2: per_r * c.k2 as f64 * self.lib.area_mzm_mm2,
            rerouter_mm2: per_r
                * super::layout::folded_rerouter_mm2(c.k2, &MziSpec::low_power(), c.l_s),
            adc_mm2: per_c * c.k1 as f64 * self.lib.area_adc_mm2,
            tia_mm2: per_c * c.k1 as f64 * self.lib.area_tia_mm2,
        }
    }

    pub fn total_mm2(&self) -> f64 {
        self.breakdown().total_mm2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MziKind;

    #[test]
    fn node_area_matches_paper_numbers() {
        // LP node: (9 + 6) µm × 115 µm = 1725 µm² = 0.001725 mm²
        let cfg = AcceleratorConfig { l_s: 9.0, mzi: MziKind::LowPower, ..Default::default() };
        let a = AreaModel::with_defaults(cfg);
        assert!((a.node_mm2() - 0.001725).abs() < 1e-9);
        // Foundry node: 156.25 × 550 µm²
        let cfg = AcceleratorConfig::foundry_baseline();
        let a = AreaModel::with_defaults(cfg);
        assert!((a.node_mm2() - 156.25 * 550.0 * 1e-6).abs() < 1e-9);
    }

    #[test]
    fn ptc_array_area_eq6() {
        // l_s=9, l_g=5 -> l_h=20; height = 15*120+115 = 1915, width = 15*20+15 = 315
        let cfg = AcceleratorConfig { l_s: 9.0, l_g: 5.0, ..Default::default() };
        let a = AreaModel::with_defaults(cfg);
        assert!((a.ptc_weight_array_mm2() - 1915.0 * 315.0 * 1e-6).abs() < 1e-9);
    }

    #[test]
    fn lg_shrink_delta_matches_table3() {
        // Table 3: l_g 5→1 µm saves ~1.83 mm² on the 16-core chip.
        let mk = |l_g: f64| {
            AreaModel::with_defaults(AcceleratorConfig { l_g, ..Default::default() })
                .total_mm2()
        };
        let delta = mk(5.0) - mk(1.0);
        assert!((delta - 1.838).abs() < 0.01, "delta={delta}");
    }

    #[test]
    fn eodac_adds_paper_quoted_area() {
        let base = AcceleratorConfig { dac: DacKind::Edac, ..Default::default() };
        let eo = AcceleratorConfig { dac: DacKind::optimal_eodac(), ..Default::default() };
        let d = AreaModel::with_defaults(eo).total_mm2()
            - AreaModel::with_defaults(base).total_mm2();
        assert!((d - 0.704).abs() < 1e-9, "eoDAC area delta = {d}");
    }

    #[test]
    fn total_area_near_table3_operating_points() {
        // Table 3 (eoDAC): l_g=1 → 12.37 mm², l_g=3 → 13.44, l_g=5 → 14.20.
        for (l_g, want) in [(1.0, 12.37), (3.0, 13.44), (5.0, 14.20)] {
            let cfg = AcceleratorConfig { l_g, ..Default::default() };
            let got = AreaModel::with_defaults(cfg).total_mm2();
            let err = (got - want).abs() / want;
            assert!(err < 0.10, "l_g={l_g}: {got:.2} vs paper {want} ({:.1}%)", err * 100.0);
        }
    }

    #[test]
    fn sharing_shrinks_converter_area() {
        let dedicated = AcceleratorConfig { share_r: 1, share_c: 1, ..Default::default() };
        let shared = AcceleratorConfig::default(); // r=c=4
        let bd_d = AreaModel::with_defaults(dedicated).breakdown();
        let bd_s = AreaModel::with_defaults(shared).breakdown();
        assert!((bd_d.dac_mm2 / bd_s.dac_mm2 - 4.0).abs() < 1e-9);
        assert!((bd_d.adc_mm2 / bd_s.adc_mm2 - 4.0).abs() < 1e-9);
        assert_eq!(bd_d.weight_array_mm2, bd_s.weight_array_mm2);
    }

    #[test]
    fn foundry_orders_of_magnitude_larger() {
        let f = AreaModel::with_defaults(AcceleratorConfig::foundry_baseline()).total_mm2();
        let s = AreaModel::with_defaults(AcceleratorConfig::default()).total_mm2();
        assert!(f / s > 20.0, "foundry/scatter = {}", f / s);
    }
}

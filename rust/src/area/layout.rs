//! Rerouter layout area (Fig. 5 note: "a folded rerouter layout is
//! designed to save area").
//!
//! The 1×k2 tunable rerouter is a binary tree of k2−1 MZI power splitters.
//! A straight tree layout occupies `depth` columns of device length, with
//! up to k2/2 devices stacked per column. The folded layout serpentines
//! consecutive tree levels into a fixed-height strip so the footprint is
//! ~(k2−1) node areas plus a routing overhead factor, independent of tree
//! depth — roughly 2× tighter than the straight tree for k2 = 16.

use crate::devices::MziSpec;

/// Routing/bend overhead multiplier for the folded serpentine.
const FOLD_ROUTING_OVERHEAD: f64 = 1.25;
/// Vertical pitch between folded splitter rows (µm).
const FOLD_ROW_PITCH_UM: f64 = 20.0;

/// Straight (unfolded) binary-tree layout area in mm².
pub fn tree_rerouter_mm2(k2: usize, spec: &MziSpec, l_s: f64) -> f64 {
    if k2 <= 1 {
        return 0.0;
    }
    let depth = (k2 as f64).log2().ceil();
    let width_um = depth * spec.length_um;
    let height_um = (k2 as f64 / 2.0) * (spec.width_um(l_s) + FOLD_ROW_PITCH_UM);
    width_um * height_um * 1e-6
}

/// Folded serpentine layout area in mm² (the shipped design).
pub fn folded_rerouter_mm2(k2: usize, spec: &MziSpec, l_s: f64) -> f64 {
    if k2 <= 1 {
        return 0.0;
    }
    let n_nodes = (k2 - 1) as f64;
    let node_mm2 = spec.width_um(l_s) * spec.length_um * 1e-6;
    n_nodes * node_mm2 * FOLD_ROUTING_OVERHEAD
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn folded_beats_tree() {
        let spec = MziSpec::low_power();
        let folded = folded_rerouter_mm2(16, &spec, 9.0);
        let tree = tree_rerouter_mm2(16, &spec, 9.0);
        assert!(folded < tree, "folded {folded} should beat tree {tree}");
        assert!(folded > 0.0);
    }

    #[test]
    fn degenerate_sizes() {
        let spec = MziSpec::low_power();
        assert_eq!(folded_rerouter_mm2(1, &spec, 9.0), 0.0);
        assert_eq!(tree_rerouter_mm2(1, &spec, 9.0), 0.0);
    }

    #[test]
    fn scales_linearly_with_ports() {
        let spec = MziSpec::low_power();
        let a16 = folded_rerouter_mm2(16, &spec, 9.0);
        let a32 = folded_rerouter_mm2(32, &spec, 9.0);
        assert!((a32 / a16 - 31.0 / 15.0).abs() < 1e-9);
    }
}

//! Table 3 — main results: dense PTC vs SCATTER across l_g ∈ {1, 3, 5} µm,
//! ideal accuracy / accuracy with thermal variation (TV) / accuracy with
//! IG+OG+LR recovery, plus single-image inference energy.
//!
//! CNN uses s = 0.3; VGG8/ResNet18 use s = 0.4 (paper's settings).

use super::common::{table3_config, BenchCtx, Workload};
use crate::area::AreaModel;
use crate::config::{AcceleratorConfig, SparsitySupport};
use crate::coordinator::EngineOptions;
use crate::util::Table;

pub fn run(ctx: &BenchCtx) -> Table {
    run_models(ctx, &[Workload::Cnn3, Workload::Vgg8, Workload::Resnet18])
}

pub fn run_models(ctx: &BenchCtx, workloads: &[Workload]) -> Table {
    let mut table = Table::new("Table 3 — main results (dense vs SCATTER)").header(&[
        "model",
        "setting",
        "Ideal Acc",
        "TV@lg=1",
        "TV@lg=3",
        "TV@lg=5",
        "+IG+OG+LR@lg=1",
        "+IG+OG+LR@lg=3",
        "+IG+OG+LR@lg=5",
        "E (mJ/img)",
    ]);
    // area header rows (config-level, model independent)
    for l_g in [1.0, 3.0, 5.0] {
        let cfg = AcceleratorConfig { l_g, ..Default::default() };
        let area = AreaModel::with_defaults(cfg).total_mm2();
        table.row(vec![
            "(chip)".into(),
            format!("l_g={l_g:.0}um"),
            format!("Area={area:.2} mm^2"),
            "".into(),
            "".into(),
            "".into(),
            "".into(),
            "".into(),
            "".into(),
            "".into(),
        ]);
    }

    for &wl in workloads {
        let n = ctx.eval_budget(wl);
        let density = match wl {
            Workload::Cnn3 => 0.3,
            _ => 0.4,
        };

        for (setting, dens) in [("DensePTC", 1.0f64), ("SCATTER", density)] {
            // deployment: DST-style masked backbone + re-fit readout
            let cfg0 = table3_config(5.0, SparsitySupport::NONE);
            let (model, ds, masks0) = ctx.deployment(wl, &cfg0, dens);
            let (acc_ideal, _) =
                ctx.accuracy(&model, &ds, &cfg0, EngineOptions::IDEAL, masks0.clone(), n);

            let mut row = vec![
                wl.label().to_string(),
                setting.to_string(),
                format!("{:.2}", acc_ideal * 100.0),
            ];

            // accuracy w/ TV (no gating/LR)
            for l_g in [1.0, 3.0, 5.0] {
                let cfg = table3_config(l_g, SparsitySupport::NONE);
                let (acc, _) =
                    ctx.accuracy(&model, &ds, &cfg, EngineOptions::NOISY, masks0.clone(), n);
                row.push(format!("{:.2}", acc * 100.0));
            }
            // recovered accuracy with IG+OG+LR (SCATTER only; dense has no
            // pruned paths to gate, mark as n/a)
            let mut energy_mj = 0.0;
            for l_g in [1.0, 3.0, 5.0] {
                if dens >= 1.0 {
                    row.push("-".into());
                    // still capture dense energy at l_g=1
                    if l_g == 1.0 {
                        let cfg = table3_config(l_g, SparsitySupport::NONE);
                        let (_, engine) = ctx.accuracy(
                            &model, &ds, &cfg, EngineOptions::NOISY, Default::default(), 1,
                        );
                        energy_mj = engine.energy_report().energy_mj;
                    }
                    continue;
                }
                let cfg = table3_config(l_g, SparsitySupport::FULL);
                let (acc, engine) =
                    ctx.accuracy(&model, &ds, &cfg, EngineOptions::NOISY, masks0.clone(), n);
                row.push(format!("{:.2}", acc * 100.0));
                if l_g == 1.0 {
                    energy_mj = engine.energy_report().energy_mj / n.max(1) as f64;
                }
            }
            row.push(format!("{energy_mj:.3}"));
            table.row(row);
        }
    }
    table
}

//! Thermal-drift serving scenario (`scatter bench drift`).
//!
//! The paper's Eqs. 8–9 crosstalk model is applied once at programming
//! time; this bench measures what that one-shot calibration costs a
//! *long-running* deployment, and what the online-recalibration runtime
//! (`thermal::drift` + `PhotonicEngine::thermal_tick`) buys back:
//!
//! 1. **accuracy under drift** (virtual time, deterministic): the CNN-3
//!    s=0.3 NOISY deployment classifies `n` samples while the
//!    accelerated drift schedule plays out; policies compared are
//!    drift-free (reference), policy-off (drift, no recalibration),
//!    threshold (recalibrate chunks past a phase-error budget), and
//!    periodic (recalibrate everything every n/8 requests);
//! 2. **serving gauges** (real TCP): a 2-worker server runs under a
//!    heat-only drift schedule while requests stream in, and
//!    `/metrics` is scraped for the drift/recalibration gauges.
//!
//! Emits `BENCH_drift.json` at the repo root; `ci/check_bench.py` gates
//! on the threshold policy recovering ≥ 90 % of the drift-free accuracy
//! while recompiling fewer chunks than naive full re-programs
//! (EXPERIMENTS.md §Thermal-drift).

use crate::bench::common::{host_info, repo_root_file, BenchCtx, Workload};
use crate::config::AcceleratorConfig;
use crate::coordinator::net::{http_request, metric_value, HttpServer, NetConfig};
use crate::coordinator::{
    EngineOptions, InferenceServer, PhotonicEngine, ServerConfig, ThermalServerConfig,
    ThermalStatus,
};
use crate::data::SyntheticDataset;
use crate::nn::Model;
use crate::sparsity::LayerMask;
use crate::thermal::{DriftConfig, ThermalPolicy};
use crate::util::{Json, Table};
use std::collections::BTreeMap;
use std::time::Duration;

/// Phase-error budget (rad) for the threshold policy.
const BUDGET_RAD: f64 = 0.02;

/// Classify `n` samples while advancing the drift runtime by `dt_s`
/// virtual seconds per request. `thermal: None` = drift-free reference.
fn accuracy_under_drift(
    model: &Model,
    ds: &SyntheticDataset,
    cfg: &AcceleratorConfig,
    masks: &BTreeMap<String, LayerMask>,
    thermal: Option<(DriftConfig, ThermalPolicy)>,
    n: usize,
    dt_s: f64,
) -> (f64, Option<ThermalStatus>) {
    let mut engine = PhotonicEngine::new(cfg.clone(), EngineOptions::NOISY);
    engine.set_masks(masks.clone());
    // paper §4.1: protected readout, as in every other harness
    if let Some((last, _, _)) = model.matmul_layers().last() {
        engine.set_protected([last.clone()].into_iter().collect());
    }
    let ticking = if let Some((d, p)) = &thermal {
        engine.set_thermal(d.clone(), *p);
        true
    } else {
        false
    };
    let mut correct = 0usize;
    let mut last = None;
    for i in 0..n {
        if ticking {
            last = engine.thermal_tick(i as f64 * dt_s, i as u64);
        }
        let (img, label) = ds.sample(0xD21F7, i);
        if model.predict(img, &mut engine) == label {
            correct += 1;
        }
    }
    (correct as f64 / n.max(1) as f64, last)
}

struct ServeGauges {
    requests_ok: u64,
    drift_rad: f64,
    phase_error_rad: f64,
    recalibrations: u64,
    recal_chunks: u64,
}

/// Serve real TCP traffic under a heat-only drift schedule (time_scale
/// 0: the envelope depends only on each worker's served count, so the
/// gauges are deterministic) and scrape `/metrics` for the drift and
/// recalibration gauges the acceptance criteria name.
fn serve_with_drift(
    model: Model,
    cfg: &AcceleratorConfig,
    masks: BTreeMap<String, LayerMask>,
    requests: usize,
) -> ServeGauges {
    let server_cfg = ServerConfig::builder()
        .max_batch(4)
        .batch_timeout(Duration::from_millis(2))
        .workers(2)
        .thermal(ThermalServerConfig {
            drift: Some(DriftConfig {
                ambient_amp_rad: 0.0,
                self_heat_amp_rad: 0.2,
                self_heat_tau_reqs: 8.0,
                time_scale: 0.0,
                ..DriftConfig::default()
            }),
            policy: ThermalPolicy::Threshold { budget_rad: 0.01 },
            ..Default::default()
        })
        .build()
        .expect("drift bench config validates");
    let server =
        InferenceServer::spawn(model, cfg.clone(), EngineOptions::NOISY, masks, server_cfg);
    let http = HttpServer::bind(server, NetConfig::default()).expect("bind ephemeral port");
    let addr = http.local_addr();

    let ds = SyntheticDataset::new(crate::data::DatasetSpec::fmnist_like());
    let bodies: Vec<String> = (0..8)
        .map(|i| {
            let (img, _) = ds.sample(0xBE7, i);
            Json::obj(vec![("image", Json::arr_f64(&img.data))]).to_string()
        })
        .collect();
    let mut requests_ok = 0u64;
    for i in 0..requests {
        if let Ok(resp) =
            http_request(&addr, "POST", "/v1/predict", Some(&bodies[i % bodies.len()]))
        {
            if resp.status == 200 {
                requests_ok += 1;
            }
        }
    }
    let metrics = http_request(&addr, "GET", "/metrics", None).expect("metrics scrape");
    let drift_rad = metric_value(&metrics.body, "scatter_thermal_drift_rad");
    let phase_error_rad = metric_value(&metrics.body, "scatter_thermal_phase_error_rad");
    let report = http.shutdown().expect("drain drift server");
    ServeGauges {
        requests_ok,
        drift_rad,
        phase_error_rad,
        recalibrations: report.recalibrations,
        recal_chunks: report.recal_chunks,
    }
}

/// Run the scenario, print the summary table, write `BENCH_drift.json`,
/// and return the rendered table.
pub fn run(ctx: &BenchCtx) -> String {
    let cfg = AcceleratorConfig::default();
    let density = 0.3;
    let (model, ds, masks) = ctx.deployment(Workload::Cnn3, &cfg, density);
    let n = ctx.n_eval.clamp(20, 200);

    let drift = DriftConfig::accelerated();
    // the virtual schedule sweeps 1.5 ambient periods across the run,
    // so policy-off sees both drift extremes
    let dt_s = 1.5 * drift.ambient_period_s / n as f64;
    let periodic_every = (n / 8).max(1) as u64;

    let (acc_free, _) =
        accuracy_under_drift(&model, &ds, &cfg, &masks, None, n, dt_s);
    let (acc_off, st_off) = accuracy_under_drift(
        &model,
        &ds,
        &cfg,
        &masks,
        Some((drift.clone(), ThermalPolicy::Off)),
        n,
        dt_s,
    );
    let (acc_thr, st_thr) = accuracy_under_drift(
        &model,
        &ds,
        &cfg,
        &masks,
        Some((drift.clone(), ThermalPolicy::Threshold { budget_rad: BUDGET_RAD })),
        n,
        dt_s,
    );
    let (acc_per, st_per) = accuracy_under_drift(
        &model,
        &ds,
        &cfg,
        &masks,
        Some((drift.clone(), ThermalPolicy::Periodic { every_requests: periodic_every })),
        n,
        dt_s,
    );

    let st_thr = st_thr.unwrap_or_default();
    let st_off = st_off.unwrap_or_default();
    let st_per = st_per.unwrap_or_default();
    let recovery = if acc_free > 0.0 { acc_thr / acc_free } else { 0.0 };
    // what a naive controller would have recompiled: every chunk, at
    // every recalibration action
    let full_reprogram = st_thr.recal_events * st_thr.chunks_total;

    let serve = serve_with_drift(model, &cfg, masks, 40);

    let mut table = Table::new(
        "thermal drift: accuracy + recalibration, accelerated schedule (CNN-3, s=0.3, NOISY)",
    )
    .header(&["metric", "value"]);
    table.row(vec!["samples × dt".into(), format!("{n} × {dt_s:.2} s")]);
    table.row(vec!["accuracy drift-free".into(), format!("{acc_free:.3}")]);
    table.row(vec![
        "accuracy policy off".into(),
        format!("{acc_off:.3} (final |err| {:.3} rad)", st_off.phase_error_rad),
    ]);
    table.row(vec![
        format!("accuracy threshold ({BUDGET_RAD} rad)"),
        format!("{acc_thr:.3} (recovery {recovery:.2})"),
    ]);
    table.row(vec![
        format!("accuracy periodic (every {periodic_every})"),
        format!("{acc_per:.3}"),
    ]);
    table.row(vec![
        "threshold recal chunks / full-reprogram".into(),
        format!(
            "{} / {} ({} events × {} chunks)",
            st_thr.recal_chunks, full_reprogram, st_thr.recal_events, st_thr.chunks_total
        ),
    ]);
    table.row(vec![
        "serve /metrics drift | phase error".into(),
        format!("{:.4} | {:.4} rad", serve.drift_rad, serve.phase_error_rad),
    ]);
    table.row(vec![
        "serve recalibrations (events / chunks)".into(),
        format!("{} / {}", serve.recalibrations, serve.recal_chunks),
    ]);

    let json = Json::obj(vec![
        ("bench", Json::Str("thermal_drift".into())),
        ("host", host_info()),
        (
            "schedule",
            Json::obj(vec![
                ("ambient_amp_rad", Json::Num(drift.ambient_amp_rad)),
                ("ambient_period_s", Json::Num(drift.ambient_period_s)),
                ("self_heat_amp_rad", Json::Num(drift.self_heat_amp_rad)),
                ("self_heat_tau_reqs", Json::Num(drift.self_heat_tau_reqs)),
                ("dt_s", Json::Num(dt_s)),
                ("samples", Json::Num(n as f64)),
                ("budget_rad", Json::Num(BUDGET_RAD)),
                ("periodic_every", Json::Num(periodic_every as f64)),
            ]),
        ),
        (
            "accuracy",
            Json::obj(vec![
                ("drift_free", Json::Num(acc_free)),
                ("policy_off", Json::Num(acc_off)),
                ("policy_threshold", Json::Num(acc_thr)),
                ("policy_periodic", Json::Num(acc_per)),
                ("recovery_threshold", Json::Num(recovery)),
            ]),
        ),
        (
            "recalibration",
            Json::obj(vec![
                ("events", Json::Num(st_thr.recal_events as f64)),
                ("chunks", Json::Num(st_thr.recal_chunks as f64)),
                ("chunks_total", Json::Num(st_thr.chunks_total as f64)),
                ("full_reprogram_chunks", Json::Num(full_reprogram as f64)),
                ("drift_applies", Json::Num(st_thr.drift_applies as f64)),
                ("periodic_chunks", Json::Num(st_per.recal_chunks as f64)),
                ("off_final_error_rad", Json::Num(st_off.phase_error_rad)),
            ]),
        ),
        (
            "serving",
            Json::obj(vec![
                ("requests_ok", Json::Num(serve.requests_ok as f64)),
                ("metrics_drift_rad", Json::Num(serve.drift_rad)),
                ("metrics_phase_error_rad", Json::Num(serve.phase_error_rad)),
                ("recalibrations", Json::Num(serve.recalibrations as f64)),
                ("recal_chunks", Json::Num(serve.recal_chunks as f64)),
            ]),
        ),
    ]);
    let path = repo_root_file("BENCH_drift.json");
    match std::fs::write(&path, json.to_string()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
    table.render()
}

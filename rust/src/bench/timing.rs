//! Hand-rolled micro-benchmark harness (criterion is unavailable in the
//! offline toolchain). Warms up, runs timed batches until a target wall
//! budget, and reports mean/median/p95 per-iteration times.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        fn fmt(ns: f64) -> String {
            if ns < 1e3 {
                format!("{ns:.0} ns")
            } else if ns < 1e6 {
                format!("{:.2} us", ns / 1e3)
            } else if ns < 1e9 {
                format!("{:.2} ms", ns / 1e6)
            } else {
                format!("{:.2} s", ns / 1e9)
            }
        }
        format!(
            "{:<44} {:>10}/iter  (median {}, p95 {}, n={})",
            self.name,
            fmt(self.mean_ns),
            fmt(self.median_ns),
            fmt(self.p95_ns),
            self.iters
        )
    }
}

/// Benchmark a closure: warm up ~10% of the budget, then sample batches.
pub fn bench(name: &str, budget: Duration, mut f: impl FnMut()) -> BenchResult {
    // warmup + calibration: find an iteration count per sample batch
    let cal_start = Instant::now();
    let mut cal_iters = 0u64;
    while cal_start.elapsed() < budget.mul_f64(0.1).max(Duration::from_millis(5)) {
        f();
        cal_iters += 1;
    }
    let per_iter = cal_start.elapsed().as_nanos() as f64 / cal_iters.max(1) as f64;
    let batch = ((5e6 / per_iter).ceil() as u64).clamp(1, 10_000); // ~5 ms batches

    let mut samples = Vec::new();
    let mut total_iters = 0u64;
    let start = Instant::now();
    while start.elapsed() < budget {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
        total_iters += batch;
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len().max(1) as f64;
    let median = samples.get(samples.len() / 2).copied().unwrap_or(mean);
    let p95 = samples
        .get((samples.len() as f64 * 0.95) as usize)
        .or(samples.last())
        .copied()
        .unwrap_or(mean);
    let r = BenchResult {
        name: name.to_string(),
        iters: total_iters,
        mean_ns: mean,
        median_ns: median,
        p95_ns: p95,
    };
    println!("{}", r.report());
    r
}

/// Time a one-shot (non-repeatable) operation.
pub fn time_once<T>(name: &str, f: impl FnOnce() -> T) -> T {
    let t0 = Instant::now();
    let out = f();
    println!("{:<44} {:>10.2?} (one-shot)", name, t0.elapsed());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let mut x = 0u64;
        let r = bench("noop-ish", Duration::from_millis(30), || {
            x = x.wrapping_add(1);
            std::hint::black_box(x);
        });
        assert!(r.iters > 100);
        assert!(r.mean_ns > 0.0);
        assert!(r.p95_ns >= r.median_ns * 0.5);
    }
}

//! Chaos bench: seeded worker-kill schedule under concurrent load
//! (`scatter bench chaos`, EXPERIMENTS.md §Robustness).
//!
//! Stands up an in-process CNN-3 server with a
//! [`FaultPlan::kill_each_worker_once`] schedule — every engine worker
//! panics exactly once, at a seed-chosen early shard — then drives
//! closed-loop keep-alive clients for the full duration and timestamps
//! every outcome. Recovery is summarized two ways:
//!
//! * **client side**: `pre_fault_rps` (ok-throughput over the first
//!   quarter of the run, which contains the kills) vs `post_fault_rps`
//!   (last quarter, after the supervisor has respawned everyone);
//!   `recovery_ratio = post/pre` is the CI-gated headline;
//! * **server side**: `/metrics` is scraped before drain for the live
//!   supervision gauges, and the drain report supplies the authoritative
//!   respawn/retry/live-worker counts.
//!
//! `ci/check_bench.py --chaos` gates: zero lost replies, at least one
//! respawn, a full-strength pool at drain, and `recovery_ratio` at or
//! above the baseline floor. Everything is seed-deterministic on the
//! fault side; only timing varies run to run.

use crate::bench::common::{host_info, repo_root_file, BenchCtx, Workload};
use crate::config::AcceleratorConfig;
use crate::coordinator::net::{http_request, metric_value, HttpClient, HttpServer, NetConfig};
use crate::coordinator::{EngineOptions, FaultPlan, InferenceServer, ServerConfig};
use crate::util::{Json, Table};
use std::net::SocketAddr;
use std::time::{Duration, Instant};

/// `scatter bench chaos` configuration.
#[derive(Debug, Clone)]
pub struct ChaosBenchConfig {
    pub duration: Duration,
    /// Concurrent keep-alive client connections.
    pub concurrency: usize,
    /// Engine-worker pool size (every worker is killed once).
    pub workers: usize,
    /// Seed for the kill schedule — same seed, same `FaultPlan`.
    pub seed: u64,
}

impl Default for ChaosBenchConfig {
    fn default() -> Self {
        Self {
            duration: Duration::from_secs(4),
            concurrency: 4,
            workers: 3,
            seed: 42,
        }
    }
}

/// One client request outcome, timestamped relative to load start.
#[derive(Debug, Clone, Copy)]
enum Outcome {
    Ok,
    /// 503 — shed by admission or worker-lost retry-after.
    Retryable,
    /// 504 — deadline expired server-side.
    Expired,
    /// Anything else: unexpected status or a connection-level failure
    /// that ate the reply. The chaos gate requires zero of these.
    Lost,
}

/// Closed-loop send loop; every request gets a timestamped outcome.
fn drive_client(
    addr: SocketAddr,
    bodies: &[String],
    started: Instant,
    deadline: Instant,
    seed: usize,
) -> Vec<(f64, Outcome)> {
    let mut events = Vec::new();
    let mut client = match HttpClient::connect(&addr) {
        Ok(c) => c,
        Err(_) => return events,
    };
    let mut i = seed;
    while Instant::now() < deadline {
        let body = &bodies[i % bodies.len()];
        i += 1;
        let outcome = match client.request("POST", "/v1/predict", Some(body)) {
            Ok(resp) => match resp.status {
                200 => Outcome::Ok,
                503 => Outcome::Retryable,
                504 => Outcome::Expired,
                _ => Outcome::Lost,
            },
            Err(_) => {
                // the reply is gone for good; reconnect and keep driving
                match HttpClient::connect(&addr) {
                    Ok(c) => client = c,
                    Err(_) => {
                        events.push((started.elapsed().as_secs_f64(), Outcome::Lost));
                        return events;
                    }
                }
                Outcome::Lost
            }
        };
        events.push((started.elapsed().as_secs_f64(), outcome));
    }
    events
}

/// ok-throughput inside `[lo, hi)` seconds of the run.
fn window_rps(events: &[(f64, Outcome)], lo: f64, hi: f64) -> f64 {
    let ok = events
        .iter()
        .filter(|(t, o)| *t >= lo && *t < hi && matches!(o, Outcome::Ok))
        .count();
    ok as f64 / (hi - lo).max(1e-9)
}

/// Run the chaos bench, print the summary table, write
/// `BENCH_chaos.json`, and return the rendered table.
pub fn run(cfg: &ChaosBenchConfig) -> String {
    let workers = cfg.workers.max(1);
    let faults = FaultPlan::kill_each_worker_once(workers, cfg.seed);
    let fault_desc = faults.describe().join(",");

    let ctx = BenchCtx::new(50);
    let acc = AcceleratorConfig::default();
    let (model, _ds, masks) = ctx.deployment(Workload::Cnn3, &acc, 0.3);
    let server = InferenceServer::spawn(
        model,
        acc,
        EngineOptions::NOISY,
        masks,
        ServerConfig::builder()
            .max_batch(4)
            .batch_timeout(Duration::from_millis(2))
            .workers(workers)
            .faults(faults)
            .build()
            .expect("chaos bench config validates"),
    );
    let http = HttpServer::bind(server, NetConfig::default()).expect("bind ephemeral");
    let addr = http.local_addr();

    let ds = crate::data::SyntheticDataset::new(crate::data::DatasetSpec::fmnist_like());
    let bodies: Vec<String> = (0..16)
        .map(|i| {
            let (img, _) = ds.sample(0xBE7, i);
            Json::obj(vec![("image", Json::arr_f64(&img.data))]).to_string()
        })
        .collect();

    let started = Instant::now();
    let deadline = started + cfg.duration;
    let events: Vec<(f64, Outcome)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..cfg.concurrency.max(1))
            .map(|c| {
                let bodies = &bodies;
                s.spawn(move || drive_client(addr, bodies, started, deadline, c * 7919))
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().expect("client thread")).collect()
    });
    let wall_s = started.elapsed().as_secs_f64().max(1e-9);

    // live supervision gauges, scraped while the server is still up
    let scraped = http_request(&addr, "GET", "/metrics", None)
        .map(|r| r.body)
        .unwrap_or_default();
    let live_restarts = metric_value(&scraped, "scatter_worker_restarts_total");
    let live_workers = metric_value(&scraped, "scatter_workers_live");

    let report = http.shutdown().expect("drain chaos server");

    let (mut ok, mut shed, mut expired, mut lost) = (0u64, 0u64, 0u64, 0u64);
    for (_, o) in &events {
        match o {
            Outcome::Ok => ok += 1,
            Outcome::Retryable => shed += 1,
            Outcome::Expired => expired += 1,
            Outcome::Lost => lost += 1,
        }
    }
    let total = ok + shed + expired + lost;
    let quarter = wall_s / 4.0;
    let pre_fault_rps = window_rps(&events, 0.0, quarter);
    let post_fault_rps = window_rps(&events, 3.0 * quarter, wall_s);
    let recovery_ratio =
        if pre_fault_rps > 0.0 { post_fault_rps / pre_fault_rps } else { 0.0 };

    let mut table = Table::new("chaos bench (kill every worker once under load)")
        .header(&["metric", "value"]);
    table.row(vec!["seed / fault plan".into(), format!("{} / {fault_desc}", cfg.seed)]);
    table.row(vec![
        "pool".into(),
        format!("{workers} workers, closed-loop x{}", cfg.concurrency.max(1)),
    ]);
    table.row(vec!["duration".into(), format!("{wall_s:.2} s")]);
    table.row(vec![
        "ok / shed / expired / lost".into(),
        format!("{ok} / {shed} / {expired} / {lost}"),
    ]);
    table.row(vec![
        "pre-fault throughput".into(),
        format!("{pre_fault_rps:.1} req/s (first quarter, kills included)"),
    ]);
    table.row(vec![
        "post-fault throughput".into(),
        format!("{post_fault_rps:.1} req/s (last quarter)"),
    ]);
    table.row(vec!["recovery ratio".into(), format!("{recovery_ratio:.2}x")]);
    table.row(vec![
        "respawns / retries".into(),
        format!("{} / {}", report.worker_restarts, report.request_retries),
    ]);
    table.row(vec![
        "workers live at drain".into(),
        format!("{} of {workers}", report.workers_live),
    ]);
    if live_restarts.is_finite() && live_workers.is_finite() {
        table.row(vec![
            "live gauges (pre-drain scrape)".into(),
            format!("restarts {live_restarts:.0}, live {live_workers:.0}"),
        ]);
    }

    let json = Json::obj(vec![
        ("bench", Json::Str("chaos".into())),
        ("host", host_info()),
        ("seed", Json::Num(cfg.seed as f64)),
        ("faults", Json::Str(fault_desc.clone())),
        ("duration_s", Json::Num(wall_s)),
        ("concurrency", Json::Num(cfg.concurrency.max(1) as f64)),
        ("workers_configured", Json::Num(workers as f64)),
        ("workers_live", Json::Num(report.workers_live as f64)),
        ("requests_total", Json::Num(total as f64)),
        ("requests_ok", Json::Num(ok as f64)),
        ("shed", Json::Num(shed as f64)),
        ("expired", Json::Num(expired as f64)),
        ("lost", Json::Num(lost as f64)),
        ("respawns", Json::Num(report.worker_restarts as f64)),
        ("retries", Json::Num(report.request_retries as f64)),
        ("brownouts", Json::Num(report.brownouts as f64)),
        ("pre_fault_rps", Json::Num(pre_fault_rps)),
        ("post_fault_rps", Json::Num(post_fault_rps)),
        ("recovery_ratio", Json::Num(recovery_ratio)),
    ]);
    let path = repo_root_file("BENCH_chaos.json");
    match std::fs::write(&path, json.to_string()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
    table.render()
}

//! End-to-end layer-matmul throughput bench for the sparsity-compiled
//! parallel execution engine: sweeps worker-thread counts × structured
//! column sparsity, times both the compiled path and the pre-compilation
//! bool-mask reference path, and emits `BENCH_engine.json` at the repo
//! root so the perf trajectory is tracked across PRs (EXPERIMENTS.md
//! §Perf).

use crate::bench::common::repo_root_file;
use crate::bench::timing::bench;
use crate::config::AcceleratorConfig;
use crate::coordinator::{EngineOptions, PhotonicEngine};
use crate::nn::MatmulEngine;
use crate::sparsity::{ChunkMask, LayerMask};
use crate::util::{Json, Table, XorShiftRng};
use std::collections::BTreeMap;
use std::time::Duration;

/// Bench problem: a 256×256 layer streaming 64 activation columns
/// (4 chunks on the default 64×64 grid — enough to exercise multi-chunk
/// accumulation and the work-item partitioner).
const OUT: usize = 256;
const IN: usize = 256;
const N_COLS: usize = 64;

/// The swept structured column sparsities (fraction of pruned columns).
pub const SPARSITIES: [f64; 3] = [0.0, 0.5, 0.875];

/// Structured column mask at `sparsity` pruned columns: within every
/// k2-segment the first `k2·(1−s)` columns stay active (the paper's
/// per-segment uniform pattern, §3.3.5), rows stay dense.
fn column_mask(
    p: usize,
    q: usize,
    rows: usize,
    cols: usize,
    k2: usize,
    sparsity: f64,
) -> LayerMask {
    let keep = ((k2 as f64 * (1.0 - sparsity)).round() as usize).clamp(0, k2);
    let col: Vec<bool> = (0..cols).map(|j| j % k2 < keep).collect();
    let chunk = ChunkMask::new(vec![true; rows], col);
    LayerMask { p, q, chunks: vec![chunk; p * q] }
}

fn bench_engine(sparsity: f64, threads: usize, reference: bool, budget: Duration) -> f64 {
    let cfg = AcceleratorConfig::default(); // FULL features: IG + OG + LR
    let (rows, cols) = cfg.chunk_shape();
    let k2 = cfg.k2;
    let mut eng = PhotonicEngine::new(cfg, EngineOptions::NOISY);
    eng.set_threads(threads);
    if sparsity > 0.0 {
        let mut masks = BTreeMap::new();
        masks.insert(
            "bench".to_string(),
            column_mask(OUT.div_ceil(rows), IN.div_ceil(cols), rows, cols, k2, sparsity),
        );
        eng.set_masks(masks);
    }
    let mut rng = XorShiftRng::new(3);
    let mut w = vec![0.0; OUT * IN];
    rng.fill_uniform(&mut w, -0.5, 0.5);
    let mut x = vec![0.0; IN * N_COLS];
    rng.fill_uniform(&mut x, 0.0, 1.0);
    // prime the programming cache so only streaming is timed
    let _ = eng.matmul("bench", &w, &x, OUT, IN, N_COLS);
    let label = format!(
        "layer_matmul {}x{}x{} {} s={:.3} t={}",
        OUT,
        IN,
        N_COLS,
        if reference { "ref " } else { "plan" },
        sparsity,
        threads
    );
    let r = bench(&label, budget, || {
        if reference {
            std::hint::black_box(eng.matmul_reference("bench", &w, &x, OUT, IN, N_COLS));
        } else {
            std::hint::black_box(eng.matmul("bench", &w, &x, OUT, IN, N_COLS));
        }
    });
    r.mean_ns
}

/// MAC/ns == GMAC/s for the fixed bench shape.
fn gmacs(mean_ns: f64) -> f64 {
    (OUT * IN * N_COLS) as f64 / mean_ns
}

fn record(results: &mut Vec<Json>, path: &str, t: usize, per_sparsity: &[(f64, f64)]) {
    for &(s, mean_ns) in per_sparsity {
        results.push(Json::obj(vec![
            ("path", Json::Str(path.into())),
            ("threads", Json::Num(t as f64)),
            ("sparsity", Json::Num(s)),
            ("mean_ns_per_call", Json::Num(mean_ns)),
            ("gmacs", Json::Num(gmacs(mean_ns))),
        ]));
    }
}

fn table_row(path: &str, t: usize, per_sparsity: &[(f64, f64)]) -> Vec<String> {
    let mut row = vec![path.to_string(), t.to_string()];
    row.extend(per_sparsity.iter().map(|&(_, ns)| format!("{:.2}", gmacs(ns))));
    row
}

/// Run the sweep, print the throughput table, write `BENCH_engine.json`,
/// and return the rendered table.
pub fn run(threads: &[usize], budget: Duration) -> String {
    let mut table = Table::new(
        "engine layer-matmul throughput (GMAC/s, noisy twin, IG+OG+LR column sparsity)",
    )
    .header(&["path", "threads", "s=0%", "s=50%", "s=87.5%"]);
    let mut results = Vec::new();

    // the seed path: single-thread scalar streaming with bool-mask
    // branching (pruned work is still paid for)
    let ref_cells: Vec<(f64, f64)> =
        SPARSITIES.iter().map(|&s| (s, bench_engine(s, 1, true, budget))).collect();
    record(&mut results, "reference", 1, &ref_cells);
    table.row(table_row("reference", 1, &ref_cells));

    let mut plan_4t_875 = None;
    for &t in threads {
        let cells: Vec<(f64, f64)> =
            SPARSITIES.iter().map(|&s| (s, bench_engine(s, t, false, budget))).collect();
        record(&mut results, "planned", t, &cells);
        if t == 4 {
            plan_4t_875 = cells.iter().find(|&&(s, _)| s > 0.8).map(|&(_, ns)| ns);
        }
        table.row(table_row("planned", t, &cells));
    }

    // headline acceptance ratio: planned @ 4 threads + 87.5% sparsity vs
    // the reference single-thread path at the same sparsity and dense
    let ref_875 = ref_cells.iter().find(|&&(s, _)| s > 0.8).map(|&(_, ns)| ns);
    let ref_dense = ref_cells.first().map(|&(_, ns)| ns);
    let mut extra = Vec::new();
    if let (Some(plan_ns), Some(ref_ns), Some(dense_ns)) = (plan_4t_875, ref_875, ref_dense) {
        extra.push(("speedup_4t_s875_vs_ref_s875", Json::Num(ref_ns / plan_ns)));
        extra.push(("speedup_4t_s875_vs_ref_dense", Json::Num(dense_ns / plan_ns)));
    }

    let mut pairs = vec![
        ("bench", Json::Str("engine_layer_matmul".into())),
        (
            "shape",
            Json::obj(vec![
                ("out", Json::Num(OUT as f64)),
                ("in", Json::Num(IN as f64)),
                ("n_cols", Json::Num(N_COLS as f64)),
            ]),
        ),
        ("results", Json::Arr(results)),
    ];
    pairs.extend(extra);
    let json = Json::obj(pairs);

    let path = repo_root_file("BENCH_engine.json");
    match std::fs::write(&path, json.to_string()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
    table.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn column_mask_hits_target_sparsity() {
        let lm = column_mask(2, 2, 64, 64, 16, 0.875);
        for chunk in &lm.chunks {
            assert_eq!(chunk.active_cols(), 8, "2 of 16 per segment × 4 segments");
            assert_eq!(chunk.active_rows(), 64);
        }
        let dense = column_mask(1, 1, 64, 64, 16, 0.0);
        assert_eq!(dense.chunks[0].active_cols(), 64);
    }
}

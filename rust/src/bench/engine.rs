//! End-to-end layer-matmul throughput bench for the sparsity-compiled
//! parallel execution engine: sweeps worker-thread counts × structured
//! column sparsity on the square 256×256 shape (compiled vs the
//! pre-compilation bool-mask reference path), plus the **tall-layer
//! sweep** (512×256, p = 8 chunk-rows) that isolates the shared
//! activation-panel cache: the two-pass cached path vs the PR1-style
//! single-pass uncached path, whose per-chunk-row re-gather redundancy
//! grows with p. Emits `BENCH_engine.json` at the repo root so the perf
//! trajectory is tracked across PRs (EXPERIMENTS.md §Perf); with
//! `--stages` it also reports the gather/kernel/scatter wall-time
//! breakdown of both paths plus the integer-quantized kernel's
//! simd-vs-scalar kernel-stage speedup (`speedup_simd_vs_scalar`,
//! floored by ci/check_bench.py; a `simd_sweep_skipped` stamp marks
//! hosts without a vector unit). Every artifact carries a `host` block
//! (CPU features + active kernel variant).

use crate::bench::common::{host_info, repo_root_file};
use crate::bench::timing::bench;
use crate::config::AcceleratorConfig;
use crate::coordinator::{EngineOptions, PhotonicEngine};
use crate::exec::{detected_simd, KernelPrecision, SimdLevel, StageBreakdown};
use crate::nn::MatmulEngine;
use crate::sparsity::{ChunkMask, LayerMask};
use crate::util::{Json, Table, XorShiftRng};
use std::collections::BTreeMap;
use std::time::Duration;

/// Square bench problem: a 256×256 layer streaming 64 activation columns
/// (4 chunks on the default 64×64 grid — enough to exercise multi-chunk
/// accumulation and the work-item partitioner).
const SQUARE: (usize, usize, usize) = (256, 256, 64);

/// Tall bench problem: 512×256×64 ⇒ p = 8 chunk-rows per chunk-column on
/// the 64×64 grid. The single-pass path gathers + quantizes every
/// activation panel 8 times (once per chunk-row); the cached path once.
const TALL: (usize, usize, usize) = (512, 256, 64);

/// Sparsity and thread count of the tall-layer headline cells.
const TALL_SPARSITY: f64 = 0.5;
const TALL_THREADS: usize = 4;

/// The swept structured column sparsities (fraction of pruned columns).
pub const SPARSITIES: [f64; 3] = [0.0, 0.5, 0.875];

/// Which execution path a cell times.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Path {
    /// Pre-compilation scalar streaming with bool-mask branching.
    Reference,
    /// PR1-style single-pass compiled path (per-item gather, `Vec` churn).
    Uncached,
    /// Two-pass shared-panel path (`MatmulEngine::matmul`).
    Cached,
}

impl Path {
    fn label(self) -> &'static str {
        match self {
            Path::Reference => "reference",
            Path::Uncached => "uncached",
            Path::Cached => "planned",
        }
    }
}

/// Structured column mask at `sparsity` pruned columns: within every
/// k2-segment the first `k2·(1−s)` columns stay active (the paper's
/// per-segment uniform pattern, §3.3.5), rows stay dense.
fn column_mask(
    p: usize,
    q: usize,
    rows: usize,
    cols: usize,
    k2: usize,
    sparsity: f64,
) -> LayerMask {
    let keep = ((k2 as f64 * (1.0 - sparsity)).round() as usize).clamp(0, k2);
    let col: Vec<bool> = (0..cols).map(|j| j % k2 < keep).collect();
    let chunk = ChunkMask::new(vec![true; rows], col);
    LayerMask { p, q, chunks: vec![chunk; p * q] }
}

/// Engine + problem for one cell, mask installed and programming primed
/// (so only streaming is timed).
fn setup(
    shape: (usize, usize, usize),
    sparsity: f64,
    threads: usize,
) -> (PhotonicEngine, Vec<f64>, Vec<f64>) {
    let (out, inp, n_cols) = shape;
    let cfg = AcceleratorConfig::default(); // FULL features: IG + OG + LR
    let (rows, cols) = cfg.chunk_shape();
    let k2 = cfg.k2;
    let mut eng = PhotonicEngine::new(cfg, EngineOptions::NOISY);
    eng.set_threads(threads);
    if sparsity > 0.0 {
        let mut masks = BTreeMap::new();
        masks.insert(
            "bench".to_string(),
            column_mask(out.div_ceil(rows), inp.div_ceil(cols), rows, cols, k2, sparsity),
        );
        eng.set_masks(masks);
    }
    let mut rng = XorShiftRng::new(3);
    let mut w = vec![0.0; out * inp];
    rng.fill_uniform(&mut w, -0.5, 0.5);
    let mut x = vec![0.0; inp * n_cols];
    rng.fill_uniform(&mut x, 0.0, 1.0);
    let _ = eng.matmul("bench", &w, &x, out, inp, n_cols);
    (eng, w, x)
}

fn bench_engine(
    shape: (usize, usize, usize),
    sparsity: f64,
    threads: usize,
    path: Path,
    budget: Duration,
) -> f64 {
    let (out, inp, n_cols) = shape;
    let (mut eng, w, x) = setup(shape, sparsity, threads);
    let label = format!(
        "layer_matmul {out}x{inp}x{n_cols} {:<9} s={sparsity:.3} t={threads}",
        path.label()
    );
    let r = bench(&label, budget, || {
        let y = match path {
            Path::Reference => eng.matmul_reference("bench", &w, &x, out, inp, n_cols),
            Path::Uncached => eng.matmul_uncached("bench", &w, &x, out, inp, n_cols),
            Path::Cached => eng.matmul("bench", &w, &x, out, inp, n_cols),
        };
        std::hint::black_box(y);
    });
    r.mean_ns
}

/// Gather/kernel/scatter breakdown of one path on the tall shape.
fn measure_stages(path: Path, iters: usize) -> StageBreakdown {
    let (out, inp, n_cols) = TALL;
    let (mut eng, w, x) = setup(TALL, TALL_SPARSITY, TALL_THREADS);
    eng.set_stage_timing(true);
    for _ in 0..iters {
        let y = match path {
            Path::Uncached => eng.matmul_uncached("bench", &w, &x, out, inp, n_cols),
            _ => eng.matmul("bench", &w, &x, out, inp, n_cols),
        };
        std::hint::black_box(y);
    }
    eng.take_stage_breakdown()
}

/// Kernel-stage breakdown of the *quantized* cached path on the tall
/// shape at a pinned SIMD level (`None` = runtime detection). The
/// simd-vs-scalar headline divides the kernel-stage times of two such
/// runs — the gather/scatter stages are identical work in both, so the
/// whole-call ratio would dilute the kernel speedup.
fn measure_quant_stages(level: Option<SimdLevel>, iters: usize) -> StageBreakdown {
    let (out, inp, n_cols) = TALL;
    let (mut eng, w, x) = setup(TALL, TALL_SPARSITY, TALL_THREADS);
    eng.set_precision(KernelPrecision::Quantized);
    eng.set_simd_override(level);
    eng.set_stage_timing(true);
    for _ in 0..iters {
        let y = eng.matmul("bench", &w, &x, out, inp, n_cols);
        std::hint::black_box(y);
    }
    eng.take_stage_breakdown()
}

/// MAC/ns == GMAC/s for a bench shape.
fn gmacs(shape: (usize, usize, usize), mean_ns: f64) -> f64 {
    (shape.0 * shape.1 * shape.2) as f64 / mean_ns
}

fn record(
    results: &mut Vec<Json>,
    shape: (usize, usize, usize),
    path: &str,
    t: usize,
    per_sparsity: &[(f64, f64)],
) {
    for &(s, mean_ns) in per_sparsity {
        results.push(Json::obj(vec![
            ("path", Json::Str(path.into())),
            ("threads", Json::Num(t as f64)),
            ("sparsity", Json::Num(s)),
            ("mean_ns_per_call", Json::Num(mean_ns)),
            ("gmacs", Json::Num(gmacs(shape, mean_ns))),
        ]));
    }
}

fn table_row(
    shape: (usize, usize, usize),
    path: &str,
    t: usize,
    per_sparsity: &[(f64, f64)],
) -> Vec<String> {
    let mut row = vec![path.to_string(), t.to_string()];
    row.extend(per_sparsity.iter().map(|&(_, ns)| format!("{:.2}", gmacs(shape, ns))));
    row
}

fn stages_json(b: &StageBreakdown) -> Json {
    let (g, k, s) = b.shares();
    Json::obj(vec![
        ("gather_share", Json::Num(g)),
        ("kernel_share", Json::Num(k)),
        ("scatter_share", Json::Num(s)),
        ("total_ns", Json::Num(b.total_ns() as f64)),
    ])
}

/// Run the sweeps, print the throughput (and optional stage-breakdown)
/// tables, write `BENCH_engine.json`, and return the rendered output.
pub fn run(threads: &[usize], budget: Duration, stages: bool) -> String {
    let mut table = Table::new(
        "engine layer-matmul throughput (GMAC/s, noisy twin, IG+OG+LR column sparsity)",
    )
    .header(&["path", "threads", "s=0%", "s=50%", "s=87.5%"]);
    let mut results = Vec::new();

    // the seed path: single-thread scalar streaming with bool-mask
    // branching (pruned work is still paid for)
    let ref_cells: Vec<(f64, f64)> = SPARSITIES
        .iter()
        .map(|&s| (s, bench_engine(SQUARE, s, 1, Path::Reference, budget)))
        .collect();
    record(&mut results, SQUARE, "reference", 1, &ref_cells);
    table.row(table_row(SQUARE, "reference", 1, &ref_cells));

    let mut plan_4t_875 = None;
    for &t in threads {
        let cells: Vec<(f64, f64)> = SPARSITIES
            .iter()
            .map(|&s| (s, bench_engine(SQUARE, s, t, Path::Cached, budget)))
            .collect();
        record(&mut results, SQUARE, "planned", t, &cells);
        if t == 4 {
            plan_4t_875 = cells.iter().find(|&&(s, _)| s > 0.8).map(|&(_, ns)| ns);
        }
        table.row(table_row(SQUARE, "planned", t, &cells));
    }

    // tall-layer sweep (p = 8): the shared-panel cache removes an O(p×)
    // gather/quantize redundancy, so cached-vs-uncached is the headline
    // ratio ci/check_bench.py floors at 1.3×
    let tall_hdr = format!("s={TALL_SPARSITY}");
    let mut tall_table = Table::new(&format!(
        "tall-layer sweep {}x{}x{} (p=8, s={TALL_SPARSITY}): shared-panel cache vs \
         PR1-style single-pass",
        TALL.0, TALL.1, TALL.2
    ))
    .header(&["path", "threads", tall_hdr.as_str()]);
    let mut tall_ratio = None;
    let mut tall = |path: Path, t: usize, results: &mut Vec<Json>| {
        let ns = bench_engine(TALL, TALL_SPARSITY, t, path, budget);
        // tall row names parallel the `stages` block's "cached" /
        // "uncached" naming (with a `_tall` suffix), not the square
        // sweep's legacy "planned" label
        let name = if path == Path::Uncached { "uncached_tall" } else { "cached_tall" };
        record(results, TALL, name, t, &[(TALL_SPARSITY, ns)]);
        tall_table.row(vec![
            name.to_string(),
            t.to_string(),
            format!("{:.2}", gmacs(TALL, ns)),
        ]);
        ns
    };
    let _ = tall(Path::Uncached, 1, &mut results);
    let _ = tall(Path::Cached, 1, &mut results);
    let un_4t = tall(Path::Uncached, TALL_THREADS, &mut results);
    let ca_4t = tall(Path::Cached, TALL_THREADS, &mut results);
    if ca_4t > 0.0 {
        tall_ratio = Some(un_4t / ca_4t);
    }

    // headline acceptance ratios: planned @ 4 threads + 87.5% sparsity vs
    // the reference single-thread path (same sparsity / dense), and the
    // tall cached-vs-uncached panel-cache speedup
    let ref_875 = ref_cells.iter().find(|&&(s, _)| s > 0.8).map(|&(_, ns)| ns);
    let ref_dense = ref_cells.first().map(|&(_, ns)| ns);
    let mut extra = Vec::new();
    if let (Some(plan_ns), Some(ref_ns), Some(dense_ns)) = (plan_4t_875, ref_875, ref_dense)
    {
        extra.push(("speedup_4t_s875_vs_ref_s875", Json::Num(ref_ns / plan_ns)));
        extra.push(("speedup_4t_s875_vs_ref_dense", Json::Num(dense_ns / plan_ns)));
    }
    if let Some(ratio) = tall_ratio {
        extra.push(("speedup_cached_vs_uncached_tall", Json::Num(ratio)));
    }

    let mut out = table.render();
    out.push('\n');
    out.push_str(&tall_table.render());
    if let Some(ratio) = tall_ratio {
        out.push_str(&format!(
            "\ntall-layer panel-cache speedup (cached vs uncached, {TALL_THREADS}t): \
             {ratio:.2}x\n"
        ));
    }

    let mut pairs = vec![
        ("bench", Json::Str("engine_layer_matmul".into())),
        ("host", host_info()),
        (
            "shape",
            Json::obj(vec![
                ("out", Json::Num(SQUARE.0 as f64)),
                ("in", Json::Num(SQUARE.1 as f64)),
                ("n_cols", Json::Num(SQUARE.2 as f64)),
            ]),
        ),
        (
            "tall_shape",
            Json::obj(vec![
                ("out", Json::Num(TALL.0 as f64)),
                ("in", Json::Num(TALL.1 as f64)),
                ("n_cols", Json::Num(TALL.2 as f64)),
            ]),
        ),
        ("results", Json::Arr(results)),
    ];
    pairs.extend(extra);

    if stages {
        // enough iterations to smooth scheduler noise, few enough to
        // stay inside the smoke budget
        let iters = 10;
        let cached = measure_stages(Path::Cached, iters);
        let uncached = measure_stages(Path::Uncached, iters);
        pairs.push((
            "stages",
            Json::obj(vec![
                ("cached", stages_json(&cached)),
                ("uncached", stages_json(&uncached)),
            ]),
        ));
        let mut st = Table::new(&format!(
            "per-stage wall-time shares, tall shape @ {TALL_THREADS}t (n={iters})"
        ))
        .header(&["path", "gather/quantize", "kernel", "scatter"]);
        for (name, b) in [("cached", &cached), ("uncached", &uncached)] {
            let (g, k, s) = b.shares();
            st.row(vec![
                name.to_string(),
                format!("{:.1}%", g * 100.0),
                format!("{:.1}%", k * 100.0),
                format!("{:.1}%", s * 100.0),
            ]);
        }
        out.push('\n');
        out.push_str(&st.render());

        // simd-vs-scalar cell: the integer-quantized kernel's vectorized
        // sweep against its own forced-scalar oracle on the same tall
        // shape, isolated to the kernel stage (ci/check_bench.py floors
        // this at >=2.0x when the baseline arms it)
        let simd = detected_simd();
        if simd == SimdLevel::Scalar {
            let reason = if std::env::var("SCATTER_FORCE_SCALAR").is_ok() {
                "SCATTER_FORCE_SCALAR set (vector path disabled)"
            } else {
                "no AVX2 on this host (scalar quantized kernel only)"
            };
            pairs.push(("simd_sweep_skipped", Json::Str(reason.into())));
            out.push_str(&format!("\nsimd-vs-scalar sweep skipped: {reason}\n"));
        } else {
            let vec_b = measure_quant_stages(None, iters);
            let sc_b = measure_quant_stages(Some(SimdLevel::Scalar), iters);
            let ratio = sc_b.kernel_ns as f64 / vec_b.kernel_ns.max(1) as f64;
            pairs.push(("speedup_simd_vs_scalar", Json::Num(ratio)));
            pairs.push((
                "simd",
                Json::obj(vec![
                    ("variant", Json::Str(simd.as_str().into())),
                    ("lanes", Json::Num(simd.lanes() as f64)),
                    ("kernel_ns_simd", Json::Num(vec_b.kernel_ns as f64)),
                    ("kernel_ns_scalar", Json::Num(sc_b.kernel_ns as f64)),
                ]),
            ));
            out.push_str(&format!(
                "\nquantized kernel, tall shape @ {TALL_THREADS}t: {} variant, \
                 {}-row lanes — kernel-stage simd-vs-scalar speedup {ratio:.2}x\n",
                simd.as_str(),
                simd.lanes(),
            ));
        }
    } else if detected_simd() == SimdLevel::Scalar {
        // no --stages and no vector unit: stamp the skip so the armed CI
        // floor reads as deliberately not evaluated, not as missing data
        pairs.push((
            "simd_sweep_skipped",
            Json::Str("no AVX2 on this host (scalar quantized kernel only)".into()),
        ));
    } else {
        pairs.push((
            "simd_sweep_skipped",
            Json::Str("stage breakdown disabled (run with --stages)".into()),
        ));
    }

    let json = Json::obj(pairs);
    let path = repo_root_file("BENCH_engine.json");
    match std::fs::write(&path, json.to_string()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn column_mask_hits_target_sparsity() {
        let lm = column_mask(2, 2, 64, 64, 16, 0.875);
        for chunk in &lm.chunks {
            assert_eq!(chunk.active_cols(), 8, "2 of 16 per segment × 4 segments");
            assert_eq!(chunk.active_rows(), 64);
        }
        let dense = column_mask(1, 1, 64, 64, 16, 0.0);
        assert_eq!(dense.chunks[0].active_cols(), 64);
    }

    #[test]
    fn quant_stage_breakdown_measures_kernel_at_any_level() {
        for level in [Some(SimdLevel::Scalar), None] {
            let b = measure_quant_stages(level, 1);
            assert!(b.kernel_ns > 0, "quantized kernel stage untimed at {level:?}");
        }
    }

    #[test]
    fn stage_breakdown_measures_all_three_stages() {
        for path in [Path::Cached, Path::Uncached] {
            let b = measure_stages(path, 1);
            assert!(b.gather_ns > 0, "gather stage untimed");
            assert!(b.kernel_ns > 0, "kernel stage untimed");
            assert!(b.scatter_ns > 0, "scatter stage untimed");
            let (g, k, s) = b.shares();
            assert!((g + k + s - 1.0).abs() < 1e-9, "shares must sum to 1");
        }
    }
}

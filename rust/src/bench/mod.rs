//! Benchmark harness: one module per paper table/figure (§4).
//!
//! Every harness prints the same rows/series the paper reports, through
//! `util::Table`, and returns the table so tests can assert on trends.
//! Absolute numbers depend on the simulated substrate; the *shape* (who
//! wins, by what factor, where crossovers fall) is the reproduction target
//! and is what the assertions in `rust/tests/reproduction.rs` pin down.

pub mod chaos;
pub mod common;
pub mod drift;
pub mod engine;
pub mod repair;
pub mod serve;
pub mod swap;
pub mod timing;

pub mod fig10;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig8;
pub mod fig9;
pub mod table1;
pub mod table2;
pub mod table3;

pub use common::BenchCtx;

/// Run every harness in paper order.
pub fn run_all(ctx: &BenchCtx) {
    println!("{}", fig4::run(ctx));
    println!("{}", fig5::run(ctx));
    println!("{}", table1::run(ctx));
    println!("{}", fig6::run(ctx));
    println!("{}", table2::run(ctx));
    println!("{}", fig8::run(ctx));
    println!("{}", fig9::run_a(ctx));
    println!("{}", fig9::run_b(ctx));
    println!("{}", fig10::run(ctx));
    println!("{}", table3::run(ctx));
}

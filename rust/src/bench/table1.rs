//! Table 1: optimal device spacing on a dense network (s = 1).
//!
//! Sweep l_s ∈ {7..11} µm at l_g = 5 µm; report accuracy under crosstalk
//! and noises, average power, area, and power-area product. The paper's
//! winner is l_s = 9 µm (minimum PAP at <1 % accuracy drop).

use super::common::{BenchCtx, Workload};
use crate::area::AreaModel;
use crate::config::{AcceleratorConfig, DacKind, SparsitySupport};
use crate::coordinator::EngineOptions;
use crate::power::energy::pap;
use crate::util::Table;

pub fn run(ctx: &BenchCtx) -> Table {
    let mut table = Table::new(
        "Table 1 — optimal device spacing, dense CNN (l_g = 5 um, s = 1)",
    )
    .header(&["l_s (um)", "l_g (um)", "Acc (%)", "P_avg (W)", "A (mm^2)", "PAP"]);

    let (model, ds) = ctx.fitted(Workload::Cnn3);
    for ls in [7.0, 8.0, 9.0, 10.0, 11.0] {
        let cfg = AcceleratorConfig {
            share_r: 1,
            share_c: 1,
            l_s: ls,
            l_g: 5.0,
            dac: DacKind::Edac,
            features: SparsitySupport::NONE,
            ..Default::default()
        };
        let n = ctx.eval_budget(Workload::Cnn3);
        let (acc, engine) =
            ctx.accuracy(&model, &ds, &cfg, EngineOptions::NOISY, Default::default(), n);
        let p_avg = engine.p_avg_w();
        let area = AreaModel::with_defaults(cfg).total_mm2();
        table.row(vec![
            format!("{ls:.0}"),
            "5".into(),
            format!("{:.2}", acc * 100.0),
            format!("{p_avg:.2}"),
            format!("{area:.2}"),
            format!("{:.1}", pap(p_avg, area)),
        ]);
    }
    table
}

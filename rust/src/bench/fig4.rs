//! Fig. 4 — thermal characterization:
//! (b) γ(d) from the heat-solver pipeline vs the paper's published fit;
//! (c) MZI power P(|Δφ|, l_s);
//! (d) N-MAE on phases/weights vs MZI pitch l_h;
//! (e) area / power / worst-case crosstalk vs spacing.

use super::common::BenchCtx;
use crate::area::AreaModel;
use crate::config::AcceleratorConfig;
use crate::devices::{Mzi, MziSpec};
use crate::thermal::heatsim::{characterize, HeatSimConfig};
use crate::thermal::{coupling::ArrayGeometry, CouplingModel, GammaModel};
use crate::util::{nmae, Table, XorShiftRng};

pub fn run(_ctx: &BenchCtx) -> Table {
    let mut table = Table::new("Fig. 4 — thermal crosstalk characterization").header(&[
        "series", "x", "value", "note",
    ]);

    // (b) γ(d): paper fit and our heat-solver refit
    let paper = GammaModel::paper();
    let (_samples, refit) = characterize(&HeatSimConfig::default(), 23.0);
    for d in [1.0f64, 3.0, 5.0, 9.0, 15.0, 23.0, 30.0, 40.0] {
        table.row(vec![
            "gamma(d) paper".into(),
            format!("{d:.0}"),
            format!("{:.4}", paper.eval(d)),
            "Eq. 10 published fit".into(),
        ]);
        table.row(vec![
            "gamma(d) heatsim".into(),
            format!("{d:.0}"),
            format!("{:.4}", refit.eval(d)),
            "2-D FEM substitute refit".into(),
        ]);
    }

    // (c) MZI power vs arm spacing at |Δφ| = π/2
    for ls in [5.0f64, 7.0, 9.0, 11.0, 15.0, 20.0] {
        let mzi = Mzi::new(MziSpec::low_power(), ls, &paper);
        table.row(vec![
            "P_MZI(pi/2, l_s) mW".into(),
            format!("{ls:.0}"),
            format!("{:.3}", mzi.power_mw(std::f64::consts::FRAC_PI_2)),
            "intra-MZI penalty 1/(1-gamma)".into(),
        ]);
    }

    // (d) N-MAE on realized weights vs pitch l_h for a 16x16 array
    let mut rng = XorShiftRng::new(42);
    let mut w = vec![0.0; 256];
    rng.fill_uniform(&mut w, -1.0, 1.0);
    for lh in [16.0f64, 20.0, 25.0, 30.0, 40.0] {
        let geom = ArrayGeometry { rows: 16, cols: 16, l_v: 120.0, l_h: lh, l_s: 9.0 };
        let cm = CouplingModel::new(geom, &paper);
        // program the phases, perturb, read back weights
        let mut phases = vec![0.0; 256];
        for j in 0..16 {
            for i in 0..16 {
                phases[j * 16 + i] = Mzi::phase_from_weight(w[i * 16 + j]);
            }
        }
        let pert = cm.perturbed(&phases);
        // map back: w̃[i][j] = -sin(φ̃[j*16+i])
        let mut w_tilde = vec![0.0; 256];
        for j in 0..16 {
            for i in 0..16 {
                w_tilde[i * 16 + j] = Mzi::weight_from_phase(pert[j * 16 + i]);
            }
        }
        table.row(vec![
            "weight N-MAE vs l_h".into(),
            format!("{lh:.0}"),
            format!("{:.4}", nmae(&w_tilde, &w)),
            "16x16 array, l_s=9".into(),
        ]);
    }

    // (e) area/power/crosstalk vs l_g for the full accelerator
    for lg in [1.0f64, 3.0, 5.0, 10.0, 20.0] {
        let cfg = AcceleratorConfig { l_g: lg, ..Default::default() };
        let area = AreaModel::with_defaults(cfg.clone()).total_mm2();
        let geom = ArrayGeometry::from_config(&cfg);
        let worst = CouplingModel::new(geom, &paper).worst_case_coupling();
        table.row(vec![
            "area mm^2 / worst gamma".into(),
            format!("{lg:.0}"),
            format!("{area:.2} / {worst:.4}"),
            "Eq. 7 area, Eq. 8 coupling".into(),
        ]);
    }
    table
}

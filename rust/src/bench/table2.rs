//! Table 2: architecture sharing factor (r, c) × sparsity — average power
//! and accuracy on CNN-FMNIST. The paper's winner is r = c = 4.

use super::common::{BenchCtx, Workload};
use crate::config::{AcceleratorConfig, DacKind, SparsitySupport};
use crate::coordinator::EngineOptions;
use crate::util::Table;

pub fn run(ctx: &BenchCtx) -> Table {
    let mut table = Table::new("Table 2 — sharing factor (r, c) x sparsity, CNN-FMNIST*")
        .header(&[
            "r", "c", "P@s=0.8 (W)", "Acc@0.8 (%)", "P@s=0.6 (W)", "Acc@0.6 (%)",
            "P@s=0.4 (W)", "Acc@0.4 (%)",
        ]);

    let n = ctx.eval_budget(Workload::Cnn3);
    for share in [1usize, 2, 4] {
        let mut cells = vec![share.to_string(), share.to_string()];
        for density in [0.8, 0.6, 0.4] {
            let cfg = AcceleratorConfig {
                share_r: share,
                share_c: share,
                l_g: 5.0,
                dac: DacKind::Edac,
                features: SparsitySupport::FULL,
                ..Default::default()
            };
            let (model, ds, masks) = ctx.deployment(Workload::Cnn3, &cfg, density);
            let (acc, engine) =
                ctx.accuracy(&model, &ds, &cfg, EngineOptions::NOISY, masks, n);
            cells.push(format!("{:.2}", engine.p_avg_w()));
            cells.push(format!("{:.2}", acc * 100.0));
        }
        table.row(cells);
    }
    table
}

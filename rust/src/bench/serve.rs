//! Closed/open-loop load generator for the networked inference service.
//!
//! Drives `POST /v1/predict` over real TCP — by default against an
//! in-process [`HttpServer`] on an ephemeral port (so CI measures the
//! full wire path with zero setup), or against `--addr host:port` for an
//! externally launched `scatter serve`. Emits `BENCH_server.json` at the
//! repo root (throughput, client p50/p99, shed rate, J/inference) so the
//! serving-perf trajectory is tracked across PRs next to
//! `BENCH_engine.json` (EXPERIMENTS.md §Serving).
//!
//! Two drive modes:
//!
//! * **closed loop** (`rps == 0`): `concurrency` keep-alive clients fire
//!   back-to-back — measures capacity;
//! * **open loop** (`rps > 0`): clients fire on a fixed schedule
//!   regardless of completions — measures behavior at a target arrival
//!   rate, where admission control (shed rate) becomes visible.
//!
//! After the main run (in-process targets only), a **`--max-batch`
//! sweep** measures what batched *compute* buys: for each B it stands up
//! a one-worker server over the MLP readout workload
//! ([`crate::nn::models::mlp`] — every matmul carries one activation
//! column per image, the worst case per-sample dispatch and exactly the
//! serving shape ENLighten batches for) and records closed-loop
//! per-image throughput. `per_image_throughput_b1` vs
//! `per_image_throughput_b8` lands in `BENCH_server.json`, where
//! `ci/check_bench.py` arms the machine-independent `b8/b1 ≥ 1.3` floor:
//! at B=8 each linear layer runs ONE `n_cols = 8` matmul instead of 8
//! matvec dispatches, so the register-blocked kernel amortizes its
//! per-run setup over 8 columns and the per-call overheads (programming
//! lookups, panel prep, pool fan-out, output alloc, energy recording)
//! are paid once per batch.
//!
//! A second **`--replicas` sweep** measures what the cluster scheduler
//! buys: for each R it stands up an R-replica server (`max_batch 1`,
//! one engine thread per replica, so every request is one shard and
//! the only lever is routing across replicas) over the same MLP
//! workload and records closed-loop per-image throughput.
//! `replica_speedup_4_over_1` lands next to the batch ratio as the
//! machine-independent replica-scaling floor (R=4 runs four engine
//! passes on four OS threads concurrently, so the ratio clears 2.0
//! even on modest CI runners).

use crate::bench::common::{host_info, repo_root_file, BenchCtx, Workload};
use crate::config::AcceleratorConfig;
use crate::coordinator::metrics::LatencyRecorder;
use crate::coordinator::net::{resolve_addr, HttpClient, HttpServer, NetConfig};
use crate::coordinator::{EngineOptions, InferenceServer, ServerConfig, ServerReport};
use crate::util::{Json, Table};
use std::net::SocketAddr;
use std::time::{Duration, Instant};

/// Load-generator configuration (`scatter bench serve`).
#[derive(Debug, Clone)]
pub struct ServeBenchConfig {
    /// Target arrival rate; 0 switches to closed-loop mode.
    pub rps: f64,
    pub duration: Duration,
    /// Concurrent keep-alive client connections.
    pub concurrency: usize,
    /// Drive an already-running server instead of spawning in-process.
    pub addr: Option<String>,
    /// Engine-worker replicas for the in-process main run (ignored
    /// with `addr`).
    pub workers: usize,
    /// Enable work stealing on every in-process server stood up here.
    pub steal: bool,
    /// Backbone density for the in-process deployment.
    pub density: f64,
    /// `--max-batch` sweep points for the batched-compute comparison
    /// (skipped with `addr`: a remote server's batching cannot be
    /// reconfigured from here). Each point serves the MLP readout
    /// workload closed-loop on one engine worker and emits
    /// `per_image_throughput_b<N>`.
    pub sweep_max_batch: Vec<usize>,
    /// `--replicas` sweep points for the replica-scaling comparison
    /// (same skip rule). Each point serves the MLP workload
    /// closed-loop at `max_batch 1` across N replicas and emits the
    /// `replicas` block plus `replica_speedup_4_over_1`.
    pub sweep_replicas: Vec<usize>,
}

impl Default for ServeBenchConfig {
    fn default() -> Self {
        Self {
            rps: 0.0,
            duration: Duration::from_secs(2),
            concurrency: 4,
            addr: None,
            workers: 2,
            steal: false,
            density: 0.3,
            sweep_max_batch: vec![1, 8],
            sweep_replicas: vec![1, 4],
        }
    }
}

#[derive(Debug, Default, Clone)]
struct ClientTally {
    ok_latencies_us: Vec<u64>,
    shed: u64,
    expired: u64,
    errors: u64,
}

/// One client connection's send loop.
fn drive_client(
    addr: SocketAddr,
    bodies: &[String],
    mode_interval: Option<Duration>,
    deadline: Instant,
    seed: usize,
) -> ClientTally {
    let mut tally = ClientTally::default();
    let mut client = match HttpClient::connect(&addr) {
        Ok(c) => c,
        Err(_) => {
            tally.errors += 1;
            return tally;
        }
    };
    let mut next_send = Instant::now();
    let mut i = seed;
    while Instant::now() < deadline {
        if let Some(interval) = mode_interval {
            let now = Instant::now();
            if next_send > now {
                std::thread::sleep(next_send - now);
            }
            next_send += interval;
        }
        let body = &bodies[i % bodies.len()];
        i += 1;
        let t0 = Instant::now();
        match client.request("POST", "/v1/predict", Some(body)) {
            Ok(resp) => match resp.status {
                200 => tally.ok_latencies_us.push(t0.elapsed().as_micros() as u64),
                503 => tally.shed += 1,
                504 => tally.expired += 1,
                _ => tally.errors += 1,
            },
            Err(_) => {
                tally.errors += 1;
                // the server may have closed the connection; reconnect
                match HttpClient::connect(&addr) {
                    Ok(c) => client = c,
                    Err(_) => return tally,
                }
            }
        }
    }
    tally
}

/// Pre-rendered request bodies (serialization stays off the timed path).
fn render_bodies(n: usize) -> Vec<String> {
    let ds = crate::data::SyntheticDataset::new(crate::data::DatasetSpec::fmnist_like());
    (0..n)
        .map(|i| {
            let (img, _) = ds.sample(0xBE7, i);
            Json::obj(vec![("image", Json::arr_f64(&img.data))]).to_string()
        })
        .collect()
}

/// Fan `concurrency` keep-alive clients at `addr` until `duration`
/// elapses; returns per-client tallies and the measured wall seconds.
fn drive_load(
    addr: SocketAddr,
    bodies: &[String],
    interval: Option<Duration>,
    duration: Duration,
    concurrency: usize,
) -> (Vec<ClientTally>, f64) {
    let started = Instant::now();
    let deadline = started + duration;
    let tallies: Vec<ClientTally> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..concurrency.max(1))
            .map(|c| s.spawn(move || drive_client(addr, bodies, interval, deadline, c * 7919)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });
    (tallies, started.elapsed().as_secs_f64().max(1e-9))
}

/// One `--max-batch` sweep point measurement.
struct SweepPoint {
    max_batch: usize,
    ok: u64,
    errors: u64,
    wall_s: f64,
    per_image_rps: f64,
    mean_occupancy: f64,
}

/// Closed-loop per-image throughput of the MLP readout workload at one
/// `max_batch`. One engine worker so the comparison isolates batched
/// *compute* (every linear layer: one `n_cols = B` matmul vs B matvec
/// dispatches), not router parallelism; client concurrency is held at
/// `≥ 2·max_batch` so full batches can actually form.
fn sweep_point(max_batch: usize, cfg: &ServeBenchConfig, bodies: &[String]) -> SweepPoint {
    let acc = AcceleratorConfig::default();
    let model = crate::nn::models::mlp();
    let masks = crate::bench::common::build_masks(&model, &acc, cfg.density);
    let server = InferenceServer::spawn(
        model,
        acc,
        EngineOptions::NOISY,
        masks,
        ServerConfig::builder()
            .max_batch(max_batch)
            .batch_timeout(Duration::from_millis(2))
            .workers(1)
            .engine_threads(1)
            .steal(cfg.steal)
            .build()
            .expect("sweep server config validates"),
    );
    let http = HttpServer::bind(server, NetConfig::default()).expect("bind ephemeral");
    let concurrency = cfg.concurrency.max(2 * max_batch).max(1);
    let (tallies, wall_s) =
        drive_load(http.local_addr(), bodies, None, cfg.duration, concurrency);
    let report = http.shutdown().expect("drain sweep server");
    let ok: u64 = tallies.iter().map(|t| t.ok_latencies_us.len() as u64).sum();
    let errors: u64 = tallies.iter().map(|t| t.errors).sum();
    SweepPoint {
        max_batch,
        ok,
        errors,
        wall_s,
        per_image_rps: ok as f64 / wall_s,
        mean_occupancy: report.mean_batch_occupancy,
    }
}

/// One `--replicas` sweep point measurement.
struct ReplicaPoint {
    replicas: usize,
    ok: u64,
    errors: u64,
    wall_s: f64,
    per_image_rps: f64,
    /// Batches routed to each replica slot (from the cluster router).
    routed: Vec<u64>,
    steals: u64,
}

/// Closed-loop per-image throughput of the MLP workload across
/// `replicas` engine workers. `max_batch 1` + one engine thread per
/// replica make every request its own shard, so throughput scales only
/// through the cluster router spreading shards across replicas — the
/// quantity `replica_speedup_4_over_1` gates. Client concurrency is
/// held at `≥ 2·replicas` so every replica can be kept busy.
fn replica_point(replicas: usize, cfg: &ServeBenchConfig, bodies: &[String]) -> ReplicaPoint {
    let acc = AcceleratorConfig::default();
    let model = crate::nn::models::mlp();
    let masks = crate::bench::common::build_masks(&model, &acc, cfg.density);
    let server = InferenceServer::spawn(
        model,
        acc,
        EngineOptions::NOISY,
        masks,
        ServerConfig::builder()
            .max_batch(1)
            .batch_timeout(Duration::from_millis(1))
            .workers(replicas)
            .engine_threads(1)
            .steal(cfg.steal)
            .build()
            .expect("replica sweep config validates"),
    );
    let http = HttpServer::bind(server, NetConfig::default()).expect("bind ephemeral");
    let concurrency = cfg.concurrency.max(2 * replicas).max(1);
    let (tallies, wall_s) =
        drive_load(http.local_addr(), bodies, None, cfg.duration, concurrency);
    let report = http.shutdown().expect("drain replica sweep server");
    let ok: u64 = tallies.iter().map(|t| t.ok_latencies_us.len() as u64).sum();
    let errors: u64 = tallies.iter().map(|t| t.errors).sum();
    ReplicaPoint {
        replicas,
        ok,
        errors,
        wall_s,
        per_image_rps: ok as f64 / wall_s,
        routed: report.routed,
        steals: report.steals,
    }
}

/// Per-image-throughput ratio between the replica sweep points at
/// `num` and `den` replicas.
fn replica_speedup(sweep: &[ReplicaPoint], num: usize, den: usize) -> Option<f64> {
    let n = sweep.iter().find(|p| p.replicas == num)?;
    let d = sweep.iter().find(|p| p.replicas == den)?;
    (d.per_image_rps > 0.0).then(|| n.per_image_rps / d.per_image_rps)
}

/// Per-image-throughput ratio between the sweep points at `num` and
/// `den` max-batch (None unless both ran and the denominator measured
/// something).
fn batch_speedup(sweep: &[SweepPoint], num: usize, den: usize) -> Option<f64> {
    let n = sweep.iter().find(|p| p.max_batch == num)?;
    let d = sweep.iter().find(|p| p.max_batch == den)?;
    (d.per_image_rps > 0.0).then(|| n.per_image_rps / d.per_image_rps)
}

/// Run the load test, print the summary table, write
/// `BENCH_server.json`, and return the rendered table.
pub fn run(cfg: &ServeBenchConfig) -> String {
    // stand up the target (in-process unless --addr points elsewhere)
    let (addr, http) = match &cfg.addr {
        Some(a) => (resolve_addr(a).expect("--addr resolves"), None),
        None => {
            let ctx = BenchCtx::new(50);
            let acc = AcceleratorConfig::default();
            let (model, _ds, masks) = ctx.deployment(Workload::Cnn3, &acc, cfg.density);
            let server = InferenceServer::spawn(
                model,
                acc,
                EngineOptions::NOISY,
                masks,
                ServerConfig::builder()
                    .workers(cfg.workers)
                    .batch_timeout(Duration::from_millis(4))
                    .steal(cfg.steal)
                    .build()
                    .expect("bench serve config validates"),
            );
            let http = HttpServer::bind(server, NetConfig::default()).expect("bind ephemeral");
            (http.local_addr(), Some(http))
        }
    };

    let bodies = render_bodies(16);
    let interval = if cfg.rps > 0.0 {
        Some(Duration::from_secs_f64(cfg.concurrency.max(1) as f64 / cfg.rps))
    } else {
        None
    };
    let (tallies, wall_s) =
        drive_load(addr, &bodies, interval, cfg.duration, cfg.concurrency);

    // graceful drain of the in-process server (also the energy source)
    let report: Option<ServerReport> =
        http.map(|h| h.shutdown().expect("drain in-process server"));

    // ---- batched-compute sweep (in-process targets only) ----
    let sweep: Vec<SweepPoint> = if cfg.addr.is_none() {
        cfg.sweep_max_batch.iter().map(|&b| sweep_point(b, cfg, &bodies)).collect()
    } else {
        if !cfg.sweep_max_batch.is_empty() {
            eprintln!("note: --max-batch sweep skipped (remote --addr target)");
        }
        Vec::new()
    };

    // ---- replica-scaling sweep (in-process targets only) ----
    let rsweep: Vec<ReplicaPoint> = if cfg.addr.is_none() {
        cfg.sweep_replicas.iter().map(|&r| replica_point(r, cfg, &bodies)).collect()
    } else {
        if !cfg.sweep_replicas.is_empty() {
            eprintln!("note: --replicas sweep skipped (remote --addr target)");
        }
        Vec::new()
    };

    // merge client tallies
    let mut lat = LatencyRecorder::new();
    let (mut ok, mut shed, mut expired, mut errors) = (0u64, 0u64, 0u64, 0u64);
    for t in &tallies {
        ok += t.ok_latencies_us.len() as u64;
        shed += t.shed;
        expired += t.expired;
        errors += t.errors;
        for &us in &t.ok_latencies_us {
            lat.record(Duration::from_micros(us));
        }
    }
    let total = ok + shed + expired + errors;
    let throughput = ok as f64 / wall_s;
    let shed_rate = if total > 0 { shed as f64 / total as f64 } else { 0.0 };
    let j_per_inference = report.as_ref().and_then(|r| {
        if r.requests > 0 {
            Some(r.energy_mj * 1e-3 / r.requests as f64)
        } else {
            None
        }
    });

    let mode = if cfg.rps > 0.0 { "open" } else { "closed" };
    let mut table = Table::new("networked serving load test (POST /v1/predict over TCP)")
        .header(&["metric", "value"]);
    table.row(vec!["mode".into(), format!("{mode}-loop x{}", cfg.concurrency.max(1))]);
    table.row(vec!["duration".into(), format!("{:.2} s", wall_s)]);
    table.row(vec![
        "ok / shed / expired / errors".into(),
        format!("{ok} / {shed} / {expired} / {errors}"),
    ]);
    table.row(vec!["throughput".into(), format!("{throughput:.1} req/s")]);
    table.row(vec!["client p50".into(), format!("{} us", lat.percentile_us(50.0))]);
    table.row(vec!["client p99".into(), format!("{} us", lat.percentile_us(99.0))]);
    table.row(vec!["shed rate".into(), format!("{:.1} %", 100.0 * shed_rate)]);
    if let Some(r) = &report {
        table.row(vec!["server p50/p99".into(), format!("{}/{} us", r.p50_us, r.p99_us)]);
        table.row(vec!["mean batch occupancy".into(), format!("{:.2}", r.mean_batch_occupancy)]);
        table.row(vec!["accelerator energy".into(), format!("{:.3} mJ", r.energy_mj)]);
        if let Some(j) = j_per_inference {
            table.row(vec!["energy/inference".into(), format!("{:.3} mJ", j * 1e3)]);
        }
    }
    for pt in &sweep {
        table.row(vec![
            format!("mlp per-image tput @B={}", pt.max_batch),
            format!(
                "{:.1} img/s (occupancy {:.2}, {} ok)",
                pt.per_image_rps, pt.mean_occupancy, pt.ok
            ),
        ]);
    }
    let speedup = batch_speedup(&sweep, 8, 1);
    if let Some(s) = speedup {
        table.row(vec!["batched-compute speedup b8/b1".into(), format!("{s:.2}x")]);
    }
    for pt in &rsweep {
        let routed: Vec<String> = pt.routed.iter().map(|r| r.to_string()).collect();
        table.row(vec![
            format!("mlp per-image tput @R={}", pt.replicas),
            format!(
                "{:.1} img/s (routed [{}], {} steals, {} ok)",
                pt.per_image_rps,
                routed.join(" "),
                pt.steals,
                pt.ok
            ),
        ]);
    }
    let rspeedup = replica_speedup(&rsweep, 4, 1);
    if let Some(s) = rspeedup {
        table.row(vec!["replica-scaling speedup r4/r1".into(), format!("{s:.2}x")]);
    }

    let mut pairs = vec![
        ("bench", Json::Str("serve".into())),
        ("host", host_info()),
        ("mode", Json::Str(mode.into())),
        ("rps_target", Json::Num(cfg.rps)),
        ("duration_s", Json::Num(wall_s)),
        ("concurrency", Json::Num(cfg.concurrency.max(1) as f64)),
        ("requests_total", Json::Num(total as f64)),
        ("requests_ok", Json::Num(ok as f64)),
        ("shed", Json::Num(shed as f64)),
        ("expired", Json::Num(expired as f64)),
        ("errors", Json::Num(errors as f64)),
        ("throughput_rps", Json::Num(throughput)),
        ("client_p50_us", Json::Num(lat.percentile_us(50.0) as f64)),
        ("client_p99_us", Json::Num(lat.percentile_us(99.0) as f64)),
        ("client_mean_us", Json::Num(lat.mean_us())),
        ("shed_rate", Json::Num(shed_rate)),
    ];
    // the sweep's headline fields are top-level so the CI gate can read
    // them without digging (ci/check_bench.py: b8/b1 >= floor); a
    // deliberately skipped sweep says so, so the gate can tell "skipped
    // on purpose" from "bench never ran the sweep"
    let sweep_names: Vec<String> =
        sweep.iter().map(|pt| format!("per_image_throughput_b{}", pt.max_batch)).collect();
    for (pt, name) in sweep.iter().zip(&sweep_names) {
        pairs.push((name.as_str(), Json::Num(pt.per_image_rps)));
    }
    if sweep.is_empty() {
        let reason = if cfg.addr.is_some() {
            "remote --addr target (server batching not reconfigurable from here)"
        } else {
            "disabled via --max-batch"
        };
        pairs.push(("batch_sweep_skipped", Json::Str(reason.into())));
    }
    if let Some(s) = speedup {
        pairs.push(("batch_speedup_b8_over_b1", Json::Num(s)));
    }
    if !sweep.is_empty() {
        pairs.push((
            "batch_sweep",
            Json::obj(vec![
                ("workload", Json::Str("mlp".into())),
                ("duration_s_per_point", Json::Num(cfg.duration.as_secs_f64())),
                (
                    "points",
                    Json::Arr(
                        sweep
                            .iter()
                            .map(|pt| {
                                Json::obj(vec![
                                    ("max_batch", Json::Num(pt.max_batch as f64)),
                                    ("requests_ok", Json::Num(pt.ok as f64)),
                                    ("errors", Json::Num(pt.errors as f64)),
                                    ("wall_s", Json::Num(pt.wall_s)),
                                    (
                                        "per_image_throughput",
                                        Json::Num(pt.per_image_rps),
                                    ),
                                    (
                                        "mean_occupancy",
                                        Json::Num(pt.mean_occupancy),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ));
    }
    // replica-scaling sweep: same top-level/skip-stamp conventions as
    // the batch sweep (ci/check_bench.py: replica_speedup_4_over_1)
    if rsweep.is_empty() {
        let reason = if cfg.addr.is_some() {
            "remote --addr target (replica count not reconfigurable from here)"
        } else {
            "disabled via --replicas"
        };
        pairs.push(("replica_sweep_skipped", Json::Str(reason.into())));
    }
    if let Some(s) = rspeedup {
        pairs.push(("replica_speedup_4_over_1", Json::Num(s)));
    }
    if !rsweep.is_empty() {
        pairs.push((
            "replicas",
            Json::obj(vec![
                ("workload", Json::Str("mlp".into())),
                ("duration_s_per_point", Json::Num(cfg.duration.as_secs_f64())),
                ("steal", Json::Bool(cfg.steal)),
                (
                    "points",
                    Json::Arr(
                        rsweep
                            .iter()
                            .map(|pt| {
                                Json::obj(vec![
                                    ("replicas", Json::Num(pt.replicas as f64)),
                                    ("requests_ok", Json::Num(pt.ok as f64)),
                                    ("errors", Json::Num(pt.errors as f64)),
                                    ("wall_s", Json::Num(pt.wall_s)),
                                    (
                                        "per_image_throughput",
                                        Json::Num(pt.per_image_rps),
                                    ),
                                    ("steals", Json::Num(pt.steals as f64)),
                                    (
                                        "routed",
                                        Json::Arr(
                                            pt.routed
                                                .iter()
                                                .map(|&r| Json::Num(r as f64))
                                                .collect(),
                                        ),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ));
    }
    if let Some(r) = &report {
        pairs.push((
            "server",
            Json::obj(vec![
                ("requests", Json::Num(r.requests as f64)),
                ("batches", Json::Num(r.batches as f64)),
                ("mean_batch_occupancy", Json::Num(r.mean_batch_occupancy)),
                ("workers", Json::Num(r.workers as f64)),
                ("p50_us", Json::Num(r.p50_us as f64)),
                ("p99_us", Json::Num(r.p99_us as f64)),
                ("energy_mj", Json::Num(r.energy_mj)),
                ("p_avg_w", Json::Num(r.p_avg_w)),
                ("shed", Json::Num(r.shed as f64)),
                ("expired", Json::Num(r.expired as f64)),
                (
                    "j_per_inference",
                    j_per_inference.map(Json::Num).unwrap_or(Json::Null),
                ),
            ]),
        ));
    }
    let json = Json::obj(pairs);
    let path = repo_root_file("BENCH_server.json");
    match std::fs::write(&path, json.to_string()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
    table.render()
}

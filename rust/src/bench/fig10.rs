//! Fig. 10 — the progressive power-area optimization waterfall:
//! baseline (dense, foundry MZI, dedicated converters, l_g = 20 µm) down
//! to full SCATTER (LP-MZI, l_g = 1 µm, shared converters, co-sparsity,
//! IG+OG+LR, eoDAC). The paper's headline: 511× area and 12.4× power vs
//! the foundry dense baseline.

use super::common::{BenchCtx, Workload};
use crate::area::AreaModel;
use crate::coordinator::EngineOptions;
use crate::power::energy::pap;
use crate::util::Table;

pub fn run(ctx: &BenchCtx) -> Table {
    let mut table = Table::new("Fig. 10 — progressive power-area optimization").header(&[
        "step", "P_avg (W)", "A (mm^2)", "PAP", "P vs base", "A vs base", "description",
    ]);
    let n = (ctx.eval_budget(Workload::Cnn3) / 4).max(5);

    let mut base: Option<(f64, f64)> = None;
    for step in crate::config::fig10_steps() {
        let (model, ds, _opt_masks) =
            ctx.deployment(Workload::Cnn3, &step.config, step.density);
        let masks = if step.density < 1.0 {
            if step.power_opt_masks {
                // §3.3.5 power-aware selection: per segment, keep the
                // columns whose weights cost the least MZI hold power
                // (plus the min-rerouter-power tie-break)
                weight_power_masks(&model, &step.config, step.density)
            } else {
                // magnitude-only masks: same cardinality, evenly spread
                // (no power awareness) to expose the step-5 delta
                naive_masks(ctx, &model, &step.config, step.density)
            }
        } else {
            Default::default()
        };
        let (_, engine) = ctx.accuracy(
            &model,
            &ds,
            &step.config,
            EngineOptions::NOISY,
            masks,
            n,
        );
        let p_avg = engine.p_avg_w();
        let area = AreaModel::with_defaults(step.config.clone()).total_mm2();
        let (pb, ab) = *base.get_or_insert((p_avg, area));
        table.row(vec![
            step.label.to_string(),
            format!("{p_avg:.2}"),
            format!("{area:.2}"),
            format!("{:.1}", pap(p_avg, area)),
            format!("{:.1}x", pb / p_avg),
            format!("{:.0}x", ab / area),
            step.description.to_string(),
        ]);
    }
    table
}

/// §3.3.5 power-aware column selection using the *actual weights*: per
/// segment, keep the columns with the smallest Σ|arcsin w| (weight-MZI
/// hold power), which is what the DST power metric minimizes once the
/// rerouter term ties.
fn weight_power_masks(
    model: &crate::nn::Model,
    cfg: &crate::AcceleratorConfig,
    density: f64,
) -> std::collections::BTreeMap<String, crate::sparsity::LayerMask> {
    use crate::sparsity::{ChunkMask, LayerMask};
    let mut weights: std::collections::BTreeMap<String, Vec<f64>> = Default::default();
    let mut m = model.clone();
    m.visit_weights_mut(|name, w, _| {
        weights.insert(name.to_string(), w.clone());
    });
    let mut masks = std::collections::BTreeMap::new();
    let (rows, cols) = cfg.chunk_shape();
    let layers = model.matmul_layers();
    let n = layers.len();
    let s_r = density.max(0.5);
    let s_c = (density / s_r).min(1.0);
    let per_seg = (s_c * cfg.k2 as f64).round() as usize;
    for (idx, (name, out_dim, in_dim)) in layers.into_iter().enumerate() {
        if idx == 0 || idx == n - 1 {
            continue;
        }
        let w = &weights[&name];
        let w_max = w.iter().fold(0.0f64, |m, &x| m.max(x.abs())).max(1e-12);
        let p = out_dim.div_ceil(rows);
        let q = in_dim.div_ceil(cols);
        let row = crate::sparsity::interleaved_row_mask(rows, s_r);
        let mut chunks = Vec::with_capacity(p * q);
        for pi in 0..p {
            for qi in 0..q {
                // per-column hold-power cost within this chunk
                let mut col = vec![false; cols];
                for seg in 0..cols / cfg.k2 {
                    let mut costs: Vec<(f64, usize)> = (0..cfg.k2)
                        .map(|j| {
                            let gj = qi * cols + seg * cfg.k2 + j;
                            let mut cost = 0.0;
                            if gj < in_dim {
                                for (i, &r) in row.iter().enumerate() {
                                    let gi = pi * rows + i;
                                    if r && gi < out_dim {
                                        cost += (w[gi * in_dim + gj] / w_max)
                                            .clamp(-1.0, 1.0)
                                            .asin()
                                            .abs();
                                    }
                                }
                            }
                            (cost, j)
                        })
                        .collect();
                    costs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                    for &(_, j) in costs.iter().take(per_seg) {
                        col[seg * cfg.k2 + j] = true;
                    }
                }
                chunks.push(ChunkMask::new(row.clone(), col));
            }
        }
        masks.insert(name, LayerMask { p, q, chunks });
    }
    masks
}

/// Masks with the target density but *evenly spread* (non-power-optimized)
/// column patterns — the strawman that step 5's power-aware selection
/// improves on.
fn naive_masks(
    _ctx: &BenchCtx,
    model: &crate::nn::Model,
    cfg: &crate::AcceleratorConfig,
    density: f64,
) -> std::collections::BTreeMap<String, crate::sparsity::LayerMask> {
    use crate::sparsity::{ChunkMask, LayerMask};
    let mut masks = std::collections::BTreeMap::new();
    let (rows, cols) = cfg.chunk_shape();
    let layers = model.matmul_layers();
    let n = layers.len();
    let s_r = density.max(0.5);
    let s_c = (density / s_r).min(1.0);
    for (idx, (name, out_dim, in_dim)) in layers.into_iter().enumerate() {
        if idx == 0 || idx == n - 1 {
            continue;
        }
        let p = out_dim.div_ceil(rows);
        let q = in_dim.div_ceil(cols);
        let row = crate::sparsity::interleaved_row_mask(rows, s_r);
        // evenly-spread columns: magnitude-style selection with no power
        // awareness — every pair-level splitter must full-swing steer,
        // the rerouter-power worst case that step 5 eliminates
        let per_seg = (s_c * cfg.k2 as f64).round() as usize;
        let col: Vec<bool> = (0..cols)
            .map(|j| {
                let s = j % cfg.k2;
                s * per_seg / cfg.k2 != (s + 1) * per_seg / cfg.k2
            })
            .collect();
        let chunk = ChunkMask::new(row, col);
        masks.insert(name, LayerMask { p, q, chunks: vec![chunk; p * q] });
    }
    masks
}

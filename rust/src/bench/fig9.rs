//! Fig. 9 — thermal-variation-induced activation error (N-MAE) on a
//! 64-channel 3×3 CONV layer:
//! (a) row-sparsity patterns with / without output TIA/ADC gating;
//! (b) input gating + light redistribution vs column sparsity.

use super::common::BenchCtx;
use crate::devices::DeviceLibrary;
use crate::ptc::crossbar::ColumnMode;
use crate::ptc::sim::{ChunkOptions, ChunkSimulator};
use crate::ptc::PtcSimulator;
use crate::sparsity::interleaved_row_mask;
use crate::thermal::{coupling::ArrayGeometry, GammaModel};
use crate::util::{nmae, Table, XorShiftRng};

fn chunk_sim(l_g: f64) -> ChunkSimulator {
    let geom = ArrayGeometry { rows: 16, cols: 16, l_v: 120.0, l_h: l_g + 15.0, l_s: 9.0 };
    let ptc = PtcSimulator::new(geom, &GammaModel::paper(), DeviceLibrary::default());
    ChunkSimulator::new(ptc, 4, 4) // 64x64 chunk = one 64-ch 3x3 conv slice
}

fn conv_like_problem(seed: u64) -> (Vec<f64>, Vec<f64>) {
    // a 64x64 chunk of an im2col'd 64-channel 3x3 conv (576 inputs -> we
    // simulate one 64-wide slice) with activation-like positive inputs
    let mut rng = XorShiftRng::new(seed);
    let mut w = vec![0.0; 64 * 64];
    rng.fill_uniform(&mut w, -1.0, 1.0);
    let mut x = vec![0.0; 64];
    rng.fill_uniform(&mut x, 0.0, 1.0);
    (w, x)
}

/// (a) row patterns ± output gating.
pub fn run_a(_ctx: &BenchCtx) -> Table {
    let mut table = Table::new(
        "Fig. 9(a) — row sparsity pattern x output gating, activation N-MAE (l_g=1um)",
    )
    .header(&["row pattern", "w/o OG", "w/ OG"]);
    let sim = chunk_sim(1.0);
    let (w, x) = conv_like_problem(1);

    let patterns: Vec<(&str, Vec<bool>)> = vec![
        ("dense 1111", vec![true; 64]),
        ("interleaved 1010 (s_r=0.5)", (0..64).map(|i| i % 2 == 0).collect()),
        ("interleaved 11111010 (s_r=0.75)", {
            let seg = interleaved_row_mask(8, 0.75);
            (0..64).map(|i| seg[i % 8]).collect()
        }),
        ("clustered 11110000", (0..64).map(|i| i % 8 < 4).collect()),
    ];

    for (name, row_mask) in patterns {
        let golden = sim.forward_ideal(&w, &x, None, Some(&row_mask));
        let mut cells = vec![name.to_string()];
        for og in [false, true] {
            let opts = ChunkOptions {
                thermal: true,
                pd_noise: true,
                phase_noise: true,
                output_gating: og,
                ..Default::default()
            };
            let mut rng = XorShiftRng::new(50);
            let mut err = 0.0;
            let trials = 30;
            for _ in 0..trials {
                err += nmae(
                    &sim.forward(&w, &x, &opts, None, Some(&row_mask), &mut rng),
                    &golden,
                );
            }
            cells.push(format!("{:.4}", err / trials as f64));
        }
        table.row(cells);
    }
    table
}

/// (b) IG + LR error suppression vs column sparsity.
pub fn run_b(_ctx: &BenchCtx) -> Table {
    let mut table = Table::new(
        "Fig. 9(b) — input gating + light redistribution vs column density (l_g=3um)",
    )
    .header(&["active cols/16", "prune-only", "+IG", "+IG+LR", "LR SNR gain (dB)"]);
    let sim = chunk_sim(3.0);
    let (w, x) = conv_like_problem(2);

    for active in [12usize, 8, 4] {
        // uniform per-segment mask (same pattern per k2=16 block)
        let seg: Vec<bool> =
            (0..16).map(|j| j * active / 16 != (j + 1) * active / 16).collect();
        let col_mask: Vec<bool> = (0..64).map(|j| seg[j % 16]).collect();
        let golden = sim.forward_ideal(&w, &x, Some(&col_mask), None);
        let mut cells = vec![format!("{active}")];
        let mut errs = Vec::new();
        for mode in [ColumnMode::PruneOnly, ColumnMode::InputGating, ColumnMode::InputGatingLr] {
            let opts = ChunkOptions {
                thermal: true,
                pd_noise: true,
                phase_noise: true,
                col_mode: mode,
                ..Default::default()
            };
            let mut rng = XorShiftRng::new(60);
            let mut err = 0.0;
            let trials = 30;
            for _ in 0..trials {
                err += nmae(
                    &sim.forward(&w, &x, &opts, Some(&col_mask), None, &mut rng),
                    &golden,
                );
            }
            errs.push(err / trials as f64);
            cells.push(format!("{:.4}", err / trials as f64));
        }
        cells.push(format!(
            "{:.1}",
            crate::rerouter::lr_snr_gain_db(active, 16)
        ));
        table.row(cells);
    }
    table
}

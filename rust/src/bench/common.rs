//! Shared benchmark context: fitted models, datasets, accuracy/error
//! measurement helpers, and the deployment recipe used by several tables.

use crate::config::{AcceleratorConfig, SparsitySupport};
use crate::coordinator::{EngineOptions, PhotonicEngine};
use crate::data::{DatasetSpec, SyntheticDataset};
use crate::devices::{Mzi, MziSpec};
use crate::nn::{fit_prototype_readout, Model};
use crate::sparsity::{init_layer_mask, LayerMask};
use crate::thermal::GammaModel;
use crate::util::Json;
use std::collections::BTreeMap;

/// Which benchmark workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    Cnn3,
    Vgg8,
    Resnet18,
}

impl Workload {
    pub fn label(&self) -> &'static str {
        match self {
            Workload::Cnn3 => "CNN-FMNIST*",
            Workload::Vgg8 => "VGG8-CIFAR10*",
            Workload::Resnet18 => "ResNet18-CIFAR100*",
        }
    }

    pub fn dataset(&self) -> DatasetSpec {
        match self {
            Workload::Cnn3 => DatasetSpec::fmnist_like(),
            Workload::Vgg8 => DatasetSpec::cifar10_like(),
            Workload::Resnet18 => DatasetSpec::cifar100_like(),
        }
    }

    pub fn model(&self) -> Model {
        match self {
            Workload::Cnn3 => crate::nn::models::cnn3(),
            Workload::Vgg8 => crate::nn::models::vgg8(),
            Workload::Resnet18 => crate::nn::models::resnet18(),
        }
    }
}

/// Benchmark context: sample budget + cached fitted models.
pub struct BenchCtx {
    /// Accuracy-evaluation sample count (paper uses full test sets; we
    /// default to 100 for CNN-3 and scale down for the big models).
    pub n_eval: usize,
    /// Trained-bundle directory (from `make train`); used when present.
    pub trained_dir: Option<std::path::PathBuf>,
    cache: std::cell::RefCell<BTreeMap<&'static str, (Model, SyntheticDataset)>>,
    sparse_cache:
        std::cell::RefCell<BTreeMap<String, (Model, BTreeMap<String, LayerMask>)>>,
}

impl Default for BenchCtx {
    fn default() -> Self {
        Self::new(100)
    }
}

impl BenchCtx {
    pub fn new(n_eval: usize) -> Self {
        // The python-DST backbone is only used when explicitly requested
        // (SCATTER_TRAINED=1): its near-zero normalized weights program
        // tiny aggressor phases, making it far more crosstalk-robust than
        // the paper's FMNIST-trained CNNs — interesting, but it flattens
        // the Table-3 degradation signal the harness is asserting. The
        // default prototype-readout deployment reproduces the paper's
        // degradation magnitudes. See EXPERIMENTS.md §Substitutions.
        let trained_dir = if std::env::var("SCATTER_TRAINED").is_ok() {
            let p = std::path::PathBuf::from("artifacts/trained");
            p.exists().then_some(p)
        } else {
            None
        };
        Self { n_eval, trained_dir, cache: Default::default(), sparse_cache: Default::default() }
    }

    /// Eval budget for a workload (big models get fewer samples).
    pub fn eval_budget(&self, wl: Workload) -> usize {
        match wl {
            Workload::Cnn3 => self.n_eval,
            Workload::Vgg8 => (self.n_eval / 2).max(10),
            Workload::Resnet18 => (self.n_eval / 4).max(10),
        }
    }

    /// Fitted model + dataset for a workload (cached).
    ///
    /// Preference order: python-trained bundle (if `make train` ran),
    /// otherwise a prototype-readout fit on the random-feature backbone.
    pub fn fitted(&self, wl: Workload) -> (Model, SyntheticDataset) {
        let key = wl.label();
        if let Some(hit) = self.cache.borrow().get(key) {
            return hit.clone();
        }
        let ds = SyntheticDataset::new(wl.dataset());
        let mut model = wl.model();
        if let Some(dir) = &self.trained_dir {
            // install the python-DST-trained backbone when available; the
            // readout is re-fit below either way (the python and rust
            // synthetic datasets share structure but not samples, so a
            // transferred readout would not be calibrated).
            let path = dir.join(short_name(wl)).join("weights.json");
            if let Ok(bundle) = crate::nn::loader::WeightBundle::load(&path) {
                let _ = bundle.install(&mut model);
            }
        }
        let n_train = match wl {
            Workload::Cnn3 => 300,
            Workload::Vgg8 => 200,
            Workload::Resnet18 => 400,
        };
        let _ = fit_prototype_readout(&mut model, &ds, n_train);
        self.cache.borrow_mut().insert(key, (model.clone(), ds.clone()));
        (model, ds)
    }

    /// A *sparsity-aware* deployment: masks built for `cfg` at `density`,
    /// permanently applied to the backbone weights, and the prototype
    /// readout re-fit on the masked features — mirroring DST, where the
    /// model trains under its mask (deploying a dense-trained readout on
    /// a 70%-pruned backbone would collapse for reasons unrelated to the
    /// hardware). Cached per (workload, density, chunk shape).
    pub fn deployment(
        &self,
        wl: Workload,
        cfg: &AcceleratorConfig,
        density: f64,
    ) -> (Model, SyntheticDataset, BTreeMap<String, LayerMask>) {
        let (model, ds) = self.fitted(wl);
        if density >= 1.0 {
            return (model, ds, BTreeMap::new());
        }
        let (rows, cols) = cfg.chunk_shape();
        let key = format!("{}|{density}|{rows}x{cols}", wl.label());
        if let Some((m, masks)) = self.sparse_cache.borrow().get(&key) {
            return (m.clone(), ds, masks.clone());
        }
        let masks = self.masks_for(&model, cfg, density);
        let mut sparse_model = model;
        apply_masks_to_model(&mut sparse_model, &masks, rows, cols);
        // re-fit the readout on the masked backbone
        let n_train = match wl {
            Workload::Cnn3 => 300,
            Workload::Vgg8 => 200,
            Workload::Resnet18 => 400,
        };
        let _ = fit_prototype_readout(&mut sparse_model, &ds, n_train);
        self.sparse_cache
            .borrow_mut()
            .insert(key, (sparse_model.clone(), masks.clone()));
        (sparse_model, ds, masks)
    }

    /// SCATTER masks for a model at target density `s`, chunked for `cfg`.
    /// The first conv and last linear stay dense (paper protects them).
    pub fn masks_for(
        &self,
        model: &Model,
        cfg: &AcceleratorConfig,
        density: f64,
    ) -> BTreeMap<String, LayerMask> {
        if let Some(dir) = &self.trained_dir {
            // try the python-exported masks first
            for wl in [Workload::Cnn3, Workload::Vgg8, Workload::Resnet18] {
                if wl.model().name == model.name {
                    let path = dir.join(short_name(wl)).join("masks.json");
                    if let Ok(masks) = crate::nn::loader::load_masks(&path) {
                        if !masks.is_empty() {
                            return masks;
                        }
                    }
                }
            }
        }
        build_masks(model, cfg, density)
    }

    /// Measure classification accuracy of the model on the photonic twin.
    pub fn accuracy(
        &self,
        model: &Model,
        ds: &SyntheticDataset,
        cfg: &AcceleratorConfig,
        opts: EngineOptions,
        masks: BTreeMap<String, LayerMask>,
        n: usize,
    ) -> (f64, PhotonicEngine) {
        let mut engine = PhotonicEngine::new(cfg.clone(), opts);
        engine.set_masks(masks);
        // paper §4.1: the last linear layer is protected by non-adjacent
        // MZI-column mapping in every evaluated setting
        if let Some((last, _, _)) = model.matmul_layers().last() {
            engine.set_protected([last.clone()].into_iter().collect());
        }
        let acc = crate::data::evaluate_accuracy(model, &mut engine, ds, 0xE7A1, n);
        (acc, engine)
    }
}

/// Resolve `name` to the repo root whether the bench runs from the repo
/// root (`scatter bench ...`) or from `rust/` (`cargo bench`/`cargo
/// test`), so perf artifacts (`BENCH_engine.json`, `BENCH_server.json`)
/// always land in one place for CI to pick up.
pub fn repo_root_file(name: &str) -> std::path::PathBuf {
    if std::path::Path::new("ROADMAP.md").exists() {
        name.into()
    } else if std::path::Path::new("../ROADMAP.md").exists() {
        std::path::Path::new("..").join(name)
    } else {
        name.into()
    }
}

/// Host CPU-feature + kernel-variant block recorded in every
/// BENCH_*.json artifact (`"host"`), so perf floors and trajectories
/// are interpretable per runner: a ratio measured on an AVX-512 box is
/// not comparable to one from a scalar ARM runner.
pub fn host_info() -> Json {
    let f = crate::exec::cpu_features();
    let simd = crate::exec::detected_simd();
    Json::obj(vec![
        ("arch", Json::Str(std::env::consts::ARCH.into())),
        (
            "cpu",
            Json::obj(vec![
                ("avx2", Json::Bool(f.avx2)),
                ("avx512f", Json::Bool(f.avx512f)),
                ("fma", Json::Bool(f.fma)),
            ]),
        ),
        ("kernel_variant", Json::Str(simd.as_str().into())),
        ("kernel_lanes", Json::Num(simd.lanes() as f64)),
    ])
}

fn short_name(wl: Workload) -> &'static str {
    match wl {
        Workload::Cnn3 => "cnn3",
        Workload::Vgg8 => "vgg8",
        Workload::Resnet18 => "resnet18",
    }
}

/// Zero the pruned weights of every masked layer in place (the chunked
/// (rows × cols) grid matches `Scheduler::schedule`'s padding).
pub fn apply_masks_to_model(
    model: &mut Model,
    masks: &BTreeMap<String, LayerMask>,
    rows: usize,
    cols: usize,
) {
    let shapes: BTreeMap<String, (usize, usize)> = model
        .matmul_layers()
        .into_iter()
        .map(|(n, o, i)| (n, (o, i)))
        .collect();
    model.visit_weights_mut(|name, w, _| {
        let Some(lm) = masks.get(name) else { return };
        let (out_dim, in_dim) = shapes[name];
        for gi in 0..out_dim {
            let (pi, i) = (gi / rows, gi % rows);
            for gj in 0..in_dim {
                let (qi, j) = (gj / cols, gj % cols);
                if !lm.chunk(pi, qi).element(i, j) {
                    w[gi * in_dim + gj] = 0.0;
                }
            }
        }
    });
}

/// Rust-side mask construction (crosstalk/power-minimized init of Alg. 1)
/// for every matmul layer except the first conv and last linear.
pub fn build_masks(
    model: &Model,
    cfg: &AcceleratorConfig,
    density: f64,
) -> BTreeMap<String, LayerMask> {
    let mut masks = BTreeMap::new();
    if density >= 1.0 {
        return masks;
    }
    let gamma = GammaModel::paper();
    let mzi = Mzi::new(MziSpec::low_power(), cfg.l_s, &gamma);
    let layers = model.matmul_layers();
    let (rows, cols) = cfg.chunk_shape();
    let n = layers.len();
    for (idx, (name, out_dim, in_dim)) in layers.into_iter().enumerate() {
        if idx == 0 || idx == n - 1 {
            continue; // paper: first CONV and last linear stay dense
        }
        let p = out_dim.div_ceil(rows);
        let q = in_dim.div_ceil(cols);
        let (mask, _, _) = init_layer_mask(p, q, rows, cols, cfg.k2, density, &mzi);
        masks.insert(name, mask);
    }
    masks
}

/// The Fig.-10-step feature sets as EngineOptions + config tweaks already
/// live in `config::presets`; here's the Table-3 deployment recipe.
pub fn table3_config(l_g: f64, features: SparsitySupport) -> AcceleratorConfig {
    AcceleratorConfig { l_g, features, ..AcceleratorConfig::default() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fitted_model_beats_chance() {
        let ctx = BenchCtx::new(40);
        let (model, ds) = ctx.fitted(Workload::Cnn3);
        let mut exact = crate::nn::ExactEngine;
        let acc = crate::data::evaluate_accuracy(&model, &mut exact, &ds, 0x11, 40);
        assert!(acc > 0.6, "fitted cnn3 accuracy {acc}");
    }

    #[test]
    fn masks_skip_first_and_last() {
        let ctx = BenchCtx::new(10);
        let (model, _) = ctx.fitted(Workload::Cnn3);
        let cfg = AcceleratorConfig::default();
        let masks = ctx.masks_for(&model, &cfg, 0.3);
        assert!(!masks.contains_key("conv1"));
        assert!(!masks.contains_key("fc"));
        assert!(masks.contains_key("conv2"));
        let lm = &masks["conv2"];
        assert!((lm.density() - 0.3).abs() < 0.1, "density {}", lm.density());
    }

    #[test]
    fn host_info_reports_kernel_variant() {
        let h = host_info();
        let variant = h.get("kernel_variant").and_then(Json::as_str).expect("variant");
        assert!(["scalar", "avx2", "avx512"].contains(&variant));
        let lanes = h.get("kernel_lanes").and_then(Json::as_f64).expect("lanes");
        assert!(lanes == 8.0 || lanes == 16.0);
        assert!(h.get("cpu").and_then(|c| c.get("avx2")).is_some());
    }

    #[test]
    fn dense_density_yields_no_masks() {
        let ctx = BenchCtx::new(10);
        let (model, _) = ctx.fitted(Workload::Cnn3);
        assert!(ctx.masks_for(&model, &AcceleratorConfig::default(), 1.0).is_empty());
    }
}

//! Device-fault repair bench: sentinel detection + mask-quarantine
//! self-repair (`scatter bench repair`, EXPERIMENTS.md §Device faults).
//!
//! Two measurements against the same CNN-3 deployment:
//!
//! * **serving** — a mid-life dead-rerouter-branch fault strikes a
//!   replica under closed-loop HTTP load; the sentinel localizes it and
//!   the quarantine repair hot-swaps around the dead device while
//!   traffic flows. Headlines: detection latency (fault pin-in → first
//!   sentinel finding), at least one promoted repair, zero replicas
//!   degraded, and reply conservation (`lost == 0` — the repair path
//!   never eats a reply).
//! * **accuracy recovery** — offline on the photonic twin: the same
//!   deployment is evaluated clean, then with stuck-MZI defects pinned
//!   across every chunk of the masked backbone layer (each stuck cell
//!   realizes a *wrong* weight, not a zero), then again after the
//!   sentinel→quarantine repair gates the faulted columns dark.
//!   Headline: `recovery = (acc_repaired − acc_faulty) /
//!   (acc_clean − acc_faulty)`, the fraction of the fault-induced
//!   accuracy drop the repair wins back.
//!
//! `ci/check_bench.py --repair` gates: at least one detection and one
//! promoted repair, zero unrepairable verdicts, zero lost replies, and
//! recovery at or above the baseline floor.

use crate::bench::common::{host_info, repo_root_file, BenchCtx, Workload};
use crate::config::AcceleratorConfig;
use crate::coordinator::net::{http_request, HttpClient, HttpServer, NetConfig};
use crate::coordinator::{
    EngineOptions, InferenceServer, PhotonicEngine, RepairServerConfig, ServerConfig,
};
use crate::ptc::DeviceFaultPlan;
use crate::sparsity::LayerMask;
use crate::util::{Json, Table};
use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::time::{Duration, Instant};

/// `scatter bench repair` configuration.
#[derive(Debug, Clone)]
pub struct RepairBenchConfig {
    /// Serving-phase load duration.
    pub duration: Duration,
    /// Concurrent keep-alive client connections.
    pub concurrency: usize,
    /// Engine-worker pool size.
    pub workers: usize,
    /// Shards each replica serves before the fault pins in.
    pub inject_after_shards: u64,
    /// Sentinel probe pacing.
    pub probe_period: Duration,
}

impl Default for RepairBenchConfig {
    fn default() -> Self {
        Self {
            duration: Duration::from_secs(4),
            concurrency: 4,
            workers: 2,
            inject_after_shards: 3,
            probe_period: Duration::from_millis(1),
        }
    }
}

/// One request outcome, classed the same way `bench swap` classes them.
#[derive(Debug, Clone, Copy, Default)]
struct Tally {
    ok: u64,
    shed: u64,
    expired: u64,
    lost: u64,
}

/// Closed-loop send loop over a keep-alive connection; reconnects once
/// per failure so a mid-repair disconnect is counted, not fatal.
fn drive_client(
    addr: SocketAddr,
    bodies: &[String],
    deadline: Instant,
    seed: usize,
) -> Tally {
    let mut t = Tally::default();
    let mut client = match HttpClient::connect(&addr) {
        Ok(c) => c,
        Err(_) => return t,
    };
    let mut i = seed;
    while Instant::now() < deadline {
        let body = &bodies[i % bodies.len()];
        i += 1;
        match client.request("POST", "/v1/predict", Some(body)) {
            Ok(resp) => match resp.status {
                200 => t.ok += 1,
                503 => t.shed += 1,
                504 => t.expired += 1,
                _ => t.lost += 1,
            },
            Err(_) => {
                t.lost += 1;
                match HttpClient::connect(&addr) {
                    Ok(c) => client = c,
                    Err(_) => return t,
                }
            }
        }
    }
    t
}

/// First masked layer plus the first active column of its chunk 0 —
/// the dead-branch target for the serving phase (an *active* column,
/// so the dark branch deviates from its golden and the quarantine has
/// a live cell to gate).
fn serving_fault_spec(masks: &BTreeMap<String, LayerMask>) -> Option<String> {
    let (layer, lm) = masks.iter().next()?;
    let j = lm.chunk(0, 0).col.iter().position(|&a| a)?;
    Some(format!("dead-branch@{layer}:c0:i{j}"))
}

/// Stuck-MZI plan for the accuracy phase: in every chunk of every
/// masked layer, pin up to `per_chunk` active columns to a large wrong
/// phase (weight ≈ −sin 1.5, nowhere near the intended value). Stuck
/// cells — unlike dead ones — keep *emitting* wrong products, so the
/// faulted fabric loses real accuracy and the repair has something to
/// win back.
fn stuck_fault_spec(masks: &BTreeMap<String, LayerMask>, per_chunk: usize) -> String {
    let mut specs = Vec::new();
    for (layer, lm) in masks {
        for pi in 0..lm.p {
            for qi in 0..lm.q {
                let cm = lm.chunk(pi, qi);
                let Some(r) = cm.row.iter().position(|&a| a) else { continue };
                let ci = pi * lm.q + qi;
                let active = cm.col.iter().enumerate().filter_map(|(j, &a)| a.then_some(j));
                for j in active.take(per_chunk) {
                    specs.push(format!("stuck@{layer}:c{ci}:r{r}:i{j}:p1.5"));
                }
            }
        }
    }
    specs.join(",")
}

struct ServePhase {
    tally: Tally,
    injected: u64,
    detections: u64,
    repairs: u64,
    unrepairable: u64,
    degraded: usize,
    detection_ms: f64,
    quarantined_cells: u64,
    wall_s: f64,
}

/// Serving run: mid-life dead branch + sentinel + quarantine repair
/// under closed-loop load.
fn run_serve_phase(cfg: &RepairBenchConfig) -> ServePhase {
    let workers = cfg.workers.max(1);
    let ctx = BenchCtx::new(50);
    let acc = AcceleratorConfig::default();
    let (model, _ds, masks) = ctx.deployment(Workload::Cnn3, &acc, 0.3);
    let plan = serving_fault_spec(&masks)
        .and_then(|s| DeviceFaultPlan::parse(&s).ok())
        .unwrap_or_else(DeviceFaultPlan::none);
    let server = InferenceServer::spawn(
        model,
        acc,
        EngineOptions::NOISY,
        masks,
        ServerConfig::builder()
            .max_batch(4)
            .batch_timeout(Duration::from_millis(2))
            .workers(workers)
            // the canary gate is opened fully: the phase measures the
            // detect→quarantine→swap machinery and its conservation,
            // not argmax agreement of a synthetic-fitted model
            .repair(RepairServerConfig {
                device_faults: plan,
                inject_after_shards: cfg.inject_after_shards,
                sentinel: true,
                probe_period: cfg.probe_period,
                canary_threshold: 0.0,
            })
            .build()
            .expect("repair bench config validates"),
    );
    let http = HttpServer::bind(server, NetConfig::default()).expect("bind ephemeral");
    let addr = http.local_addr();

    let ds = crate::data::SyntheticDataset::new(crate::data::DatasetSpec::fmnist_like());
    let bodies: Vec<String> = (0..16)
        .map(|i| {
            let (img, _) = ds.sample(0x51A9, i);
            Json::obj(vec![("image", Json::arr_f64(&img.data))]).to_string()
        })
        .collect();

    let started = Instant::now();
    let deadline = started + cfg.duration;
    let tallies: Vec<Tally> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..cfg.concurrency.max(1))
            .map(|c| {
                let bodies = &bodies;
                s.spawn(move || drive_client(addr, bodies, deadline, c * 7919))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });
    let wall_s = started.elapsed().as_secs_f64().max(1e-9);

    // the quarantine gauge is per-replica labeled; sum it while the
    // server is still up
    let scraped = http_request(&addr, "GET", "/metrics", None)
        .map(|r| r.body)
        .unwrap_or_default();
    let quarantined_cells = scraped
        .lines()
        .filter(|l| l.starts_with("scatter_quarantined_cells{"))
        .filter_map(|l| l.rsplit(' ').next()?.parse::<f64>().ok())
        .sum::<f64>() as u64;

    let report = http.shutdown().expect("drain repair server");

    let mut tally = Tally::default();
    for t in &tallies {
        tally.ok += t.ok;
        tally.shed += t.shed;
        tally.expired += t.expired;
        tally.lost += t.lost;
    }
    ServePhase {
        tally,
        injected: report.faults_injected,
        detections: report.fault_detections,
        repairs: report.fault_repairs,
        unrepairable: report.fault_unrepairable,
        degraded: report.degraded.iter().filter(|&&d| d).count(),
        detection_ms: report.fault_detection_latency_us as f64 / 1000.0,
        quarantined_cells,
        wall_s,
    }
}

struct AccuracyPhase {
    acc_clean: f64,
    acc_faulty: f64,
    acc_repaired: f64,
    recovery: f64,
    stuck_cells: usize,
    findings: usize,
    quarantined_cells: usize,
}

/// Offline triple on the twin: clean → stuck-faulted → repaired, same
/// evaluation seed and sample set throughout.
fn run_accuracy_phase(n_eval: usize) -> AccuracyPhase {
    let ctx = BenchCtx::new(n_eval);
    let acc = AcceleratorConfig::default();
    let (model, ds, masks) = ctx.deployment(Workload::Cnn3, &acc, 0.3);
    let (acc_clean, _) =
        ctx.accuracy(&model, &ds, &acc, EngineOptions::NOISY, masks.clone(), n_eval);

    let spec = stuck_fault_spec(&masks, 4);
    let plan = DeviceFaultPlan::parse(&spec).expect("generated stuck spec parses");
    let stuck_cells = plan.len();

    // one engine carries faulty → repaired so the repair is measured
    // against the exact fabric it ran on
    let mut engine = PhotonicEngine::new(acc.clone(), EngineOptions::NOISY);
    engine.set_masks(masks.clone());
    if let Some((last, _, _)) = model.matmul_layers().last() {
        engine.set_protected([last.clone()].into_iter().collect());
    }
    engine.set_device_faults(plan);
    let acc_faulty = crate::data::evaluate_accuracy(&model, &mut engine, &ds, 0xE7A1, n_eval);

    let findings = engine.sentinel_probe_all();
    let mut quarantined_cells = 0usize;
    if let Some((repaired, cells)) = engine.quarantine_masks(&findings) {
        let gen = engine.mask_generation();
        engine.apply_mask_update(repaired, gen + 1);
        engine.record_quarantine(&findings);
        quarantined_cells = cells;
    }
    let acc_repaired =
        crate::data::evaluate_accuracy(&model, &mut engine, &ds, 0xE7A1, n_eval);

    // fraction of the fault-induced drop the repair wins back; a fault
    // too weak to move accuracy leaves nothing to recover
    let drop = acc_clean - acc_faulty;
    let recovery = if drop < 0.02 {
        1.0
    } else {
        ((acc_repaired - acc_faulty) / drop).clamp(0.0, 1.0)
    };
    AccuracyPhase {
        acc_clean,
        acc_faulty,
        acc_repaired,
        recovery,
        stuck_cells,
        findings: findings.len(),
        quarantined_cells,
    }
}

/// Run the repair bench, print the summary table, write
/// `BENCH_repair.json`, and return the rendered table.
pub fn run(cfg: &RepairBenchConfig) -> String {
    let serve = run_serve_phase(cfg);
    let acc = run_accuracy_phase(100);

    let mut table = Table::new("device-fault repair bench (sentinel + quarantine)")
        .header(&["metric", "value"]);
    table.row(vec!["serving duration".into(), format!("{:.2} s", serve.wall_s)]);
    table.row(vec![
        "ok / shed / expired / lost".into(),
        format!(
            "{} / {} / {} / {}",
            serve.tally.ok, serve.tally.shed, serve.tally.expired, serve.tally.lost
        ),
    ]);
    table.row(vec![
        "faults injected / detections".into(),
        format!("{} / {}", serve.injected, serve.detections),
    ]);
    table.row(vec![
        "repairs / unrepairable / degraded".into(),
        format!("{} / {} / {}", serve.repairs, serve.unrepairable, serve.degraded),
    ]);
    table.row(vec![
        "detection latency".into(),
        format!("{:.3} ms", serve.detection_ms),
    ]);
    table.row(vec![
        "quarantined cells (serving)".into(),
        format!("{}", serve.quarantined_cells),
    ]);
    table.row(vec![
        "stuck cells / findings / cells gated (offline)".into(),
        format!("{} / {} / {}", acc.stuck_cells, acc.findings, acc.quarantined_cells),
    ]);
    table.row(vec![
        "accuracy clean → faulty → repaired".into(),
        format!(
            "{:.3} → {:.3} → {:.3}",
            acc.acc_clean, acc.acc_faulty, acc.acc_repaired
        ),
    ]);
    table.row(vec!["recovery".into(), format!("{:.3}", acc.recovery)]);

    let json = Json::obj(vec![
        ("bench", Json::Str("repair".into())),
        ("host", host_info()),
        ("concurrency", Json::Num(cfg.concurrency.max(1) as f64)),
        ("workers", Json::Num(cfg.workers.max(1) as f64)),
        ("duration_s", Json::Num(serve.wall_s)),
        ("requests_ok", Json::Num(serve.tally.ok as f64)),
        ("shed", Json::Num(serve.tally.shed as f64)),
        ("expired", Json::Num(serve.tally.expired as f64)),
        ("lost", Json::Num(serve.tally.lost as f64)),
        ("faults_injected", Json::Num(serve.injected as f64)),
        ("detections", Json::Num(serve.detections as f64)),
        ("repairs", Json::Num(serve.repairs as f64)),
        ("unrepairable", Json::Num(serve.unrepairable as f64)),
        ("degraded", Json::Num(serve.degraded as f64)),
        ("detection_ms", Json::Num(serve.detection_ms)),
        ("quarantined_cells_serving", Json::Num(serve.quarantined_cells as f64)),
        ("stuck_cells", Json::Num(acc.stuck_cells as f64)),
        ("offline_findings", Json::Num(acc.findings as f64)),
        ("quarantined_cells_offline", Json::Num(acc.quarantined_cells as f64)),
        ("acc_clean", Json::Num(acc.acc_clean)),
        ("acc_faulty", Json::Num(acc.acc_faulty)),
        ("acc_repaired", Json::Num(acc.acc_repaired)),
        ("recovery", Json::Num(acc.recovery)),
    ]);
    let path = repo_root_file("BENCH_repair.json");
    match std::fs::write(&path, json.to_string()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
    table.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The generated stuck-fault spec round-trips through the plan
    /// grammar and lands only on active cells of masked layers.
    #[test]
    fn stuck_spec_parses_and_covers_every_chunk() {
        let ctx = BenchCtx::new(10);
        let acc = AcceleratorConfig::default();
        let (_model, _ds, masks) = ctx.deployment(Workload::Cnn3, &acc, 0.3);
        let spec = stuck_fault_spec(&masks, 2);
        let plan = DeviceFaultPlan::parse(&spec).expect("spec parses");
        assert!(!plan.is_empty(), "masked deployment must yield stuck cells");
        let chunks: usize = masks.values().map(|lm| lm.p * lm.q).sum();
        assert!(
            plan.len() <= chunks * 2,
            "at most per_chunk faults per chunk: {} > {}",
            plan.len(),
            chunks * 2
        );
    }

    /// The serving fault targets an active column (a masked-off column
    /// would neither deviate from its golden nor be quarantinable).
    #[test]
    fn serving_fault_spec_hits_an_active_column() {
        let ctx = BenchCtx::new(10);
        let acc = AcceleratorConfig::default();
        let (_model, _ds, masks) = ctx.deployment(Workload::Cnn3, &acc, 0.3);
        let spec = serving_fault_spec(&masks).expect("masked deployment");
        let plan = DeviceFaultPlan::parse(&spec).expect("spec parses");
        assert_eq!(plan.len(), 1);
        let (layer, lm) = masks.iter().next().expect("non-empty");
        assert!(spec.starts_with(&format!("dead-branch@{layer}")));
        let j: usize = spec.rsplit(":i").next().unwrap().parse().expect("col index");
        assert!(lm.chunk(0, 0).col[j], "target column must be active");
    }
}

//! Fig. 6 — power-area-accuracy design space of a 16×16 PTC across arm
//! spacing l_s and MZI gap l_g; dense network under variations.

use super::common::{BenchCtx, Workload};
use crate::area::AreaModel;
use crate::config::{AcceleratorConfig, DacKind, SparsitySupport};
use crate::coordinator::EngineOptions;
use crate::devices::{Mzi, MziSpec};
use crate::thermal::GammaModel;
use crate::util::Table;

pub fn run(ctx: &BenchCtx) -> Table {
    let mut table = Table::new("Fig. 6 — 16x16 PTC power/area/accuracy vs (l_s, l_g)")
        .header(&["l_s", "l_g", "array area (mm^2)", "MZI power (mW avg)", "Acc w/ TV (%)"]);

    let gamma = GammaModel::paper();
    let (model, ds) = ctx.fitted(Workload::Cnn3);
    let n = (ctx.eval_budget(Workload::Cnn3) / 2).max(10);
    for &ls in &[7.0, 9.0, 11.0] {
        for &lg in &[1.0, 5.0, 10.0] {
            let cfg = AcceleratorConfig {
                l_s: ls,
                l_g: lg,
                share_r: 1,
                share_c: 1,
                dac: DacKind::Edac,
                features: SparsitySupport::NONE,
                ..Default::default()
            };
            let area = AreaModel::with_defaults(cfg.clone()).ptc_weight_array_mm2();
            let mzi = Mzi::new(MziSpec::low_power(), ls, &gamma);
            let p_avg = mzi.mean_power_uniform_mw();
            let (acc, _) = ctx.accuracy(
                &model,
                &ds,
                &cfg,
                EngineOptions::NOISY,
                Default::default(),
                n,
            );
            table.row(vec![
                format!("{ls:.0}"),
                format!("{lg:.0}"),
                format!("{area:.4}"),
                format!("{p_avg:.3}"),
                format!("{:.1}", acc * 100.0),
            ]);
        }
    }
    table
}

//! Fig. 5 — column-sparsity handling on an 8×8 block: computing N-MAE for
//! weight-pruning-only vs + input gating (IG) vs + light redistribution
//! (IG+LR). Refocusing should cut the error dramatically.

use super::common::BenchCtx;
use crate::devices::DeviceLibrary;
use crate::ptc::crossbar::{ColumnMode, ForwardOptions, PtcSimulator};
use crate::thermal::{coupling::ArrayGeometry, GammaModel};
use crate::util::{nmae, Table, XorShiftRng};

pub fn run(_ctx: &BenchCtx) -> Table {
    let mut table = Table::new("Fig. 5 — 8x8 block computing N-MAE by column-sparsity mode")
        .header(&["active cols", "prune-only", "+IG", "+IG+LR"]);

    let geom = ArrayGeometry { rows: 8, cols: 8, l_v: 120.0, l_h: 20.0, l_s: 9.0 };
    let sim = PtcSimulator::new(geom, &GammaModel::paper(), DeviceLibrary::default());
    let mut rng = XorShiftRng::new(7);
    let mut w = vec![0.0; 64];
    rng.fill_uniform(&mut w, -1.0, 1.0);
    let mut x = vec![0.0; 8];
    rng.fill_uniform(&mut x, 0.2, 1.0);

    for active in [6usize, 4, 2] {
        let col_mask: Vec<bool> = (0..8).map(|j| j * active / 8 != (j + 1) * active / 8).collect();
        // the above picks `active` roughly-evenly-spaced true entries
        let n_active = col_mask.iter().filter(|&&m| m).count();
        assert_eq!(n_active, active);
        let golden = sim.forward_ideal(&w, &x, Some(&col_mask), None);
        let mut cells = vec![format!("{active}/8")];
        for mode in [ColumnMode::PruneOnly, ColumnMode::InputGating, ColumnMode::InputGatingLr] {
            let opts = ForwardOptions {
                thermal: true,
                pd_noise: true,
                phase_noise: true,
                col_mask: Some(&col_mask),
                col_mode: mode,
                ..Default::default()
            };
            let mut noise_rng = XorShiftRng::new(100);
            let mut err = 0.0;
            let trials = 400;
            for _ in 0..trials {
                err += nmae(&sim.forward(&w, &x, &opts, &mut noise_rng), &golden);
            }
            cells.push(format!("{:.4}", err / trials as f64));
        }
        table.row(cells);
    }
    table
}

//! Fig. 8 — hybrid eoDAC design points: DAC power, IO pads, area factor,
//! and SNR headroom for each partitioning of a 6-bit conversion.
//! The paper's optimum is two 3-bit segments (8:1), 2.3× power saving.

use super::common::BenchCtx;
use crate::devices::{Dac, EoDac};
use crate::util::Table;

pub fn run(_ctx: &BenchCtx) -> Table {
    let mut table = Table::new("Fig. 8 — eoDAC partitioning of a 6-bit @ 5 GHz conversion")
        .header(&[
            "config", "DAC power (mW)", "saving vs eDAC", "IO pads", "area factor",
            "SNR gain (dB)",
        ]);
    let p0 = crate::devices::DeviceLibrary::default().edac_p0_pj;
    let mono = Dac::new(6, 5.0, p0);
    table.row(vec![
        "1 x 6-bit eDAC".into(),
        format!("{:.2}", mono.power_mw()),
        "1.00x".into(),
        "1".into(),
        "1.0x".into(),
        "0.0".into(),
    ]);
    for (segments, bits) in [(2u8, 3u8), (3, 2), (6, 1)] {
        let eo = EoDac::new(segments, bits, 5.0, p0);
        table.row(vec![
            format!("{segments} x {bits}-bit eoDAC"),
            format!("{:.2}", eo.power_mw()),
            format!("{:.2}x", eo.power_saving_vs_edac()),
            eo.io_pads().to_string(),
            format!("{:.1}x", eo.area_factor()),
            format!("{:.1}", eo.snr_gain_db()),
        ]);
    }
    table
}

//! Mask hot-swap bench: in-serving DST under concurrent load
//! (`scatter bench swap`, EXPERIMENTS.md §Mask hot-swap protocol).
//!
//! Two phases against the same CNN-3 deployment, both driven by
//! closed-loop keep-alive HTTP clients:
//!
//! * **promote** — DST enabled with a permissive canary: the dispatcher
//!   steps the power-optimized mask search on its idle headroom and the
//!   workers cut candidate generations over at shard boundaries while
//!   traffic flows. Headlines: promoted swap count, reply conservation
//!   (`lost == 0` — a swap never eats a reply), and client-observed
//!   energy per image before vs after the swaps.
//! * **rollback** — same loop with an injected failing canary
//!   (`dst.inject_bad_canary`): every candidate is applied, probed, and
//!   rolled back at the shard boundary. Headlines: at least one
//!   rollback, zero promotions, and again zero lost replies.
//!
//! `ci/check_bench.py --swap` gates: promoted swaps at or above the
//! baseline floor, zero lost replies in BOTH phases, the rollback path
//! exercised at least once, and no promotion slipping past the bad
//! canary.

use crate::bench::common::{host_info, repo_root_file, BenchCtx, Workload};
use crate::config::AcceleratorConfig;
use crate::coordinator::net::{http_request, metric_value, HttpClient, HttpServer, NetConfig};
use crate::coordinator::{DstServerConfig, EngineOptions, InferenceServer, ServerConfig};
use crate::util::{Json, Table};
use std::net::SocketAddr;
use std::time::{Duration, Instant};

/// `scatter bench swap` configuration.
#[derive(Debug, Clone)]
pub struct SwapBenchConfig {
    /// Promote-phase load duration (the rollback phase runs half).
    pub duration: Duration,
    /// Concurrent keep-alive client connections.
    pub concurrency: usize,
    /// Engine-worker pool size.
    pub workers: usize,
    /// DST stepping period (idle-headroom pacing).
    pub period: Duration,
    /// DST rounds (upper bound on candidate generations).
    pub rounds: usize,
}

impl Default for SwapBenchConfig {
    fn default() -> Self {
        Self {
            duration: Duration::from_secs(4),
            concurrency: 4,
            workers: 2,
            period: Duration::from_millis(2),
            rounds: 40,
        }
    }
}

/// One request outcome: timestamp, status class, per-reply energy.
#[derive(Debug, Clone, Copy)]
struct Event {
    t_s: f64,
    ok: bool,
    shed: bool,
    expired: bool,
    lost: bool,
    energy_mj: f64,
}

/// Closed-loop send loop; every request gets a timestamped outcome and,
/// on a 200, its batched-pass energy share (the before/after-swap
/// energy-per-image headline is client-observed).
fn drive_client(
    addr: SocketAddr,
    bodies: &[String],
    started: Instant,
    deadline: Instant,
    seed: usize,
) -> Vec<Event> {
    let mut events = Vec::new();
    let mut client = match HttpClient::connect(&addr) {
        Ok(c) => c,
        Err(_) => return events,
    };
    let mut i = seed;
    while Instant::now() < deadline {
        let body = &bodies[i % bodies.len()];
        i += 1;
        let mut ev = Event {
            t_s: 0.0,
            ok: false,
            shed: false,
            expired: false,
            lost: false,
            energy_mj: 0.0,
        };
        match client.request("POST", "/v1/predict", Some(body)) {
            Ok(resp) => match resp.status {
                200 => {
                    ev.ok = true;
                    ev.energy_mj = Json::parse(&resp.body)
                        .ok()
                        .and_then(|v| v.get("energy_mj").and_then(Json::as_f64))
                        .unwrap_or(0.0);
                }
                503 => ev.shed = true,
                504 => ev.expired = true,
                _ => ev.lost = true,
            },
            Err(_) => {
                ev.lost = true;
                match HttpClient::connect(&addr) {
                    Ok(c) => client = c,
                    Err(_) => {
                        ev.t_s = started.elapsed().as_secs_f64();
                        events.push(ev);
                        return events;
                    }
                }
            }
        }
        ev.t_s = started.elapsed().as_secs_f64();
        events.push(ev);
    }
    events
}

/// Mean per-reply energy inside `[lo, hi)` seconds; NaN when empty.
fn window_energy(events: &[Event], lo: f64, hi: f64) -> f64 {
    let hits: Vec<f64> = events
        .iter()
        .filter(|e| e.ok && e.t_s >= lo && e.t_s < hi)
        .map(|e| e.energy_mj)
        .collect();
    if hits.is_empty() {
        f64::NAN
    } else {
        hits.iter().sum::<f64>() / hits.len() as f64
    }
}

struct PhaseResult {
    ok: u64,
    shed: u64,
    expired: u64,
    lost: u64,
    swaps: u64,
    rollbacks: u64,
    generation_max: u64,
    mask_power_mw: f64,
    energy_pre_mj: f64,
    energy_post_mj: f64,
    wall_s: f64,
}

/// One serving run with the given DST settings under closed-loop load.
fn run_phase(cfg: &SwapBenchConfig, dst: DstServerConfig, duration: Duration) -> PhaseResult {
    let workers = cfg.workers.max(1);
    let ctx = BenchCtx::new(50);
    let acc = AcceleratorConfig::default();
    let (model, _ds, masks) = ctx.deployment(Workload::Cnn3, &acc, 0.3);
    let server = InferenceServer::spawn(
        model,
        acc,
        EngineOptions::NOISY,
        masks,
        ServerConfig::builder()
            .max_batch(4)
            .batch_timeout(Duration::from_millis(2))
            .workers(workers)
            .dst(dst)
            .build()
            .expect("swap bench config validates"),
    );
    let http = HttpServer::bind(server, NetConfig::default()).expect("bind ephemeral");
    let addr = http.local_addr();

    let ds = crate::data::SyntheticDataset::new(crate::data::DatasetSpec::fmnist_like());
    let bodies: Vec<String> = (0..16)
        .map(|i| {
            let (img, _) = ds.sample(0x51A9, i);
            Json::obj(vec![("image", Json::arr_f64(&img.data))]).to_string()
        })
        .collect();

    let started = Instant::now();
    let deadline = started + duration;
    let events: Vec<Event> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..cfg.concurrency.max(1))
            .map(|c| {
                let bodies = &bodies;
                s.spawn(move || drive_client(addr, bodies, started, deadline, c * 7919))
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().expect("client thread")).collect()
    });
    let wall_s = started.elapsed().as_secs_f64().max(1e-9);

    // live swap gauges, scraped while the server is still up
    let scraped = http_request(&addr, "GET", "/metrics", None)
        .map(|r| r.body)
        .unwrap_or_default();
    let mask_power_mw = metric_value(&scraped, "scatter_mask_power_mw");

    let report = http.shutdown().expect("drain swap server");

    let (mut ok, mut shed, mut expired, mut lost) = (0u64, 0u64, 0u64, 0u64);
    for e in &events {
        ok += u64::from(e.ok);
        shed += u64::from(e.shed);
        expired += u64::from(e.expired);
        lost += u64::from(e.lost);
    }
    let quarter = wall_s / 4.0;
    PhaseResult {
        ok,
        shed,
        expired,
        lost,
        swaps: report.mask_swaps,
        rollbacks: report.mask_rollbacks,
        generation_max: report.mask_generation.iter().copied().max().unwrap_or(0),
        mask_power_mw,
        energy_pre_mj: window_energy(&events, 0.0, quarter),
        energy_post_mj: window_energy(&events, 3.0 * quarter, wall_s),
        wall_s,
    }
}

/// Run the swap bench, print the summary table, write
/// `BENCH_swap.json`, and return the rendered table.
pub fn run(cfg: &SwapBenchConfig) -> String {
    // promote: the canary gate is opened fully so every candidate the
    // DST job emits cuts over — the phase measures the swap machinery
    // (conservation + energy trend), not argmax agreement of a
    // synthetic-fitted model
    let promote = run_phase(
        cfg,
        DstServerConfig {
            enabled: true,
            period: cfg.period,
            rounds: cfg.rounds,
            canary_threshold: 0.0,
            inject_bad_canary: false,
            artifact_dir: None,
        },
        cfg.duration,
    );
    // rollback: every candidate fails its canary by injection and must
    // be rolled back at the shard boundary without touching traffic
    let rollback = run_phase(
        cfg,
        DstServerConfig {
            enabled: true,
            period: cfg.period,
            rounds: cfg.rounds,
            canary_threshold: 0.5,
            inject_bad_canary: true,
            artifact_dir: None,
        },
        cfg.duration / 2,
    );

    let mut table = Table::new("mask hot-swap bench (in-serving DST under load)")
        .header(&["metric", "promote", "rollback (bad canary)"]);
    table.row(vec![
        "duration".into(),
        format!("{:.2} s", promote.wall_s),
        format!("{:.2} s", rollback.wall_s),
    ]);
    table.row(vec![
        "ok / shed / expired / lost".into(),
        format!(
            "{} / {} / {} / {}",
            promote.ok, promote.shed, promote.expired, promote.lost
        ),
        format!(
            "{} / {} / {} / {}",
            rollback.ok, rollback.shed, rollback.expired, rollback.lost
        ),
    ]);
    table.row(vec![
        "mask swaps / rollbacks".into(),
        format!("{} / {}", promote.swaps, promote.rollbacks),
        format!("{} / {}", rollback.swaps, rollback.rollbacks),
    ]);
    table.row(vec![
        "max generation at drain".into(),
        format!("{}", promote.generation_max),
        format!("{}", rollback.generation_max),
    ]);
    table.row(vec![
        "active mask power".into(),
        format!("{:.3} mW", promote.mask_power_mw),
        format!("{:.3} mW", rollback.mask_power_mw),
    ]);
    table.row(vec![
        "energy/img pre → post swap".into(),
        format!("{:.4} → {:.4} mJ", promote.energy_pre_mj, promote.energy_post_mj),
        format!("{:.4} → {:.4} mJ", rollback.energy_pre_mj, rollback.energy_post_mj),
    ]);

    let json = Json::obj(vec![
        ("bench", Json::Str("swap".into())),
        ("host", host_info()),
        ("concurrency", Json::Num(cfg.concurrency.max(1) as f64)),
        ("workers", Json::Num(cfg.workers.max(1) as f64)),
        ("dst_rounds", Json::Num(cfg.rounds as f64)),
        ("duration_s", Json::Num(promote.wall_s)),
        ("requests_ok", Json::Num(promote.ok as f64)),
        ("shed", Json::Num(promote.shed as f64)),
        ("expired", Json::Num(promote.expired as f64)),
        ("lost", Json::Num(promote.lost as f64)),
        ("swaps", Json::Num(promote.swaps as f64)),
        ("rollbacks", Json::Num(promote.rollbacks as f64)),
        ("generation_max", Json::Num(promote.generation_max as f64)),
        ("mask_power_mw", Json::Num(promote.mask_power_mw)),
        ("energy_mj_per_img_pre", Json::Num(promote.energy_pre_mj)),
        ("energy_mj_per_img_post", Json::Num(promote.energy_post_mj)),
        ("rollback_ok", Json::Num(rollback.ok as f64)),
        ("rollback_lost", Json::Num(rollback.lost as f64)),
        ("rollback_swaps", Json::Num(rollback.swaps as f64)),
        ("rollback_rollbacks", Json::Num(rollback.rollbacks as f64)),
        ("rollback_generation_max", Json::Num(rollback.generation_max as f64)),
    ]);
    let path = repo_root_file("BENCH_swap.json");
    match std::fs::write(&path, json.to_string()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
    table.render()
}

//! Synthetic image-classification datasets.
//!
//! The paper evaluates FashionMNIST / CIFAR-10 / CIFAR-100, which are not
//! available in this offline environment (substitution documented in
//! DESIGN.md). The generator below produces deterministic class-structured
//! images: each class owns a random low-frequency template, and samples
//! are the template plus pixel noise and a random shift. The tasks retain
//! the property the paper's tables actually exercise — accuracy is high
//! for matched models and degrades under injected hardware noise.

use crate::nn::Tensor;
use crate::util::XorShiftRng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DatasetSpec {
    pub channels: usize,
    pub height: usize,
    pub width: usize,
    pub n_classes: usize,
    pub seed: u64,
}

impl DatasetSpec {
    /// FashionMNIST-shaped: 1×28×28, 10 classes.
    pub fn fmnist_like() -> Self {
        Self { channels: 1, height: 28, width: 28, n_classes: 10, seed: 0xF31 }
    }

    /// CIFAR-10-shaped: 3×32×32, 10 classes.
    pub fn cifar10_like() -> Self {
        Self { channels: 3, height: 32, width: 32, n_classes: 10, seed: 0xC10 }
    }

    /// CIFAR-100-shaped: 3×32×32, 100 classes.
    pub fn cifar100_like() -> Self {
        Self { channels: 3, height: 32, width: 32, n_classes: 100, seed: 0xC100 }
    }
}

/// Deterministic class-conditional image generator.
#[derive(Debug, Clone)]
pub struct SyntheticDataset {
    pub spec: DatasetSpec,
    /// Per-class low-frequency templates (CHW each).
    templates: Vec<Vec<f64>>,
}

impl SyntheticDataset {
    pub fn new(spec: DatasetSpec) -> Self {
        let mut rng = XorShiftRng::new(spec.seed);
        let n = spec.channels * spec.height * spec.width;
        let mut templates = Vec::with_capacity(spec.n_classes);
        for _ in 0..spec.n_classes {
            // low-frequency template: sum of a few random 2-D cosines
            let mut img = vec![0.0f64; n];
            for _ in 0..4 {
                let fx = rng.uniform_in(0.5, 3.0);
                let fy = rng.uniform_in(0.5, 3.0);
                let phase = rng.uniform_in(0.0, std::f64::consts::TAU);
                let amp = rng.uniform_in(0.4, 1.0);
                let chan_w: Vec<f64> =
                    (0..spec.channels).map(|_| rng.uniform_in(0.3, 1.0)).collect();
                for c in 0..spec.channels {
                    for y in 0..spec.height {
                        for x in 0..spec.width {
                            let v = amp
                                * chan_w[c]
                                * ((fx * x as f64 / spec.width as f64
                                    + fy * y as f64 / spec.height as f64)
                                    * std::f64::consts::TAU
                                    + phase)
                                    .cos();
                            img[(c * spec.height + y) * spec.width + x] += v;
                        }
                    }
                }
            }
            // normalize template into [0, 1]
            let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
            for &v in &img {
                lo = lo.min(v);
                hi = hi.max(v);
            }
            let range = (hi - lo).max(1e-9);
            for v in &mut img {
                *v = (*v - lo) / range;
            }
            templates.push(img);
        }
        Self { spec, templates }
    }

    /// The `idx`-th sample of split `split_seed`: (image, label).
    /// Deterministic in (spec.seed, split_seed, idx).
    pub fn sample(&self, split_seed: u64, idx: usize) -> (Tensor, usize) {
        let mut rng =
            XorShiftRng::new(self.spec.seed ^ split_seed.wrapping_mul(0x9E37) ^ idx as u64);
        let label = rng.index(self.spec.n_classes);
        let (c, h, w) = (self.spec.channels, self.spec.height, self.spec.width);
        let tmpl = &self.templates[label];
        let (dy, dx) = (rng.index(5) as isize - 2, rng.index(5) as isize - 2);
        let mut img = vec![0.0f64; c * h * w];
        for ci in 0..c {
            for y in 0..h {
                for x in 0..w {
                    let sy = (y as isize + dy).clamp(0, h as isize - 1) as usize;
                    let sx = (x as isize + dx).clamp(0, w as isize - 1) as usize;
                    let noise = rng.gaussian_std(0.08);
                    img[(ci * h + y) * w + x] =
                        (tmpl[(ci * h + sy) * w + sx] + noise).clamp(0.0, 1.0);
                }
            }
        }
        (Tensor::from_vec(&[c, h, w], img), label)
    }

    /// A batch of samples.
    pub fn batch(&self, split_seed: u64, start: usize, n: usize) -> Vec<(Tensor, usize)> {
        (start..start + n).map(|i| self.sample(split_seed, i)).collect()
    }

    pub fn templates(&self) -> &[Vec<f64>] {
        &self.templates
    }
}

/// Classification accuracy of `model` over `n` samples of the dataset,
/// run through the given engine.
pub fn evaluate_accuracy(
    model: &crate::nn::Model,
    engine: &mut dyn crate::nn::MatmulEngine,
    ds: &SyntheticDataset,
    split_seed: u64,
    n: usize,
) -> f64 {
    let mut correct = 0usize;
    for i in 0..n {
        let (img, label) = ds.sample(split_seed, i);
        if model.predict(img, engine) == label {
            correct += 1;
        }
    }
    correct as f64 / n.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_samples() {
        let ds = SyntheticDataset::new(DatasetSpec::fmnist_like());
        let (a, la) = ds.sample(1, 42);
        let (b, lb) = ds.sample(1, 42);
        assert_eq!(la, lb);
        assert_eq!(a.data, b.data);
        let (c, _) = ds.sample(2, 42);
        assert_ne!(a.data, c.data, "different split differs");
    }

    #[test]
    fn pixel_range_and_shape() {
        let ds = SyntheticDataset::new(DatasetSpec::cifar10_like());
        let (img, label) = ds.sample(0, 0);
        assert_eq!(img.shape, vec![3, 32, 32]);
        assert!(label < 10);
        assert!(img.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn labels_cover_classes() {
        let ds = SyntheticDataset::new(DatasetSpec::fmnist_like());
        let mut seen = vec![false; 10];
        for i in 0..200 {
            let (_, l) = ds.sample(3, i);
            seen[l] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() >= 9, "most classes present");
    }

    #[test]
    fn classes_are_separable_by_template_matching() {
        // nearest-template classification should be near-perfect -> the
        // synthetic task is learnable.
        let ds = SyntheticDataset::new(DatasetSpec::fmnist_like());
        let mut correct = 0;
        let n = 100;
        for i in 0..n {
            let (img, label) = ds.sample(7, i);
            let mut best = (f64::INFINITY, 0usize);
            for (k, t) in ds.templates.iter().enumerate() {
                let d: f64 =
                    img.data.iter().zip(t).map(|(a, b)| (a - b) * (a - b)).sum();
                if d < best.0 {
                    best = (d, k);
                }
            }
            if best.1 == label {
                correct += 1;
            }
        }
        assert!(correct >= 88, "template matching accuracy {correct}/100");
    }
}

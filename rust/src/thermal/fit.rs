//! Least-squares fitting used by the heat-solver characterization to
//! re-derive the Eq.-10 piecewise γ(d) model (Fig. 4(b)).

/// Fit an (N−1)-degree polynomial to samples via the normal equations,
/// solved with partially-pivoted Gaussian elimination. Returns [c0..c_{N-1}]
/// for c0 + c1·d + … .
pub fn fit_polynomial<const N: usize>(samples: &[(f64, f64)]) -> [f64; N] {
    assert!(samples.len() >= N, "need at least {N} samples");
    // Build A^T A (N x N) and A^T y.
    let mut ata = [[0.0f64; N]; N];
    let mut aty = [0.0f64; N];
    for &(x, y) in samples {
        let mut powers = [0.0f64; N];
        let mut p = 1.0;
        for slot in powers.iter_mut() {
            *slot = p;
            p *= x;
        }
        for i in 0..N {
            aty[i] += powers[i] * y;
            for j in 0..N {
                ata[i][j] += powers[i] * powers[j];
            }
        }
    }
    solve_linear::<N>(&mut ata, &mut aty);
    aty
}

/// Fit y = a0 · exp(−a1 x) by linear regression on ln(y). Samples with
/// non-positive y are skipped. Returns [a0, a1].
pub fn fit_exponential(samples: &[(f64, f64)]) -> [f64; 2] {
    let pts: Vec<(f64, f64)> =
        samples.iter().filter(|(_, y)| *y > 0.0).map(|&(x, y)| (x, y.ln())).collect();
    assert!(pts.len() >= 2, "need at least 2 positive samples");
    let n = pts.len() as f64;
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    let slope = (n * sxy - sx * sy) / (n * sxx - sx * sx);
    let intercept = (sy - slope * sx) / n;
    [intercept.exp(), -slope]
}

/// R² goodness of fit of a model against samples.
pub fn r_squared(samples: &[(f64, f64)], model: impl Fn(f64) -> f64) -> f64 {
    let mean_y: f64 = samples.iter().map(|p| p.1).sum::<f64>() / samples.len() as f64;
    let ss_tot: f64 = samples.iter().map(|p| (p.1 - mean_y).powi(2)).sum();
    let ss_res: f64 = samples.iter().map(|p| (p.1 - model(p.0)).powi(2)).sum();
    if ss_tot == 0.0 {
        1.0
    } else {
        1.0 - ss_res / ss_tot
    }
}

/// In-place Gaussian elimination with partial pivoting: solves A x = b,
/// leaving x in `b`.
fn solve_linear<const N: usize>(a: &mut [[f64; N]; N], b: &mut [f64; N]) {
    for col in 0..N {
        // pivot
        let mut pivot = col;
        for row in col + 1..N {
            if a[row][col].abs() > a[pivot][col].abs() {
                pivot = row;
            }
        }
        if pivot != col {
            a.swap(col, pivot);
            b.swap(col, pivot);
        }
        let diag = a[col][col];
        assert!(diag.abs() > 1e-300, "singular normal matrix");
        for row in col + 1..N {
            let f = a[row][col] / diag;
            for k in col..N {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    // back substitution
    for col in (0..N).rev() {
        let mut acc = b[col];
        for k in col + 1..N {
            acc -= a[col][k] * b[k];
        }
        b[col] = acc / a[col][col];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_exact_quadratic() {
        let samples: Vec<(f64, f64)> =
            (0..20).map(|i| (i as f64, 2.0 + 3.0 * i as f64 + 0.5 * (i * i) as f64)).collect();
        let c = fit_polynomial::<3>(&samples);
        assert!((c[0] - 2.0).abs() < 1e-8);
        assert!((c[1] - 3.0).abs() < 1e-8);
        assert!((c[2] - 0.5).abs() < 1e-8);
    }

    #[test]
    fn recovers_exact_exponential() {
        let samples: Vec<(f64, f64)> =
            (0..30).map(|i| (i as f64, 0.217 * (-0.127 * i as f64).exp())).collect();
        let [a0, a1] = fit_exponential(&samples);
        assert!((a0 - 0.217).abs() < 1e-10);
        assert!((a1 - 0.127).abs() < 1e-10);
    }

    #[test]
    fn r_squared_perfect_and_poor() {
        let samples: Vec<(f64, f64)> = (0..10).map(|i| (i as f64, 2.0 * i as f64)).collect();
        assert!((r_squared(&samples, |x| 2.0 * x) - 1.0).abs() < 1e-12);
        assert!(r_squared(&samples, |_| 0.0) < 0.0); // worse than the mean
    }

    #[test]
    fn refits_paper_gamma_with_high_fidelity() {
        // Sample the paper's own model and re-fit; should recover it.
        let g = crate::thermal::gamma::GammaModel::paper();
        let near: Vec<(f64, f64)> =
            (0..46).map(|i| (i as f64 * 0.5, g.eval(i as f64 * 0.5))).collect();
        let c = fit_polynomial::<6>(&near);
        let refit = crate::thermal::gamma::GammaModel::new(c, [0.217, 0.127], 23.0);
        let r2 = r_squared(&near, |d| refit.eval(d));
        assert!(r2 > 0.995, "R2={r2}");
    }

    #[test]
    #[should_panic]
    fn polynomial_needs_enough_samples() {
        let _ = fit_polynomial::<6>(&[(0.0, 1.0), (1.0, 2.0)]);
    }
}

//! The distance-dependent thermal-coupling coefficient γ(d) (Eq. 10):
//!
//! ```text
//!   γ(d) = Σ_{i=0..5} p_i d^i          for d < 23 µm
//!        = a0 · exp(−a1 · d)           for d ≥ 23 µm
//! ```
//!
//! The paper's published fit (R² = 0.999 / 0.998) is the golden default;
//! `GammaModel::from_samples` re-derives coefficients from heat-solver
//! samples (see `thermal::fit`), reproducing the Fig. 4(b) pipeline.


/// Paper Eq. 10 polynomial coefficients [p0..p5].
pub const PAPER_POLY: [f64; 6] = [1.0, -1.76e-1, 9.9e-3, -8.30e-6, -1.56e-5, 3.55e-7];
/// Paper Eq. 10 exponential coefficients [a0, a1].
pub const PAPER_EXP: [f64; 2] = [0.217, 0.127];
/// Breakpoint between the polynomial and exponential branches (µm).
pub const PAPER_BREAK_UM: f64 = 23.0;

#[derive(Debug, Clone)]
pub struct GammaModel {
    pub poly: [f64; 6],
    pub exp: [f64; 2],
    pub break_um: f64,
}

impl Default for GammaModel {
    fn default() -> Self {
        Self::paper()
    }
}

impl GammaModel {
    /// The paper's published Eq.-10 fit.
    pub fn paper() -> Self {
        Self { poly: PAPER_POLY, exp: PAPER_EXP, break_um: PAPER_BREAK_UM }
    }

    pub fn new(poly: [f64; 6], exp: [f64; 2], break_um: f64) -> Self {
        Self { poly, exp, break_um }
    }

    /// Evaluate γ(d) for a center distance d in µm. Clamped to [0, 1]:
    /// coupling is a passive fraction of the aggressor phase.
    #[inline]
    pub fn eval(&self, d: f64) -> f64 {
        let d = d.max(0.0);
        let v = if d < self.break_um {
            // Horner evaluation of the 5th-order polynomial.
            let p = &self.poly;
            ((((p[5] * d + p[4]) * d + p[3]) * d + p[2]) * d + p[1]) * d + p[0]
        } else {
            self.exp[0] * (-self.exp[1] * d).exp()
        };
        v.clamp(0.0, 1.0)
    }

    /// Differential coupling Δγ between an aggressor heater and the two
    /// arms of a victim MZI (Eq. 8): γ(d_up) − γ(d_lo).
    #[inline]
    pub fn differential(&self, d_up: f64, d_lo: f64) -> f64 {
        self.eval(d_up) - self.eval(d_lo)
    }

    /// Sample the model on a distance grid (for table pre-computation and
    /// the Fig. 4(b) output).
    pub fn sample(&self, d_max: f64, step: f64) -> Vec<(f64, f64)> {
        let mut out = Vec::new();
        let mut d = 0.0;
        while d <= d_max {
            out.push((d, self.eval(d)));
            d += step;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values_of_paper_fit() {
        let g = GammaModel::paper();
        assert!((g.eval(0.0) - 1.0).abs() < 1e-12, "self-coupling is 1");
        // hand-computed points of the published polynomial
        assert!((g.eval(9.0) - 0.13046).abs() < 1e-3, "gamma(9)={}", g.eval(9.0));
        assert!((g.eval(5.0) - 0.35781).abs() < 1e-3, "gamma(5)={}", g.eval(5.0));
        // exponential branch
        let e30 = 0.217 * (-0.127f64 * 30.0).exp();
        assert!((g.eval(30.0) - e30).abs() < 1e-12);
    }

    #[test]
    fn monotone_decreasing_on_physical_range() {
        let g = GammaModel::paper();
        let mut prev = g.eval(0.5);
        let mut d = 1.0;
        while d < 22.0 {
            let v = g.eval(d);
            assert!(v <= prev + 1e-9, "gamma must decay on (0,22): d={d} v={v} prev={prev}");
            prev = v;
            d += 0.5;
        }
        // and the exponential branch always decays
        assert!(g.eval(25.0) > g.eval(40.0));
    }

    #[test]
    fn clamped_to_unit_interval() {
        let g = GammaModel::paper();
        for i in 0..400 {
            let v = g.eval(i as f64 * 0.25);
            assert!((0.0..=1.0).contains(&v));
        }
    }

    #[test]
    fn differential_sign() {
        let g = GammaModel::paper();
        // victim arm closer to aggressor couples more
        assert!(g.differential(5.0, 10.0) > 0.0);
        assert!(g.differential(10.0, 5.0) < 0.0);
        assert_eq!(g.differential(7.0, 7.0), 0.0);
    }

    #[test]
    fn far_field_negligible() {
        let g = GammaModel::paper();
        assert!(g.eval(120.0) < 1e-6, "vertical neighbors (l_v=120) decoupled");
    }
}

//! Thermal crosstalk substrate (§3.2.3, Fig. 4).
//!
//! The paper characterizes heater-to-waveguide thermal coupling with
//! Lumerical HEAT/MODE FEM simulations and reduces it to a distance-only
//! coefficient γ(d) (Eq. 10). We rebuild that pipeline:
//!
//! * [`heatsim`] — a 2-D steady-state heat solver over the chip cross
//!   section (the Lumerical substitute) producing γ-vs-distance samples;
//! * [`fit`] — least-squares fitting of the paper's piecewise model
//!   (5th-order polynomial below 23 µm, exponential above) to those samples;
//! * [`gamma`] — the fitted γ(d) model, shipping the paper's published
//!   coefficients as the golden default;
//! * [`coupling`] — the array-level coupling matrices of Eqs. 8–9 with the
//!   phase-sign-dependent aggressor/victim distances;
//! * [`drift`] — the *runtime* counterpart: time-varying ambient +
//!   activity-dependent self-heating drift over programmed phases, and
//!   the online-recalibration policy that keeps a serving deployment
//!   inside its phase-error budget.

pub mod coupling;
pub mod drift;
pub mod fit;
pub mod gamma;
pub mod heatsim;

pub use coupling::CouplingModel;
pub use drift::{DriftConfig, DriftModel, ThermalPolicy};
pub use gamma::GammaModel;

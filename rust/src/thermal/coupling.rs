//! Array-level inter-MZI thermal coupling (Eqs. 8–9).
//!
//! A `k2 × k1` PTC lays its weight MZIs on a grid: physical row = input
//! index j (vertical pitch `l_v`), physical column = output index i
//! (horizontal pitch `l_h = l_g + node width`). Each MZI has two heater
//! arms separated by `l_s`; which arm is driven depends on the *sign* of
//! the programmed phase (upper for Δφ ≥ 0, lower for Δφ < 0), so the
//! aggressor→victim distance — and therefore the differential coupling
//! Δγ_ij = γ(d_ij^up) − γ(d_ij^lo) — is phase-sign dependent (Eq. 9).
//!
//! We precompute two dense coupling matrices (aggressor-positive and
//! aggressor-negative) so the runtime perturbation is two mat-vecs:
//!
//! ```text
//!   Δφ̃ = Δφ + G⁺ · max(Δφ, 0) + G⁻ · max(−Δφ, 0)
//! ```

use super::gamma::GammaModel;

/// Physical geometry of one PTC's MZI array.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArrayGeometry {
    /// Physical rows (input dim k2).
    pub rows: usize,
    /// Physical columns (output dim k1).
    pub cols: usize,
    /// Vertical pitch l_v (µm).
    pub l_v: f64,
    /// Horizontal pitch l_h (µm) — gap + node width.
    pub l_h: f64,
    /// Arm spacing l_s (µm).
    pub l_s: f64,
}

impl ArrayGeometry {
    pub fn from_config(cfg: &crate::AcceleratorConfig) -> Self {
        Self { rows: cfg.k2, cols: cfg.k1, l_v: cfg.l_v, l_h: cfg.l_h(), l_s: cfg.l_s }
    }

    pub fn n(&self) -> usize {
        self.rows * self.cols
    }

    /// (row, col) of flat index m (row-major: m = row·cols + col).
    #[inline]
    pub fn rc(&self, m: usize) -> (isize, isize) {
        ((m / self.cols) as isize, (m % self.cols) as isize)
    }
}

/// Precomputed phase-sign-dependent coupling matrices for one geometry.
///
/// Coupling is *local* (γ decays exponentially; vertical neighbours at
/// l_v = 120 µm are below the cutoff), so besides the dense matrices —
/// kept for AOT export parity with the Pallas kernel — a CSR form stores
/// only the ~10 % nonzero entries; `perturb_phases` walks the CSR and is
/// ~8× faster than the dense mat-vec (see EXPERIMENTS.md §Perf).
#[derive(Debug, Clone)]
pub struct CouplingModel {
    pub geom: ArrayGeometry,
    /// Δγ for positive-phase aggressors, row-major [victim][aggressor].
    g_pos: Vec<f64>,
    /// Δγ for negative-phase aggressors.
    g_neg: Vec<f64>,
    /// CSR over the union sparsity pattern: row offsets into `entries`.
    row_ptr: Vec<usize>,
    /// (aggressor index, Δγ⁺, Δγ⁻) nonzero entries.
    entries: Vec<(u32, f64, f64)>,
}

impl CouplingModel {
    /// Build the coupling matrices from Eq. 9 distances and the γ(d) model.
    ///
    /// Couplings below `cutoff` are truncated to exact zero, which keeps
    /// the matrices numerically sparse for far-apart pairs (γ decays
    /// exponentially; beyond ~60 µm contributions are < 1e-4).
    pub fn new(geom: ArrayGeometry, gamma: &GammaModel) -> Self {
        Self::with_cutoff(geom, gamma, 1e-6)
    }

    pub fn with_cutoff(geom: ArrayGeometry, gamma: &GammaModel, cutoff: f64) -> Self {
        let n = geom.n();
        let mut g_pos = vec![0.0f64; n * n];
        let mut g_neg = vec![0.0f64; n * n];
        for i in 0..n {
            let (ri, ci) = geom.rc(i);
            for j in 0..n {
                if i == j {
                    continue; // intra-MZI handled in the device power model
                }
                let (rj, cj) = geom.rc(j);
                let dy = (rj - ri) as f64 * geom.l_v;
                let dx = (cj - ci) as f64 * geom.l_h;
                // Eq. 9, aggressor positive (upper arm heated):
                //   d_up: indicator(Δφ_j < 0) = 0  -> dx
                //   d_lo: indicator(Δφ_j ≥ 0) = 1  -> dx + l_s
                let d_up_pos = (dy * dy + dx * dx).sqrt();
                let d_lo_pos = {
                    let h = dx + geom.l_s;
                    (dy * dy + h * h).sqrt()
                };
                // aggressor negative (lower arm heated):
                //   d_up: dx − l_s ; d_lo: dx
                let d_up_neg = {
                    let h = dx - geom.l_s;
                    (dy * dy + h * h).sqrt()
                };
                let d_lo_neg = d_up_pos;
                let gp = gamma.differential(d_up_pos, d_lo_pos);
                let gn = gamma.differential(d_up_neg, d_lo_neg);
                if gp.abs() >= cutoff {
                    g_pos[i * n + j] = gp;
                }
                if gn.abs() >= cutoff {
                    g_neg[i * n + j] = gn;
                }
            }
        }
        // CSR over the union pattern
        let mut row_ptr = Vec::with_capacity(n + 1);
        let mut entries = Vec::new();
        row_ptr.push(0);
        for i in 0..n {
            for j in 0..n {
                let (gp, gn) = (g_pos[i * n + j], g_neg[i * n + j]);
                if gp != 0.0 || gn != 0.0 {
                    entries.push((j as u32, gp, gn));
                }
            }
            row_ptr.push(entries.len());
        }
        Self { geom, g_pos, g_neg, row_ptr, entries }
    }

    /// Coupling entries for a (victim, aggressor) pair.
    pub fn entry(&self, victim: usize, aggressor: usize, aggressor_positive: bool) -> f64 {
        let n = self.geom.n();
        if aggressor_positive {
            self.g_pos[victim * n + aggressor]
        } else {
            self.g_neg[victim * n + aggressor]
        }
    }

    /// Apply Eq. 8: perturb a flat phase vector (row-major over the array)
    /// into `out`. `phases.len() == out.len() == rows·cols`. Walks only
    /// the CSR nonzeros.
    pub fn perturb_phases(&self, phases: &[f64], out: &mut [f64]) {
        let n = self.geom.n();
        assert_eq!(phases.len(), n, "phase vector must match array size");
        assert_eq!(out.len(), n);
        for i in 0..n {
            let mut acc = phases[i];
            for &(j, gp, gn) in &self.entries[self.row_ptr[i]..self.row_ptr[i + 1]] {
                let p = phases[j as usize];
                // Δγ(sign_j)·|Δφ_j|: gp for positive aggressors, gn negative
                if p >= 0.0 {
                    acc += gp * p;
                } else {
                    acc -= gn * p;
                }
            }
            out[i] = acc;
        }
    }

    /// Fraction of nonzero coupling entries (diagnostics / perf notes).
    pub fn nnz_fraction(&self) -> f64 {
        let n = self.geom.n();
        self.entries.len() as f64 / (n * n) as f64
    }

    /// Convenience: perturbed copy.
    pub fn perturbed(&self, phases: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; phases.len()];
        self.perturb_phases(phases, &mut out);
        out
    }

    /// Worst-case total coupling magnitude seen by any victim — a scalar
    /// "how bad is this geometry" indicator used by Fig. 4(e).
    pub fn worst_case_coupling(&self) -> f64 {
        let n = self.geom.n();
        (0..n)
            .map(|i| {
                (0..n)
                    .map(|j| self.g_pos[i * n + j].abs().max(self.g_neg[i * n + j].abs()))
                    .sum::<f64>()
            })
            .fold(0.0, f64::max)
    }

    /// Export the dense positive/negative matrices (row-major) — consumed
    /// by the AOT path so the Pallas kernel sees the identical model.
    pub fn matrices(&self) -> (&[f64], &[f64]) {
        (&self.g_pos, &self.g_neg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::thermal::gamma::GammaModel;

    fn geom(rows: usize, cols: usize, l_h: f64) -> ArrayGeometry {
        ArrayGeometry { rows, cols, l_v: 120.0, l_h, l_s: 9.0 }
    }

    #[test]
    fn zero_phases_unperturbed() {
        let m = CouplingModel::new(geom(4, 4, 20.0), &GammaModel::paper());
        let phases = vec![0.0; 16];
        let out = m.perturbed(&phases);
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn self_coupling_excluded() {
        let m = CouplingModel::new(geom(2, 2, 20.0), &GammaModel::paper());
        for i in 0..4 {
            assert_eq!(m.entry(i, i, true), 0.0);
            assert_eq!(m.entry(i, i, false), 0.0);
        }
    }

    #[test]
    fn single_aggressor_perturbs_horizontal_neighbor() {
        // one row, two MZIs side by side at l_h = 20 µm
        let m = CouplingModel::new(geom(1, 2, 20.0), &GammaModel::paper());
        let mut phases = vec![0.0, 1.0]; // aggressor at col 1, positive
        let out = m.perturbed(&phases);
        // victim 0 picks up γ(d_up) − γ(d_lo) with d_up = 20, d_lo = 29
        let g = GammaModel::paper();
        let expect = g.differential(20.0, 29.0) * 1.0;
        assert!((out[0] - expect).abs() < 1e-12, "{} vs {expect}", out[0]);
        assert!(out[0] > 0.0, "positive aggressor drags victim positive");
        // negative aggressor: heated lower arm is *closer* to victim 0? it
        // sits at dx − l_s = 11 µm from victim's upper arm, 20 from lower
        phases = vec![0.0, -1.0];
        let out = m.perturbed(&phases);
        let expect = g.differential(11.0, 20.0) * 1.0;
        assert!((out[0] - expect).abs() < 1e-12);
    }

    #[test]
    fn vertical_neighbors_negligible() {
        // l_v = 120 µm: same-column (vertical) MZIs barely couple
        let m = CouplingModel::new(geom(2, 1, 20.0), &GammaModel::paper());
        let out = m.perturbed(&[0.0, 1.5]);
        assert!(out[0].abs() < 1e-4, "vertical coupling should be tiny: {}", out[0]);
    }

    #[test]
    fn closer_pitch_couples_more() {
        let g = GammaModel::paper();
        let near = CouplingModel::new(geom(1, 2, 16.0), &g);
        let far = CouplingModel::new(geom(1, 2, 40.0), &g);
        let pn = near.perturbed(&[0.0, 1.0])[0].abs();
        let pf = far.perturbed(&[0.0, 1.0])[0].abs();
        assert!(pn > pf, "near={pn} far={pf}");
    }

    #[test]
    fn worst_case_monotone_in_pitch() {
        let g = GammaModel::paper();
        let w16 = CouplingModel::new(geom(4, 4, 16.0), &g).worst_case_coupling();
        let w22 = CouplingModel::new(geom(4, 4, 22.0), &g).worst_case_coupling();
        let w35 = CouplingModel::new(geom(4, 4, 35.0), &g).worst_case_coupling();
        assert!(w16 > w22 && w22 > w35, "{w16} {w22} {w35}");
    }

    #[test]
    fn interleaved_pattern_reduces_aggression() {
        // Fig. 9(a): gating alternate physical columns (row-sparsity with
        // interleaved 1s) should reduce perturbation on the active ones.
        let g = GammaModel::paper();
        let m = CouplingModel::new(geom(1, 8, 16.0), &g);
        let dense: Vec<f64> = (0..8).map(|_| 0.8).collect();
        let mut inter = dense.clone();
        for j in (1..8).step_by(2) {
            inter[j] = 0.0; // powered-off MZIs aggress nothing
        }
        let pd = m.perturbed(&dense);
        let pi = m.perturbed(&inter);
        let err_dense: f64 =
            (0..8).step_by(2).map(|i| (pd[i] - dense[i]).abs()).sum();
        let err_inter: f64 =
            (0..8).step_by(2).map(|i| (pi[i] - inter[i]).abs()).sum();
        assert!(
            err_inter < err_dense * 0.7,
            "interleaving should cut crosstalk: {err_inter} vs {err_dense}"
        );
    }

    #[test]
    fn matrices_shapes() {
        let m = CouplingModel::new(geom(3, 5, 20.0), &GammaModel::paper());
        let (p, n) = m.matrices();
        assert_eq!(p.len(), 15 * 15);
        assert_eq!(n.len(), 15 * 15);
    }
}

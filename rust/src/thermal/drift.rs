//! Time-varying thermal drift and the online-recalibration policy.
//!
//! Eqs. 8–9 are applied once, at programming time; this module models
//! what happens *afterwards*: a long-running accelerator sits in an
//! ambient that ramps slowly (HVAC cycles, neighbouring boards) and
//! self-heats with served traffic, so the realized phases walk away from
//! their programmed values. ENLighten (arXiv 2510.01673) treats this
//! runtime thermal loop as a first-class system concern; SCATTER's
//! redistribution hardware makes the *recovery* cheap — recalibrating a
//! chunk re-realizes only its programmed MZI phases and recompiles its
//! execution plan, while the masks, rerouter trees, quantization and
//! gain tables compiled at `program_layer` time are untouched.
//!
//! The model is deliberately simple and fully deterministic:
//!
//! ```text
//!   env(t, n)   = A_a·(sin(2π·t/T + φ₀) − sin φ₀)  ambient ramp (rad)
//!               + A_s·(1 − exp(−n/τ))              self-heating (rad)
//!   Δφ_m(t, n)  = env(t, n) · pattern_m            per-MZI offset
//! ```
//!
//! `t` is (virtual) seconds since programming, `n` requests served by
//! this engine worker. `pattern_m` is a fixed per-node susceptibility
//! fingerprint (positive, counter-based from the seed, per-chunk gain ×
//! per-node variation) so different chunks cross a phase-error budget at
//! different times — the property that makes *incremental*
//! recalibration pay off over a full re-program. `φ₀` is a per-worker
//! ambient phase, so replicas behind one router drift independently
//! (the `− sin φ₀` term anchors env(0, 0) = 0: drift is deviation
//! *since calibration*).

use crate::util::XorShiftRng;
use std::f64::consts::TAU;

/// When/how engine workers recalibrate against drift.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum ThermalPolicy {
    /// Never recalibrate (the drift still applies — this is the
    /// "one-shot calibration" failure mode the subsystem exists to fix).
    #[default]
    Off,
    /// Recalibrate every programmed chunk every `every_requests` served
    /// requests, drifted or not.
    Periodic { every_requests: u64 },
    /// Recalibrate a chunk when its estimated phase error exceeds
    /// `budget_rad` — only the chunks over budget are touched.
    Threshold { budget_rad: f64 },
}

/// Drift-model parameters. All phase quantities are radians.
#[derive(Debug, Clone)]
pub struct DriftConfig {
    /// Base seed for the susceptibility fingerprints and the per-worker
    /// ambient phase (independent of the engine's noise seed).
    pub seed: u64,
    /// Stream id of the engine worker owning this model; replicas get
    /// distinct ambient phases and fingerprints.
    pub worker_id: u64,
    /// Peak ambient phase drift A_a.
    pub ambient_amp_rad: f64,
    /// Ambient ramp period T (virtual seconds).
    pub ambient_period_s: f64,
    /// Asymptotic self-heating phase drift A_s.
    pub self_heat_amp_rad: f64,
    /// Served-request count τ to reach ~63 % of A_s.
    pub self_heat_tau_reqs: f64,
    /// Minimum |env| change before drifted weights are re-realized
    /// (bounds how often the physics update recompiles plans).
    pub apply_eps_rad: f64,
    /// Wall-clock → virtual-time multiplier used by serving workers
    /// (benches/tests accelerate drift without waiting; 0 freezes the
    /// ambient term so only self-heating drives env — deterministic).
    pub time_scale: f64,
}

impl Default for DriftConfig {
    fn default() -> Self {
        Self {
            seed: 0xD21F7,
            worker_id: 0,
            ambient_amp_rad: 0.08,
            ambient_period_s: 120.0,
            self_heat_amp_rad: 0.05,
            self_heat_tau_reqs: 256.0,
            apply_eps_rad: 2e-3,
            time_scale: 1.0,
        }
    }
}

impl DriftConfig {
    /// Aggressive schedule for benches and tests: drift large enough to
    /// visibly break an uncompensated deployment within tens of requests
    /// / a couple of virtual minutes.
    pub fn accelerated() -> Self {
        Self {
            ambient_amp_rad: 0.35,
            ambient_period_s: 40.0,
            self_heat_amp_rad: 0.20,
            self_heat_tau_reqs: 24.0,
            ..Self::default()
        }
    }
}

/// Deterministic drift generator for one engine worker.
#[derive(Debug, Clone)]
pub struct DriftModel {
    cfg: DriftConfig,
    /// Per-worker ambient phase φ₀.
    phase0: f64,
}

impl DriftModel {
    pub fn new(cfg: DriftConfig) -> Self {
        let phase0 =
            XorShiftRng::from_stream(cfg.seed, &[cfg.worker_id]).uniform_in(0.0, TAU);
        Self { cfg, phase0 }
    }

    pub fn config(&self) -> &DriftConfig {
        &self.cfg
    }

    /// Drift envelope (rad) at virtual time `t_s` after `served`
    /// requests. `env(0, 0) == 0` by construction.
    pub fn env(&self, t_s: f64, served: u64) -> f64 {
        let c = &self.cfg;
        let ambient = if c.ambient_period_s > 0.0 {
            let arg = TAU * t_s / c.ambient_period_s + self.phase0;
            c.ambient_amp_rad * (arg.sin() - self.phase0.sin())
        } else {
            0.0
        };
        let heat = if c.self_heat_tau_reqs > 0.0 {
            c.self_heat_amp_rad * (1.0 - (-(served as f64) / c.self_heat_tau_reqs).exp())
        } else {
            0.0
        };
        ambient + heat
    }

    /// Per-chunk thermal-environment gain in [0.3, 1) — how close this
    /// chunk's physical slot sits to the hot spots.
    fn chunk_gain(&self, layer_id: u64, chunk: u64) -> f64 {
        XorShiftRng::from_stream(self.cfg.seed, &[self.cfg.worker_id, layer_id, chunk])
            .uniform_in(0.3, 1.0)
    }

    /// Per-node susceptibility fingerprints for all `blocks` PTC blocks
    /// of one chunk: the chunk gain (derived once) times per-node
    /// variation in [0.35, 1). Counter-based: the same (worker, layer,
    /// chunk, block) tuple always yields the same fingerprint.
    pub fn chunk_patterns(
        &self,
        layer_id: u64,
        chunk: u64,
        blocks: usize,
        n: usize,
    ) -> Vec<Vec<f64>> {
        let gain = self.chunk_gain(layer_id, chunk);
        (0..blocks)
            .map(|block| {
                let mut rng = XorShiftRng::from_stream(
                    self.cfg.seed,
                    &[self.cfg.worker_id, layer_id, chunk, block as u64],
                );
                (0..n).map(|_| gain * rng.uniform_in(0.35, 1.0)).collect()
            })
            .collect()
    }

    /// Single-block fingerprint — identical to the matching entry of
    /// [`Self::chunk_patterns`] (diagnostics/tests).
    pub fn block_pattern(
        &self,
        layer_id: u64,
        chunk: u64,
        block: u64,
        n: usize,
    ) -> Vec<f64> {
        let gain = self.chunk_gain(layer_id, chunk);
        let mut rng = XorShiftRng::from_stream(
            self.cfg.seed,
            &[self.cfg.worker_id, layer_id, chunk, block],
        );
        (0..n).map(|_| gain * rng.uniform_in(0.35, 1.0)).collect()
    }
}

/// Stable stream id for a layer name (FNV-1a), so fingerprints survive
/// re-programming and differ across layers.
pub fn layer_stream_id(name: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_zero_at_calibration_point() {
        let m = DriftModel::new(DriftConfig::accelerated());
        assert_eq!(m.env(0.0, 0), 0.0);
    }

    #[test]
    fn env_deterministic_and_worker_dependent() {
        let a = DriftModel::new(DriftConfig { worker_id: 0, ..DriftConfig::accelerated() });
        let b = DriftModel::new(DriftConfig { worker_id: 0, ..DriftConfig::accelerated() });
        let c = DriftModel::new(DriftConfig { worker_id: 1, ..DriftConfig::accelerated() });
        assert_eq!(a.env(13.0, 40), b.env(13.0, 40));
        assert_ne!(a.env(13.0, 40), c.env(13.0, 40), "workers drift independently");
    }

    #[test]
    fn self_heating_saturates_monotonically() {
        let cfg = DriftConfig {
            ambient_amp_rad: 0.0, // isolate the self-heating term
            self_heat_amp_rad: 0.2,
            self_heat_tau_reqs: 24.0,
            ..DriftConfig::default()
        };
        let m = DriftModel::new(cfg);
        let mut prev = -1.0;
        for n in [0u64, 1, 8, 24, 100, 10_000] {
            let e = m.env(0.0, n);
            assert!(e >= prev, "self-heating must be monotone");
            assert!(e <= 0.2 + 1e-12, "bounded by the amplitude");
            prev = e;
        }
        assert!((m.env(0.0, 1_000_000) - 0.2).abs() < 1e-9, "saturates at A_s");
    }

    #[test]
    fn ambient_bounded_by_twice_amplitude() {
        let m = DriftModel::new(DriftConfig {
            self_heat_amp_rad: 0.0,
            ambient_amp_rad: 0.35,
            ..DriftConfig::accelerated()
        });
        for i in 0..200 {
            let e = m.env(i as f64 * 0.7, 0);
            assert!(e.abs() <= 2.0 * 0.35 + 1e-12, "|env|={e}");
        }
    }

    #[test]
    fn time_frozen_leaves_only_self_heating() {
        // time_scale = 0 callers pass t = 0: the ambient term vanishes
        // and env depends only on the served count (fully deterministic).
        let m = DriftModel::new(DriftConfig::accelerated());
        let pure_heat = m.cfg.self_heat_amp_rad
            * (1.0 - (-(40.0) / m.cfg.self_heat_tau_reqs).exp());
        assert!((m.env(0.0, 40) - pure_heat).abs() < 1e-12);
    }

    #[test]
    fn patterns_positive_bounded_and_counter_based() {
        let m = DriftModel::new(DriftConfig::default());
        let a = m.block_pattern(7, 2, 3, 256);
        let b = m.block_pattern(7, 2, 3, 256);
        assert_eq!(a, b, "same ids reproduce the fingerprint");
        assert!(a.iter().all(|&v| v > 0.0 && v < 1.0));
        let c = m.block_pattern(7, 2, 4, 256);
        assert_ne!(a, c, "different block, different fingerprint");
        // per-chunk gain: nodes of one chunk share a scale factor, so
        // two chunks' mean susceptibilities must differ measurably
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        let other = m.block_pattern(7, 9, 3, 256);
        assert!((mean(&a) - mean(&other)).abs() > 1e-3, "chunk gains spread");
    }

    #[test]
    fn chunk_patterns_match_per_block_derivation() {
        let m = DriftModel::new(DriftConfig::default());
        let all = m.chunk_patterns(7, 2, 4, 64);
        assert_eq!(all.len(), 4);
        for (b, pattern) in all.iter().enumerate() {
            assert_eq!(pattern, &m.block_pattern(7, 2, b as u64, 64), "block {b}");
        }
    }

    #[test]
    fn layer_ids_distinct() {
        assert_ne!(layer_stream_id("conv1"), layer_stream_id("conv2"));
        assert_eq!(layer_stream_id("fc"), layer_stream_id("fc"));
    }
}

//! 2-D steady-state heat solver — the in-repo substitute for the paper's
//! Lumerical HEAT characterization (Fig. 4(a,b)).
//!
//! We model the chip cross-section perpendicular to the waveguides:
//! a TiN micro-heater strip sits on the oxide surface, the silicon
//! waveguide core lies `cladding_um` below, the silicon substrate at the
//! bottom is an isothermal heat sink. The steady-state temperature field
//! solves ∇·(κ∇T) = −q with successive over-relaxation (SOR); the induced
//! phase shift of a waveguide at lateral offset `d` is proportional to the
//! temperature at its core (thermo-optic effect, dn/dT ≈ 1.8e-4 /K for Si).
//!
//! The coupling coefficient is the *ratio* γ(d) = Δφ(d)/Δφ(0) =
//! T(d)/T(0), which is exactly how the paper defines γ ("with the same
//! spacing, γ ∝ Δφ_i/Δφ_j is constant ... only a function of spacing").

use super::fit::{fit_exponential, fit_polynomial};
use super::gamma::GammaModel;

/// Material stack and grid parameters for the cross-section solve.
#[derive(Debug, Clone)]
pub struct HeatSimConfig {
    /// Lateral half-width of the simulated domain (µm).
    pub half_width_um: f64,
    /// Domain depth from heater plane to substrate sink (µm).
    pub depth_um: f64,
    /// Grid pitch (µm).
    pub dx_um: f64,
    /// Heater strip width (µm).
    pub heater_width_um: f64,
    /// Oxide thickness between heater and waveguide core (µm).
    pub cladding_um: f64,
    /// Thermal conductivity of the oxide cladding (W/m/K).
    pub k_oxide: f64,
    /// Thermal conductivity of silicon (substrate/device layer).
    pub k_silicon: f64,
    /// SOR relaxation factor.
    pub omega: f64,
    /// Convergence threshold on max update.
    pub tol: f64,
    /// Iteration cap.
    pub max_iters: usize,
}

impl Default for HeatSimConfig {
    fn default() -> Self {
        Self {
            half_width_um: 60.0,
            depth_um: 12.0,
            dx_um: 0.5,
            heater_width_um: 2.0,
            cladding_um: 2.0,
            k_oxide: 1.4,
            k_silicon: 140.0,
            omega: 1.85,
            tol: 1e-7,
            max_iters: 20_000,
        }
    }
}

/// Result of one cross-section solve.
#[derive(Debug, Clone)]
pub struct HeatField {
    pub nx: usize,
    pub ny: usize,
    pub dx_um: f64,
    /// Temperature rise field, row-major [ny][nx], arbitrary units.
    pub t: Vec<f64>,
    cfg: HeatSimConfig,
}

impl HeatField {
    /// Temperature at the waveguide plane, lateral offset `d` µm from the
    /// heater center (linear interpolation).
    pub fn waveguide_temp(&self, d: f64) -> f64 {
        let y = (self.cfg.cladding_um / self.dx_um).round() as usize;
        let y = y.min(self.ny - 1);
        let xc = (self.nx / 2) as f64;
        let xf = xc + d / self.dx_um;
        let x0 = xf.floor().max(0.0) as usize;
        let x1 = (x0 + 1).min(self.nx - 1);
        let frac = (xf - x0 as f64).clamp(0.0, 1.0);
        let row = &self.t[y * self.nx..(y + 1) * self.nx];
        row[x0.min(self.nx - 1)] * (1.0 - frac) + row[x1] * frac
    }
}

/// Solve the steady-state temperature field for a single heater at the
/// center of the domain driven with unit power density.
pub fn solve(cfg: &HeatSimConfig) -> HeatField {
    let nx = (2.0 * cfg.half_width_um / cfg.dx_um).round() as usize + 1;
    let ny = (cfg.depth_um / cfg.dx_um).round() as usize + 1;
    let mut t = vec![0.0f64; nx * ny];
    // conductivity map: oxide above the substrate interface, silicon below
    let si_start = ((cfg.depth_um - 2.0) / cfg.dx_um).round() as usize; // 2 µm Si handle top
    let kappa = |y: usize| -> f64 {
        if y >= si_start {
            cfg.k_silicon
        } else {
            cfg.k_oxide
        }
    };
    // heater source cells: top row, centered strip
    let hw_cells = (cfg.heater_width_um / cfg.dx_um / 2.0).round() as isize;
    let xc = (nx / 2) as isize;
    let q = 1.0; // unit volumetric source
    let mut iter = 0;
    loop {
        let mut max_delta = 0.0f64;
        for y in 0..ny {
            for x in 0..nx {
                // Dirichlet sink at the bottom boundary (substrate) and at
                // the lateral edges (far-field); insulating (mirror) at top.
                if y == ny - 1 || x == 0 || x == nx - 1 {
                    continue; // stays 0
                }
                let idx = y * nx + x;
                let k_here = kappa(y);
                let up = if y == 0 { t[idx + nx] } else { t[idx - nx] }; // mirror at top
                let down = t[idx + nx];
                let left = t[idx - 1];
                let right = t[idx + 1];
                let mut src = 0.0;
                if y == 0 && (x as isize - xc).abs() <= hw_cells {
                    src = q * cfg.dx_um * cfg.dx_um / k_here;
                }
                let new = 0.25 * (up + down + left + right + src);
                let relaxed = t[idx] + cfg.omega * (new - t[idx]);
                let delta = (relaxed - t[idx]).abs();
                if delta > max_delta {
                    max_delta = delta;
                }
                t[idx] = relaxed;
            }
        }
        iter += 1;
        if max_delta < cfg.tol || iter >= cfg.max_iters {
            break;
        }
    }
    HeatField { nx, ny, dx_um: cfg.dx_um, t, cfg: cfg.clone() }
}

/// Run the full Fig.-4(b) pipeline: solve the field once, sample
/// γ(d) = T(d)/T(0) on a distance grid, and fit the paper's piecewise
/// model (poly below `break_um`, exponential above).
pub fn characterize(cfg: &HeatSimConfig, break_um: f64) -> (Vec<(f64, f64)>, GammaModel) {
    let field = solve(cfg);
    let t0 = field.waveguide_temp(0.0);
    let mut samples = Vec::new();
    let mut d = 0.0;
    while d <= cfg.half_width_um * 0.8 {
        samples.push((d, (field.waveguide_temp(d) / t0).clamp(0.0, 1.0)));
        d += 1.0;
    }
    let near: Vec<(f64, f64)> =
        samples.iter().copied().filter(|(d, _)| *d < break_um).collect();
    let far: Vec<(f64, f64)> =
        samples.iter().copied().filter(|(d, g)| *d >= break_um && *g > 1e-12).collect();
    let poly = fit_polynomial::<6>(&near);
    let exp = fit_exponential(&far);
    (samples, GammaModel::new(poly, exp, break_um))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> HeatSimConfig {
        HeatSimConfig {
            half_width_um: 40.0,
            depth_um: 10.0,
            dx_um: 1.0,
            max_iters: 5_000,
            tol: 1e-8,
            ..Default::default()
        }
    }

    #[test]
    fn field_peaks_under_heater_and_decays() {
        let f = solve(&small_cfg());
        let t0 = f.waveguide_temp(0.0);
        assert!(t0 > 0.0);
        let t5 = f.waveguide_temp(5.0);
        let t15 = f.waveguide_temp(15.0);
        let t30 = f.waveguide_temp(30.0);
        assert!(t0 > t5 && t5 > t15 && t15 > t30, "{t0} {t5} {t15} {t30}");
    }

    #[test]
    fn field_is_symmetric() {
        let f = solve(&small_cfg());
        for d in [3.0, 7.0, 12.0] {
            let a = f.waveguide_temp(d);
            let b = f.waveguide_temp(-d);
            assert!((a - b).abs() < 1e-6 * a.max(1e-12), "asymmetry at {d}");
        }
    }

    #[test]
    fn characterization_yields_decaying_fit() {
        let (samples, model) = characterize(&small_cfg(), 20.0);
        assert!(samples.len() > 20);
        // fitted model reproduces the samples reasonably (it's our own fit)
        for (d, g) in samples.iter().filter(|(d, _)| *d > 1.0 && *d < 30.0) {
            let m = model.eval(*d);
            assert!((m - g).abs() < 0.08, "fit deviates at d={d}: {m} vs {g}");
        }
        // γ(0) ≈ 1 by construction
        assert!((model.eval(0.0) - 1.0).abs() < 0.05);
        // decays with distance like the paper's curve
        assert!(model.eval(5.0) > model.eval(15.0));
        assert!(model.eval(25.0) > model.eval(35.0));
    }
}

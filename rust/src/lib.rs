//! # SCATTER — algorithm-circuit co-sparse photonic accelerator
//!
//! Rust implementation of the SCATTER accelerator (Yin et al., 2024):
//! a multi-core incoherent photonic tensor-core (PTC) architecture with
//! in-situ light redistribution (LR), input gating (IG), output TIA/ADC
//! gating (OG), a hybrid electronic-optic DAC, and power/crosstalk-aware
//! structured sparsity.
//!
//! This crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L1** — a Pallas kernel (`python/compile/kernels/photonic_mvm.py`)
//!   models the noisy photonic crossbar MVM and is AOT-lowered to HLO.
//! * **L2** — a JAX model (`python/compile/model.py`) expresses the CNNs
//!   as blocked PTC matmuls; `python/compile/dst.py` runs Algorithm 1
//!   (power/crosstalk-aware dynamic sparse training) at build time.
//! * **L3** — this crate: the accelerator digital twin (device, thermal,
//!   power, area models), the cycle-level multi-core scheduler, gating and
//!   rerouter control, the power-aware mask optimizer, the
//!   sparsity-compiled parallel execution layer (`exec`), a threaded
//!   batched inference service, and the benchmark harness that regenerates
//!   every table and figure in the paper's evaluation.
//!
//! Python never runs on the request path: the `runtime` module loads the
//! AOT artifacts (HLO text) via the PJRT C API (`xla` crate) and executes
//! them natively; the pure-rust `ptc` simulator provides the fast sweep
//! path and is cross-validated against the artifacts.
//!
//! ## Units
//!
//! Lengths are **µm**, powers **mW**, areas **mm²**, frequencies **GHz**,
//! energies per-op **pJ**, total energies **mJ**, phases **radians**.

// Numeric-twin idiom: explicit index loops mirror the paper's blocked-
// matrix equations (row/column math stays visible), device constructors
// take the full parameter tuple, and constants carry the paper's printed
// precision. Clippy's iterator/arg-struct rewrites would obscure the
// correspondence, so those style lints are opted out crate-wide; the CI
// clippy job (-D warnings) enforces everything else.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::manual_memcpy)]
#![allow(clippy::new_without_default)]
#![allow(clippy::excessive_precision)]

pub mod area;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod devices;
pub mod exec;
pub mod nn;
pub mod power;
pub mod ptc;
pub mod quant;
pub mod rerouter;
pub mod runtime;
pub mod sparsity;
pub mod thermal;
pub mod util;

pub use config::AcceleratorConfig;

/// Crate-wide error type. Display/Error are hand-implemented — the
/// offline toolchain has no thiserror.
#[derive(Debug)]
pub enum Error {
    Config(String),
    Shape(String),
    Io(std::io::Error),
    Serde(String),
    Runtime(String),
    /// Admission control shed the request: the inference server is at
    /// its in-flight cap. Carries the suggested client back-off (the
    /// HTTP front-end maps this to `503` + `Retry-After`).
    Busy { retry_after_ms: u64 },
    Other(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Config(m) => write!(f, "configuration error: {m}"),
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Serde(m) => write!(f, "serialization error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Busy { retry_after_ms } => {
                write!(f, "server busy (admission cap reached): retry after {retry_after_ms} ms")
            }
            Error::Other(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

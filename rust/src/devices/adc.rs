//! Readout ADC model (§3.2.1, Eq. 4): `P_ADC(b_o, f) = P0_ADC · b_o · f` —
//! linear in both output resolution and sampling frequency.


#[derive(Debug, Clone, Copy)]
pub struct Adc {
    pub bits: u8,
    pub freq_ghz: f64,
    /// P0 coefficient in pJ/bit (see `DeviceLibrary::adc_p0_pj`).
    pub p0_pj: f64,
}

impl Adc {
    pub fn new(bits: u8, freq_ghz: f64, p0_pj: f64) -> Self {
        Self { bits, freq_ghz, p0_pj }
    }

    /// Power in mW: P0[pJ/bit] · b · f[GHz].
    pub fn power_mw(&self) -> f64 {
        self.p0_pj * self.bits as f64 * self.freq_ghz
    }

    /// Quantize a value in [-1, 1] to the signed ADC grid.
    pub fn quantize(&self, x: f64) -> f64 {
        let half = (1u64 << (self.bits - 1)) as f64 - 1.0;
        (x.clamp(-1.0, 1.0) * half).round() / half
    }

    pub fn lsb(&self) -> f64 {
        1.0 / ((1u64 << (self.bits - 1)) as f64 - 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_linear_in_bits_and_freq() {
        let a = Adc::new(8, 5.0, 0.3);
        assert!((a.power_mw() - 12.0).abs() < 1e-12);
        assert!((Adc::new(4, 5.0, 0.3).power_mw() - 6.0).abs() < 1e-12);
        assert!((Adc::new(8, 2.5, 0.3).power_mw() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn quantize_signed_range() {
        let a = Adc::new(8, 5.0, 0.3);
        assert_eq!(a.quantize(2.0), 1.0);
        assert_eq!(a.quantize(-2.0), -1.0);
        assert_eq!(a.quantize(0.0), 0.0);
        let q = a.quantize(0.3);
        assert!((q - 0.3).abs() <= a.lsb() / 2.0 + 1e-12);
    }
}

//! Device library: parameterized models for every photonic / electronic
//! component in the SCATTER datapath (§3.2, §3.3.1, §3.3.4).
//!
//! Power model constants are calibrated so that the analytic models of
//! `crate::power` land on the paper's reported operating points (Table 1:
//! ~20.6 W dense LP r=c=1; Table 2; Fig. 10 waterfall). Each constant is
//! documented with its role; all are overridable through [`DeviceLibrary`].

pub mod adc;
pub mod dac;
pub mod mmi;
pub mod mzi;
pub mod mzm;
pub mod photodetector;
pub mod tia;

pub use adc::Adc;
pub use dac::{Dac, EoDac};
pub use mmi::MmiSplitter;
pub use mzi::{Mzi, MziSpec};
pub use mzm::Mzm;
pub use photodetector::Photodetector;
pub use tia::Tia;


/// All per-device constants in one place so configurations and tests can
/// override them coherently. Units: mW, pJ, µm, mm².
#[derive(Debug, Clone)]
pub struct DeviceLibrary {
    /// MZM static bias power (mW). Eq. 2 `P_mod,static`.
    pub mzm_static_mw: f64,
    /// MZM dynamic modulation energy (pJ per full-range symbol). Eq. 2 `E_mod`.
    pub mzm_energy_pj: f64,
    /// eDAC power coefficient `P0_eDAC` (pJ): P = P0 · 2^b/(b+1) · f.
    pub edac_p0_pj: f64,
    /// ADC power coefficient `P0_ADC` (pJ/bit): P = P0 · b · f.
    pub adc_p0_pj: f64,
    /// TIA static power (mW).
    pub tia_mw: f64,
    /// Photodetector bias power (mW) per PD.
    pub pd_mw: f64,
    /// PD relative photocurrent noise std (paper §3.3.2: δn_PD = 0.01).
    pub pd_noise_std: f64,
    /// Static phase-bias deviation std (rad) on *unpowered* MZIs: the
    /// fabricated φ_b ≠ π/2 exactly, so a powered-off weight MZI holds a
    /// residual weight δw ≈ −sin(δφ_bias) — the Eq.-12 leakage source
    /// (driven MZIs are programmed closed-loop and don't see it).
    pub bias_deviation_std: f64,
    /// MZI extinction ratio in dB (limits IG leakage; typical 25 dB).
    pub extinction_ratio_db: f64,
    /// Random phase-noise std on programmed MZI phases (rad).
    pub phase_noise_std: f64,
    /// Areas (mm²) of the electronic/photonic periphery.
    pub area_dac_mm2: f64,
    pub area_adc_mm2: f64,
    pub area_tia_mm2: f64,
    pub area_mzm_mm2: f64,
    pub area_pd_mm2: f64,
    /// 1×k1 MMI splitter area per input port (mm²).
    pub area_mmi_mm2: f64,
}

impl Default for DeviceLibrary {
    fn default() -> Self {
        Self {
            // ~1 mW static + 50 fJ/bit dynamic MZM (silicon-photonic MZM
            // class used by [29]).
            mzm_static_mw: 1.0,
            mzm_energy_pj: 0.05,
            // 6-bit @ 5 GHz -> P0 · (64/7) · 5 = 32 mW with P0 = 0.7 pJ.
            edac_p0_pj: 0.7,
            // 8-bit @ 5 GHz -> 0.3 · 8 · 5 = 12 mW.
            adc_p0_pj: 0.3,
            tia_mw: 1.0,
            pd_mw: 0.05,
            pd_noise_std: 0.01,
            bias_deviation_std: 0.03,
            extinction_ratio_db: 25.0,
            phase_noise_std: 0.005,
            area_dac_mm2: 0.011,
            area_adc_mm2: 0.002,
            area_tia_mm2: 0.0005,
            area_mzm_mm2: 0.024,
            area_pd_mm2: 1.0e-4,
            area_mmi_mm2: 0.002,
        }
    }
}

impl DeviceLibrary {
    /// Linear extinction ratio (power ratio max/min transmission).
    pub fn extinction_ratio_linear(&self) -> f64 {
        10f64.powf(self.extinction_ratio_db / 10.0)
    }

    /// Residual transmission of a "fully off" modulator (1/ER).
    pub fn leakage_floor(&self) -> f64 {
        1.0 / self.extinction_ratio_linear()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extinction_ratio_25db() {
        let lib = DeviceLibrary::default();
        assert!((lib.extinction_ratio_linear() - 316.2278).abs() < 1e-3);
        assert!((lib.leakage_floor() - 0.0031623).abs() < 1e-6);
    }
}

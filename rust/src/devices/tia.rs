//! Transimpedance amplifier (TIA) in the readout chain (§3.2.1, Eq. 4).
//!
//! Under light redistribution the TIA gain is reduced by k2'/k2 to restore
//! the nominal output range (§3.3.2, Eq. 14).


#[derive(Debug, Clone, Copy)]
pub struct Tia {
    /// Static power (mW).
    pub power_mw: f64,
    /// Current gain (unitless in the normalized signal chain).
    pub gain: f64,
}

impl Tia {
    pub fn new(power_mw: f64) -> Self {
        Self { power_mw, gain: 1.0 }
    }

    /// Gain rescaled for light redistribution: k2'/k2 (Eq. 14).
    pub fn with_lr_gain(self, k2_active: usize, k2: usize) -> Self {
        assert!(k2_active <= k2 && k2 > 0);
        Self { gain: self.gain * k2_active as f64 / k2 as f64, ..self }
    }

    /// Amplify a photocurrent into the ADC input range.
    #[inline]
    pub fn amplify(&self, i: f64) -> f64 {
        self.gain * i
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_gain_rescale() {
        let t = Tia::new(1.0).with_lr_gain(12, 16);
        assert!((t.gain - 0.75).abs() < 1e-12);
        assert!((t.amplify(2.0) - 1.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn lr_gain_rejects_overactive() {
        let _ = Tia::new(1.0).with_lr_gain(17, 16);
    }
}

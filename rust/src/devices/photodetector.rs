//! Balanced photodetector (BPD) pair at each crossbar node (§3.3.1) and
//! the photocurrent-noise model of Eq. 11 (`δn_PD`, std 0.01).

use crate::util::XorShiftRng;

#[derive(Debug, Clone, Copy)]
pub struct Photodetector {
    /// Bias power per PD (mW).
    pub bias_mw: f64,
    /// Relative photocurrent noise std (paper: 0.01).
    pub noise_std: f64,
    /// Responsivity (A/W) — normalized to 1 in the unitless signal chain.
    pub responsivity: f64,
}

impl Photodetector {
    pub fn new(bias_mw: f64, noise_std: f64) -> Self {
        Self { bias_mw, noise_std, responsivity: 1.0 }
    }

    /// Differential detection of the two splitter outputs: photocurrent
    /// `i = R · (P1 − P2)`, plus one noise draw (Eq. 11's δn_PD).
    pub fn detect_differential(&self, p1: f64, p2: f64, rng: &mut XorShiftRng) -> f64 {
        self.responsivity * (p1 - p2) + rng.gaussian_std(self.noise_std)
    }

    /// Noise-free differential detection.
    pub fn detect_ideal(&self, p1: f64, p2: f64) -> f64 {
        self.responsivity * (p1 - p2)
    }

    /// Power of the balanced pair (2 PDs).
    pub fn pair_power_mw(&self) -> f64 {
        2.0 * self.bias_mw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_detection_is_difference() {
        let pd = Photodetector::new(0.05, 0.01);
        assert!((pd.detect_ideal(0.8, 0.3) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn noisy_detection_statistics() {
        let pd = Photodetector::new(0.05, 0.01);
        let mut rng = XorShiftRng::new(5);
        let n = 50_000;
        let mut acc = 0.0;
        let mut acc2 = 0.0;
        for _ in 0..n {
            let v = pd.detect_differential(0.6, 0.1, &mut rng) - 0.5;
            acc += v;
            acc2 += v * v;
        }
        let mean = acc / n as f64;
        let std = (acc2 / n as f64 - mean * mean).sqrt();
        assert!(mean.abs() < 2e-4);
        assert!((std - 0.01).abs() < 5e-4);
    }
}

//! Thermo-optic MZI power splitter: the full-range multiplication engine
//! of the SCATTER crossbar node (§3.3.1, Eq. 1) and the workhorse of the
//! in-situ light rerouter.
//!
//! Transfer function (Eq. 1, with default bias φ_b = π/2):
//!
//! ```text
//!   W(Δφ) = 2 cos²((Δφ + φ_b)/2) − 1 = cos(Δφ + π/2) = −sin(Δφ)
//! ```
//!
//! so Δφ ∈ [−π/2, π/2] spans the full weight range W ∈ [−1, 1] and the
//! inverse mapping is Δφ = −arcsin(W).
//!
//! The *electrical* power to realize Δφ depends on the arm spacing l_s:
//! heating the active arm also heats the passive arm (intra-MZI crosstalk
//! coefficient γ(l_s)), shrinking the net phase difference and costing a
//! power penalty of 1/(1 − γ(l_s)) (§3.3.1, Fig. 4(c)).

use crate::thermal::gamma::GammaModel;
use std::f64::consts::{FRAC_PI_2, PI};

/// LP-MZI phase-shifter width w_PS (µm).
pub const LP_PS_WIDTH_UM: f64 = 6.0;
/// LP-MZI node length l_Y + l_PS + l_DC (µm).
pub const LP_LENGTH_UM: f64 = 115.0;
/// Foundry MZI footprint (µm).
pub const FOUNDRY_WIDTH_UM: f64 = 156.25;
pub const FOUNDRY_LENGTH_UM: f64 = 550.0;
/// Pπ of the optimized low-power MZI (mW) — §4.1.
pub const LP_P_PI_MW: f64 = 15.02;
/// Pπ of the foundry MZI switch (mW) — §3.3.1.
pub const FOUNDRY_P_PI_MW: f64 = 30.0;

/// Static spec of an MZI device variant.
#[derive(Debug, Clone, Copy)]
pub struct MziSpec {
    /// Power for a π phase shift with *ideal isolation* (mW).
    pub p_pi_mw: f64,
    /// Device length along propagation (µm).
    pub length_um: f64,
    /// Phase-shifter width (µm); node width = l_s + width for LP.
    pub ps_width_um: f64,
    /// Fixed device width, if the layout is not l_s-parameterized
    /// (foundry block). `None` -> width = l_s + ps_width_um.
    pub fixed_width_um: Option<f64>,
}

impl MziSpec {
    pub fn low_power() -> Self {
        Self {
            p_pi_mw: LP_P_PI_MW,
            length_um: LP_LENGTH_UM,
            ps_width_um: LP_PS_WIDTH_UM,
            fixed_width_um: None,
        }
    }

    pub fn foundry() -> Self {
        Self {
            p_pi_mw: FOUNDRY_P_PI_MW,
            length_um: FOUNDRY_LENGTH_UM,
            ps_width_um: LP_PS_WIDTH_UM,
            fixed_width_um: Some(FOUNDRY_WIDTH_UM),
        }
    }

    pub fn from_kind(kind: crate::config::MziKind) -> Self {
        match kind {
            crate::config::MziKind::LowPower => Self::low_power(),
            crate::config::MziKind::Foundry => Self::foundry(),
        }
    }

    /// Node width for a given arm spacing (µm).
    pub fn width_um(&self, l_s: f64) -> f64 {
        self.fixed_width_um.unwrap_or(l_s + self.ps_width_um)
    }
}

/// An MZI configured at a given arm spacing, with the γ model supplying the
/// intra-MZI thermal coupling.
#[derive(Debug, Clone)]
pub struct Mzi {
    pub spec: MziSpec,
    /// Arm (heater) spacing l_s (µm).
    pub l_s: f64,
    /// Intra-MZI coupling γ(l_s) — fraction of the heater phase leaking
    /// into the passive arm.
    gamma_ls: f64,
}

impl Mzi {
    pub fn new(spec: MziSpec, l_s: f64, gamma: &GammaModel) -> Self {
        let g = gamma.eval(l_s).clamp(0.0, 0.999);
        Self { spec, l_s, gamma_ls: g }
    }

    /// Intra-MZI coupling coefficient γ(l_s).
    pub fn intra_coupling(&self) -> f64 {
        self.gamma_ls
    }

    /// Ideal transfer: weight realized by arm phase difference Δφ (Eq. 1).
    #[inline]
    pub fn weight_from_phase(delta_phi: f64) -> f64 {
        -delta_phi.sin()
    }

    /// Inverse transfer: phase needed for weight w ∈ [−1, 1].
    #[inline]
    pub fn phase_from_weight(w: f64) -> f64 {
        -w.clamp(-1.0, 1.0).asin()
    }

    /// Power splitter ratio: fraction of input power routed to the bar
    /// port for phase Δφ, `t = cos²((Δφ + π/2)/2)` ∈ [0, 1].
    #[inline]
    pub fn split_ratio(delta_phi: f64) -> f64 {
        let half = (delta_phi + FRAC_PI_2) / 2.0;
        half.cos().powi(2)
    }

    /// Phase for a target bar-port split ratio t ∈ [0, 1]
    /// (inverse of [`Self::split_ratio`]): Δφ = 2·arccos(√t) − π/2.
    #[inline]
    pub fn phase_for_split(t: f64) -> f64 {
        2.0 * t.clamp(0.0, 1.0).sqrt().acos() - FRAC_PI_2
    }

    /// Electrical power (mW) to hold phase difference |Δφ|, including the
    /// intra-MZI penalty: P = (|Δφ|/π)·Pπ / (1 − γ(l_s)).
    ///
    /// This is the paper's simulated `P(|Δφ|, l_s)` surface (Fig. 4(c)):
    /// monotonically decreasing in l_s, linear in |Δφ|.
    #[inline]
    pub fn power_mw(&self, delta_phi: f64) -> f64 {
        (delta_phi.abs() / PI) * self.spec.p_pi_mw / (1.0 - self.gamma_ls)
    }

    /// Power to realize weight `w`, going through the inverse transfer.
    #[inline]
    pub fn power_for_weight_mw(&self, w: f64) -> f64 {
        self.power_mw(Self::phase_from_weight(w))
    }

    /// Mean power over a uniform weight distribution w ~ U[−1, 1]:
    /// E[|arcsin w|] = π/2 − 1, useful for closed-form power estimates.
    pub fn mean_power_uniform_mw(&self) -> f64 {
        ((FRAC_PI_2 - 1.0) / PI) * self.spec.p_pi_mw / (1.0 - self.gamma_ls)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::thermal::gamma::GammaModel;

    fn lp(l_s: f64) -> Mzi {
        Mzi::new(MziSpec::low_power(), l_s, &GammaModel::paper())
    }

    #[test]
    fn transfer_endpoints() {
        assert!((Mzi::weight_from_phase(-FRAC_PI_2) - 1.0).abs() < 1e-12);
        assert!((Mzi::weight_from_phase(0.0)).abs() < 1e-12);
        assert!((Mzi::weight_from_phase(FRAC_PI_2) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn transfer_matches_eq1_form() {
        // W = 2cos²((Δφ+π/2)/2) − 1 must equal −sin(Δφ)
        for i in 0..100 {
            let phi = -FRAC_PI_2 + (i as f64) * (PI / 99.0);
            let eq1 = 2.0 * ((phi + FRAC_PI_2) / 2.0).cos().powi(2) - 1.0;
            assert!((eq1 - Mzi::weight_from_phase(phi)).abs() < 1e-12);
        }
    }

    #[test]
    fn inverse_roundtrip() {
        for i in 0..41 {
            let w = -1.0 + i as f64 * 0.05;
            let phi = Mzi::phase_from_weight(w);
            assert!(phi.abs() <= FRAC_PI_2 + 1e-12);
            assert!((Mzi::weight_from_phase(phi) - w).abs() < 1e-12);
        }
    }

    #[test]
    fn split_ratio_roundtrip() {
        for i in 0..=20 {
            let t = i as f64 / 20.0;
            let phi = Mzi::phase_for_split(t);
            assert!((Mzi::split_ratio(phi) - t).abs() < 1e-12, "t={t}");
        }
    }

    #[test]
    fn power_increases_with_phase_decreases_with_spacing() {
        let m9 = lp(9.0);
        let m11 = lp(11.0);
        assert!(m9.power_mw(0.5) > 0.0);
        assert!(m9.power_mw(1.0) > m9.power_mw(0.5));
        // larger arm spacing -> smaller intra coupling -> less power (Fig 4c)
        assert!(m11.power_mw(1.0) < m9.power_mw(1.0));
        // symmetric in sign
        assert_eq!(m9.power_mw(-0.7), m9.power_mw(0.7));
    }

    #[test]
    fn pi_power_close_to_p_pi_at_large_spacing() {
        let m = lp(60.0);
        // at huge spacing the penalty vanishes
        assert!((m.power_mw(PI) - LP_P_PI_MW).abs() / LP_P_PI_MW < 0.02);
    }

    #[test]
    fn foundry_is_bigger_and_hungrier() {
        let f = MziSpec::foundry();
        let l = MziSpec::low_power();
        assert!(f.p_pi_mw > l.p_pi_mw);
        assert!(f.length_um > l.length_um);
        assert!(f.width_um(9.0) > l.width_um(9.0));
    }

    #[test]
    fn mean_uniform_power_matches_monte_carlo() {
        let m = lp(9.0);
        let mut rng = crate::util::XorShiftRng::new(11);
        let n = 200_000;
        let mut acc = 0.0;
        for _ in 0..n {
            acc += m.power_for_weight_mw(rng.uniform_in(-1.0, 1.0));
        }
        let mc = acc / n as f64;
        assert!((mc - m.mean_power_uniform_mw()).abs() / mc < 0.01);
    }
}

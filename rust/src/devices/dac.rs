//! Electronic DAC (eDAC) and hybrid electronic-optic DAC (eoDAC) models
//! (§3.2.1 Eq. 2, §3.3.4 Fig. 8).
//!
//! eDAC power:  `P = P0 · 2^b / (b + 1) · f`  — exponential in resolution,
//! linear in sampling frequency.
//!
//! The eoDAC splits a b-bit conversion across `n` low-bit eDACs driving
//! non-uniform MZM segments (e.g. a 6-bit symbol as two 3-bit segments
//! with an 8:1 actuator length ratio): power drops from `2^b/(b+1)` to
//! `n · 2^(b/n)/(b/n + 1)` at the cost of `n×` DAC area and IO pads.


/// A single electronic DAC running at `freq_ghz` with `bits` resolution.
#[derive(Debug, Clone, Copy)]
pub struct Dac {
    pub bits: u8,
    pub freq_ghz: f64,
    /// P0 coefficient in pJ (see `DeviceLibrary::edac_p0_pj`).
    pub p0_pj: f64,
}

impl Dac {
    pub fn new(bits: u8, freq_ghz: f64, p0_pj: f64) -> Self {
        Self { bits, freq_ghz, p0_pj }
    }

    /// Power in mW: P0[pJ] · 2^b/(b+1) · f[GHz] (pJ·GHz = mW).
    pub fn power_mw(&self) -> f64 {
        let b = self.bits as f64;
        self.p0_pj * (2f64.powf(b) / (b + 1.0)) * self.freq_ghz
    }

    /// Quantize a value in [0, 1] to this DAC's grid.
    pub fn quantize(&self, x: f64) -> f64 {
        let levels = (1u64 << self.bits) as f64 - 1.0;
        (x.clamp(0.0, 1.0) * levels).round() / levels
    }

    /// LSB step size.
    pub fn lsb(&self) -> f64 {
        1.0 / ((1u64 << self.bits) as f64 - 1.0)
    }
}

/// Hybrid eoDAC: `segments` eDACs of `bits_per_seg` bits each, driving MZM
/// segments with binary-weighted lengths (ratio 2^bits_per_seg : 1 for two
/// segments, the paper's 8:1 at 3 bits).
#[derive(Debug, Clone, Copy)]
pub struct EoDac {
    pub segments: u8,
    pub bits_per_seg: u8,
    pub freq_ghz: f64,
    pub p0_pj: f64,
}

impl EoDac {
    pub fn new(segments: u8, bits_per_seg: u8, freq_ghz: f64, p0_pj: f64) -> Self {
        Self { segments, bits_per_seg, freq_ghz, p0_pj }
    }

    /// Effective total resolution.
    pub fn total_bits(&self) -> u8 {
        self.segments * self.bits_per_seg
    }

    /// Total electrical DAC power in mW: n sub-DACs at b/n bits each.
    pub fn power_mw(&self) -> f64 {
        let sub = Dac::new(self.bits_per_seg, self.freq_ghz, self.p0_pj);
        self.segments as f64 * sub.power_mw()
    }

    /// Number of independent IO pads (one per segment).
    pub fn io_pads(&self) -> u32 {
        self.segments as u32
    }

    /// DAC area multiplier relative to a single full-resolution eDAC
    /// (the paper trades 2× DAC area for 2.28× power at 2 segments).
    pub fn area_factor(&self) -> f64 {
        self.segments as f64
    }

    /// Power saving factor vs a monolithic eDAC at the same total bits.
    pub fn power_saving_vs_edac(&self) -> f64 {
        let mono = Dac::new(self.total_bits(), self.freq_ghz, self.p0_pj);
        mono.power_mw() / self.power_mw()
    }

    /// Quantize x ∈ [0,1] through the segmented conversion: each segment
    /// contributes its sub-word scaled by its binary weight. Equivalent to
    /// a full-resolution quantization when segment lengths are ideal.
    pub fn quantize(&self, x: f64) -> f64 {
        let total_levels = (1u64 << self.total_bits()) as f64 - 1.0;
        let code = (x.clamp(0.0, 1.0) * total_levels).round() as u64;
        // decompose into segments (MSB first) and reassemble — with ideal
        // 2^b-weighted segments this is exact; mismatch modeled elsewhere.
        let mut acc = 0u64;
        for s in (0..self.segments).rev() {
            let shift = s * self.bits_per_seg;
            let word = (code >> shift) & ((1 << self.bits_per_seg) - 1);
            acc |= word << shift;
        }
        acc as f64 / total_levels
    }

    /// Symbol-level SNR advantage (dB) over the monolithic eDAC from
    /// relaxed per-segment swing: each 3-bit segment has 8× wider symbol
    /// spacing than a 6-bit symbol at the same swing -> 20·log10(2^(b−b/n))
    /// potential eye opening improvement. Reported for Fig. 8.
    pub fn snr_gain_db(&self) -> f64 {
        let b = self.total_bits() as f64;
        let bs = self.bits_per_seg as f64;
        20.0 * ((b - bs) * std::f64::consts::LN_2 / std::f64::consts::LN_10)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edac_power_formula() {
        // 6-bit @ 5 GHz, P0=0.7pJ: 0.7 * 64/7 * 5 = 32 mW
        let d = Dac::new(6, 5.0, 0.7);
        assert!((d.power_mw() - 32.0).abs() < 1e-9);
    }

    #[test]
    fn paper_ratio_2p28x() {
        // Fig. 8: two 3-bit eDACs vs one 6-bit eDAC -> 64/7 vs 2*8/4 = 2.2857x
        let eo = EoDac::new(2, 3, 5.0, 0.7);
        assert!((eo.power_saving_vs_edac() - 64.0 / 7.0 / 4.0).abs() < 1e-9);
        assert!((eo.power_saving_vs_edac() - 2.2857).abs() < 1e-3);
        assert_eq!(eo.total_bits(), 6);
        assert_eq!(eo.io_pads(), 2);
        assert_eq!(eo.area_factor(), 2.0);
    }

    #[test]
    fn further_partitioning_diminishing_returns() {
        // Fig. 8: the first split is the big win (2.3x); three 2-bit
        // segments tie with two 3-bit ones (2*8/4 = 3*4/3 = 4 units), and
        // the pure optical DAC (6 x 1-bit) costs MORE power again while
        // tripling the pads — exactly the paper's "negligible benefit,
        // more area/layout complexity" conclusion.
        let eo2 = EoDac::new(2, 3, 5.0, 0.7);
        let eo3 = EoDac::new(3, 2, 5.0, 0.7);
        let eo6 = EoDac::new(6, 1, 5.0, 0.7);
        let gain12 = Dac::new(6, 5.0, 0.7).power_mw() / eo2.power_mw();
        assert!(gain12 > 2.0, "first split is the big win");
        assert!((eo3.power_mw() - eo2.power_mw()).abs() < 1e-9, "second split is free at best");
        assert!(eo6.power_mw() > eo3.power_mw(), "pure optical DAC costs more");
        assert!(eo6.area_factor() == 6.0);
    }

    #[test]
    fn quantize_matches_monolithic_when_ideal() {
        let eo = EoDac::new(2, 3, 5.0, 0.7);
        let mono = Dac::new(6, 5.0, 0.7);
        for i in 0..=100 {
            let x = i as f64 / 100.0;
            assert!((eo.quantize(x) - mono.quantize(x)).abs() < 1e-12);
        }
    }

    #[test]
    fn quantize_is_idempotent_and_bounded() {
        let d = Dac::new(6, 5.0, 0.7);
        for i in 0..=50 {
            let x = i as f64 / 50.0;
            let q = d.quantize(x);
            assert!((0.0..=1.0).contains(&q));
            assert_eq!(d.quantize(q), q);
            assert!((q - x).abs() <= d.lsb() / 2.0 + 1e-12);
        }
    }

    #[test]
    fn snr_gain_positive() {
        let eo = EoDac::new(2, 3, 5.0, 0.7);
        assert!(eo.snr_gain_db() > 0.0);
    }
}

//! Passive 1×k even MMI splitter (§3.3.1): broadcasts the modulated input
//! to the k1 crossbar columns. The rerouter (crate::rerouter) replaces the
//! *input-side* splitter tree; this MMI stays on the broadcast side.


#[derive(Debug, Clone, Copy)]
pub struct MmiSplitter {
    pub fanout: usize,
    /// Excess insertion loss in dB (beyond the ideal 1/k split).
    pub excess_loss_db: f64,
}

impl MmiSplitter {
    pub fn new(fanout: usize) -> Self {
        Self { fanout, excess_loss_db: 0.1 }
    }

    /// Per-port transmission: (1/k) · 10^(−loss/10).
    pub fn per_port_transmission(&self) -> f64 {
        (1.0 / self.fanout as f64) * 10f64.powf(-self.excess_loss_db / 10.0)
    }

    /// Split an input power evenly to all ports.
    pub fn split(&self, p_in: f64) -> Vec<f64> {
        vec![p_in * self.per_port_transmission(); self.fanout]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_split_conserves_power_up_to_loss() {
        let m = MmiSplitter::new(16);
        let out = m.split(1.0);
        assert_eq!(out.len(), 16);
        let total: f64 = out.iter().sum();
        assert!(total <= 1.0);
        assert!(total > 0.95); // 0.1 dB excess loss
        assert!((out[0] - out[15]).abs() < 1e-15);
    }
}

//! High-speed Mach-Zehnder modulator (MZM) for input encoding (§3.2.1).
//!
//! Power: `P_mod = P_mod,static + E_mod · f` (Eq. 2). When input gating is
//! active on a pruned port the supply is cut, but light still leaks through
//! at the extinction-ratio floor (the §3.3.2 leakage term that light
//! redistribution eliminates).


#[derive(Debug, Clone, Copy)]
pub struct Mzm {
    /// Static bias power (mW).
    pub static_mw: f64,
    /// Dynamic modulation energy (pJ per symbol).
    pub energy_pj: f64,
    /// Clock in GHz.
    pub freq_ghz: f64,
    /// Extinction-ratio leakage floor (fraction of light passing when off).
    pub leakage_floor: f64,
}

impl Mzm {
    pub fn new(static_mw: f64, energy_pj: f64, freq_ghz: f64, leakage_floor: f64) -> Self {
        Self { static_mw, energy_pj, freq_ghz, leakage_floor }
    }

    /// Active modulation power in mW (Eq. 2): static + E·f.
    pub fn power_mw(&self) -> f64 {
        self.static_mw + self.energy_pj * self.freq_ghz
    }

    /// Transmission for a target intensity x ∈ [0, 1]: the device cannot
    /// go below the extinction floor.
    pub fn transmission(&self, x: f64) -> f64 {
        x.clamp(0.0, 1.0).max(self.leakage_floor)
    }

    /// Transmission when the driver is power-gated: the floor.
    pub fn gated_transmission(&self) -> f64 {
        self.leakage_floor
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_eq2() {
        let m = Mzm::new(1.0, 0.05, 5.0, 0.003);
        assert!((m.power_mw() - 1.25).abs() < 1e-12);
    }

    #[test]
    fn leakage_floor_enforced() {
        let m = Mzm::new(1.0, 0.05, 5.0, 0.003);
        assert_eq!(m.transmission(0.0), 0.003);
        assert_eq!(m.transmission(0.5), 0.5);
        assert_eq!(m.gated_transmission(), 0.003);
    }
}

//! SCATTER command-line interface.
//!
//! ```text
//! scatter serve  [--config FILE] [--addr 127.0.0.1:8080] [--workers N]
//!         [--engine-threads N] [--precision exact|quantized] [--max-batch N] [--max-in-flight N]
//!         [--deadline-ms N] [--density D] [--steal]
//!         [--thermal off|threshold[:RAD]|periodic[:N]] [--brownout RAD]
//!         [--faults SPEC] [--watchdog-ms N] [--dst on[:PERIOD_MS]|off]
//!         [--device-faults SPEC] [--sentinel]
//! scatter bench <table1|table2|table3|fig4|fig5|fig6|fig8|fig9|fig10|engine|serve|drift|chaos|swap|repair|all>
//!         [--samples N] [--models cnn3,vgg8,resnet18] [--threads 1,2,4,8] [--stages]
//!         [--rps R] [--duration S] [--concurrency C] [--addr HOST:PORT]
//!         [--workers N] [--max-batch 1,8] [--replicas 1,4] [--steal] [--seed N]
//! scatter config [--preset default|dense|foundry] [--out FILE]
//! scatter gamma  [--heatsim]
//! scatter info
//! ```
//!
//! Every subcommand answers `--help` with a generated flag table
//! ([`scatter::util::FlagTable`] — the offline toolchain has no clap).
//!
//! `serve` exposes the inference service over HTTP (`POST /v1/predict`,
//! `GET /healthz`, `GET /metrics`); EOF or `quit` on stdin drains
//! gracefully. `--config FILE` loads a [`ServerConfig`] JSON document
//! (write a starting point with `ServerConfig::default().to_json()`;
//! see README §Serving); CLI flags override the file, and the merged
//! config passes builder validation before anything spawns. `--thermal`
//! enables the runtime drift model + online recalibration policy;
//! `--steal` lets idle replicas pull queued shards from the deepest
//! backlog.
//!
//! `bench engine` sweeps the sparsity-compiled execution engine and
//! writes `BENCH_engine.json`; `bench serve` load-tests the TCP
//! endpoint, sweeps `--max-batch` and `--replicas`, and writes
//! `BENCH_server.json`; `bench drift` measures accuracy/recalibration
//! under the thermal-drift schedule and writes `BENCH_drift.json`;
//! `bench chaos` kills every worker once (seeded `FaultPlan`) under
//! concurrent load, measures recovery, and writes `BENCH_chaos.json`;
//! `bench swap` runs in-serving DST mask hot-swap (promote + injected
//! bad-canary rollback) under load and writes `BENCH_swap.json`;
//! `bench repair` breaks photonic devices mid-serve, measures sentinel
//! detection latency + quarantine accuracy recovery, and writes
//! `BENCH_repair.json`.
//!
//! `--faults` takes the grammar accepted by `FaultPlan::parse`
//! (e.g. `panic@w0:s3,stall@w1:s5:200ms` or `kill-each:42`);
//! `--device-faults` takes the hardware-defect grammar of
//! `DeviceFaultPlan::parse` (e.g. `stuck@conv2:c0:r1:i3:p0.9` or
//! `rand:s7:n4`), and `--sentinel` arms the probe + quarantine-repair
//! loop against whatever breaks.

use scatter::bench::{self, BenchCtx};
use scatter::config::AcceleratorConfig;
use scatter::coordinator::{
    DstServerConfig, EngineOptions, FaultPlan, HttpServer, InferenceServer, NetConfig,
    ServerConfig, ThermalServerConfig,
};
use scatter::ptc::DeviceFaultPlan;
use scatter::thermal::{DriftConfig, ThermalPolicy};
use scatter::util::{FlagTable, ParsedArgs};
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "serve" => cmd_serve(&args[1..]),
        "bench" => cmd_bench(&args[1..]),
        "config" => cmd_config(&args[1..]),
        "gamma" => cmd_gamma(&args[1..]),
        "info" => cmd_info(),
        _ => {
            eprintln!(
                "usage: scatter <serve|bench|config|gamma|info> [...]\n\
                 \n\
                 serve   the networked inference service (scatter serve --help)\n\
                 bench   paper tables/figures + engine/serve/drift/chaos perf\n\
                 \x20       benches (scatter bench --help)\n\
                 config  print or write an AcceleratorConfig preset\n\
                 gamma   print the thermal crosstalk model gamma(d)\n\
                 info    chip area / power / runtime summary\n\
                 \n\
                 each subcommand answers --help with its full flag table"
            );
            std::process::exit(if cmd == "help" { 0 } else { 2 });
        }
    }
}

// ---------------------------------------------------------------------------
// shared flag-table plumbing
// ---------------------------------------------------------------------------

/// Parse `args` against `table`: `--help` prints the generated screen
/// and exits 0; a parse error prints the error plus the screen and
/// exits 2.
fn parse_or_exit(table: &FlagTable, args: &[String]) -> ParsedArgs {
    match table.parse(args) {
        Ok(p) if p.wants_help() => {
            print!("{}", table.help_text());
            std::process::exit(0);
        }
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e}\n");
            eprint!("{}", table.help_text());
            std::process::exit(2);
        }
    }
}

/// Typed flag lookup; an unparseable value is a usage error (exit 2),
/// never a silent default.
fn get_or_exit<T: std::str::FromStr>(p: &ParsedArgs, name: &str) -> Option<T> {
    p.get(name).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    })
}

/// Comma-separated typed list (`--replicas 1,4`), same error policy.
fn get_list_or_exit<T: std::str::FromStr>(p: &ParsedArgs, name: &str) -> Option<Vec<T>> {
    p.get_list(name).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    })
}

// ---------------------------------------------------------------------------
// serve
// ---------------------------------------------------------------------------

fn serve_flags() -> FlagTable {
    FlagTable::new(
        "scatter serve [options]",
        "Serve batched inference over HTTP (POST /v1/predict, GET /healthz, GET /metrics).\n\
         EOF or 'quit' on stdin drains gracefully. Flags override --config FILE values;\n\
         the merged config is validated before anything spawns.",
    )
    .flag("--addr", "HOST:PORT", "bind address (default 127.0.0.1:8080)")
    .flag("--config", "FILE", "ServerConfig JSON to start from (README §Serving)")
    .flag("--density", "D", "backbone density of the CNN-3 deployment (default 0.3)")
    .flag("--workers", "N", "engine-worker replicas (default 2)")
    .flag("--engine-threads", "N", "compute threads per replica (default 1)")
    .flag("--precision", "MODE", "kernel precision: exact | quantized (default exact)")
    .flag("--max-batch", "N", "max requests fused per engine pass (default 8)")
    .flag("--max-in-flight", "N", "admission cap before shedding 503s (default 256)")
    .flag("--deadline-ms", "N", "per-request deadline (default: none)")
    .flag("--watchdog-ms", "N", "supervisor stuck-worker threshold")
    .flag("--thermal", "SPEC", "off | threshold[:RAD] | periodic[:N] drift policy")
    .flag("--brownout", "RAD", "phase-error budget that triggers replica brownout")
    .flag("--faults", "SPEC", "fault injection plan (FaultPlan grammar, e.g. kill-each:42)")
    .flag("--dst", "SPEC", "in-serving DST mask hot-swap: on[:PERIOD_MS] | off")
    .flag(
        "--device-faults",
        "SPEC",
        "hardware defects (DeviceFaultPlan grammar, e.g. stuck@conv2:c0:r1:i3:p0.9)",
    )
    .switch("--sentinel", "arm the sentinel probe + mask-quarantine repair loop")
    .switch("--steal", "idle replicas steal queued shards from the deepest backlog")
}

/// Stand up the networked inference front-end and serve until stdin
/// closes (EOF) or reads `quit`, then drain gracefully and report.
fn cmd_serve(args: &[String]) {
    let table = serve_flags();
    let p = parse_or_exit(&table, args);
    let addr = p.value("--addr").unwrap_or("127.0.0.1:8080").to_string();
    let density: f64 = get_or_exit(&p, "--density").unwrap_or(0.3);

    // base config: --config FILE when given, else the serve defaults
    let base = match p.value("--config") {
        Some(path) => {
            let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
                eprintln!("cannot read --config {path}: {e}");
                std::process::exit(2);
            });
            ServerConfig::from_json(&text).unwrap_or_else(|e| {
                eprintln!("bad --config {path}: {e}");
                std::process::exit(2);
            })
        }
        None => ServerConfig::builder()
            .workers(2)
            .batch_timeout(Duration::from_millis(4))
            .build()
            .expect("default serve config validates"),
    };

    // CLI flags layer on top of the base; faults parse against the
    // final worker count so `kill-each` covers every replica
    let workers = get_or_exit::<usize>(&p, "--workers").unwrap_or(base.workers());
    let mut b = base.to_builder().workers(workers);
    if let Some(n) = get_or_exit::<usize>(&p, "--engine-threads") {
        b = b.engine_threads(n);
    }
    if let Some(s) = p.value("--precision") {
        let mode = s.parse::<scatter::exec::KernelPrecision>().unwrap_or_else(|e| {
            eprintln!("error: --precision: {e}");
            std::process::exit(2);
        });
        b = b.precision(mode);
    }
    if let Some(n) = get_or_exit::<usize>(&p, "--max-batch") {
        b = b.max_batch(n);
    }
    if let Some(n) = get_or_exit::<usize>(&p, "--max-in-flight") {
        b = b.max_in_flight(n);
    }
    if let Some(ms) = get_or_exit::<u64>(&p, "--deadline-ms") {
        b = b.default_deadline(Some(Duration::from_millis(ms)));
    }
    if let Some(ms) = get_or_exit::<u64>(&p, "--watchdog-ms") {
        b = b.watchdog(Duration::from_millis(ms));
    }
    if p.has("--steal") {
        b = b.steal(true);
    }
    let mut thermal = match p.value("--thermal") {
        Some(spec) => parse_thermal(spec),
        None => base.thermal().clone(),
    };
    if let Some(rad) = get_or_exit::<f64>(&p, "--brownout") {
        thermal.brownout_budget_rad = Some(rad);
    }
    b = b.thermal(thermal);
    if let Some(spec) = p.value("--faults") {
        b = b.faults(FaultPlan::parse(spec, workers).unwrap_or_else(|e| {
            eprintln!("bad --faults '{spec}': {e}");
            std::process::exit(2);
        }));
    }
    if let Some(spec) = p.value("--dst") {
        b = b.dst(parse_dst(spec));
    }
    if let Some(spec) = p.value("--device-faults") {
        b = b.device_faults(DeviceFaultPlan::parse(spec).unwrap_or_else(|e| {
            eprintln!("bad --device-faults '{spec}': {e}");
            std::process::exit(2);
        }));
    }
    if p.has("--sentinel") {
        b = b.sentinel(true);
    }
    let server_cfg = b.build().unwrap_or_else(|e| {
        eprintln!("invalid server config: {e}");
        std::process::exit(2);
    });
    if !server_cfg.faults().is_empty() {
        for line in server_cfg.faults().describe() {
            eprintln!("fault injection armed: {line}");
        }
    }
    if !server_cfg.repair().device_faults.is_empty() {
        for line in server_cfg.repair().device_faults.describe() {
            eprintln!("device defect armed: {line}");
        }
    }

    eprintln!("loading CNN-3 deployment (density {density}) ...");
    let ctx = BenchCtx::new(50);
    let acc = AcceleratorConfig::default();
    let (model, _ds, masks) =
        ctx.deployment(bench::common::Workload::Cnn3, &acc, density);
    let server =
        InferenceServer::spawn(model, acc, EngineOptions::NOISY, masks, server_cfg);
    let http = HttpServer::bind(server, NetConfig { addr: addr.clone(), ..Default::default() })
        .unwrap_or_else(|e| {
            eprintln!("cannot bind {addr}: {e}");
            std::process::exit(1);
        });
    eprintln!("serving on http://{}", http.local_addr());
    eprintln!("  POST /v1/predict   {{\"image\":[...784 floats]}}");
    eprintln!("  GET  /healthz | /metrics");
    eprintln!("EOF or 'quit' on stdin drains and exits.");

    let mut line = String::new();
    loop {
        line.clear();
        match std::io::stdin().read_line(&mut line) {
            Ok(0) => break,
            Ok(_) if line.trim() == "quit" => break,
            Ok(_) => {}
            Err(_) => break,
        }
    }
    eprintln!("draining ...");
    match http.shutdown() {
        Ok(r) => {
            if r.faults_injected > 0 {
                eprintln!(
                    "device faults: {} injected, {} detected, {} repaired, \
                     {} unrepairable, {} replica(s) degraded",
                    r.faults_injected,
                    r.fault_detections,
                    r.fault_repairs,
                    r.fault_unrepairable,
                    r.degraded.iter().filter(|&&d| d).count()
                );
            }
            eprintln!(
                "served {} requests in {} batches (mean occupancy {:.2}, {:.1} req/s, \
                 p50 {} us, p99 {} us, {:.3} mJ, shed {}, expired {}, recal {}x/{} chunks, \
                 workers {} live, {} respawns, {} retries, {} brownouts, {} steals, \
                 mask swaps {}/{} rollbacks, top generation {})",
                r.requests, r.batches, r.mean_batch_occupancy, r.throughput_rps, r.p50_us,
                r.p99_us, r.energy_mj, r.shed, r.expired, r.recalibrations, r.recal_chunks,
                r.workers_live, r.worker_restarts, r.request_retries, r.brownouts, r.steals,
                r.mask_swaps, r.mask_rollbacks,
                r.mask_generation.iter().copied().max().unwrap_or(0)
            );
        }
        Err(e) => eprintln!("shutdown error: {e}"),
    }
}

/// `--thermal off | threshold[:BUDGET_RAD] | periodic[:EVERY_REQS]` →
/// drift runtime config (default schedule, per-policy knobs inline).
/// A present-but-unparseable knob is an error, never a silent default.
fn parse_thermal(spec: &str) -> ThermalServerConfig {
    fn knob<T: std::str::FromStr>(spec: &str, rest: &str, default: T) -> T {
        match rest.strip_prefix(':') {
            None if rest.is_empty() => default,
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("bad --thermal value '{spec}': cannot parse '{v}'");
                std::process::exit(2);
            }),
            _ => {
                eprintln!("unknown --thermal '{spec}' (off|threshold[:RAD]|periodic[:N])");
                std::process::exit(2);
            }
        }
    }
    let policy = if spec == "off" {
        return ThermalServerConfig::default();
    } else if let Some(rest) = spec.strip_prefix("threshold") {
        ThermalPolicy::Threshold { budget_rad: knob(spec, rest, 0.02) }
    } else if let Some(rest) = spec.strip_prefix("periodic") {
        ThermalPolicy::Periodic { every_requests: knob(spec, rest, 256) }
    } else {
        eprintln!("unknown --thermal '{spec}' (off|threshold[:RAD]|periodic[:N])");
        std::process::exit(2);
    };
    ThermalServerConfig { drift: Some(DriftConfig::default()), policy, ..Default::default() }
}

/// `--dst on[:PERIOD_MS] | off` → in-serving DST + mask hot-swap
/// config. Everything beyond the stepping period (rounds, canary
/// threshold, artifact directory) stays a `--config FILE` concern.
fn parse_dst(spec: &str) -> DstServerConfig {
    if spec == "off" {
        return DstServerConfig::default();
    }
    let Some(rest) = spec.strip_prefix("on") else {
        eprintln!("unknown --dst '{spec}' (on[:PERIOD_MS]|off)");
        std::process::exit(2);
    };
    let period_ms: u64 = match rest.strip_prefix(':') {
        None if rest.is_empty() => 20,
        Some(v) => v.parse().unwrap_or_else(|_| {
            eprintln!("bad --dst value '{spec}': cannot parse '{v}'");
            std::process::exit(2);
        }),
        _ => {
            eprintln!("unknown --dst '{spec}' (on[:PERIOD_MS]|off)");
            std::process::exit(2);
        }
    };
    DstServerConfig {
        enabled: true,
        period: Duration::from_millis(period_ms),
        ..Default::default()
    }
}

// ---------------------------------------------------------------------------
// bench
// ---------------------------------------------------------------------------

fn bench_flags() -> FlagTable {
    FlagTable::new(
        "scatter bench <target> [options]",
        "Run paper reproductions and perf benches. Targets: table1 table2 table3\n\
         fig4 fig5 fig6 fig8 fig9 fig10 engine serve drift chaos swap repair all.",
    )
    .flag("--samples", "N", "evaluation samples (engine: time budget = N*10 ms/cell)")
    .flag("--models", "A,B", "table3 workloads (cnn3,vgg8,resnet18)")
    .flag("--threads", "A,B", "engine bench thread sweep (default 1,2,4,8)")
    .switch("--stages", "engine bench: per-stage latency breakdown")
    .flag("--rps", "R", "bench serve: open-loop arrival rate (0 = closed loop)")
    .flag("--duration", "S", "bench serve/chaos/swap/repair: seconds per measurement")
    .flag("--concurrency", "C", "bench serve/chaos/swap/repair: concurrent client connections")
    .flag("--addr", "HOST:PORT", "bench serve: drive an external server (skips sweeps)")
    .flag("--workers", "N", "bench serve/chaos/swap/repair: engine-worker replicas for the main run")
    .flag("--max-batch", "A,B", "bench serve: batched-compute sweep points (0 disables)")
    .flag("--replicas", "A,B", "bench serve: replica-scaling sweep points (0 disables)")
    .switch("--steal", "bench serve: enable work stealing on in-process servers")
    .flag("--seed", "N", "bench chaos: fault-plan seed")
}

fn cmd_bench(args: &[String]) {
    let table = bench_flags();
    let p = parse_or_exit(&table, args);
    let which = p.positionals().first().map(String::as_str).unwrap_or("all");
    let samples: usize = get_or_exit(&p, "--samples").unwrap_or(100);
    let ctx = BenchCtx::new(samples);
    match which {
        "table1" => println!("{}", bench::table1::run(&ctx)),
        "table2" => println!("{}", bench::table2::run(&ctx)),
        "table3" => {
            let models = p.value("--models").unwrap_or("cnn3,vgg8,resnet18");
            let workloads: Vec<_> = models
                .split(',')
                .filter_map(|m| match m.trim() {
                    "cnn3" => Some(bench::common::Workload::Cnn3),
                    "vgg8" => Some(bench::common::Workload::Vgg8),
                    "resnet18" => Some(bench::common::Workload::Resnet18),
                    _ => None,
                })
                .collect();
            println!("{}", bench::table3::run_models(&ctx, &workloads));
        }
        "fig4" => println!("{}", bench::fig4::run(&ctx)),
        "fig5" => println!("{}", bench::fig5::run(&ctx)),
        "fig6" => println!("{}", bench::fig6::run(&ctx)),
        "fig8" => println!("{}", bench::fig8::run(&ctx)),
        "fig9" => {
            println!("{}", bench::fig9::run_a(&ctx));
            println!("{}", bench::fig9::run_b(&ctx));
        }
        "fig10" => println!("{}", bench::fig10::run(&ctx)),
        "drift" => println!("{}", bench::drift::run(&ctx)),
        "engine" => {
            let threads =
                get_list_or_exit::<usize>(&p, "--threads").unwrap_or_else(|| vec![1, 2, 4, 8]);
            // --samples doubles as the per-cell time budget (ms × 10):
            // the default 100 gives ~1 s per cell
            let budget = std::time::Duration::from_millis((samples as u64) * 10);
            println!("{}", bench::engine::run(&threads, budget, p.has("--stages")));
        }
        "serve" => {
            let mut cfg = bench::serve::ServeBenchConfig {
                rps: get_or_exit::<f64>(&p, "--rps").unwrap_or(0.0),
                duration: Duration::from_secs_f64(
                    get_or_exit::<f64>(&p, "--duration").unwrap_or(2.0),
                ),
                concurrency: get_or_exit::<usize>(&p, "--concurrency").unwrap_or(4),
                addr: p.value("--addr").map(String::from),
                workers: get_or_exit::<usize>(&p, "--workers").unwrap_or(2),
                steal: p.has("--steal"),
                ..Default::default()
            };
            // sweep points: `--max-batch 0` / `--replicas 0` disable
            if let Some(list) = get_list_or_exit::<usize>(&p, "--max-batch") {
                cfg.sweep_max_batch = list.into_iter().filter(|&b| b > 0).collect();
            }
            if let Some(list) = get_list_or_exit::<usize>(&p, "--replicas") {
                cfg.sweep_replicas = list.into_iter().filter(|&r| r > 0).collect();
            }
            println!("{}", bench::serve::run(&cfg));
        }
        "chaos" => {
            let cfg = bench::chaos::ChaosBenchConfig {
                duration: Duration::from_secs_f64(
                    get_or_exit::<f64>(&p, "--duration").unwrap_or(4.0),
                ),
                concurrency: get_or_exit::<usize>(&p, "--concurrency").unwrap_or(4),
                workers: get_or_exit::<usize>(&p, "--workers").unwrap_or(3),
                seed: get_or_exit::<u64>(&p, "--seed").unwrap_or(42),
            };
            println!("{}", bench::chaos::run(&cfg));
        }
        "swap" => {
            let cfg = bench::swap::SwapBenchConfig {
                duration: Duration::from_secs_f64(
                    get_or_exit::<f64>(&p, "--duration").unwrap_or(4.0),
                ),
                concurrency: get_or_exit::<usize>(&p, "--concurrency").unwrap_or(4),
                workers: get_or_exit::<usize>(&p, "--workers").unwrap_or(2),
                ..Default::default()
            };
            println!("{}", bench::swap::run(&cfg));
        }
        "repair" => {
            let cfg = bench::repair::RepairBenchConfig {
                duration: Duration::from_secs_f64(
                    get_or_exit::<f64>(&p, "--duration").unwrap_or(4.0),
                ),
                concurrency: get_or_exit::<usize>(&p, "--concurrency").unwrap_or(4),
                workers: get_or_exit::<usize>(&p, "--workers").unwrap_or(2),
                ..Default::default()
            };
            println!("{}", bench::repair::run(&cfg));
        }
        "all" => bench::run_all(&ctx),
        other => {
            eprintln!("unknown bench target '{other}'");
            std::process::exit(2);
        }
    }
}

// ---------------------------------------------------------------------------
// config / gamma / info
// ---------------------------------------------------------------------------

fn config_flags() -> FlagTable {
    FlagTable::new(
        "scatter config [options]",
        "Print (or write) an AcceleratorConfig preset as JSON.",
    )
    .flag("--preset", "NAME", "default | dense | foundry")
    .flag("--out", "FILE", "write to FILE instead of stdout")
}

fn cmd_config(args: &[String]) {
    let table = config_flags();
    let p = parse_or_exit(&table, args);
    let cfg = match p.value("--preset").unwrap_or("default") {
        "dense" => AcceleratorConfig::dense_optimal(),
        "foundry" => AcceleratorConfig::foundry_baseline(),
        _ => AcceleratorConfig::default(),
    };
    let json = cfg.to_json();
    match p.value("--out") {
        Some(path) => {
            std::fs::write(path, &json).expect("write config");
            eprintln!("wrote {path}");
        }
        None => println!("{json}"),
    }
}

fn gamma_flags() -> FlagTable {
    FlagTable::new(
        "scatter gamma [options]",
        "Print the thermal crosstalk model gamma(d).",
    )
    .switch("--heatsim", "characterize gamma from the finite-difference heat solver")
}

fn cmd_gamma(args: &[String]) {
    use scatter::thermal::GammaModel;
    let table = gamma_flags();
    let p = parse_or_exit(&table, args);
    if p.has("--heatsim") {
        let (samples, model) = scatter::thermal::heatsim::characterize(
            &scatter::thermal::heatsim::HeatSimConfig::default(),
            23.0,
        );
        println!("# heat-solver gamma(d) samples and piecewise refit");
        println!("# d_um  gamma_sample  gamma_fit");
        for (d, g) in samples {
            println!("{d:6.1}  {g:.6}  {:.6}", model.eval(d));
        }
    } else {
        let g = GammaModel::paper();
        println!("# paper Eq.-10 gamma(d)");
        for (d, v) in g.sample(60.0, 1.0) {
            println!("{d:6.1}  {v:.6}");
        }
    }
}

fn cmd_info() {
    let cfg = AcceleratorConfig::default();
    let area = scatter::area::AreaModel::with_defaults(cfg.clone());
    let power = scatter::power::PowerModel::with_defaults(cfg.clone());
    println!("SCATTER digital twin");
    println!("  default config: R={} C={} k1={} k2={} r={} c={} f={} GHz",
        cfg.tiles_r, cfg.cores_c, cfg.k1, cfg.k2, cfg.share_r, cfg.share_c, cfg.freq_ghz);
    println!("  chip area     : {:.2} mm^2", area.total_mm2());
    println!("  dense power   : {:.2} W (closed form)", power.dense(None).total_w());
    match scatter::runtime::ArtifactRuntime::new("artifacts") {
        Ok(rt) => println!("  PJRT platform : {}", rt.platform()),
        Err(e) => println!("  PJRT platform : unavailable ({e})"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every subcommand's declared flag table, plus one flag known to
    /// be in it and whether that flag takes a value (for the
    /// duplicate-spelling probes).
    fn all_tables() -> Vec<(&'static str, FlagTable, &'static str, bool)> {
        vec![
            ("serve", serve_flags(), "--workers", true),
            ("bench", bench_flags(), "--samples", true),
            ("config", config_flags(), "--preset", true),
            ("gamma", gamma_flags(), "--heatsim", false),
        ]
    }

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    /// Satellite: no subcommand silently swallows a flag it never
    /// declared — the error names the offending flag.
    #[test]
    fn every_subcommand_table_rejects_unknown_flags() {
        for (cmd, table, _, _) in all_tables() {
            let err = table
                .parse(&args(&["--no-such-flag"]))
                .expect_err("unknown flag must fail");
            assert!(
                err.contains("--no-such-flag"),
                "{cmd}: error must name the flag: {err}"
            );
            let err = table
                .parse(&args(&["--no-such-flag=7"]))
                .expect_err("unknown inline flag must fail");
            assert!(err.contains("--no-such-flag"), "{cmd}: {err}");
        }
    }

    /// The self-repair CLI surface: `--device-faults SPEC` and the
    /// `--sentinel` switch parse on `serve`, and the fault spec is
    /// recoverable verbatim.
    #[test]
    fn serve_table_accepts_device_fault_flags() {
        let p = serve_flags()
            .parse(&args(&["--device-faults", "dead-pd@conv2:c0:r1", "--sentinel"]))
            .expect("device-fault flags parse");
        assert_eq!(p.value("--device-faults"), Some("dead-pd@conv2:c0:r1"));
        assert!(p.has("--sentinel"));
    }

    /// Satellite: a repeated flag is rejected on every subcommand — the
    /// second spelling must not silently win.
    #[test]
    fn every_subcommand_table_rejects_duplicate_flags() {
        for (cmd, table, flag, takes_value) in all_tables() {
            // value flags get a dummy value; switches repeat bare
            let argv: Vec<&str> = if takes_value {
                vec![flag, "1", flag, "2"]
            } else {
                vec![flag, flag]
            };
            let err = table.parse(&args(&argv)).expect_err("duplicate must fail");
            assert!(
                err.contains("duplicate") && err.contains(flag),
                "{cmd}: duplicate error must name {flag}: {err}"
            );
        }
    }
}

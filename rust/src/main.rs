//! SCATTER command-line interface.
//!
//! ```text
//! scatter serve  [--addr 127.0.0.1:8080] [--workers N] [--engine-threads N]
//!         [--max-batch N] [--max-in-flight N] [--deadline-ms N] [--density D]
//!         [--thermal off|threshold[:RAD]|periodic[:N]] [--brownout RAD]
//!         [--faults SPEC] [--watchdog-ms N]
//! scatter bench <table1|table2|table3|fig4|fig5|fig6|fig8|fig9|fig10|engine|serve|drift|chaos|all>
//!         [--samples N] [--models cnn3,vgg8,resnet18] [--threads 1,2,4,8] [--stages]
//!         [--rps R] [--duration S] [--concurrency C] [--addr HOST:PORT]
//!         [--max-batch 1,8] [--seed N]
//! scatter config [--preset default|dense|foundry] [--out FILE]
//! scatter gamma  [--heatsim]
//! scatter info
//! ```
//!
//! `serve` exposes the inference service over HTTP (`POST /v1/predict`,
//! `GET /healthz`, `GET /metrics`); EOF or `quit` on stdin drains
//! gracefully; `--thermal` enables the runtime drift model + online
//! recalibration policy. `bench engine` sweeps the sparsity-compiled
//! execution engine and writes `BENCH_engine.json`; `bench serve`
//! load-tests the TCP endpoint and writes `BENCH_server.json`; `bench
//! drift` measures accuracy/recalibration under the thermal-drift
//! schedule and writes `BENCH_drift.json`; `bench chaos` kills every
//! worker once (seeded `FaultPlan`) under concurrent load, measures
//! recovery, and writes `BENCH_chaos.json`.
//!
//! `--faults` takes the grammar accepted by `FaultPlan::parse`
//! (e.g. `panic@w0:s3,stall@w1:s5:200ms` or `kill-each:42`).
//!
//! (Hand-rolled parsing: the offline toolchain has no clap.)

use scatter::bench::{self, BenchCtx};
use scatter::config::AcceleratorConfig;
use scatter::coordinator::{
    AdmissionConfig, EngineOptions, FaultPlan, HttpServer, InferenceServer, NetConfig,
    ServerConfig, SupervisorConfig, ThermalServerConfig,
};
use scatter::thermal::{DriftConfig, ThermalPolicy};
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "serve" => cmd_serve(&args[1..]),
        "bench" => cmd_bench(&args[1..]),
        "config" => cmd_config(&args[1..]),
        "gamma" => cmd_gamma(&args[1..]),
        "info" => cmd_info(),
        _ => {
            eprintln!(
                "usage: scatter <serve|bench|config|gamma|info> [...]\n\
                 \n\
                 serve  [--addr 127.0.0.1:8080] [--workers N] [--engine-threads N]\n\
                 \x20      [--max-batch N] [--max-in-flight N] [--deadline-ms N] [--density D]\n\
                 \x20      [--thermal off|threshold[:RAD]|periodic[:N]] [--brownout RAD]\n\
                 \x20      [--faults SPEC] [--watchdog-ms N]\n\
                 bench <table1|table2|table3|fig4|fig5|fig6|fig8|fig9|fig10|engine|serve|drift|chaos|all>\n\
                 \x20      [--samples N] [--models cnn3,vgg8,resnet18] [--threads 1,2,4,8] [--stages]\n\
                 \x20      [--rps R] [--duration S] [--concurrency C] [--addr HOST:PORT]\n\
                 \x20      [--max-batch 1,8] [--seed N]\n\
                 config [--preset default|dense|foundry] [--out FILE]\n\
                 gamma  [--heatsim]\n\
                 info"
            );
            std::process::exit(if cmd == "help" { 0 } else { 2 });
        }
    }
}

/// Stand up the networked inference front-end and serve until stdin
/// closes (EOF) or reads `quit`, then drain gracefully and report.
fn cmd_serve(args: &[String]) {
    let addr = flag_value(args, "--addr").unwrap_or("127.0.0.1:8080").to_string();
    let parse_usize = |name: &str, default: usize| {
        flag_value(args, name).and_then(|s| s.parse().ok()).unwrap_or(default)
    };
    let density: f64 =
        flag_value(args, "--density").and_then(|s| s.parse().ok()).unwrap_or(0.3);
    let workers = parse_usize("--workers", 2);
    let mut thermal = parse_thermal(flag_value(args, "--thermal"));
    if let Some(rad) = flag_value(args, "--brownout") {
        thermal.brownout_budget_rad = Some(rad.parse().unwrap_or_else(|_| {
            eprintln!("bad --brownout value '{rad}': expected radians (e.g. 0.02)");
            std::process::exit(2);
        }));
    }
    let faults = match flag_value(args, "--faults") {
        Some(spec) => FaultPlan::parse(spec, workers).unwrap_or_else(|e| {
            eprintln!("bad --faults '{spec}': {e}");
            std::process::exit(2);
        }),
        None => FaultPlan::none(),
    };
    let mut supervisor = SupervisorConfig::default();
    if let Some(ms) = flag_value(args, "--watchdog-ms") {
        supervisor.watchdog = Duration::from_millis(ms.parse().unwrap_or_else(|_| {
            eprintln!("bad --watchdog-ms value '{ms}': expected milliseconds");
            std::process::exit(2);
        }));
    }
    if !faults.is_empty() {
        for line in faults.describe() {
            eprintln!("fault injection armed: {line}");
        }
    }
    let server_cfg = ServerConfig {
        max_batch: parse_usize("--max-batch", 8),
        batch_timeout: Duration::from_millis(4),
        workers,
        engine_threads: parse_usize("--engine-threads", 1),
        admission: AdmissionConfig {
            max_in_flight: parse_usize("--max-in-flight", 256),
            default_deadline: flag_value(args, "--deadline-ms")
                .and_then(|s| s.parse().ok())
                .map(Duration::from_millis),
            ..Default::default()
        },
        thermal,
        supervisor,
        faults,
    };

    eprintln!("loading CNN-3 deployment (density {density}) ...");
    let ctx = BenchCtx::new(50);
    let acc = AcceleratorConfig::default();
    let (model, _ds, masks) =
        ctx.deployment(bench::common::Workload::Cnn3, &acc, density);
    let server =
        InferenceServer::spawn(model, acc, EngineOptions::NOISY, masks, server_cfg);
    let http = HttpServer::bind(server, NetConfig { addr: addr.clone(), ..Default::default() })
        .unwrap_or_else(|e| {
            eprintln!("cannot bind {addr}: {e}");
            std::process::exit(1);
        });
    eprintln!("serving on http://{}", http.local_addr());
    eprintln!("  POST /v1/predict   {{\"image\":[...784 floats]}}");
    eprintln!("  GET  /healthz | /metrics");
    eprintln!("EOF or 'quit' on stdin drains and exits.");

    let mut line = String::new();
    loop {
        line.clear();
        match std::io::stdin().read_line(&mut line) {
            Ok(0) => break,
            Ok(_) if line.trim() == "quit" => break,
            Ok(_) => {}
            Err(_) => break,
        }
    }
    eprintln!("draining ...");
    match http.shutdown() {
        Ok(r) => eprintln!(
            "served {} requests in {} batches (mean occupancy {:.2}, {:.1} req/s, \
             p50 {} us, p99 {} us, {:.3} mJ, shed {}, expired {}, recal {}x/{} chunks, \
             workers {} live, {} respawns, {} retries, {} brownouts)",
            r.requests, r.batches, r.mean_batch_occupancy, r.throughput_rps, r.p50_us,
            r.p99_us, r.energy_mj, r.shed, r.expired, r.recalibrations, r.recal_chunks,
            r.workers_live, r.worker_restarts, r.request_retries, r.brownouts
        ),
        Err(e) => eprintln!("shutdown error: {e}"),
    }
}

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).map(String::as_str)
}

/// `--thermal off | threshold[:BUDGET_RAD] | periodic[:EVERY_REQS]` →
/// drift runtime config (default schedule, per-policy knobs inline).
/// A present-but-unparseable knob is an error, never a silent default.
fn parse_thermal(spec: Option<&str>) -> ThermalServerConfig {
    fn knob<T: std::str::FromStr>(spec: &str, rest: &str, default: T) -> T {
        match rest.strip_prefix(':') {
            None if rest.is_empty() => default,
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("bad --thermal value '{spec}': cannot parse '{v}'");
                std::process::exit(2);
            }),
            _ => {
                eprintln!("unknown --thermal '{spec}' (off|threshold[:RAD]|periodic[:N])");
                std::process::exit(2);
            }
        }
    }
    let Some(spec) = spec else { return ThermalServerConfig::default() };
    let policy = if spec == "off" {
        return ThermalServerConfig::default();
    } else if let Some(rest) = spec.strip_prefix("threshold") {
        ThermalPolicy::Threshold { budget_rad: knob(spec, rest, 0.02) }
    } else if let Some(rest) = spec.strip_prefix("periodic") {
        ThermalPolicy::Periodic { every_requests: knob(spec, rest, 256) }
    } else {
        eprintln!("unknown --thermal '{spec}' (off|threshold[:RAD]|periodic[:N])");
        std::process::exit(2);
    };
    ThermalServerConfig { drift: Some(DriftConfig::default()), policy, ..Default::default() }
}

fn cmd_bench(args: &[String]) {
    let which = args.first().map(String::as_str).unwrap_or("all");
    let samples: usize =
        flag_value(args, "--samples").and_then(|s| s.parse().ok()).unwrap_or(100);
    let ctx = BenchCtx::new(samples);
    match which {
        "table1" => println!("{}", bench::table1::run(&ctx)),
        "table2" => println!("{}", bench::table2::run(&ctx)),
        "table3" => {
            let models = flag_value(args, "--models").unwrap_or("cnn3,vgg8,resnet18");
            let workloads: Vec<_> = models
                .split(',')
                .filter_map(|m| match m.trim() {
                    "cnn3" => Some(bench::common::Workload::Cnn3),
                    "vgg8" => Some(bench::common::Workload::Vgg8),
                    "resnet18" => Some(bench::common::Workload::Resnet18),
                    _ => None,
                })
                .collect();
            println!("{}", bench::table3::run_models(&ctx, &workloads));
        }
        "fig4" => println!("{}", bench::fig4::run(&ctx)),
        "fig5" => println!("{}", bench::fig5::run(&ctx)),
        "fig6" => println!("{}", bench::fig6::run(&ctx)),
        "fig8" => println!("{}", bench::fig8::run(&ctx)),
        "fig9" => {
            println!("{}", bench::fig9::run_a(&ctx));
            println!("{}", bench::fig9::run_b(&ctx));
        }
        "fig10" => println!("{}", bench::fig10::run(&ctx)),
        "drift" => println!("{}", bench::drift::run(&ctx)),
        "engine" => {
            let threads: Vec<usize> = flag_value(args, "--threads")
                .unwrap_or("1,2,4,8")
                .split(',')
                .filter_map(|t| t.trim().parse().ok())
                .collect();
            // --samples doubles as the per-cell time budget (ms × 10):
            // the default 100 gives ~1 s per cell
            let budget = std::time::Duration::from_millis((samples as u64) * 10);
            let stages = args.iter().any(|a| a == "--stages");
            println!("{}", bench::engine::run(&threads, budget, stages));
        }
        "serve" => {
            let mut cfg = bench::serve::ServeBenchConfig {
                rps: flag_value(args, "--rps").and_then(|s| s.parse().ok()).unwrap_or(0.0),
                duration: Duration::from_secs_f64(
                    flag_value(args, "--duration").and_then(|s| s.parse().ok()).unwrap_or(2.0),
                ),
                concurrency: flag_value(args, "--concurrency")
                    .and_then(|s| s.parse().ok())
                    .unwrap_or(4),
                addr: flag_value(args, "--addr").map(String::from),
                ..Default::default()
            };
            cfg.server.workers =
                flag_value(args, "--workers").and_then(|s| s.parse().ok()).unwrap_or(2);
            // batched-compute sweep points (default 1,8 → the CI-gated
            // per_image_throughput_b8/b1 ratio); `--max-batch 0` disables
            if let Some(list) = flag_value(args, "--max-batch") {
                cfg.sweep_max_batch = list
                    .split(',')
                    .filter_map(|b| b.trim().parse().ok())
                    .filter(|&b: &usize| b > 0)
                    .collect();
            }
            println!("{}", bench::serve::run(&cfg));
        }
        "chaos" => {
            let cfg = bench::chaos::ChaosBenchConfig {
                duration: Duration::from_secs_f64(
                    flag_value(args, "--duration").and_then(|s| s.parse().ok()).unwrap_or(4.0),
                ),
                concurrency: flag_value(args, "--concurrency")
                    .and_then(|s| s.parse().ok())
                    .unwrap_or(4),
                workers: flag_value(args, "--workers")
                    .and_then(|s| s.parse().ok())
                    .unwrap_or(3),
                seed: flag_value(args, "--seed").and_then(|s| s.parse().ok()).unwrap_or(42),
            };
            println!("{}", bench::chaos::run(&cfg));
        }
        "all" => bench::run_all(&ctx),
        other => {
            eprintln!("unknown bench target '{other}'");
            std::process::exit(2);
        }
    }
}

fn cmd_config(args: &[String]) {
    let cfg = match flag_value(args, "--preset").unwrap_or("default") {
        "dense" => AcceleratorConfig::dense_optimal(),
        "foundry" => AcceleratorConfig::foundry_baseline(),
        _ => AcceleratorConfig::default(),
    };
    let json = cfg.to_json();
    match flag_value(args, "--out") {
        Some(path) => {
            std::fs::write(path, &json).expect("write config");
            eprintln!("wrote {path}");
        }
        None => println!("{json}"),
    }
}

fn cmd_gamma(args: &[String]) {
    use scatter::thermal::GammaModel;
    if args.iter().any(|a| a == "--heatsim") {
        let (samples, model) = scatter::thermal::heatsim::characterize(
            &scatter::thermal::heatsim::HeatSimConfig::default(),
            23.0,
        );
        println!("# heat-solver gamma(d) samples and piecewise refit");
        println!("# d_um  gamma_sample  gamma_fit");
        for (d, g) in samples {
            println!("{d:6.1}  {g:.6}  {:.6}", model.eval(d));
        }
    } else {
        let g = GammaModel::paper();
        println!("# paper Eq.-10 gamma(d)");
        for (d, v) in g.sample(60.0, 1.0) {
            println!("{d:6.1}  {v:.6}");
        }
    }
}

fn cmd_info() {
    let cfg = AcceleratorConfig::default();
    let area = scatter::area::AreaModel::with_defaults(cfg.clone());
    let power = scatter::power::PowerModel::with_defaults(cfg.clone());
    println!("SCATTER digital twin");
    println!("  default config: R={} C={} k1={} k2={} r={} c={} f={} GHz",
        cfg.tiles_r, cfg.cores_c, cfg.k1, cfg.k2, cfg.share_r, cfg.share_c, cfg.freq_ghz);
    println!("  chip area     : {:.2} mm^2", area.total_mm2());
    println!("  dense power   : {:.2} W (closed form)", power.dense(None).total_w());
    match scatter::runtime::ArtifactRuntime::new("artifacts") {
        Ok(rt) => println!("  PJRT platform : {}", rt.platform()),
        Err(e) => println!("  PJRT platform : unavailable ({e})"),
    }
}
